//! Quickstart: train DSEKL on the XOR problem (Fig. 1 of the paper)
//! through the unified estimator API, evaluate on held-out data, save +
//! reload the model.
//!
//! Run: `cargo run --release --example quickstart`
//! With the AOT path: `cargo run --release --example quickstart -- pjrt`
//! (requires `make artifacts`).

use dsekl::data::synth;
use dsekl::estimator::{Fit, FitBackend, TrainSet};
use dsekl::model::KernelModel;
use dsekl::rng::Pcg64;
use dsekl::runtime::BackendSpec;

fn main() -> dsekl::Result<()> {
    // Pick the backend: native rust compute, or the PJRT path that
    // executes the jax/Pallas AOT artifacts.
    let backend_arg = std::env::args().nth(1).unwrap_or_else(|| "native".into());
    let spec = BackendSpec::parse(&backend_arg, "artifacts")?;
    let mut backend = FitBackend::new(spec);

    // The paper's Fig. 1 workload: 2-d XOR, gaussian clusters (std 0.2).
    let mut rng = Pcg64::seed_from(7);
    let data = synth::xor(200, 0.2, &mut rng);
    let (train, test) = data.split(0.5, &mut rng);
    println!("train: {} points, test: {} points", train.len(), test.len());

    // Algorithm 1 behind the one front door: swap `.parallel(4)` in for
    // the coordinator, or hand a multiclass/CSR set to the same call.
    let fitted = Fit::dsekl()
        .gamma(1.0) // RBF width
        .lam(1e-4) // L2 regularisation
        .sizes(32, 32) // gradient sample |I|, expansion sample |J|
        .iters(500)
        .fit(&mut backend, TrainSet::from(&train), &mut rng)?;
    println!(
        "trained {} iterations ({} gradient samples) in {:.2}s on {}",
        fitted.stats.iterations,
        fitted.stats.points_processed,
        fitted.stats.elapsed_s,
        backend.leader()?.name(),
    );

    let train_err = fitted
        .predictor
        .error(backend.leader()?, &TrainSet::from(&train))?;
    let test_err = fitted
        .predictor
        .error(backend.leader()?, &TrainSet::from(&test))?;
    println!("train error: {train_err:.3}, test error: {test_err:.3}");
    let model = fitted.predictor.as_kernel().expect("binary kernel model");
    println!(
        "support vectors: {} / {}",
        model.n_support(1e-6),
        model.len()
    );

    // Persist and reload.
    let path = std::env::temp_dir().join("quickstart.dsekl");
    fitted.predictor.save_file(&path)?;
    let loaded = KernelModel::load_file(&path)?;
    let reload_err = loaded.error(backend.leader()?, &test)?;
    assert_eq!(test_err, reload_err);
    println!("model round-tripped through {}", path.display());
    Ok(())
}
