//! Quickstart: train DSEKL on the XOR problem (Fig. 1 of the paper),
//! evaluate on held-out data, save + reload the model.
//!
//! Run: `cargo run --release --example quickstart`
//! With the AOT path: `cargo run --release --example quickstart -- pjrt`
//! (requires `make artifacts`).

use dsekl::data::synth;
use dsekl::rng::Pcg64;
use dsekl::runtime::BackendSpec;
use dsekl::model::KernelModel;
use dsekl::solver::dsekl::{DseklOpts, DseklSolver};

fn main() -> dsekl::Result<()> {
    // Pick the backend: native rust compute, or the PJRT path that
    // executes the jax/Pallas AOT artifacts.
    let backend_arg = std::env::args().nth(1).unwrap_or_else(|| "native".into());
    let spec = BackendSpec::parse(&backend_arg, "artifacts")?;
    let mut backend = spec.instantiate()?;
    println!("backend: {}", backend.name());

    // The paper's Fig. 1 workload: 2-d XOR, gaussian clusters (std 0.2).
    let mut rng = Pcg64::seed_from(7);
    let data = synth::xor(200, 0.2, &mut rng);
    let (train, test) = data.split(0.5, &mut rng);
    println!("train: {} points, test: {} points", train.len(), test.len());

    // Algorithm 1: doubly stochastic SGD on the dual coefficients.
    let opts = DseklOpts {
        gamma: 1.0,  // RBF width
        lam: 1e-4,   // L2 regularisation
        i_size: 32,  // gradient sample |I|
        j_size: 32,  // kernel expansion sample |J|
        max_iters: 500,
        ..Default::default()
    };
    let result = DseklSolver::new(opts).train(backend.as_mut(), &train, &mut rng)?;
    println!(
        "trained {} iterations ({} gradient samples) in {:.2}s",
        result.stats.iterations, result.stats.points_processed, result.stats.elapsed_s
    );

    let train_err = result.model.error(backend.as_mut(), &train)?;
    let test_err = result.model.error(backend.as_mut(), &test)?;
    println!("train error: {train_err:.3}, test error: {test_err:.3}");
    println!(
        "support vectors: {} / {}",
        result.model.n_support(1e-6),
        result.model.len()
    );

    // Persist and reload.
    let path = std::env::temp_dir().join("quickstart.dsekl");
    result.model.save_file(&path)?;
    let loaded = KernelModel::load_file(&path)?;
    let reload_err = loaded.error(backend.as_mut(), &test)?;
    assert_eq!(test_err, reload_err);
    println!("model round-tripped through {}", path.display());
    Ok(())
}
