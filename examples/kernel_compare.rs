//! Kernel-approximation face-off (a fast cut of Figure 2): the doubly
//! stochastic empirical kernel map (Emp) vs random kitchen sinks (RKS)
//! vs a fixed random subset (Emp_Fix) vs the batch SVM, on XOR, at a
//! small and a large expansion budget.
//!
//! Run: `cargo run --release --example kernel_compare`

use dsekl::experiments::fig2::{run_cell, CellCfg, Method};
use dsekl::estimator::FitBackend;

fn main() -> dsekl::Result<()> {
    let mut be = FitBackend::native();
    println!("XOR N=100, 5 reps, 400 iters — test error (mean ± std)\n");
    println!("{:<10} {:>16} {:>16}", "method", "J = 4", "J = 64");
    for method in Method::ALL {
        let small = run_cell(
            &mut be,
            method,
            &CellCfg {
                i_size: 32,
                j_size: 4,
                reps: 5,
                ..Default::default()
            },
        )?;
        let large = run_cell(
            &mut be,
            method,
            &CellCfg {
                i_size: 32,
                j_size: 64,
                reps: 5,
                ..Default::default()
            },
        )?;
        println!(
            "{:<10} {:>7.3} ± {:<5.3} {:>7.3} ± {:<5.3}",
            method.label(),
            small.0,
            small.1,
            large.0,
            large.1
        );
    }
    println!(
        "\nReading: with a tiny expansion budget the explicit map (RKS) \
         competes, but once J grows the empirical kernel map (Emp) \
         closes on the batch SVM — the paper's Fig. 2 story."
    );
    Ok(())
}
