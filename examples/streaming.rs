//! Streaming / online DSEKL (the paper's future-work extension): learn
//! a drifting nonlinear stream prequentially (test-then-train) under a
//! fixed expansion budget.
//!
//! Run: `cargo run --release --example streaming`

use dsekl::data::synth;
use dsekl::rng::Pcg64;
use dsekl::runtime::NativeBackend;
use dsekl::solver::online::{OnlineDsekl, OnlineOpts};

fn main() -> dsekl::Result<()> {
    let mut rng = Pcg64::seed_from(3);
    let mut be = NativeBackend::new();
    let mut learner = OnlineDsekl::new(
        OnlineOpts {
            gamma: 1.0,
            budget: 128, // expansion cap: memory & predict cost bounded
            chunk: 16,
            ..Default::default()
        },
        2,
    );

    println!("streaming XOR, budget 128, prequential error per 500-item window:");
    let mut window_wrong = 0usize;
    let stream = synth::xor(5_000, 0.2, &mut rng);
    for idx in 0..stream.len() {
        let score = learner.observe(&mut be, stream.row(idx), stream.y[idx], &mut rng)?;
        if score * stream.y[idx] <= 0.0 {
            window_wrong += 1;
        }
        if (idx + 1) % 500 == 0 {
            println!(
                "  items {:>5}: window error {:.3}  (expansion {}/{})",
                idx + 1,
                window_wrong as f64 / 500.0,
                learner.expansion_len(),
                128
            );
            window_wrong = 0;
        }
    }
    let _ = learner.step(&mut be)?; // flush the last partial chunk

    // Freeze the stream model and reuse it offline.
    let model = learner.to_model().compact(1e-6);
    let test = synth::xor(1_000, 0.2, &mut rng);
    let err = model.error(&mut be, &test)?;
    println!(
        "\nfrozen model: {} support vectors, offline test error {:.3}",
        model.len(),
        err
    );
    Ok(())
}
