//! Hyper-parameter selection as in the paper's §4 protocol: exhaustive
//! grid search with two-fold cross-validation, here on the
//! diabetes-analogue dataset; the winner refits through the unified
//! estimator API.
//!
//! Run: `cargo run --release --example gridsearch`

use dsekl::data::{synth, Scaler};
use dsekl::estimator::{Fit, FitBackend, TrainSet};
use dsekl::hyper::{grid_search_dsekl, GridSpec};
use dsekl::rng::Pcg64;
use dsekl::solver::dsekl::DseklOpts;

fn main() -> dsekl::Result<()> {
    let mut rng = Pcg64::seed_from(1);
    let pool = synth::diabetes_like(768, &mut rng);
    let (mut train, mut test) = pool.split(0.5, &mut rng);
    let scaler = Scaler::fit(&train);
    scaler.transform(&mut train);
    scaler.transform(&mut test);

    let base = DseklOpts {
        i_size: 64,
        j_size: 64,
        max_iters: 300,
        ..Default::default()
    };
    let spec = GridSpec::default();
    println!(
        "grid: {} gammas x {} lambdas x {} step sizes = {} candidates, 2-fold CV",
        spec.gammas.len(),
        spec.lams.len(),
        spec.eta0s.len(),
        spec.candidates().len()
    );

    let mut be = FitBackend::native();
    let res = grid_search_dsekl(&mut be, &train, &base, &spec, 2, 42)?;
    println!(
        "best: gamma={} lambda={} eta0={} (cv error {:.3})",
        res.best.gamma, res.best.lam, res.best.eta0, res.best_cv_error
    );

    // Refit on the full training split with the winner and report test
    // error (the paper's held-out protocol).
    let fitted = Fit::dsekl()
        .gamma(res.best.gamma)
        .lam(res.best.lam)
        .eta0(res.best.eta0)
        .sizes(base.i_size, base.j_size)
        .iters(600)
        .fit(&mut be, TrainSet::from(&train), &mut rng)?;
    let err = fitted.predictor.error(be.leader()?, &TrainSet::from(&test))?;
    println!("held-out test error with best params: {err:.3} (paper, diabetes: 0.20)");
    Ok(())
}
