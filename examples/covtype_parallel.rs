//! End-to-end driver on a real large workload (DESIGN.md §4, E6): train
//! the parallel shared-memory DSEKL solver (Algorithm 2) on covtype-like
//! data, logging the validation-error curve — a scaled-down live run of
//! Figure 3a. All three layers compose here when run with the `pjrt`
//! argument: rust coordinator -> PJRT executables -> HLO lowered from
//! the jax model that calls the Pallas kernels.
//!
//! Run:   cargo run --release --example covtype_parallel
//!        cargo run --release --example covtype_parallel -- pjrt
//! Env:   COVTYPE_N=60000 COVTYPE_BATCH=2048 COVTYPE_WORKERS=4

use std::sync::Arc;

use dsekl::coordinator::{ParallelDsekl, ParallelOpts};
use dsekl::data::synth;
use dsekl::metrics::error_rate;
use dsekl::rng::Pcg64;
use dsekl::runtime::BackendSpec;

fn env_or(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> dsekl::Result<()> {
    let backend_arg = std::env::args().nth(1).unwrap_or_else(|| "native".into());
    let spec = BackendSpec::parse(&backend_arg, "artifacts")?;

    let n = env_or("COVTYPE_N", 20_000);
    let batch = env_or("COVTYPE_BATCH", 1_024);
    let workers = env_or("COVTYPE_WORKERS", 4);

    println!("covtype-like: N={n} D=54, batch I=J={batch}, {workers} workers");
    let mut rng = Pcg64::seed_from(42);
    let train = Arc::new(synth::covtype_like(n, &mut rng));
    let val = synth::covtype_like(1_122, &mut rng); // paper's holdout size
    let eval = synth::covtype_like(5_000, &mut rng);
    println!(
        "positive rate: {:.3} (covtype class-2 share: 0.488)",
        train.positive_rate()
    );

    let opts = ParallelOpts {
        gamma: 1.0,            // paper: RBF scale fixed to 1.0
        lam: 1.0 / n as f32,   // paper: lambda = 1/N
        i_size: batch,
        j_size: batch,
        workers,
        max_epochs: 6,
        tol: 1.0,              // paper's stopping criterion
        eta0: 1.0,
        eval_every_rounds: 1,
        ..Default::default()
    };
    let res = ParallelDsekl::new(opts).train(&spec, &train, Some(&val), 42)?;

    println!("\npoints_processed  round  train_loss  val_error");
    for p in &res.stats.trace.points {
        if let Some(v) = p.val_error {
            println!(
                "{:>16}  {:>5}  {:>10.4}  {:>9.4}",
                p.points_processed, p.iteration, p.loss, v
            );
        }
    }

    let mut backend = spec.instantiate()?;
    let scores = res.model.scores(backend.as_mut(), &eval)?;
    let eval_err = error_rate(&scores, &eval.y);
    println!(
        "\nepochs: {} (converged: {}), wall: {:.1}s",
        res.stats.iterations, res.stats.converged, res.stats.elapsed_s
    );
    println!("final evaluation error: {:.2}% (paper, full covtype: 13.34%)", eval_err * 100.0);
    println!(
        "throughput: {:.0} gradient samples/s; serial fraction {:.4}",
        res.stats.points_processed as f64 / res.stats.elapsed_s,
        res.telemetry.serial_fraction()
    );
    Ok(())
}
