//! Microbenchmarks of the compute hot paths: kernel block evaluation,
//! fused DSEKL step and prediction, native vs PJRT, across tile sizes.
//! This is the §Perf harness (EXPERIMENTS.md) — criterion is not in the
//! offline crate set, so timing is a hand-rolled best-of-R loop.
//!
//! Run: `cargo bench --bench micro_kernels`.

use std::time::Instant;

use dsekl::data::SparseDataset;
use dsekl::kernel::Kernel;
use dsekl::rng::{Pcg64, Rng};
use dsekl::runtime::{Backend, BackendSpec, MultiStepInput, NativeBackend, Rows, StepInput};

/// Best-of-reps wall time of `f`, in seconds.
fn time_best<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    // One warmup (compile caches, page faults).
    f();
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t = Instant::now();
        f();
        best = best.min(t.elapsed().as_secs_f64());
    }
    best
}

fn randv(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn pjrt() -> Option<Box<dyn Backend>> {
    if !cfg!(feature = "pjrt") {
        return None; // built without PJRT support
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        return None;
    }
    BackendSpec::Pjrt {
        artifacts_dir: dir,
    }
    .instantiate()
    .ok()
}

fn main() {
    let mut rng = Pcg64::seed_from(42);
    let mut native: Box<dyn Backend> = Box::new(NativeBackend::new());
    let mut pjrt_be = pjrt();
    let reps = 5;

    println!("# micro_kernels — best of {reps} (seconds); GFLOP/s for the 2*i*j*d cross term");
    println!(
        "\n| op | shape | native s | native GF/s | pjrt s | pjrt GF/s |\n|---|---|---|---|---|---|"
    );

    for &(i, j, d) in &[
        (64usize, 64usize, 8usize),
        (256, 256, 64),
        (256, 256, 784),
        (1024, 1024, 64),
        (1024, 1024, 784),
    ] {
        let xi = randv(&mut rng, i * d);
        let yi: Vec<f32> = (0..i).map(|_| rng.sign()).collect();
        let xj = randv(&mut rng, j * d);
        let alpha = randv(&mut rng, j);
        let kernel = Kernel::rbf(1.0 / d as f32);
        let flops = 2.0 * i as f64 * j as f64 * d as f64;

        // kernel block
        let mut out = Vec::new();
        let tn = time_best(reps, || {
            native
                .kernel_block(kernel, Rows::dense(&xi, i, d), Rows::dense(&xj, j, d), &mut out)
                .unwrap()
        });
        let tp = pjrt_be.as_mut().map(|b| {
            let mut out = Vec::new();
            time_best(reps, || {
                b.kernel_block(kernel, Rows::dense(&xi, i, d), Rows::dense(&xj, j, d), &mut out)
                    .unwrap()
            })
        });
        print_row("kernel_block", i, j, d, tn, flops, tp);

        // fused step (2x the cross-term flops: scores + transposed grad)
        let inp = StepInput {
            xi: Rows::dense(&xi, i, d),
            yi: &yi,
            xj: Rows::dense(&xj, j, d),
            alpha: &alpha,
            lam: 1e-4,
            frac: 0.1,
            loss: dsekl::loss::Loss::Hinge,
        };
        let mut g = Vec::new();
        let tn = time_best(reps, || {
            native.dsekl_step(kernel, &inp, &mut g).unwrap();
        });
        let tp = pjrt_be.as_mut().map(|b| {
            let mut g = Vec::new();
            time_best(reps, || {
                b.dsekl_step(kernel, &inp, &mut g).unwrap();
            })
        });
        print_row("dsekl_step", i, j, d, tn, 2.0 * flops, tp);

        // prediction
        let mut f = Vec::new();
        let tn = time_best(reps, || {
            native
                .predict(kernel, Rows::dense(&xi, i, d), Rows::dense(&xj, j, d), &alpha, &mut f)
                .unwrap()
        });
        let tp = pjrt_be.as_mut().map(|b| {
            let mut f = Vec::new();
            time_best(reps, || {
                b.predict(kernel, Rows::dense(&xi, i, d), Rows::dense(&xj, j, d), &alpha, &mut f)
                    .unwrap()
            })
        });
        print_row("predict", i, j, d, tn, flops, tp);
    }
    if pjrt_be.is_none() {
        println!("\n(pjrt columns empty: run `make artifacts` first)");
    }

    // Fused K-head step (one shared kernel block, K residual/gradient
    // heads — the one-vs-rest structure) vs K independent single-head
    // steps over the same batch.
    println!("\n# fused K-head step vs K independent steps (native)");
    println!("| K | shape | looped s | fused s | speedup |\n|---|---|---|---|---|");
    for &heads in &[4usize, 7] {
        for &(i, j, d) in &[(256usize, 256usize, 64usize), (1024, 1024, 64)] {
            let xi = randv(&mut rng, i * d);
            let xj = randv(&mut rng, j * d);
            let yi: Vec<f32> = (0..heads * i).map(|_| rng.sign()).collect();
            let alpha = randv(&mut rng, heads * j);
            let kernel = Kernel::rbf(1.0 / d as f32);
            let lam = 1e-4f32;
            let frac = 0.1f32;
            let loss = dsekl::loss::Loss::Hinge;

            let mut g = Vec::new();
            let t_looped = time_best(reps, || {
                for h in 0..heads {
                    native
                        .dsekl_step(
                            kernel,
                            &StepInput {
                                xi: Rows::dense(&xi, i, d),
                                yi: &yi[h * i..(h + 1) * i],
                                xj: Rows::dense(&xj, j, d),
                                alpha: &alpha[h * j..(h + 1) * j],
                                lam,
                                frac,
                                loss,
                            },
                            &mut g,
                        )
                        .unwrap();
                }
            });

            let mut gm = Vec::new();
            let t_fused = time_best(reps, || {
                native
                    .dsekl_step_multi(
                        kernel,
                        &MultiStepInput {
                            xi: Rows::dense(&xi, i, d),
                            yi: &yi,
                            xj: Rows::dense(&xj, j, d),
                            alpha: &alpha,
                            heads,
                            lam,
                            frac,
                            loss,
                        },
                        &mut gm,
                    )
                    .unwrap();
            });
            println!(
                "| {heads} | {i}x{j}x{d} | {t_looped:.5} | {t_fused:.5} | {:.2}x |",
                t_looped / t_fused
            );
        }
    }

    // Sparse (CSR) vs dense kernel_block at rcv1-like densities: the
    // sparse path's work scales with nnz, so the speedup should track
    // ~1/density at the low end (minus bookkeeping overhead).
    println!("\n# sparse (CSR) vs dense kernel_block (native, RBF)");
    println!("| density | shape | dense s | sparse s | speedup |\n|---|---|---|---|---|");
    for &density in &[0.01f64, 0.1, 0.5] {
        for &(i, j, d) in &[(256usize, 256usize, 1024usize), (1024, 1024, 1024)] {
            let mut si = SparseDataset::with_dim(d);
            let mut sj = SparseDataset::with_dim(d);
            for (ds, n) in [(&mut si, i), (&mut sj, j)] {
                for _ in 0..n {
                    let mut cols = Vec::new();
                    let mut vals = Vec::new();
                    for c in 0..d {
                        if rng.range_f64(0.0, 1.0) < density {
                            cols.push(c as u32);
                            vals.push(rng.normal() as f32);
                        }
                    }
                    ds.push(&cols, &vals, 1.0);
                }
            }
            let xi = si.densify_x();
            let xj = sj.densify_x();
            let kernel = Kernel::rbf(1.0 / d as f32);
            let mut out = Vec::new();
            let t_dense = time_best(reps, || {
                native
                    .kernel_block(kernel, Rows::dense(&xi, i, d), Rows::dense(&xj, j, d), &mut out)
                    .unwrap()
            });
            let t_sparse = time_best(reps, || {
                native
                    .kernel_block(kernel, si.rows(), sj.rows(), &mut out)
                    .unwrap()
            });
            println!(
                "| {density} | {i}x{j}x{d} | {t_dense:.5} | {t_sparse:.5} | {:.2}x |",
                t_dense / t_sparse
            );
        }
    }

    // CSR-store vs dense-store model prediction: the same expansion
    // rows held as a CSR-backed vs a dense ExpansionStore, scoring a
    // sparse test batch — the serving-side win of the O(nnz) model
    // path (DSEKLv3 models predict straight from CSR rows).
    println!("\n# CSR-store vs dense-store predict (native, RBF)");
    println!("| density | shape | dense-store s | csr-store s | speedup |\n|---|---|---|---|---|");
    for &density in &[0.01f64, 0.1] {
        for &(t, j, d) in &[(512usize, 1024usize, 1024usize)] {
            let mut sj = SparseDataset::with_dim(d);
            let mut st = SparseDataset::with_dim(d);
            for (ds, n) in [(&mut sj, j), (&mut st, t)] {
                for _ in 0..n {
                    let mut cols = Vec::new();
                    let mut vals = Vec::new();
                    for c in 0..d {
                        if rng.range_f64(0.0, 1.0) < density {
                            cols.push(c as u32);
                            vals.push(rng.normal() as f32);
                        }
                    }
                    ds.push(&cols, &vals, 1.0);
                }
            }
            let alpha = randv(&mut rng, j);
            let kernel = Kernel::rbf(1.0 / d as f32);
            let csr_model = dsekl::model::KernelModel::from_store(
                kernel,
                dsekl::model::ExpansionStore::from_rows(sj.rows()),
                alpha.clone(),
            );
            let dense_model =
                dsekl::model::KernelModel::new(kernel, sj.densify_x(), alpha, d);
            let t_dense = time_best(reps, || {
                dense_model.scores_rows(native.as_mut(), st.rows()).unwrap();
            });
            let t_csr = time_best(reps, || {
                csr_model.scores_rows(native.as_mut(), st.rows()).unwrap();
            });
            println!(
                "| {density} | {t}x{j}x{d} | {t_dense:.5} | {t_csr:.5} | {:.2}x |",
                t_dense / t_csr
            );
        }
    }
}

fn print_row(op: &str, i: usize, j: usize, d: usize, tn: f64, flops: f64, tp: Option<f64>) {
    let gn = flops / tn / 1e9;
    match tp {
        Some(tp) => println!(
            "| {op} | {i}x{j}x{d} | {tn:.5} | {gn:.2} | {tp:.5} | {:.2} |",
            flops / tp / 1e9
        ),
        None => println!("| {op} | {i}x{j}x{d} | {tn:.5} | {gn:.2} | - | - |"),
    }
}
