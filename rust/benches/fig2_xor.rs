//! Figure 2 regenerator: XOR test error for Emp / RKS / Emp_Fix / Batch
//! while sweeping I (panels a, b) and J (panels c, d).
//!
//! Run: `cargo bench --bench fig2_xor` (DSEKL_BENCH_SCALE=quick|full).

use dsekl::experiments::fig2::{run_panel, CellCfg};
use dsekl::experiments::{markdown_table, Scale};
use dsekl::estimator::FitBackend;

fn print_panel(title: &str, panel: &dsekl::experiments::fig2::Panel) {
    println!("\n### {title}");
    let mut header: Vec<&str> = vec![panel.axis];
    for (m, _) in &panel.series {
        header.push(m.label());
    }
    let mut rows = Vec::new();
    for (vi, v) in panel.values.iter().enumerate() {
        let mut row = vec![v.to_string()];
        for (_, pts) in &panel.series {
            let (mean, std) = pts[vi];
            row.push(format!("{mean:.3}±{std:.3}"));
        }
        rows.push(row);
    }
    print!("{}", markdown_table(&header, &rows));
}

fn main() {
    let scale = Scale::from_env();
    let (reps, iters) = match scale {
        Scale::Quick => (3, 200),
        Scale::Default => (10, 400),
        Scale::Full => (10, 800),
    };
    let base = CellCfg {
        n: 100,
        reps,
        iters,
        ..Default::default()
    };
    let sweep: Vec<usize> = vec![1, 2, 4, 8, 16, 32, 64];
    let mut be = FitBackend::native();

    println!("# Figure 2 — XOR (N=100), {reps} reps, {iters} iters");
    let t0 = std::time::Instant::now();

    // (a) error vs I, small J; (b) error vs I, large J.
    let pa = run_panel(&mut be, true, 4, &sweep, &base).expect("panel a");
    print_panel("(a) error vs I (J = 4)", &pa);
    let pb = run_panel(&mut be, true, 64, &sweep, &base).expect("panel b");
    print_panel("(b) error vs I (J = 64)", &pb);

    // (c) error vs J, small I; (d) error vs J, large I.
    let pc = run_panel(&mut be, false, 4, &sweep, &base).expect("panel c");
    print_panel("(c) error vs J (I = 4)", &pc);
    let pd = run_panel(&mut be, false, 64, &sweep, &base).expect("panel d");
    print_panel("(d) error vs J (I = 64)", &pd);

    // Budgeted variants: the paper's "with too few data points ... RKS
    // and a fixed sample have an advantage over the doubly stochastic
    // approach" regime only appears under a tight optimisation budget —
    // with enough iterations DSEKL's J-resampling covers the whole data
    // set and small per-step samples stop hurting (that robustness is
    // the method's point). These panels fix the budget at 25 steps.
    let tight = CellCfg {
        iters: 25,
        ..base.clone()
    };
    let pa2 = run_panel(&mut be, true, 4, &sweep, &tight).expect("panel a'");
    print_panel("(a') error vs I (J = 4), 25-step budget", &pa2);
    let pc2 = run_panel(&mut be, false, 4, &sweep, &tight).expect("panel c'");
    print_panel("(c') error vs J (I = 4), 25-step budget", &pc2);

    println!("\nelapsed: {:.1}s", t0.elapsed().as_secs_f64());
}
