//! Figure 3a regenerator: validation error vs points processed on
//! covtype(-like) data with the parallel shared-memory solver.
//!
//! Run: `cargo bench --bench fig3a_covtype`
//! (DSEKL_BENCH_SCALE=full for the paper-exact 581k x 54, I=J=10k run).

use dsekl::experiments::fig3a::{run, Fig3aCfg};
use dsekl::experiments::Scale;
use dsekl::runtime::BackendSpec;

fn main() {
    let scale = Scale::from_env();
    let cfg = Fig3aCfg::at_scale(scale);
    println!(
        "# Figure 3a — covtype-like N={} I=J={} workers={} max_epochs={}",
        cfg.n, cfg.batch, cfg.workers, cfg.max_epochs
    );
    let t0 = std::time::Instant::now();
    let res = run(&BackendSpec::Native, &cfg).expect("fig3a");

    println!("\npoints\tround\tloss\tval_error\telapsed_s");
    for p in &res.run.stats.trace.points {
        if let Some(v) = p.val_error {
            println!(
                "{}\t{}\t{:.4}\t{:.4}\t{:.1}",
                p.points_processed, p.iteration, p.loss, v, p.elapsed_s
            );
        }
    }
    println!(
        "\nepochs run: {} (converged: {})",
        res.run.stats.iterations, res.run.stats.converged
    );
    if let Some(v) = res.val_error_after_one_pass {
        println!("validation error after ~1 pass: {:.2}% (paper: ~17%)", v * 100.0);
    }
    println!(
        "final evaluation error: {:.2}% (paper: 13.34%)",
        res.eval_error * 100.0
    );
    println!(
        "serial fraction (telemetry): {:.4}",
        res.run.telemetry.as_ref().map(|t| t.serial_fraction()).unwrap_or(0.0)
    );
    println!("elapsed: {:.1}s", t0.elapsed().as_secs_f64());
}
