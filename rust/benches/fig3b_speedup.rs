//! Figure 3b regenerator: speedup vs number of workers.
//!
//! Two sections (DESIGN.md §4 "Substitutions" — this container exposes
//! one core):
//!   1. measured multi-thread runs (real code path, wall times honest
//!      for *this* machine),
//!   2. the telemetry-calibrated analytic model evaluated at the paper's
//!      24-physical-core testbed, which reproduces the published curve
//!      shape (linear to ~20 cores, ~16x, then plateau).
//!
//! Run: `cargo bench --bench fig3b_speedup`.

use dsekl::experiments::fig3b::{calibrate, measure, paper_core_counts, Fig3bCfg};
use dsekl::experiments::{markdown_table, Scale};
use dsekl::runtime::BackendSpec;

fn main() {
    let scale = Scale::from_env();
    let cfg = match scale {
        Scale::Quick => Fig3bCfg {
            n: 2_048,
            batch: 256,
            worker_counts: vec![1, 2, 4],
            epochs: 1,
            ..Default::default()
        },
        Scale::Default => Fig3bCfg::default(),
        Scale::Full => Fig3bCfg {
            n: 65_536,
            batch: 2_048,
            worker_counts: vec![1, 2, 4, 8, 16, 32, 48],
            epochs: 2,
            ..Default::default()
        },
    };
    println!(
        "# Figure 3b — covtype-like N={} batch={} epochs={}",
        cfg.n, cfg.batch, cfg.epochs
    );
    let t0 = std::time::Instant::now();
    let ms = measure(&BackendSpec::Native, &cfg).expect("measure");

    println!("\n## measured on this host ({} logical cores)", std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
    let rows: Vec<Vec<String>> = ms
        .iter()
        .map(|m| {
            vec![
                m.workers.to_string(),
                format!("{:.4}", m.secs_per_round),
                format!("{:.4}", m.compute_secs_per_batch),
                format!("{:.2}", ms[0].secs_per_round / m.secs_per_round),
                format!("{:.4}", m.serial_fraction),
            ]
        })
        .collect();
    print!(
        "{}",
        markdown_table(
            &["K", "s/round", "s/batch (compute)", "speedup", "serial frac"],
            &rows
        )
    );

    let model = calibrate(&ms);
    println!(
        "\n## calibrated model @ paper testbed (24 phys cores + HT; parallel_frac={:.4})",
        model.parallel_frac
    );
    let rows: Vec<Vec<String>> = paper_core_counts()
        .iter()
        .map(|&k| vec![k.to_string(), format!("{:.1}", model.speedup(k))])
        .collect();
    print!("{}", markdown_table(&["cores", "speedup"], &rows));
    println!("(paper: ~16x at 20 cores, flattening beyond)");
    println!("\nelapsed: {:.1}s", t0.elapsed().as_secs_f64());
}
