//! Table 1 regenerator: DSEKL vs batch kernel SVM mean ± std test error
//! across the seven real-world analogue datasets.
//!
//! Run: `cargo bench --bench table1_datasets`.

use dsekl::experiments::table1::run_table;
use dsekl::experiments::{markdown_table, pm, Scale};
use dsekl::estimator::FitBackend;

fn main() {
    let scale = Scale::from_env();
    let (reps, iters) = match scale {
        Scale::Quick => (3, 300),
        Scale::Default => (10, 600),
        Scale::Full => (10, 1500),
    };
    println!("# Table 1 — {reps} repetitions, {iters} DSEKL iters");
    let t0 = std::time::Instant::now();
    let mut be = FitBackend::native();
    let rows = run_table(&mut be, reps, iters, 42).expect("table 1");
    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.dataset.to_string(),
                pm(r.dsekl_mean, r.dsekl_std),
                pm(r.batch_mean, r.batch_std),
            ]
        })
        .collect();
    print!(
        "{}",
        markdown_table(&["Data Set", "DSEKL", "Batch"], &table_rows)
    );
    println!("\nelapsed: {:.1}s", t0.elapsed().as_secs_f64());
}
