//! Ablation table for the design choices DESIGN.md calls out (AdaGrad,
//! sampling discipline, lr schedule, regulariser scaling).
//!
//! Run: `cargo bench --bench ablations`.

use dsekl::experiments::ablations;
use dsekl::experiments::markdown_table;

fn print_block(title: &str, rows: Vec<(&'static str, f64)>) {
    println!("\n### {title}");
    let rows: Vec<Vec<String>> = rows
        .into_iter()
        .map(|(label, err)| vec![label.to_string(), format!("{err:.3}")])
        .collect();
    print!("{}", markdown_table(&["variant", "test error"], &rows));
}

fn main() {
    println!("# Ablations (seed 42)");
    let t0 = std::time::Instant::now();
    print_block(
        "A1 — AdaGrad dampening (covtype-like 4k)",
        ablations::adagrad_ablation(42).expect("a1"),
    );
    print_block(
        "A2 — index sampling discipline (XOR)",
        ablations::sampling_ablation(42).expect("a2"),
    );
    print_block(
        "A3 — learning-rate schedule (diabetes-like)",
        ablations::schedule_ablation(42).expect("a3"),
    );
    print_block(
        "A4 — |I|/N regulariser scaling (blobs)",
        ablations::frac_ablation(42).expect("a4"),
    );
    println!("\nelapsed: {:.1}s", t0.elapsed().as_secs_f64());
}
