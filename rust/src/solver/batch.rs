//! Batch kernel SVM baseline — the scikit-learn SVC stand-in of
//! Table 1 and Fig. 2.
//!
//! Minimises the same objective as DSEKL (L2-regularised hinge over the
//! full empirical kernel map) but with **full-batch** subgradients on the
//! complete `N x N` kernel matrix, run to a tight tolerance. This is the
//! `O(N^2)` memory / `O(N^2)` per-step algorithm whose cost motivates the
//! paper; at Table-1 scale (N <= 500 train) it is exact enough to serve
//! as the error-rate reference.
//!
//! The kernel matrix is assembled once through the backend (tile-by-tile
//! when PJRT), then iterated on in rust.

use crate::data::Dataset;
use crate::kernel::Kernel;
use crate::loss::Loss;
use crate::metrics::{Stopwatch, TracePoint};
use crate::model::KernelModel;
use crate::runtime::{Backend, Rows};
use crate::solver::{LrSchedule, TrainStats};
use crate::{Error, Result};

/// Batch solver hyper-parameters.
#[derive(Debug, Clone)]
pub struct BatchOpts {
    pub gamma: f32,
    pub lam: f32,
    /// Step schedule (default 1/t, like the SGD solvers, but full-batch).
    pub lr: LrSchedule,
    /// Epoch cap.
    pub max_iters: u64,
    /// Stop when the full-gradient update norm falls below this.
    pub tol: f32,
    /// Override kernel.
    pub kernel: Option<Kernel>,
    /// Per-example loss (paper: hinge).
    pub loss: Loss,
}

impl Default for BatchOpts {
    fn default() -> Self {
        BatchOpts {
            gamma: 1.0,
            lam: 1e-4,
            lr: LrSchedule::InvSqrtT { eta0: 0.5 },
            max_iters: 2_000,
            tol: 1e-4,
            kernel: None,
            loss: Loss::Hinge,
        }
    }
}

/// Full-batch kernel SVM.
#[derive(Debug, Clone)]
pub struct BatchSvm {
    opts: BatchOpts,
}

/// Batch training output.
#[derive(Debug, Clone)]
pub struct BatchResult {
    pub model: KernelModel,
    pub stats: TrainStats,
    /// Final objective value.
    pub objective: f64,
}

impl BatchSvm {
    /// New batch solver.
    pub fn new(opts: BatchOpts) -> Self {
        BatchSvm { opts }
    }

    /// The options in use.
    pub fn opts(&self) -> &BatchOpts {
        &self.opts
    }

    /// Train to convergence on the full kernel matrix.
    pub fn train(&self, backend: &mut dyn Backend, train: &Dataset) -> Result<BatchResult> {
        let n = train.len();
        if n == 0 {
            return Err(Error::invalid("empty training set"));
        }
        let o = &self.opts;
        let kernel = o.kernel.unwrap_or(Kernel::Rbf { gamma: o.gamma });
        let watch = Stopwatch::new();

        // Assemble K once (the expensive O(N^2 D) part the paper avoids).
        let mut k = Vec::new();
        let rows = Rows::dense(&train.x, n, train.d);
        backend.kernel_block(kernel, rows, rows, &mut k)?;

        let mut alpha = vec![0.0f32; n];
        let mut f = vec![0.0f32; n];
        let mut g = vec![0.0f32; n];
        let mut stats = TrainStats::new();
        let mut objective = f64::INFINITY;

        for t in 1..=o.max_iters {
            // f = K alpha
            for a in 0..n {
                let row = &k[a * n..(a + 1) * n];
                f[a] = row.iter().zip(&alpha).map(|(kv, av)| kv * av).sum();
            }
            // Residuals + objective (loss-generic; hinge reproduces the
            // paper's active-set form).
            let mut data_loss = 0.0f64;
            let mut r = vec![0.0f32; n];
            for a in 0..n {
                let (v, res) = o.loss.eval(train.y[a], f[a]);
                data_loss += v as f64;
                r[a] = res;
            }
            objective = data_loss
                + o.lam as f64
                    * alpha.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>();
            // g = 2 lam alpha - K^T r   (K symmetric for same-set rows).
            for b in 0..n {
                let mut acc = 0.0f32;
                for a in 0..n {
                    if r[a] != 0.0 {
                        acc += k[a * n + b] * r[a];
                    }
                }
                g[b] = 2.0 * o.lam * alpha[b] - acc;
            }
            let eta = o.lr.at(t);
            let mut change_sq = 0.0f64;
            for (av, gv) in alpha.iter_mut().zip(&g) {
                let delta = eta * gv / n as f32; // mean-normalised step
                *av -= delta;
                change_sq += (delta as f64) * (delta as f64);
            }
            stats.iterations = t;
            stats.points_processed += n as u64;
            if change_sq.sqrt() < o.tol as f64 {
                stats.converged = true;
                stats.trace.push(TracePoint {
                    points_processed: stats.points_processed,
                    iteration: t,
                    loss: data_loss / n as f64,
                    val_error: None,
                    elapsed_s: watch.total(),
                });
                break;
            }
        }

        stats.elapsed_s = watch.total();
        Ok(BatchResult {
            model: KernelModel::new(kernel, train.x.clone(), alpha, train.d),
            stats,
            objective,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::rng::Pcg64;
    use crate::runtime::NativeBackend;

    #[test]
    fn solves_xor_exactly() {
        let mut rng = Pcg64::seed_from(1);
        let ds = synth::xor(100, 0.2, &mut rng);
        let solver = BatchSvm::new(BatchOpts {
            gamma: 1.0,
            lam: 1e-4,
            max_iters: 3000,
            ..Default::default()
        });
        let mut be = NativeBackend::new();
        let res = solver.train(&mut be, &ds).unwrap();
        let err = res.model.error(&mut be, &ds).unwrap();
        assert!(err <= 0.02, "batch XOR error {err}");
    }

    #[test]
    fn objective_decreases() {
        let mut rng = Pcg64::seed_from(2);
        let ds = synth::blobs(80, 4, 5.0, &mut rng);
        let mut be = NativeBackend::new();
        let short = BatchSvm::new(BatchOpts {
            max_iters: 5,
            tol: 0.0,
            ..Default::default()
        })
        .train(&mut be, &ds)
        .unwrap();
        let long = BatchSvm::new(BatchOpts {
            max_iters: 200,
            tol: 0.0,
            ..Default::default()
        })
        .train(&mut be, &ds)
        .unwrap();
        assert!(long.objective < short.objective);
    }

    #[test]
    fn empty_dataset_rejected() {
        let mut be = NativeBackend::new();
        assert!(BatchSvm::new(BatchOpts::default())
            .train(&mut be, &Dataset::with_dim(2))
            .is_err());
    }
}
