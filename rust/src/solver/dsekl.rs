//! Algorithm 1 — serial doubly stochastic empirical kernel learning.
//!
//! Per iteration: draw `I ~ unif(1,N)` (gradient sample) and an
//! independent `J ~ unif(1,N)` (empirical-kernel-map expansion sample),
//! compute the hinge subgradient of the dual coefficients at indices `J`
//! evaluated on points `I`, and take a decaying-step update on
//! `alpha_J`. Memory footprint is `O(N)` — just `alpha` — as the paper
//! emphasises; compute per step touches only the `|I| x |J|` kernel
//! submatrix.
//!
//! There is exactly **one** training loop ([`DseklSolver::train_rows`]),
//! written against the gather abstraction ([`Rows::gather_into`] +
//! [`crate::data::GatherBatch`]): the dense and CSR entry points are
//! thin wrappers over it, so their sampling schedules, tolerance
//! bookkeeping and validation cadence are identical *by construction*
//! (pinned bitwise in `rust/tests/schedule_parity.rs`). A CSR run keeps
//! O(nnz) memory end-to-end — the returned model's expansion store
//! preserves the input layout, nothing is densified.

use crate::data::{Dataset, GatherBatch, Rows, SparseDataset};
use crate::kernel::Kernel;
use crate::loss::Loss;
use crate::metrics::{Stopwatch, TracePoint};
use crate::model::{ExpansionStore, KernelModel};
use crate::rng::{sample_without_replacement, Rng};
use crate::runtime::{Backend, StepInput};
use crate::solver::{LrSchedule, TrainStats};
use crate::{Error, Result};

/// Hyper-parameters of Algorithm 1.
#[derive(Debug, Clone)]
pub struct DseklOpts {
    /// RBF width (the paper's experiments are all RBF; use
    /// [`DseklOpts::kernel`] for other kernels).
    pub gamma: f32,
    /// L2 regularisation strength.
    pub lam: f32,
    /// Gradient sample size |I|.
    pub i_size: usize,
    /// Expansion sample size |J|.
    pub j_size: usize,
    /// Step-size schedule (paper: 1/t).
    pub lr: LrSchedule,
    /// Hard iteration cap.
    pub max_iters: u64,
    /// Convergence: L2 norm of the alpha change over one epoch
    /// (N/|I| iterations) below this stops training. Paper: 1.0 on
    /// covtype. `0.0` disables.
    pub tol: f32,
    /// Evaluate validation error every this many iterations (0 = never).
    pub eval_every: u64,
    /// Override kernel (defaults to RBF(gamma)).
    pub kernel: Option<Kernel>,
    /// Per-example loss (paper: hinge).
    pub loss: Loss,
}

impl Default for DseklOpts {
    fn default() -> Self {
        DseklOpts {
            gamma: 1.0,
            lam: 1e-4,
            i_size: 64,
            j_size: 64,
            lr: LrSchedule::InvT { eta0: 1.0 },
            max_iters: 2_000,
            tol: 0.0,
            eval_every: 0,
            kernel: None,
            loss: Loss::Hinge,
        }
    }
}

impl DseklOpts {
    /// Effective kernel.
    pub fn kernel(&self) -> Kernel {
        self.kernel.unwrap_or(Kernel::Rbf { gamma: self.gamma })
    }
}

/// Output of a training run.
#[derive(Debug, Clone)]
pub struct TrainResult {
    pub model: KernelModel,
    pub stats: TrainStats,
}

/// Serial DSEKL solver (Algorithm 1).
#[derive(Debug, Clone)]
pub struct DseklSolver {
    opts: DseklOpts,
}

impl DseklSolver {
    /// New solver with the given options.
    pub fn new(opts: DseklOpts) -> Self {
        DseklSolver { opts }
    }

    /// The options in use.
    pub fn opts(&self) -> &DseklOpts {
        &self.opts
    }

    /// **The** doubly stochastic training loop, generic over the data
    /// layout through the gather abstraction: `x` is any [`Rows`] view
    /// (dense or CSR), `y` its ±1 labels, `val` an optional labelled
    /// validation view. Every entry point below is a thin wrapper, so
    /// dense and CSR runs draw identical I/J schedules, accumulate the
    /// identical tolerance bookkeeping and share the validation cadence
    /// by construction. The returned model's expansion store preserves
    /// the input layout — CSR training yields a CSR-backed model in
    /// O(nnz) memory, nothing is densified.
    pub fn train_rows<R: Rng>(
        &self,
        backend: &mut dyn Backend,
        x: Rows,
        y: &[f32],
        val: Option<(Rows, &[f32])>,
        rng: &mut R,
    ) -> Result<TrainResult> {
        let n = x.len();
        if n == 0 {
            return Err(Error::invalid("empty training set"));
        }
        if y.len() != n {
            return Err(Error::invalid(format!(
                "labels/rows length mismatch ({} vs {n})",
                y.len()
            )));
        }
        let o = &self.opts;
        let i_size = o.i_size.min(n);
        let j_size = o.j_size.min(n);
        let kernel = o.kernel();

        // One layout-preserving copy of the expansion rows, materialised
        // lazily (first validation snapshot, or the final model) like
        // the coordinator's shared store, so a no-validation run never
        // holds a second copy of the training rows during the loop;
        // snapshots after the first are Arc clones, never row copies.
        let mut store_cache: Option<ExpansionStore> = None;

        let mut alpha = vec![0.0f32; n];
        let mut stats = TrainStats::new();
        let watch = Stopwatch::new();

        // Reused gather buffers — the hot loop allocates nothing after
        // warmup, in either layout.
        let mut xi = GatherBatch::default();
        let mut xj = GatherBatch::default();
        let mut yi = Vec::with_capacity(i_size);
        let mut alpha_j = Vec::with_capacity(j_size);
        let mut g = Vec::with_capacity(j_size);

        let iters_per_epoch = (n as u64).div_ceil(i_size as u64).max(1);
        let mut epoch_change_sq = 0.0f64;
        let mut loss_acc = 0.0f64;
        let mut loss_cnt = 0u64;

        for t in 1..=o.max_iters {
            // Two independent uniform samples (the "doubly" part).
            let ii = sample_without_replacement(rng, n, i_size);
            let jj = sample_without_replacement(rng, n, j_size);
            // Regularise by the batch's *actual* size, the same
            // per-batch contract the coordinator ships in each work
            // item (uniform sampling always fills the batch here, so
            // this matches the old hoisted value bit-for-bit).
            let frac = ii.len() as f32 / n as f32;

            x.gather_into(&ii, &mut xi);
            x.gather_into(&jj, &mut xj);
            yi.clear();
            yi.extend(ii.iter().map(|&i| y[i]));
            alpha_j.clear();
            alpha_j.extend(jj.iter().map(|&j| alpha[j]));

            let out = backend.dsekl_step(
                kernel,
                &StepInput {
                    xi: xi.view(),
                    yi: &yi,
                    xj: xj.view(),
                    alpha: &alpha_j,
                    lam: o.lam,
                    frac,
                    loss: o.loss,
                },
                &mut g,
            )?;

            let eta = o.lr.at(t);
            for (&j, &gv) in jj.iter().zip(&g) {
                let delta = eta * gv;
                alpha[j] -= delta;
                epoch_change_sq += (delta as f64) * (delta as f64);
            }

            stats.iterations = t;
            stats.points_processed += i_size as u64;
            loss_acc += out.loss as f64 / i_size as f64;
            loss_cnt += 1;

            let mut record = o.eval_every > 0 && t % o.eval_every == 0;
            let mut val_error = None;
            if record {
                if let Some((vx, vy)) = val {
                    let store = store_cache
                        .get_or_insert_with(|| ExpansionStore::from_rows(x))
                        .clone();
                    let m = KernelModel::from_store(kernel, store, alpha.clone());
                    val_error = Some(m.error_rows(backend, vx, vy)?);
                }
            }

            // Epoch boundary: convergence check on the accumulated
            // weight change (paper's covtype criterion).
            if t % iters_per_epoch == 0 {
                let change = epoch_change_sq.sqrt();
                epoch_change_sq = 0.0;
                if o.tol > 0.0 && change < o.tol as f64 {
                    stats.converged = true;
                    record = true;
                }
            }

            if record {
                stats.trace.push(TracePoint {
                    points_processed: stats.points_processed,
                    iteration: t,
                    loss: loss_acc / loss_cnt.max(1) as f64,
                    val_error,
                    elapsed_s: watch.total(),
                });
                loss_acc = 0.0;
                loss_cnt = 0;
            }
            if stats.converged {
                break;
            }
        }

        stats.elapsed_s = watch.total();
        let store = store_cache.unwrap_or_else(|| ExpansionStore::from_rows(x));
        Ok(TrainResult {
            model: KernelModel::from_store(kernel, store, alpha),
            stats,
        })
    }

    /// Train on a dense dataset; if `val` is given and `eval_every > 0`,
    /// the trace records validation error along the way.
    pub fn train_with_val<R: Rng>(
        &self,
        backend: &mut dyn Backend,
        train: &Dataset,
        val: Option<&Dataset>,
        rng: &mut R,
    ) -> Result<TrainResult> {
        self.train_rows(
            backend,
            train.rows(),
            &train.y,
            val.map(|v| (v.rows(), v.y.as_slice())),
            rng,
        )
    }

    /// Train without validation tracking.
    pub fn train<R: Rng>(
        &self,
        backend: &mut dyn Backend,
        train: &Dataset,
        rng: &mut R,
    ) -> Result<TrainResult> {
        self.train_with_val(backend, train, None, rng)
    }

    /// Train on a **CSR** dataset with optional (CSR) validation
    /// tracking. This is [`DseklSolver::train_rows`] on CSR views:
    /// batches gather as CSR, the backend runs the O(nnz) block path,
    /// and the model keeps a CSR-backed [`ExpansionStore`] (serialising
    /// as DSEKLv3) — memory is O(nnz + N) end-to-end.
    pub fn train_sparse_with_val<R: Rng>(
        &self,
        backend: &mut dyn Backend,
        train: &SparseDataset,
        val: Option<&SparseDataset>,
        rng: &mut R,
    ) -> Result<TrainResult> {
        self.train_rows(
            backend,
            train.rows(),
            &train.y,
            val.map(|v| (v.rows(), v.y.as_slice())),
            rng,
        )
    }

    /// Train on a **CSR** dataset without validation tracking.
    pub fn train_sparse<R: Rng>(
        &self,
        backend: &mut dyn Backend,
        train: &SparseDataset,
        rng: &mut R,
    ) -> Result<TrainResult> {
        self.train_sparse_with_val(backend, train, None, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::rng::Pcg64;
    use crate::runtime::NativeBackend;

    #[test]
    fn learns_xor() {
        let mut rng = Pcg64::seed_from(7);
        let ds = synth::xor(100, 0.2, &mut rng);
        let solver = DseklSolver::new(DseklOpts {
            gamma: 1.0,
            lam: 1e-4,
            i_size: 32,
            j_size: 32,
            max_iters: 300,
            ..Default::default()
        });
        let mut be = NativeBackend::new();
        let res = solver.train(&mut be, &ds, &mut rng).unwrap();
        let err = res.model.error(&mut be, &ds).unwrap();
        assert!(err <= 0.05, "XOR training error {err}");
        assert_eq!(res.stats.points_processed, 300 * 32);
    }

    #[test]
    fn learns_blobs_generalisation() {
        let mut rng = Pcg64::seed_from(8);
        let ds = synth::blobs(300, 6, 6.0, &mut rng);
        let (train, test) = ds.split(0.5, &mut rng);
        let solver = DseklSolver::new(DseklOpts {
            gamma: 0.2,
            lam: 1e-4,
            i_size: 32,
            j_size: 32,
            max_iters: 400,
            ..Default::default()
        });
        let mut be = NativeBackend::new();
        let res = solver.train(&mut be, &train, &mut rng).unwrap();
        let err = res.model.error(&mut be, &test).unwrap();
        assert!(err <= 0.08, "blobs test error {err}");
    }

    #[test]
    fn learns_xor_every_loss() {
        // The doubly stochastic loop is loss-agnostic: all four losses
        // separate XOR well above chance with the same budget.
        for loss in crate::loss::ALL_LOSSES {
            let mut rng = Pcg64::seed_from(21);
            let ds = synth::xor(120, 0.2, &mut rng);
            // Unbounded-residual losses (ridge, squared hinge) want a
            // gentler step than the margin losses at this tiny scale.
            let eta0 = match loss {
                Loss::Hinge | Loss::Logistic => 1.0,
                Loss::SquaredHinge | Loss::Ridge => 0.3,
            };
            let solver = DseklSolver::new(DseklOpts {
                gamma: 1.0,
                lam: 1e-4,
                i_size: 32,
                j_size: 32,
                lr: LrSchedule::InvT { eta0 },
                max_iters: 400,
                loss,
                ..Default::default()
            });
            let mut be = NativeBackend::new();
            let res = solver.train(&mut be, &ds, &mut rng).unwrap();
            let err = res.model.error(&mut be, &ds).unwrap();
            assert!(err <= 0.12, "{loss}: XOR training error {err}");
        }
    }

    #[test]
    fn deterministic_under_seed() {
        let mut r1 = Pcg64::seed_from(3);
        let ds = synth::xor(60, 0.2, &mut r1);
        let solver = DseklSolver::new(DseklOpts {
            max_iters: 50,
            ..Default::default()
        });
        let mut be = NativeBackend::new();
        let mut ra = Pcg64::seed_from(11);
        let mut rb = Pcg64::seed_from(11);
        let a = solver.train(&mut be, &ds, &mut ra).unwrap();
        let b = solver.train(&mut be, &ds, &mut rb).unwrap();
        assert_eq!(a.model.alpha, b.model.alpha);
    }

    #[test]
    fn tolerance_stops_early() {
        let mut rng = Pcg64::seed_from(4);
        let ds = synth::blobs(64, 4, 8.0, &mut rng);
        let solver = DseklSolver::new(DseklOpts {
            i_size: 32,
            j_size: 32,
            max_iters: 100_000,
            tol: 0.5,
            lr: LrSchedule::InvT { eta0: 1.0 },
            ..Default::default()
        });
        let mut be = NativeBackend::new();
        let res = solver.train(&mut be, &ds, &mut rng).unwrap();
        assert!(res.stats.converged);
        assert!(res.stats.iterations < 100_000);
    }

    #[test]
    fn trace_records_val_error() {
        let mut rng = Pcg64::seed_from(5);
        let ds = synth::xor(80, 0.2, &mut rng);
        let (train, val) = ds.split(0.5, &mut rng);
        let solver = DseklSolver::new(DseklOpts {
            i_size: 16,
            j_size: 16,
            max_iters: 60,
            eval_every: 20,
            ..Default::default()
        });
        let mut be = NativeBackend::new();
        let res = solver
            .train_with_val(&mut be, &train, Some(&val), &mut rng)
            .unwrap();
        assert_eq!(res.stats.trace.points.len(), 3);
        assert!(res.stats.trace.last_val_error().is_some());
    }

    #[test]
    fn rejects_empty_dataset() {
        let ds = Dataset::with_dim(3);
        let solver = DseklSolver::new(DseklOpts::default());
        let mut be = NativeBackend::new();
        let mut rng = Pcg64::seed_from(1);
        assert!(solver.train(&mut be, &ds, &mut rng).is_err());
        let sparse = crate::data::SparseDataset::with_dim(3);
        assert!(solver.train_sparse(&mut be, &sparse, &mut rng).is_err());
    }

    #[test]
    fn sparse_training_learns_high_sparsity_set() {
        // CSR end-to-end on a ~95%-sparse synthetic set: the sparse
        // loop reaches low error, and because it consumes the RNG
        // exactly like the dense loop, the dense run on the densified
        // copy lands within rounding of the same error.
        let mut rng = Pcg64::seed_from(31);
        let ds = synth::sparse_binary(240, 60, 0.05, &mut rng);
        let solver = DseklSolver::new(DseklOpts {
            lam: 1e-4,
            i_size: 32,
            j_size: 32,
            lr: LrSchedule::InvT { eta0: 0.5 },
            max_iters: 300,
            kernel: Some(Kernel::Linear),
            ..Default::default()
        });
        let mut be = NativeBackend::new();
        let mut rng_s = Pcg64::seed_from(77);
        let res_s = solver.train_sparse(&mut be, &ds, &mut rng_s).unwrap();
        let err_s = res_s.model.error_rows(&mut be, ds.rows(), &ds.y).unwrap();
        assert!(err_s <= 0.05, "sparse training error {err_s}");

        let dense = ds.to_dense();
        let mut rng_d = Pcg64::seed_from(77);
        let res_d = solver.train(&mut be, &dense, &mut rng_d).unwrap();
        let err_d = res_d.model.error(&mut be, &dense).unwrap();
        assert!(
            (err_s - err_d).abs() <= 0.02,
            "sparse {err_s} vs dense {err_d}"
        );
    }
}
