//! Random kitchen sinks (Rahimi & Recht) — the *explicit* kernel map
//! baseline of Fig. 2.
//!
//! Draw `R` random Fourier features (frequencies `~ N(0, 2 gamma)`,
//! phases `~ U[0, 2 pi)`) approximating the RBF kernel, then run a linear
//! SVM by minibatch SGD in feature space. The optimisation loop matches
//! the DSEKL solver exactly (same sampling, same schedule) so Fig. 2
//! compares *approximations*, not optimisers — the experimental control
//! the paper calls out in §2.1.

use crate::data::Dataset;
use crate::loss::Loss;
use crate::metrics::{Stopwatch, TracePoint};
use crate::model::RksModel;
use crate::rng::{sample_without_replacement, Rng};
use crate::runtime::{Backend, RksStepInput, Rows};
use crate::solver::{LrSchedule, TrainStats};
use crate::{Error, Result};

/// RKS hyper-parameters.
#[derive(Debug, Clone)]
pub struct RksOpts {
    /// RBF width being approximated.
    pub gamma: f32,
    /// L2 regularisation strength.
    pub lam: f32,
    /// Number of random Fourier features (Fig. 2's J axis counterpart:
    /// "the number of basis functions matched the number of expansion
    /// coefficients J").
    pub n_features: usize,
    /// Gradient minibatch size |I|.
    pub i_size: usize,
    /// Step schedule.
    pub lr: LrSchedule,
    /// Iteration cap.
    pub max_iters: u64,
    /// Per-example loss (paper: hinge, i.e. a linear SVM in RFF space).
    pub loss: Loss,
}

impl Default for RksOpts {
    fn default() -> Self {
        RksOpts {
            gamma: 1.0,
            lam: 1e-4,
            n_features: 64,
            i_size: 64,
            lr: LrSchedule::InvT { eta0: 1.0 },
            max_iters: 2_000,
            loss: Loss::Hinge,
        }
    }
}

/// RKS training output.
#[derive(Debug, Clone)]
pub struct RksResult {
    pub model: RksModel,
    pub stats: TrainStats,
}

/// Random-kitchen-sinks linear SVM.
#[derive(Debug, Clone)]
pub struct RksSolver {
    opts: RksOpts,
}

impl RksSolver {
    /// New solver.
    pub fn new(opts: RksOpts) -> Self {
        RksSolver { opts }
    }

    /// The options in use.
    pub fn opts(&self) -> &RksOpts {
        &self.opts
    }

    /// Sample the feature map and train the linear SVM.
    pub fn train<R: Rng>(
        &self,
        backend: &mut dyn Backend,
        train: &Dataset,
        rng: &mut R,
    ) -> Result<RksResult> {
        let n = train.len();
        if n == 0 {
            return Err(Error::invalid("empty training set"));
        }
        let o = &self.opts;
        let d = train.d;
        let r = o.n_features;
        let i_size = o.i_size.min(n);
        let watch = Stopwatch::new();

        // Feature map: w ~ N(0, 2 gamma) so that E[phi.phi] = RBF(gamma).
        let std = (2.0 * o.gamma as f64).sqrt();
        let w_feat: Vec<f32> = (0..d * r).map(|_| rng.normal_ms(0.0, std) as f32).collect();
        let b_feat: Vec<f32> = (0..r)
            .map(|_| rng.range_f64(0.0, 2.0 * std::f64::consts::PI) as f32)
            .collect();

        let mut w = vec![0.0f32; r];
        let mut g = Vec::with_capacity(r);
        let mut xi = Vec::with_capacity(i_size * d);
        let mut yi = Vec::with_capacity(i_size);
        let mut stats = TrainStats::new();
        let mut loss_acc = 0.0f64;

        for t in 1..=o.max_iters {
            let ii = sample_without_replacement(rng, n, i_size);
            // Same per-batch contract as the other solvers: scale the
            // regulariser by the batch's actual size.
            let frac = ii.len() as f32 / n as f32;
            train.gather_into(&ii, &mut xi);
            train.gather_labels_into(&ii, &mut yi);
            let out = backend.rks_step(
                &RksStepInput {
                    xi: Rows::dense(&xi, i_size, d),
                    yi: &yi,
                    w_feat: &w_feat,
                    b_feat: &b_feat,
                    w: &w,
                    r,
                    lam: o.lam,
                    frac,
                    loss: o.loss,
                },
                &mut g,
            )?;
            let eta = o.lr.at(t);
            for (wv, gv) in w.iter_mut().zip(&g) {
                *wv -= eta * gv;
            }
            stats.iterations = t;
            stats.points_processed += i_size as u64;
            loss_acc += out.loss as f64 / i_size as f64;
        }
        stats.trace.push(TracePoint {
            points_processed: stats.points_processed,
            iteration: stats.iterations,
            loss: loss_acc / stats.iterations.max(1) as f64,
            val_error: None,
            elapsed_s: watch.total(),
        });
        stats.elapsed_s = watch.total();
        Ok(RksResult {
            model: RksModel {
                w_feat,
                b_feat,
                w,
                d,
                r,
            },
            stats,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::rng::Pcg64;
    use crate::runtime::NativeBackend;

    #[test]
    fn learns_xor_with_enough_features() {
        let mut rng = Pcg64::seed_from(1);
        let ds = synth::xor(150, 0.2, &mut rng);
        let solver = RksSolver::new(RksOpts {
            gamma: 1.0,
            n_features: 128,
            i_size: 32,
            max_iters: 500,
            ..Default::default()
        });
        let mut be = NativeBackend::new();
        let res = solver.train(&mut be, &ds, &mut rng).unwrap();
        let err = res.model.error(&mut be, &ds).unwrap();
        assert!(err <= 0.08, "RKS XOR error {err}");
    }

    #[test]
    fn few_features_underfit() {
        let mut rng = Pcg64::seed_from(2);
        let ds = synth::xor(150, 0.2, &mut rng);
        let few = RksSolver::new(RksOpts {
            n_features: 2,
            i_size: 32,
            max_iters: 300,
            ..Default::default()
        });
        let many = RksSolver::new(RksOpts {
            n_features: 256,
            i_size: 32,
            max_iters: 300,
            ..Default::default()
        });
        let mut be = NativeBackend::new();
        let e_few = few
            .train(&mut be, &ds, &mut rng)
            .unwrap()
            .model
            .error(&mut be, &ds)
            .unwrap();
        let e_many = many
            .train(&mut be, &ds, &mut rng)
            .unwrap()
            .model
            .error(&mut be, &ds)
            .unwrap();
        assert!(
            e_many < e_few,
            "more features should help: few={e_few} many={e_many}"
        );
    }

    #[test]
    fn learns_linear_blobs() {
        let mut rng = Pcg64::seed_from(3);
        let ds = synth::blobs(200, 5, 6.0, &mut rng);
        let (train, test) = ds.split(0.5, &mut rng);
        let solver = RksSolver::new(RksOpts {
            gamma: 0.3,
            n_features: 128,
            i_size: 32,
            max_iters: 400,
            ..Default::default()
        });
        let mut be = NativeBackend::new();
        let res = solver.train(&mut be, &train, &mut rng).unwrap();
        let err = res.model.error(&mut be, &test).unwrap();
        assert!(err <= 0.1, "RKS blobs error {err}");
    }
}
