//! Learning algorithms: the paper's contribution (serial DSEKL,
//! Algorithm 1) and every baseline its evaluation compares against.
//!
//! | Solver | Paper role |
//! |--------|-----------|
//! | [`dsekl::DseklSolver`] | Algorithm 1 — doubly stochastic empirical kernel learning |
//! | [`batch::BatchSvm`] | batch kernel SVM (scikit-learn stand-in of Table 1 / Fig. 2) |
//! | [`empfix::EmpFixSolver`] | "Emp_Fix" — train on one fixed random subset (Fig. 2) |
//! | [`rks::RksSolver`] | random kitchen sinks — explicit kernel map baseline (Fig. 2) |
//! | [`ovr::OvrSolver`] | one-vs-rest multiclass driver over K DSEKL machines |
//! | [`online::OnlineDsekl`] / [`online::OnlineSolver`] | streaming DSEKL with a budgeted reservoir expansion — the paper-conclusion extension |
//!
//! Every solver takes its per-example [`crate::loss::Loss`] from its
//! options (default: the paper's hinge). The parallel shared-memory
//! variant (Algorithm 2) lives in [`crate::coordinator`] because it owns
//! threads and channels, not just math. All of them are also reachable
//! through the unified [`crate::estimator::Estimator`] /
//! [`crate::estimator::Fit`] front door, which routes
//! serial-vs-parallel and dense-vs-sparse once.

pub mod batch;
pub mod dsekl;
pub mod empfix;
pub mod online;
pub mod ovr;
pub mod rks;

use crate::metrics::Trace;

/// Common convergence/trace bundle returned by every solver.
#[derive(Debug, Clone)]
pub struct TrainStats {
    /// Convergence trace (loss / validation error per eval point).
    pub trace: Trace,
    /// Iterations (steps for SGD solvers, epochs for batch).
    pub iterations: u64,
    /// Total gradient samples processed (sum of |I| over steps).
    pub points_processed: u64,
    /// Whether the tolerance criterion fired (vs hitting max_iters).
    pub converged: bool,
    /// Wall-clock seconds spent in training.
    pub elapsed_s: f64,
}

impl TrainStats {
    pub(crate) fn new() -> Self {
        TrainStats {
            trace: Trace::default(),
            iterations: 0,
            points_processed: 0,
            converged: false,
            elapsed_s: 0.0,
        }
    }
}

/// Learning-rate schedules for the SGD solvers. The paper uses `eta0/t`
/// (serial) and `1/epoch` with AdaGrad dampening (parallel); inverse-
/// sqrt is the standard variance-friendly alternative the paper's
/// "better control of the variance" remark gestures at.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    /// `eta0 / t`
    InvT { eta0: f32 },
    /// `eta0 / sqrt(t)`
    InvSqrtT { eta0: f32 },
    /// Constant `eta0`.
    Const { eta0: f32 },
}

impl LrSchedule {
    /// Step size at iteration `t` (1-based).
    pub fn at(&self, t: u64) -> f32 {
        let t = t.max(1) as f32;
        match *self {
            LrSchedule::InvT { eta0 } => eta0 / t,
            LrSchedule::InvSqrtT { eta0 } => eta0 / t.sqrt(),
            LrSchedule::Const { eta0 } => eta0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules() {
        assert_eq!(LrSchedule::InvT { eta0: 2.0 }.at(4), 0.5);
        assert_eq!(LrSchedule::InvSqrtT { eta0: 2.0 }.at(4), 1.0);
        assert_eq!(LrSchedule::Const { eta0: 0.3 }.at(100), 0.3);
        // t = 0 is clamped to 1.
        assert_eq!(LrSchedule::InvT { eta0: 1.0 }.at(0), 1.0);
    }
}
