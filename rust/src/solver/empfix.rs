//! "Emp_Fix" baseline of Fig. 2: draw **one** fixed random subset of the
//! data up front and train only on it.
//!
//! This stands in for the family of large-scale approximations that
//! discard data (Nyström-style landmark selection, distributed
//! block-diagonal solvers, budgets): the paper deliberately strips the
//! smarter selection/extrapolation schemes and keeps "the main
//! difference ... training on a fixed random subset of the data".
//! Contrast with DSEKL, which resamples both index sets every iteration
//! and therefore touches the entire data set over time.

use crate::data::Dataset;
use crate::rng::Rng;
use crate::runtime::Backend;
use crate::solver::dsekl::{DseklOpts, DseklSolver, TrainResult};
use crate::Result;

/// Emp_Fix hyper-parameters: subset size + the inner SGD options.
#[derive(Debug, Clone)]
pub struct EmpFixOpts {
    /// Size of the one fixed subset (Fig. 2's J axis).
    pub subset_size: usize,
    /// Inner solver configuration (i_size/j_size are clamped to the
    /// subset).
    pub inner: DseklOpts,
}

/// Fixed-subset kernel SVM baseline.
#[derive(Debug, Clone)]
pub struct EmpFixSolver {
    opts: EmpFixOpts,
}

impl EmpFixSolver {
    /// New solver.
    pub fn new(opts: EmpFixOpts) -> Self {
        EmpFixSolver { opts }
    }

    /// The options in use.
    pub fn opts(&self) -> &EmpFixOpts {
        &self.opts
    }

    /// Draw the fixed subset and train on it. The returned model's
    /// expansion contains only subset points — prediction cost shrinks
    /// accordingly, which is exactly the trade Fig. 2 probes.
    pub fn train<R: Rng>(
        &self,
        backend: &mut dyn Backend,
        train: &Dataset,
        rng: &mut R,
    ) -> Result<TrainResult> {
        let subset = train.sample(self.opts.subset_size, rng);
        let mut inner = self.opts.inner.clone();
        inner.i_size = inner.i_size.min(subset.len());
        inner.j_size = inner.j_size.min(subset.len());
        DseklSolver::new(inner).train(backend, &subset, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::rng::Pcg64;
    use crate::runtime::NativeBackend;

    #[test]
    fn subset_model_has_subset_expansion() {
        let mut rng = Pcg64::seed_from(1);
        let ds = synth::xor(200, 0.2, &mut rng);
        let solver = EmpFixSolver::new(EmpFixOpts {
            subset_size: 32,
            inner: DseklOpts {
                max_iters: 100,
                ..Default::default()
            },
        });
        let mut be = NativeBackend::new();
        let res = solver.train(&mut be, &ds, &mut rng).unwrap();
        assert_eq!(res.model.len(), 32);
    }

    #[test]
    fn large_subset_still_learns_xor() {
        let mut rng = Pcg64::seed_from(2);
        let ds = synth::xor(150, 0.2, &mut rng);
        let solver = EmpFixSolver::new(EmpFixOpts {
            subset_size: 100,
            inner: DseklOpts {
                gamma: 1.0,
                i_size: 32,
                j_size: 32,
                max_iters: 300,
                ..Default::default()
            },
        });
        let mut be = NativeBackend::new();
        let res = solver.train(&mut be, &ds, &mut rng).unwrap();
        let err = res.model.error(&mut be, &ds).unwrap();
        assert!(err < 0.1, "emp_fix error {err}");
    }

    #[test]
    fn tiny_subset_underfits_xor() {
        // With 4 expansion points XOR is (usually) not representable —
        // the effect Fig. 2c shows at small J. Use a fixed seed known to
        // produce an unbalanced subset.
        let mut rng = Pcg64::seed_from(3);
        let ds = synth::xor(200, 0.2, &mut rng);
        let solver = EmpFixSolver::new(EmpFixOpts {
            subset_size: 4,
            inner: DseklOpts {
                max_iters: 200,
                ..Default::default()
            },
        });
        let mut be = NativeBackend::new();
        let res = solver.train(&mut be, &ds, &mut rng).unwrap();
        let err = res.model.error(&mut be, &ds).unwrap();
        // Not an exact bound — just "visibly worse than the full model".
        assert!(err > 0.02, "unexpectedly good tiny-subset error {err}");
    }
}
