//! Streaming / online DSEKL — the extension sketched in the paper's
//! conclusion: "use the proposed approach in a streaming/online learning
//! setting, similar to [NORMA, Forgetron] but with a simpler, randomized
//! scheme for reducing the cost of the empirical kernel map".
//!
//! Data arrives one example at a time and is *also* the gradient sample;
//! the empirical kernel map is expanded over a fixed-size **reservoir**
//! of previously seen points (uniform reservoir sampling keeps it an
//! unbiased sample of the stream — the online analogue of drawing `J`
//! uniformly). A budget cap with smallest-|alpha| eviction keeps memory
//! and prediction cost bounded, as in the budgeted-perceptron line of
//! work the paper cites.

use crate::kernel::Kernel;
use crate::loss::Loss;
use crate::model::KernelModel;
use crate::rng::Rng;
use crate::runtime::{Backend, Rows, StepInput};
use crate::solver::LrSchedule;
use crate::Result;

/// Online solver configuration.
#[derive(Debug, Clone)]
pub struct OnlineOpts {
    pub gamma: f32,
    pub lam: f32,
    /// Expansion budget (reservoir size).
    pub budget: usize,
    /// Gradient minibatch: how many recent stream items per step.
    pub chunk: usize,
    pub lr: LrSchedule,
    /// Override kernel.
    pub kernel: Option<Kernel>,
    /// Per-example loss (paper: hinge).
    pub loss: Loss,
}

impl Default for OnlineOpts {
    fn default() -> Self {
        OnlineOpts {
            gamma: 1.0,
            lam: 1e-4,
            budget: 256,
            chunk: 16,
            lr: LrSchedule::InvSqrtT { eta0: 0.5 },
            kernel: None,
            loss: Loss::Hinge,
        }
    }
}

/// Streaming DSEKL state: a budgeted kernel expansion updated per chunk.
#[derive(Debug)]
pub struct OnlineDsekl {
    opts: OnlineOpts,
    kernel: Kernel,
    d: usize,
    /// Reservoir expansion points, row-major `[len, d]`.
    x: Vec<f32>,
    /// Dual coefficients over the reservoir.
    alpha: Vec<f32>,
    /// Stream position (for reservoir sampling + lr schedule).
    seen: u64,
    steps: u64,
    /// Pending chunk buffers.
    pend_x: Vec<f32>,
    pend_y: Vec<f32>,
    g: Vec<f32>,
}

impl OnlineDsekl {
    /// New empty stream learner for `d`-dimensional inputs.
    pub fn new(opts: OnlineOpts, d: usize) -> Self {
        let kernel = opts.kernel.unwrap_or(Kernel::Rbf { gamma: opts.gamma });
        OnlineDsekl {
            opts,
            kernel,
            d,
            x: Vec::new(),
            alpha: Vec::new(),
            seen: 0,
            steps: 0,
            pend_x: Vec::new(),
            pend_y: Vec::new(),
            g: Vec::new(),
        }
    }

    /// Number of expansion points currently held (<= budget).
    pub fn expansion_len(&self) -> usize {
        self.alpha.len()
    }

    /// Total stream items consumed.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Current decision score for a point (0 before any data).
    pub fn score(&self, backend: &mut dyn Backend, x: &[f32]) -> Result<f32> {
        if self.alpha.is_empty() {
            return Ok(0.0);
        }
        let mut f = Vec::new();
        backend.predict(
            self.kernel,
            Rows::dense(x, 1, self.d),
            Rows::dense(&self.x, self.alpha.len(), self.d),
            &self.alpha,
            &mut f,
        )?;
        Ok(f[0])
    }

    /// Consume one labelled example; runs a gradient step every `chunk`
    /// items. Returns the pre-update score (for prequential evaluation:
    /// test-then-train).
    pub fn observe<R: Rng>(
        &mut self,
        backend: &mut dyn Backend,
        x: &[f32],
        y: f32,
        rng: &mut R,
    ) -> Result<f32> {
        assert_eq!(x.len(), self.d);
        let score = self.score(backend, x)?;
        self.seen += 1;
        self.pend_x.extend_from_slice(x);
        self.pend_y.push(y);

        // Reservoir update: keep the expansion a uniform sample of the
        // stream. While under budget, always admit (alpha starts at 0).
        let cap = self.opts.budget;
        if self.alpha.len() < cap {
            self.x.extend_from_slice(x);
            self.alpha.push(0.0);
        } else {
            let slot = rng.below(self.seen as usize);
            if slot < cap {
                // Evict the reservoir slot; if its coefficient carries
                // weight, prefer dropping the globally smallest |alpha|
                // instead (budget-perceptron style truncation).
                let victim = if self.alpha[slot].abs() < 1e-6 {
                    slot
                } else {
                    (0..cap)
                        .min_by(|&a, &b| {
                            self.alpha[a]
                                .abs()
                                .partial_cmp(&self.alpha[b].abs())
                                .unwrap()
                        })
                        .unwrap()
                };
                self.x[victim * self.d..(victim + 1) * self.d].copy_from_slice(x);
                self.alpha[victim] = 0.0;
            }
        }

        if self.pend_y.len() >= self.opts.chunk {
            self.step(backend)?;
        }
        Ok(score)
    }

    /// Run the pending-chunk gradient step (called automatically; public
    /// so callers can flush at stream end).
    pub fn step(&mut self, backend: &mut dyn Backend) -> Result<()> {
        let i = self.pend_y.len();
        if i == 0 || self.alpha.is_empty() {
            self.pend_x.clear();
            self.pend_y.clear();
            return Ok(());
        }
        self.steps += 1;
        let j = self.alpha.len();
        let frac = (i as f32) / (self.seen.max(1) as f32);
        let out = backend.dsekl_step(
            self.kernel,
            &StepInput {
                xi: Rows::dense(&self.pend_x, i, self.d),
                yi: &self.pend_y,
                xj: Rows::dense(&self.x, j, self.d),
                alpha: &self.alpha,
                lam: self.opts.lam,
                frac,
                loss: self.opts.loss,
            },
            &mut self.g,
        )?;
        let _ = out;
        let eta = self.opts.lr.at(self.steps);
        for (a, gv) in self.alpha.iter_mut().zip(&self.g) {
            *a -= eta * gv;
        }
        self.pend_x.clear();
        self.pend_y.clear();
        Ok(())
    }

    /// Snapshot the current expansion as a standalone model.
    pub fn to_model(&self) -> KernelModel {
        KernelModel::new(self.kernel, self.x.clone(), self.alpha.clone(), self.d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::metrics::error_rate;
    use crate::rng::Pcg64;
    use crate::runtime::NativeBackend;

    #[test]
    fn learns_xor_stream_prequentially() {
        let mut rng = Pcg64::seed_from(1);
        let stream = synth::xor(2000, 0.2, &mut rng);
        let mut be = NativeBackend::new();
        let mut learner = OnlineDsekl::new(
            OnlineOpts {
                budget: 128,
                chunk: 16,
                ..Default::default()
            },
            2,
        );
        let mut late_wrong = 0usize;
        let mut late_total = 0usize;
        for idx in 0..stream.len() {
            let score = learner
                .observe(&mut be, stream.row(idx), stream.y[idx], &mut rng)
                .unwrap();
            if idx >= 1000 {
                late_total += 1;
                if score * stream.y[idx] <= 0.0 {
                    late_wrong += 1;
                }
            }
        }
        let preq_err = late_wrong as f64 / late_total as f64;
        assert!(preq_err < 0.10, "prequential error {preq_err}");
        assert_eq!(learner.expansion_len(), 128);
        assert_eq!(learner.seen(), 2000);
    }

    #[test]
    fn budget_is_respected_and_model_works() {
        let mut rng = Pcg64::seed_from(2);
        let stream = synth::blobs(600, 4, 6.0, &mut rng);
        let test = synth::blobs(200, 4, 6.0, &mut rng);
        let mut be = NativeBackend::new();
        let mut learner = OnlineDsekl::new(
            OnlineOpts {
                gamma: 0.3,
                budget: 64,
                chunk: 8,
                ..Default::default()
            },
            4,
        );
        for idx in 0..stream.len() {
            learner
                .observe(&mut be, stream.row(idx), stream.y[idx], &mut rng)
                .unwrap();
        }
        learner.step(&mut be).unwrap(); // flush
        assert!(learner.expansion_len() <= 64);
        let model = learner.to_model();
        let scores = model.scores(&mut be, &test).unwrap();
        let err = error_rate(&scores, &test.y);
        assert!(err < 0.1, "stream model test error {err}");
    }

    #[test]
    fn empty_learner_scores_zero() {
        let mut be = NativeBackend::new();
        let learner = OnlineDsekl::new(OnlineOpts::default(), 3);
        assert_eq!(learner.score(&mut be, &[1.0, 2.0, 3.0]).unwrap(), 0.0);
    }
}
