//! Streaming / online DSEKL — the extension sketched in the paper's
//! conclusion: "use the proposed approach in a streaming/online learning
//! setting, similar to [NORMA, Forgetron] but with a simpler, randomized
//! scheme for reducing the cost of the empirical kernel map".
//!
//! Data arrives one example at a time and is *also* the gradient sample;
//! the empirical kernel map is expanded over a fixed-size **reservoir**
//! of previously seen points (uniform reservoir sampling keeps it an
//! unbiased sample of the stream — the online analogue of drawing `J`
//! uniformly). A budget cap with smallest-|alpha| eviction keeps memory
//! and prediction cost bounded, as in the budgeted-perceptron line of
//! work the paper cites.

use crate::data::{Dataset, SparseDataset};
use crate::kernel::native::StepOut;
use crate::kernel::Kernel;
use crate::loss::Loss;
use crate::metrics::{PrequentialWindow, Stopwatch, TracePoint};
use crate::model::KernelModel;
use crate::rng::Rng;
use crate::runtime::{Backend, Rows, StepInput};
use crate::solver::{LrSchedule, TrainStats};
use crate::{Error, Result};

/// Online solver configuration.
#[derive(Debug, Clone)]
pub struct OnlineOpts {
    pub gamma: f32,
    pub lam: f32,
    /// Expansion budget (reservoir size).
    pub budget: usize,
    /// Gradient minibatch: how many recent stream items per step.
    pub chunk: usize,
    pub lr: LrSchedule,
    /// Override kernel.
    pub kernel: Option<Kernel>,
    /// Per-example loss (paper: hinge).
    pub loss: Loss,
    /// Prequential trace window: a windowed error point is emitted every
    /// `trace_window` stream items. `0` picks a stream-relative default
    /// (`n / 10`, at least `chunk`), so traces have ~10 points however
    /// long the stream is.
    pub trace_window: usize,
}

/// Default rationale. `budget: 256` keeps prediction at 256 kernel
/// evaluations per point — small enough for per-item streaming latency,
/// large enough that the reservoir stays an informative sample of the
/// streams this repo generates (the paper's Fig. 2c/d shows expansion
/// sizes in the tens-to-hundreds already close the gap to the batch
/// solver on such workloads). `chunk: 16` amortises one `|I| x |J|`
/// kernel block over 16 observations without letting the model lag the
/// stream by more than 16 items. The step schedule is `0.5 / sqrt(t)`
/// rather than the serial solver's `1/t`: a budgeted reservoir keeps
/// *replacing* expansion points, so the gradient never becomes
/// stationary and the slower-decaying schedule retains enough plasticity
/// to track it (the "better control of the variance" trade-off the
/// paper remarks on). `lam`, `gamma`, `kernel` and `loss` mirror
/// [`crate::solver::dsekl::DseklOpts`].
impl Default for OnlineOpts {
    fn default() -> Self {
        OnlineOpts {
            gamma: 1.0,
            lam: 1e-4,
            budget: 256,
            chunk: 16,
            lr: LrSchedule::InvSqrtT { eta0: 0.5 },
            kernel: None,
            loss: Loss::Hinge,
            trace_window: 0,
        }
    }
}

impl OnlineOpts {
    /// Reject configurations that cannot produce a usable model. A
    /// `budget` of 0 admits nothing into the reservoir, so the frozen
    /// model would be a zero-row expansion — unsaveable and scoring
    /// everything 0 — and a `chunk` of 0 would step on every empty
    /// pending buffer. Both are caller errors; fail at the front door
    /// instead of emitting a degenerate model at stream end.
    pub fn validate(&self) -> Result<()> {
        if self.budget == 0 {
            return Err(Error::invalid(
                "online budget must be >= 1: a zero-point reservoir can \
                 never admit an expansion point, so the frozen model \
                 would be empty",
            ));
        }
        if self.chunk == 0 {
            return Err(Error::invalid("online chunk must be >= 1"));
        }
        Ok(())
    }
}

/// Streaming DSEKL state: a budgeted kernel expansion updated per chunk.
#[derive(Debug)]
pub struct OnlineDsekl {
    opts: OnlineOpts,
    kernel: Kernel,
    d: usize,
    /// Reservoir expansion points, row-major `[len, d]`.
    x: Vec<f32>,
    /// Dual coefficients over the reservoir.
    alpha: Vec<f32>,
    /// Stream position (for reservoir sampling + lr schedule).
    seen: u64,
    steps: u64,
    /// Pending chunk buffers.
    pend_x: Vec<f32>,
    pend_y: Vec<f32>,
    g: Vec<f32>,
    /// Cumulative masked loss over all chunk steps, and the number of
    /// examples those steps covered (for mean-loss reporting).
    loss_acc: f64,
    loss_pts: u64,
}

impl OnlineDsekl {
    /// New empty stream learner for `d`-dimensional inputs.
    pub fn new(opts: OnlineOpts, d: usize) -> Self {
        let kernel = opts.kernel.unwrap_or(Kernel::Rbf { gamma: opts.gamma });
        OnlineDsekl {
            opts,
            kernel,
            d,
            x: Vec::new(),
            alpha: Vec::new(),
            seen: 0,
            steps: 0,
            pend_x: Vec::new(),
            pend_y: Vec::new(),
            g: Vec::new(),
            loss_acc: 0.0,
            loss_pts: 0,
        }
    }

    /// Number of expansion points currently held (<= budget).
    pub fn expansion_len(&self) -> usize {
        self.alpha.len()
    }

    /// Total stream items consumed.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Gradient steps taken (one per full chunk, plus flushes).
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Mean per-example loss over every chunk step so far.
    pub fn mean_loss(&self) -> f64 {
        self.loss_acc / self.loss_pts.max(1) as f64
    }

    /// Current decision score for a point (0 before any data).
    pub fn score(&self, backend: &mut dyn Backend, x: &[f32]) -> Result<f32> {
        if self.alpha.is_empty() {
            return Ok(0.0);
        }
        let mut f = Vec::new();
        backend.predict(
            self.kernel,
            Rows::dense(x, 1, self.d),
            Rows::dense(&self.x, self.alpha.len(), self.d),
            &self.alpha,
            &mut f,
        )?;
        Ok(f[0])
    }

    /// Consume one labelled example; runs a gradient step every `chunk`
    /// items. Returns the pre-update score (for prequential evaluation:
    /// test-then-train).
    pub fn observe<R: Rng>(
        &mut self,
        backend: &mut dyn Backend,
        x: &[f32],
        y: f32,
        rng: &mut R,
    ) -> Result<f32> {
        assert_eq!(x.len(), self.d);
        let score = self.score(backend, x)?;
        self.seen += 1;
        self.pend_x.extend_from_slice(x);
        self.pend_y.push(y);

        // Reservoir update: keep the expansion a uniform sample of the
        // stream. While under budget, always admit (alpha starts at 0).
        let cap = self.opts.budget;
        if self.alpha.len() < cap {
            self.x.extend_from_slice(x);
            self.alpha.push(0.0);
        } else {
            let slot = rng.below(self.seen as usize);
            if slot < cap {
                // Evict the reservoir slot; if its coefficient carries
                // weight, prefer dropping the globally smallest |alpha|
                // instead (budget-perceptron style truncation).
                let victim = if self.alpha[slot].abs() < 1e-6 {
                    slot
                } else {
                    (0..cap)
                        .min_by(|&a, &b| {
                            self.alpha[a]
                                .abs()
                                .partial_cmp(&self.alpha[b].abs())
                                .unwrap()
                        })
                        .unwrap()
                };
                self.x[victim * self.d..(victim + 1) * self.d].copy_from_slice(x);
                self.alpha[victim] = 0.0;
            }
        }

        if self.pend_y.len() >= self.opts.chunk {
            let _ = self.step(backend)?;
        }
        Ok(score)
    }

    /// Run the pending-chunk gradient step (called automatically; public
    /// so callers can flush at stream end). Returns the step's loss
    /// diagnostics, or `None` when nothing was pending.
    pub fn step(&mut self, backend: &mut dyn Backend) -> Result<Option<StepOut>> {
        let i = self.pend_y.len();
        if i == 0 || self.alpha.is_empty() {
            self.pend_x.clear();
            self.pend_y.clear();
            return Ok(None);
        }
        self.steps += 1;
        let j = self.alpha.len();
        let frac = (i as f32) / (self.seen.max(1) as f32);
        let out = backend.dsekl_step(
            self.kernel,
            &StepInput {
                xi: Rows::dense(&self.pend_x, i, self.d),
                yi: &self.pend_y,
                xj: Rows::dense(&self.x, j, self.d),
                alpha: &self.alpha,
                lam: self.opts.lam,
                frac,
                loss: self.opts.loss,
            },
            &mut self.g,
        )?;
        self.loss_acc += out.loss as f64;
        self.loss_pts += i as u64;
        let eta = self.opts.lr.at(self.steps);
        for (a, gv) in self.alpha.iter_mut().zip(&self.g) {
            *a -= eta * gv;
        }
        self.pend_x.clear();
        self.pend_y.clear();
        Ok(Some(out))
    }

    /// Snapshot the current expansion as a standalone model.
    pub fn to_model(&self) -> KernelModel {
        KernelModel::new(self.kernel, self.x.clone(), self.alpha.clone(), self.d)
    }
}

/// Output of a dataset-driven streaming run ([`OnlineSolver`]).
#[derive(Debug, Clone)]
pub struct OnlineResult {
    /// The budgeted expansion frozen at stream end (dense rows — the
    /// reservoir densifies CSR stream items one row at a time).
    pub model: KernelModel,
    /// Stats bundle: iterations = chunk steps, points = items consumed.
    /// The trace carries one windowed prequential-error point per
    /// [`OnlineOpts::trace_window`] items, and a final cumulative point
    /// at stream end (so `trace.last_val_error()` is always the
    /// whole-stream prequential error below).
    pub stats: TrainStats,
    /// Prequential (test-then-train) error over the whole stream: each
    /// item is scored *before* the learner may train on it, so this is
    /// an honest online generalisation estimate, not a training error.
    pub prequential_error: f64,
}

/// Dataset-driven streaming driver over [`OnlineDsekl`]: presents the
/// rows of a dataset **in storage order** as a stream (chunked into
/// [`OnlineOpts::chunk`]-sized gradient steps), scoring each item
/// before it trains on it. This is the estimator-facing surface of the
/// paper-conclusion workload — `dsekl train --solver online` on the
/// CLI and [`crate::estimator::Fit::online`] in the library. CSR
/// datasets stream without densifying the set: each row is scattered
/// into one reused `d`-length buffer as it arrives (the reservoir
/// itself is dense — budget × d floats, independent of N).
#[derive(Debug, Clone)]
pub struct OnlineSolver {
    opts: OnlineOpts,
}

impl OnlineSolver {
    /// New solver with the given options.
    pub fn new(opts: OnlineOpts) -> Self {
        OnlineSolver { opts }
    }

    /// The options in use.
    pub fn opts(&self) -> &OnlineOpts {
        &self.opts
    }

    /// **The** streaming loop, generic over the data layout: feed the
    /// `x` rows (dense or CSR) with ±1 labels `y` through a fresh
    /// [`OnlineDsekl`] in storage order, flush the last partial chunk,
    /// and freeze the reservoir into a model. Consumes `rng` exactly
    /// like a manual `observe` loop over the same learner would.
    pub fn train_rows<R: Rng>(
        &self,
        backend: &mut dyn Backend,
        x: Rows,
        y: &[f32],
        rng: &mut R,
    ) -> Result<OnlineResult> {
        self.opts.validate()?;
        let n = x.len();
        if n == 0 {
            return Err(Error::invalid("empty training set"));
        }
        if y.len() != n {
            return Err(Error::invalid(format!(
                "labels/rows length mismatch ({} vs {n})",
                y.len()
            )));
        }
        let d = x.dim();
        let watch = Stopwatch::new();
        let mut learner = OnlineDsekl::new(self.opts.clone(), d);
        let mut scratch = vec![0.0f32; d];
        // Windowed prequential trace: one error point per completed
        // window mid-stream (consuming no rng, so the learner's update
        // sequence is byte-identical to a traceless run), then a final
        // cumulative point at stream end.
        let window = if self.opts.trace_window > 0 {
            self.opts.trace_window
        } else {
            (n / 10).max(self.opts.chunk).max(1)
        };
        let mut preq = PrequentialWindow::new(window);
        let mut stats = TrainStats::new();
        for i in 0..n {
            let row: &[f32] = match x {
                Rows::Dense { x, .. } => &x[i * d..(i + 1) * d],
                Rows::Csr(c) => {
                    scratch.fill(0.0);
                    let (cols, vals) = c.row(i);
                    for (&col, &v) in cols.iter().zip(vals) {
                        scratch[col as usize] = v;
                    }
                    &scratch[..]
                }
            };
            let score = learner.observe(backend, row, y[i], rng)?;
            if let Some(win_err) = preq.observe(score * y[i] <= 0.0) {
                if (i + 1) < n {
                    stats.trace.push(TracePoint {
                        points_processed: preq.seen(),
                        iteration: learner.steps(),
                        loss: learner.mean_loss(),
                        val_error: Some(win_err),
                        elapsed_s: watch.total(),
                    });
                }
            }
        }
        let _ = learner.step(backend)?; // flush the last partial chunk

        let prequential_error = preq.total_error();
        stats.iterations = learner.steps();
        stats.points_processed = learner.seen();
        stats.elapsed_s = watch.total();
        stats.trace.push(TracePoint {
            points_processed: stats.points_processed,
            iteration: stats.iterations,
            loss: learner.mean_loss(),
            val_error: Some(prequential_error),
            elapsed_s: stats.elapsed_s,
        });
        Ok(OnlineResult {
            model: learner.to_model(),
            stats,
            prequential_error,
        })
    }

    /// Stream a dense dataset.
    pub fn train<R: Rng>(
        &self,
        backend: &mut dyn Backend,
        train: &Dataset,
        rng: &mut R,
    ) -> Result<OnlineResult> {
        self.train_rows(backend, train.rows(), &train.y, rng)
    }

    /// Stream a **CSR** dataset (rows are densified one at a time into
    /// a reused buffer; the set itself stays CSR).
    pub fn train_sparse<R: Rng>(
        &self,
        backend: &mut dyn Backend,
        train: &SparseDataset,
        rng: &mut R,
    ) -> Result<OnlineResult> {
        self.train_rows(backend, train.rows(), &train.y, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::metrics::error_rate;
    use crate::rng::Pcg64;
    use crate::runtime::NativeBackend;

    #[test]
    fn learns_xor_stream_prequentially() {
        let mut rng = Pcg64::seed_from(1);
        let stream = synth::xor(2000, 0.2, &mut rng);
        let mut be = NativeBackend::new();
        let mut learner = OnlineDsekl::new(
            OnlineOpts {
                budget: 128,
                chunk: 16,
                ..Default::default()
            },
            2,
        );
        let mut late_wrong = 0usize;
        let mut late_total = 0usize;
        for idx in 0..stream.len() {
            let score = learner
                .observe(&mut be, stream.row(idx), stream.y[idx], &mut rng)
                .unwrap();
            if idx >= 1000 {
                late_total += 1;
                if score * stream.y[idx] <= 0.0 {
                    late_wrong += 1;
                }
            }
        }
        let preq_err = late_wrong as f64 / late_total as f64;
        assert!(preq_err < 0.10, "prequential error {preq_err}");
        assert_eq!(learner.expansion_len(), 128);
        assert_eq!(learner.seen(), 2000);
    }

    #[test]
    fn budget_is_respected_and_model_works() {
        let mut rng = Pcg64::seed_from(2);
        let stream = synth::blobs(600, 4, 6.0, &mut rng);
        let test = synth::blobs(200, 4, 6.0, &mut rng);
        let mut be = NativeBackend::new();
        let mut learner = OnlineDsekl::new(
            OnlineOpts {
                gamma: 0.3,
                budget: 64,
                chunk: 8,
                ..Default::default()
            },
            4,
        );
        for idx in 0..stream.len() {
            learner
                .observe(&mut be, stream.row(idx), stream.y[idx], &mut rng)
                .unwrap();
        }
        let _ = learner.step(&mut be).unwrap(); // flush
        assert!(learner.expansion_len() <= 64);
        let model = learner.to_model();
        let scores = model.scores(&mut be, &test).unwrap();
        let err = error_rate(&scores, &test.y);
        assert!(err < 0.1, "stream model test error {err}");
    }

    #[test]
    fn empty_learner_scores_zero() {
        let mut be = NativeBackend::new();
        let learner = OnlineDsekl::new(OnlineOpts::default(), 3);
        assert_eq!(learner.score(&mut be, &[1.0, 2.0, 3.0]).unwrap(), 0.0);
    }

    #[test]
    fn solver_matches_manual_observe_loop_bitwise() {
        // OnlineSolver::train is the manual observe/flush loop, nothing
        // more: same rng stream in, bitwise-identical model out.
        let mut rng = Pcg64::seed_from(13);
        let ds = synth::xor(300, 0.2, &mut rng);
        let opts = OnlineOpts {
            budget: 64,
            chunk: 8,
            ..Default::default()
        };
        let mut be = NativeBackend::new();

        let mut manual_rng = Pcg64::seed_from(5);
        let mut learner = OnlineDsekl::new(opts.clone(), ds.d);
        let mut wrong = 0usize;
        for i in 0..ds.len() {
            let score = learner
                .observe(&mut be, ds.row(i), ds.y[i], &mut manual_rng)
                .unwrap();
            if score * ds.y[i] <= 0.0 {
                wrong += 1;
            }
        }
        let _ = learner.step(&mut be).unwrap();
        let want = learner.to_model();

        let mut solver_rng = Pcg64::seed_from(5);
        let res = OnlineSolver::new(opts)
            .train(&mut be, &ds, &mut solver_rng)
            .unwrap();
        assert_eq!(res.model.alpha, want.alpha);
        assert_eq!(res.model.x(), want.x());
        assert_eq!(res.stats.iterations, learner.steps());
        assert_eq!(res.stats.points_processed, ds.len() as u64);
        assert_eq!(res.prequential_error, wrong as f64 / ds.len() as f64);
        assert_eq!(res.stats.trace.last_val_error(), Some(res.prequential_error));
    }

    #[test]
    fn trace_has_windowed_points_throughout_the_stream() {
        // Regression for the degenerate single-point trace: a 300-item
        // stream with trace_window 50 must carry 5 mid-stream windowed
        // points plus the final cumulative point — and windowing must
        // not perturb the learner (it consumes no rng).
        let mut rng = Pcg64::seed_from(21);
        let ds = synth::xor(300, 0.2, &mut rng);
        let mut be = NativeBackend::new();
        let opts = OnlineOpts {
            budget: 64,
            chunk: 8,
            trace_window: 50,
            ..Default::default()
        };
        let mut rng_a = Pcg64::seed_from(7);
        let res = OnlineSolver::new(opts)
            .train(&mut be, &ds, &mut rng_a)
            .unwrap();
        let points = &res.stats.trace.points;
        assert_eq!(points.len(), 6, "5 windows + final cumulative point");
        for (w, p) in points.iter().take(5).enumerate() {
            assert_eq!(p.points_processed, 50 * (w as u64 + 1));
            let ve = p.val_error.expect("windowed error present");
            assert!((0.0..=1.0).contains(&ve));
        }
        let last = points.last().unwrap();
        assert_eq!(last.points_processed, 300);
        assert_eq!(last.val_error, Some(res.prequential_error));
        // Same seed without windowing: bitwise-identical model.
        let mut rng_b = Pcg64::seed_from(7);
        let plain = OnlineSolver::new(OnlineOpts {
            budget: 64,
            chunk: 8,
            trace_window: 300,
            ..Default::default()
        })
        .train(&mut be, &ds, &mut rng_b)
        .unwrap();
        assert_eq!(plain.stats.trace.points.len(), 1);
        assert_eq!(plain.model.alpha, res.model.alpha);
        assert_eq!(plain.prequential_error, res.prequential_error);
    }

    #[test]
    fn solver_sparse_stream_matches_dense_twin_bitwise() {
        // A CSR stream densifies rows one at a time; item-for-item it
        // must be the identical stream, so the models match bitwise.
        let mut rng = Pcg64::seed_from(17);
        let sparse = synth::sparse_binary(240, 40, 0.1, &mut rng);
        let dense = sparse.to_dense();
        let opts = OnlineOpts {
            budget: 48,
            chunk: 8,
            kernel: Some(Kernel::Linear),
            ..Default::default()
        };
        let mut be = NativeBackend::new();
        let mut rng_s = Pcg64::seed_from(9);
        let rs = OnlineSolver::new(opts.clone())
            .train_sparse(&mut be, &sparse, &mut rng_s)
            .unwrap();
        let mut rng_d = Pcg64::seed_from(9);
        let rd = OnlineSolver::new(opts)
            .train(&mut be, &dense, &mut rng_d)
            .unwrap();
        assert_eq!(rs.model.alpha, rd.model.alpha);
        assert_eq!(rs.model.x(), rd.model.x());
        assert_eq!(rs.prequential_error, rd.prequential_error);
    }

    #[test]
    fn zero_budget_is_rejected_up_front() {
        // Regression: a budget-0 reservoir never admits a point, so the
        // frozen model would be a zero-row expansion. Reject at the
        // front door instead of emitting a degenerate model.
        let mut be = NativeBackend::new();
        let mut rng = Pcg64::seed_from(3);
        let ds = synth::xor(20, 0.2, &mut rng);
        let opts = OnlineOpts {
            budget: 0,
            ..Default::default()
        };
        let err = OnlineSolver::new(opts.clone())
            .train(&mut be, &ds, &mut rng)
            .unwrap_err()
            .to_string();
        assert!(err.contains("budget must be >= 1"), "{err}");
        assert!(opts.validate().is_err());
        assert!(OnlineOpts {
            chunk: 0,
            ..Default::default()
        }
        .validate()
        .is_err());
        assert!(OnlineOpts::default().validate().is_ok());
    }

    #[test]
    fn solver_rejects_empty_and_mismatched() {
        let mut be = NativeBackend::new();
        let mut rng = Pcg64::seed_from(1);
        let solver = OnlineSolver::new(OnlineOpts::default());
        assert!(solver
            .train(&mut be, &crate::data::Dataset::with_dim(2), &mut rng)
            .is_err());
        let mut rng2 = Pcg64::seed_from(2);
        let ds = synth::xor(10, 0.2, &mut rng2);
        assert!(solver
            .train_rows(&mut be, ds.rows(), &ds.y[..5], &mut rng)
            .is_err());
    }
}
