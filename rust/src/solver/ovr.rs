//! One-vs-rest multiclass training on top of the DSEKL machinery.
//!
//! The paper binarises covtype ("class 2 vs rest") to fit the binary SVM
//! formulation; this driver opens the native K-class workload instead:
//! it trains K binary DSEKL heads and predicts by argmax over their
//! decision scores ([`MulticlassModel`]).
//!
//! **One schedule, one kernel block, K heads.** Every head sees the
//! identical doubly stochastic `I`/`J` index sequence, so the expensive
//! `|I| x |J|` kernel block of a step is *identical across classes* —
//! only the ±1 labels and the coefficients differ. The driver therefore
//! draws the schedule **once per iteration**, gathers the sample rows
//! once, and steps all K heads against the shared block through
//! [`Backend::dsekl_step_multi`] — the block-reuse structure that the
//! doubly-stochastic-gradients literature (Dai et al. 2014, Tu et al.
//! 2016) gets its multi-output throughput from. Per-head arithmetic is
//! bitwise-identical to K independent [`DseklSolver`] runs over cloned
//! RNGs (pinned by the mirror-image and fused-vs-looped tests below),
//! and the caller's RNG is left untouched.
//!
//! Labels are taken as per-class *views* over the shared class-id
//! vector and the resulting model heads are views over one shared
//! [`crate::model::ExpansionStore`], so neither training memory nor
//! model storage scales the feature rows with K.
//!
//! Like [`DseklSolver`], the driver has exactly **one** training loop
//! ([`OvrSolver::train_rows`]) written against the gather abstraction:
//! the dense and CSR entry points are wrappers over it, so their I/J
//! schedules and per-head tolerance freezing are identical by
//! construction (`rust/tests/schedule_parity.rs`), and a CSR run keeps
//! O(nnz) memory through to the saved (DSEKLv3) model.

use crate::data::{GatherBatch, MultiDataset, Rows, SparseMultiDataset};
use crate::metrics::{Stopwatch, TracePoint};
use crate::model::{ExpansionStore, MulticlassModel};
use crate::rng::{sample_without_replacement, Rng};
use crate::runtime::{Backend, MultiStepInput};
use crate::solver::dsekl::DseklOpts;
#[allow(unused_imports)] // docs reference it
use crate::solver::dsekl::DseklSolver;
use crate::solver::TrainStats;
use crate::{Error, Result};

/// One-vs-rest options: the shared per-class binary solver
/// configuration (loss, kernel, sample sizes, schedule — everything in
/// [`DseklOpts`] applies to each of the K heads).
#[derive(Debug, Clone, Default)]
pub struct OvrOpts {
    /// Per-class binary DSEKL configuration.
    pub inner: DseklOpts,
}

/// One-vs-rest training output.
#[derive(Debug, Clone)]
pub struct OvrResult {
    /// The argmax model over K heads sharing one expansion store.
    pub model: MulticlassModel,
    /// Per-class training statistics (index == class id).
    pub per_class: Vec<TrainStats>,
}

/// One-vs-rest multiclass DSEKL driver (fused K-head steps).
#[derive(Debug, Clone)]
pub struct OvrSolver {
    opts: OvrOpts,
}

impl OvrSolver {
    /// New solver with the given options.
    pub fn new(opts: OvrOpts) -> Self {
        OvrSolver { opts }
    }

    /// The options in use.
    pub fn opts(&self) -> &OvrOpts {
        &self.opts
    }

    /// **The** fused K-head training loop, generic over the data layout
    /// through the gather abstraction: `x` is any [`Rows`] view (dense
    /// or CSR), `y` the class ids `0..n_classes` over those rows. The
    /// dense and CSR entry points are thin wrappers, so the shared I/J
    /// schedule, the per-head tolerance freezing and the per-head
    /// bookkeeping are identical by construction. The caller's `rng` is
    /// cloned, never advanced, and the returned model's K heads share
    /// one layout-preserving [`ExpansionStore`].
    pub fn train_rows<R: Rng + Clone>(
        &self,
        backend: &mut dyn Backend,
        x: Rows,
        y: &[u32],
        n_classes: usize,
        rng: &mut R,
    ) -> Result<OvrResult> {
        let n = x.len();
        if n == 0 {
            return Err(Error::invalid("empty training set"));
        }
        if y.len() != n {
            return Err(Error::invalid(format!(
                "labels/rows length mismatch ({} vs {n})",
                y.len()
            )));
        }
        if n_classes < 2 {
            return Err(Error::invalid(format!(
                "one-vs-rest needs >= 2 classes, dataset declares {n_classes}"
            )));
        }
        // The dataset wrappers enforce this at push time, but this is a
        // public entry point over a raw label slice: an out-of-range id
        // would otherwise silently train every head against -1.
        if let Some(&bad) = y.iter().find(|&&c| c as usize >= n_classes) {
            return Err(Error::invalid(format!(
                "class id {bad} out of range (K = {n_classes})"
            )));
        }
        let k = n_classes;
        let o = &self.opts.inner;
        let i_size = o.i_size.min(n);
        let j_size = o.j_size.min(n);
        let kernel = o.kernel();

        // One cloned stream drives the schedule for every head; the
        // caller's stream is untouched (same contract as before).
        let mut sched = rng.clone();

        // Per-head state: coefficients [K, n] and solver bookkeeping
        // mirroring DseklSolver::train_rows head-for-head.
        let mut alpha = vec![0.0f32; k * n];
        let mut stats = vec![TrainStats::new(); k];
        let mut epoch_change_sq = vec![0.0f64; k];
        let mut loss_acc = vec![0.0f64; k];
        let mut loss_cnt = vec![0u64; k];
        let watch = Stopwatch::new();

        // Reused gather buffers — the hot loop allocates nothing after
        // warmup, in either layout.
        let mut xi = GatherBatch::default();
        let mut xj = GatherBatch::default();
        let mut yi = Vec::with_capacity(k * i_size);
        let mut alpha_j = Vec::with_capacity(k * j_size);
        let mut g = Vec::new();

        let iters_per_epoch = (n as u64).div_ceil(i_size as u64).max(1);

        // Heads still training; a head that hits its tolerance is frozen
        // (exactly where its independent run would have stopped) while
        // the rest keep stepping against the shared blocks.
        let mut active: Vec<usize> = (0..k).collect();

        for t in 1..=o.max_iters {
            if active.is_empty() {
                break;
            }
            // Two independent uniform samples (the "doubly" part), drawn
            // once and shared by every head.
            let ii = sample_without_replacement(&mut sched, n, i_size);
            let jj = sample_without_replacement(&mut sched, n, j_size);
            // Per-batch regularisation fraction from the batch's actual
            // size — the same contract the coordinator ships per work
            // item (bit-identical here: uniform sampling fills the
            // batch).
            let frac = ii.len() as f32 / n as f32;
            x.gather_into(&ii, &mut xi);
            x.gather_into(&jj, &mut xj);

            // Per-head ±1 label views over the shared class ids and
            // coefficient snapshots, packed [active, i] / [active, j]
            // for the fused step.
            yi.clear();
            alpha_j.clear();
            for &h in &active {
                yi.extend(
                    ii.iter()
                        .map(|&i| if y[i] == h as u32 { 1.0 } else { -1.0 }),
                );
                alpha_j.extend(jj.iter().map(|&j| alpha[h * n + j]));
            }

            let outs = backend.dsekl_step_multi(
                kernel,
                &MultiStepInput {
                    xi: xi.view(),
                    yi: &yi,
                    xj: xj.view(),
                    alpha: &alpha_j,
                    heads: active.len(),
                    lam: o.lam,
                    frac,
                    loss: o.loss,
                },
                &mut g,
            )?;

            let eta = o.lr.at(t);
            let mut any_frozen = false;
            for (slot, &h) in active.iter().enumerate() {
                let gh = &g[slot * j_size..(slot + 1) * j_size];
                let ah = &mut alpha[h * n..(h + 1) * n];
                for (&j, &gv) in jj.iter().zip(gh) {
                    let delta = eta * gv;
                    ah[j] -= delta;
                    epoch_change_sq[h] += (delta as f64) * (delta as f64);
                }

                let s = &mut stats[h];
                s.iterations = t;
                s.points_processed += i_size as u64;
                loss_acc[h] += outs[slot].loss as f64 / i_size as f64;
                loss_cnt[h] += 1;

                let mut record = o.eval_every > 0 && t % o.eval_every == 0;

                // Epoch boundary: per-head convergence check on the
                // accumulated weight change.
                if t % iters_per_epoch == 0 {
                    let change = epoch_change_sq[h].sqrt();
                    epoch_change_sq[h] = 0.0;
                    if o.tol > 0.0 && change < o.tol as f64 {
                        s.converged = true;
                        record = true;
                        any_frozen = true;
                    }
                }

                if record {
                    s.trace.push(TracePoint {
                        points_processed: s.points_processed,
                        iteration: t,
                        loss: loss_acc[h] / loss_cnt[h].max(1) as f64,
                        val_error: None,
                        elapsed_s: watch.total(),
                    });
                    loss_acc[h] = 0.0;
                    loss_cnt[h] = 0;
                }
            }
            if any_frozen {
                active.retain(|&h| !stats[h].converged);
            }
        }

        let elapsed = watch.total();
        for s in &mut stats {
            s.elapsed_s = elapsed;
        }

        // One shared row block for all K heads — the rows are stored
        // (and serialised) once, in the layout of the training data;
        // copied only here, so the loop never holds a second copy.
        let store = ExpansionStore::from_rows(x);
        Ok(OvrResult {
            model: MulticlassModel::from_shared(kernel, store, alpha),
            per_class: stats,
        })
    }

    /// Train K one-vs-rest heads on a dense dataset with a shared I/J
    /// schedule and fused K-head steps (see module docs); the caller's
    /// `rng` is not advanced.
    pub fn train<R: Rng + Clone>(
        &self,
        backend: &mut dyn Backend,
        train: &MultiDataset,
        rng: &mut R,
    ) -> Result<OvrResult> {
        self.train_rows(backend, train.rows(), &train.y, train.n_classes, rng)
    }

    /// Train K one-vs-rest heads on a **CSR** dataset — the same
    /// [`OvrSolver::train_rows`] loop over CSR views: batches gather as
    /// CSR, the backend runs the O(nnz) block path, and the model's
    /// shared expansion store stays CSR (serialising as DSEKLv3) —
    /// nothing is densified.
    pub fn train_sparse<R: Rng + Clone>(
        &self,
        backend: &mut dyn Backend,
        train: &SparseMultiDataset,
        rng: &mut R,
    ) -> Result<OvrResult> {
        self.train_rows(backend, train.rows(), &train.y, train.n_classes, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::loss::Loss;
    use crate::rng::Pcg64;
    use crate::runtime::NativeBackend;
    use crate::solver::dsekl::DseklSolver;

    fn ring_opts(loss: Loss, max_iters: u64) -> OvrOpts {
        OvrOpts {
            inner: DseklOpts {
                gamma: 1.0,
                lam: 1e-4,
                i_size: 32,
                j_size: 32,
                max_iters,
                loss,
                ..Default::default()
            },
        }
    }

    #[test]
    fn learns_four_class_blobs_with_logistic() {
        // The acceptance workload: a seeded 4-class ring, logistic loss,
        // one-vs-rest — test error well under 10%.
        let mut rng = Pcg64::seed_from(42);
        let ds = synth::multi_blobs(400, 4, 2, 0.25, &mut rng);
        let (train, test) = ds.split(0.5, &mut rng);
        let mut be = NativeBackend::new();
        let res = OvrSolver::new(ring_opts(Loss::Logistic, 600))
            .train(&mut be, &train, &mut rng)
            .unwrap();
        assert_eq!(res.model.n_classes(), 4);
        assert_eq!(res.per_class.len(), 4);
        let err = res.model.error(&mut be, &test).unwrap();
        assert!(err <= 0.10, "4-class blob test error {err}");
    }

    #[test]
    fn learns_with_hinge_too() {
        let mut rng = Pcg64::seed_from(7);
        let ds = synth::multi_blobs(300, 3, 2, 0.25, &mut rng);
        let (train, test) = ds.split(0.5, &mut rng);
        let mut be = NativeBackend::new();
        let res = OvrSolver::new(ring_opts(Loss::Hinge, 500))
            .train(&mut be, &train, &mut rng)
            .unwrap();
        let err = res.model.error(&mut be, &test).unwrap();
        assert!(err <= 0.10, "3-class hinge test error {err}");
    }

    #[test]
    fn shared_schedule_makes_two_class_machines_mirror_images() {
        // For K = 2 the class-1 binary view is the exact label negation
        // of the class-0 view. Because both machines draw the *same*
        // I/J schedule, their coefficient trajectories are exact
        // negations of each other — a bitwise witness that the sampling
        // schedule is shared across class machines.
        let mut rng = Pcg64::seed_from(3);
        let ds = synth::multi_blobs(120, 2, 2, 0.3, &mut rng);
        let mut be = NativeBackend::new();
        let res = OvrSolver::new(ring_opts(Loss::Hinge, 150))
            .train(&mut be, &ds, &mut rng)
            .unwrap();
        let a0 = &res.model.models[0].alpha;
        let a1 = &res.model.models[1].alpha;
        assert_eq!(a0.len(), a1.len());
        assert!(a0.iter().any(|v| *v != 0.0), "training moved nothing");
        for (x, y) in a0.iter().zip(a1) {
            assert_eq!(*x, -*y, "schedules diverged: {x} vs {y}");
        }
    }

    #[test]
    fn deterministic_and_rng_not_advanced() {
        let mut rng = Pcg64::seed_from(5);
        let ds = synth::multi_blobs(90, 3, 2, 0.3, &mut rng);
        let mut be = NativeBackend::new();
        let solver = OvrSolver::new(ring_opts(Loss::Logistic, 100));
        let before = rng.clone();
        let a = solver.train(&mut be, &ds, &mut rng).unwrap();
        let b = solver.train(&mut be, &ds, &mut rng).unwrap();
        for (ma, mb) in a.model.models.iter().zip(&b.model.models) {
            assert_eq!(ma.alpha, mb.alpha);
        }
        // The caller's stream was never advanced.
        let mut fresh = before;
        let mut used = rng;
        for _ in 0..8 {
            assert_eq!(fresh.next_u64(), used.next_u64());
        }
    }

    /// The looped reference implementation the fused driver replaced:
    /// K independent DseklSolver runs over per-class binary views with
    /// cloned RNGs (the pre-redesign OvrSolver, verbatim semantics).
    fn looped_reference(
        opts: &OvrOpts,
        train: &crate::data::MultiDataset,
        rng: &Pcg64,
    ) -> Vec<Vec<f32>> {
        let inner = DseklSolver::new(opts.inner.clone());
        let mut be = NativeBackend::new();
        (0..train.n_classes)
            .map(|class| {
                let view = train.binary_view(class as u32);
                let mut class_rng = rng.clone();
                inner
                    .train(&mut be, &view, &mut class_rng)
                    .unwrap()
                    .model
                    .alpha
            })
            .collect()
    }

    #[test]
    fn fused_step_matches_looped_training_bitwise_k4() {
        // The redesign's core claim: one shared kernel block stepping
        // K = 4 heads is *bitwise* equal to 4 independent single-head
        // runs over cloned RNGs — for the paper's hinge and a smooth
        // loss, with the block shared for hundreds of iterations.
        for loss in [Loss::Hinge, Loss::Logistic] {
            let mut rng = Pcg64::seed_from(19);
            let ds = synth::multi_blobs(160, 4, 2, 0.3, &mut rng);
            let mut be = NativeBackend::new();
            let opts = ring_opts(loss, 250);
            let want = looped_reference(&opts, &ds, &rng);
            let res = OvrSolver::new(opts).train(&mut be, &ds, &mut rng).unwrap();
            assert_eq!(res.model.n_classes(), 4);
            for (c, w) in want.iter().enumerate() {
                assert_eq!(
                    &res.model.models[c].alpha, w,
                    "{loss}: fused head {c} diverged from looped reference"
                );
            }
        }
    }

    #[test]
    fn fused_tolerance_freezing_matches_looped_early_stop() {
        // With a convergence tolerance, heads freeze at exactly the
        // iteration their independent run would have stopped at.
        let mut rng = Pcg64::seed_from(23);
        let ds = synth::multi_blobs(96, 3, 2, 0.3, &mut rng);
        let mut opts = ring_opts(Loss::Hinge, 4000);
        opts.inner.tol = 0.2;
        let want = looped_reference(&opts, &ds, &rng);
        let mut be = NativeBackend::new();
        let res = OvrSolver::new(opts).train(&mut be, &ds, &mut rng).unwrap();
        for (c, w) in want.iter().enumerate() {
            assert_eq!(&res.model.models[c].alpha, w, "head {c} diverged");
        }
        assert!(
            res.per_class.iter().any(|s| s.converged),
            "tolerance never fired; test exercises nothing"
        );
    }

    #[test]
    fn model_heads_share_one_expansion_store() {
        let mut rng = Pcg64::seed_from(29);
        let ds = synth::multi_blobs(80, 3, 2, 0.3, &mut rng);
        let mut be = NativeBackend::new();
        let res = OvrSolver::new(ring_opts(Loss::Hinge, 50))
            .train(&mut be, &ds, &mut rng)
            .unwrap();
        assert!(res.model.is_shared());
        let first = res.model.models[0].store();
        for head in &res.model.models {
            assert!(head.store().shares_rows_with(first));
        }
    }

    #[test]
    fn beats_majority_baseline_on_covtype_multi() {
        let mut rng = Pcg64::seed_from(9);
        let ds = synth::covtype_multi(700, &mut rng);
        let (train, test) = ds.split(0.5, &mut rng);
        let mut be = NativeBackend::new();
        let opts = OvrOpts {
            inner: DseklOpts {
                gamma: 0.1,
                lam: 1e-4,
                i_size: 64,
                j_size: 64,
                max_iters: 300,
                loss: Loss::Logistic,
                ..Default::default()
            },
        };
        let res = OvrSolver::new(opts).train(&mut be, &train, &mut rng).unwrap();
        let err = res.model.error(&mut be, &test).unwrap();
        // Majority class carries ~1/7 of the mass => baseline error
        // ~0.86; the 7 machines must do far better.
        assert!(err < 0.45, "7-class covtype error {err}");
    }

    #[test]
    fn sparse_ovr_matches_dense_accuracy() {
        // CSR K-head training on a high-sparsity 3-class set reaches
        // the dense run's accuracy (same seed -> same I/J schedule).
        let mut rng = Pcg64::seed_from(41);
        let ds = synth::sparse_multiclass(240, 3, 48, 0.08, &mut rng);
        let opts = OvrOpts {
            inner: crate::solver::dsekl::DseklOpts {
                lam: 1e-4,
                i_size: 32,
                j_size: 32,
                lr: crate::solver::LrSchedule::InvT { eta0: 0.5 },
                max_iters: 300,
                kernel: Some(crate::kernel::Kernel::Linear),
                loss: Loss::Logistic,
                ..Default::default()
            },
        };
        let mut be = NativeBackend::new();
        let mut rng_s = Pcg64::seed_from(5);
        let res_s = OvrSolver::new(opts.clone())
            .train_sparse(&mut be, &ds, &mut rng_s)
            .unwrap();
        assert!(res_s.model.is_shared());
        let err_s = res_s.model.error_sparse(&mut be, &ds).unwrap();
        assert!(err_s <= 0.06, "sparse ovr error {err_s}");

        let dense = ds.to_dense();
        let mut rng_d = Pcg64::seed_from(5);
        let res_d = OvrSolver::new(opts).train(&mut be, &dense, &mut rng_d).unwrap();
        let err_d = res_d.model.error(&mut be, &dense).unwrap();
        assert!(
            (err_s - err_d).abs() <= 0.03,
            "sparse {err_s} vs dense {err_d}"
        );
    }

    #[test]
    fn rejects_degenerate_inputs() {
        let mut be = NativeBackend::new();
        let mut rng = Pcg64::seed_from(1);
        let empty = crate::data::MultiDataset::with_dims(2, 3);
        assert!(OvrSolver::new(OvrOpts::default())
            .train(&mut be, &empty, &mut rng)
            .is_err());
        let mut one_class = crate::data::MultiDataset::with_dims(2, 1);
        one_class.push(&[0.0, 0.0], 0);
        assert!(OvrSolver::new(OvrOpts::default())
            .train(&mut be, &one_class, &mut rng)
            .is_err());
    }
}
