//! One-vs-rest multiclass training on top of the DSEKL machinery.
//!
//! The paper binarises covtype ("class 2 vs rest") to fit the binary SVM
//! formulation; this driver opens the native K-class workload instead:
//! it trains K binary DSEKL machines, one per class, and predicts by
//! argmax over their decision scores ([`MulticlassModel`]).
//!
//! **Shared sampling schedule.** Every class machine is trained from a
//! *clone* of the caller's RNG, so all K machines draw exactly the same
//! doubly stochastic `I`/`J` index sequence over the shared feature
//! rows. Besides making runs reproducible per class, this mirrors the
//! efficient implementation the doubly-stochastic-gradients literature
//! suggests (one index draw serves all K heads) and is what a future
//! fused K-head compute kernel would exploit: the `|I| x |J|` kernel
//! block of a step is identical across classes, only the labels and
//! coefficients differ. The caller's RNG itself is left untouched.
//!
//! Known trade-off: each per-class [`crate::model::KernelModel`] owns
//! its own copy of the (shared) expansion rows, so memory and model-file
//! size scale with K. Deduplicating needs shared-ownership feature
//! storage in `KernelModel` (a ROADMAP item), which the K-head kernel
//! above would also want.

use crate::data::MultiDataset;
use crate::model::MulticlassModel;
use crate::rng::Rng;
use crate::runtime::Backend;
use crate::solver::dsekl::{DseklOpts, DseklSolver};
use crate::solver::TrainStats;
use crate::{Error, Result};

/// One-vs-rest options: the shared per-class binary solver
/// configuration (loss, kernel, sample sizes, schedule — everything in
/// [`DseklOpts`] applies to each of the K machines).
#[derive(Debug, Clone, Default)]
pub struct OvrOpts {
    /// Per-class binary DSEKL configuration.
    pub inner: DseklOpts,
}

/// One-vs-rest training output.
#[derive(Debug, Clone)]
pub struct OvrResult {
    /// The argmax model over K per-class machines.
    pub model: MulticlassModel,
    /// Per-class training statistics (index == class id).
    pub per_class: Vec<TrainStats>,
}

/// One-vs-rest multiclass DSEKL driver.
#[derive(Debug, Clone)]
pub struct OvrSolver {
    opts: OvrOpts,
}

impl OvrSolver {
    /// New solver with the given options.
    pub fn new(opts: OvrOpts) -> Self {
        OvrSolver { opts }
    }

    /// The options in use.
    pub fn opts(&self) -> &OvrOpts {
        &self.opts
    }

    /// Train K one-vs-rest machines on `train`. Each machine sees the
    /// identical index schedule (see module docs); the caller's `rng` is
    /// not advanced.
    pub fn train<R: Rng + Clone>(
        &self,
        backend: &mut dyn Backend,
        train: &MultiDataset,
        rng: &mut R,
    ) -> Result<OvrResult> {
        if train.is_empty() {
            return Err(Error::invalid("empty training set"));
        }
        if train.n_classes < 2 {
            return Err(Error::invalid(format!(
                "one-vs-rest needs >= 2 classes, dataset declares {}",
                train.n_classes
            )));
        }
        let inner = DseklSolver::new(self.opts.inner.clone());
        let mut models = Vec::with_capacity(train.n_classes);
        let mut per_class = Vec::with_capacity(train.n_classes);
        for class in 0..train.n_classes {
            let view = train.binary_view(class as u32);
            // Clone => identical I/J schedule for every class machine.
            let mut class_rng = rng.clone();
            let res = inner.train(backend, &view, &mut class_rng)?;
            models.push(res.model);
            per_class.push(res.stats);
        }
        Ok(OvrResult {
            model: MulticlassModel::new(models),
            per_class,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::loss::Loss;
    use crate::rng::Pcg64;
    use crate::runtime::NativeBackend;

    fn ring_opts(loss: Loss, max_iters: u64) -> OvrOpts {
        OvrOpts {
            inner: DseklOpts {
                gamma: 1.0,
                lam: 1e-4,
                i_size: 32,
                j_size: 32,
                max_iters,
                loss,
                ..Default::default()
            },
        }
    }

    #[test]
    fn learns_four_class_blobs_with_logistic() {
        // The acceptance workload: a seeded 4-class ring, logistic loss,
        // one-vs-rest — test error well under 10%.
        let mut rng = Pcg64::seed_from(42);
        let ds = synth::multi_blobs(400, 4, 2, 0.25, &mut rng);
        let (train, test) = ds.split(0.5, &mut rng);
        let mut be = NativeBackend::new();
        let res = OvrSolver::new(ring_opts(Loss::Logistic, 600))
            .train(&mut be, &train, &mut rng)
            .unwrap();
        assert_eq!(res.model.n_classes(), 4);
        assert_eq!(res.per_class.len(), 4);
        let err = res.model.error(&mut be, &test).unwrap();
        assert!(err <= 0.10, "4-class blob test error {err}");
    }

    #[test]
    fn learns_with_hinge_too() {
        let mut rng = Pcg64::seed_from(7);
        let ds = synth::multi_blobs(300, 3, 2, 0.25, &mut rng);
        let (train, test) = ds.split(0.5, &mut rng);
        let mut be = NativeBackend::new();
        let res = OvrSolver::new(ring_opts(Loss::Hinge, 500))
            .train(&mut be, &train, &mut rng)
            .unwrap();
        let err = res.model.error(&mut be, &test).unwrap();
        assert!(err <= 0.10, "3-class hinge test error {err}");
    }

    #[test]
    fn shared_schedule_makes_two_class_machines_mirror_images() {
        // For K = 2 the class-1 binary view is the exact label negation
        // of the class-0 view. Because both machines draw the *same*
        // I/J schedule, their coefficient trajectories are exact
        // negations of each other — a bitwise witness that the sampling
        // schedule is shared across class machines.
        let mut rng = Pcg64::seed_from(3);
        let ds = synth::multi_blobs(120, 2, 2, 0.3, &mut rng);
        let mut be = NativeBackend::new();
        let res = OvrSolver::new(ring_opts(Loss::Hinge, 150))
            .train(&mut be, &ds, &mut rng)
            .unwrap();
        let a0 = &res.model.models[0].alpha;
        let a1 = &res.model.models[1].alpha;
        assert_eq!(a0.len(), a1.len());
        assert!(a0.iter().any(|v| *v != 0.0), "training moved nothing");
        for (x, y) in a0.iter().zip(a1) {
            assert_eq!(*x, -*y, "schedules diverged: {x} vs {y}");
        }
    }

    #[test]
    fn deterministic_and_rng_not_advanced() {
        let mut rng = Pcg64::seed_from(5);
        let ds = synth::multi_blobs(90, 3, 2, 0.3, &mut rng);
        let mut be = NativeBackend::new();
        let solver = OvrSolver::new(ring_opts(Loss::Logistic, 100));
        let before = rng.clone();
        let a = solver.train(&mut be, &ds, &mut rng).unwrap();
        let b = solver.train(&mut be, &ds, &mut rng).unwrap();
        for (ma, mb) in a.model.models.iter().zip(&b.model.models) {
            assert_eq!(ma.alpha, mb.alpha);
        }
        // The caller's stream was never advanced.
        let mut fresh = before;
        let mut used = rng;
        for _ in 0..8 {
            assert_eq!(fresh.next_u64(), used.next_u64());
        }
    }

    #[test]
    fn beats_majority_baseline_on_covtype_multi() {
        let mut rng = Pcg64::seed_from(9);
        let ds = synth::covtype_multi(700, &mut rng);
        let (train, test) = ds.split(0.5, &mut rng);
        let mut be = NativeBackend::new();
        let opts = OvrOpts {
            inner: DseklOpts {
                gamma: 0.1,
                lam: 1e-4,
                i_size: 64,
                j_size: 64,
                max_iters: 300,
                loss: Loss::Logistic,
                ..Default::default()
            },
        };
        let res = OvrSolver::new(opts).train(&mut be, &train, &mut rng).unwrap();
        let err = res.model.error(&mut be, &test).unwrap();
        // Majority class carries ~1/7 of the mass => baseline error
        // ~0.86; the 7 machines must do far better.
        assert!(err < 0.45, "7-class covtype error {err}");
    }

    #[test]
    fn rejects_degenerate_inputs() {
        let mut be = NativeBackend::new();
        let mut rng = Pcg64::seed_from(1);
        let empty = crate::data::MultiDataset::with_dims(2, 3);
        assert!(OvrSolver::new(OvrOpts::default())
            .train(&mut be, &empty, &mut rng)
            .is_err());
        let mut one_class = crate::data::MultiDataset::with_dims(2, 1);
        one_class.push(&[0.0, 0.0], 0);
        assert!(OvrSolver::new(OvrOpts::default())
            .train(&mut be, &one_class, &mut rng)
            .is_err());
    }
}
