//! Prequential harness and the solver-shaped front of the subsystem:
//! [`StreamOpts`] configures the hybrid learner, [`StreamSolver::run`]
//! drives any [`StreamSource`] through it test-then-train, and
//! [`StreamResult`] carries the frozen models plus a windowed error
//! trace — the quality gate `tests/stream_drift.rs` pins.

use crate::kernel::Kernel;
use crate::loss::Loss;
use crate::metrics::{PrequentialWindow, Stopwatch, TracePoint};
use crate::model::{KernelModel, RksModel};
use crate::rng::Rng;
use crate::runtime::{Backend, Rows};
use crate::solver::{LrSchedule, TrainStats};
use crate::stream::hybrid::HybridDsekl;
use crate::stream::source::{RowsReplay, StreamSource};
use crate::{Error, Result};

/// Streaming solver configuration.
#[derive(Debug, Clone)]
pub struct StreamOpts {
    pub gamma: f32,
    pub lam: f32,
    /// Head expansion budget (post-eviction size).
    pub budget: usize,
    /// Gradient minibatch: stream items per step.
    pub chunk: usize,
    /// Eviction cadence in gradient steps: every `evict_every` steps the
    /// head is trimmed back to `budget` by coefficient magnitude. The
    /// expansion therefore never exceeds `budget + evict_every * chunk`
    /// rows.
    pub evict_every: u64,
    /// RKS tail width `r`; 0 disables the tail (budget-only streaming).
    pub tail_features: usize,
    /// Step schedule for head and tail. Constant by default: a drifting
    /// stream never becomes stationary, so a decaying schedule would
    /// freeze the model into the past.
    pub lr: LrSchedule,
    /// Override kernel (default RBF at `gamma`).
    pub kernel: Option<Kernel>,
    /// Per-example loss (paper: hinge).
    pub loss: Loss,
    /// Prequential trace window in items; 0 picks `n / 10` (at least
    /// `chunk`).
    pub trace_window: usize,
}

impl Default for StreamOpts {
    fn default() -> Self {
        StreamOpts {
            gamma: 1.0,
            lam: 1e-4,
            budget: 256,
            chunk: 16,
            evict_every: 4,
            tail_features: 128,
            lr: LrSchedule::Const { eta0: 0.2 },
            kernel: None,
            loss: Loss::Hinge,
            trace_window: 0,
        }
    }
}

impl StreamOpts {
    /// Reject configurations that cannot stream: a zero budget can keep
    /// nothing, a zero chunk steps on empty buffers, and a zero
    /// eviction cadence never trims — the budget would be a lie.
    pub fn validate(&self) -> Result<()> {
        if self.budget == 0 {
            return Err(Error::invalid(
                "stream budget must be >= 1: a zero-point head can never \
                 hold an expansion, so the frozen model would be empty",
            ));
        }
        if self.chunk == 0 {
            return Err(Error::invalid("stream chunk must be >= 1"));
        }
        if self.evict_every == 0 {
            return Err(Error::invalid(
                "stream evict_every must be >= 1 gradient steps: a zero \
                 cadence never evicts, so the budget would be unenforced",
            ));
        }
        Ok(())
    }
}

/// Output of a streaming run.
#[derive(Debug, Clone)]
pub struct StreamResult {
    /// The budgeted empirical-map head frozen at stream end.
    pub head: KernelModel,
    /// The RKS tail, when `tail_features > 0`. Hybrid scores are
    /// head + tail; persist both through
    /// [`crate::model::HybridModel`].
    pub tail: Option<RksModel>,
    /// Stats bundle: iterations = head steps, points = items consumed.
    /// The trace carries one windowed prequential-error point per
    /// [`StreamOpts::trace_window`] items and a final cumulative point,
    /// so `trace.last_val_error()` always equals `prequential_error`.
    pub stats: TrainStats,
    /// Cumulative prequential (test-then-train) error over the stream.
    pub prequential_error: f64,
}

/// Drives a [`StreamSource`] through the hybrid learner prequentially.
#[derive(Debug, Clone)]
pub struct StreamSolver {
    opts: StreamOpts,
}

impl StreamSolver {
    /// New solver with the given options.
    pub fn new(opts: StreamOpts) -> Self {
        StreamSolver { opts }
    }

    /// The options in use.
    pub fn opts(&self) -> &StreamOpts {
        &self.opts
    }

    /// **The** streaming loop: score each arriving item (test), train on
    /// it (then-train), emit a windowed error point per trace window,
    /// flush the last partial chunk, freeze. `rng` is consumed only for
    /// the tail's one-time feature draw, so a fixed `(opts, source,
    /// seed)` triple is bitwise-deterministic.
    pub fn run<R: Rng>(
        &self,
        backend: &mut dyn Backend,
        source: &mut dyn StreamSource,
        rng: &mut R,
    ) -> Result<StreamResult> {
        self.opts.validate()?;
        let n = source.len();
        if n == 0 {
            return Err(Error::invalid("empty stream source"));
        }
        let d = source.dim();
        if d == 0 {
            return Err(Error::invalid("stream source with zero dimensions"));
        }
        let watch = Stopwatch::new();
        let mut learner = HybridDsekl::new(&self.opts, d, rng);
        let window = if self.opts.trace_window > 0 {
            self.opts.trace_window
        } else {
            (n / 10).max(self.opts.chunk).max(1)
        };
        let mut preq = PrequentialWindow::new(window);
        let mut stats = TrainStats::new();
        let mut row = vec![0.0f32; d];
        while let Some(y) = source.next_into(&mut row) {
            let score = learner.observe(backend, &row, y)?;
            if let Some(win_err) = preq.observe(score * y <= 0.0) {
                if (preq.seen() as usize) < n {
                    stats.trace.push(TracePoint {
                        points_processed: preq.seen(),
                        iteration: learner.steps(),
                        loss: learner.mean_loss(),
                        val_error: Some(win_err),
                        elapsed_s: watch.total(),
                    });
                }
            }
        }
        learner.step(backend)?; // flush the last partial chunk

        let prequential_error = preq.total_error();
        stats.iterations = learner.steps();
        stats.points_processed = learner.seen();
        stats.elapsed_s = watch.total();
        stats.trace.push(TracePoint {
            points_processed: stats.points_processed,
            iteration: stats.iterations,
            loss: learner.mean_loss(),
            val_error: Some(prequential_error),
            elapsed_s: stats.elapsed_s,
        });
        let (head, tail) = learner.freeze();
        Ok(StreamResult {
            head,
            tail,
            stats,
            prequential_error,
        })
    }

    /// Stream borrowed rows (dense or CSR) in storage order — the
    /// estimator-facing surface behind `Fit::stream()`. CSR rows are
    /// scattered one at a time into a reused buffer; the set itself
    /// stays CSR.
    pub fn train_rows<R: Rng>(
        &self,
        backend: &mut dyn Backend,
        x: Rows,
        y: &[f32],
        rng: &mut R,
    ) -> Result<StreamResult> {
        if x.is_empty() {
            return Err(Error::invalid("empty training set"));
        }
        if y.len() != x.len() {
            return Err(Error::invalid(format!(
                "labels/rows length mismatch ({} vs {})",
                y.len(),
                x.len()
            )));
        }
        let mut source = RowsReplay::new(x, y);
        self.run(backend, &mut source, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::rng::Pcg64;
    use crate::runtime::NativeBackend;
    use crate::stream::source::StationaryBlobs;

    #[test]
    fn run_matches_manual_observe_loop_bitwise() {
        let opts = StreamOpts {
            budget: 16,
            chunk: 8,
            tail_features: 32,
            ..Default::default()
        };
        let mut be = NativeBackend::new();

        let mut manual_src = StationaryBlobs::new(120, 3, 4.0, 5);
        let mut manual_rng = Pcg64::seed_from(9);
        let mut learner = HybridDsekl::new(&opts, 3, &mut manual_rng);
        let mut row = vec![0.0f32; 3];
        let mut wrong = 0usize;
        while let Some(y) = manual_src.next_into(&mut row) {
            let s = learner.observe(&mut be, &row, y).unwrap();
            if s * y <= 0.0 {
                wrong += 1;
            }
        }
        learner.step(&mut be).unwrap();
        let (want_head, want_tail) = learner.freeze();

        let mut src = StationaryBlobs::new(120, 3, 4.0, 5);
        let mut rng = Pcg64::seed_from(9);
        let res = StreamSolver::new(opts).run(&mut be, &mut src, &mut rng).unwrap();
        assert_eq!(res.head.alpha, want_head.alpha);
        assert_eq!(res.head.x(), want_head.x());
        assert_eq!(res.tail.as_ref().unwrap().w, want_tail.unwrap().w);
        assert_eq!(res.prequential_error, wrong as f64 / 120.0);
        assert_eq!(res.stats.trace.last_val_error(), Some(res.prequential_error));
        assert_eq!(res.stats.points_processed, 120);
    }

    #[test]
    fn trace_is_windowed_throughout() {
        let opts = StreamOpts {
            budget: 16,
            chunk: 8,
            tail_features: 16,
            trace_window: 30,
            ..Default::default()
        };
        let mut be = NativeBackend::new();
        let mut src = StationaryBlobs::new(120, 3, 4.0, 2);
        let mut rng = Pcg64::seed_from(1);
        let res = StreamSolver::new(opts).run(&mut be, &mut src, &mut rng).unwrap();
        let points = &res.stats.trace.points;
        assert_eq!(points.len(), 4, "3 mid-stream windows + final point");
        assert_eq!(points[0].points_processed, 30);
        assert_eq!(points[1].points_processed, 60);
        assert_eq!(points[2].points_processed, 90);
        assert_eq!(points[3].points_processed, 120);
        assert_eq!(points[3].val_error, Some(res.prequential_error));
    }

    #[test]
    fn learns_a_stationary_stream() {
        let opts = StreamOpts {
            budget: 64,
            chunk: 8,
            tail_features: 64,
            ..Default::default()
        };
        let mut be = NativeBackend::new();
        let mut src = StationaryBlobs::new(800, 4, 6.0, 3);
        let mut rng = Pcg64::seed_from(4);
        let res = StreamSolver::new(opts).run(&mut be, &mut src, &mut rng).unwrap();
        // Late windows must be accurate on a well-separated stationary
        // stream (early windows pay the cold start).
        let late = res
            .stats
            .trace
            .points
            .iter()
            .rev()
            .nth(1)
            .and_then(|p| p.val_error)
            .unwrap();
        assert!(late < 0.1, "late-window prequential error {late}");
    }

    #[test]
    fn invalid_opts_and_empty_streams_are_rejected() {
        let mut be = NativeBackend::new();
        let mut rng = Pcg64::seed_from(1);
        let mut src = StationaryBlobs::new(0, 3, 4.0, 1);
        let err = StreamSolver::new(StreamOpts::default())
            .run(&mut be, &mut src, &mut rng)
            .unwrap_err()
            .to_string();
        assert!(err.contains("empty stream"), "{err}");
        for bad in [
            StreamOpts { budget: 0, ..Default::default() },
            StreamOpts { chunk: 0, ..Default::default() },
            StreamOpts { evict_every: 0, ..Default::default() },
        ] {
            assert!(bad.validate().is_err());
        }
        assert!(StreamOpts::default().validate().is_ok());
        // Mismatched labels through the rows front door.
        let mut rng2 = Pcg64::seed_from(2);
        let ds = synth::blobs(10, 2, 4.0, &mut rng2);
        assert!(StreamSolver::new(StreamOpts::default())
            .train_rows(&mut be, ds.rows(), &ds.y[..5], &mut rng)
            .is_err());
    }

    #[test]
    fn dense_and_csr_replays_match_bitwise() {
        let mut rng = Pcg64::seed_from(31);
        let sparse = synth::sparse_binary(160, 24, 0.15, &mut rng);
        let dense = sparse.to_dense();
        let opts = StreamOpts {
            budget: 24,
            chunk: 8,
            tail_features: 16,
            kernel: Some(Kernel::Linear),
            ..Default::default()
        };
        let mut be = NativeBackend::new();
        let mut rng_s = Pcg64::seed_from(6);
        let rs = StreamSolver::new(opts.clone())
            .train_rows(&mut be, sparse.rows(), &sparse.y, &mut rng_s)
            .unwrap();
        let mut rng_d = Pcg64::seed_from(6);
        let rd = StreamSolver::new(opts)
            .train_rows(&mut be, dense.rows(), &dense.y, &mut rng_d)
            .unwrap();
        assert_eq!(rs.head.alpha, rd.head.alpha);
        assert_eq!(rs.head.x(), rd.head.x());
        assert_eq!(rs.tail.unwrap().w, rd.tail.unwrap().w);
        assert_eq!(rs.prequential_error, rd.prequential_error);
    }
}
