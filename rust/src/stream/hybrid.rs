//! The RKS-tail hybrid: a budgeted empirical-map head plus a primal
//! random-features tail, trained jointly on every stream item and
//! scored as `f(x) = f_head(x) + f_tail(x)`.
//!
//! This is the Dai et al. "doubly stochastic gradients with random
//! features" answer to budget saturation (PAPERS.md): when drift churns
//! the head's expansion past its budget, the tail — whose capacity is
//! `r` random features, independent of the stream — keeps carrying the
//! part of the decision function the head had to evict, so accuracy
//! degrades gracefully instead of cliffing.

use crate::kernel::Kernel;
use crate::loss::Loss;
use crate::model::RksModel;
use crate::rng::Rng;
use crate::runtime::{Backend, RksStepInput, Rows};
use crate::solver::LrSchedule;
use crate::stream::learner::BudgetedDsekl;
use crate::stream::StreamOpts;
use crate::Result;

/// Primal random-kitchen-sinks tail: `r` RBF random features with a
/// linear head, stepped by the same chunked SGD as the kernel head.
#[derive(Debug)]
pub struct RksTail {
    w_feat: Vec<f32>,
    b_feat: Vec<f32>,
    w: Vec<f32>,
    d: usize,
    r: usize,
    lam: f32,
    loss: Loss,
    lr: LrSchedule,
    steps: u64,
    g: Vec<f32>,
}

impl RksTail {
    /// Sample an `r`-feature tail for the RBF bandwidth `gamma`. This
    /// is the only rng the streaming learner ever consumes — the
    /// feature draw at construction.
    pub fn new<R: Rng>(d: usize, r: usize, gamma: f32, opts: &StreamOpts, rng: &mut R) -> Self {
        let std = (2.0 * gamma as f64).sqrt();
        let w_feat: Vec<f32> = (0..d * r).map(|_| rng.normal_ms(0.0, std) as f32).collect();
        let b_feat: Vec<f32> = (0..r)
            .map(|_| rng.range_f64(0.0, 2.0 * std::f64::consts::PI) as f32)
            .collect();
        RksTail {
            w_feat,
            b_feat,
            w: vec![0.0; r],
            d,
            r,
            lam: opts.lam,
            loss: opts.loss,
            lr: opts.lr,
            steps: 0,
            g: Vec::new(),
        }
    }

    /// Current tail score for one point.
    pub fn score(&self, backend: &mut dyn Backend, x: &[f32]) -> Result<f32> {
        let mut f = Vec::new();
        backend.rks_predict(
            Rows::dense(x, 1, self.d),
            &self.w_feat,
            &self.b_feat,
            &self.w,
            self.r,
            &mut f,
        )?;
        Ok(f.first().copied().unwrap_or(0.0))
    }

    /// One SGD step on a pending chunk.
    pub fn step_chunk(
        &mut self,
        backend: &mut dyn Backend,
        xi: &[f32],
        yi: &[f32],
        seen: u64,
    ) -> Result<()> {
        let i = yi.len();
        if i == 0 {
            return Ok(());
        }
        self.steps += 1;
        let frac = (i as f32) / (seen.max(1) as f32);
        backend.rks_step(
            &RksStepInput {
                xi: Rows::dense(xi, i, self.d),
                yi,
                w_feat: &self.w_feat,
                b_feat: &self.b_feat,
                w: &self.w,
                r: self.r,
                lam: self.lam,
                frac,
                loss: self.loss,
            },
            &mut self.g,
        )?;
        let eta = self.lr.at(self.steps);
        for (wv, gv) in self.w.iter_mut().zip(&self.g) {
            *wv -= eta * gv;
        }
        Ok(())
    }

    /// Freeze the tail as a standalone RKS model.
    pub fn to_model(&self) -> RksModel {
        RksModel {
            w_feat: self.w_feat.clone(),
            b_feat: self.b_feat.clone(),
            w: self.w.clone(),
            d: self.d,
            r: self.r,
        }
    }
}

/// The streaming learner: budgeted head (+ optional RKS tail), fed one
/// item at a time, stepping both parts jointly on every full chunk.
/// With `tail_features == 0` this *is* budget-only streaming DSEKL with
/// magnitude eviction — the baseline the hybrid is gated against.
#[derive(Debug)]
pub struct HybridDsekl {
    head: BudgetedDsekl,
    tail: Option<RksTail>,
    d: usize,
    chunk: usize,
    pend_x: Vec<f32>,
    pend_y: Vec<f32>,
    seen: u64,
}

impl HybridDsekl {
    /// New learner for `d`-dimensional items. Consumes rng only for the
    /// tail's feature draw (none when `tail_features == 0`), so the
    /// whole stream run is deterministic in `(opts, seed)`.
    pub fn new<R: Rng>(opts: &StreamOpts, d: usize, rng: &mut R) -> Self {
        let kernel = opts.kernel.unwrap_or(Kernel::Rbf { gamma: opts.gamma });
        let head = BudgetedDsekl::new(
            kernel,
            d,
            opts.budget,
            opts.evict_every,
            opts.lam,
            opts.loss,
            opts.lr,
        );
        let tail = if opts.tail_features > 0 {
            Some(RksTail::new(d, opts.tail_features, opts.gamma, opts, rng))
        } else {
            None
        };
        HybridDsekl {
            head,
            tail,
            d,
            chunk: opts.chunk.max(1),
            pend_x: Vec::new(),
            pend_y: Vec::new(),
            seen: 0,
        }
    }

    /// Combined decision score: head + tail.
    pub fn score(&self, backend: &mut dyn Backend, x: &[f32]) -> Result<f32> {
        let mut s = self.head.score(backend, x)?;
        if let Some(tail) = &self.tail {
            s += tail.score(backend, x)?;
        }
        Ok(s)
    }

    /// Consume one labelled item: score it (prequential, pre-update),
    /// admit it into the head, and step both parts once a chunk is
    /// pending. Returns the pre-update combined score.
    pub fn observe(&mut self, backend: &mut dyn Backend, x: &[f32], y: f32) -> Result<f32> {
        debug_assert_eq!(x.len(), self.d);
        let score = self.score(backend, x)?;
        self.seen += 1;
        self.head.admit(x);
        self.pend_x.extend_from_slice(x);
        self.pend_y.push(y);
        if self.pend_y.len() >= self.chunk {
            self.step(backend)?;
        }
        Ok(score)
    }

    /// Step both parts on the pending chunk (public so stream drivers
    /// can flush the last partial chunk).
    pub fn step(&mut self, backend: &mut dyn Backend) -> Result<()> {
        if self.pend_y.is_empty() {
            return Ok(());
        }
        self.head
            .step_chunk(backend, &self.pend_x, &self.pend_y, self.seen)?;
        if let Some(tail) = &mut self.tail {
            tail.step_chunk(backend, &self.pend_x, &self.pend_y, self.seen)?;
        }
        self.pend_x.clear();
        self.pend_y.clear();
        Ok(())
    }

    /// Stream items consumed.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Head gradient steps taken.
    pub fn steps(&self) -> u64 {
        self.head.steps()
    }

    /// Mean per-example head loss over every step so far.
    pub fn mean_loss(&self) -> f64 {
        self.head.mean_loss()
    }

    /// Expansion points currently held by the head.
    pub fn expansion_len(&self) -> usize {
        self.head.expansion_len()
    }

    /// Whether an RKS tail is attached.
    pub fn has_tail(&self) -> bool {
        self.tail.is_some()
    }

    /// Freeze into (head model, optional tail model).
    pub fn freeze(&self) -> (crate::model::KernelModel, Option<RksModel>) {
        (self.head.to_model(), self.tail.as_ref().map(RksTail::to_model))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::rng::Pcg64;
    use crate::runtime::NativeBackend;

    #[test]
    fn hybrid_score_is_head_plus_tail() {
        let mut rng = Pcg64::seed_from(2);
        let ds = synth::blobs(64, 3, 4.0, &mut rng);
        let mut be = NativeBackend::new();
        let opts = StreamOpts { budget: 16, chunk: 8, tail_features: 32, ..Default::default() };
        let mut lrng = Pcg64::seed_from(7);
        let mut learner = HybridDsekl::new(&opts, 3, &mut lrng);
        for i in 0..ds.len() {
            learner.observe(&mut be, ds.row(i), ds.y[i]).unwrap();
        }
        learner.step(&mut be).unwrap();
        let probe = ds.row(0);
        let combined = learner.score(&mut be, probe).unwrap();
        let (head, tail) = learner.freeze();
        let hs = head.scores_rows(&mut be, Rows::dense(probe, 1, 3)).unwrap()[0];
        let ts = tail
            .as_ref()
            .unwrap()
            .scores_rows(&mut be, Rows::dense(probe, 1, 3))
            .unwrap()[0];
        assert!((combined - (hs + ts)).abs() < 1e-6);
    }

    #[test]
    fn tailless_hybrid_consumes_no_rng() {
        let opts = StreamOpts { tail_features: 0, ..Default::default() };
        let mut rng = Pcg64::seed_from(3);
        let before = rng.clone();
        let learner = HybridDsekl::new(&opts, 4, &mut rng);
        assert!(!learner.has_tail());
        // Construction must not advance the rng when there is no tail.
        let mut b = before;
        assert_eq!(rng.next_u64(), { b.next_u64() });
    }

    #[test]
    fn tail_matches_standalone_rks_model_scores() {
        let mut rng = Pcg64::seed_from(9);
        let opts = StreamOpts::default();
        let tail = RksTail::new(3, 16, opts.gamma, &opts, &mut rng);
        let mut be = NativeBackend::new();
        let model = tail.to_model();
        let x = [0.3f32, -1.0, 0.5];
        let live = tail.score(&mut be, &x).unwrap();
        let frozen = model.scores_rows(&mut be, Rows::dense(&x, 1, 3)).unwrap()[0];
        assert_eq!(live, frozen);
    }
}
