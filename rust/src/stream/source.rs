//! Deterministic seeded stream sources: stationary replays of the
//! synthetic generators, three concept-drift generators, and dataset
//! (libsvm file) replay.
//!
//! Every source owns its own [`Pcg64`], so a `(name, n, d, seed)`
//! quadruple pins the entire stream bit-for-bit — drift scenarios are
//! reproducible test fixtures, not anecdotes. Items are produced one at
//! a time into a caller-owned row buffer, so an unbounded stream never
//! materialises a dataset.

use crate::data::synth;
use crate::data::{Dataset, Rows};
use crate::rng::{Pcg64, Rng};

/// A bounded, seeded stream of labelled examples.
///
/// `next_into` writes the next feature row into `row` (whose length
/// must equal `dim()`) and returns its ±1 label, or `None` once `len()`
/// items have been emitted. Sources are deterministic: two instances
/// built with the same parameters emit identical streams.
pub trait StreamSource {
    /// Feature dimensionality of every item.
    fn dim(&self) -> usize;
    /// Total number of items this source will emit.
    fn len(&self) -> usize;
    /// Whether the source emits no items at all.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Produce the next item, or `None` at end of stream.
    fn next_into(&mut self, row: &mut [f32]) -> Option<f32>;
}

/// Dedicated rng stream id for stream sources, so a stream seeded with
/// `s` never collides with a solver seeded with the same `s`.
const SOURCE_STREAM: u64 = 0x57EA;

/// Stationary two-blob replay ([`synth::blob_item`] per item).
#[derive(Debug)]
pub struct StationaryBlobs {
    rng: Pcg64,
    d: usize,
    separation: f64,
    n: usize,
    emitted: usize,
}

impl StationaryBlobs {
    /// Blob stream of `n` items in `d` dims with the given separation.
    pub fn new(n: usize, d: usize, separation: f64, seed: u64) -> Self {
        StationaryBlobs {
            rng: Pcg64::with_stream(seed, SOURCE_STREAM),
            d,
            separation,
            n,
            emitted: 0,
        }
    }
}

impl StreamSource for StationaryBlobs {
    fn dim(&self) -> usize {
        self.d
    }
    fn len(&self) -> usize {
        self.n
    }
    fn next_into(&mut self, row: &mut [f32]) -> Option<f32> {
        if self.emitted >= self.n {
            return None;
        }
        self.emitted += 1;
        Some(synth::blob_item(&mut self.rng, row, self.separation))
    }
}

/// Stationary covtype replay ([`synth::covtype_item`] per item,
/// d = [`synth::COVTYPE_DIM`]).
#[derive(Debug)]
pub struct CovtypeReplay {
    rng: Pcg64,
    n: usize,
    emitted: usize,
}

impl CovtypeReplay {
    /// Covtype stream of `n` items.
    pub fn new(n: usize, seed: u64) -> Self {
        CovtypeReplay { rng: Pcg64::with_stream(seed, SOURCE_STREAM), n, emitted: 0 }
    }
}

impl StreamSource for CovtypeReplay {
    fn dim(&self) -> usize {
        synth::COVTYPE_DIM
    }
    fn len(&self) -> usize {
        self.n
    }
    fn next_into(&mut self, row: &mut [f32]) -> Option<f32> {
        if self.emitted >= self.n {
            return None;
        }
        self.emitted += 1;
        Some(synth::covtype_item(&mut self.rng, row))
    }
}

/// Abrupt concept drift: blob geometry with the label map inverted
/// after `switch_at` items — the classic label-switch scenario. A model
/// that cannot forget its pre-switch expansion pays for every stale
/// coefficient after the switch.
#[derive(Debug)]
pub struct AbruptLabelSwitch {
    rng: Pcg64,
    d: usize,
    separation: f64,
    n: usize,
    switch_at: usize,
    emitted: usize,
}

impl AbruptLabelSwitch {
    /// Blob stream whose labels flip sign from item `switch_at` on.
    pub fn new(n: usize, d: usize, separation: f64, switch_at: usize, seed: u64) -> Self {
        AbruptLabelSwitch {
            rng: Pcg64::with_stream(seed, SOURCE_STREAM),
            d,
            separation,
            n,
            switch_at,
            emitted: 0,
        }
    }
}

impl StreamSource for AbruptLabelSwitch {
    fn dim(&self) -> usize {
        self.d
    }
    fn len(&self) -> usize {
        self.n
    }
    fn next_into(&mut self, row: &mut [f32]) -> Option<f32> {
        if self.emitted >= self.n {
            return None;
        }
        let label = synth::blob_item(&mut self.rng, row, self.separation);
        let flipped = self.emitted >= self.switch_at;
        self.emitted += 1;
        Some(if flipped { -label } else { label })
    }
}

/// Gradual concept drift: unit-gaussian features with the true boundary
/// `sign(w_t . x)` rotating in the first two dimensions by `rate`
/// radians per item. Bayes error is zero at every instant, so all
/// prequential error is tracking lag — the cleanest probe of
/// plasticity under a frozen-vs-adaptive budget.
#[derive(Debug)]
pub struct GradualRotation {
    rng: Pcg64,
    d: usize,
    n: usize,
    rate: f64,
    theta: f64,
    emitted: usize,
}

impl GradualRotation {
    /// Rotating-boundary stream of `n` items in `d >= 2` dims.
    pub fn new(n: usize, d: usize, rate: f64, seed: u64) -> Self {
        GradualRotation {
            rng: Pcg64::with_stream(seed, SOURCE_STREAM),
            d: d.max(2),
            n,
            rate,
            theta: 0.0,
            emitted: 0,
        }
    }
}

impl StreamSource for GradualRotation {
    fn dim(&self) -> usize {
        self.d
    }
    fn len(&self) -> usize {
        self.n
    }
    fn next_into(&mut self, row: &mut [f32]) -> Option<f32> {
        if self.emitted >= self.n {
            return None;
        }
        self.emitted += 1;
        for v in row.iter_mut() {
            *v = self.rng.normal() as f32;
        }
        let (x0, x1) = (
            row.first().copied().unwrap_or(0.0) as f64,
            row.get(1).copied().unwrap_or(0.0) as f64,
        );
        let margin = self.theta.cos() * x0 + self.theta.sin() * x1;
        self.theta += self.rate;
        Some(if margin >= 0.0 { 1.0 } else { -1.0 })
    }
}

/// Covariate shift: stationary blob concept, but the input distribution
/// slides along the first axis by `rate` per item. `P(y | x - shift)`
/// never changes; an RBF expansion anchored at stale inputs still goes
/// blind as the data walks out from under its support points.
#[derive(Debug)]
pub struct CovariateShift {
    rng: Pcg64,
    d: usize,
    separation: f64,
    n: usize,
    rate: f64,
    emitted: usize,
}

impl CovariateShift {
    /// Blob stream whose inputs drift along dim 0 at `rate` per item.
    pub fn new(n: usize, d: usize, separation: f64, rate: f64, seed: u64) -> Self {
        CovariateShift {
            rng: Pcg64::with_stream(seed, SOURCE_STREAM),
            d,
            separation,
            n,
            rate,
            emitted: 0,
        }
    }
}

impl StreamSource for CovariateShift {
    fn dim(&self) -> usize {
        self.d
    }
    fn len(&self) -> usize {
        self.n
    }
    fn next_into(&mut self, row: &mut [f32]) -> Option<f32> {
        if self.emitted >= self.n {
            return None;
        }
        let label = synth::blob_item(&mut self.rng, row, self.separation);
        let shift = (self.rate * self.emitted as f64) as f32;
        if let Some(v) = row.first_mut() {
            *v += shift;
        }
        self.emitted += 1;
        Some(label)
    }
}

/// Replay an in-memory dataset in storage order — the libsvm file
/// replay source (`dsekl stream --source libsvm:PATH` loads the file,
/// then streams it through here), also what `Fit::stream()` uses to
/// present a batch `TrainSet` as a stream.
#[derive(Debug)]
pub struct DatasetReplay {
    ds: Dataset,
    pos: usize,
}

impl DatasetReplay {
    /// Replay `ds` front to back, once.
    pub fn new(ds: Dataset) -> Self {
        DatasetReplay { ds, pos: 0 }
    }
}

impl StreamSource for DatasetReplay {
    fn dim(&self) -> usize {
        self.ds.d
    }
    fn len(&self) -> usize {
        self.ds.len()
    }
    fn next_into(&mut self, row: &mut [f32]) -> Option<f32> {
        if self.pos >= self.ds.len() {
            return None;
        }
        let src = self.ds.row(self.pos);
        row.copy_from_slice(src);
        let label = self.ds.y.get(self.pos).copied()?;
        self.pos += 1;
        Some(label)
    }
}

/// Replay borrowed rows (dense or CSR) in storage order — the zero-copy
/// variant [`crate::stream::StreamSolver::train_rows`] wraps around an
/// estimator `TrainSet`. CSR rows are scattered into the caller's dense
/// row buffer.
#[derive(Debug)]
pub struct RowsReplay<'a> {
    x: Rows<'a>,
    y: &'a [f32],
    pos: usize,
}

impl<'a> RowsReplay<'a> {
    /// Replay `x`/`y` front to back, once. `y.len()` must equal the
    /// number of rows (the caller validates).
    pub fn new(x: Rows<'a>, y: &'a [f32]) -> Self {
        RowsReplay { x, y, pos: 0 }
    }
}

impl StreamSource for RowsReplay<'_> {
    fn dim(&self) -> usize {
        self.x.dim()
    }
    fn len(&self) -> usize {
        self.y.len().min(self.x.len())
    }
    fn next_into(&mut self, row: &mut [f32]) -> Option<f32> {
        if self.pos >= StreamSource::len(self) {
            return None;
        }
        match self.x {
            Rows::Dense { x, d, .. } => {
                let start = self.pos * d;
                let src = x.get(start..start + d)?;
                row.copy_from_slice(src);
            }
            Rows::Csr(view) => {
                row.fill(0.0);
                let (idx, vals) = view.row(self.pos);
                for (&j, &v) in idx.iter().zip(vals) {
                    if let Some(slot) = row.get_mut(j as usize) {
                        *slot = v;
                    }
                }
            }
        }
        let label = self.y.get(self.pos).copied()?;
        self.pos += 1;
        Some(label)
    }
}

/// Names accepted by [`by_name`], in presentation order.
pub const SOURCE_NAMES: [&str; 5] = ["blobs", "covtype", "abrupt", "rotate", "covshift"];

/// Build a synthetic source by name: `blobs` / `covtype` (stationary),
/// `abrupt` (label switch at n/2), `rotate` (half-turn boundary
/// rotation over the stream), `covshift` (inputs slide 4 units along
/// dim 0 over the stream). Returns `None` for unknown names; `d` is
/// ignored by `covtype` (always 54).
pub fn by_name(name: &str, n: usize, d: usize, seed: u64) -> Option<Box<dyn StreamSource>> {
    let half_turn = std::f64::consts::PI / (n.max(1) as f64);
    match name {
        "blobs" => Some(Box::new(StationaryBlobs::new(n, d, 4.0, seed))),
        "covtype" => Some(Box::new(CovtypeReplay::new(n, seed))),
        "abrupt" => Some(Box::new(AbruptLabelSwitch::new(n, d, 4.0, n / 2, seed))),
        "rotate" => Some(Box::new(GradualRotation::new(n, d, half_turn, seed))),
        "covshift" => Some(Box::new(CovariateShift::new(n, d, 4.0, 4.0 / n.max(1) as f64, seed))),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(src: &mut dyn StreamSource) -> (Vec<f32>, Vec<f32>) {
        let mut row = vec![0.0f32; src.dim()];
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        while let Some(y) = src.next_into(&mut row) {
            xs.extend_from_slice(&row);
            ys.push(y);
        }
        (xs, ys)
    }

    #[test]
    fn every_named_source_is_seed_deterministic() {
        for name in SOURCE_NAMES {
            let mut a = by_name(name, 64, 6, 7).expect(name);
            let mut b = by_name(name, 64, 6, 7).expect(name);
            let (xa, ya) = drain(a.as_mut());
            let (xb, yb) = drain(b.as_mut());
            assert_eq!(ya.len(), 64, "{name} length");
            assert_eq!(xa, xb, "{name} rows must be bitwise seed-deterministic");
            assert_eq!(ya, yb, "{name} labels must be bitwise seed-deterministic");
            let mut c = by_name(name, 64, 6, 8).expect(name);
            let (xc, _) = drain(c.as_mut());
            assert_ne!(xa, xc, "{name} must actually depend on the seed");
        }
    }

    #[test]
    fn abrupt_switch_flips_exactly_the_tail_labels() {
        let mut plain = StationaryBlobs::new(20, 3, 4.0, 11);
        let mut switched = AbruptLabelSwitch::new(20, 3, 4.0, 10, 11);
        let (xp, yp) = drain(&mut plain);
        let (xs, ys) = drain(&mut switched);
        assert_eq!(xp, xs, "features unchanged by a label switch");
        for (i, (a, b)) in yp.iter().zip(&ys).enumerate() {
            if i < 10 {
                assert_eq!(a, b, "item {i} before the switch");
            } else {
                assert_eq!(*a, -*b, "item {i} after the switch");
            }
        }
    }

    #[test]
    fn rotation_labels_track_the_moving_boundary() {
        let mut src = GradualRotation::new(50, 4, 0.1, 3);
        let mut row = vec![0.0f32; 4];
        let mut theta: f64 = 0.0;
        while let Some(y) = src.next_into(&mut row) {
            let margin = theta.cos() * row[0] as f64 + theta.sin() * row[1] as f64;
            let want = if margin >= 0.0 { 1.0 } else { -1.0 };
            assert_eq!(y, want);
            theta += 0.1;
        }
    }

    #[test]
    fn covariate_shift_slides_only_dim_zero() {
        let mut fixed = StationaryBlobs::new(30, 3, 4.0, 5);
        let mut drifting = CovariateShift::new(30, 3, 4.0, 0.5, 5);
        let (xf, yf) = drain(&mut fixed);
        let (xd, yd) = drain(&mut drifting);
        assert_eq!(yf, yd, "labels unchanged under covariate shift");
        for i in 0..30 {
            let shift = (0.5 * i as f64) as f32;
            assert_eq!(xd[i * 3], xf[i * 3] + shift, "dim 0 of item {i}");
            assert_eq!(&xd[i * 3 + 1..i * 3 + 3], &xf[i * 3 + 1..i * 3 + 3]);
        }
    }

    #[test]
    fn blob_stream_matches_the_batch_generator_item_for_item() {
        // Same underlying rng discipline => a stream replay and a batch
        // dataset built from the same seed agree exactly.
        let mut src = StationaryBlobs::new(25, 5, 4.0, 9);
        let (xs, ys) = drain(&mut src);
        let mut rng = Pcg64::with_stream(9, SOURCE_STREAM);
        let ds = synth::blobs(25, 5, 4.0, &mut rng);
        assert_eq!(xs, ds.x);
        assert_eq!(ys, ds.y);
    }

    #[test]
    fn dataset_and_rows_replay_agree() {
        let mut rng = Pcg64::seed_from(4);
        let ds = synth::blobs(12, 3, 4.0, &mut rng);
        let mut a = DatasetReplay::new(ds.clone());
        let (xa, ya) = drain(&mut a);
        let mut b = RowsReplay::new(ds.rows(), &ds.y);
        let (xb, yb) = drain(&mut b);
        assert_eq!(xa, xb);
        assert_eq!(ya, yb);
        assert_eq!(xa, ds.x);
    }
}
