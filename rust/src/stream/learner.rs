//! Drift-aware budgeted DSEKL: every arriving item is admitted into the
//! expansion, and on a cadence of gradient steps the expansion is
//! trimmed back to budget by **coefficient magnitude** — the principled
//! replacement for the online reservoir's eviction-by-chance. Trimming
//! goes through [`KernelModel::compact`] (and therefore
//! `ExpansionStore::filter`), so eviction is exactly the machinery that
//! already compacts frozen models, row order and layout preserved.

use crate::kernel::Kernel;
use crate::loss::Loss;
use crate::model::KernelModel;
use crate::runtime::{Backend, Rows, StepInput};
use crate::solver::LrSchedule;
use crate::Result;

/// Budgeted empirical-map head of the streaming learner.
///
/// Unlike [`crate::solver::online::OnlineDsekl`], admission is
/// unconditional and eviction is deterministic: the expansion grows
/// freely between cadences (bounded by `budget + evict_every * chunk`
/// rows) and every `evict_every` gradient steps it is trimmed to the
/// `budget` largest-|alpha| points. Because eviction runs *after* a
/// gradient step, every admitted point has received at least one
/// update before it can be judged by magnitude. No rng is consumed
/// anywhere, so the head is deterministic given the stream.
#[derive(Debug)]
pub struct BudgetedDsekl {
    kernel: Kernel,
    d: usize,
    budget: usize,
    evict_every: u64,
    lam: f32,
    loss: Loss,
    lr: LrSchedule,
    /// Expansion rows, row-major `[len, d]`, in admission order.
    x: Vec<f32>,
    /// Dual coefficients over the expansion.
    alpha: Vec<f32>,
    steps: u64,
    g: Vec<f32>,
    loss_acc: f64,
    loss_pts: u64,
}

impl BudgetedDsekl {
    /// New empty head for `d`-dimensional inputs.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        kernel: Kernel,
        d: usize,
        budget: usize,
        evict_every: u64,
        lam: f32,
        loss: Loss,
        lr: LrSchedule,
    ) -> Self {
        BudgetedDsekl {
            kernel,
            d,
            budget,
            evict_every,
            lam,
            loss,
            lr,
            x: Vec::new(),
            alpha: Vec::new(),
            steps: 0,
            g: Vec::new(),
            loss_acc: 0.0,
            loss_pts: 0,
        }
    }

    /// Expansion points currently held (may exceed `budget` between
    /// eviction cadences, never by more than `evict_every * chunk`).
    pub fn expansion_len(&self) -> usize {
        self.alpha.len()
    }

    /// Gradient steps taken.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Mean per-example loss over every step so far.
    pub fn mean_loss(&self) -> f64 {
        self.loss_acc / self.loss_pts.max(1) as f64
    }

    /// Kernel in use.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// Current decision score for one point (0 before any data).
    pub fn score(&self, backend: &mut dyn Backend, x: &[f32]) -> Result<f32> {
        if self.alpha.is_empty() {
            return Ok(0.0);
        }
        let mut f = Vec::new();
        backend.predict(
            self.kernel,
            Rows::dense(x, 1, self.d),
            Rows::dense(&self.x, self.alpha.len(), self.d),
            &self.alpha,
            &mut f,
        )?;
        Ok(f.first().copied().unwrap_or(0.0))
    }

    /// Admit one arriving item into the expansion (alpha 0). Admission
    /// is unconditional — drift means a new point may matter however
    /// full the budget is; magnitude eviction settles who leaves.
    pub fn admit(&mut self, x: &[f32]) {
        debug_assert_eq!(x.len(), self.d);
        self.x.extend_from_slice(x);
        self.alpha.push(0.0);
    }

    /// One gradient step over a pending chunk (`xi` row-major
    /// `[yi.len(), d]`); `seen` is the stream position for the
    /// regulariser fraction. Runs the eviction cadence afterwards.
    pub fn step_chunk(
        &mut self,
        backend: &mut dyn Backend,
        xi: &[f32],
        yi: &[f32],
        seen: u64,
    ) -> Result<()> {
        let i = yi.len();
        if i == 0 || self.alpha.is_empty() {
            return Ok(());
        }
        self.steps += 1;
        let j = self.alpha.len();
        let frac = (i as f32) / (seen.max(1) as f32);
        let out = backend.dsekl_step(
            self.kernel,
            &StepInput {
                xi: Rows::dense(xi, i, self.d),
                yi,
                xj: Rows::dense(&self.x, j, self.d),
                alpha: &self.alpha,
                lam: self.lam,
                frac,
                loss: self.loss,
            },
            &mut self.g,
        )?;
        self.loss_acc += out.loss as f64;
        self.loss_pts += i as u64;
        let eta = self.lr.at(self.steps);
        for (a, gv) in self.alpha.iter_mut().zip(&self.g) {
            *a -= eta * gv;
        }
        if self.evict_every > 0 && self.steps % self.evict_every == 0 {
            self.evict_to_budget();
        }
        Ok(())
    }

    /// The magnitude-eviction threshold that trims `alpha` to at most
    /// `budget` survivors under `compact`'s keep-|alpha|>tol rule, or
    /// `None` when the expansion is within budget or magnitude carries
    /// no signal (all |alpha| equal, e.g. an untouched all-zero head).
    pub fn eviction_threshold(alpha: &[f32], budget: usize) -> Option<f32> {
        if alpha.len() <= budget {
            return None;
        }
        let mut mags: Vec<f32> = alpha.iter().map(|a| a.abs()).collect();
        mags.sort_unstable_by(f32::total_cmp);
        let tol = mags.get(alpha.len() - budget - 1).copied()?;
        let max = mags.last().copied()?;
        if tol >= max {
            // All magnitudes tie at the cut: compact(tol) would evict
            // everything. Skip — recency (admission) will churn the
            // expansion instead.
            return None;
        }
        Some(tol)
    }

    /// Trim the expansion to at most `budget` points, keeping the
    /// largest-|alpha| ones, through the frozen-model `compact` path so
    /// eviction and offline compaction are the same operation.
    fn evict_to_budget(&mut self) {
        let tol = match Self::eviction_threshold(&self.alpha, self.budget) {
            Some(tol) => tol,
            None => return,
        };
        let model = KernelModel::new(
            self.kernel,
            std::mem::take(&mut self.x),
            std::mem::take(&mut self.alpha),
            self.d,
        );
        let kept = model.compact(tol);
        self.x = kept.x().map(|s| s.to_vec()).unwrap_or_default();
        self.alpha = kept.alpha;
    }

    /// Snapshot the current expansion as a standalone model.
    pub fn to_model(&self) -> KernelModel {
        KernelModel::new(self.kernel, self.x.clone(), self.alpha.clone(), self.d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::rng::{Pcg64, Rng};
    use crate::runtime::NativeBackend;

    fn head(budget: usize, evict_every: u64, d: usize) -> BudgetedDsekl {
        BudgetedDsekl::new(
            Kernel::Rbf { gamma: 1.0 },
            d,
            budget,
            evict_every,
            1e-4,
            Loss::Hinge,
            LrSchedule::Const { eta0: 0.2 },
        )
    }

    #[test]
    fn eviction_trims_to_budget_by_magnitude() {
        let mut rng = Pcg64::seed_from(3);
        let ds = synth::blobs(96, 3, 4.0, &mut rng);
        let mut be = NativeBackend::new();
        let mut h = head(16, 1, 3);
        for i in 0..ds.len() {
            h.admit(ds.row(i));
            if (i + 1) % 8 == 0 {
                let chunk = &ds.x[(i + 1 - 8) * 3..(i + 1) * 3];
                h.step_chunk(&mut be, chunk, &ds.y[i + 1 - 8..i + 1], (i + 1) as u64)
                    .unwrap();
                assert!(h.expansion_len() <= 16, "after eviction cadence");
            }
        }
        // Survivors are the largest-|alpha| points: nothing kept may be
        // smaller in magnitude than anything that could have stayed.
        let m = h.to_model();
        assert!(m.len() <= 16);
        assert!(m.alpha.iter().any(|a| a.abs() > 0.0));
    }

    #[test]
    fn eviction_threshold_keeps_at_most_budget() {
        let alpha = [0.5f32, -0.1, 0.9, 0.0, -0.7, 0.2];
        let tol = BudgetedDsekl::eviction_threshold(&alpha, 3).unwrap();
        let kept = alpha.iter().filter(|a| a.abs() > tol).count();
        assert_eq!(kept, 3);
        assert_eq!(tol, 0.2);
        // Within budget: no eviction.
        assert_eq!(BudgetedDsekl::eviction_threshold(&alpha, 6), None);
        // Degenerate all-equal magnitudes: skip rather than wipe.
        assert_eq!(BudgetedDsekl::eviction_threshold(&[0.0; 8], 4), None);
        assert_eq!(BudgetedDsekl::eviction_threshold(&[0.3; 8], 4), None);
    }

    #[test]
    fn eviction_is_the_compact_filter_operation() {
        // The in-stream trim and an offline compact of the frozen model
        // at the same threshold are the same operation.
        let mut rng = Pcg64::seed_from(5);
        let mut h = head(8, u64::MAX, 2); // cadence never fires on its own
        let mut be = NativeBackend::new();
        let ds = synth::blobs(32, 2, 4.0, &mut rng);
        for i in 0..ds.len() {
            h.admit(ds.row(i));
        }
        h.step_chunk(&mut be, &ds.x, &ds.y, 32).unwrap();
        let before = h.to_model();
        let tol = BudgetedDsekl::eviction_threshold(&h.alpha, 8).unwrap();
        let offline = before.compact(tol);
        h.evict_to_budget();
        let online = h.to_model();
        assert_eq!(online.alpha, offline.alpha);
        assert_eq!(online.x(), offline.x());
        assert!(online.len() <= 8);
    }

    #[test]
    fn head_consumes_no_rng() {
        // Determinism by construction: the head never touches an rng, so
        // two identical drives produce bitwise-identical state.
        let mut rng = Pcg64::seed_from(11);
        let ds = synth::blobs(40, 2, 4.0, &mut rng);
        let mut be = NativeBackend::new();
        let mut models = Vec::new();
        for _ in 0..2 {
            let mut h = head(8, 2, 2);
            for i in 0..ds.len() {
                h.admit(ds.row(i));
                if (i + 1) % 10 == 0 {
                    let chunk = &ds.x[(i + 1 - 10) * 2..(i + 1) * 2];
                    h.step_chunk(&mut be, chunk, &ds.y[i + 1 - 10..i + 1], (i + 1) as u64)
                        .unwrap();
                }
            }
            models.push(h.to_model());
        }
        assert_eq!(models[0].alpha, models[1].alpha);
        assert_eq!(models[0].x(), models[1].x());
        let _ = rng.next_u64();
    }
}
