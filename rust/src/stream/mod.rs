//! Streaming subsystem: drift-aware sources, principled eviction, and
//! the RKS-tail hybrid — the paper-conclusion extension ("use the
//! proposed approach in a streaming/online learning setting") grown
//! into a production workload axis.
//!
//! | Piece | Role |
//! |-------|------|
//! | [`source::StreamSource`] | seeded, bounded item streams: stationary blob/covtype replay, abrupt label switch, gradual boundary rotation, covariate shift, dataset (libsvm) replay |
//! | [`learner::BudgetedDsekl`] | budgeted empirical-map head; admission unconditional, eviction by coefficient magnitude on a step cadence via `compact`/`ExpansionStore::filter` |
//! | [`hybrid::HybridDsekl`] | head + primal RKS tail (Dai et al., PAPERS.md), trained jointly per item, scored as head + tail |
//! | [`harness::StreamSolver`] | prequential (test-then-train) driver with windowed error traces |
//!
//! The subsystem sits inside repo-lint's determinism zone: no clocks
//! (beyond the stats stopwatch in `metrics`), no hash-ordered
//! containers — a fixed `(opts, source, seed)` triple reproduces every
//! run bitwise, drift scenarios included. Frozen hybrids persist as
//! [`crate::model::HybridModel`] (`DSEKLhy1`) and load back through the
//! sniffing `Predictor::load_file` front door like every other family.

pub mod harness;
pub mod hybrid;
pub mod learner;
pub mod source;

pub use harness::{StreamOpts, StreamResult, StreamSolver};
pub use hybrid::{HybridDsekl, RksTail};
pub use learner::BudgetedDsekl;
pub use source::{
    by_name, AbruptLabelSwitch, CovariateShift, CovtypeReplay, DatasetReplay, GradualRotation,
    RowsReplay, StationaryBlobs, StreamSource, SOURCE_NAMES,
};
