//! PCG-XSL-RR 128/64 — O'Neill's PCG64 variant.
//!
//! 128-bit LCG state, 64-bit xorshift-low + random-rotate output. Chosen
//! over xorshift because the parallel coordinator derives per-worker
//! streams (`split`) and PCG's stream parameter gives statistically
//! independent sequences from the same seed.

use super::Rng;

const MUL: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;
const DEFAULT_INC: u128 = 0x5851_f42d_4c95_7f2d_1405_7b7e_f767_814f;

/// PCG64 generator. `Clone` is intentional: cloning freezes a stream for
/// replay (used by the deterministic-coordinator tests).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

impl Pcg64 {
    /// Seed with a 64-bit value on the default stream.
    pub fn seed_from(seed: u64) -> Self {
        Self::with_stream(seed, 0)
    }

    /// Seed with an explicit stream id — distinct streams are independent
    /// generators even under the same seed (PCG construction).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        // Standard PCG init: advance once around inc, add seed, advance.
        let inc = (DEFAULT_INC ^ ((stream as u128) << 64 | stream as u128)) | 1;
        let mut g = Pcg64 { state: 0, inc };
        g.step();
        g.state = g.state.wrapping_add(seed as u128);
        g.step();
        g
    }

    /// Derive the n-th child stream — used to hand each coordinator
    /// worker its own generator.
    pub fn split(&mut self, n: u64) -> Pcg64 {
        let seed = super::Rng::next_u64(self);
        Pcg64::with_stream(seed, n.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
    }
}

impl Rng for Pcg64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.step();
        // XSL-RR output function.
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg64::with_stream(7, 0);
        let mut b = Pcg64::with_stream(7, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_children_differ() {
        let mut root = Pcg64::seed_from(5);
        let mut c0 = root.split(0);
        let mut c1 = root.split(1);
        let same = (0..64).filter(|_| c0.next_u64() == c1.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_is_deterministic() {
        let mut r1 = Pcg64::seed_from(9);
        let mut r2 = Pcg64::seed_from(9);
        let mut a = r1.split(3);
        let mut b = r2.split(3);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn no_trivial_fixed_point() {
        let mut g = Pcg64::seed_from(0);
        let first: Vec<u64> = (0..8).map(|_| g.next_u64()).collect();
        assert!(first.iter().any(|&x| x != first[0]));
    }
}
