//! Index samplers for the doubly stochastic loops.
//!
//! Algorithm 1 draws `I, J ~ unif(1, N)` each iteration; Algorithm 2
//! partitions epochs into disjoint batches via sampling *without*
//! replacement (the paper: "We used sampling without replacement to
//! generate the sample batches for the different workers").

use super::Rng;

/// Draw `k` indices from `[0, n)` i.i.d. uniform (duplicates allowed).
pub fn sample_with_replacement<R: Rng>(rng: &mut R, n: usize, k: usize) -> Vec<usize> {
    (0..k).map(|_| rng.below(n)).collect()
}

/// Draw `k` distinct indices from `[0, n)` uniformly.
///
/// Uses Floyd's algorithm for `k << n` (O(k) expected time, no O(n)
/// scratch) and a partial Fisher-Yates otherwise.
pub fn sample_without_replacement<R: Rng>(rng: &mut R, n: usize, k: usize) -> Vec<usize> {
    assert!(k <= n, "cannot draw {k} distinct from {n}");
    if k * 4 <= n {
        // Floyd: for j in n-k..n, pick t in [0, j]; insert t or j. The
        // membership structure is a sorted Vec (k is small here), which
        // keeps this file free of hash-order nondeterminism; the
        // accept/reject decisions are identical to the HashSet version,
        // so fixed-seed draws are unchanged.
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = rng.below(j + 1);
            let pick = match chosen.binary_search(&t) {
                Err(at) => {
                    chosen.insert(at, t);
                    t
                }
                Ok(_) => j,
            };
            if pick != t {
                if let Err(at) = chosen.binary_search(&pick) {
                    chosen.insert(at, pick);
                }
            }
            out.push(pick);
        }
        out
    } else {
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + rng.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// Reusable epoch shuffler: hands out disjoint batches covering `[0, n)`
/// in random order, reshuffling between epochs. This is the sampling
/// discipline of Algorithm 2's per-worker batches.
#[derive(Debug)]
pub struct Shuffler {
    perm: Vec<usize>,
    cursor: usize,
}

impl Shuffler {
    /// New shuffler over `[0, n)`; first epoch order is drawn from `rng`.
    pub fn new<R: Rng>(n: usize, rng: &mut R) -> Self {
        let mut s = Shuffler {
            perm: (0..n).collect(),
            cursor: 0,
        };
        s.reshuffle(rng);
        s
    }

    /// Number of indices per epoch.
    pub fn len(&self) -> usize {
        self.perm.len()
    }

    /// True if the permutation is empty.
    pub fn is_empty(&self) -> bool {
        self.perm.is_empty()
    }

    /// Remaining indices in the current epoch.
    pub fn remaining(&self) -> usize {
        self.perm.len() - self.cursor
    }

    /// Fisher-Yates reshuffle and reset the cursor (start a new epoch).
    pub fn reshuffle<R: Rng>(&mut self, rng: &mut R) {
        let n = self.perm.len();
        for i in (1..n).rev() {
            let j = rng.below(i + 1);
            self.perm.swap(i, j);
        }
        self.cursor = 0;
    }

    /// Next batch of up to `k` disjoint indices; returns `None` when the
    /// epoch is exhausted (caller reshuffles to start the next epoch).
    pub fn next_batch(&mut self, k: usize) -> Option<&[usize]> {
        if self.cursor >= self.perm.len() {
            return None;
        }
        let end = (self.cursor + k).min(self.perm.len());
        let batch = &self.perm[self.cursor..end];
        self.cursor = end;
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn with_replacement_length_and_range() {
        let mut r = Pcg64::seed_from(1);
        let s = sample_with_replacement(&mut r, 10, 100);
        assert_eq!(s.len(), 100);
        assert!(s.iter().all(|&i| i < 10));
    }

    #[test]
    fn without_replacement_distinct() {
        let mut r = Pcg64::seed_from(2);
        for &(n, k) in &[(100usize, 10usize), (100, 80), (50, 50), (7, 1)] {
            let s = sample_without_replacement(&mut r, n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates for n={n} k={k}");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn without_replacement_is_uniform() {
        // Each index should be chosen with probability k/n.
        let mut r = Pcg64::seed_from(3);
        let (n, k, trials) = (20usize, 5usize, 20_000usize);
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            for i in sample_without_replacement(&mut r, n, k) {
                counts[i] += 1;
            }
        }
        let expected = trials as f64 * k as f64 / n as f64;
        for (i, &c) in counts.iter().enumerate() {
            let dev = (c as f64 - expected).abs() / expected;
            assert!(dev < 0.1, "index {i}: count {c} vs expected {expected}");
        }
    }

    #[test]
    fn shuffler_covers_everything_once_per_epoch() {
        let mut r = Pcg64::seed_from(4);
        let mut s = Shuffler::new(103, &mut r);
        let mut seen = vec![0usize; 103];
        while let Some(batch) = s.next_batch(10) {
            for &i in batch {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn shuffler_epochs_differ() {
        let mut r = Pcg64::seed_from(5);
        let mut s = Shuffler::new(64, &mut r);
        let first: Vec<usize> = s.next_batch(64).unwrap().to_vec();
        s.reshuffle(&mut r);
        let second: Vec<usize> = s.next_batch(64).unwrap().to_vec();
        assert_ne!(first, second);
        let mut f = first.clone();
        let mut g = second.clone();
        f.sort_unstable();
        g.sort_unstable();
        assert_eq!(f, g, "same index set, different order");
    }

    #[test]
    fn shuffler_batch_sizes() {
        let mut r = Pcg64::seed_from(6);
        let mut s = Shuffler::new(25, &mut r);
        assert_eq!(s.next_batch(10).unwrap().len(), 10);
        assert_eq!(s.next_batch(10).unwrap().len(), 10);
        assert_eq!(s.next_batch(10).unwrap().len(), 5);
        assert!(s.next_batch(10).is_none());
        assert_eq!(s.remaining(), 0);
    }
}
