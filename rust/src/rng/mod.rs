//! Deterministic RNG substrate.
//!
//! The paper's algorithm is *doubly stochastic*: every iteration draws an
//! index sample `I` for the gradient and an independent sample `J` for
//! the empirical kernel map. Everything downstream (experiments, tests,
//! the parallel coordinator) must be reproducible under a fixed seed, so
//! we implement our own PCG-64 generator instead of depending on platform
//! entropy, plus the samplers Algorithm 1/2 need: uniform ints, draws
//! with and without replacement, Fisher-Yates shuffles, and Box-Muller
//! gaussians for the synthetic data generators and RFF frequencies.

mod pcg;
mod sampler;

pub use pcg::Pcg64;
pub use sampler::{sample_with_replacement, sample_without_replacement, Shuffler};

/// Trait for the operations solvers need from a generator, so tests can
/// substitute counting/fixed generators when asserting routing behaviour.
pub trait Rng {
    /// Next raw 64 random bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform in `[0, 1)` with 53-bit resolution.
    fn next_f64(&mut self) -> f64 {
        // 53 high bits / 2^53.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift rejection.
    fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                return (m >> 64) as usize;
            }
            // Rejection zone: only reached with probability < n / 2^64.
            let t = n.wrapping_neg() % n;
            if lo >= t {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller (one value per call, no caching so
    /// the stream stays splittable/deterministic across refactors).
    fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with explicit mean / stddev.
    fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Random sign label in {-1.0, +1.0}.
    fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Bernoulli with probability `p`.
    fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seed_from(1);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg64::seed_from(2);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let x = r.below(7);
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn below_is_approximately_uniform() {
        let mut r = Pcg64::seed_from(3);
        let n = 10usize;
        let trials = 100_000;
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            counts[r.below(n)] += 1;
        }
        let expected = trials as f64 / n as f64;
        // chi-square with 9 dof, 99.9% quantile ~ 27.9
        let chi2: f64 = counts
            .iter()
            .map(|&c| {
                let d = c as f64 - expected;
                d * d / expected
            })
            .sum();
        assert!(chi2 < 27.9, "chi2 = {chi2}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seed_from(4);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean = {mean}");
        assert!((var - 1.0).abs() < 0.03, "var = {var}");
    }

    #[test]
    fn deterministic_under_seed() {
        let mut a = Pcg64::seed_from(42);
        let mut b = Pcg64::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::seed_from(1);
        let mut b = Pcg64::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
