//! Ablations of the design choices DESIGN.md calls out:
//!
//! * **A1 — AdaGrad dampening** (Algorithm 2 line 11/14) vs a plain
//!   `1/epoch` scatter update in the parallel solver.
//! * **A2 — sampling discipline**: without-replacement epoch partitions
//!   (Algorithm 2) vs i.i.d. with-replacement draws (Algorithm 1 style)
//!   in the serial solver.
//! * **A3 — learning-rate schedule**: the paper's `1/t` vs `1/sqrt(t)`
//!   vs constant.
//! * **A4 — regulariser scaling**: the `|I|/N` stochastic-gradient
//!   correction vs unscaled `lambda`.
//!
//! Each ablation returns (variant label, final test error) pairs on a
//! fixed workload so `cargo bench --bench ablations` prints a table.

use std::sync::Arc;

use crate::data::synth;
use crate::estimator::{Fit, FitBackend, TrainSet};
use crate::loss::Loss;
use crate::rng::{sample_with_replacement, sample_without_replacement, Pcg64, Rng};
use crate::runtime::{Backend, NativeBackend, Rows, StepInput};
use crate::solver::LrSchedule;
use crate::Result;

/// A1: parallel solver with vs without AdaGrad, same budget. AdaGrad is
/// baked into the coordinator, so the "without" arm emulates the plain
/// update by pre-flattening: we compare against the serial solver run
/// with the same per-epoch sample budget and plain 1/epoch steps. Both
/// arms run through the unified [`Fit`] builder.
pub fn adagrad_ablation(seed: u64) -> Result<Vec<(&'static str, f64)>> {
    let mut rng = Pcg64::seed_from(seed);
    let train = Arc::new(synth::covtype_like(4_000, &mut rng));
    let test = synth::covtype_like(1_000, &mut rng);
    let mut be = FitBackend::native();
    let test_set = TrainSet::from(&test);

    let mut par_rng = Pcg64::seed_from(seed);
    let with = Fit::dsekl()
        .parallel(2)
        .gamma(1.0)
        .lam(1.0 / 4000.0)
        .sizes(256, 256)
        .epochs(4)
        .fit(&mut be, TrainSet::from(&train), &mut par_rng)?;
    let with_err = with.predictor.error(be.leader()?, &test_set)?;

    // Plain-SGD arm: serial solver, same number of gradient samples.
    let plain = Fit::dsekl()
        .gamma(1.0)
        .lam(1.0 / 4000.0)
        .sizes(256, 256)
        .eta0(1.0)
        .iters(4 * 4000 / 256)
        .fit(&mut be, TrainSet::from(&train), &mut rng)?;
    let plain_err = plain.predictor.error(be.leader()?, &test_set)?;

    Ok(vec![
        ("adagrad (Alg. 2)", with_err),
        ("plain 1/t scatter", plain_err),
    ])
}

/// A2: with- vs without-replacement index sampling in the serial loop,
/// identical budgets. Runs the raw step loop directly so the *only*
/// difference is the sampler.
pub fn sampling_ablation(seed: u64) -> Result<Vec<(&'static str, f64)>> {
    let mut rng = Pcg64::seed_from(seed);
    let train = synth::xor(200, 0.2, &mut rng);
    let test = synth::xor(200, 0.2, &mut rng);
    let mut out = Vec::new();
    for (label, with_replacement) in [("without replacement", false), ("with replacement", true)]
    {
        let mut be = NativeBackend::new();
        let mut loop_rng = Pcg64::with_stream(seed, with_replacement as u64);
        let n = train.len();
        let (i_size, j_size) = (32usize, 32usize);
        let mut alpha = vec![0.0f32; n];
        let (mut xi, mut yi, mut xj, mut aj, mut g) =
            (Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new());
        for t in 1..=400u64 {
            let ii = if with_replacement {
                sample_with_replacement(&mut loop_rng, n, i_size)
            } else {
                sample_without_replacement(&mut loop_rng, n, i_size)
            };
            let jj = if with_replacement {
                sample_with_replacement(&mut loop_rng, n, j_size)
            } else {
                sample_without_replacement(&mut loop_rng, n, j_size)
            };
            train.gather_into(&ii, &mut xi);
            train.gather_labels_into(&ii, &mut yi);
            train.gather_into(&jj, &mut xj);
            aj.clear();
            aj.extend(jj.iter().map(|&j| alpha[j]));
            be.dsekl_step(
                crate::kernel::Kernel::rbf(1.0),
                &StepInput {
                    xi: Rows::dense(&xi, i_size, train.d),
                    yi: &yi,
                    xj: Rows::dense(&xj, j_size, train.d),
                    alpha: &aj,
                    lam: 1e-4,
                    frac: i_size as f32 / n as f32,
                    loss: Loss::Hinge,
                },
                &mut g,
            )?;
            let eta = 1.0 / t as f32;
            for (&j, &gv) in jj.iter().zip(&g) {
                alpha[j] -= eta * gv;
            }
        }
        let model =
            crate::model::KernelModel::new(crate::kernel::Kernel::rbf(1.0), train.x.clone(), alpha, 2);
        out.push((label, model.error(&mut be, &test)?));
    }
    Ok(out)
}

/// A3: learning-rate schedules, serial solver, fixed budget.
pub fn schedule_ablation(seed: u64) -> Result<Vec<(&'static str, f64)>> {
    let mut rng = Pcg64::seed_from(seed);
    let train = synth::diabetes_like(500, &mut rng);
    let test = synth::diabetes_like(500, &mut rng);
    let mut out = Vec::new();
    for (label, lr) in [
        ("1/t (paper)", LrSchedule::InvT { eta0: 1.0 }),
        ("1/sqrt(t)", LrSchedule::InvSqrtT { eta0: 0.3 }),
        ("constant", LrSchedule::Const { eta0: 0.05 }),
    ] {
        let mut be = FitBackend::native();
        let mut r = Pcg64::with_stream(seed, 7);
        let res = Fit::dsekl()
            .gamma(0.1)
            .lam(1e-3)
            .sizes(64, 64)
            .lr(lr)
            .iters(500)
            .fit(&mut be, TrainSet::from(&train), &mut r)?;
        out.push((
            label,
            res.predictor.error(be.leader()?, &TrainSet::from(&test))?,
        ));
    }
    Ok(out)
}

/// A4: `|I|/N` regulariser scaling on vs off (frac forced to 1).
pub fn frac_ablation(seed: u64) -> Result<Vec<(&'static str, f64)>> {
    let mut rng = Pcg64::seed_from(seed);
    let train = synth::blobs(400, 6, 4.0, &mut rng);
    let test = synth::blobs(400, 6, 4.0, &mut rng);
    let mut out = Vec::new();
    for (label, frac) in [("scaled |I|/N (ours)", 32.0 / 400.0), ("unscaled", 1.0f32)] {
        let mut be = NativeBackend::new();
        let mut r = Pcg64::with_stream(seed, 9);
        let n = train.len();
        let mut alpha = vec![0.0f32; n];
        let (mut xi, mut yi, mut xj, mut aj, mut g) =
            (Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new());
        for t in 1..=400u64 {
            let ii = sample_without_replacement(&mut r, n, 32);
            let jj = sample_without_replacement(&mut r, n, 32);
            train.gather_into(&ii, &mut xi);
            train.gather_labels_into(&ii, &mut yi);
            train.gather_into(&jj, &mut xj);
            aj.clear();
            aj.extend(jj.iter().map(|&j| alpha[j]));
            be.dsekl_step(
                crate::kernel::Kernel::rbf(0.2),
                &StepInput {
                    xi: Rows::dense(&xi, 32, train.d),
                    yi: &yi,
                    xj: Rows::dense(&xj, 32, train.d),
                    alpha: &aj,
                    lam: 1e-2,
                    frac,
                    loss: Loss::Hinge,
                },
                &mut g,
            )?;
            let eta = 1.0 / t as f32;
            for (&j, &gv) in jj.iter().zip(&g) {
                alpha[j] -= eta * gv;
            }
        }
        let model = crate::model::KernelModel::new(
            crate::kernel::Kernel::rbf(0.2),
            train.x.clone(),
            alpha,
            train.d,
        );
        out.push((label, model.error(&mut be, &test)?));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_ablations_run_and_learn() {
        for rows in [
            adagrad_ablation(3).unwrap(),
            sampling_ablation(3).unwrap(),
            schedule_ablation(3).unwrap(),
            frac_ablation(3).unwrap(),
        ] {
            assert!(rows.len() >= 2);
            for (label, err) in &rows {
                assert!(
                    (0.0..=0.5).contains(err),
                    "{label}: error {err} out of range"
                );
            }
        }
    }

    #[test]
    fn sampling_variants_comparable() {
        // The paper's claim that the simple randomized scheme suffices:
        // neither sampler should be catastrophically worse on XOR.
        let rows = sampling_ablation(11).unwrap();
        let worst = rows.iter().map(|r| r.1).fold(0.0f64, f64::max);
        assert!(worst < 0.15, "sampling ablation degraded: {rows:?}");
    }
}
