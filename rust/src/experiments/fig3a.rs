//! Figure 3a: validation error vs data points processed on covtype with
//! the parallel shared-memory solver.
//!
//! Paper protocol (§4.2): covtype (581,012 x 54), I = J = 10,000,
//! lambda = 1/N, RBF scale 1.0, learning rate 1/epoch, stop when the
//! epoch weight-change norm < 1; 1,122 held-back validation samples,
//! 20,000 held-back evaluation samples. Headline numbers: validation
//! error 51% -> ~17% after one pass, 13.34% on the evaluation set at
//! convergence (54 epochs).
//!
//! The full-N run takes hours on this container's single core, so the
//! driver scales N (and I/J proportionally) by default and exposes the
//! paper-exact configuration under `Scale::Full`.

use std::sync::Arc;

use crate::data::synth;
use crate::estimator::{Fit, FitBackend, Fitted, TrainSet};
use crate::experiments::Scale;
use crate::rng::Pcg64;
use crate::runtime::BackendSpec;
use crate::Result;

/// Configuration of a Fig. 3a run.
#[derive(Debug, Clone)]
pub struct Fig3aCfg {
    /// Training points (paper: 559,890 after holdouts; we generate N
    /// directly).
    pub n: usize,
    /// Validation holdout (paper: 1,122).
    pub n_val: usize,
    /// Final-evaluation holdout (paper: 20,000).
    pub n_eval: usize,
    /// Batch sizes I = J (paper: 10,000).
    pub batch: usize,
    /// Worker threads.
    pub workers: usize,
    /// Epoch cap (paper converges at 54).
    pub max_epochs: u64,
    /// Seed.
    pub seed: u64,
}

impl Fig3aCfg {
    /// Scale-dependent defaults.
    pub fn at_scale(scale: Scale) -> Fig3aCfg {
        match scale {
            Scale::Quick => Fig3aCfg {
                n: 8_000,
                n_val: 500,
                n_eval: 1_000,
                batch: 512,
                workers: 4,
                max_epochs: 4,
                seed: 42,
            },
            Scale::Default => Fig3aCfg {
                n: 60_000,
                n_val: 1_122,
                n_eval: 5_000,
                batch: 2_000,
                workers: 4,
                max_epochs: 8,
                seed: 42,
            },
            Scale::Full => Fig3aCfg {
                n: 581_012,
                n_val: 1_122,
                n_eval: 20_000,
                batch: 10_000,
                workers: 4,
                max_epochs: 54,
                seed: 42,
            },
        }
    }
}

/// Outcome: the convergence trace plus the final evaluation error.
#[derive(Debug)]
pub struct Fig3aResult {
    /// The fitted run (trace/stats in `run.stats`, coordinator
    /// telemetry in `run.telemetry`).
    pub run: Fitted,
    /// Error on the held-out evaluation set at convergence (paper:
    /// 13.34%).
    pub eval_error: f64,
    /// Validation error after roughly one pass through the data
    /// (paper: ~17%).
    pub val_error_after_one_pass: Option<f64>,
}

/// Run the experiment (through the unified [`Fit`] builder — the
/// coordinator's seed derives from `cfg.seed`, so runs reproduce).
pub fn run(spec: &BackendSpec, cfg: &Fig3aCfg) -> Result<Fig3aResult> {
    let mut rng = Pcg64::with_stream(cfg.seed, 0xC0);
    let train = Arc::new(synth::covtype_like(cfg.n, &mut rng));
    let val = synth::covtype_like(cfg.n_val, &mut rng);
    let eval = synth::covtype_like(cfg.n_eval, &mut rng);

    let mut backend = FitBackend::new(spec.clone());
    let mut fit_rng = Pcg64::seed_from(cfg.seed);
    let run = Fit::dsekl()
        .parallel(cfg.workers)
        .gamma(1.0) // paper: "fix the RBF scale to 1.0"
        .lam(1.0 / cfg.n as f32)
        .sizes(cfg.batch, cfg.batch)
        .epochs(cfg.max_epochs)
        .tol(1.0) // paper's stopping criterion
        .eta0(1.0)
        .eval_every(1) // paper: per mini-batch validation curve
        .fit(
            &mut backend,
            TrainSet::from(&train).with_val(&val),
            &mut fit_rng,
        )?;

    // Validation error nearest to one full pass.
    let n64 = cfg.n as u64;
    let val_error_after_one_pass = run
        .stats
        .trace
        .points
        .iter()
        .filter(|p| p.points_processed >= n64)
        .find_map(|p| p.val_error);

    // Final evaluation on the big holdout.
    let eval_error = run
        .predictor
        .error(backend.leader()?, &TrainSet::from(&eval))?;

    Ok(Fig3aResult {
        run,
        eval_error,
        val_error_after_one_pass,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_scale_converges_below_baseline() {
        let cfg = Fig3aCfg {
            n: 3_000,
            n_val: 300,
            n_eval: 500,
            batch: 256,
            workers: 2,
            max_epochs: 3,
            seed: 9,
        };
        let res = run(&BackendSpec::Native, &cfg).unwrap();
        // Chance is ~0.49 (covtype positive rate); training must beat it.
        assert!(res.eval_error < 0.40, "eval error {}", res.eval_error);
        assert!(!res.run.stats.trace.points.is_empty());
        // Small-sample validation is noisy; the invariant is "stays well
        // below the ~0.49 positive-rate baseline", not monotonicity.
        let last_val = res.run.stats.trace.last_val_error().unwrap();
        assert!(last_val < 0.45, "validation error {last_val}");
    }
}
