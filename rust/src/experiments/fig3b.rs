//! Figure 3b: speedup of the parallel solver with increasing worker
//! count.
//!
//! The paper measures wall-clock per batch round on a 48-core machine
//! (24 physical + hyperthreading): linear speedup to ~20 cores (16x at
//! 20), then a plateau attributed to hyperthreading and python
//! serialisation. This container exposes **one** core, so the figure is
//! reproduced in two parts (DESIGN.md §4 "Substitutions"):
//!
//! 1. **Measured**: real multi-threaded runs at each K on this machine,
//!    reporting per-round wall time and the serial (aggregation)
//!    fraction from coordinator telemetry. The threading code path is
//!    fully exercised; on a 1-core host the wall-clock curve is flat by
//!    construction.
//! 2. **Modelled**: the telemetry-calibrated [`SpeedupModel`] evaluated
//!    at the paper's core counts, reproducing the shape of Fig. 3b
//!    (slope, knee position, plateau).

use std::sync::Arc;

use crate::data::synth;
use crate::estimator::{Fit, FitBackend, TrainSet};
use crate::metrics::SpeedupModel;
use crate::rng::Pcg64;
use crate::runtime::BackendSpec;
use crate::Result;

/// Per-K measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub workers: usize,
    /// Mean wall-clock seconds per round.
    pub secs_per_round: f64,
    /// Mean pure-compute seconds per batch (inside workers).
    pub compute_secs_per_batch: f64,
    /// Serial (aggregation) fraction of total work.
    pub serial_fraction: f64,
}

/// Fig. 3b experiment configuration.
#[derive(Debug, Clone)]
pub struct Fig3bCfg {
    /// Dataset size (per-batch work dominates; N just needs to cover
    /// K * batch per round).
    pub n: usize,
    /// Batch size I = J per worker.
    pub batch: usize,
    /// Worker counts to measure.
    pub worker_counts: Vec<usize>,
    /// Epochs per measurement (more = tighter timing).
    pub epochs: u64,
    pub seed: u64,
}

impl Default for Fig3bCfg {
    fn default() -> Self {
        Fig3bCfg {
            n: 8_192,
            batch: 512,
            worker_counts: vec![1, 2, 4, 8],
            epochs: 2,
            seed: 42,
        }
    }
}

/// Measure per-round time and serial fraction at each worker count.
pub fn measure(spec: &BackendSpec, cfg: &Fig3bCfg) -> Result<Vec<Measurement>> {
    let mut rng = Pcg64::with_stream(cfg.seed, 0xB3);
    let train = Arc::new(synth::covtype_like(cfg.n, &mut rng));
    let mut backend = FitBackend::new(spec.clone());
    let mut out = Vec::new();
    for &workers in &cfg.worker_counts {
        let mut fit_rng = Pcg64::seed_from(cfg.seed);
        let res = Fit::dsekl()
            .parallel(workers)
            .gamma(1.0)
            .lam(1.0 / cfg.n as f32)
            .sizes(cfg.batch, cfg.batch)
            .epochs(cfg.epochs)
            .fit(&mut backend, TrainSet::from(&train), &mut fit_rng)?;
        let t = res.telemetry.as_ref().expect("parallel run has telemetry");
        out.push(Measurement {
            workers,
            secs_per_round: res.stats.elapsed_s / t.rounds.max(1) as f64,
            compute_secs_per_batch: t.compute_ns as f64 / 1e9 / t.batches.max(1) as f64,
            serial_fraction: t.serial_fraction(),
        });
    }
    Ok(out)
}

/// Calibrate the analytic speedup model from a measurement set: the
/// parallel fraction comes from the measured aggregation share; the
/// HT knee/efficiency stay at the paper's testbed values (24 physical
/// cores), since those are hardware constants we cannot measure here.
pub fn calibrate(measures: &[Measurement]) -> SpeedupModel {
    let serial = measures
        .iter()
        .map(|m| m.serial_fraction)
        .sum::<f64>()
        / measures.len().max(1) as f64;
    SpeedupModel {
        // Clamp: the aggregation share measured at tiny test scales can
        // exceed what a 10k-batch covtype round would see.
        parallel_frac: (1.0 - serial).clamp(0.95, 0.9995),
        ..SpeedupModel::default()
    }
}

/// The paper's x-axis: 1..=48 cores in steps of 10 past 1 (we emit a
/// denser grid for a smoother curve).
pub fn paper_core_counts() -> Vec<usize> {
    vec![1, 5, 10, 15, 20, 24, 30, 40, 48]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurement_and_calibration() {
        let cfg = Fig3bCfg {
            n: 1_024,
            batch: 128,
            worker_counts: vec![1, 2],
            epochs: 1,
            seed: 3,
        };
        let ms = measure(&BackendSpec::Native, &cfg).unwrap();
        assert_eq!(ms.len(), 2);
        for m in &ms {
            assert!(m.secs_per_round > 0.0);
            assert!(m.compute_secs_per_batch > 0.0);
            assert!((0.0..1.0).contains(&m.serial_fraction));
        }
        let model = calibrate(&ms);
        // Shape invariants of the paper's curve.
        assert!(model.speedup(20) > 8.0);
        let s24 = model.speedup(24);
        let s48 = model.speedup(48);
        assert!(s48 >= s24 * 0.9 && s48 < s24 * 1.5, "plateau: {s24} -> {s48}");
    }
}
