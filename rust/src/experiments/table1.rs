//! Table 1: DSEKL vs batch kernel SVM test error on the seven
//! real-world analogue datasets (see DESIGN.md §4 "Substitutions" for
//! the generator-for-download substitution).
//!
//! Protocol (paper §4.1): sample `min(1000, N_dataset)` points, split
//! half train / half test, standardise on the train half, tune
//! per-dataset hyper-parameters on the training set (we use a small
//! fixed grid per dataset geometry), 10 repetitions, report mean ± std.

use crate::data::{synth, Scaler};
use crate::estimator::{Fit, FitBackend, TrainSet};
use crate::rng::Pcg64;
use crate::util::mean_std;
use crate::Result;

/// One Table 1 row.
#[derive(Debug, Clone)]
pub struct Row {
    pub dataset: &'static str,
    pub dsekl_mean: f64,
    pub dsekl_std: f64,
    pub batch_mean: f64,
    pub batch_std: f64,
}

/// Per-dataset hyper-parameters, selected by a coarse grid search on the
/// training split (run once via `dsekl gridsearch`; frozen here so the
/// table is reproducible without re-searching every run). The third
/// field is whether to standardise features (madelon keeps its native
/// common scale — see `synth::madelon_like`'s probe-energy note).
pub fn params_for(name: &str) -> (f32, f32, bool) {
    // (gamma, lam, standardise).
    match name {
        "mnist" => (0.01, 1e-5, true),
        "diabetes" => (0.1, 1e-3, true),
        "breast-cancer" => (0.05, 1e-4, true),
        "mushrooms" => (0.05, 1e-5, true),
        "sonar" => (0.01, 1e-1, true),
        "skin-nonskin" => (1.0, 1e-5, true),
        "madelon" => (1.0, 1e-1, false),
        _ => (0.1, 1e-4, true),
    }
}

/// Run one dataset row (both methods through the [`Fit`] builder).
pub fn run_row(
    backend: &mut FitBackend,
    name: &'static str,
    full_n: usize,
    gen: fn(usize, &mut Pcg64) -> crate::data::Dataset,
    reps: usize,
    iters: u64,
    seed: u64,
) -> Result<Row> {
    let (gamma, lam, standardise) = params_for(name);
    let mut dsekl_errs = Vec::with_capacity(reps);
    let mut batch_errs = Vec::with_capacity(reps);
    for rep in 0..reps {
        let mut rng = Pcg64::with_stream(seed, rep as u64);
        // Paper: sample min(1000, N) points, half train / half test.
        let pool_n = full_n.min(1000);
        let pool = gen(pool_n, &mut rng);
        let (mut train, mut test) = pool.split(0.5, &mut rng);
        if standardise {
            let scaler = Scaler::fit(&train);
            scaler.transform(&mut train);
            scaler.transform(&mut test);
        }
        let train_set = TrainSet::from(&train);
        let test_set = TrainSet::from(&test);

        let dsekl = Fit::dsekl()
            .gamma(gamma)
            .lam(lam)
            .sizes(64, 64)
            .eta0(1.0)
            .iters(iters)
            .fit(backend, train_set, &mut rng)?;
        dsekl_errs.push(dsekl.predictor.error(backend.leader()?, &test_set)?);

        let batch = Fit::batch()
            .gamma(gamma)
            .lam(lam)
            .iters(1000)
            .fit(backend, train_set, &mut rng)?;
        batch_errs.push(batch.predictor.error(backend.leader()?, &test_set)?);
    }
    let (dm, ds) = mean_std(&dsekl_errs);
    let (bm, bs) = mean_std(&batch_errs);
    Ok(Row {
        dataset: name,
        dsekl_mean: dm,
        dsekl_std: ds,
        batch_mean: bm,
        batch_std: bs,
    })
}

/// Run the full table.
pub fn run_table(
    backend: &mut FitBackend,
    reps: usize,
    iters: u64,
    seed: u64,
) -> Result<Vec<Row>> {
    synth::table1_registry()
        .into_iter()
        .map(|(name, full_n, gen)| run_row(backend, name, full_n, gen, reps, iters, seed))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::FitBackend;

    #[test]
    fn row_runs_and_is_sane() {
        let mut be = FitBackend::native();
        let row = run_row(
            &mut be,
            "breast-cancer",
            683,
            |n, r| synth::breast_cancer_like(n, r),
            2,
            250,
            7,
        )
        .unwrap();
        // Easy dataset: both methods should be far below chance.
        assert!(row.dsekl_mean < 0.25, "dsekl {}", row.dsekl_mean);
        assert!(row.batch_mean < 0.25, "batch {}", row.batch_mean);
    }

    #[test]
    fn dsekl_tracks_batch_on_easy_data() {
        // The table's claim: DSEKL is comparable to batch. On the
        // separable sets the gap must be small.
        let mut be = FitBackend::native();
        let row = run_row(
            &mut be,
            "mushrooms",
            8124,
            |n, r| synth::mushrooms_like(n, r),
            2,
            400,
            11,
        )
        .unwrap();
        assert!(
            (row.dsekl_mean - row.batch_mean).abs() < 0.15,
            "gap too large: dsekl {} batch {}",
            row.dsekl_mean,
            row.batch_mean
        );
    }
}
