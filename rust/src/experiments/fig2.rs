//! Figure 2: test error on the XOR problem for four methods while
//! sweeping the gradient sample size `I` (panels a/b) and the expansion
//! size `J` (panels c/d).
//!
//! Protocol (paper §4.1): N = 100 XOR points, hyper-parameters fixed at
//! the values the grid search selects for this problem (gamma = 1,
//! lambda = 1e-4, eta0 = 1), 10 repetitions, test set the same size as
//! the train set.

use crate::data::synth;
use crate::estimator::{Fit, FitBackend, TrainSet};
use crate::rng::Pcg64;
use crate::util::mean_std;
use crate::Result;

/// The four methods of Fig. 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// DSEKL (doubly stochastic empirical kernel map).
    Emp,
    /// Random kitchen sinks.
    Rks,
    /// One fixed random subset.
    EmpFix,
    /// Full batch kernel SVM (the dotted reference line).
    Batch,
}

impl Method {
    /// All methods in figure order.
    pub const ALL: [Method; 4] = [Method::Emp, Method::Rks, Method::EmpFix, Method::Batch];

    /// Figure label.
    pub fn label(&self) -> &'static str {
        match self {
            Method::Emp => "Emp",
            Method::Rks => "RKS",
            Method::EmpFix => "Emp_Fix",
            Method::Batch => "Batch",
        }
    }
}

/// One Fig. 2 cell configuration.
#[derive(Debug, Clone)]
pub struct CellCfg {
    /// Training-set size (paper: 100; test set matches).
    pub n: usize,
    /// Gradient sample size |I|.
    pub i_size: usize,
    /// Expansion size |J| (RKS feature count / Emp_Fix subset size).
    pub j_size: usize,
    /// SGD iteration budget.
    pub iters: u64,
    /// Repetitions.
    pub reps: usize,
    /// Base seed.
    pub seed: u64,
}

impl Default for CellCfg {
    fn default() -> Self {
        CellCfg {
            n: 100,
            i_size: 16,
            j_size: 16,
            iters: 400,
            reps: 10,
            seed: 42,
        }
    }
}

const GAMMA: f32 = 1.0;
const LAM: f32 = 1e-4;
const ETA0: f32 = 1.0;

/// Mean ± std test error of `method` on fresh XOR draws. All four
/// methods go through the unified [`Fit`] builder — the figure
/// compares approximations, and the estimator layer guarantees they
/// share one training surface.
pub fn run_cell(backend: &mut FitBackend, method: Method, cfg: &CellCfg) -> Result<(f64, f64)> {
    let mut errs = Vec::with_capacity(cfg.reps);
    for rep in 0..cfg.reps {
        let mut rng = Pcg64::with_stream(cfg.seed, rep as u64);
        let train = synth::xor(cfg.n, 0.2, &mut rng);
        let test = synth::xor(cfg.n, 0.2, &mut rng);
        let builder = match method {
            Method::Emp => Fit::dsekl()
                .sizes(cfg.i_size, cfg.j_size)
                .iters(cfg.iters),
            Method::Rks => Fit::rks()
                .features(cfg.j_size)
                .i_size(cfg.i_size)
                .iters(cfg.iters),
            Method::EmpFix => Fit::empfix()
                .subset(cfg.j_size)
                .sizes(cfg.i_size, cfg.j_size)
                .iters(cfg.iters),
            // The reference line runs to its own tight-tolerance budget.
            Method::Batch => Fit::batch().iters(1500),
        }
        .gamma(GAMMA)
        .lam(LAM)
        .eta0(ETA0);
        let fitted = builder.fit(backend, TrainSet::from(&train), &mut rng)?;
        errs.push(fitted.predictor.error(backend.leader()?, &TrainSet::from(&test))?);
    }
    Ok(mean_std(&errs))
}

/// A full panel: sweep one axis, all methods. Returns
/// `(axis_values, per-method (mean, std) series in Method::ALL order)`.
pub struct Panel {
    pub axis: &'static str,
    pub values: Vec<usize>,
    pub series: Vec<(Method, Vec<(f64, f64)>)>,
}

/// Panels (a)/(b): sweep I with J fixed. Panels (c)/(d): sweep J with I
/// fixed. `sweep_i` selects which.
pub fn run_panel(
    backend: &mut FitBackend,
    sweep_i: bool,
    fixed: usize,
    values: &[usize],
    base: &CellCfg,
) -> Result<Panel> {
    let mut series = Vec::new();
    for method in Method::ALL {
        let mut pts = Vec::with_capacity(values.len());
        for &v in values {
            let cfg = CellCfg {
                i_size: if sweep_i { v } else { fixed },
                j_size: if sweep_i { fixed } else { v },
                ..base.clone()
            };
            pts.push(run_cell(backend, method, &cfg)?);
        }
        series.push((method, pts));
    }
    Ok(Panel {
        axis: if sweep_i { "I" } else { "J" },
        values: values.to_vec(),
        series,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> CellCfg {
        CellCfg {
            n: 60,
            iters: 150,
            reps: 2,
            ..Default::default()
        }
    }

    #[test]
    fn all_methods_run() {
        let mut be = FitBackend::native();
        for m in Method::ALL {
            let cfg = CellCfg {
                i_size: 16,
                j_size: 16,
                ..quick_cfg()
            };
            let (mean, std) = run_cell(&mut be, m, &cfg).unwrap();
            assert!((0.0..=1.0).contains(&mean), "{m:?}: {mean}");
            assert!(std >= 0.0);
        }
    }

    #[test]
    fn emp_improves_with_j_under_tight_budget() {
        // The headline qualitative claim of Fig. 2c/d: with a fixed
        // (small) iteration budget, more expansion samples -> better
        // DSEKL error. (With a generous budget even J=2 converges,
        // because DSEKL resamples J every step — that is the point of
        // the method; the budgeted regime is where the J sweep bites.)
        let mut be = FitBackend::native();
        let budget = CellCfg {
            n: 100,
            iters: 15,
            reps: 4,
            ..Default::default()
        };
        let small = run_cell(
            &mut be,
            Method::Emp,
            &CellCfg {
                i_size: 32,
                j_size: 1,
                ..budget.clone()
            },
        )
        .unwrap();
        let large = run_cell(
            &mut be,
            Method::Emp,
            &CellCfg {
                i_size: 32,
                j_size: 64,
                ..budget
            },
        )
        .unwrap();
        assert!(
            large.0 < small.0,
            "J=64 should beat J=1 at 15 iters: {large:?} vs {small:?}"
        );
    }

    #[test]
    fn panel_shape() {
        let mut be = FitBackend::native();
        let cfg = CellCfg {
            reps: 1,
            iters: 60,
            n: 40,
            ..Default::default()
        };
        let p = run_panel(&mut be, true, 16, &[4, 16], &cfg).unwrap();
        assert_eq!(p.axis, "I");
        assert_eq!(p.values, vec![4, 16]);
        assert_eq!(p.series.len(), 4);
        assert!(p.series.iter().all(|(_, pts)| pts.len() == 2));
    }
}
