//! Experiment harness: the code that regenerates every table and figure
//! of the paper's evaluation (DESIGN.md §4 experiment index).
//!
//! Each submodule owns one artifact:
//!
//! * [`fig2`] — XOR error vs `I` and vs `J` for Emp / RKS / Emp_Fix /
//!   Batch (Figure 2 a-d).
//! * [`table1`] — DSEKL vs batch SVM across the seven real-world
//!   analogue datasets (Table 1).
//! * [`fig3a`] — covtype-scale convergence of the parallel solver
//!   (Figure 3a).
//! * [`fig3b`] — multi-worker speedup, measured + calibrated model
//!   (Figure 3b).
//!
//! The `cargo bench` targets in `rust/benches/` are thin drivers around
//! these functions; keeping the logic here makes it unit-testable and
//! reusable from the examples.

pub mod ablations;
pub mod fig2;
pub mod fig3a;
pub mod fig3b;
pub mod table1;

/// Render a markdown table (used by benches to print paper-style rows).
pub fn markdown_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str("| ");
    out.push_str(&header.join(" | "));
    out.push_str(" |\n|");
    for _ in header {
        out.push_str("---|");
    }
    out.push('\n');
    for row in rows {
        out.push_str("| ");
        out.push_str(&row.join(" | "));
        out.push_str(" |\n");
    }
    out
}

/// `mean ± std` with fixed precision, e.g. `0.03 ± 0.01` (Table 1 cells).
pub fn pm(mean: f64, std: f64) -> String {
    format!("{mean:.2} ± {std:.2}")
}

/// Experiment scale knob: benches honour `DSEKL_BENCH_SCALE=quick|default|full`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// CI-friendly: minutes, trends visible.
    Quick,
    /// Reasonable single-machine run (default).
    Default,
    /// Paper-scale (covtype at full 581k etc.) — hours on one core.
    Full,
}

impl Scale {
    /// Read from the environment.
    pub fn from_env() -> Scale {
        match std::env::var("DSEKL_BENCH_SCALE").as_deref() {
            Ok("quick") => Scale::Quick,
            Ok("full") => Scale::Full,
            _ => Scale::Default,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_rendering() {
        let t = markdown_table(
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["3".into(), "4".into()]],
        );
        assert!(t.contains("| a | b |"));
        assert!(t.contains("| 3 | 4 |"));
        assert_eq!(t.lines().count(), 4);
    }

    #[test]
    fn pm_format() {
        assert_eq!(pm(0.034, 0.011), "0.03 ± 0.01");
    }

    #[test]
    fn scale_default() {
        // Without the env var set, default scale.
        std::env::remove_var("DSEKL_BENCH_SCALE");
        assert_eq!(Scale::from_env(), Scale::Default);
    }
}
