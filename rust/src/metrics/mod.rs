//! Metrics substrate: classification error, timing, throughput, a loss
//! trace for Fig. 3a-style convergence curves, and the calibrated
//! speedup model used for the Fig. 3b reproduction (DESIGN.md §4,
//! "Substitutions": the container exposes one core, so the *curve* is
//! modelled from measured per-batch compute and aggregation fractions).

use std::fmt;
use std::time::{Duration, Instant};

/// Classification error rate between scores and ±1 labels.
pub fn error_rate(scores: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    if scores.is_empty() {
        return 0.0;
    }
    let wrong = scores
        .iter()
        .zip(labels)
        .filter(|(s, y)| (s.is_sign_positive() && **y < 0.0) || (s.is_sign_negative() && **y > 0.0))
        .count();
    wrong as f64 / scores.len() as f64
}

/// Confusion counts for binary classification.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct Confusion {
    pub tp: usize,
    pub tn: usize,
    pub fp: usize,
    pub fn_: usize,
}

impl Confusion {
    /// Tally scores vs labels.
    pub fn from_scores(scores: &[f32], labels: &[f32]) -> Self {
        let mut c = Confusion::default();
        for (s, y) in scores.iter().zip(labels) {
            match (*s >= 0.0, *y > 0.0) {
                (true, true) => c.tp += 1,
                (true, false) => c.fp += 1,
                (false, true) => c.fn_ += 1,
                (false, false) => c.tn += 1,
            }
        }
        c
    }

    /// Accuracy.
    pub fn accuracy(&self) -> f64 {
        let total = self.tp + self.tn + self.fp + self.fn_;
        if total == 0 {
            return 0.0;
        }
        (self.tp + self.tn) as f64 / total as f64
    }

    /// Precision (0 when no positive predictions).
    pub fn precision(&self) -> f64 {
        if self.tp + self.fp == 0 {
            return 0.0;
        }
        self.tp as f64 / (self.tp + self.fp) as f64
    }

    /// Recall (0 when no positive labels).
    pub fn recall(&self) -> f64 {
        if self.tp + self.fn_ == 0 {
            return 0.0;
        }
        self.tp as f64 / (self.tp + self.fn_) as f64
    }
}

/// Wall-clock stopwatch with split support.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    last: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    /// Start timing now.
    pub fn new() -> Self {
        let now = Instant::now();
        Stopwatch { start: now, last: now }
    }

    /// Seconds since construction.
    pub fn total(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Seconds since the previous `lap` (or construction).
    pub fn lap(&mut self) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        dt
    }
}

/// One point of a convergence trace (Fig. 3a rows).
#[derive(Debug, Clone, PartialEq)]
pub struct TracePoint {
    /// Cumulative gradient samples processed (the paper's x-axis:
    /// "data points processed").
    pub points_processed: u64,
    /// Iteration / epoch counter.
    pub iteration: u64,
    /// Current training loss estimate (masked hinge mean).
    pub loss: f64,
    /// Validation error, when a validation set was evaluated.
    pub val_error: Option<f64>,
    /// Seconds since training start.
    pub elapsed_s: f64,
}

/// Accumulating convergence trace.
#[derive(Debug, Default, Clone)]
pub struct Trace {
    pub points: Vec<TracePoint>,
}

impl Trace {
    /// Append a point.
    pub fn push(&mut self, p: TracePoint) {
        self.points.push(p);
    }

    /// Last validation error seen, if any.
    pub fn last_val_error(&self) -> Option<f64> {
        self.points.iter().rev().find_map(|p| p.val_error)
    }

    /// Render as TSV (header + rows) for EXPERIMENTS.md extraction.
    pub fn to_tsv(&self) -> String {
        let mut out = String::from("points\titer\tloss\tval_error\telapsed_s\n");
        for p in &self.points {
            let ve = p
                .val_error
                .map(|v| format!("{v:.4}"))
                .unwrap_or_else(|| "-".into());
            out.push_str(&format!(
                "{}\t{}\t{:.5}\t{}\t{:.3}\n",
                p.points_processed, p.iteration, p.loss, ve, p.elapsed_s
            ));
        }
        out
    }
}

/// Windowed prequential (test-then-train) error accumulator, shared by
/// the online solver and the `stream/` harness so both emit the same
/// trace shape: one error point per completed window of the stream plus
/// a cumulative total at the end.
///
/// Feed it one `wrong` verdict per stream item (scored *before* the
/// model trains on the item). `observe` returns `Some(window_error)`
/// exactly when a window completes, so callers can push a trace point
/// mid-stream without duplicating the boundary arithmetic.
#[derive(Debug, Clone)]
pub struct PrequentialWindow {
    window: u64,
    seen: u64,
    wrong: u64,
    win_seen: u64,
    win_wrong: u64,
}

impl PrequentialWindow {
    /// New accumulator emitting a point every `window` items
    /// (`window == 0` is treated as 1).
    pub fn new(window: usize) -> Self {
        PrequentialWindow {
            window: (window as u64).max(1),
            seen: 0,
            wrong: 0,
            win_seen: 0,
            win_wrong: 0,
        }
    }

    /// Record one prequential verdict; returns the completed window's
    /// error rate when this item closes a window.
    pub fn observe(&mut self, wrong: bool) -> Option<f64> {
        self.seen += 1;
        self.win_seen += 1;
        if wrong {
            self.wrong += 1;
            self.win_wrong += 1;
        }
        if self.win_seen == self.window {
            let err = self.win_wrong as f64 / self.win_seen as f64;
            self.win_seen = 0;
            self.win_wrong = 0;
            Some(err)
        } else {
            None
        }
    }

    /// Items observed so far.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Cumulative prequential error over the whole stream so far.
    pub fn total_error(&self) -> f64 {
        if self.seen == 0 {
            return 0.0;
        }
        self.wrong as f64 / self.seen as f64
    }
}

/// Nearest-rank percentile of a **sorted** sample, `q` in `[0, 1]`.
/// Returns 0 on an empty sample.
pub fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = (q.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Latency distribution summary over a sample of per-request
/// durations in microseconds — what the serve layer reports.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LatencySummary {
    /// Number of samples summarised.
    pub count: usize,
    /// Median (p50) in microseconds.
    pub p50_us: u64,
    /// 90th percentile in microseconds.
    pub p90_us: u64,
    /// 99th percentile in microseconds.
    pub p99_us: u64,
    /// Largest sample in microseconds.
    pub max_us: u64,
    /// Arithmetic mean in microseconds.
    pub mean_us: u64,
}

impl LatencySummary {
    /// Summarise a sample of microsecond durations (sorts in place).
    pub fn from_samples(samples: &mut [u64]) -> LatencySummary {
        if samples.is_empty() {
            return LatencySummary::default();
        }
        samples.sort_unstable();
        let sum: u128 = samples.iter().map(|&v| v as u128).sum();
        LatencySummary {
            count: samples.len(),
            p50_us: percentile(samples, 0.50),
            p90_us: percentile(samples, 0.90),
            p99_us: percentile(samples, 0.99),
            max_us: *samples.last().expect("non-empty"),
            mean_us: (sum / samples.len() as u128) as u64,
        }
    }
}

impl fmt::Display for LatencySummary {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "p50={}us p90={}us p99={}us max={}us mean={}us (n={})",
            self.p50_us, self.p90_us, self.p99_us, self.max_us, self.mean_us, self.count
        )
    }
}

/// Throughput helper: points/sec over a window.
pub fn throughput(points: u64, elapsed: Duration) -> f64 {
    let s = elapsed.as_secs_f64();
    if s <= 0.0 {
        return 0.0;
    }
    points as f64 / s
}

/// Calibrated speedup model for Fig. 3b (see DESIGN.md §4).
///
/// The paper measures per-batch runtime with K workers on a 48-core
/// machine (24 physical + HT) and observes: linear speedup to ~20 cores
/// (slope ~0.8, i.e. speedup 16 at 20), then a flattening attributed to
/// hyperthreading and python serialisation overhead.
///
/// Model: a work fraction `p` parallelises perfectly across min(K, C_phys)
/// cores; beyond the physical-core knee each extra logical core
/// contributes only `ht_eff` of a core; a serial fraction `(1-p)` (the
/// paper: gradient aggregation + α update, plus GIL-ish serialisation
/// cost `s·K` growing with worker count).
#[derive(Debug, Clone, Copy)]
pub struct SpeedupModel {
    /// Parallel fraction of one batch's work (calibrated from measured
    /// aggregation vs compute time).
    pub parallel_frac: f64,
    /// Physical cores before the hyperthreading knee.
    pub physical_cores: usize,
    /// Marginal efficiency of a hyperthread vs a physical core.
    pub ht_efficiency: f64,
    /// Per-worker serialisation overhead fraction.
    pub serialization_per_worker: f64,
}

impl Default for SpeedupModel {
    fn default() -> Self {
        // Paper's testbed: 24 physical cores + HT; knee at ~20 with
        // speedup 16 => effective slope 0.8.
        SpeedupModel {
            parallel_frac: 0.995,
            physical_cores: 24,
            ht_efficiency: 0.15,
            serialization_per_worker: 0.0004,
        }
    }
}

impl SpeedupModel {
    /// Effective parallel capacity of K workers.
    fn capacity(&self, k: usize) -> f64 {
        let k = k.max(1);
        if k <= self.physical_cores {
            k as f64
        } else {
            self.physical_cores as f64 + (k - self.physical_cores) as f64 * self.ht_efficiency
        }
    }

    /// Predicted speedup of K workers over 1 worker.
    pub fn speedup(&self, k: usize) -> f64 {
        let p = self.parallel_frac;
        let t1 = 1.0; // normalised single-worker batch time
        let tk = (1.0 - p)
            + p / self.capacity(k)
            + self.serialization_per_worker * (k.saturating_sub(1)) as f64;
        t1 / tk
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_rate_basic() {
        let scores = [1.0f32, -0.5, 0.2, -2.0];
        let labels = [1.0f32, 1.0, -1.0, -1.0];
        assert!((error_rate(&scores, &labels) - 0.5).abs() < 1e-12);
        assert_eq!(error_rate(&[], &[]), 0.0);
    }

    #[test]
    fn confusion_counts() {
        let scores = [1.0f32, 1.0, -1.0, -1.0, 1.0];
        let labels = [1.0f32, -1.0, 1.0, -1.0, 1.0];
        let c = Confusion::from_scores(&scores, &labels);
        assert_eq!(c, Confusion { tp: 2, tn: 1, fp: 1, fn_: 1 });
        assert!((c.accuracy() - 0.6).abs() < 1e-12);
        assert!((c.precision() - 2.0 / 3.0).abs() < 1e-12);
        assert!((c.recall() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn trace_tsv_and_last_val() {
        let mut t = Trace::default();
        t.push(TracePoint {
            points_processed: 100,
            iteration: 1,
            loss: 0.9,
            val_error: None,
            elapsed_s: 0.1,
        });
        t.push(TracePoint {
            points_processed: 200,
            iteration: 2,
            loss: 0.5,
            val_error: Some(0.17),
            elapsed_s: 0.2,
        });
        assert_eq!(t.last_val_error(), Some(0.17));
        let tsv = t.to_tsv();
        assert!(tsv.contains("0.1700"));
        assert_eq!(tsv.lines().count(), 3);
    }

    #[test]
    fn speedup_model_matches_paper_shape() {
        let m = SpeedupModel::default();
        // Monotone increasing in the measured range...
        let s1 = m.speedup(1);
        let s10 = m.speedup(10);
        let s20 = m.speedup(20);
        let s40 = m.speedup(40);
        assert!((s1 - 1.0).abs() < 0.05);
        assert!(s10 > 7.0 && s10 < 10.0, "s10 = {s10}");
        // Paper: ~16x at 20 cores.
        assert!(s20 > 13.0 && s20 < 18.0, "s20 = {s20}");
        // ...then flattens: 40 workers gain little over 20.
        assert!(s40 < s20 * 1.35, "s40 = {s40}, s20 = {s20}");
        assert!(s40 > s20 * 0.8);
    }

    #[test]
    fn prequential_window_boundaries_and_totals() {
        let mut w = PrequentialWindow::new(3);
        // wrong, right, right | wrong, wrong, right | right (tail)
        assert_eq!(w.observe(true), None);
        assert_eq!(w.observe(false), None);
        let first = w.observe(false).expect("window of 3 completes");
        assert!((first - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(w.observe(true), None);
        assert_eq!(w.observe(true), None);
        let second = w.observe(false).expect("second window completes");
        assert!((second - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(w.observe(false), None);
        assert_eq!(w.seen(), 7);
        assert!((w.total_error() - 3.0 / 7.0).abs() < 1e-12);
        // Degenerate window of 0 behaves like 1, and the empty
        // accumulator reports zero error.
        assert_eq!(PrequentialWindow::new(0).total_error(), 0.0);
        let mut unit = PrequentialWindow::new(0);
        assert_eq!(unit.observe(true), Some(1.0));
    }

    #[test]
    fn percentile_nearest_rank() {
        assert_eq!(percentile(&[], 0.5), 0);
        assert_eq!(percentile(&[7], 0.5), 7);
        let s: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&s, 0.50), 50);
        assert_eq!(percentile(&s, 0.90), 90);
        assert_eq!(percentile(&s, 0.99), 99);
        assert_eq!(percentile(&s, 1.0), 100);
        assert_eq!(percentile(&s, 0.0), 1);
    }

    #[test]
    fn latency_summary_shape() {
        let mut s: Vec<u64> = (1..=200).rev().collect();
        let l = LatencySummary::from_samples(&mut s);
        assert_eq!(l.count, 200);
        assert_eq!(l.p50_us, 100);
        assert_eq!(l.p90_us, 180);
        assert_eq!(l.p99_us, 198);
        assert_eq!(l.max_us, 200);
        assert!((l.mean_us as i64 - 100).abs() <= 1);
        let text = l.to_string();
        assert!(text.contains("p50=100us") && text.contains("n=200"), "{text}");
        assert_eq!(LatencySummary::from_samples(&mut []).count, 0);
    }

    #[test]
    fn throughput_zero_guard() {
        assert_eq!(throughput(100, Duration::from_secs(0)), 0.0);
        assert!((throughput(100, Duration::from_secs(2)) - 50.0).abs() < 1e-9);
    }
}
