//! Minimal recursive-descent JSON parser.
//!
//! The offline build environment has no serde, so the AOT manifest
//! (`artifacts/manifest.json`, written by `python/compile/aot.py`) is
//! parsed with this self-contained implementation. It supports the full
//! JSON grammar minus exotic number forms we never emit (hex, huge
//! exponents are fine; NaN/Infinity are not JSON and not accepted).

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Parsed JSON value. Object keys are sorted (BTreeMap) so iteration —
/// and therefore everything downstream — is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::parse(format!(
                "trailing characters at byte {}",
                p.pos
            )));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String content, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric content, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Number as usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// Array contents, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Bool content, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        match self.bump() {
            Some(b) if b == c => Ok(()),
            other => Err(Error::parse(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.pos.saturating_sub(1),
                other.map(|b| b as char)
            ))),
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::parse(format!(
                "unexpected {:?} at byte {}",
                other.map(|b| b as char),
                self.pos
            ))),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::parse(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                other => {
                    return Err(Error::parse(format!(
                        "expected ',' or '}}' in object, found {:?}",
                        other.map(|b| b as char)
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                other => {
                    return Err(Error::parse(format!(
                        "expected ',' or ']' in array, found {:?}",
                        other.map(|b| b as char)
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(Error::parse("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(Error::parse("bad low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(ch.ok_or_else(|| Error::parse("bad codepoint"))?);
                    }
                    other => {
                        return Err(Error::parse(format!(
                            "bad escape {:?}",
                            other.map(|b| b as char)
                        )))
                    }
                },
                Some(b) if b < 0x20 => {
                    return Err(Error::parse("control char in string"))
                }
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let len = if b >= 0xF0 {
                            4
                        } else if b >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(Error::parse("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| Error::parse("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| Error::parse("eof in \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| Error::parse("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| Error::parse("invalid number bytes"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| Error::parse(format!("bad number '{text}': {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"hi\\n\"").unwrap(),
            Json::Str("hi\n".to_string())
        );
    }

    #[test]
    fn nested_document() {
        let doc = r#"{"a": [1, 2, {"b": "c"}], "d": {"e": null}, "f": true}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.get("d").unwrap().get("e"), Some(&Json::Null));
        assert_eq!(v.get("f").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é""#).unwrap(),
            Json::Str("é".to_string())
        );
        // surrogate pair: U+1F600
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".to_string())
        );
    }

    #[test]
    fn raw_utf8_passthrough() {
        assert_eq!(
            Json::parse("\"héllo — ok\"").unwrap(),
            Json::Str("héllo — ok".to_string())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n\t\"k\" :\r [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
        assert_eq!(Json::parse("7.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-7").unwrap().as_usize(), None);
    }
}
