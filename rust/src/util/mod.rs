//! Small shared utilities: a dependency-free JSON parser (for the AOT
//! manifest and config files) and padding/shape helpers used by the
//! fixed-shape runtime.

pub mod json;

/// Round `n` up to the next multiple of `m` (m > 0).
pub fn round_up(n: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    n.div_ceil(m) * m
}

/// Smallest tile in `tiles` (ascending) that is `>= n`, or `None`.
pub fn smallest_fitting(tiles: &[usize], n: usize) -> Option<usize> {
    tiles.iter().copied().filter(|&t| t >= n).min()
}

/// Zero-pad a row-major `[rows, cols]` matrix to `[rows_p, cols_p]`.
/// Returns a fresh buffer; the source is untouched.
pub fn pad_matrix(src: &[f32], rows: usize, cols: usize, rows_p: usize, cols_p: usize) -> Vec<f32> {
    assert_eq!(src.len(), rows * cols, "matrix buffer size mismatch");
    assert!(rows_p >= rows && cols_p >= cols);
    if rows_p == rows && cols_p == cols {
        return src.to_vec();
    }
    let mut out = vec![0.0f32; rows_p * cols_p];
    for r in 0..rows {
        out[r * cols_p..r * cols_p + cols].copy_from_slice(&src[r * cols..(r + 1) * cols]);
    }
    out
}

/// Zero-pad a vector to length `n_p`.
pub fn pad_vec(src: &[f32], n_p: usize) -> Vec<f32> {
    assert!(n_p >= src.len());
    let mut out = vec![0.0f32; n_p];
    out[..src.len()].copy_from_slice(src);
    out
}

/// 0/1 mask of length `n_p` with the first `n` entries set.
pub fn mask(n: usize, n_p: usize) -> Vec<f32> {
    assert!(n_p >= n);
    let mut m = vec![0.0f32; n_p];
    m[..n].fill(1.0);
    m
}

/// Mean and (population) standard deviation of a slice.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_cases() {
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
        assert_eq!(round_up(0, 8), 0);
    }

    #[test]
    fn smallest_fitting_cases() {
        assert_eq!(smallest_fitting(&[64, 256, 1024], 2), Some(64));
        assert_eq!(smallest_fitting(&[64, 256, 1024], 64), Some(64));
        assert_eq!(smallest_fitting(&[64, 256, 1024], 65), Some(256));
        assert_eq!(smallest_fitting(&[64, 256, 1024], 2000), None);
    }

    #[test]
    fn pad_matrix_preserves_rows() {
        let src = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3
        let out = pad_matrix(&src, 2, 3, 3, 5);
        assert_eq!(out.len(), 15);
        assert_eq!(&out[0..3], &[1.0, 2.0, 3.0]);
        assert_eq!(&out[3..5], &[0.0, 0.0]);
        assert_eq!(&out[5..8], &[4.0, 5.0, 6.0]);
        assert!(out[10..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn pad_matrix_noop_when_same_shape() {
        let src = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(pad_matrix(&src, 2, 2, 2, 2), src);
    }

    #[test]
    fn mask_layout() {
        let m = mask(3, 5);
        assert_eq!(m, vec![1.0, 1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn mean_std_known() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.0).abs() < 1e-12);
    }
}
