//! Minimal argv parser: one positional subcommand, then `--key value`
//! options and `--flag` booleans (a flag is an option whose next token
//! starts with `--` or is absent).

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    subcommand: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse `argv` (excluding the program name).
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if matches!(it.peek(), Some(first) if !first.starts_with("--")) {
            args.subcommand = it.next().cloned();
        }
        while let Some(tok) = it.next() {
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| Error::invalid(format!("unexpected positional '{tok}'")))?;
            if key.is_empty() {
                return Err(Error::invalid("empty option name '--'"));
            }
            // `--key=value` form.
            if let Some((k, v)) = key.split_once('=') {
                args.options.insert(k.to_string(), v.to_string());
                continue;
            }
            match it.peek() {
                Some(next) if !next.starts_with("--") => {
                    if let Some(val) = it.next() {
                        args.options.insert(key.to_string(), val.clone());
                    }
                }
                _ => args.flags.push(key.to_string()),
            }
        }
        Ok(args)
    }

    /// The positional subcommand, if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.subcommand.as_deref()
    }

    /// Raw option value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// Boolean flag presence.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Typed option with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(raw) => raw.parse().map_err(|_| {
                Error::invalid(format!("--{key}: cannot parse '{raw}'"))
            }),
        }
    }

    /// Required typed option.
    pub fn require<T: std::str::FromStr>(&self, key: &str) -> Result<T> {
        let raw = self
            .get(key)
            .ok_or_else(|| Error::invalid(format!("missing required --{key}")))?;
        raw.parse()
            .map_err(|_| Error::invalid(format!("--{key}: cannot parse '{raw}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn subcommand_and_options() {
        let a = Args::parse(&argv("train --dataset xor --n 100 --verbose")).unwrap();
        assert_eq!(a.subcommand(), Some("train"));
        assert_eq!(a.get("dataset"), Some("xor"));
        assert_eq!(a.get_or::<usize>("n", 0).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn key_equals_value() {
        let a = Args::parse(&argv("train --gamma=0.5")).unwrap();
        assert_eq!(a.require::<f32>("gamma").unwrap(), 0.5);
    }

    #[test]
    fn no_subcommand() {
        let a = Args::parse(&argv("--help")).unwrap();
        assert_eq!(a.subcommand(), None);
        assert!(a.flag("help"));
    }

    #[test]
    fn negative_number_values() {
        // A value starting with '-' (not '--') is still a value.
        let a = Args::parse(&argv("train --shift -1.5")).unwrap();
        assert_eq!(a.require::<f32>("shift").unwrap(), -1.5);
    }

    #[test]
    fn loss_and_multiclass_forms() {
        // `--loss X` parses as an option and feeds the typed accessor.
        let a = Args::parse(&argv("train --loss logistic --multiclass ovr")).unwrap();
        assert_eq!(a.get("loss"), Some("logistic"));
        assert_eq!(
            a.get_or("loss", crate::loss::Loss::Hinge).unwrap(),
            crate::loss::Loss::Logistic
        );
        assert_eq!(a.get("multiclass"), Some("ovr"));
        // Bare `--multiclass` (no value) degrades to a flag.
        let b = Args::parse(&argv("train --multiclass --n 10")).unwrap();
        assert_eq!(b.get("multiclass"), None);
        assert!(b.flag("multiclass"));
        assert_eq!(b.get_or::<usize>("n", 0).unwrap(), 10);
    }

    #[test]
    fn errors() {
        assert!(Args::parse(&argv("train stray")).is_err());
        let a = Args::parse(&argv("train --n abc")).unwrap();
        assert!(a.require::<usize>("n").is_err());
        assert!(a.require::<usize>("missing").is_err());
    }
}
