//! CLI substrate: argument parsing (no external deps offline) and the
//! subcommand implementations behind the `dsekl` binary.
//!
//! ```text
//! dsekl train      --dataset xor --n 200 --solver parallel --workers 4 ...
//! dsekl stream     --source rotate --n 4000 --budget 128 --tail-features 256
//! dsekl predict    --model m.dsekl --dataset xor --n 100
//! dsekl serve      --model m.dsekl --addr 127.0.0.1:7878
//! dsekl gridsearch --dataset diabetes --n 500 --folds 2
//! dsekl info       [--artifacts artifacts]
//! ```

pub mod args;
pub mod commands;

pub use args::Args;

use crate::Result;

/// Entry point used by `main.rs`: dispatch a full argv.
pub fn run(argv: &[String]) -> Result<i32> {
    let args = Args::parse(argv)?;
    match args.subcommand() {
        Some("train") => commands::train(&args),
        Some("stream") => commands::stream(&args),
        Some("predict") => commands::predict(&args),
        Some("serve") => commands::serve(&args),
        Some("gridsearch") => commands::gridsearch(&args),
        Some("info") => commands::info(&args),
        Some("help") | None => {
            print!("{}", commands::USAGE);
            Ok(0)
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'\n");
            print!("{}", commands::USAGE);
            Ok(2)
        }
    }
}
