//! Subcommand implementations for the `dsekl` binary.
//!
//! `train` is **one** dispatch function: it parses the flags, loads the
//! requested layout (dense/CSR × binary/multiclass), and hands a
//! [`TrainSet`] to the [`Fit`] builder — the single routing point for
//! solver × layout × serial/parallel. The four near-duplicate dispatch
//! functions this file used to carry are gone; a new solver or layout
//! plugs into the estimator layer, not into the CLI.

use std::sync::Arc;
use std::time::Duration;

use super::Args;
use crate::coordinator::CoordTransport;
use crate::data::{
    libsvm, synth, Dataset, MultiDataset, Scaler, SparseDataset, SparseMultiDataset,
};
use crate::estimator::{Fit, FitBackend, FitBuilder, Predictor, SolverKind, TrainSet};
use crate::hyper::{grid_search_dsekl, GridSpec};
use crate::loss::Loss;
use crate::model::HybridModel;
use crate::rng::Pcg64;
use crate::runtime::BackendSpec;
use crate::serve::{ServeOpts, Server};
use crate::solver::dsekl::DseklOpts;
use crate::solver::LrSchedule;
use crate::stream::{by_name, DatasetReplay, StreamOpts, StreamSolver, StreamSource};
use crate::{Error, Result};

/// Top-level usage text.
pub const USAGE: &str = "\
dsekl — doubly stochastic empirical kernel learning

USAGE:
  dsekl <SUBCOMMAND> [OPTIONS]

SUBCOMMANDS:
  train        train a model
  stream       prequential training on a drift-aware stream
  predict      evaluate a saved model on a dataset
  serve        host a saved model as a long-lived scoring server
  gridsearch   exhaustive grid search with k-fold CV
  info         show AOT artifact manifest
  help         this text

COMMON OPTIONS:
  --dataset <name|libsvm:PATH>   xor|covtype|blobs|mnist|diabetes|
                                 breast-cancer|mushrooms|sonar|
                                 skin-nonskin|madelon, or libsvm:file
  --n <N>                        synthetic dataset size   [1000]
  --seed <S>                     RNG seed                 [42]
  --backend <native|pjrt[:dir]>  compute backend          [native]
  --scale                        standardise features
  --sparse                       CSR data path: libsvm files parse
                                 straight to CSR, training/prediction
                                 run the O(nnz) sparse kernel path, and
                                 saved models keep CSR expansion rows
                                 (DSEKLv3 — file size scales with nnz)
                                 (solvers dsekl|parallel|online|stream;
                                 --scale becomes center-free scaling)
  --dim <d> / --density <p>      shape of the `sparse` synthetic
                                 generator                [200 / 0.05]

TRAIN OPTIONS:
  --solver <dsekl|parallel|batch|empfix|rks|online|stream> [dsekl]
  --loss <hinge|squared-hinge|logistic|ridge>             [hinge]
  --multiclass <ovr>             one-vs-rest over K classes
  --classes <k>                  synthetic class count    [4]
  --gamma/--lam/--eta0 <f>       hyper-parameters
  --isize/--jsize <n>            sample sizes |I|, |J|    [64]
  --iters <n>                    iteration cap            [2000]
  --epochs <n>                   epoch cap (parallel)     [20]
  --workers <k>                  worker threads (parallel)[4]
  --round-batches <g>            batches per round        [=workers]
  --shards <w>                   worker-hosted coefficient shards
                                 (parallel; 0 = leader-applied) [0]
  --coord-transport <t>          leader-worker transport,
                                 channel|socket (parallel) [channel]
  --tol <f>                      epoch-change tolerance   [0]
  --features <r>                 RKS feature count        [=jsize]
  --subset <m>                   EmpFix subset size       [=jsize]
  --budget <b>                   online/stream expansion budget [256]
  --chunk <c>                    online/stream items per step   [16]
  --evict-every <k>              stream eviction cadence, steps [4]
  --train-frac <f>               train split fraction     [0.5]
  --save <path>                  write model file (every solver, RKS
                                 included — DSEKLrk1 primal weights)

STREAM OPTIONS:
  --source <name|libsvm:PATH>    blobs|covtype|abrupt|rotate|covshift,
                                 or libsvm:file replay    [blobs]
  --n <N> / --dim <d>            stream length / item dim [2000 / 10]
  --budget <b>                   head expansion budget    [256]
  --chunk <c>                    items per gradient step  [16]
  --evict-every <k>              eviction cadence, steps  [4]
  --tail-features <r>            RKS tail width, 0=off    [128]
  --window <w>                   trace window, items      [n/10]
  --gamma/--lam/--eta0 <f>       hyper-parameters
  --save <path>                  write the frozen model (DSEKLhy1
                                 hybrid, or DSEKLv1 when tail off)

SERVE OPTIONS:
  --model <path>                 model file (any format; sniffed)
  --addr <host:port>             TCP listen address       [127.0.0.1:7878]
  --stdio                        serve stdin/stdout instead of TCP
  --max-batch-rows <n>           micro-batch row cap      [256]
  --max-wait-us <us>             micro-batch linger, us   [1000]
  --scorer-threads <n>           scorer worker threads    [1]
  --max-queue-rows <n>           queued-row cap, 0=off    [4096]
  --request-timeout-ms <ms>      per-request deadline     [10000]

PREDICT:
  `dsekl predict --model m.dsekl` reads the file's 8-byte magic and
  loads whichever family it holds (DSEKLv1/v2/v3/mc1/rk1/hy1) — no
  `--multiclass` flag needed (it is tolerated but ignored). `--sparse`
  still selects the CSR dataset loader; a dataset whose dimensionality
  disagrees with the model is a clear error, not a panic.

SERVE:
  `dsekl serve` hosts the model behind a length-prefixed binary
  protocol (see README): ping, score (dense or CSR rows), reload
  (atomic hot model swap — in-flight batches finish on the old model)
  and stats (p50/p90/p99 latency, throughput, batch-size counters).
  Concurrent requests are micro-batched into one fused kernel pass per
  compatible group; tune with --max-batch-rows / --max-wait-us.
  --scorer-threads workers drain the queue concurrently (scores are
  identical for any N), --max-queue-rows sheds excess load immediately
  with a structured overloaded error instead of queuing without bound,
  and --request-timeout-ms bounds how long any request can wait — a
  wedged scorer or stalled client can never hang the server.

MULTICLASS:
  `--multiclass ovr` trains K one-vs-rest DSEKL heads that share one
  doubly stochastic sampling schedule: each step computes one |I|x|J|
  kernel block and steps all K heads against it (fused multi-head
  path), and the saved model stores the expansion rows once for all K
  coefficient vectors (DSEKLv2; legacy files still load). Datasets:
  blobs (default; K from --classes), covtype (always 7-class), or
  libsvm:PATH with integer class labels. --solver dsekl (serial) and
  parallel (fused K-head coordinator) apply; all --loss values work on
  the native backend.

ONLINE:
  `--solver online` streams the training split in storage order through
  a budgeted reservoir expansion (the paper-conclusion extension):
  every item is scored before the learner trains on it, so the
  reported prequential_error is an honest online generalisation
  estimate. --budget caps the expansion (memory and predict cost),
  --chunk sets how many items share one gradient step. Works on dense
  and --sparse data (rows stream one at a time); the frozen reservoir
  saves as a regular model file.

STREAM:
  `dsekl stream` drives a seeded drift source (abrupt label switch,
  gradual boundary rotation, covariate shift, stationary replays, or a
  libsvm file) through the prequential harness: every item is scored
  before the learner trains on it, one windowed error point prints per
  --window items. The learner is a budgeted empirical-map head —
  admission is unconditional, eviction trims back to --budget by
  coefficient magnitude every --evict-every steps — plus an RKS tail of
  --tail-features random features trained jointly, so accuracy degrades
  gracefully when drift saturates the budget. Fixed (opts, source,
  seed) reproduce runs bitwise. `--solver stream` inside `dsekl train`
  runs the same learner over a dataset split in storage order.
";

/// Load the dataset selected by `--dataset` / `--n` / `--seed`.
pub fn load_dataset(args: &Args) -> Result<Dataset> {
    let name = args.get("dataset").unwrap_or("xor");
    let n: usize = args.get_or("n", 1000)?;
    let seed: u64 = args.get_or("seed", 42)?;
    let mut rng = Pcg64::with_stream(seed, 0xDA7A);
    let mut ds = if let Some(path) = name.strip_prefix("libsvm:") {
        libsvm::read_file(path, None, Default::default())?
    } else {
        synth::by_name(name, n, &mut rng)
            .ok_or_else(|| Error::invalid(format!("unknown dataset '{name}'")))?
    };
    if args.flag("scale") {
        let scaler = Scaler::fit(&ds);
        scaler.transform(&mut ds);
    }
    Ok(ds)
}

fn backend_spec(args: &Args) -> Result<BackendSpec> {
    BackendSpec::parse(args.get("backend").unwrap_or("native"), "artifacts")
}

/// Load the dataset selected by `--dataset` as **CSR**. `libsvm:PATH`
/// parses straight to CSR (no dense round-trip); synthetic names are
/// generated dense and converted (plus the dedicated `sparse` name for
/// a genuinely high-sparsity generator). `--scale` applies the
/// center-free variance scaling (CSR-safe; see [`Scaler::fit_sparse`]).
pub fn load_sparse_dataset(args: &Args) -> Result<SparseDataset> {
    let name = args.get("dataset").unwrap_or("sparse");
    let n: usize = args.get_or("n", 1000)?;
    let seed: u64 = args.get_or("seed", 42)?;
    let density: f64 = args.get_or("density", 0.05)?;
    let mut rng = Pcg64::with_stream(seed, 0xDA7A);
    let mut ds = if let Some(path) = name.strip_prefix("libsvm:") {
        libsvm::read_sparse_file(path, None, Default::default())?
    } else if name == "sparse" {
        synth::sparse_binary(n, args.get_or("dim", 200)?, density, &mut rng)
    } else {
        let dense = synth::by_name(name, n, &mut rng)
            .ok_or_else(|| Error::invalid(format!("unknown dataset '{name}'")))?;
        SparseDataset::from_dense(&dense)
    };
    if args.flag("scale") {
        let scaler = Scaler::fit_sparse(&ds);
        scaler.transform_sparse(&mut ds);
    }
    Ok(ds)
}

/// Multiclass twin of [`load_sparse_dataset`] (`sparse` generates the
/// K-class high-sparsity set; K from `--classes`).
pub fn load_sparse_multiclass_dataset(args: &Args) -> Result<SparseMultiDataset> {
    let name = args.get("dataset").unwrap_or("sparse");
    let n: usize = args.get_or("n", 1000)?;
    let k: usize = args.get_or("classes", 4)?;
    let seed: u64 = args.get_or("seed", 42)?;
    let density: f64 = args.get_or("density", 0.05)?;
    let mut rng = Pcg64::with_stream(seed, 0xDA7A);
    let mut ds = if let Some(path) = name.strip_prefix("libsvm:") {
        libsvm::read_sparse_multiclass_file(path, None)?
    } else if name == "sparse" {
        synth::sparse_multiclass(n, k.max(2), args.get_or("dim", 200)?, density, &mut rng)
    } else {
        let dense = synth::multi_by_name(name, n, k, &mut rng).ok_or_else(|| {
            Error::invalid(format!(
                "dataset '{name}' has no multiclass generator \
                 (expected sparse|blobs|covtype|libsvm:PATH)"
            ))
        })?;
        SparseMultiDataset::from_dense(&dense)
    };
    if args.flag("scale") {
        let scaler = Scaler::fit_sparse_multi(&ds);
        scaler.transform_sparse_multi(&mut ds);
    }
    Ok(ds)
}

/// Load the multiclass dataset selected by `--dataset` / `--n` /
/// `--classes` / `--seed` (default: the K-class blob ring).
pub fn load_multiclass_dataset(args: &Args) -> Result<MultiDataset> {
    let name = args.get("dataset").unwrap_or("blobs");
    let n: usize = args.get_or("n", 1000)?;
    let k: usize = args.get_or("classes", 4)?;
    let seed: u64 = args.get_or("seed", 42)?;
    let mut rng = Pcg64::with_stream(seed, 0xDA7A);
    let mut ds = if let Some(path) = name.strip_prefix("libsvm:") {
        libsvm::read_multiclass_file(path, None)?
    } else {
        synth::multi_by_name(name, n, k, &mut rng).ok_or_else(|| {
            Error::invalid(format!(
                "dataset '{name}' has no multiclass generator \
                 (expected blobs|covtype|libsvm:PATH)"
            ))
        })?
    };
    if args.flag("scale") {
        let scaler = Scaler::fit_multi(&ds);
        scaler.transform_multi(&mut ds);
    }
    Ok(ds)
}

/// The `--multiclass` mode, if requested (`--multiclass` alone means
/// `ovr`, the only mode so far).
fn multiclass_mode(args: &Args) -> Result<Option<&str>> {
    match args.get("multiclass") {
        Some("ovr") => Ok(Some("ovr")),
        Some(other) => Err(Error::invalid(format!(
            "unknown multiclass mode '{other}' (expected ovr)"
        ))),
        None if args.flag("multiclass") => Ok(Some("ovr")),
        None => Ok(None),
    }
}

/// A typed flag that keeps the routed solver's own default when absent
/// (so e.g. batch retains its `InvSqrtT` schedule and 1e-4 tolerance
/// unless `--eta0`/`--tol` are given explicitly).
fn flag_opt<T: std::str::FromStr>(args: &Args, key: &str) -> Result<Option<T>> {
    match args.get(key) {
        None => Ok(None),
        Some(_) => args.require(key).map(Some),
    }
}

/// Map the CLI flags onto the [`Fit`] builder — one function for every
/// solver × layout combination, so a new flag wired here applies
/// everywhere and defaults cannot drift.
fn fit_builder_from(args: &Args, kind: SolverKind) -> Result<FitBuilder> {
    let mut b = Fit::solver(kind).loss(args.get_or("loss", Loss::Hinge)?);
    if let Some(v) = flag_opt(args, "gamma")? {
        b = b.gamma(v);
    }
    if let Some(v) = flag_opt(args, "lam")? {
        b = b.lam(v);
    }
    if let Some(v) = flag_opt(args, "eta0")? {
        b = b.eta0(v);
    }
    // The CLI's documented sample-size default is 64 for every solver
    // (the coordinator's library default is 256) — set it explicitly so
    // the flag-absent behaviour matches the usage text.
    b = b.sizes(args.get_or("isize", 64)?, args.get_or("jsize", 64)?);
    if let Some(v) = flag_opt(args, "iters")? {
        b = b.iters(v);
    }
    if let Some(v) = flag_opt(args, "tol")? {
        b = b.tol(v);
    }
    if let Some(v) = flag_opt(args, "subset")? {
        b = b.subset(v);
    }
    if let Some(v) = flag_opt(args, "features")? {
        b = b.features(v);
    }
    if let Some(v) = flag_opt(args, "budget")? {
        b = b.budget(v);
    }
    if let Some(v) = flag_opt(args, "chunk")? {
        b = b.chunk(v);
    }
    if let Some(v) = flag_opt(args, "evict-every")? {
        b = b.evict_every(v);
    }
    if let Some(v) = flag_opt(args, "tail-features")? {
        b = b.features(v);
    }
    if kind == SolverKind::Parallel {
        if let Some(v) = flag_opt(args, "workers")? {
            b = b.parallel(v);
        }
        if let Some(v) = flag_opt(args, "epochs")? {
            b = b.epochs(v);
        }
        if let Some(v) = flag_opt(args, "round-batches")? {
            b = b.round_batches(v);
        }
        if let Some(v) = flag_opt(args, "shards")? {
            b = b.shards(v);
        }
        if let Some(v) = flag_opt(args, "coord-transport")? {
            b = b.coord_transport(v);
        }
    }
    Ok(b)
}

/// The loaded-and-split training data, one variant per layout. The
/// training half sits behind an `Arc` so a parallel fit shares the
/// rows with its workers instead of copying them.
enum SplitData {
    Dense {
        train: Arc<Dataset>,
        test: Dataset,
    },
    Sparse {
        train: Arc<SparseDataset>,
        test: SparseDataset,
    },
    Multi {
        train: Arc<MultiDataset>,
        test: MultiDataset,
    },
    SparseMulti {
        train: Arc<SparseMultiDataset>,
        test: SparseMultiDataset,
    },
}

impl SplitData {
    /// Load the layout selected by `--multiclass` / `--sparse` and
    /// split off the held-out test half.
    fn load(
        args: &Args,
        multiclass: bool,
        sparse: bool,
        frac: f64,
        rng: &mut Pcg64,
    ) -> Result<SplitData> {
        Ok(match (multiclass, sparse) {
            (false, false) => {
                let (train, test) = load_dataset(args)?.split(frac, rng);
                SplitData::Dense {
                    train: Arc::new(train),
                    test,
                }
            }
            (false, true) => {
                let (train, test) = load_sparse_dataset(args)?.split(frac, rng);
                SplitData::Sparse {
                    train: Arc::new(train),
                    test,
                }
            }
            (true, false) => {
                let (train, test) = load_multiclass_dataset(args)?.split(frac, rng);
                SplitData::Multi {
                    train: Arc::new(train),
                    test,
                }
            }
            (true, true) => {
                let (train, test) = load_sparse_multiclass_dataset(args)?.split(frac, rng);
                SplitData::SparseMulti {
                    train: Arc::new(train),
                    test,
                }
            }
        })
    }

    /// The training half as a [`TrainSet`].
    fn train_set(&self) -> TrainSet<'_> {
        match self {
            SplitData::Dense { train, .. } => TrainSet::from(train),
            SplitData::Sparse { train, .. } => TrainSet::from(train),
            SplitData::Multi { train, .. } => TrainSet::from(train),
            SplitData::SparseMulti { train, .. } => TrainSet::from(train),
        }
    }

    /// The held-out half as a [`TrainSet`] (for error evaluation).
    fn test_set(&self) -> TrainSet<'_> {
        match self {
            SplitData::Dense { test, .. } => TrainSet::from(test),
            SplitData::Sparse { test, .. } => TrainSet::from(test),
            SplitData::Multi { test, .. } => TrainSet::from(test),
            SplitData::SparseMulti { test, .. } => TrainSet::from(test),
        }
    }
}

/// `dsekl train` — the one dispatch: parse, load, route through the
/// [`Fit`] builder, report, save.
pub fn train(args: &Args) -> Result<i32> {
    // Solver names parse before any data loads, and in exactly one
    // place — binary and multiclass runs report an unknown solver with
    // the identical structured error.
    let kind = SolverKind::parse(args.get("solver").unwrap_or("dsekl"))?;
    let multiclass = multiclass_mode(args)?.is_some();
    let sparse = args.flag("sparse");
    let seed: u64 = args.get_or("seed", 42)?;
    let train_frac: f64 = args.get_or("train-frac", 0.5)?;
    let loss: Loss = args.get_or("loss", Loss::Hinge)?;

    let mut rng = Pcg64::seed_from(seed);
    let data = SplitData::load(args, multiclass, sparse, train_frac, &mut rng)?;
    let builder = fit_builder_from(args, kind)?;
    let mut backend = FitBackend::new(backend_spec(args)?);
    let fitted = builder.fit(&mut backend, data.train_set(), &mut rng)?;

    if let Some(t) = &fitted.telemetry {
        println!(
            "# telemetry: rounds={} batches={} serial_fraction={:.4}",
            t.rounds,
            t.batches,
            t.serial_fraction()
        );
    }
    if let Some(per_class) = &fitted.per_class {
        for (c, s) in per_class.iter().enumerate() {
            println!(
                "#   class {c}: iters={} points={} converged={}",
                s.iterations, s.points_processed, s.converged
            );
        }
    }

    let be = backend.leader()?;
    let train_set = data.train_set();
    let train_err = fitted.predictor.error(&mut *be, &train_set)?;
    let test_err = fitted.predictor.error(&mut *be, &data.test_set())?;

    let solver_label = if multiclass {
        format!("ovr({kind})")
    } else {
        kind.name().to_string()
    };
    let mut line = format!("solver={solver_label} loss={loss} backend={}", be.name());
    if sparse {
        line.push_str(" sparse=csr");
    }
    if multiclass {
        line.push_str(&format!(
            " classes={} n_train={}",
            fitted.predictor.n_classes(),
            train_set.len()
        ));
    }
    line.push_str(&format!(" iters={}", fitted.stats.iterations));
    if let Some(m) = fitted.predictor.as_kernel() {
        line.push_str(&format!(" n_sv={}", m.n_support(1e-8)));
    }
    if sparse {
        line.push_str(&format!(" sparsity={:.3}", train_set.data().sparsity()));
    }
    if matches!(kind, SolverKind::Online | SolverKind::Stream) {
        // These traces' final val_error is the prequential error.
        if let Some(p) = fitted.stats.trace.last_val_error() {
            line.push_str(&format!(" prequential_error={p:.4}"));
        }
    }
    line.push_str(&format!(
        " train_error={train_err:.4} test_error={test_err:.4}"
    ));
    println!("{line}");

    if let Some(path) = args.get("save") {
        fitted.predictor.save_file(path)?;
        println!("model written to {path}");
    }
    Ok(0)
}

/// `dsekl stream` — prequential training on a drift-aware stream: pick
/// a seeded source by name (or replay a libsvm file), drive it through
/// [`StreamSolver`], print one windowed prequential-error line per
/// trace window plus a final summary, and optionally save the frozen
/// model (DSEKLhy1 hybrid, or plain DSEKLv1 when the tail is off).
pub fn stream(args: &Args) -> Result<i32> {
    let name = args.get("source").unwrap_or("blobs");
    let n: usize = args.get_or("n", 2000)?;
    let d: usize = args.get_or("dim", 10)?;
    let seed: u64 = args.get_or("seed", 42)?;

    let mut opts = StreamOpts::default();
    if let Some(v) = flag_opt(args, "gamma")? {
        opts.gamma = v;
    }
    if let Some(v) = flag_opt(args, "lam")? {
        opts.lam = v;
    }
    if let Some(v) = flag_opt(args, "budget")? {
        opts.budget = v;
    }
    if let Some(v) = flag_opt(args, "chunk")? {
        opts.chunk = v;
    }
    if let Some(v) = flag_opt(args, "evict-every")? {
        opts.evict_every = v;
    }
    if let Some(v) = flag_opt(args, "tail-features")? {
        opts.tail_features = v;
    }
    if let Some(v) = flag_opt(args, "eta0")? {
        // Streaming keeps a constant rate: a drifting stream never
        // becomes stationary, so decaying schedules freeze the past.
        opts.lr = LrSchedule::Const { eta0: v };
    }
    if let Some(v) = flag_opt(args, "window")? {
        opts.trace_window = v;
    }
    opts.loss = args.get_or("loss", Loss::Hinge)?;

    let mut source: Box<dyn StreamSource> = if let Some(path) = name.strip_prefix("libsvm:") {
        let ds = libsvm::read_file(path, None, Default::default())?;
        Box::new(DatasetReplay::new(ds))
    } else {
        by_name(name, n, d, seed).ok_or_else(|| {
            Error::invalid(format!(
                "unknown stream source '{name}' \
                 (expected blobs|covtype|abrupt|rotate|covshift|libsvm:PATH)"
            ))
        })?
    };

    let mut backend = backend_spec(args)?.instantiate()?;
    let mut rng = Pcg64::seed_from(seed);
    let res = StreamSolver::new(opts).run(backend.as_mut(), source.as_mut(), &mut rng)?;

    for p in &res.stats.trace.points {
        if let Some(e) = p.val_error {
            println!(
                "# items={} steps={} expansion_loss={:.4} window_error={e:.4}",
                p.points_processed, p.iteration, p.loss
            );
        }
    }
    let tail_r = res.tail.as_ref().map_or(0, |t| t.r);
    println!(
        "source={name} items={} steps={} n_expansion={} tail_features={tail_r} \
         elapsed_s={:.3} prequential_error={:.4}",
        res.stats.points_processed, res.stats.iterations, res.head.len(), res.stats.elapsed_s,
        res.prequential_error
    );

    if let Some(path) = args.get("save") {
        let predictor = match res.tail {
            Some(rks) => Predictor::Hybrid(HybridModel::new(res.head, rks)?),
            None => Predictor::Kernel(res.head),
        };
        predictor.save_file(path)?;
        println!("model written to {path}");
    }
    Ok(0)
}

/// `dsekl predict` — the model file's own magic decides the family
/// ([`Predictor::load_file`] sniffs v1/v2/v3/mc1/rk1/hy1), so no family
/// flag is required; `--multiclass` is still accepted for backwards
/// compatibility but the file wins. `--sparse` keeps selecting the
/// CSR dataset loader (a data-layout choice, not a model trait).
pub fn predict(args: &Args) -> Result<i32> {
    let model_path: String = args.require("model")?;
    // Validate (but do not act on) a legacy --multiclass value so
    // `--multiclass tournament` still errors rather than being
    // silently swallowed.
    multiclass_mode(args)?;
    let model = Predictor::load_file(&model_path)?;
    let spec = backend_spec(args)?;
    let mut backend = spec.instantiate()?;
    let sparse = args.flag("sparse");
    let multiclass = matches!(model, Predictor::Multiclass(_));
    let err = match (multiclass, sparse) {
        (false, false) => {
            let ds = load_dataset(args)?;
            model.error(backend.as_mut(), &TrainSet::from(&ds))?
        }
        (false, true) => {
            let ds = load_sparse_dataset(args)?;
            model.error(backend.as_mut(), &TrainSet::from(&ds))?
        }
        (true, false) => {
            let ds = load_multiclass_dataset(args)?;
            model.error(backend.as_mut(), &TrainSet::from(&ds))?
        }
        (true, true) => {
            let ds = load_sparse_multiclass_dataset(args)?;
            model.error(backend.as_mut(), &TrainSet::from(&ds))?
        }
    };
    println!(
        "model={model_path} family={} classes={} n_expansion={} error={err:.4}",
        model.family(),
        model.n_classes(),
        model.n_expansion()
    );
    Ok(0)
}

/// `dsekl serve` — load the model once (any format, sniffed), then
/// host it over TCP (or stdio with `--stdio`) until killed. The
/// banner goes to stderr so the stdio protocol owns stdout.
pub fn serve(args: &Args) -> Result<i32> {
    let model_path: String = args.require("model")?;
    let scorer_threads: usize = args.get_or("scorer-threads", 1)?;
    if scorer_threads == 0 {
        return Err(Error::invalid(
            "--scorer-threads must be at least 1 — a server with no scorers answers nothing",
        ));
    }
    let opts = ServeOpts {
        backend: backend_spec(args)?,
        max_batch_rows: args.get_or("max-batch-rows", 256)?,
        max_wait: Duration::from_micros(args.get_or("max-wait-us", 1000)?),
        scorer_threads,
        max_queue_rows: args.get_or("max-queue-rows", 4096)?,
        request_timeout: Duration::from_millis(args.get_or("request-timeout-ms", 10_000)?),
    };
    let server = Server::new(&model_path, opts)?;
    eprintln!("serving {model_path}: {}", server.describe_model());
    if args.flag("stdio") {
        let scorers = server.spawn_scorers();
        let res = server.serve_stdio();
        server.shutdown();
        for scorer in scorers {
            let _ = scorer.join();
        }
        res?;
        return Ok(0);
    }
    let addr = args.get("addr").unwrap_or("127.0.0.1:7878");
    let handle = server.spawn_tcp(addr)?;
    eprintln!("listening on {}", handle.addr());
    handle.join();
    Ok(0)
}

/// `dsekl gridsearch`
pub fn gridsearch(args: &Args) -> Result<i32> {
    let ds = load_dataset(args)?;
    let mut backend = FitBackend::new(backend_spec(args)?);
    let folds: usize = args.get_or("folds", 2)?;
    let seed: u64 = args.get_or("seed", 42)?;
    let base = DseklOpts {
        i_size: args.get_or("isize", 64)?,
        j_size: args.get_or("jsize", 64)?,
        max_iters: args.get_or("iters", 300)?,
        ..Default::default()
    };
    let grid = if args.flag("full-grid") {
        GridSpec::paper_full()
    } else {
        GridSpec::default()
    };
    let res = grid_search_dsekl(&mut backend, &ds, &base, &grid, folds, seed)?;
    println!(
        "best: gamma={} lam={} eta0={} cv_error={:.4} ({} candidates)",
        res.best.gamma,
        res.best.lam,
        res.best.eta0,
        res.best_cv_error,
        res.all.len()
    );
    Ok(0)
}

/// `dsekl info`
pub fn info(args: &Args) -> Result<i32> {
    let dir = std::path::PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let manifest = crate::runtime::manifest::Manifest::load(&dir)?;
    println!("artifacts in {}:", dir.display());
    for a in manifest.artifacts() {
        println!(
            "  {:30} kind={:?} rows={} cols={} d={}",
            a.name, a.kind, a.rows, a.cols, a.d
        );
    }
    println!("total: {}", manifest.artifacts().len());
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn load_dataset_synthetic() {
        let a = Args::parse(&argv("train --dataset xor --n 50")).unwrap();
        let ds = load_dataset(&a).unwrap();
        assert_eq!(ds.len(), 50);
        assert_eq!(ds.d, 2);
    }

    #[test]
    fn load_dataset_unknown_name() {
        let a = Args::parse(&argv("train --dataset nope")).unwrap();
        assert!(load_dataset(&a).is_err());
    }

    #[test]
    fn load_dataset_scaled() {
        let a = Args::parse(&argv("train --dataset diabetes --n 200 --scale")).unwrap();
        let ds = load_dataset(&a).unwrap();
        // Standardised columns have ~zero mean.
        let col0: f64 = (0..ds.len()).map(|i| ds.row(i)[0] as f64).sum::<f64>() / ds.len() as f64;
        assert!(col0.abs() < 0.2);
    }

    #[test]
    fn train_dsekl_end_to_end() {
        let a = Args::parse(&argv(
            "train --dataset xor --n 100 --solver dsekl --iters 200 --isize 32 --jsize 32",
        ))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
    }

    #[test]
    fn train_rejects_unknown_solver() {
        let a = Args::parse(&argv("train --dataset xor --n 40 --solver magic")).unwrap();
        assert!(train(&a).is_err());
    }

    #[test]
    fn unknown_solver_error_is_identical_across_modes() {
        // The dedupe pin: binary, multiclass and sparse runs must all
        // report an unknown --solver with the same structured error
        // (SolverKind::parse is the one place it is constructed).
        let binary = train(
            &Args::parse(&argv("train --dataset xor --n 40 --solver magic")).unwrap(),
        )
        .unwrap_err()
        .to_string();
        let multi = train(
            &Args::parse(&argv("train --multiclass ovr --n 40 --solver magic")).unwrap(),
        )
        .unwrap_err()
        .to_string();
        let sparse = train(
            &Args::parse(&argv("train --sparse --n 40 --solver magic")).unwrap(),
        )
        .unwrap_err()
        .to_string();
        assert_eq!(binary, multi);
        assert_eq!(binary, sparse);
        assert!(binary.contains("unknown solver 'magic'"), "{binary}");
    }

    #[test]
    fn train_rejects_unknown_loss_and_mode() {
        let a = Args::parse(&argv("train --dataset xor --n 40 --loss focal")).unwrap();
        assert!(train(&a).is_err());
        let a = Args::parse(&argv("train --multiclass tournament")).unwrap();
        assert!(train(&a).is_err());
        // Non-DSEKL solvers are rejected in multiclass mode, not ignored.
        let a = Args::parse(&argv("train --multiclass ovr --solver batch --n 40")).unwrap();
        assert!(train(&a).is_err());
        let a = Args::parse(&argv("train --multiclass ovr --solver online --n 40")).unwrap();
        assert!(train(&a).is_err());
    }

    #[test]
    fn train_each_loss_end_to_end() {
        for loss in ["hinge", "squared-hinge", "logistic", "ridge"] {
            let a = Args::parse(&argv(&format!(
                "train --dataset xor --n 80 --loss {loss} --iters 150 --isize 16 --jsize 16 --eta0 0.3"
            )))
            .unwrap();
            assert_eq!(train(&a).unwrap(), 0, "loss {loss}");
        }
    }

    #[test]
    fn train_multiclass_ovr_end_to_end() {
        let a = Args::parse(&argv(
            "train --multiclass ovr --loss logistic --n 160 --classes 4 --iters 200 --isize 16 --jsize 16",
        ))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
    }

    #[test]
    fn train_multiclass_parallel_end_to_end() {
        let a = Args::parse(&argv(
            "train --multiclass ovr --solver parallel --n 120 --classes 3 \
             --epochs 5 --workers 2 --isize 16 --jsize 16",
        ))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
    }

    #[test]
    fn train_parallel_sharded_socket_end_to_end() {
        // The full flag surface of the message-passing engine: worker-
        // hosted coefficient shards over the framed socket transport.
        let a = Args::parse(&argv(
            "train --solver parallel --n 120 --epochs 4 --workers 2 \
             --shards 2 --coord-transport socket --isize 16 --jsize 16",
        ))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
        // And the in-process default with leader-applied updates.
        let a = Args::parse(&argv(
            "train --solver parallel --n 120 --epochs 4 --workers 2 \
             --shards 3 --coord-transport channel --isize 16 --jsize 16",
        ))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
        // An unknown transport is a parse error, not a silent default.
        let a = Args::parse(&argv(
            "train --solver parallel --n 40 --coord-transport carrier-pigeon",
        ))
        .unwrap();
        assert!(train(&a).is_err());
    }

    #[test]
    fn train_online_end_to_end_dense_and_sparse() {
        let a = Args::parse(&argv(
            "train --solver online --dataset xor --n 200 --budget 64 --chunk 8",
        ))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
        let a = Args::parse(&argv(
            "train --solver online --sparse --dataset sparse --n 160 --dim 60 \
             --budget 48 --chunk 8 --gamma 0.05",
        ))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
    }

    #[test]
    fn rks_save_predict_roundtrip() {
        // RKS models save as DSEKLrk1 primal weights and predict
        // flag-free like every other family (they used to be a --save
        // no-op; that gap is closed).
        let dir = std::env::temp_dir().join("dsekl_cli_rks_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rks.dsekl");
        let a = Args::parse(&argv(&format!(
            "train --solver rks --dataset xor --n 120 --iters 300 --features 64 --save {}",
            path.display()
        )))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
        assert!(path.exists(), "rks run must write a model file now");
        let p = Args::parse(&argv(&format!(
            "predict --model {} --dataset xor --n 60",
            path.display()
        )))
        .unwrap();
        assert_eq!(predict(&p).unwrap(), 0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn predict_dim_mismatch_is_a_clear_error() {
        // Scoring a d=8 dataset with a d=2 model must produce the
        // structured dim error, not a shape panic.
        let dir = std::env::temp_dir().join("dsekl_cli_dim_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("xor.dsekl");
        let a = Args::parse(&argv(&format!(
            "train --dataset xor --n 80 --iters 100 --isize 16 --jsize 16 --save {}",
            path.display()
        )))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
        let p = Args::parse(&argv(&format!(
            "predict --model {} --dataset diabetes --n 40",
            path.display()
        )))
        .unwrap();
        let err = predict(&p).unwrap_err().to_string();
        assert!(err.contains("dataset dim 8 != model dim 2"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn online_save_predict_roundtrip() {
        // The frozen reservoir is a regular kernel model file.
        let dir = std::env::temp_dir().join("dsekl_cli_online_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("online.dsekl");
        let a = Args::parse(&argv(&format!(
            "train --solver online --dataset xor --n 200 --budget 64 --save {}",
            path.display()
        )))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
        let p = Args::parse(&argv(&format!(
            "predict --model {} --dataset xor --n 60",
            path.display()
        )))
        .unwrap();
        assert_eq!(predict(&p).unwrap(), 0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn multiclass_save_predict_roundtrip() {
        let dir = std::env::temp_dir().join("dsekl_cli_mc_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mc.dsekl");
        let a = Args::parse(&argv(&format!(
            "train --multiclass ovr --n 120 --classes 3 --iters 150 --isize 16 --jsize 16 --save {}",
            path.display()
        )))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
        // Flag-free: the file's magic says multiclass, so predict
        // routes to the multiclass dataset loader on its own.
        let p = Args::parse(&argv(&format!(
            "predict --model {} --n 60 --classes 3",
            path.display()
        )))
        .unwrap();
        assert_eq!(predict(&p).unwrap(), 0);
        // The legacy --multiclass flag is tolerated (the file wins).
        let p = Args::parse(&argv(&format!(
            "predict --multiclass ovr --model {} --n 60 --classes 3",
            path.display()
        )))
        .unwrap();
        assert_eq!(predict(&p).unwrap(), 0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_multiclass_dataset_names() {
        let a = Args::parse(&argv("train --multiclass ovr --n 50 --classes 5")).unwrap();
        let ds = load_multiclass_dataset(&a).unwrap();
        assert_eq!(ds.n_classes, 5);
        assert_eq!(ds.len(), 50);
        let a = Args::parse(&argv("train --multiclass ovr --dataset covtype --n 40")).unwrap();
        assert_eq!(load_multiclass_dataset(&a).unwrap().n_classes, 7);
        let a = Args::parse(&argv("train --multiclass ovr --dataset sonar --n 40")).unwrap();
        assert!(load_multiclass_dataset(&a).is_err());
    }

    #[test]
    fn train_sparse_end_to_end_serial_and_parallel() {
        let a = Args::parse(&argv(
            "train --sparse --dataset sparse --n 160 --dim 80 --density 0.05 \
             --solver dsekl --iters 200 --isize 16 --jsize 16 --gamma 0.05 --eta0 0.5",
        ))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
        let a = Args::parse(&argv(
            "train --sparse --solver parallel --n 120 --dim 60 --epochs 5 \
             --workers 2 --isize 16 --jsize 16 --gamma 0.05",
        ))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
    }

    #[test]
    fn train_sparse_multiclass_both_solvers() {
        let a = Args::parse(&argv(
            "train --multiclass ovr --sparse --n 150 --classes 3 --dim 60 \
             --iters 150 --isize 16 --jsize 16 --gamma 0.05 --loss logistic",
        ))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
        let a = Args::parse(&argv(
            "train --multiclass ovr --sparse --solver parallel --n 120 \
             --classes 3 --dim 60 --epochs 4 --workers 2 --isize 16 --jsize 16 --gamma 0.05",
        ))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
    }

    #[test]
    fn sparse_rejects_unsupported_solver() {
        for solver in ["batch", "empfix", "rks"] {
            let a = Args::parse(&argv(&format!(
                "train --sparse --n 40 --solver {solver}"
            )))
            .unwrap();
            assert!(train(&a).is_err(), "--sparse --solver {solver} accepted");
        }
    }

    #[test]
    fn sparse_libsvm_train_save_predict_roundtrip() {
        // The acceptance path: libsvm file -> CSR train (with --scale,
        // exercising the center-free scaler) -> save -> sparse predict.
        let dir = std::env::temp_dir().join("dsekl_cli_sparse_test");
        std::fs::create_dir_all(&dir).unwrap();
        let data_path = dir.join("sparse.libsvm");
        let mut rng = crate::rng::Pcg64::seed_from(3);
        let ds = synth::sparse_binary(160, 80, 0.05, &mut rng);
        let f = std::fs::File::create(&data_path).unwrap();
        libsvm::write(&ds.to_dense(), f).unwrap();
        let model_path = dir.join("sparse.dsekl");
        let a = Args::parse(&argv(&format!(
            "train --sparse --scale --dataset libsvm:{} --iters 200 --isize 16 \
             --jsize 16 --gamma 0.05 --eta0 0.5 --save {}",
            data_path.display(),
            model_path.display()
        )))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
        let p = Args::parse(&argv(&format!(
            "predict --sparse --scale --model {} --dataset libsvm:{}",
            model_path.display(),
            data_path.display()
        )))
        .unwrap();
        assert_eq!(predict(&p).unwrap(), 0);
        std::fs::remove_file(&data_path).ok();
        std::fs::remove_file(&model_path).ok();
    }

    #[test]
    fn load_sparse_dataset_shapes() {
        let a = Args::parse(&argv(
            "train --sparse --dataset sparse --n 50 --dim 40 --density 0.1",
        ))
        .unwrap();
        let ds = load_sparse_dataset(&a).unwrap();
        assert_eq!(ds.len(), 50);
        assert_eq!(ds.d, 40);
        assert!(ds.sparsity() > 0.8, "sparsity {}", ds.sparsity());
        // Dense synthetic names convert to CSR losslessly.
        let a = Args::parse(&argv("train --sparse --dataset xor --n 30")).unwrap();
        let ds = load_sparse_dataset(&a).unwrap();
        assert_eq!(ds.len(), 30);
        assert_eq!(ds.d, 2);
        let m = Args::parse(&argv(
            "train --multiclass ovr --sparse --n 40 --classes 5 --dim 30",
        ))
        .unwrap();
        let ds = load_sparse_multiclass_dataset(&m).unwrap();
        assert_eq!(ds.n_classes, 5);
        assert_eq!(ds.len(), 40);
    }

    #[test]
    fn stream_end_to_end_every_named_source() {
        for source in ["blobs", "covtype", "abrupt", "rotate", "covshift"] {
            let a = Args::parse(&argv(&format!(
                "stream --source {source} --n 200 --dim 6 --budget 32 --chunk 8 \
                 --tail-features 16 --window 50"
            )))
            .unwrap();
            assert_eq!(stream(&a).unwrap(), 0, "source {source}");
        }
    }

    #[test]
    fn stream_rejects_unknown_source() {
        let a = Args::parse(&argv("stream --source tides --n 50")).unwrap();
        let e = stream(&a).unwrap_err().to_string();
        assert!(e.contains("unknown stream source 'tides'"), "{e}");
    }

    #[test]
    fn stream_save_predict_roundtrip_hybrid_and_budget_only() {
        let dir = std::env::temp_dir().join("dsekl_cli_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        // With a tail: the file is a DSEKLhy1 hybrid, predict sniffs it.
        let path = dir.join("hybrid.dsekl");
        let a = Args::parse(&argv(&format!(
            "stream --source blobs --n 200 --dim 2 --budget 32 --tail-features 16 --save {}",
            path.display()
        )))
        .unwrap();
        assert_eq!(stream(&a).unwrap(), 0);
        let p = Args::parse(&argv(&format!(
            "predict --model {} --dataset xor --n 60",
            path.display()
        )))
        .unwrap();
        assert_eq!(predict(&p).unwrap(), 0);
        // Tail off: a plain kernel model file.
        let path2 = dir.join("budget_only.dsekl");
        let a = Args::parse(&argv(&format!(
            "stream --source blobs --n 200 --dim 2 --budget 32 --tail-features 0 --save {}",
            path2.display()
        )))
        .unwrap();
        assert_eq!(stream(&a).unwrap(), 0);
        let p = Args::parse(&argv(&format!(
            "predict --model {} --dataset xor --n 60",
            path2.display()
        )))
        .unwrap();
        assert_eq!(predict(&p).unwrap(), 0);
        std::fs::remove_file(path).ok();
        std::fs::remove_file(path2).ok();
    }

    #[test]
    fn train_solver_stream_dense_and_sparse() {
        let a = Args::parse(&argv(
            "train --solver stream --dataset xor --n 200 --budget 48 --chunk 8 \
             --evict-every 2 --tail-features 16",
        ))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
        let a = Args::parse(&argv(
            "train --solver stream --sparse --dataset sparse --n 160 --dim 60 \
             --budget 48 --chunk 8 --gamma 0.05 --tail-features 0",
        ))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
    }

    #[test]
    fn train_save_and_predict_roundtrip() {
        let dir = std::env::temp_dir().join("dsekl_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.dsekl");
        let a = Args::parse(&argv(&format!(
            "train --dataset xor --n 80 --iters 150 --isize 16 --jsize 16 --save {}",
            path.display()
        )))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
        let p = Args::parse(&argv(&format!(
            "predict --model {} --dataset xor --n 60",
            path.display()
        )))
        .unwrap();
        assert_eq!(predict(&p).unwrap(), 0);
        std::fs::remove_file(path).ok();
    }
}
