//! Subcommand implementations for the `dsekl` binary.

use std::sync::Arc;

use super::Args;
use crate::data::{
    libsvm, synth, Dataset, MultiDataset, Scaler, SparseDataset, SparseMultiDataset,
};
use crate::coordinator::{ParallelDsekl, ParallelOpts};
use crate::hyper::{grid_search_dsekl, GridSpec};
use crate::loss::Loss;
use crate::model::{KernelModel, MulticlassModel};
use crate::rng::Pcg64;
use crate::runtime::BackendSpec;
use crate::solver::batch::{BatchOpts, BatchSvm};
use crate::solver::dsekl::{DseklOpts, DseklSolver};
use crate::solver::empfix::{EmpFixOpts, EmpFixSolver};
use crate::solver::ovr::{OvrOpts, OvrSolver};
use crate::solver::rks::{RksOpts, RksSolver};
use crate::solver::LrSchedule;
use crate::{Error, Result};

/// Top-level usage text.
pub const USAGE: &str = "\
dsekl — doubly stochastic empirical kernel learning

USAGE:
  dsekl <SUBCOMMAND> [OPTIONS]

SUBCOMMANDS:
  train        train a model
  predict      evaluate a saved model on a dataset
  gridsearch   exhaustive grid search with k-fold CV
  info         show AOT artifact manifest
  help         this text

COMMON OPTIONS:
  --dataset <name|libsvm:PATH>   xor|covtype|blobs|mnist|diabetes|
                                 breast-cancer|mushrooms|sonar|
                                 skin-nonskin|madelon, or libsvm:file
  --n <N>                        synthetic dataset size   [1000]
  --seed <S>                     RNG seed                 [42]
  --backend <native|pjrt[:dir]>  compute backend          [native]
  --scale                        standardise features
  --sparse                       CSR data path: libsvm files parse
                                 straight to CSR, training/prediction
                                 run the O(nnz) sparse kernel path, and
                                 saved models keep CSR expansion rows
                                 (DSEKLv3 — file size scales with nnz)
                                 (solvers dsekl|parallel; --scale
                                 becomes center-free variance scaling)
  --dim <d> / --density <p>      shape of the `sparse` synthetic
                                 generator                [200 / 0.05]

TRAIN OPTIONS:
  --solver <dsekl|parallel|batch|empfix|rks>              [dsekl]
  --loss <hinge|squared-hinge|logistic|ridge>             [hinge]
  --multiclass <ovr>             one-vs-rest over K classes
  --classes <k>                  synthetic class count    [4]
  --gamma/--lam/--eta0 <f>       hyper-parameters
  --isize/--jsize <n>            sample sizes |I|, |J|    [64]
  --iters <n>                    iteration cap            [2000]
  --epochs <n>                   epoch cap (parallel)     [20]
  --workers <k>                  worker threads (parallel)[4]
  --round-batches <g>            batches per round        [=workers]
  --tol <f>                      epoch-change tolerance   [0]
  --features <r>                 RKS feature count        [=jsize]
  --subset <m>                   EmpFix subset size       [=jsize]
  --train-frac <f>               train split fraction     [0.5]
  --save <path>                  write model file

MULTICLASS:
  `--multiclass ovr` trains K one-vs-rest DSEKL heads that share one
  doubly stochastic sampling schedule: each step computes one |I|x|J|
  kernel block and steps all K heads against it (fused multi-head
  path), and the saved model stores the expansion rows once for all K
  coefficient vectors (DSEKLv2; legacy files still load). Datasets:
  blobs (default; K from --classes), covtype (always 7-class), or
  libsvm:PATH with integer class labels. --solver dsekl (serial) and
  parallel (fused K-head coordinator) apply; all --loss values work on
  the native backend.
";

/// Load the dataset selected by `--dataset` / `--n` / `--seed`.
pub fn load_dataset(args: &Args) -> Result<Dataset> {
    let name = args.get("dataset").unwrap_or("xor");
    let n: usize = args.get_or("n", 1000)?;
    let seed: u64 = args.get_or("seed", 42)?;
    let mut rng = Pcg64::with_stream(seed, 0xDA7A);
    let mut ds = if let Some(path) = name.strip_prefix("libsvm:") {
        libsvm::read_file(path, None, Default::default())?
    } else {
        synth::by_name(name, n, &mut rng)
            .ok_or_else(|| Error::invalid(format!("unknown dataset '{name}'")))?
    };
    if args.flag("scale") {
        let scaler = Scaler::fit(&ds);
        scaler.transform(&mut ds);
    }
    Ok(ds)
}

fn backend_spec(args: &Args) -> Result<BackendSpec> {
    BackendSpec::parse(args.get("backend").unwrap_or("native"), "artifacts")
}

/// Serial DSEKL options from the shared CLI flags — one builder for
/// the dense and sparse paths (binary and per-OvR-head), so a new flag
/// wired here applies everywhere and defaults cannot drift.
fn dsekl_opts_from(args: &Args, loss: Loss) -> Result<DseklOpts> {
    Ok(DseklOpts {
        gamma: args.get_or("gamma", 1.0)?,
        lam: args.get_or("lam", 1e-4)?,
        i_size: args.get_or("isize", 64)?,
        j_size: args.get_or("jsize", 64)?,
        lr: LrSchedule::InvT {
            eta0: args.get_or("eta0", 1.0)?,
        },
        max_iters: args.get_or("iters", 2000)?,
        tol: args.get_or("tol", 0.0)?,
        loss,
        ..Default::default()
    })
}

/// Parallel-coordinator options from the shared CLI flags — one
/// builder for all four train paths (dense/sparse × binary/multi).
fn parallel_opts_from(args: &Args, loss: Loss) -> Result<ParallelOpts> {
    Ok(ParallelOpts {
        gamma: args.get_or("gamma", 1.0)?,
        lam: args.get_or("lam", 1e-4)?,
        i_size: args.get_or("isize", 64)?,
        j_size: args.get_or("jsize", 64)?,
        workers: args.get_or("workers", 4)?,
        max_epochs: args.get_or("epochs", 20)?,
        tol: args.get_or("tol", 0.0)?,
        eta0: args.get_or("eta0", 1.0)?,
        loss,
        round_batches: args.get_or("round-batches", 0)?,
        ..Default::default()
    })
}

/// Load the dataset selected by `--dataset` as **CSR**. `libsvm:PATH`
/// parses straight to CSR (no dense round-trip); synthetic names are
/// generated dense and converted (plus the dedicated `sparse` name for
/// a genuinely high-sparsity generator). `--scale` applies the
/// center-free variance scaling (CSR-safe; see [`Scaler::fit_sparse`]).
pub fn load_sparse_dataset(args: &Args) -> Result<SparseDataset> {
    let name = args.get("dataset").unwrap_or("sparse");
    let n: usize = args.get_or("n", 1000)?;
    let seed: u64 = args.get_or("seed", 42)?;
    let density: f64 = args.get_or("density", 0.05)?;
    let mut rng = Pcg64::with_stream(seed, 0xDA7A);
    let mut ds = if let Some(path) = name.strip_prefix("libsvm:") {
        libsvm::read_sparse_file(path, None, Default::default())?
    } else if name == "sparse" {
        synth::sparse_binary(n, args.get_or("dim", 200)?, density, &mut rng)
    } else {
        let dense = synth::by_name(name, n, &mut rng)
            .ok_or_else(|| Error::invalid(format!("unknown dataset '{name}'")))?;
        SparseDataset::from_dense(&dense)
    };
    if args.flag("scale") {
        let scaler = Scaler::fit_sparse(&ds);
        scaler.transform_sparse(&mut ds);
    }
    Ok(ds)
}

/// Multiclass twin of [`load_sparse_dataset`] (`sparse` generates the
/// K-class high-sparsity set; K from `--classes`).
pub fn load_sparse_multiclass_dataset(args: &Args) -> Result<SparseMultiDataset> {
    let name = args.get("dataset").unwrap_or("sparse");
    let n: usize = args.get_or("n", 1000)?;
    let k: usize = args.get_or("classes", 4)?;
    let seed: u64 = args.get_or("seed", 42)?;
    let density: f64 = args.get_or("density", 0.05)?;
    let mut rng = Pcg64::with_stream(seed, 0xDA7A);
    let mut ds = if let Some(path) = name.strip_prefix("libsvm:") {
        libsvm::read_sparse_multiclass_file(path, None)?
    } else if name == "sparse" {
        synth::sparse_multiclass(n, k.max(2), args.get_or("dim", 200)?, density, &mut rng)
    } else {
        let dense = synth::multi_by_name(name, n, k, &mut rng).ok_or_else(|| {
            Error::invalid(format!(
                "dataset '{name}' has no multiclass generator \
                 (expected sparse|blobs|covtype|libsvm:PATH)"
            ))
        })?;
        SparseMultiDataset::from_dense(&dense)
    };
    if args.flag("scale") {
        let scaler = Scaler::fit_sparse_multi(&ds);
        scaler.transform_sparse_multi(&mut ds);
    }
    Ok(ds)
}

/// Load the multiclass dataset selected by `--dataset` / `--n` /
/// `--classes` / `--seed` (default: the K-class blob ring).
pub fn load_multiclass_dataset(args: &Args) -> Result<MultiDataset> {
    let name = args.get("dataset").unwrap_or("blobs");
    let n: usize = args.get_or("n", 1000)?;
    let k: usize = args.get_or("classes", 4)?;
    let seed: u64 = args.get_or("seed", 42)?;
    let mut rng = Pcg64::with_stream(seed, 0xDA7A);
    let mut ds = if let Some(path) = name.strip_prefix("libsvm:") {
        libsvm::read_multiclass_file(path, None)?
    } else {
        synth::multi_by_name(name, n, k, &mut rng).ok_or_else(|| {
            Error::invalid(format!(
                "dataset '{name}' has no multiclass generator \
                 (expected blobs|covtype|libsvm:PATH)"
            ))
        })?
    };
    if args.flag("scale") {
        let scaler = Scaler::fit_multi(&ds);
        scaler.transform_multi(&mut ds);
    }
    Ok(ds)
}

/// The `--multiclass` mode, if requested (`--multiclass` alone means
/// `ovr`, the only mode so far).
fn multiclass_mode(args: &Args) -> Result<Option<&str>> {
    match args.get("multiclass") {
        Some("ovr") => Ok(Some("ovr")),
        Some(other) => Err(Error::invalid(format!(
            "unknown multiclass mode '{other}' (expected ovr)"
        ))),
        None if args.flag("multiclass") => Ok(Some("ovr")),
        None => Ok(None),
    }
}

/// `dsekl train --multiclass ovr --sparse`: fused K-head training over
/// CSR rows, serial ([`OvrSolver::train_sparse`]) or parallel
/// ([`ParallelDsekl::train_multi_sparse`]).
fn train_multiclass_sparse(args: &Args, solver: &str) -> Result<i32> {
    let seed: u64 = args.get_or("seed", 42)?;
    let ds = load_sparse_multiclass_dataset(args)?;
    let train_frac: f64 = args.get_or("train-frac", 0.5)?;
    let mut rng = Pcg64::seed_from(seed);
    let (train, test) = ds.split(train_frac, &mut rng);
    let train = Arc::new(train);
    let spec = backend_spec(args)?;
    let mut backend = spec.instantiate()?;
    let loss: Loss = args.get_or("loss", Loss::Hinge)?;

    let model = match solver {
        "parallel" => {
            let opts = parallel_opts_from(args, loss)?;
            let r = ParallelDsekl::new(opts).train_multi_sparse(&spec, &train, None, seed)?;
            println!(
                "# telemetry: rounds={} batches={} serial_fraction={:.4}",
                r.telemetry.rounds,
                r.telemetry.batches,
                r.telemetry.serial_fraction()
            );
            r.model
        }
        _ => {
            let opts = OvrOpts {
                inner: dsekl_opts_from(args, loss)?,
            };
            let res = OvrSolver::new(opts).train_sparse(backend.as_mut(), &train, &mut rng)?;
            for (c, s) in res.per_class.iter().enumerate() {
                println!(
                    "#   class {c}: iters={} points={} converged={}",
                    s.iterations, s.points_processed, s.converged
                );
            }
            res.model
        }
    };
    let train_err = model.error_sparse(backend.as_mut(), &train)?;
    let test_err = model.error_sparse(backend.as_mut(), &test)?;
    println!(
        "solver=ovr({solver}) loss={loss} backend={} sparse=csr classes={} \
         n_train={} sparsity={:.3} train_error={train_err:.4} test_error={test_err:.4}",
        backend.name(),
        model.n_classes(),
        train.len(),
        train.sparsity(),
    );
    if let Some(path) = args.get("save") {
        model.save_file(path)?;
        println!("multiclass model (DSEKLv3, shared CSR rows) written to {path}");
    }
    Ok(0)
}

/// `dsekl train --multiclass ovr`: fused K-head training (one kernel
/// block per step shared by all K one-vs-rest heads), serial
/// ([`OvrSolver`]) or parallel ([`ParallelDsekl::train_multi`]).
fn train_multiclass(args: &Args) -> Result<i32> {
    // Both multiclass drivers step DSEKL machines; reject other
    // --solver choices instead of silently ignoring them.
    let solver = args.get("solver").unwrap_or("dsekl");
    if solver != "dsekl" && solver != "parallel" {
        return Err(Error::invalid(format!(
            "--multiclass ovr trains DSEKL machines; supported solvers \
             are dsekl|parallel, not {solver}"
        )));
    }
    if args.flag("sparse") {
        return train_multiclass_sparse(args, solver);
    }
    let seed: u64 = args.get_or("seed", 42)?;
    let ds = load_multiclass_dataset(args)?;
    let train_frac: f64 = args.get_or("train-frac", 0.5)?;
    let mut rng = Pcg64::seed_from(seed);
    let (train, test) = ds.split(train_frac, &mut rng);
    // Arc up front: the parallel coordinator shares the rows across
    // worker threads without another copy of the feature matrix.
    let train = Arc::new(train);
    let spec = backend_spec(args)?;
    let mut backend = spec.instantiate()?;
    let loss: Loss = args.get_or("loss", Loss::Hinge)?;

    let model = match solver {
        "parallel" => {
            let opts = parallel_opts_from(args, loss)?;
            let r = ParallelDsekl::new(opts).train_multi(&spec, &train, None, seed)?;
            println!(
                "# telemetry: rounds={} batches={} serial_fraction={:.4}",
                r.telemetry.rounds,
                r.telemetry.batches,
                r.telemetry.serial_fraction()
            );
            r.model
        }
        _ => {
            let opts = OvrOpts {
                inner: dsekl_opts_from(args, loss)?,
            };
            let res = OvrSolver::new(opts).train(backend.as_mut(), &train, &mut rng)?;
            for (c, s) in res.per_class.iter().enumerate() {
                println!(
                    "#   class {c}: iters={} points={} converged={}",
                    s.iterations, s.points_processed, s.converged
                );
            }
            res.model
        }
    };
    let train_err = model.error(backend.as_mut(), &train)?;
    let test_err = model.error(backend.as_mut(), &test)?;
    println!(
        "solver=ovr({solver}) loss={loss} backend={} classes={} n_train={} \
         train_error={train_err:.4} test_error={test_err:.4}",
        backend.name(),
        model.n_classes(),
        train.len(),
    );
    if let Some(path) = args.get("save") {
        model.save_file(path)?;
        println!("multiclass model (DSEKLv2, shared rows) written to {path}");
    }
    Ok(0)
}

/// `dsekl train --sparse`: binary CSR training, serial
/// ([`DseklSolver::train_sparse`]) or parallel
/// ([`ParallelDsekl::train_sparse`]); the CSR batches flow to the
/// backend's O(nnz) kernel path end-to-end.
fn train_sparse_binary(args: &Args) -> Result<i32> {
    let solver = args.get("solver").unwrap_or("dsekl");
    if solver != "dsekl" && solver != "parallel" {
        return Err(Error::invalid(format!(
            "--sparse supports --solver dsekl|parallel, not {solver} \
             (densify the data to use the other baselines)"
        )));
    }
    let seed: u64 = args.get_or("seed", 42)?;
    let ds = load_sparse_dataset(args)?;
    let train_frac: f64 = args.get_or("train-frac", 0.5)?;
    let mut rng = Pcg64::seed_from(seed);
    let (train, test) = ds.split(train_frac, &mut rng);
    let spec = backend_spec(args)?;
    let mut backend = spec.instantiate()?;
    let loss: Loss = args.get_or("loss", Loss::Hinge)?;

    let (model, n_iters): (KernelModel, u64) = match solver {
        "parallel" => {
            let opts = parallel_opts_from(args, loss)?;
            let r = ParallelDsekl::new(opts)
                .train_sparse(&spec, &Arc::new(train.clone()), None, seed)?;
            println!(
                "# telemetry: rounds={} batches={} serial_fraction={:.4}",
                r.telemetry.rounds,
                r.telemetry.batches,
                r.telemetry.serial_fraction()
            );
            (r.model, r.stats.iterations)
        }
        _ => {
            let opts = dsekl_opts_from(args, loss)?;
            let r = DseklSolver::new(opts).train_sparse(backend.as_mut(), &train, &mut rng)?;
            (r.model, r.stats.iterations)
        }
    };
    let train_err = model.error_sparse(backend.as_mut(), &train)?;
    let test_err = model.error_sparse(backend.as_mut(), &test)?;
    println!(
        "solver={solver} loss={loss} backend={} sparse=csr iters={n_iters} n_sv={} \
         sparsity={:.3} train_error={train_err:.4} test_error={test_err:.4}",
        backend.name(),
        model.n_support(1e-8),
        train.sparsity(),
    );
    if let Some(path) = args.get("save") {
        model.save_file(path)?;
        println!("model (DSEKLv3, CSR rows) written to {path}");
    }
    Ok(0)
}

/// `dsekl train`
pub fn train(args: &Args) -> Result<i32> {
    if multiclass_mode(args)?.is_some() {
        return train_multiclass(args);
    }
    if args.flag("sparse") {
        return train_sparse_binary(args);
    }
    let seed: u64 = args.get_or("seed", 42)?;
    let ds = load_dataset(args)?;
    let train_frac: f64 = args.get_or("train-frac", 0.5)?;
    let mut rng = Pcg64::seed_from(seed);
    let (train, test) = ds.split(train_frac, &mut rng);
    let spec = backend_spec(args)?;
    let mut backend = spec.instantiate()?;

    let gamma: f32 = args.get_or("gamma", 1.0)?;
    let lam: f32 = args.get_or("lam", 1e-4)?;
    let eta0: f32 = args.get_or("eta0", 1.0)?;
    let i_size: usize = args.get_or("isize", 64)?;
    let j_size: usize = args.get_or("jsize", 64)?;
    let iters: u64 = args.get_or("iters", 2000)?;
    let loss: Loss = args.get_or("loss", Loss::Hinge)?;
    let solver = args.get("solver").unwrap_or("dsekl");

    let dsekl_opts = dsekl_opts_from(args, loss)?;

    let (model, n_iters): (KernelModel, u64) = match solver {
        "dsekl" => {
            let r = DseklSolver::new(dsekl_opts).train(backend.as_mut(), &train, &mut rng)?;
            (r.model, r.stats.iterations)
        }
        "parallel" => {
            let opts = parallel_opts_from(args, loss)?;
            let r = ParallelDsekl::new(opts).train(&spec, &Arc::new(train.clone()), None, seed)?;
            println!(
                "# telemetry: rounds={} batches={} serial_fraction={:.4}",
                r.telemetry.rounds,
                r.telemetry.batches,
                r.telemetry.serial_fraction()
            );
            (r.model, r.stats.iterations)
        }
        "batch" => {
            let r = BatchSvm::new(BatchOpts {
                gamma,
                lam,
                max_iters: iters,
                loss,
                ..Default::default()
            })
            .train(backend.as_mut(), &train)?;
            (r.model, r.stats.iterations)
        }
        "empfix" => {
            let r = EmpFixSolver::new(EmpFixOpts {
                subset_size: args.get_or("subset", j_size)?,
                inner: dsekl_opts,
            })
            .train(backend.as_mut(), &train, &mut rng)?;
            (r.model, r.stats.iterations)
        }
        "rks" => {
            let r = RksSolver::new(RksOpts {
                gamma,
                lam,
                n_features: args.get_or("features", j_size)?,
                i_size,
                lr: LrSchedule::InvT { eta0 },
                max_iters: iters,
                loss,
            })
            .train(backend.as_mut(), &train, &mut rng)?;
            let train_err = r.model.error(backend.as_mut(), &train)?;
            let test_err = r.model.error(backend.as_mut(), &test)?;
            println!(
                "solver=rks loss={loss} backend={} iters={} train_error={train_err:.4} test_error={test_err:.4}",
                backend.name(),
                r.stats.iterations
            );
            return Ok(0); // RKS models are primal; no kernel-model save
        }
        other => return Err(Error::invalid(format!("unknown solver '{other}'"))),
    };

    let train_err = model.error(backend.as_mut(), &train)?;
    let test_err = model.error(backend.as_mut(), &test)?;
    println!(
        "solver={solver} loss={loss} backend={} iters={n_iters} n_sv={} train_error={train_err:.4} test_error={test_err:.4}",
        backend.name(),
        model.n_support(1e-8),
    );
    if let Some(path) = args.get("save") {
        model.save_file(path)?;
        println!("model written to {path}");
    }
    Ok(0)
}

/// `dsekl predict`
pub fn predict(args: &Args) -> Result<i32> {
    let model_path: String = args.require("model")?;
    let spec = backend_spec(args)?;
    let mut backend = spec.instantiate()?;
    let sparse = args.flag("sparse");
    if multiclass_mode(args)?.is_some() {
        let model = MulticlassModel::load_file(&model_path)?;
        let err = if sparse {
            let ds = load_sparse_multiclass_dataset(args)?;
            model.error_sparse(backend.as_mut(), &ds)?
        } else {
            let ds = load_multiclass_dataset(args)?;
            model.error(backend.as_mut(), &ds)?
        };
        println!(
            "model={model_path} classes={} error={err:.4}",
            model.n_classes()
        );
        return Ok(0);
    }
    let model = KernelModel::load_file(&model_path)?;
    let err = if sparse {
        let ds = load_sparse_dataset(args)?;
        model.error_sparse(backend.as_mut(), &ds)?
    } else {
        let ds = load_dataset(args)?;
        model.error(backend.as_mut(), &ds)?
    };
    println!(
        "model={model_path} n_expansion={} error={err:.4}",
        model.len()
    );
    Ok(0)
}

/// `dsekl gridsearch`
pub fn gridsearch(args: &Args) -> Result<i32> {
    let ds = load_dataset(args)?;
    let spec = backend_spec(args)?;
    let mut backend = spec.instantiate()?;
    let folds: usize = args.get_or("folds", 2)?;
    let seed: u64 = args.get_or("seed", 42)?;
    let base = DseklOpts {
        i_size: args.get_or("isize", 64)?,
        j_size: args.get_or("jsize", 64)?,
        max_iters: args.get_or("iters", 300)?,
        ..Default::default()
    };
    let grid = if args.flag("full-grid") {
        GridSpec::paper_full()
    } else {
        GridSpec::default()
    };
    let res = grid_search_dsekl(backend.as_mut(), &ds, &base, &grid, folds, seed)?;
    println!(
        "best: gamma={} lam={} eta0={} cv_error={:.4} ({} candidates)",
        res.best.gamma,
        res.best.lam,
        res.best.eta0,
        res.best_cv_error,
        res.all.len()
    );
    Ok(0)
}

/// `dsekl info`
pub fn info(args: &Args) -> Result<i32> {
    let dir = std::path::PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let manifest = crate::runtime::manifest::Manifest::load(&dir)?;
    println!("artifacts in {}:", dir.display());
    for a in manifest.artifacts() {
        println!(
            "  {:30} kind={:?} rows={} cols={} d={}",
            a.name, a.kind, a.rows, a.cols, a.d
        );
    }
    println!("total: {}", manifest.artifacts().len());
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn load_dataset_synthetic() {
        let a = Args::parse(&argv("train --dataset xor --n 50")).unwrap();
        let ds = load_dataset(&a).unwrap();
        assert_eq!(ds.len(), 50);
        assert_eq!(ds.d, 2);
    }

    #[test]
    fn load_dataset_unknown_name() {
        let a = Args::parse(&argv("train --dataset nope")).unwrap();
        assert!(load_dataset(&a).is_err());
    }

    #[test]
    fn load_dataset_scaled() {
        let a = Args::parse(&argv("train --dataset diabetes --n 200 --scale")).unwrap();
        let ds = load_dataset(&a).unwrap();
        // Standardised columns have ~zero mean.
        let col0: f64 = (0..ds.len()).map(|i| ds.row(i)[0] as f64).sum::<f64>() / ds.len() as f64;
        assert!(col0.abs() < 0.2);
    }

    #[test]
    fn train_dsekl_end_to_end() {
        let a = Args::parse(&argv(
            "train --dataset xor --n 100 --solver dsekl --iters 200 --isize 32 --jsize 32",
        ))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
    }

    #[test]
    fn train_rejects_unknown_solver() {
        let a = Args::parse(&argv("train --dataset xor --n 40 --solver magic")).unwrap();
        assert!(train(&a).is_err());
    }

    #[test]
    fn train_rejects_unknown_loss_and_mode() {
        let a = Args::parse(&argv("train --dataset xor --n 40 --loss focal")).unwrap();
        assert!(train(&a).is_err());
        let a = Args::parse(&argv("train --multiclass tournament")).unwrap();
        assert!(train(&a).is_err());
        // Non-DSEKL solvers are rejected in multiclass mode, not ignored.
        let a = Args::parse(&argv("train --multiclass ovr --solver batch --n 40")).unwrap();
        assert!(train(&a).is_err());
    }

    #[test]
    fn train_each_loss_end_to_end() {
        for loss in ["hinge", "squared-hinge", "logistic", "ridge"] {
            let a = Args::parse(&argv(&format!(
                "train --dataset xor --n 80 --loss {loss} --iters 150 --isize 16 --jsize 16 --eta0 0.3"
            )))
            .unwrap();
            assert_eq!(train(&a).unwrap(), 0, "loss {loss}");
        }
    }

    #[test]
    fn train_multiclass_ovr_end_to_end() {
        let a = Args::parse(&argv(
            "train --multiclass ovr --loss logistic --n 160 --classes 4 --iters 200 --isize 16 --jsize 16",
        ))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
    }

    #[test]
    fn train_multiclass_parallel_end_to_end() {
        let a = Args::parse(&argv(
            "train --multiclass ovr --solver parallel --n 120 --classes 3 \
             --epochs 5 --workers 2 --isize 16 --jsize 16",
        ))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
    }

    #[test]
    fn multiclass_save_predict_roundtrip() {
        let dir = std::env::temp_dir().join("dsekl_cli_mc_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mc.dsekl");
        let a = Args::parse(&argv(&format!(
            "train --multiclass ovr --n 120 --classes 3 --iters 150 --isize 16 --jsize 16 --save {}",
            path.display()
        )))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
        let p = Args::parse(&argv(&format!(
            "predict --multiclass ovr --model {} --n 60 --classes 3",
            path.display()
        )))
        .unwrap();
        assert_eq!(predict(&p).unwrap(), 0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_multiclass_dataset_names() {
        let a = Args::parse(&argv("train --multiclass ovr --n 50 --classes 5")).unwrap();
        let ds = load_multiclass_dataset(&a).unwrap();
        assert_eq!(ds.n_classes, 5);
        assert_eq!(ds.len(), 50);
        let a = Args::parse(&argv("train --multiclass ovr --dataset covtype --n 40")).unwrap();
        assert_eq!(load_multiclass_dataset(&a).unwrap().n_classes, 7);
        let a = Args::parse(&argv("train --multiclass ovr --dataset sonar --n 40")).unwrap();
        assert!(load_multiclass_dataset(&a).is_err());
    }

    #[test]
    fn train_sparse_end_to_end_serial_and_parallel() {
        let a = Args::parse(&argv(
            "train --sparse --dataset sparse --n 160 --dim 80 --density 0.05 \
             --solver dsekl --iters 200 --isize 16 --jsize 16 --gamma 0.05 --eta0 0.5",
        ))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
        let a = Args::parse(&argv(
            "train --sparse --solver parallel --n 120 --dim 60 --epochs 5 \
             --workers 2 --isize 16 --jsize 16 --gamma 0.05",
        ))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
    }

    #[test]
    fn train_sparse_multiclass_both_solvers() {
        let a = Args::parse(&argv(
            "train --multiclass ovr --sparse --n 150 --classes 3 --dim 60 \
             --iters 150 --isize 16 --jsize 16 --gamma 0.05 --loss logistic",
        ))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
        let a = Args::parse(&argv(
            "train --multiclass ovr --sparse --solver parallel --n 120 \
             --classes 3 --dim 60 --epochs 4 --workers 2 --isize 16 --jsize 16 --gamma 0.05",
        ))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
    }

    #[test]
    fn sparse_rejects_unsupported_solver() {
        for solver in ["batch", "empfix", "rks"] {
            let a = Args::parse(&argv(&format!(
                "train --sparse --n 40 --solver {solver}"
            )))
            .unwrap();
            assert!(train(&a).is_err(), "--sparse --solver {solver} accepted");
        }
    }

    #[test]
    fn sparse_libsvm_train_save_predict_roundtrip() {
        // The acceptance path: libsvm file -> CSR train (with --scale,
        // exercising the center-free scaler) -> save -> sparse predict.
        let dir = std::env::temp_dir().join("dsekl_cli_sparse_test");
        std::fs::create_dir_all(&dir).unwrap();
        let data_path = dir.join("sparse.libsvm");
        let mut rng = crate::rng::Pcg64::seed_from(3);
        let ds = synth::sparse_binary(160, 80, 0.05, &mut rng);
        let f = std::fs::File::create(&data_path).unwrap();
        libsvm::write(&ds.to_dense(), f).unwrap();
        let model_path = dir.join("sparse.dsekl");
        let a = Args::parse(&argv(&format!(
            "train --sparse --scale --dataset libsvm:{} --iters 200 --isize 16 \
             --jsize 16 --gamma 0.05 --eta0 0.5 --save {}",
            data_path.display(),
            model_path.display()
        )))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
        let p = Args::parse(&argv(&format!(
            "predict --sparse --scale --model {} --dataset libsvm:{}",
            model_path.display(),
            data_path.display()
        )))
        .unwrap();
        assert_eq!(predict(&p).unwrap(), 0);
        std::fs::remove_file(&data_path).ok();
        std::fs::remove_file(&model_path).ok();
    }

    #[test]
    fn load_sparse_dataset_shapes() {
        let a = Args::parse(&argv(
            "train --sparse --dataset sparse --n 50 --dim 40 --density 0.1",
        ))
        .unwrap();
        let ds = load_sparse_dataset(&a).unwrap();
        assert_eq!(ds.len(), 50);
        assert_eq!(ds.d, 40);
        assert!(ds.sparsity() > 0.8, "sparsity {}", ds.sparsity());
        // Dense synthetic names convert to CSR losslessly.
        let a = Args::parse(&argv("train --sparse --dataset xor --n 30")).unwrap();
        let ds = load_sparse_dataset(&a).unwrap();
        assert_eq!(ds.len(), 30);
        assert_eq!(ds.d, 2);
        let m = Args::parse(&argv(
            "train --multiclass ovr --sparse --n 40 --classes 5 --dim 30",
        ))
        .unwrap();
        let ds = load_sparse_multiclass_dataset(&m).unwrap();
        assert_eq!(ds.n_classes, 5);
        assert_eq!(ds.len(), 40);
    }

    #[test]
    fn train_save_and_predict_roundtrip() {
        let dir = std::env::temp_dir().join("dsekl_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.dsekl");
        let a = Args::parse(&argv(&format!(
            "train --dataset xor --n 80 --iters 150 --isize 16 --jsize 16 --save {}",
            path.display()
        )))
        .unwrap();
        assert_eq!(train(&a).unwrap(), 0);
        let p = Args::parse(&argv(&format!(
            "predict --model {} --dataset xor --n 60",
            path.display()
        )))
        .unwrap();
        assert_eq!(predict(&p).unwrap(), 0);
        std::fs::remove_file(path).ok();
    }
}
