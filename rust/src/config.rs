//! Config-file substrate: a small `key = value` format with sections,
//! comments and typed accessors, so experiment setups can live in files
//! (`examples/*.toml`-style) instead of long CLI invocations.
//!
//! Grammar (a strict subset of TOML):
//!
//! ```text
//! # comment
//! [section]
//! key = value        # value: string | number | bool
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use crate::{Error, Result};

/// Parsed config: `section.key -> raw value string`. Keys outside any
/// section live under the empty section `""`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    entries: BTreeMap<String, String>,
}

impl Config {
    /// Parse config text.
    pub fn parse(text: &str) -> Result<Config> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| {
                    Error::parse(format!("line {}: unterminated section", lineno + 1))
                })?;
                if name.is_empty() {
                    return Err(Error::parse(format!("line {}: empty section", lineno + 1)));
                }
                section = name.trim().to_string();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                Error::parse(format!("line {}: expected 'key = value'", lineno + 1))
            })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(Error::parse(format!("line {}: empty key", lineno + 1)));
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let value = value.trim().trim_matches('"').to_string();
            if entries.insert(full.clone(), value).is_some() {
                return Err(Error::parse(format!(
                    "line {}: duplicate key '{full}'",
                    lineno + 1
                )));
            }
        }
        Ok(Config { entries })
    }

    /// Load from a file.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Config> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Raw string value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(String::as_str)
    }

    /// Typed accessor with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(raw) => raw.parse().map_err(|_| {
                Error::parse(format!(
                    "config key '{key}': cannot parse '{raw}' as {}",
                    std::any::type_name::<T>()
                ))
            }),
        }
    }

    /// Required typed accessor.
    pub fn require<T: std::str::FromStr>(&self, key: &str) -> Result<T> {
        let raw = self
            .get(key)
            .ok_or_else(|| Error::parse(format!("config key '{key}' missing")))?;
        raw.parse().map_err(|_| {
            Error::parse(format!(
                "config key '{key}': cannot parse '{raw}' as {}",
                std::any::type_name::<T>()
            ))
        })
    }

    /// All keys (sorted).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let cfg = Config::parse(
            r#"
            # run setup
            seed = 42
            [solver]
            gamma = 0.5       # rbf width
            lam = 1e-4
            backend = "native"
            verbose = true
            "#,
        )
        .unwrap();
        assert_eq!(cfg.require::<u64>("seed").unwrap(), 42);
        assert_eq!(cfg.require::<f32>("solver.gamma").unwrap(), 0.5);
        assert_eq!(cfg.require::<f32>("solver.lam").unwrap(), 1e-4);
        assert_eq!(cfg.get("solver.backend"), Some("native"));
        assert!(cfg.require::<bool>("solver.verbose").unwrap());
    }

    #[test]
    fn defaults_and_missing() {
        let cfg = Config::parse("a = 1").unwrap();
        assert_eq!(cfg.get_or::<u32>("nope", 7).unwrap(), 7);
        assert!(cfg.require::<u32>("nope").is_err());
        assert!(cfg.get_or::<u32>("a", 0).unwrap() == 1);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[unterminated").is_err());
        assert!(Config::parse("no_equals_sign").is_err());
        assert!(Config::parse("= value").is_err());
        assert!(Config::parse("a = 1\na = 2").is_err());
        assert!(Config::parse("[]").is_err());
    }

    #[test]
    fn hash_inside_quotes_kept() {
        let cfg = Config::parse(r##"name = "a#b""##).unwrap();
        assert_eq!(cfg.get("name"), Some("a#b"));
    }

    #[test]
    fn type_error_is_reported() {
        let cfg = Config::parse("x = abc").unwrap();
        let err = cfg.require::<f64>("x").unwrap_err();
        assert!(err.to_string().contains("abc"));
    }
}
