//! Sharded coefficient ownership (`--shards W`).
//!
//! In shard mode the AdaGrad state and the authoritative coefficient
//! blocks live **on the workers**, not the leader: the global `[K, n]`
//! slot grid (`slot = head * n + j`) is striped round-robin across W
//! shards (`owner(slot) = slot % W`), and shard `s` is hosted by
//! worker `s % workers`. Per round the leader ships each shard only
//! the `(slot, gradient)` sequence it owns ([`ShardUpdate`]) and gets
//! back only the dampened coefficient deltas ([`ShardDelta`]) — the
//! delta-exchange pattern of block-coordinate-descent sharding (Tu et
//! al.), simulated in-process first exactly as the ROADMAP prescribes.
//!
//! **Bitwise parity.** The leader builds every shard's sequence by
//! traversing the round's results in the *global order* (items by id,
//! heads major, batch positions minor) — the same order the unsharded
//! path applies gradients in. Restricting one traversal to each shard
//! preserves every slot's gradient subsequence, AdaGrad depends only
//! on per-slot history, and the leader merges the returned deltas back
//! in the same global traversal (per-shard cursors), so the replica
//! coefficients **and** the f64 epoch-change accumulation are
//! bit-for-bit identical to the leader-applied path — for any shard
//! count, any worker count, either transport. That invariant is pinned
//! in `rust/tests/coordinator_shard.rs`.
//!
//! The leader keeps a full replica of `alpha` (snapshot authority for
//! dispatch and validation, and the final model); the shards' blocks
//! are the same values striped by `slot % W`.

use crate::{Error, Result};

use super::adagrad::AdaGrad;
use super::protocol::{CoordMsg, ShardDelta, ShardUpdate, WorkResult};
use super::transport::WorkerPool;

/// One shard's worker-side state: the owned stripe of coefficients and
/// their AdaGrad accumulators, indexed locally by `slot / of` and
/// grown on first touch (each slot starts at `alpha = 0`, `G = 1`, so
/// materialisation order cannot affect values).
#[derive(Debug)]
pub(crate) struct ShardState {
    shard: usize,
    of: usize,
    g: AdaGrad,
    alpha: Vec<f32>,
}

impl ShardState {
    pub(crate) fn new(shard: usize, of: usize) -> Self {
        ShardState {
            shard,
            of,
            g: AdaGrad::new(0),
            alpha: Vec::new(),
        }
    }

    /// The shard id this state serves.
    pub(crate) fn shard(&self) -> usize {
        self.shard
    }

    /// The shard count this state was created under.
    pub(crate) fn of(&self) -> usize {
        self.of
    }

    /// Apply one round's owned gradient sequence: AdaGrad accumulate +
    /// dampened step per entry, in the order received (the leader's
    /// global traversal order), returning the deltas in that order.
    pub(crate) fn apply(&mut self, upd: &ShardUpdate) -> Result<ShardDelta> {
        if upd.shard != self.shard || upd.of != self.of {
            return Err(Error::Coordinator(format!(
                "shard update for {}/{} routed to shard {}/{}",
                upd.shard, upd.of, self.shard, self.of
            )));
        }
        if upd.slots.len() != upd.grads.len() {
            return Err(Error::Coordinator(format!(
                "shard update with {} slots but {} gradients",
                upd.slots.len(),
                upd.grads.len()
            )));
        }
        let mut deltas = Vec::with_capacity(upd.slots.len());
        for (&slot, &gv) in upd.slots.iter().zip(&upd.grads) {
            if slot % self.of != self.shard {
                return Err(Error::Coordinator(format!(
                    "slot {slot} is not owned by shard {}/{}",
                    self.shard, self.of
                )));
            }
            let local = slot / self.of;
            self.g.ensure(local + 1);
            if self.alpha.len() <= local {
                self.alpha.resize(local + 1, 0.0);
            }
            self.g.accumulate(local, gv);
            let delta = self.g.step(local, upd.eta, gv);
            let a = self
                .alpha
                .get_mut(local)
                .ok_or_else(|| Error::Coordinator("shard slot vanished after resize".into()))?;
            *a -= delta;
            deltas.push(delta);
        }
        Ok(ShardDelta {
            shard: self.shard,
            deltas,
        })
    }
}

/// Validate one round's results before any state is touched: exactly
/// the dispatched item ids (sorted, no duplicates, no gaps), every
/// expansion index inside the grid, every gradient block shaped
/// `[k, |jj|]`. Results arrive over a wire on the socket transport, so
/// these are real protocol checks, not assertions.
pub(crate) fn check_round(results: &[WorkResult], dispatched: usize, k: usize, n: usize) -> Result<()> {
    if results.len() != dispatched {
        return Err(Error::Coordinator(format!(
            "round barrier collected {} results for {dispatched} items",
            results.len()
        )));
    }
    for (want, r) in results.iter().enumerate() {
        if r.item != want {
            return Err(Error::Coordinator(format!(
                "protocol violation: round results carry item {} where {want} was expected \
                 (duplicate or missing delta)",
                r.item
            )));
        }
        if r.jj.is_empty() {
            return Err(Error::Coordinator(
                "protocol violation: result with an empty expansion batch".into(),
            ));
        }
        if r.g.len() != k * r.jj.len() {
            return Err(Error::Coordinator(format!(
                "protocol violation: gradient block of {} values for {} heads x {} indices",
                r.g.len(),
                k,
                r.jj.len()
            )));
        }
        if let Some(&bad) = r.jj.iter().find(|&&j| j >= n) {
            return Err(Error::Coordinator(format!(
                "protocol violation: expansion index {bad} outside the {n}-point grid"
            )));
        }
    }
    Ok(())
}

/// How a round's gradients become coefficient updates: applied by the
/// leader against its own AdaGrad state (the classic path), or shipped
/// to the owning shards and merged back from their deltas.
pub(crate) enum RoundApplier {
    /// Leader-applied updates over the full `[K, n]` grid.
    Local(AdaGrad),
    /// Shard-applied updates, `shards` stripes over the same grid.
    Sharded {
        /// Shard count W (> 0).
        shards: usize,
    },
}

impl RoundApplier {
    /// `shards == 0` selects the leader-applied path over a `slots`
    /// sized grid; any positive count stripes that grid.
    pub(crate) fn new(shards: usize, slots: usize) -> Self {
        if shards == 0 {
            RoundApplier::Local(AdaGrad::new(slots))
        } else {
            RoundApplier::Sharded { shards }
        }
    }

    /// Apply one validated round (see [`check_round`]) to the leader's
    /// `alpha` replica, returning the round's contribution to the
    /// epoch-change squared norm. Both arms traverse results in the
    /// same global order, so they are bitwise interchangeable.
    pub(crate) fn apply(
        &mut self,
        pool: &mut WorkerPool,
        results: &[WorkResult],
        k: usize,
        n: usize,
        eta: f32,
        alpha: &mut [f32],
    ) -> Result<f64> {
        match self {
            RoundApplier::Local(adagrad) => apply_local(adagrad, results, k, n, eta, alpha),
            RoundApplier::Sharded { shards } => {
                apply_sharded(pool, *shards, results, k, n, eta, alpha)
            }
        }
    }
}

/// Walk one result's gradient block in head-major order, yielding the
/// global slot and gradient value per entry — the single definition of
/// the round's traversal order both appliers (and the shard-update
/// builder) share.
fn for_each_entry<F>(results: &[WorkResult], k: usize, n: usize, mut f: F) -> Result<()>
where
    F: FnMut(usize, f32) -> Result<()>,
{
    for r in results {
        let j_len = r.jj.len();
        for h in 0..k {
            let gh = r
                .g
                .get(h * j_len..(h + 1) * j_len)
                .ok_or_else(|| Error::Coordinator("gradient block shorter than declared".into()))?;
            for (&j, &gv) in r.jj.iter().zip(gh) {
                f(h * n + j, gv)?;
            }
        }
    }
    Ok(())
}

/// The leader-applied path (Algorithm 2 lines 11 & 14).
fn apply_local(
    adagrad: &mut AdaGrad,
    results: &[WorkResult],
    k: usize,
    n: usize,
    eta: f32,
    alpha: &mut [f32],
) -> Result<f64> {
    let mut change_sq = 0.0f64;
    for_each_entry(results, k, n, |slot, gv| {
        let a = alpha
            .get_mut(slot)
            .ok_or_else(|| Error::Coordinator(format!("slot {slot} outside the coefficient grid")))?;
        adagrad.accumulate(slot, gv);
        let delta = adagrad.step(slot, eta, gv);
        *a -= delta;
        change_sq += (delta as f64) * (delta as f64);
        Ok(())
    })?;
    Ok(change_sq)
}

/// The shard-applied path: build each shard's owned gradient sequence
/// in global order, exchange it for deltas, merge the deltas back in
/// the same order.
fn apply_sharded(
    pool: &mut WorkerPool,
    shards: usize,
    results: &[WorkResult],
    k: usize,
    n: usize,
    eta: f32,
    alpha: &mut [f32],
) -> Result<f64> {
    // Phase 1: per-shard (slot, gradient) sequences, global order.
    let mut updates: Vec<ShardUpdate> = (0..shards)
        .map(|s| ShardUpdate {
            shard: s,
            of: shards,
            eta,
            slots: Vec::new(),
            grads: Vec::new(),
        })
        .collect();
    for_each_entry(results, k, n, |slot, gv| {
        let u = updates
            .get_mut(slot % shards)
            .ok_or_else(|| Error::Coordinator("shard owner outside the stripe set".into()))?;
        u.slots.push(slot);
        u.grads.push(gv);
        Ok(())
    })?;

    // Phase 2: ship non-empty sequences to the hosting workers.
    let sizes: Vec<usize> = updates.iter().map(|u| u.slots.len()).collect();
    let workers = pool.workers();
    let mut pending = 0usize;
    for u in updates {
        if u.slots.is_empty() {
            continue;
        }
        let host = u.shard % workers;
        pool.send(host, &CoordMsg::ShardUpdate(u))?;
        pending += 1;
    }

    // Phase 3: collect every shard's deltas (death notices and stray
    // messages surface as precise errors, same as the round barrier).
    let mut deltas: Vec<Option<Vec<f32>>> = (0..shards).map(|_| None).collect();
    while pending > 0 {
        match pool.recv()? {
            CoordMsg::ShardDelta(d) => {
                let want = sizes.get(d.shard).copied().ok_or_else(|| {
                    Error::Coordinator(format!(
                        "protocol violation: delta from unknown shard {} of {shards}",
                        d.shard
                    ))
                })?;
                if d.deltas.len() != want {
                    return Err(Error::Coordinator(format!(
                        "protocol violation: shard {} returned {} deltas for {want} updates",
                        d.shard,
                        d.deltas.len()
                    )));
                }
                let slot = deltas.get_mut(d.shard).ok_or_else(|| {
                    Error::Coordinator("shard delta outside the stripe set".into())
                })?;
                if slot.is_some() {
                    return Err(Error::Coordinator(format!(
                        "protocol violation: duplicate delta from shard {}",
                        d.shard
                    )));
                }
                *slot = Some(d.deltas);
                pending -= 1;
            }
            CoordMsg::WorkerError { message, .. } => return Err(Error::Coordinator(message)),
            other => {
                return Err(Error::Coordinator(format!(
                    "protocol violation: unexpected {} while collecting shard deltas",
                    other.kind()
                )))
            }
        }
    }

    // Phase 4: merge in the same global order with per-shard cursors —
    // the replica update and the f64 change accumulation land in the
    // exact order of the leader-applied path.
    let mut cursors = vec![0usize; shards];
    let mut change_sq = 0.0f64;
    for_each_entry(results, k, n, |slot, _gv| {
        let s = slot % shards;
        let cur = cursors
            .get_mut(s)
            .ok_or_else(|| Error::Coordinator("shard cursor outside the stripe set".into()))?;
        let delta = deltas
            .get(s)
            .and_then(|d| d.as_ref())
            .and_then(|d| d.get(*cur))
            .copied()
            .ok_or_else(|| {
                Error::Coordinator(format!("shard {s} delta sequence exhausted early"))
            })?;
        *cur += 1;
        let a = alpha
            .get_mut(slot)
            .ok_or_else(|| Error::Coordinator(format!("slot {slot} outside the coefficient grid")))?;
        *a -= delta;
        change_sq += (delta as f64) * (delta as f64);
        Ok(())
    })?;
    Ok(change_sq)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_state_matches_global_adagrad() {
        // One shard owning every second slot must reproduce the global
        // accumulator's values on its stripe exactly.
        let mut global = AdaGrad::new(6);
        let mut alpha = vec![0.0f32; 6];
        let seq = [(0usize, 0.5f32), (2, -1.0), (0, 0.25), (4, 2.0)];
        let eta = 0.3;
        let mut expected = Vec::new();
        for &(slot, gv) in &seq {
            global.accumulate(slot, gv);
            let d = global.step(slot, eta, gv);
            alpha[slot] -= d;
            expected.push(d);
        }

        let mut shard = ShardState::new(0, 2);
        let upd = ShardUpdate {
            shard: 0,
            of: 2,
            eta,
            slots: seq.iter().map(|&(s, _)| s).collect(),
            grads: seq.iter().map(|&(_, g)| g).collect(),
        };
        let got = shard.apply(&upd).unwrap();
        assert_eq!(got.deltas, expected, "delta sequences must be bitwise equal");
        // The shard's local block equals the replica stripe.
        assert_eq!(shard.alpha[0], alpha[0]);
        assert_eq!(shard.alpha[1], alpha[2]);
        assert_eq!(shard.alpha[2], alpha[4]);
    }

    #[test]
    fn shard_state_rejects_foreign_slots_and_mismatched_routing() {
        let mut shard = ShardState::new(1, 4);
        let foreign = ShardUpdate {
            shard: 1,
            of: 4,
            eta: 0.1,
            slots: vec![2], // 2 % 4 != 1
            grads: vec![1.0],
        };
        assert!(shard.apply(&foreign).is_err());
        let misrouted = ShardUpdate {
            shard: 0,
            of: 4,
            eta: 0.1,
            slots: vec![0],
            grads: vec![1.0],
        };
        assert!(shard.apply(&misrouted).is_err());
    }

    #[test]
    fn check_round_flags_protocol_violations() {
        let good = WorkResult {
            item: 0,
            jj: vec![0, 1],
            g: vec![0.1, 0.2],
            loss: 0.0,
            nactive: 0.0,
            points: 2,
            compute_ns: 0,
        };
        assert!(check_round(std::slice::from_ref(&good), 1, 1, 2).is_ok());
        // Wrong item order / duplicate.
        let dup = vec![good.clone(), good.clone()];
        assert!(check_round(&dup, 2, 1, 2).is_err());
        // Gradient block not [k, |jj|].
        let mut short = good.clone();
        short.g.pop();
        assert!(check_round(std::slice::from_ref(&short), 1, 1, 2).is_err());
        // Expansion index outside the grid.
        let mut oob = good.clone();
        oob.jj = vec![0, 7];
        assert!(check_round(std::slice::from_ref(&oob), 1, 1, 2).is_err());
    }
}
