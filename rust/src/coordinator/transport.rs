//! Transport seam between the coordinator's leader and its workers.
//!
//! The leader talks [`CoordMsg`] to W workers over a [`WorkerPool`]
//! that hides *how* the messages move:
//!
//! * [`CoordTransport::Channel`] — worker threads in this process,
//!   messages over `mpsc` channels (values, no serialisation cost);
//! * [`CoordTransport::Socket`] — worker threads behind a loopback TCP
//!   connection each, every message passing through the length-prefixed
//!   binary codec of [`super::protocol`]. Same threads, real wire: the
//!   codec, the handshake, and the death detection are exactly what a
//!   multi-process deployment uses, so the bitwise-determinism suite
//!   can pin "threaded == socketed" today.
//!
//! Replies and failures funnel into one [`Mailbox`] the leader drains
//! at the round barrier. Worker death is detected by RAII, mirroring
//! the serve layer's `ScorerGuard`: each link **registers** with the
//! mailbox before its thread starts, and a [`LinkGuard`] owned by that
//! thread posts a precise `worker K died: <cause>` message when it
//! unwinds or returns without being defused. The leader therefore
//! never blocks on a round that can no longer complete — the bug this
//! module fixes is exactly the old shared `Sender` keeping the result
//! channel open while one worker was already gone.

use std::collections::VecDeque;
use std::fmt;
use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::str::FromStr;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
// lint:allow(determinism) reason="socket handshake deadline and polling only; never feeds training arithmetic"
use std::time::{Duration, Instant};

use crate::kernel::Kernel;
use crate::loss::Loss;
use crate::runtime::BackendSpec;
use crate::serve::protocol::{read_frame, write_frame};
use crate::{Error, Result};

use super::protocol::{decode_msg, encode_msg, CoordMsg};
use super::worker::{self, WorkerData};

/// How long the leader waits for every socket worker to connect and
/// identify itself before declaring the pool dead.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

/// How the leader's messages reach the workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CoordTransport {
    /// In-process worker threads over `mpsc` channels (the default).
    #[default]
    Channel,
    /// Worker threads behind one loopback TCP connection each; every
    /// message round-trips through the binary protocol codec.
    Socket,
}

impl fmt::Display for CoordTransport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CoordTransport::Channel => write!(f, "channel"),
            CoordTransport::Socket => write!(f, "socket"),
        }
    }
}

impl FromStr for CoordTransport {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "channel" | "thread" => Ok(CoordTransport::Channel),
            "socket" | "tcp" => Ok(CoordTransport::Socket),
            other => Err(Error::invalid(format!(
                "unknown coordinator transport '{other}' (expected 'channel' or 'socket')"
            ))),
        }
    }
}

struct MailboxState {
    queue: VecDeque<CoordMsg>,
    /// Links registered and not yet torn down. `recv` can only block
    /// while this is positive, so a round barrier over dead workers
    /// errors instead of hanging.
    live: usize,
    /// Set by the leader before shutdown so expected link teardown
    /// stops being reported as death.
    closing: bool,
}

/// The leader's single inbound queue: every worker reply and every
/// failure notification lands here, in arrival order.
pub(crate) struct Mailbox {
    state: Mutex<MailboxState>,
    ready: Condvar,
}

impl Mailbox {
    pub(crate) fn new() -> Self {
        Mailbox {
            state: Mutex::new(MailboxState {
                queue: VecDeque::new(),
                live: 0,
                closing: false,
            }),
            ready: Condvar::new(),
        }
    }

    /// Poison recovery: a panicking poster must not take the leader's
    /// error reporting down with it — the state (a queue and two
    /// counters) is valid after any partial operation.
    fn lock(&self) -> MutexGuard<'_, MailboxState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Count a link in **before** its thread starts, so there is no
    /// window where the thread has died but `recv` would still block.
    pub(crate) fn register(&self) {
        self.lock().live += 1;
    }

    /// Deliver a message to the leader.
    pub(crate) fn post(&self, msg: CoordMsg) {
        self.lock().queue.push_back(msg);
        self.ready.notify_all();
    }

    /// Tear down one link: decrement the live count and, when the pool
    /// is not already closing, deliver the death notice.
    fn link_down(&self, notice: Option<CoordMsg>) {
        let mut st = self.lock();
        st.live = st.live.saturating_sub(1);
        if let Some(msg) = notice {
            if !st.closing {
                st.queue.push_back(msg);
            }
        }
        self.ready.notify_all();
    }

    /// Mark teardown as expected: link deaths stop producing notices.
    fn close(&self) {
        self.lock().closing = true;
        self.ready.notify_all();
    }

    /// Next message, blocking while at least one link is alive. When
    /// the queue is empty and every link is gone this errors instead
    /// of blocking forever — the leader can never wedge on a round
    /// that no surviving worker will complete.
    pub(crate) fn recv(&self) -> Result<CoordMsg> {
        let mut st = self.lock();
        loop {
            if let Some(msg) = st.queue.pop_front() {
                return Ok(msg);
            }
            if st.live == 0 {
                return Err(Error::Coordinator(
                    "every worker link is down and no result is pending".into(),
                ));
            }
            st = self.ready.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }
}

/// RAII death notice for one worker link, mirroring the serve layer's
/// `ScorerGuard`: constructed at the top of the thread that owns the
/// link, it posts `cause` and releases the mailbox registration when
/// dropped — on clean return *and* on unwind — unless the thread
/// defused it first. This is what converts a panicking, aborting, or
/// silently-exiting worker into a prompt, precise leader-side error
/// even while every other link keeps the mailbox open.
pub(crate) struct LinkGuard {
    worker: usize,
    mailbox: Arc<Mailbox>,
    cause: String,
    defused: bool,
}

impl LinkGuard {
    /// The caller must have `register()`ed the link already.
    pub(crate) fn new(worker: usize, mailbox: Arc<Mailbox>, cause: String) -> Self {
        LinkGuard {
            worker,
            mailbox,
            cause,
            defused: false,
        }
    }

    /// The link ended as expected (clean shutdown or an error already
    /// posted precisely): drop turns into a bare deregistration.
    pub(crate) fn defuse(&mut self) {
        self.defused = true;
    }
}

impl Drop for LinkGuard {
    fn drop(&mut self) {
        let notice = if self.defused {
            None
        } else {
            Some(CoordMsg::WorkerError {
                worker: self.worker,
                message: std::mem::take(&mut self.cause),
            })
        };
        self.mailbox.link_down(notice);
    }
}

/// One leader→worker downlink.
enum Link {
    Channel(Sender<CoordMsg>),
    Socket(TcpStream),
}

impl Link {
    /// Best-effort send. A dead peer is not an error here: its death
    /// notice is already in (or on its way to) the mailbox, which is
    /// where the leader picks up the precise cause. Only a
    /// leader-side encoding bug surfaces as `Err`.
    fn push(&mut self, msg: &CoordMsg) -> Result<()> {
        match self {
            Link::Channel(tx) => {
                let _ = tx.send(msg.clone());
                Ok(())
            }
            Link::Socket(stream) => {
                let bytes = encode_msg(msg)?;
                let _ = write_frame(stream, &bytes);
                let _ = stream.flush();
                Ok(())
            }
        }
    }
}

/// A spawned set of W workers plus the leader-side plumbing: downlinks
/// for work, one shared [`Mailbox`] for results and failures, and the
/// join handles Drop tears down. Dropping the pool performs a clean
/// shutdown: mark closing, send [`CoordMsg::Shutdown`] everywhere,
/// close the downlinks, join every thread.
pub(crate) struct WorkerPool {
    links: Vec<Link>,
    mailbox: Arc<Mailbox>,
    threads: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` workers on `transport`. `sabotage` (tests only)
    /// names a worker that dies silently on its first work item — the
    /// regression hook for the dead-worker hang.
    pub(crate) fn spawn(
        transport: CoordTransport,
        workers: usize,
        spec: &BackendSpec,
        data: &WorkerData,
        kernel: Kernel,
        loss: Loss,
        lam: f32,
        sabotage: Option<usize>,
    ) -> Result<WorkerPool> {
        match transport {
            CoordTransport::Channel => {
                Self::spawn_channel(workers, spec, data, kernel, loss, lam, sabotage)
            }
            CoordTransport::Socket => {
                Self::spawn_socket(workers, spec, data, kernel, loss, lam, sabotage)
            }
        }
    }

    fn spawn_channel(
        workers: usize,
        spec: &BackendSpec,
        data: &WorkerData,
        kernel: Kernel,
        loss: Loss,
        lam: f32,
        sabotage: Option<usize>,
    ) -> Result<WorkerPool> {
        let mailbox = Arc::new(Mailbox::new());
        let mut links = Vec::with_capacity(workers);
        let mut threads = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = channel::<CoordMsg>();
            mailbox.register();
            let mb = Arc::clone(&mailbox);
            let spec = spec.clone();
            let data = data.clone();
            let handle = std::thread::Builder::new()
                .name(format!("dsekl-worker-{w}"))
                .spawn(move || {
                    run_channel_worker(w, rx, mb, spec, data, kernel, loss, lam, sabotage)
                })
                .map_err(|e| {
                    Error::Coordinator(format!("failed to spawn worker thread {w}: {e}"))
                })?;
            links.push(Link::Channel(tx));
            threads.push(handle);
        }
        Ok(WorkerPool {
            links,
            mailbox,
            threads,
        })
    }

    fn spawn_socket(
        workers: usize,
        spec: &BackendSpec,
        data: &WorkerData,
        kernel: Kernel,
        loss: Loss,
        lam: f32,
        sabotage: Option<usize>,
    ) -> Result<WorkerPool> {
        let listener = TcpListener::bind("127.0.0.1:0")
            .map_err(|e| Error::Coordinator(format!("coordinator listener bind failed: {e}")))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::Coordinator(format!("coordinator listener address: {e}")))?;

        let mailbox = Arc::new(Mailbox::new());
        let mut threads = Vec::with_capacity(2 * workers);
        for w in 0..workers {
            let spec = spec.clone();
            let data = data.clone();
            let handle = std::thread::Builder::new()
                .name(format!("dsekl-worker-{w}"))
                .spawn(move || {
                    run_socket_worker(w, addr, spec, data, kernel, loss, lam, sabotage)
                })
                .map_err(|e| {
                    Error::Coordinator(format!("failed to spawn worker thread {w}: {e}"))
                })?;
            threads.push(handle);
        }

        // Accept W connections; each worker's first frame is a hello
        // naming its id, so the link order is deterministic regardless
        // of connect/accept interleaving. The whole handshake is
        // bounded by a deadline — a worker that never connects is an
        // error, not a hang.
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::Coordinator(format!("coordinator listener mode: {e}")))?;
        // lint:allow(determinism) reason="socket handshake deadline only; never feeds training arithmetic"
        let deadline = Instant::now() + HANDSHAKE_TIMEOUT;
        let mut slots: Vec<Option<TcpStream>> = (0..workers).map(|_| None).collect();
        let mut accepted = 0usize;
        while accepted < workers {
            match listener.accept() {
                Ok((mut stream, _)) => {
                    stream
                        .set_nonblocking(false)
                        .map_err(|e| Error::Coordinator(format!("worker stream mode: {e}")))?;
                    stream
                        .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
                        .map_err(|e| Error::Coordinator(format!("worker stream timeout: {e}")))?;
                    let frame = read_frame(&mut stream)
                        .map_err(|e| {
                            Error::Coordinator(format!("worker handshake read failed: {e}"))
                        })?
                        .ok_or_else(|| {
                            Error::Coordinator("worker closed during the handshake".into())
                        })?;
                    let w = match decode_msg(&frame)? {
                        CoordMsg::Hello { worker } => worker,
                        other => {
                            return Err(Error::Coordinator(format!(
                                "protocol violation: expected hello, got {} during the handshake",
                                other.kind()
                            )))
                        }
                    };
                    stream
                        .set_read_timeout(None)
                        .map_err(|e| Error::Coordinator(format!("worker stream timeout: {e}")))?;
                    let slot = slots.get_mut(w).ok_or_else(|| {
                        Error::Coordinator(format!(
                            "protocol violation: hello from unknown worker {w} (pool of {workers})"
                        ))
                    })?;
                    if slot.is_some() {
                        return Err(Error::Coordinator(format!(
                            "protocol violation: duplicate hello from worker {w}"
                        )));
                    }
                    *slot = Some(stream);
                    accepted += 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    // lint:allow(determinism) reason="socket handshake deadline only; never feeds training arithmetic"
                    if Instant::now() >= deadline {
                        return Err(Error::Coordinator(format!(
                            "only {accepted} of {workers} workers connected within {}s",
                            HANDSHAKE_TIMEOUT.as_secs()
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => {
                    return Err(Error::Coordinator(format!(
                        "coordinator accept failed: {e}"
                    )))
                }
            }
        }

        // One reader thread per connection decodes inbound frames into
        // the mailbox. Its LinkGuard is the death detector: a worker
        // panic or abort closes the socket, the reader sees EOF, and
        // the guard posts the death notice (unless the pool is
        // closing). Registration precedes the spawn, as always.
        let mut links = Vec::with_capacity(workers);
        for (w, slot) in slots.into_iter().enumerate() {
            let stream = slot.ok_or_else(|| {
                Error::Coordinator(format!("worker {w} missing after the handshake"))
            })?;
            let reader_stream = stream.try_clone().map_err(|e| {
                Error::Coordinator(format!("worker {w} stream clone failed: {e}"))
            })?;
            mailbox.register();
            let mb = Arc::clone(&mailbox);
            let handle = std::thread::Builder::new()
                .name(format!("dsekl-link-{w}"))
                .spawn(move || run_link_reader(w, reader_stream, mb))
                .map_err(|e| {
                    Error::Coordinator(format!("failed to spawn link reader {w}: {e}"))
                })?;
            links.push(Link::Socket(stream));
            threads.push(handle);
        }
        Ok(WorkerPool {
            links,
            mailbox,
            threads,
        })
    }

    /// Send `msg` to worker `worker`. Dead peers are not an error (see
    /// [`Link::push`]); addressing a worker outside the pool is.
    pub(crate) fn send(&mut self, worker: usize, msg: &CoordMsg) -> Result<()> {
        self.links
            .get_mut(worker)
            .ok_or_else(|| {
                Error::Coordinator(format!(
                    "dispatch to worker {worker} outside the pool of {}",
                    self.links.len()
                ))
            })?
            .push(msg)
    }

    /// Next inbound message (a delta or a death notice), erroring
    /// instead of blocking when no live link remains.
    pub(crate) fn recv(&self) -> Result<CoordMsg> {
        self.mailbox.recv()
    }

    /// Worker count (shard `s` is hosted by worker `s % workers()`).
    pub(crate) fn workers(&self) -> usize {
        self.links.len()
    }

    /// The shared mailbox — exposed for the death-detection unit tests.
    #[cfg(test)]
    pub(crate) fn mailbox(&self) -> Arc<Mailbox> {
        Arc::clone(&self.mailbox)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Expected teardown from here on: link deaths stop producing
        // notices, then every worker is told to exit.
        self.mailbox.close();
        for link in &mut self.links {
            let _ = link.push(&CoordMsg::Shutdown);
        }
        // Closing the channel downlinks unblocks any worker waiting in
        // recv; socket workers read the shutdown frame or EOF.
        self.links.clear();
        for handle in self.threads.drain(..) {
            let _ = handle.join();
        }
    }
}

/// Body of one channel-transport worker thread.
#[allow(clippy::too_many_arguments)]
fn run_channel_worker(
    w: usize,
    rx: Receiver<CoordMsg>,
    mailbox: Arc<Mailbox>,
    spec: BackendSpec,
    data: WorkerData,
    kernel: Kernel,
    loss: Loss,
    lam: f32,
    sabotage: Option<usize>,
) {
    let mut guard = LinkGuard::new(
        w,
        Arc::clone(&mailbox),
        format!("worker {w} died: thread exited without completing its round (panic or abort)"),
    );
    if sabotage == Some(w) {
        // Regression hook: swallow the first message, then vanish
        // without defusing — the guard must surface the death.
        let _ = rx.recv();
        return;
    }
    let mut recv = || Ok(rx.recv().ok());
    let mut send = |msg: CoordMsg| {
        mailbox.post(msg);
        true
    };
    match worker::run(&spec, data, kernel, loss, lam, &mut recv, &mut send) {
        Ok(()) => guard.defuse(),
        Err(e) => {
            // The precise cause travels as a message; the guard then
            // has nothing left to report.
            mailbox.post(CoordMsg::WorkerError {
                worker: w,
                message: format!("worker {w} died: {e}"),
            });
            guard.defuse();
        }
    }
}

/// Body of one socket-transport worker thread: connect, identify, then
/// serve the same loop as the channel transport with every message
/// passing through the binary codec.
#[allow(clippy::too_many_arguments)]
fn run_socket_worker(
    w: usize,
    addr: std::net::SocketAddr,
    spec: BackendSpec,
    data: WorkerData,
    kernel: Kernel,
    loss: Loss,
    lam: f32,
    sabotage: Option<usize>,
) {
    // Failures before the link exists (connect refused, hello lost)
    // surface on the leader side as a handshake timeout; afterwards the
    // closed socket is the death signal the link reader reports.
    let Ok(mut stream) = TcpStream::connect(addr) else {
        return;
    };
    let Ok(hello) = encode_msg(&CoordMsg::Hello { worker: w }) else {
        return;
    };
    if write_frame(&mut stream, &hello).is_err() {
        return;
    }
    if sabotage == Some(w) {
        // Regression hook: swallow the first frame, then drop the
        // connection — the leader-side reader must surface the death.
        let _ = read_frame(&mut stream);
        return;
    }
    let Ok(mut reader) = stream.try_clone() else {
        return;
    };
    let mut recv = || match read_frame(&mut reader) {
        Ok(Some(payload)) => decode_msg(&payload).map(Some).map_err(|e| {
            Error::Coordinator(format!("leader sent an undecodable frame: {e}"))
        }),
        Ok(None) => Ok(None),
        Err(_) => Ok(None), // leader gone: exit quietly
    };
    let mut send = |msg: CoordMsg| match encode_msg(&msg) {
        Ok(bytes) => write_frame(&mut stream, &bytes).is_ok(),
        Err(_) => false,
    };
    if let Err(e) = worker::run(&spec, data, kernel, loss, lam, &mut recv, &mut send) {
        // Best-effort precise cause before the socket closes; if the
        // write fails the EOF notice still reaches the leader.
        if let Ok(bytes) = encode_msg(&CoordMsg::WorkerError {
            worker: w,
            message: format!("worker {w} died: {e}"),
        }) {
            let _ = write_frame(&mut stream, &bytes);
        }
    }
}

/// Leader-side reader of one worker connection: decode inbound frames
/// into the mailbox until EOF or a framing error. The guard converts
/// an unexpected EOF — a worker panic, abort, or kill closes the
/// socket — into a precise death notice.
fn run_link_reader(w: usize, mut stream: TcpStream, mailbox: Arc<Mailbox>) {
    let mut guard = LinkGuard::new(
        w,
        Arc::clone(&mailbox),
        format!("worker {w} died: connection closed mid-round"),
    );
    loop {
        match read_frame(&mut stream) {
            Ok(Some(payload)) => match decode_msg(&payload) {
                Ok(msg) => mailbox.post(msg),
                Err(e) => {
                    mailbox.post(CoordMsg::WorkerError {
                        worker: w,
                        message: format!("worker {w} died: sent an undecodable frame: {e}"),
                    });
                    guard.defuse();
                    return;
                }
            },
            Ok(None) => return, // EOF: the guard reports it if unexpected
            Err(e) => {
                mailbox.post(CoordMsg::WorkerError {
                    worker: w,
                    message: format!("worker {w} died: link read failed: {e}"),
                });
                guard.defuse();
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_parses_and_displays() {
        assert_eq!("channel".parse::<CoordTransport>().unwrap(), CoordTransport::Channel);
        assert_eq!("socket".parse::<CoordTransport>().unwrap(), CoordTransport::Socket);
        assert_eq!("tcp".parse::<CoordTransport>().unwrap(), CoordTransport::Socket);
        assert!("carrier-pigeon".parse::<CoordTransport>().is_err());
        assert_eq!(CoordTransport::Channel.to_string(), "channel");
        assert_eq!(CoordTransport::Socket.to_string(), "socket");
    }

    #[test]
    fn guard_reports_death_even_with_other_links_live() {
        // The regression shape of the old hang: one worker dies while
        // another link keeps the mailbox open. recv must return the
        // precise death notice promptly, not block.
        let mailbox = Arc::new(Mailbox::new());
        mailbox.register(); // the survivor
        mailbox.register(); // the victim
        let mb = Arc::clone(&mailbox);
        let victim = std::thread::spawn(move || {
            let _guard = LinkGuard::new(1, mb, "worker 1 died: unit-test panic".into());
            panic!("synthetic worker death");
        });
        assert!(victim.join().is_err(), "victim must have panicked");
        match mailbox.recv().unwrap() {
            CoordMsg::WorkerError { worker, message } => {
                assert_eq!(worker, 1);
                assert!(message.contains("worker 1 died"), "{message}");
            }
            other => panic!("expected a death notice, got {}", other.kind()),
        }
    }

    #[test]
    fn defused_guard_is_silent_and_recv_errors_when_all_links_down() {
        let mailbox = Arc::new(Mailbox::new());
        mailbox.register();
        let mut guard =
            LinkGuard::new(0, Arc::clone(&mailbox), "worker 0 died: should not appear".into());
        guard.defuse();
        drop(guard);
        let err = mailbox.recv().unwrap_err();
        assert!(
            err.to_string().contains("every worker link is down"),
            "{err}"
        );
    }

    #[test]
    fn closing_suppresses_death_notices() {
        let mailbox = Arc::new(Mailbox::new());
        mailbox.register();
        mailbox.close();
        let guard = LinkGuard::new(
            0,
            Arc::clone(&mailbox),
            "worker 0 died: expected teardown".into(),
        );
        drop(guard);
        let err = mailbox.recv().unwrap_err();
        assert!(
            err.to_string().contains("every worker link is down"),
            "suppressed notice expected, got {err}"
        );
    }
}
