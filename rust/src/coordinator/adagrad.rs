//! AdaGrad-style dampening matrix `G` of Algorithm 2.
//!
//! The paper keeps a diagonal matrix of accumulated squared gradients
//! ("aggregate inverse gradients for dampening updates of alpha",
//! Algorithm 2 line 11) and updates `alpha <- alpha - G^{-1/2} sum_k
//! g^(k)`. `G` is initialised to the identity so the first step has unit
//! dampening.

/// Diagonal AdaGrad accumulator over `n` dual coefficients.
#[derive(Debug, Clone)]
pub struct AdaGrad {
    g: Vec<f64>,
}

impl AdaGrad {
    /// `G = I` (paper line 4).
    pub fn new(n: usize) -> Self {
        AdaGrad { g: vec![1.0; n] }
    }

    /// Number of coordinates.
    pub fn len(&self) -> usize {
        self.g.len()
    }

    /// True if tracking zero coordinates.
    pub fn is_empty(&self) -> bool {
        self.g.is_empty()
    }

    /// Grow to at least `n` coordinates, new ones at the identity.
    /// Shard-local accumulators start empty and grow on first touch;
    /// growth order cannot affect values (each slot starts at 1.0
    /// regardless of when it is materialised).
    pub fn ensure(&mut self, n: usize) {
        if self.g.len() < n {
            self.g.resize(n, 1.0);
        }
    }

    /// Accumulate a squared gradient at coordinate `j` (line 11).
    pub fn accumulate(&mut self, j: usize, grad: f32) {
        // lint:allow(panic) reason="every caller bounds j against the coefficient grid before stepping; this is the per-gradient hot loop"
        self.g[j] += (grad as f64) * (grad as f64);
    }

    /// Dampened step `eta * g / sqrt(G_jj)` (line 14).
    pub fn step(&self, j: usize, eta: f32, grad: f32) -> f32 {
        // lint:allow(panic) reason="every caller bounds j against the coefficient grid before stepping; this is the per-gradient hot loop"
        (eta as f64 * grad as f64 / self.g[j].sqrt()) as f32
    }

    /// Raw accumulator value (tests / invariant checks).
    pub fn value(&self, j: usize) -> f64 {
        // lint:allow(panic) reason="test/introspection accessor; callers bound j against len()"
        self.g[j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_identity() {
        let g = AdaGrad::new(4);
        assert_eq!(g.len(), 4);
        // Unit dampening before any accumulation.
        assert!((g.step(0, 0.1, 2.0) - 0.2).abs() < 1e-7);
    }

    #[test]
    fn accumulation_dampens() {
        let mut g = AdaGrad::new(1);
        let first = g.step(0, 1.0, 1.0);
        g.accumulate(0, 3.0); // G = 1 + 9 = 10
        let second = g.step(0, 1.0, 1.0);
        assert!(second < first);
        assert!((second - 1.0 / 10f32.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn monotone_nondecreasing() {
        let mut g = AdaGrad::new(1);
        let mut prev = g.value(0);
        for t in 0..100 {
            g.accumulate(0, (t % 7) as f32 - 3.0);
            assert!(g.value(0) >= prev);
            prev = g.value(0);
        }
    }

    #[test]
    fn coordinates_independent() {
        let mut g = AdaGrad::new(2);
        g.accumulate(0, 100.0);
        assert!((g.step(1, 1.0, 1.0) - 1.0).abs() < 1e-7);
        assert!(g.step(0, 1.0, 1.0) < 0.01 + 1e-7);
    }
}
