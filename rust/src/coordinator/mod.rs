//! Algorithm 2 — the parallel DSEKL coordinator.
//!
//! This module is the paper's *systems* contribution, grown from its
//! python multithreading prototype into a message-passing leader/worker
//! engine:
//!
//! * The **leader** partitions each epoch's indices into disjoint
//!   `I^(k)` / `J^(k)` batches by sampling without replacement (paper
//!   §4.2), dispatches them round-robin as [`protocol::CoordMsg::Work`]
//!   messages, and turns the round's gradients into coefficient
//!   updates at a per-round barrier.
//! * **Workers** (one thread each, private backend instance) compute
//!   independent gradients on their `|I| x |J|` kernel submatrices —
//!   the "embarrassingly parallel" structure the paper exploits.
//!
//! Every leader↔worker exchange is a serialisable [`protocol::CoordMsg`]
//! behind the [`transport`] seam: in-process channels by default, or a
//! framed loopback socket per worker ([`CoordTransport::Socket`]) where
//! each message round-trips through the binary codec — the same round
//! logic runs threaded or wired. Worker death is a *message*, not a
//! hang: RAII link guards convert a panicking, aborting, or vanishing
//! worker into a precise `worker K died: <cause>` error at the barrier.
//!
//! With `shards: W > 0` the AdaGrad state and coefficient ownership
//! move onto the workers ([`shard`]): the leader ships each shard only
//! the gradient entries it owns and merges the returned deltas —
//! exchanging coefficient deltas per round instead of whole snapshots,
//! the block-coordinate-descent sharding pattern, bitwise-equal to the
//! leader-applied path by construction.
//!
//! Determinism: batches are assigned and results applied in item-id
//! order at the barrier, so a fixed seed reproduces training
//! bit-for-bit regardless of thread scheduling, worker count (with
//! fixed `round_batches`), shard count, and transport (verified in
//! `rust/tests/coordinator_props.rs` and
//! `rust/tests/coordinator_shard.rs`).
//!
//! Telemetry: per-batch compute time and per-round aggregation time feed
//! the calibrated speedup model reproducing Fig. 3b (the container
//! exposes a single core; DESIGN.md §4 documents the substitution).

pub mod adagrad;
pub mod protocol;
mod shard;
pub mod transport;
pub mod worker;

use std::sync::Arc;
// lint:allow(determinism) reason="telemetry timing only; never feeds training arithmetic"
use std::time::Instant;

use crate::data::{Dataset, MultiDataset, SparseDataset, SparseMultiDataset};
use crate::kernel::Kernel;
use crate::loss::Loss;
use crate::metrics::{Stopwatch, TracePoint};
use crate::model::{ExpansionStore, KernelModel, MulticlassModel};
use crate::rng::{Pcg64, Shuffler};
use crate::runtime::{Backend, BackendSpec};
use crate::solver::dsekl::TrainResult;
use crate::solver::TrainStats;
use crate::{Error, Result};

use protocol::{CoordMsg, WorkItem, WorkResult};
use shard::RoundApplier;
use transport::WorkerPool;
use worker::WorkerData;

pub use transport::CoordTransport;

/// The leader's expansion store over the full training rows,
/// materialised at most once per run (lazily) and **layout-preserving**:
/// CSR training data yields a CSR-backed store, so validation snapshots
/// predict through the O(nnz) kernel path and the final model (and its
/// DSEKLv3 file) stay O(nnz) — nothing is densified.
fn shared_store(cache: &mut Option<ExpansionStore>, data: &WorkerData) -> ExpansionStore {
    cache.get_or_insert_with(|| data.store()).clone()
}

/// Hyper-parameters of the parallel solver.
#[derive(Debug, Clone)]
pub struct ParallelOpts {
    /// RBF width.
    pub gamma: f32,
    /// L2 regularisation (paper's covtype run: 1/N).
    pub lam: f32,
    /// Gradient batch size per worker |I^(k)| (paper: 10,000).
    pub i_size: usize,
    /// Expansion batch size per worker |J^(k)| (paper: 10,000).
    pub j_size: usize,
    /// Number of workers K.
    pub workers: usize,
    /// Epoch cap ("passes through the entire data set").
    pub max_epochs: u64,
    /// Stop when the L2 norm of the alpha change over one epoch drops
    /// below this (paper: 1.0). `0.0` disables.
    pub tol: f32,
    /// Base learning rate; effective rate is `eta0 / epoch` (paper).
    pub eta0: f32,
    /// Evaluate validation error every this many rounds (0 = per epoch).
    pub eval_every_rounds: u64,
    /// Kernel override.
    pub kernel: Option<Kernel>,
    /// Per-example loss (paper: hinge).
    pub loss: Loss,
    /// Batches per round (the unit of gradient staleness: all batches in
    /// a round share the round-start `alpha` snapshot). `0` means "one
    /// per worker" — the paper's shared-memory semantics, where the
    /// algorithm changes with K. A fixed positive value decouples the
    /// *algorithm* from the *executor*: the same seed then reproduces
    /// training bit-for-bit for any worker count (workers only split the
    /// round's compute), which is what the determinism tests pin down.
    pub round_batches: usize,
    /// Coefficient shards W (`--shards`). `0` keeps AdaGrad state and
    /// coefficient updates on the leader; `W > 0` stripes the `[K, n]`
    /// slot grid across W worker-hosted shards (`slot % W`), each round
    /// exchanging only owned gradients out and coefficient deltas back.
    /// Bitwise-equal to the leader-applied path for any W.
    pub shards: usize,
    /// How leader↔worker messages travel: in-process channels or one
    /// framed loopback socket per worker (same round logic, real wire).
    pub transport: CoordTransport,
    /// Test-only fault injection: this worker dies silently on its
    /// first message (the dead-worker-hang regression hook).
    #[cfg(test)]
    pub sabotage: Option<usize>,
}

impl Default for ParallelOpts {
    fn default() -> Self {
        ParallelOpts {
            gamma: 1.0,
            lam: 1e-4,
            i_size: 256,
            j_size: 256,
            workers: 4,
            max_epochs: 20,
            tol: 0.0,
            eta0: 1.0,
            eval_every_rounds: 0,
            kernel: None,
            loss: Loss::Hinge,
            round_batches: 0,
            shards: 0,
            transport: CoordTransport::Channel,
            #[cfg(test)]
            sabotage: None,
        }
    }
}

impl ParallelOpts {
    /// The fault-injection target (always `None` outside test builds).
    fn sabotage_worker(&self) -> Option<usize> {
        #[cfg(test)]
        {
            self.sabotage
        }
        #[cfg(not(test))]
        {
            None
        }
    }
}

/// Telemetry of one training run, beyond the generic stats: the numbers
/// that calibrate the Fig. 3b speedup model.
#[derive(Debug, Clone, Default)]
pub struct ParallelTelemetry {
    /// Total pure-compute nanoseconds across all workers.
    pub compute_ns: u64,
    /// Total leader-side aggregation nanoseconds (G update + alpha
    /// scatter, or shard update build + delta merge) — the serial
    /// fraction.
    pub aggregate_ns: u64,
    /// Rounds executed.
    pub rounds: u64,
    /// Batches processed.
    pub batches: u64,
}

impl ParallelTelemetry {
    /// Serial fraction of one round: aggregation time relative to the
    /// sum of compute and aggregation. Feeds
    /// [`crate::metrics::SpeedupModel::parallel_frac`].
    pub fn serial_fraction(&self) -> f64 {
        let total = (self.compute_ns + self.aggregate_ns) as f64;
        if total == 0.0 {
            return 0.0;
        }
        self.aggregate_ns as f64 / total
    }
}

/// Parallel DSEKL solver (Algorithm 2).
#[derive(Debug, Clone)]
pub struct ParallelDsekl {
    opts: ParallelOpts,
}

/// Result bundle including coordinator telemetry.
#[derive(Debug)]
pub struct ParallelResult {
    pub model: KernelModel,
    pub stats: TrainStats,
    pub telemetry: ParallelTelemetry,
}

impl From<ParallelResult> for TrainResult {
    fn from(r: ParallelResult) -> TrainResult {
        TrainResult {
            model: r.model,
            stats: r.stats,
        }
    }
}

/// Draw up to `round_size` disjoint `(I, J)` batches from the epoch
/// partitions. The J partition exhausts independently of I (different
/// batch sizes), so it starts a fresh pass mid-epoch when needed — an
/// empty fresh pass is a structured error, never a panic.
fn assemble_round(
    i_shuffler: &mut Shuffler,
    j_shuffler: &mut Shuffler,
    rng: &mut Pcg64,
    i_size: usize,
    j_size: usize,
    round_size: usize,
) -> Result<Vec<(Vec<usize>, Vec<usize>)>> {
    let mut batches = Vec::with_capacity(round_size);
    for _ in 0..round_size {
        let ii = match i_shuffler.next_batch(i_size) {
            Some(b) => b.to_vec(),
            None => break, // epoch exhausted
        };
        let jj = match j_shuffler.next_batch(j_size) {
            Some(b) => b.to_vec(),
            None => {
                j_shuffler.reshuffle(rng);
                j_shuffler
                    .next_batch(j_size)
                    .ok_or_else(|| {
                        Error::Coordinator(
                            "expansion partition empty after a fresh reshuffle".into(),
                        )
                    })?
                    .to_vec()
            }
        };
        batches.push((ii, jj));
    }
    Ok(batches)
}

/// What one round contributed to the epoch's accounting.
struct RoundOutcome {
    /// Summed masked loss across the round's batches.
    loss: f64,
    /// Gradient samples processed (|I| summed over batches).
    points: u64,
    /// The round's contribution to the epoch-change squared norm.
    change_sq: f64,
}

/// Dispatch one assembled round, collect its deltas at the barrier,
/// and apply them through `applier`. Worker death notices and protocol
/// violations surface as precise errors — the barrier can never block
/// on a round no surviving worker will complete (the mailbox errors
/// once every link is down). `frac` rides in each work item, computed
/// from that item's **actual** `|I|`, so a short tail batch regularises
/// by its true size.
#[allow(clippy::too_many_arguments)]
fn run_round(
    pool: &mut WorkerPool,
    applier: &mut RoundApplier,
    batches: Vec<(Vec<usize>, Vec<usize>)>,
    alpha: &mut [f32],
    k: usize,
    n: usize,
    eta: f32,
    telemetry: &mut ParallelTelemetry,
) -> Result<RoundOutcome> {
    let dispatched = batches.len();
    let workers = pool.workers();
    for (item, (ii, jj)) in batches.into_iter().enumerate() {
        // [K, j] coefficient snapshot for this round's alpha.
        let mut alpha_j = Vec::with_capacity(k * jj.len());
        for h in 0..k {
            // lint:allow(panic) reason="j < n by Shuffler construction and the snapshot grid is sized k*n"
            alpha_j.extend(jj.iter().map(|&j| alpha[h * n + j]));
        }
        let frac = ii.len() as f32 / n as f32;
        pool.send(
            item % workers,
            &CoordMsg::Work(WorkItem {
                item,
                ii,
                jj,
                alpha_j,
                frac,
            }),
        )?;
    }

    // Round barrier: collect all results, order by item id so the
    // update is schedule-independent.
    let mut results: Vec<WorkResult> = Vec::with_capacity(dispatched);
    while results.len() < dispatched {
        match pool.recv()? {
            CoordMsg::Delta(r) => {
                telemetry.compute_ns += r.compute_ns;
                results.push(r);
            }
            CoordMsg::WorkerError { message, .. } => return Err(Error::Coordinator(message)),
            other => {
                return Err(Error::Coordinator(format!(
                    "protocol violation: unexpected {} at the round barrier",
                    other.kind()
                )))
            }
        }
    }
    results.sort_by_key(|r| r.item);
    shard::check_round(&results, dispatched, k, n)?;

    let mut loss = 0.0f64;
    let mut points = 0u64;
    for r in &results {
        loss += r.loss as f64;
        points += r.points;
    }

    // Aggregate (Algorithm 2 lines 11 & 14), leader-applied or
    // shard-applied — bitwise interchangeable.
    // lint:allow(determinism) reason="telemetry timing only; never feeds training arithmetic"
    let agg_start = Instant::now();
    let change_sq = applier.apply(pool, &results, k, n, eta, alpha)?;
    telemetry.aggregate_ns += agg_start.elapsed().as_nanos() as u64;
    telemetry.rounds += 1;
    telemetry.batches += dispatched as u64;
    Ok(RoundOutcome {
        loss,
        points,
        change_sq,
    })
}

impl ParallelDsekl {
    /// New solver.
    pub fn new(opts: ParallelOpts) -> Self {
        ParallelDsekl { opts }
    }

    /// Options in use.
    pub fn opts(&self) -> &ParallelOpts {
        &self.opts
    }

    /// Train on `train` with `opts.workers` threads. The leader keeps its
    /// own backend (from `spec`) for validation evaluation.
    pub fn train(
        &self,
        spec: &BackendSpec,
        train: &Arc<Dataset>,
        val: Option<&Dataset>,
        seed: u64,
    ) -> Result<ParallelResult> {
        self.train_binary_on(spec, WorkerData::Binary(Arc::clone(train)), val, seed)
    }

    /// Train on a **CSR** dataset: identical leader algorithm (same
    /// seed → same epoch partitions and round structure as the dense
    /// run — pinned bitwise in `rust/tests/schedule_parity.rs`), with
    /// workers gathering CSR batches and stepping the backend's O(nnz)
    /// sparse path. `val` stays a dense dataset; the leader's
    /// validation snapshots predict dense test points through the
    /// **CSR-backed** shared store (mixed-layout kernel path), and the
    /// final model keeps that store — nothing is densified.
    pub fn train_sparse(
        &self,
        spec: &BackendSpec,
        train: &Arc<SparseDataset>,
        val: Option<&Dataset>,
        seed: u64,
    ) -> Result<ParallelResult> {
        self.train_binary_on(spec, WorkerData::SparseBinary(Arc::clone(train)), val, seed)
    }

    /// Shared leader loop behind [`ParallelDsekl::train`] /
    /// [`ParallelDsekl::train_sparse`]: `data` must be one of the
    /// binary [`WorkerData`] layouts.
    fn train_binary_on(
        &self,
        spec: &BackendSpec,
        data: WorkerData,
        val: Option<&Dataset>,
        seed: u64,
    ) -> Result<ParallelResult> {
        let o = &self.opts;
        let n = data.len();
        if n == 0 {
            return Err(Error::invalid("empty training set"));
        }
        if o.workers == 0 {
            return Err(Error::invalid("need at least one worker"));
        }
        let kernel = o.kernel.unwrap_or(Kernel::Rbf { gamma: o.gamma });
        let i_size = o.i_size.min(n);
        let j_size = o.j_size.min(n);

        let mut rng = Pcg64::seed_from(seed);
        let watch = Stopwatch::new();
        let mut pool = WorkerPool::spawn(
            o.transport,
            o.workers,
            spec,
            &data,
            kernel,
            o.loss,
            o.lam,
            o.sabotage_worker(),
        )?;

        let mut leader_backend = spec.instantiate()?;
        let mut store_cache: Option<ExpansionStore> = None;
        let mut alpha = vec![0.0f32; n];
        let mut applier = RoundApplier::new(o.shards, n);
        let mut stats = TrainStats::new();
        let mut telemetry = ParallelTelemetry::default();

        // Round-0 validation point: the untrained model (alpha = 0
        // scores everything 0 -> all-positive predictions), so Fig. 3a
        // curves start at the class-prior error (~51% on covtype).
        if o.eval_every_rounds > 0 {
            if let Some(v) = val {
                let m = KernelModel::from_store(
                    kernel,
                    shared_store(&mut store_cache, &data),
                    alpha.clone(),
                );
                stats.trace.push(TracePoint {
                    points_processed: 0,
                    iteration: 0,
                    // Per-example loss at alpha = 0 (f = 0), which is
                    // label-independent for every supported loss.
                    loss: o.loss.value(1.0, 0.0) as f64,
                    val_error: Some(m.error(leader_backend.as_mut(), v)?),
                    elapsed_s: watch.total(),
                });
            }
        }

        // Disjoint epoch partitions for I and J (independent orders).
        let mut i_shuffler = Shuffler::new(n, &mut rng);
        let mut j_shuffler = Shuffler::new(n, &mut rng);

        let mut round: u64 = 0;
        let mut loss_acc = 0.0f64;
        let mut loss_pts = 0u64;

        'epochs: for epoch in 1..=o.max_epochs {
            i_shuffler.reshuffle(&mut rng);
            j_shuffler.reshuffle(&mut rng);
            let eta = o.eta0 / epoch as f32;
            let mut epoch_change_sq = 0.0f64;

            // Round size: fixed (K-independent determinism) or one batch
            // per worker (the paper's semantics).
            let round_size = if o.round_batches > 0 {
                o.round_batches
            } else {
                o.workers
            };

            loop {
                let batches = assemble_round(
                    &mut i_shuffler,
                    &mut j_shuffler,
                    &mut rng,
                    i_size,
                    j_size,
                    round_size,
                )?;
                if batches.is_empty() {
                    break; // epoch exhausted
                }
                let out = run_round(
                    &mut pool,
                    &mut applier,
                    batches,
                    &mut alpha,
                    1,
                    n,
                    eta,
                    &mut telemetry,
                )?;
                loss_acc += out.loss;
                loss_pts += out.points;
                stats.points_processed += out.points;
                epoch_change_sq += out.change_sq;
                round += 1;

                // Validation cadence (Fig. 3a: per mini-batch round).
                let do_eval = o.eval_every_rounds > 0 && round % o.eval_every_rounds == 0;
                if do_eval {
                    let val_error = match val {
                        Some(v) => {
                            let m = KernelModel::from_store(
                                kernel,
                                shared_store(&mut store_cache, &data),
                                alpha.clone(),
                            );
                            Some(m.error(leader_backend.as_mut(), v)?)
                        }
                        None => None,
                    };
                    stats.trace.push(TracePoint {
                        points_processed: stats.points_processed,
                        iteration: round,
                        loss: if loss_pts > 0 {
                            loss_acc / loss_pts as f64
                        } else {
                            0.0
                        },
                        val_error,
                        elapsed_s: watch.total(),
                    });
                    loss_acc = 0.0;
                    loss_pts = 0;
                }
            }

            stats.iterations = epoch;
            // End-of-epoch validation point when no round cadence is set.
            if o.eval_every_rounds == 0 {
                let val_error = match val {
                    Some(v) => {
                        let m = KernelModel::from_store(
                            kernel,
                            shared_store(&mut store_cache, &data),
                            alpha.clone(),
                        );
                        Some(m.error(leader_backend.as_mut(), v)?)
                    }
                    None => None,
                };
                stats.trace.push(TracePoint {
                    points_processed: stats.points_processed,
                    iteration: epoch,
                    loss: if loss_pts > 0 {
                        loss_acc / loss_pts as f64
                    } else {
                        0.0
                    },
                    val_error,
                    elapsed_s: watch.total(),
                });
                loss_acc = 0.0;
                loss_pts = 0;
            }

            if o.tol > 0.0 && epoch_change_sq.sqrt() < o.tol as f64 {
                stats.converged = true;
                break 'epochs;
            }
        }

        stats.elapsed_s = watch.total();
        Ok(ParallelResult {
            model: KernelModel::from_store(kernel, shared_store(&mut store_cache, &data), alpha),
            stats,
            telemetry,
        })
    }

    /// Train K one-vs-rest heads in parallel with **fused K-head
    /// batches**: the leader owns the `[K, n]` coefficient matrix (with
    /// per-head AdaGrad dampening over the same `[K, n]` grid), draws
    /// *one* I/J partition per round, and every worker computes its
    /// `|I| x |J|` kernel block once and contracts it against all K
    /// heads ([`crate::runtime::Backend::dsekl_step_multi`]). Same
    /// determinism contract as [`ParallelDsekl::train`]: results are
    /// applied in dispatch order at a per-round barrier; the tolerance
    /// criterion is the L2 norm of the per-epoch change of the whole
    /// `[K, n]` matrix. The model heads share one
    /// [`ExpansionStore`] — rows stored once, not K times.
    pub fn train_multi(
        &self,
        spec: &BackendSpec,
        train: &Arc<MultiDataset>,
        val: Option<&MultiDataset>,
        seed: u64,
    ) -> Result<ParallelMultiResult> {
        self.train_multi_on(spec, WorkerData::Multi(Arc::clone(train)), val, seed)
    }

    /// Fused K-head training over a **CSR** dataset: same leader
    /// algorithm as [`ParallelDsekl::train_multi`], with workers
    /// gathering CSR batches for the sparse kernel-block path. `val`
    /// stays dense (snapshots predict through the CSR-backed shared
    /// store, materialised lazily — never densified).
    pub fn train_multi_sparse(
        &self,
        spec: &BackendSpec,
        train: &Arc<SparseMultiDataset>,
        val: Option<&MultiDataset>,
        seed: u64,
    ) -> Result<ParallelMultiResult> {
        self.train_multi_on(spec, WorkerData::SparseMulti(Arc::clone(train)), val, seed)
    }

    /// Shared K-head leader loop behind [`ParallelDsekl::train_multi`] /
    /// [`ParallelDsekl::train_multi_sparse`]: `data` must be one of the
    /// multiclass [`WorkerData`] layouts.
    fn train_multi_on(
        &self,
        spec: &BackendSpec,
        data: WorkerData,
        val: Option<&MultiDataset>,
        seed: u64,
    ) -> Result<ParallelMultiResult> {
        let o = &self.opts;
        let n = data.len();
        if n == 0 {
            return Err(Error::invalid("empty training set"));
        }
        let k = data
            .n_classes()
            .ok_or_else(|| Error::invalid("multiclass training needs multiclass worker data"))?;
        if k < 2 {
            return Err(Error::invalid(format!(
                "one-vs-rest needs >= 2 classes, dataset declares {k}"
            )));
        }
        if o.workers == 0 {
            return Err(Error::invalid("need at least one worker"));
        }
        let kernel = o.kernel.unwrap_or(Kernel::Rbf { gamma: o.gamma });
        let i_size = o.i_size.min(n);
        let j_size = o.j_size.min(n);

        let mut rng = Pcg64::seed_from(seed);
        let watch = Stopwatch::new();
        let mut pool = WorkerPool::spawn(
            o.transport,
            o.workers,
            spec,
            &data,
            kernel,
            o.loss,
            o.lam,
            o.sabotage_worker(),
        )?;

        let mut leader_backend = spec.instantiate()?;
        // The shared row block (layout-preserving) is materialised at
        // most once (lazily); validation snapshots and the final model
        // are views over it.
        let mut store_cache: Option<ExpansionStore> = None;
        let mut alpha = vec![0.0f32; k * n];
        let mut applier = RoundApplier::new(o.shards, k * n);
        let mut stats = TrainStats::new();
        let mut telemetry = ParallelTelemetry::default();

        let eval = |alpha: &[f32],
                    backend: &mut dyn Backend,
                    cache: &mut Option<ExpansionStore>|
         -> Result<Option<f64>> {
            match val {
                Some(v) => {
                    let m = MulticlassModel::from_shared(
                        kernel,
                        shared_store(cache, &data),
                        alpha.to_vec(),
                    );
                    Ok(Some(m.error(backend, v)?))
                }
                None => Ok(None),
            }
        };

        // Round-0 validation point, mirroring the binary coordinator:
        // the untrained model (all-zero scores -> argmax class 0), so
        // convergence curves start at the class-prior error.
        if o.eval_every_rounds > 0 {
            if let Some(err) = eval(&alpha, leader_backend.as_mut(), &mut store_cache)? {
                stats.trace.push(TracePoint {
                    points_processed: 0,
                    iteration: 0,
                    // Per-head-example loss at alpha = 0 (f = 0), which
                    // is label-independent for every supported loss.
                    loss: o.loss.value(1.0, 0.0) as f64,
                    val_error: Some(err),
                    elapsed_s: watch.total(),
                });
            }
        }

        // Disjoint epoch partitions for I and J (independent orders),
        // shared by all K heads.
        let mut i_shuffler = Shuffler::new(n, &mut rng);
        let mut j_shuffler = Shuffler::new(n, &mut rng);

        let mut round: u64 = 0;
        let mut loss_acc = 0.0f64;
        let mut loss_pts = 0u64;

        'epochs: for epoch in 1..=o.max_epochs {
            i_shuffler.reshuffle(&mut rng);
            j_shuffler.reshuffle(&mut rng);
            let eta = o.eta0 / epoch as f32;
            let mut epoch_change_sq = 0.0f64;

            let round_size = if o.round_batches > 0 {
                o.round_batches
            } else {
                o.workers
            };

            loop {
                let batches = assemble_round(
                    &mut i_shuffler,
                    &mut j_shuffler,
                    &mut rng,
                    i_size,
                    j_size,
                    round_size,
                )?;
                if batches.is_empty() {
                    break; // epoch exhausted
                }
                let out = run_round(
                    &mut pool,
                    &mut applier,
                    batches,
                    &mut alpha,
                    k,
                    n,
                    eta,
                    &mut telemetry,
                )?;
                loss_acc += out.loss;
                loss_pts += out.points * k as u64;
                stats.points_processed += out.points;
                epoch_change_sq += out.change_sq;
                round += 1;

                let do_eval = o.eval_every_rounds > 0 && round % o.eval_every_rounds == 0;
                if do_eval {
                    let val_error = eval(&alpha, leader_backend.as_mut(), &mut store_cache)?;
                    stats.trace.push(TracePoint {
                        points_processed: stats.points_processed,
                        iteration: round,
                        loss: if loss_pts > 0 {
                            loss_acc / loss_pts as f64
                        } else {
                            0.0
                        },
                        val_error,
                        elapsed_s: watch.total(),
                    });
                    loss_acc = 0.0;
                    loss_pts = 0;
                }
            }

            stats.iterations = epoch;
            if o.eval_every_rounds == 0 {
                let val_error = eval(&alpha, leader_backend.as_mut(), &mut store_cache)?;
                stats.trace.push(TracePoint {
                    points_processed: stats.points_processed,
                    iteration: epoch,
                    loss: if loss_pts > 0 {
                        loss_acc / loss_pts as f64
                    } else {
                        0.0
                    },
                    val_error,
                    elapsed_s: watch.total(),
                });
                loss_acc = 0.0;
                loss_pts = 0;
            }

            if o.tol > 0.0 && epoch_change_sq.sqrt() < o.tol as f64 {
                stats.converged = true;
                break 'epochs;
            }
        }

        stats.elapsed_s = watch.total();
        let store = shared_store(&mut store_cache, &data);
        Ok(ParallelMultiResult {
            model: MulticlassModel::from_shared(kernel, store, alpha),
            stats,
            telemetry,
        })
    }
}

/// Result bundle of the fused multiclass coordinator
/// ([`ParallelDsekl::train_multi`]).
#[derive(Debug)]
pub struct ParallelMultiResult {
    /// K argmax heads over one shared expansion store.
    pub model: MulticlassModel,
    /// Aggregate training statistics (epochs as iterations).
    pub stats: TrainStats,
    /// Round/batch telemetry, as in the binary coordinator.
    pub telemetry: ParallelTelemetry,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::runtime::NativeBackend;
    use std::time::Duration;

    fn xor_arc(seed: u64, n: usize) -> Arc<Dataset> {
        let mut rng = Pcg64::seed_from(seed);
        Arc::new(synth::xor(n, 0.2, &mut rng))
    }

    #[test]
    fn parallel_learns_xor() {
        let ds = xor_arc(1, 200);
        let solver = ParallelDsekl::new(ParallelOpts {
            gamma: 1.0,
            lam: 1e-4,
            i_size: 32,
            j_size: 32,
            workers: 3,
            max_epochs: 40,
            ..Default::default()
        });
        let res = solver
            .train(&BackendSpec::Native, &ds, None, 7)
            .unwrap();
        let mut be = NativeBackend::new();
        let err = res.model.error(&mut be, &ds).unwrap();
        assert!(err <= 0.05, "parallel XOR error {err}");
        assert!(res.telemetry.rounds > 0);
        assert!(res.telemetry.compute_ns > 0);
    }

    #[test]
    fn deterministic_across_worker_counts_epoch_coverage() {
        // Same seed => same batches processed per epoch (coverage
        // invariant), regardless of K. Full bitwise determinism across
        // *the same* K is tested in rust/tests/coordinator_props.rs.
        let ds = xor_arc(2, 120);
        for workers in [1, 2, 5] {
            let solver = ParallelDsekl::new(ParallelOpts {
                i_size: 25,
                j_size: 25,
                workers,
                max_epochs: 2,
                ..Default::default()
            });
            let res = solver.train(&BackendSpec::Native, &ds, None, 3).unwrap();
            // 120/25 -> 5 batches per epoch, 2 epochs.
            assert_eq!(res.telemetry.batches, 10, "workers={workers}");
            assert_eq!(res.stats.points_processed, 240);
        }
    }

    #[test]
    fn tail_batches_regularise_by_true_size() {
        // n = 90, i_size = 16: each epoch is five full batches plus a
        // tail of 10. The per-item frac fix means the run still learns
        // and covers every point; the frac a worker receives is pinned
        // directly in worker.rs tests and the shard suite.
        let ds = xor_arc(8, 90);
        let solver = ParallelDsekl::new(ParallelOpts {
            i_size: 16,
            j_size: 16,
            workers: 2,
            max_epochs: 4,
            ..Default::default()
        });
        let res = solver.train(&BackendSpec::Native, &ds, None, 3).unwrap();
        // ceil(90/16) = 6 batches per epoch, all 90 points covered.
        assert_eq!(res.telemetry.batches, 24);
        assert_eq!(res.stats.points_processed, 360);
    }

    #[test]
    fn validation_trace_recorded() {
        let ds = xor_arc(3, 100);
        let mut rng = Pcg64::seed_from(4);
        let val = synth::xor(50, 0.2, &mut rng);
        let solver = ParallelDsekl::new(ParallelOpts {
            i_size: 20,
            j_size: 20,
            workers: 2,
            max_epochs: 3,
            eval_every_rounds: 1,
            ..Default::default()
        });
        let res = solver
            .train(&BackendSpec::Native, &ds, Some(&val), 5)
            .unwrap();
        assert!(!res.stats.trace.points.is_empty());
        assert!(res.stats.trace.last_val_error().is_some());
        // Error should end well below chance.
        assert!(res.stats.trace.last_val_error().unwrap() < 0.25);
    }

    #[test]
    fn tolerance_converges() {
        let ds = xor_arc(5, 80);
        let solver = ParallelDsekl::new(ParallelOpts {
            i_size: 40,
            j_size: 40,
            workers: 2,
            max_epochs: 500,
            tol: 0.05,
            ..Default::default()
        });
        let res = solver.train(&BackendSpec::Native, &ds, None, 6).unwrap();
        assert!(res.stats.converged);
        assert!(res.stats.iterations < 500);
    }

    #[test]
    fn zero_workers_rejected() {
        let ds = xor_arc(6, 10);
        let solver = ParallelDsekl::new(ParallelOpts {
            workers: 0,
            ..Default::default()
        });
        assert!(solver.train(&BackendSpec::Native, &ds, None, 1).is_err());
    }

    #[test]
    fn dead_worker_yields_structured_error_not_hang_channel() {
        // The PR's headline regression: worker 1 dies on its first
        // message while worker 0's link keeps the mailbox open. The
        // old coordinator blocked in recv() forever; the RAII guard
        // must now surface a precise diagnostic promptly.
        let ds = xor_arc(30, 90);
        let solver = ParallelDsekl::new(ParallelOpts {
            i_size: 16,
            j_size: 16,
            workers: 2,
            max_epochs: 3,
            sabotage: Some(1),
            ..Default::default()
        });
        let start = Instant::now();
        let err = solver
            .train(&BackendSpec::Native, &ds, None, 7)
            .unwrap_err();
        assert!(
            start.elapsed() < Duration::from_secs(20),
            "dead worker must not stall the leader"
        );
        let msg = err.to_string();
        assert!(msg.contains("worker 1 died"), "imprecise diagnostic: {msg}");
    }

    #[test]
    fn dead_worker_yields_structured_error_not_hang_socket() {
        // Same regression over the socket transport: the worker drops
        // its connection mid-round; the link reader's EOF guard must
        // convert that into the same precise diagnostic.
        let ds = xor_arc(31, 90);
        let solver = ParallelDsekl::new(ParallelOpts {
            i_size: 16,
            j_size: 16,
            workers: 2,
            max_epochs: 3,
            transport: CoordTransport::Socket,
            sabotage: Some(1),
            ..Default::default()
        });
        let start = Instant::now();
        let err = solver
            .train(&BackendSpec::Native, &ds, None, 7)
            .unwrap_err();
        assert!(
            start.elapsed() < Duration::from_secs(20),
            "dead socket worker must not stall the leader"
        );
        let msg = err.to_string();
        assert!(msg.contains("worker 1 died"), "imprecise diagnostic: {msg}");
    }

    #[test]
    fn socket_transport_trains_and_matches_channel() {
        // The framed loopback transport must produce the *same bits*
        // as the in-process channel transport (the broader matrix over
        // shards and worker counts lives in tests/coordinator_shard.rs).
        let ds = xor_arc(32, 90);
        let mut models = Vec::new();
        for transport in [CoordTransport::Channel, CoordTransport::Socket] {
            let solver = ParallelDsekl::new(ParallelOpts {
                i_size: 16,
                j_size: 16,
                workers: 2,
                max_epochs: 3,
                transport,
                ..Default::default()
            });
            let res = solver.train(&BackendSpec::Native, &ds, None, 11).unwrap();
            models.push(res.model.alpha.clone());
        }
        assert_eq!(models[0], models[1], "socket and channel runs diverged");
    }

    fn blobs_multi_arc(seed: u64, n: usize, k: usize) -> Arc<crate::data::MultiDataset> {
        let mut rng = Pcg64::seed_from(seed);
        Arc::new(synth::multi_blobs(n, k, 2, 0.25, &mut rng))
    }

    #[test]
    fn parallel_multiclass_learns_blobs() {
        let ds = blobs_multi_arc(11, 240, 3);
        let solver = ParallelDsekl::new(ParallelOpts {
            gamma: 1.0,
            lam: 1e-4,
            i_size: 32,
            j_size: 32,
            workers: 3,
            max_epochs: 30,
            ..Default::default()
        });
        let res = solver
            .train_multi(&BackendSpec::Native, &ds, None, 7)
            .unwrap();
        assert_eq!(res.model.n_classes(), 3);
        assert!(res.model.is_shared(), "heads must share one row block");
        let mut be = NativeBackend::new();
        let err = res.model.error(&mut be, &ds).unwrap();
        assert!(err <= 0.08, "parallel 3-class blob error {err}");
        assert!(res.telemetry.rounds > 0);
        assert!(res.telemetry.compute_ns > 0);
    }

    #[test]
    fn parallel_multiclass_deterministic_across_worker_counts() {
        // With a fixed round size the fused K-head coordinator is
        // bitwise deterministic for any worker count, exactly like the
        // binary one.
        let ds = blobs_multi_arc(12, 120, 4);
        let mut reference: Option<Vec<f32>> = None;
        for workers in [1, 2, 5] {
            let solver = ParallelDsekl::new(ParallelOpts {
                i_size: 24,
                j_size: 24,
                workers,
                max_epochs: 3,
                round_batches: 2,
                ..Default::default()
            });
            let res = solver
                .train_multi(&BackendSpec::Native, &ds, None, 9)
                .unwrap();
            let coef = res.model.coef_matrix();
            match &reference {
                None => reference = Some(coef),
                Some(want) => {
                    assert_eq!(&coef, want, "workers={workers} diverged");
                }
            }
        }
    }

    #[test]
    fn parallel_multiclass_validation_trace() {
        let ds = blobs_multi_arc(13, 120, 3);
        let mut rng = Pcg64::seed_from(14);
        let val = synth::multi_blobs(60, 3, 2, 0.25, &mut rng);
        let solver = ParallelDsekl::new(ParallelOpts {
            i_size: 24,
            j_size: 24,
            workers: 2,
            max_epochs: 6,
            eval_every_rounds: 1,
            ..Default::default()
        });
        let res = solver
            .train_multi(&BackendSpec::Native, &ds, Some(&val), 15)
            .unwrap();
        assert!(!res.stats.trace.points.is_empty());
        let last = res.stats.trace.last_val_error().unwrap();
        assert!(last < 0.34, "validation error {last} not better than chance");
    }

    #[test]
    fn parallel_sparse_matches_dense_accuracy() {
        // CSR end-to-end through the coordinator: same seed -> same
        // epoch partitions as the dense run on the densified copy, so
        // the two runs land at (numerically) the same model.
        let mut rng = Pcg64::seed_from(21);
        let sparse = Arc::new(synth::sparse_binary(240, 60, 0.05, &mut rng));
        let dense = Arc::new(sparse.to_dense());
        let solver = ParallelDsekl::new(ParallelOpts {
            lam: 1e-4,
            i_size: 32,
            j_size: 32,
            workers: 2,
            max_epochs: 15,
            kernel: Some(Kernel::Linear),
            ..Default::default()
        });
        let res_s = solver
            .train_sparse(&BackendSpec::Native, &sparse, None, 9)
            .unwrap();
        let res_d = solver.train(&BackendSpec::Native, &dense, None, 9).unwrap();
        let mut be = NativeBackend::new();
        let err_s = res_s.model.error_sparse(&mut be, &sparse).unwrap();
        let err_d = res_d.model.error(&mut be, &dense).unwrap();
        assert!(err_s <= 0.05, "parallel sparse error {err_s}");
        assert!(
            (err_s - err_d).abs() <= 0.02,
            "sparse {err_s} vs dense {err_d}"
        );
        assert!(res_s.telemetry.rounds > 0);
    }

    #[test]
    fn parallel_multiclass_sparse_learns() {
        let mut rng = Pcg64::seed_from(22);
        let ds = Arc::new(synth::sparse_multiclass(240, 3, 48, 0.08, &mut rng));
        let solver = ParallelDsekl::new(ParallelOpts {
            lam: 1e-4,
            i_size: 32,
            j_size: 32,
            workers: 2,
            max_epochs: 20,
            kernel: Some(Kernel::Linear),
            loss: Loss::Logistic,
            ..Default::default()
        });
        let res = solver
            .train_multi_sparse(&BackendSpec::Native, &ds, None, 11)
            .unwrap();
        assert_eq!(res.model.n_classes(), 3);
        assert!(res.model.is_shared(), "heads must share one row block");
        let mut be = NativeBackend::new();
        let err = res.model.error_sparse(&mut be, &ds).unwrap();
        assert!(err <= 0.08, "parallel sparse 3-class error {err}");
    }

    #[test]
    fn parallel_multiclass_rejects_degenerate() {
        let empty = Arc::new(crate::data::MultiDataset::with_dims(2, 3));
        let solver = ParallelDsekl::new(ParallelOpts::default());
        assert!(solver
            .train_multi(&BackendSpec::Native, &empty, None, 1)
            .is_err());
        let mut one_class = crate::data::MultiDataset::with_dims(2, 1);
        one_class.push(&[0.0, 0.0], 0);
        assert!(solver
            .train_multi(&BackendSpec::Native, &Arc::new(one_class), None, 1)
            .is_err());
    }
}
