//! Worker threads of the parallel coordinator.
//!
//! Each worker owns a thread, a private compute backend (instantiated
//! from the `BackendSpec` *inside* the thread — PJRT clients are not
//! `Send`) and a pair of channels. The leader ships index batches plus an
//! `alpha_J` snapshot; the worker gathers rows from the shared dataset,
//! runs one DSEKL step, and ships the gradient back with compute-time
//! telemetry (used to calibrate the Fig. 3b speedup model).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::data::Dataset;
use crate::kernel::Kernel;
use crate::loss::Loss;
use crate::runtime::{BackendSpec, StepInput};
use crate::{Error, Result};

/// One unit of work: compute the gradient of batch `(ii, jj)` at the
/// given coefficient snapshot.
#[derive(Debug)]
pub struct WorkItem {
    /// Round-trip tag so the leader can order results deterministically.
    pub worker_id: usize,
    /// Gradient sample indices I^(k).
    pub ii: Vec<usize>,
    /// Expansion indices J^(k).
    pub jj: Vec<usize>,
    /// Snapshot of alpha at indices J^(k).
    pub alpha_j: Vec<f32>,
    /// Regulariser scaling |I|/N.
    pub frac: f32,
}

/// Gradient result for one work item.
#[derive(Debug)]
pub struct WorkResult {
    pub worker_id: usize,
    /// Expansion indices the gradient refers to.
    pub jj: Vec<usize>,
    /// Gradient over `jj`.
    pub g: Vec<f32>,
    /// Masked hinge loss over the I batch.
    pub loss: f32,
    /// Margin violations in the I batch.
    pub nactive: f32,
    /// Gradient samples processed (|I|).
    pub points: u64,
    /// Pure compute nanoseconds (excludes channel/queue time) — the
    /// parallelisable fraction measured for the speedup model.
    pub compute_ns: u64,
}

/// Handle to a spawned worker.
pub struct Worker {
    tx: Sender<WorkItem>,
    handle: Option<JoinHandle<()>>,
}

impl Worker {
    /// Spawn worker `id`. Results go to the shared `results` sender.
    pub fn spawn(
        id: usize,
        spec: BackendSpec,
        data: Arc<Dataset>,
        kernel: Kernel,
        loss: Loss,
        lam: f32,
        results: Sender<WorkResult>,
    ) -> Worker {
        let (tx, rx): (Sender<WorkItem>, Receiver<WorkItem>) = channel();
        let handle = std::thread::Builder::new()
            .name(format!("dsekl-worker-{id}"))
            .spawn(move || {
                // Backend lives entirely inside the thread.
                let mut backend = match spec.instantiate() {
                    Ok(b) => b,
                    Err(e) => {
                        eprintln!("worker {id}: backend init failed: {e}");
                        return;
                    }
                };
                let mut xi = Vec::new();
                let mut yi = Vec::new();
                let mut xj = Vec::new();
                let mut g = Vec::new();
                while let Ok(item) = rx.recv() {
                    let start = Instant::now();
                    data.gather_into(&item.ii, &mut xi);
                    data.gather_labels_into(&item.ii, &mut yi);
                    data.gather_into(&item.jj, &mut xj);
                    let out = match backend.dsekl_step(
                        kernel,
                        &StepInput {
                            xi: &xi,
                            yi: &yi,
                            xj: &xj,
                            alpha: &item.alpha_j,
                            i: item.ii.len(),
                            j: item.jj.len(),
                            d: data.d,
                            lam,
                            frac: item.frac,
                            loss,
                        },
                        &mut g,
                    ) {
                        Ok(o) => o,
                        Err(e) => {
                            eprintln!("worker {id}: step failed: {e}");
                            return;
                        }
                    };
                    let res = WorkResult {
                        worker_id: item.worker_id,
                        points: item.ii.len() as u64,
                        jj: item.jj,
                        g: g.clone(),
                        loss: out.loss,
                        nactive: out.nactive,
                        compute_ns: start.elapsed().as_nanos() as u64,
                    };
                    if results.send(res).is_err() {
                        return; // leader gone
                    }
                }
            })
            .expect("spawn worker thread");
        Worker {
            tx,
            handle: Some(handle),
        }
    }

    /// Queue a work item.
    pub fn submit(&self, item: WorkItem) -> Result<()> {
        self.tx
            .send(item)
            .map_err(|_| Error::Coordinator("worker channel closed".into()))
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        // Close the channel, then join so panics surface.
        let (dead_tx, _) = channel();
        self.tx = dead_tx;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
