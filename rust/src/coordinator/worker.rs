//! Worker threads of the parallel coordinator.
//!
//! Each worker owns a thread, a private compute backend (instantiated
//! from the `BackendSpec` *inside* the thread — PJRT clients are not
//! `Send`) and a pair of channels. The leader ships index batches plus an
//! `alpha_J` snapshot; the worker gathers rows from the shared dataset,
//! runs one DSEKL step, and ships the gradient back with compute-time
//! telemetry (used to calibrate the Fig. 3b speedup model).
//!
//! Workers serve both workloads over the same channel protocol: binary
//! training (one head, [`crate::runtime::Backend::dsekl_step`]) and
//! fused K-head one-vs-rest training, where the leader ships a `[K, j]`
//! coefficient snapshot and the worker computes the shared `|I| x |J|`
//! kernel block **once** for all K heads
//! ([`crate::runtime::Backend::dsekl_step_multi`]), building per-head
//! ±1 labels as views over the shared class ids.
//!
//! The worker loop runs on the gather abstraction
//! ([`Rows::gather_into`] + [`GatherBatch`]): one binary arm and one
//! multiclass arm serve dense and CSR data alike, so the dense and
//! sparse coordinator schedules execute identical code (schedule parity
//! by construction, as in the serial solvers).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
// lint:allow(determinism) reason="telemetry timing only; never feeds training arithmetic"
use std::time::Instant;

use crate::data::{Dataset, GatherBatch, MultiDataset, Rows, SparseDataset, SparseMultiDataset};
use crate::kernel::Kernel;
use crate::loss::Loss;
use crate::model::ExpansionStore;
use crate::runtime::{BackendSpec, MultiStepInput, StepInput};
use crate::{Error, Result};

/// The shared training data a worker gathers batches from: binary rows
/// with ±1 labels, or multiclass rows whose per-head ±1 labels the
/// worker derives per batch (label views — the rows are never copied
/// per class). Each layout exists in dense and CSR form; sparse
/// variants gather CSR batches and drive the backend's O(nnz) path.
#[derive(Clone, Debug)]
pub enum WorkerData {
    /// Binary workload (one head), dense rows.
    Binary(Arc<Dataset>),
    /// K-head one-vs-rest workload over shared dense rows.
    Multi(Arc<MultiDataset>),
    /// Binary workload over CSR rows.
    SparseBinary(Arc<SparseDataset>),
    /// K-head one-vs-rest workload over shared CSR rows.
    SparseMulti(Arc<SparseMultiDataset>),
}

impl WorkerData {
    /// Number of examples.
    pub(crate) fn len(&self) -> usize {
        match self {
            WorkerData::Binary(ds) => ds.len(),
            WorkerData::Multi(ds) => ds.len(),
            WorkerData::SparseBinary(ds) => ds.len(),
            WorkerData::SparseMulti(ds) => ds.len(),
        }
    }

    /// Class count of the multiclass layouts.
    pub(crate) fn n_classes(&self) -> Option<usize> {
        match self {
            WorkerData::Multi(ds) => Some(ds.n_classes),
            WorkerData::SparseMulti(ds) => Some(ds.n_classes),
            _ => None,
        }
    }

    /// Borrowed dense-or-CSR [`Rows`] view over the shared feature rows
    /// — the gather abstraction the worker loop (and the leader's
    /// store) runs on.
    pub(crate) fn rows(&self) -> Rows<'_> {
        match self {
            WorkerData::Binary(ds) => ds.rows(),
            WorkerData::Multi(ds) => ds.rows(),
            WorkerData::SparseBinary(ds) => ds.rows(),
            WorkerData::SparseMulti(ds) => ds.rows(),
        }
    }

    /// ±1 labels of the binary layouts.
    fn binary_labels(&self) -> &[f32] {
        match self {
            WorkerData::Binary(ds) => &ds.y,
            WorkerData::SparseBinary(ds) => &ds.y,
            _ => unreachable!("binary labels requested from multiclass worker data"),
        }
    }

    /// Class ids of the multiclass layouts.
    fn class_ids(&self) -> &[u32] {
        match self {
            WorkerData::Multi(ds) => &ds.y,
            WorkerData::SparseMulti(ds) => &ds.y,
            _ => unreachable!("class ids requested from binary worker data"),
        }
    }

    /// A **layout-preserving** expansion store over the full rows —
    /// used by the leader for validation snapshots and the final model.
    /// CSR data yields a CSR-backed store: nothing is densified
    /// anywhere between the training data and the saved model.
    pub(crate) fn store(&self) -> ExpansionStore {
        ExpansionStore::from_rows(self.rows())
    }
}

/// One unit of work: compute the gradient of batch `(ii, jj)` at the
/// given coefficient snapshot.
#[derive(Debug)]
pub struct WorkItem {
    /// Round-trip tag so the leader can order results deterministically.
    pub worker_id: usize,
    /// Gradient sample indices I^(k).
    pub ii: Vec<usize>,
    /// Expansion indices J^(k).
    pub jj: Vec<usize>,
    /// Snapshot of alpha at indices J^(k): `[j]` for binary work,
    /// row-major `[heads, j]` for fused multiclass work.
    pub alpha_j: Vec<f32>,
    /// Regulariser scaling |I|/N.
    pub frac: f32,
}

/// Gradient result for one work item.
#[derive(Debug)]
pub struct WorkResult {
    pub worker_id: usize,
    /// Expansion indices the gradient refers to.
    pub jj: Vec<usize>,
    /// Gradient over `jj`: `[j]` for binary, `[heads, j]` for fused
    /// multiclass work.
    pub g: Vec<f32>,
    /// Masked loss over the I batch (summed across heads).
    pub loss: f32,
    /// Residual-active examples in the I batch (summed across heads).
    pub nactive: f32,
    /// Gradient samples processed (|I|).
    pub points: u64,
    /// Pure compute nanoseconds (excludes channel/queue time) — the
    /// parallelisable fraction measured for the speedup model.
    pub compute_ns: u64,
}

/// Handle to a spawned worker.
pub struct Worker {
    tx: Sender<WorkItem>,
    handle: Option<JoinHandle<()>>,
}

impl Worker {
    /// Spawn worker `id`. Results go to the shared `results` sender.
    pub fn spawn(
        id: usize,
        spec: BackendSpec,
        data: WorkerData,
        kernel: Kernel,
        loss: Loss,
        lam: f32,
        results: Sender<WorkResult>,
    ) -> Worker {
        let (tx, rx): (Sender<WorkItem>, Receiver<WorkItem>) = channel();
        let handle = std::thread::Builder::new()
            .name(format!("dsekl-worker-{id}"))
            .spawn(move || {
                // Backend lives entirely inside the thread.
                let mut backend = match spec.instantiate() {
                    Ok(b) => b,
                    Err(e) => {
                        eprintln!("worker {id}: backend init failed: {e}");
                        return;
                    }
                };
                let mut xi = GatherBatch::default();
                let mut xj = GatherBatch::default();
                let mut yi = Vec::new();
                let mut g = Vec::new();
                while let Ok(item) = rx.recv() {
                    // lint:allow(determinism) reason="telemetry timing only; never feeds training arithmetic"
                    let start = Instant::now();
                    let i = item.ii.len();
                    // Layout-polymorphic gathers: dense data fills dense
                    // batches, CSR data CSR batches — one code path.
                    let rows = data.rows();
                    rows.gather_into(&item.ii, &mut xi);
                    rows.gather_into(&item.jj, &mut xj);
                    let step = match data.n_classes() {
                        None => {
                            let y = data.binary_labels();
                            yi.clear();
                            yi.extend(item.ii.iter().map(|&a| y[a]));
                            backend
                                .dsekl_step(
                                    kernel,
                                    &StepInput {
                                        xi: xi.view(),
                                        yi: &yi,
                                        xj: xj.view(),
                                        alpha: &item.alpha_j,
                                        lam,
                                        frac: item.frac,
                                        loss,
                                    },
                                    &mut g,
                                )
                                .map(|o| (o.loss, o.nactive))
                        }
                        Some(heads) => {
                            // Per-head ±1 label views over the shared
                            // class ids, packed [heads, i].
                            let cls = data.class_ids();
                            yi.clear();
                            for h in 0..heads {
                                yi.extend(
                                    item.ii
                                        .iter()
                                        .map(|&a| if cls[a] == h as u32 { 1.0 } else { -1.0 }),
                                );
                            }
                            backend
                                .dsekl_step_multi(
                                    kernel,
                                    &MultiStepInput {
                                        xi: xi.view(),
                                        yi: &yi,
                                        xj: xj.view(),
                                        alpha: &item.alpha_j,
                                        heads,
                                        lam,
                                        frac: item.frac,
                                        loss,
                                    },
                                    &mut g,
                                )
                                .map(|outs| {
                                    outs.iter().fold((0.0f32, 0.0f32), |(l, a), o| {
                                        (l + o.loss, a + o.nactive)
                                    })
                                })
                        }
                    };
                    let (loss_sum, nactive) = match step {
                        Ok(v) => v,
                        Err(e) => {
                            eprintln!("worker {id}: step failed: {e}");
                            return;
                        }
                    };
                    let res = WorkResult {
                        worker_id: item.worker_id,
                        points: i as u64,
                        jj: item.jj,
                        g: g.clone(),
                        loss: loss_sum,
                        nactive,
                        compute_ns: start.elapsed().as_nanos() as u64,
                    };
                    if results.send(res).is_err() {
                        return; // leader gone
                    }
                }
            })
            .expect("spawn worker thread");
        Worker {
            tx,
            handle: Some(handle),
        }
    }

    /// Queue a work item.
    pub fn submit(&self, item: WorkItem) -> Result<()> {
        self.tx
            .send(item)
            .map_err(|_| Error::Coordinator("worker channel closed".into()))
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        // Close the channel, then join so panics surface.
        let (dead_tx, _) = channel();
        self.tx = dead_tx;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
