//! The worker side of the coordinator protocol.
//!
//! A worker owns a private compute backend (instantiated from the
//! `BackendSpec` *inside* its thread — PJRT clients are not `Send`),
//! gather scratch, and the [`ShardState`] blocks it hosts in `--shards`
//! mode. It is transport-agnostic: [`run`] drives a [`WorkerCtx`] from
//! two closures (receive a [`CoordMsg`], send one back) that the
//! transport layer binds to an `mpsc` channel or a framed socket.
//!
//! Workers serve both workloads over the same message protocol: binary
//! training (one head, [`crate::runtime::Backend::dsekl_step`]) and
//! fused K-head one-vs-rest training, where the leader ships a `[K, j]`
//! coefficient snapshot and the worker computes the shared `|I| x |J|`
//! kernel block **once** for all K heads
//! ([`crate::runtime::Backend::dsekl_step_multi`]), building per-head
//! ±1 labels as views over the shared class ids.
//!
//! Failure discipline: nothing here prints or panics. Every fault —
//! bad message, failed backend, out-of-range index from the wire —
//! returns a structured error that [`run`]'s caller ships back to the
//! leader as a [`CoordMsg::WorkerError`], where it becomes a precise
//! `Error::Coordinator` diagnostic. (The old worker loop `eprintln!`ed
//! and died silently; the leader then hung at the round barrier.)
//!
//! The compute path runs on the gather abstraction
//! ([`Rows::gather_into`] + [`GatherBatch`]): one binary arm and one
//! multiclass arm serve dense and CSR data alike, so the dense and
//! sparse coordinator schedules execute identical code (schedule parity
//! by construction, as in the serial solvers).

use std::sync::Arc;
// lint:allow(determinism) reason="telemetry timing only; never feeds training arithmetic"
use std::time::Instant;

use crate::data::{Dataset, GatherBatch, MultiDataset, Rows, SparseDataset, SparseMultiDataset};
use crate::kernel::Kernel;
use crate::loss::Loss;
use crate::model::ExpansionStore;
use crate::runtime::{Backend, BackendSpec, MultiStepInput, StepInput};
use crate::{Error, Result};

use super::protocol::{CoordMsg, WorkItem, WorkResult};
use super::shard::ShardState;

/// The shared training data a worker gathers batches from: binary rows
/// with ±1 labels, or multiclass rows whose per-head ±1 labels the
/// worker derives per batch (label views — the rows are never copied
/// per class). Each layout exists in dense and CSR form; sparse
/// variants gather CSR batches and drive the backend's O(nnz) path.
#[derive(Clone, Debug)]
pub enum WorkerData {
    /// Binary workload (one head), dense rows.
    Binary(Arc<Dataset>),
    /// K-head one-vs-rest workload over shared dense rows.
    Multi(Arc<MultiDataset>),
    /// Binary workload over CSR rows.
    SparseBinary(Arc<SparseDataset>),
    /// K-head one-vs-rest workload over shared CSR rows.
    SparseMulti(Arc<SparseMultiDataset>),
}

impl WorkerData {
    /// Number of examples.
    pub(crate) fn len(&self) -> usize {
        match self {
            WorkerData::Binary(ds) => ds.len(),
            WorkerData::Multi(ds) => ds.len(),
            WorkerData::SparseBinary(ds) => ds.len(),
            WorkerData::SparseMulti(ds) => ds.len(),
        }
    }

    /// Class count of the multiclass layouts.
    pub(crate) fn n_classes(&self) -> Option<usize> {
        match self {
            WorkerData::Multi(ds) => Some(ds.n_classes),
            WorkerData::SparseMulti(ds) => Some(ds.n_classes),
            _ => None,
        }
    }

    /// Borrowed dense-or-CSR [`Rows`] view over the shared feature rows
    /// — the gather abstraction the worker loop (and the leader's
    /// store) runs on.
    pub(crate) fn rows(&self) -> Rows<'_> {
        match self {
            WorkerData::Binary(ds) => ds.rows(),
            WorkerData::Multi(ds) => ds.rows(),
            WorkerData::SparseBinary(ds) => ds.rows(),
            WorkerData::SparseMulti(ds) => ds.rows(),
        }
    }

    /// ±1 labels of the binary layouts.
    fn binary_labels(&self) -> Result<&[f32]> {
        match self {
            WorkerData::Binary(ds) => Ok(&ds.y),
            WorkerData::SparseBinary(ds) => Ok(&ds.y),
            _ => Err(Error::Coordinator(
                "binary labels requested from multiclass worker data".into(),
            )),
        }
    }

    /// Class ids of the multiclass layouts.
    fn class_ids(&self) -> Result<&[u32]> {
        match self {
            WorkerData::Multi(ds) => Ok(&ds.y),
            WorkerData::SparseMulti(ds) => Ok(&ds.y),
            _ => Err(Error::Coordinator(
                "class ids requested from binary worker data".into(),
            )),
        }
    }

    /// A **layout-preserving** expansion store over the full rows —
    /// used by the leader for validation snapshots and the final model.
    /// CSR data yields a CSR-backed store: nothing is densified
    /// anywhere between the training data and the saved model.
    pub(crate) fn store(&self) -> ExpansionStore {
        ExpansionStore::from_rows(self.rows())
    }
}

/// One worker's state across a training run: backend, gather scratch,
/// and the shard blocks it hosts.
pub(crate) struct WorkerCtx {
    data: WorkerData,
    kernel: Kernel,
    loss: Loss,
    lam: f32,
    backend: Box<dyn Backend>,
    xi: GatherBatch,
    xj: GatherBatch,
    yi: Vec<f32>,
    g: Vec<f32>,
    shards: Vec<ShardState>,
}

impl WorkerCtx {
    /// Instantiate the backend and the scratch. Must run inside the
    /// worker's own thread (backends are not `Send`).
    pub(crate) fn new(
        spec: &BackendSpec,
        data: WorkerData,
        kernel: Kernel,
        loss: Loss,
        lam: f32,
    ) -> Result<Self> {
        let backend = spec
            .instantiate()
            .map_err(|e| Error::Coordinator(format!("backend init failed: {e}")))?;
        Ok(WorkerCtx {
            data,
            kernel,
            loss,
            lam,
            backend,
            xi: GatherBatch::default(),
            xj: GatherBatch::default(),
            yi: Vec::new(),
            g: Vec::new(),
            shards: Vec::new(),
        })
    }

    /// Handle one leader message. `Ok(Some(reply))` ships back,
    /// `Ok(None)` is a clean shutdown, `Err` is a fault the transport
    /// reports as a [`CoordMsg::WorkerError`].
    pub(crate) fn handle(&mut self, msg: CoordMsg) -> Result<Option<CoordMsg>> {
        match msg {
            CoordMsg::Work(item) => Ok(Some(CoordMsg::Delta(self.compute(item)?))),
            CoordMsg::ShardUpdate(upd) => {
                let idx = match self.shards.iter().position(|s| s.shard() == upd.shard) {
                    Some(i) => i,
                    None => {
                        self.shards.push(ShardState::new(upd.shard, upd.of));
                        self.shards.len() - 1
                    }
                };
                let state = self
                    .shards
                    .get_mut(idx)
                    .ok_or_else(|| Error::Coordinator("shard state vanished".into()))?;
                if state.of() != upd.of {
                    return Err(Error::Coordinator(format!(
                        "shard count changed mid-run: hosting {} of {}, update says of {}",
                        state.shard(),
                        state.of(),
                        upd.of
                    )));
                }
                Ok(Some(CoordMsg::ShardDelta(state.apply(&upd)?)))
            }
            CoordMsg::Shutdown => Ok(None),
            other => Err(Error::Coordinator(format!(
                "protocol violation: worker received a {} message",
                other.kind()
            ))),
        }
    }

    /// Validate and compute one gradient batch. Work items arrive over
    /// a wire on the socket transport, so every index is checked
    /// against the dataset before any gather touches it.
    fn compute(&mut self, item: WorkItem) -> Result<WorkResult> {
        // lint:allow(determinism) reason="telemetry timing only; never feeds training arithmetic"
        let start = Instant::now();
        let n = self.data.len();
        if item.ii.is_empty() || item.jj.is_empty() {
            return Err(Error::Coordinator("work item with an empty index batch".into()));
        }
        if let Some(&bad) = item.ii.iter().find(|&&a| a >= n) {
            return Err(Error::Coordinator(format!(
                "gradient index {bad} outside the {n}-point dataset"
            )));
        }
        if let Some(&bad) = item.jj.iter().find(|&&j| j >= n) {
            return Err(Error::Coordinator(format!(
                "expansion index {bad} outside the {n}-point dataset"
            )));
        }
        let heads = self.data.n_classes().unwrap_or(1);
        if item.alpha_j.len() != heads * item.jj.len() {
            return Err(Error::Coordinator(format!(
                "alpha snapshot of {} values for {} heads x {} indices",
                item.alpha_j.len(),
                heads,
                item.jj.len()
            )));
        }
        if !(item.frac > 0.0 && item.frac <= 1.0) {
            return Err(Error::Coordinator(format!(
                "regulariser fraction {} outside (0, 1]",
                item.frac
            )));
        }

        // Layout-polymorphic gathers: dense data fills dense batches,
        // CSR data CSR batches — one code path.
        let rows = self.data.rows();
        rows.gather_into(&item.ii, &mut self.xi);
        rows.gather_into(&item.jj, &mut self.xj);
        let step = match self.data.n_classes() {
            None => {
                let y = self.data.binary_labels()?;
                self.yi.clear();
                // lint:allow(panic) reason="ii bounds-checked against the dataset above; labels are len()-long by dataset invariant"
                self.yi.extend(item.ii.iter().map(|&a| y[a]));
                self.backend
                    .dsekl_step(
                        self.kernel,
                        &StepInput {
                            xi: self.xi.view(),
                            yi: &self.yi,
                            xj: self.xj.view(),
                            alpha: &item.alpha_j,
                            lam: self.lam,
                            frac: item.frac,
                            loss: self.loss,
                        },
                        &mut self.g,
                    )
                    .map(|o| (o.loss, o.nactive))
            }
            Some(heads) => {
                // Per-head ±1 label views over the shared class ids,
                // packed [heads, i].
                let cls = self.data.class_ids()?;
                self.yi.clear();
                for h in 0..heads {
                    let hid = h as u32;
                    // lint:allow(panic) reason="ii bounds-checked against the dataset above; class ids are len()-long by dataset invariant"
                    let pm1 = |&a: &usize| if cls[a] == hid { 1.0 } else { -1.0 };
                    self.yi.extend(item.ii.iter().map(pm1));
                }
                self.backend
                    .dsekl_step_multi(
                        self.kernel,
                        &MultiStepInput {
                            xi: self.xi.view(),
                            yi: &self.yi,
                            xj: self.xj.view(),
                            alpha: &item.alpha_j,
                            heads,
                            lam: self.lam,
                            frac: item.frac,
                            loss: self.loss,
                        },
                        &mut self.g,
                    )
                    .map(|outs| {
                        outs.iter()
                            .fold((0.0f32, 0.0f32), |(l, a), o| (l + o.loss, a + o.nactive))
                    })
            }
        };
        let (loss_sum, nactive) =
            step.map_err(|e| Error::Coordinator(format!("step failed: {e}")))?;
        Ok(WorkResult {
            item: item.item,
            points: item.ii.len() as u64,
            jj: item.jj,
            g: self.g.clone(),
            loss: loss_sum,
            nactive,
            compute_ns: start.elapsed().as_nanos() as u64,
        })
    }
}

/// Drive a worker until shutdown: `recv` yields the next message
/// (`Ok(None)` = link closed, treated as shutdown), `send` ships a
/// reply (`false` = leader gone, exit quietly). Any `Err` is a worker
/// fault the transport reports back to the leader.
pub(crate) fn run<R, S>(
    spec: &BackendSpec,
    data: WorkerData,
    kernel: Kernel,
    loss: Loss,
    lam: f32,
    recv: &mut R,
    send: &mut S,
) -> Result<()>
where
    R: FnMut() -> Result<Option<CoordMsg>>,
    S: FnMut(CoordMsg) -> bool,
{
    let mut ctx = WorkerCtx::new(spec, data, kernel, loss, lam)?;
    while let Some(msg) = recv()? {
        match ctx.handle(msg)? {
            Some(reply) => {
                if !send(reply) {
                    return Ok(());
                }
            }
            None => return Ok(()),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::rng::Pcg64;

    fn ctx(n: usize) -> WorkerCtx {
        let mut rng = Pcg64::seed_from(1);
        let ds = Arc::new(synth::xor(n, 0.2, &mut rng));
        WorkerCtx::new(
            &BackendSpec::Native,
            WorkerData::Binary(ds),
            Kernel::Rbf { gamma: 1.0 },
            Loss::Hinge,
            1e-4,
        )
        .unwrap()
    }

    #[test]
    fn work_item_produces_delta_with_per_item_frac() {
        let mut c = ctx(20);
        let reply = c
            .handle(CoordMsg::Work(WorkItem {
                item: 3,
                ii: vec![0, 1, 2],
                jj: vec![4, 5],
                alpha_j: vec![0.0, 0.0],
                frac: 3.0 / 20.0,
            }))
            .unwrap()
            .unwrap();
        match reply {
            CoordMsg::Delta(r) => {
                assert_eq!(r.item, 3);
                assert_eq!(r.points, 3);
                assert_eq!(r.jj, vec![4, 5]);
                assert_eq!(r.g.len(), 2);
            }
            other => panic!("expected a delta, got {}", other.kind()),
        }
    }

    #[test]
    fn hostile_work_items_error_instead_of_panicking() {
        let mut c = ctx(10);
        // Out-of-range gradient index.
        assert!(c
            .handle(CoordMsg::Work(WorkItem {
                item: 0,
                ii: vec![99],
                jj: vec![0],
                alpha_j: vec![0.0],
                frac: 0.1,
            }))
            .is_err());
        // Out-of-range expansion index.
        assert!(c
            .handle(CoordMsg::Work(WorkItem {
                item: 0,
                ii: vec![0],
                jj: vec![10],
                alpha_j: vec![0.0],
                frac: 0.1,
            }))
            .is_err());
        // Mis-sized coefficient snapshot.
        assert!(c
            .handle(CoordMsg::Work(WorkItem {
                item: 0,
                ii: vec![0],
                jj: vec![1, 2],
                alpha_j: vec![0.0],
                frac: 0.1,
            }))
            .is_err());
        // Nonsense regulariser fraction.
        assert!(c
            .handle(CoordMsg::Work(WorkItem {
                item: 0,
                ii: vec![0],
                jj: vec![1],
                alpha_j: vec![0.0],
                frac: f32::NAN,
            }))
            .is_err());
        // Leader-only messages are protocol violations on a worker.
        assert!(c.handle(CoordMsg::Hello { worker: 0 }).is_err());
        assert!(c
            .handle(CoordMsg::WorkerError {
                worker: 0,
                message: "x".into()
            })
            .is_err());
        // Shutdown is the clean exit.
        assert!(matches!(c.handle(CoordMsg::Shutdown), Ok(None)));
    }

    #[test]
    fn shard_updates_route_to_hosted_state() {
        use super::super::protocol::ShardUpdate;
        let mut c = ctx(10);
        let reply = c
            .handle(CoordMsg::ShardUpdate(ShardUpdate {
                shard: 1,
                of: 2,
                eta: 0.5,
                slots: vec![1, 3],
                grads: vec![1.0, -1.0],
            }))
            .unwrap()
            .unwrap();
        match reply {
            CoordMsg::ShardDelta(d) => {
                assert_eq!(d.shard, 1);
                assert_eq!(d.deltas.len(), 2);
            }
            other => panic!("expected a shard delta, got {}", other.kind()),
        }
        // A second update for the same shard reuses the state (AdaGrad
        // keeps accumulating), and a conflicting shard count errors.
        assert!(c
            .handle(CoordMsg::ShardUpdate(ShardUpdate {
                shard: 1,
                of: 4,
                eta: 0.5,
                slots: vec![1],
                grads: vec![1.0],
            }))
            .is_err());
    }
}
