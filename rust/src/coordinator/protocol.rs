//! The leader↔worker wire protocol of the parallel coordinator.
//!
//! Every exchange between the leader and its workers is a [`CoordMsg`]
//! with a length-prefixed little-endian binary encoding, mirroring the
//! bounds-checked framing idioms of [`crate::serve::protocol`] (whose
//! `write_frame`/`read_frame` carry these payloads on the socket
//! transport). Making the exchange message-shaped — instead of shared
//! memory through ad-hoc channel pairs — is what lets the same round
//! logic run threaded, multi-process, or over a socket, and it turns
//! two long-standing bugs into protocol properties:
//!
//! * worker failures travel back as [`CoordMsg::WorkerError`] messages
//!   (never `eprintln!` into the void), so the leader surfaces a
//!   precise `worker K died: <cause>` diagnostic within bounded time;
//! * the regulariser scaling `frac` rides in each [`WorkItem`],
//!   computed from the **actual** `ii.len()` — tail batches of a
//!   partial epoch regularise by their true size, not by `i_size`.
//!
//! Messages start with a one-byte opcode:
//!
//! | op | message | direction | body |
//! |----|---------|-----------|------|
//! | 1  | hello        | worker → leader | `u32 worker` (socket handshake) |
//! | 2  | work         | leader → worker | `u32 item, f32 frac, u32 i, u32 j, u32 a, u32 ii[i], u32 jj[j], f32 alpha_j[a]` |
//! | 3  | shard update | leader → worker | `u32 shard, u32 of, f32 eta, u32 c, u32 slots[c], f32 grads[c]` |
//! | 4  | shutdown     | leader → worker | — |
//! | 5  | delta        | worker → leader | `u32 item, u64 points, u64 compute_ns, f32 loss, f32 nactive, u32 j, u32 g, u32 jj[j], f32 g[g]` |
//! | 6  | shard delta  | worker → leader | `u32 shard, u32 c, f32 deltas[c]` |
//! | 7  | worker error | worker → leader | `u32 worker, utf8 message` |
//!
//! Every decoder validates counts against the bytes actually present
//! and rejects trailing junk, so a corrupt or truncated frame degrades
//! to an error instead of a panic or an over-allocation
//! (`rust/tests/no_panic_fuzz.rs` fuzzes exactly this contract).

use crate::{Error, Result};

const OP_HELLO: u8 = 1;
const OP_WORK: u8 = 2;
const OP_SHARD_UPDATE: u8 = 3;
const OP_SHUTDOWN: u8 = 4;
const OP_DELTA: u8 = 5;
const OP_SHARD_DELTA: u8 = 6;
const OP_WORKER_ERR: u8 = 7;

/// One unit of work: compute the gradient of batch `(ii, jj)` at the
/// given coefficient snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkItem {
    /// Dispatch-order tag so the leader can order results
    /// deterministically at the round barrier.
    pub item: usize,
    /// Gradient sample indices I^(k).
    pub ii: Vec<usize>,
    /// Expansion indices J^(k).
    pub jj: Vec<usize>,
    /// Snapshot of alpha at indices J^(k): `[j]` for binary work,
    /// row-major `[heads, j]` for fused multiclass work.
    pub alpha_j: Vec<f32>,
    /// Regulariser scaling `|I|/N` of **this** batch — computed from
    /// `ii.len()`, so a short tail batch regularises by its true size.
    pub frac: f32,
}

/// Gradient result for one work item.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkResult {
    /// Echo of [`WorkItem::item`].
    pub item: usize,
    /// Expansion indices the gradient refers to.
    pub jj: Vec<usize>,
    /// Gradient over `jj`: `[j]` for binary, `[heads, j]` for fused
    /// multiclass work.
    pub g: Vec<f32>,
    /// Masked loss over the I batch (summed across heads).
    pub loss: f32,
    /// Residual-active examples in the I batch (summed across heads).
    pub nactive: f32,
    /// Gradient samples processed (|I|).
    pub points: u64,
    /// Pure compute nanoseconds (excludes channel/queue time) — the
    /// parallelisable fraction measured for the speedup model.
    pub compute_ns: u64,
}

/// Per-round AdaGrad work routed to the shard that owns the slots: the
/// `(slot, gradient)` sequence in **global traversal order** (items by
/// id, heads major, batch positions minor), restricted to slots owned
/// by `shard`. Applying per-slot sequences in this order is what keeps
/// sharded training bitwise equal to the leader-applied path.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardUpdate {
    /// Owning shard (slots with `slot % of == shard`).
    pub shard: usize,
    /// Total shard count W.
    pub of: usize,
    /// Epoch learning rate for the dampened step.
    pub eta: f32,
    /// Global `[K, n]` grid slots, each owned by `shard`.
    pub slots: Vec<usize>,
    /// Gradient values, parallel to `slots`.
    pub grads: Vec<f32>,
}

/// The shard's reply: dampened coefficient deltas, parallel to the
/// update's `slots` order. The leader merges these back into the
/// global traversal order to update its replica and the epoch-change
/// norm bitwise-identically to the unsharded path.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardDelta {
    /// Echo of [`ShardUpdate::shard`].
    pub shard: usize,
    /// `alpha[slot] -= delta`, parallel to the update's `slots`.
    pub deltas: Vec<f32>,
}

/// One leader↔worker protocol message.
#[derive(Debug, Clone, PartialEq)]
pub enum CoordMsg {
    /// Socket-transport handshake: the connecting worker identifies
    /// itself so the leader maps connections to worker ids
    /// deterministically regardless of accept order.
    Hello {
        /// The worker's id.
        worker: usize,
    },
    /// Leader → worker: compute a gradient batch.
    Work(WorkItem),
    /// Leader → worker: apply AdaGrad steps on an owned slot block.
    ShardUpdate(ShardUpdate),
    /// Leader → worker: exit cleanly.
    Shutdown,
    /// Worker → leader: a gradient result.
    Delta(WorkResult),
    /// Worker → leader: dampened deltas for an owned slot block.
    ShardDelta(ShardDelta),
    /// Worker → leader: the worker failed; the message is the precise
    /// cause the leader surfaces as `Error::Coordinator`.
    WorkerError {
        /// The failing worker's id.
        worker: usize,
        /// Human-readable cause (`worker K died: …`).
        message: String,
    },
}

impl CoordMsg {
    /// Short message-kind name for protocol-violation diagnostics.
    pub fn kind(&self) -> &'static str {
        match self {
            CoordMsg::Hello { .. } => "hello",
            CoordMsg::Work(_) => "work",
            CoordMsg::ShardUpdate(_) => "shard-update",
            CoordMsg::Shutdown => "shutdown",
            CoordMsg::Delta(_) => "delta",
            CoordMsg::ShardDelta(_) => "shard-delta",
            CoordMsg::WorkerError { .. } => "worker-error",
        }
    }
}

/// Checked `usize → u32` narrowing for wire counts and indices.
fn wire_u32(v: usize, what: &str) -> Result<u32> {
    u32::try_from(v).map_err(|_| Error::invalid(format!("{what} {v} exceeds the u32 wire range")))
}

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_idxs(out: &mut Vec<u8>, idxs: &[usize], what: &str) -> Result<()> {
    for &v in idxs {
        push_u32(out, wire_u32(v, what)?);
    }
    Ok(())
}

fn push_f32s(out: &mut Vec<u8>, vals: &[f32]) {
    for &v in vals {
        push_f32(out, v);
    }
}

/// Encode one message to its payload bytes (framing is the caller's:
/// [`crate::serve::protocol::write_frame`] on the socket transport).
pub fn encode_msg(msg: &CoordMsg) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    match msg {
        CoordMsg::Hello { worker } => {
            out.push(OP_HELLO);
            push_u32(&mut out, wire_u32(*worker, "worker id")?);
        }
        CoordMsg::Work(w) => {
            out.push(OP_WORK);
            push_u32(&mut out, wire_u32(w.item, "work item id")?);
            push_f32(&mut out, w.frac);
            push_u32(&mut out, wire_u32(w.ii.len(), "gradient batch size")?);
            push_u32(&mut out, wire_u32(w.jj.len(), "expansion batch size")?);
            push_u32(&mut out, wire_u32(w.alpha_j.len(), "alpha snapshot size")?);
            push_idxs(&mut out, &w.ii, "gradient index")?;
            push_idxs(&mut out, &w.jj, "expansion index")?;
            push_f32s(&mut out, &w.alpha_j);
        }
        CoordMsg::ShardUpdate(u) => {
            out.push(OP_SHARD_UPDATE);
            push_u32(&mut out, wire_u32(u.shard, "shard id")?);
            push_u32(&mut out, wire_u32(u.of, "shard count")?);
            push_f32(&mut out, u.eta);
            if u.slots.len() != u.grads.len() {
                return Err(Error::invalid(format!(
                    "shard update with {} slots but {} gradients",
                    u.slots.len(),
                    u.grads.len()
                )));
            }
            push_u32(&mut out, wire_u32(u.slots.len(), "shard update size")?);
            push_idxs(&mut out, &u.slots, "shard slot")?;
            push_f32s(&mut out, &u.grads);
        }
        CoordMsg::Shutdown => out.push(OP_SHUTDOWN),
        CoordMsg::Delta(r) => {
            out.push(OP_DELTA);
            push_u32(&mut out, wire_u32(r.item, "result item id")?);
            push_u64(&mut out, r.points);
            push_u64(&mut out, r.compute_ns);
            push_f32(&mut out, r.loss);
            push_f32(&mut out, r.nactive);
            push_u32(&mut out, wire_u32(r.jj.len(), "result expansion size")?);
            push_u32(&mut out, wire_u32(r.g.len(), "result gradient size")?);
            push_idxs(&mut out, &r.jj, "expansion index")?;
            push_f32s(&mut out, &r.g);
        }
        CoordMsg::ShardDelta(d) => {
            out.push(OP_SHARD_DELTA);
            push_u32(&mut out, wire_u32(d.shard, "shard id")?);
            push_u32(&mut out, wire_u32(d.deltas.len(), "shard delta size")?);
            push_f32s(&mut out, &d.deltas);
        }
        CoordMsg::WorkerError { worker, message } => {
            out.push(OP_WORKER_ERR);
            push_u32(&mut out, wire_u32(*worker, "worker id")?);
            out.extend_from_slice(message.as_bytes());
        }
    }
    Ok(out)
}

/// Byte cursor over a message payload; every take is bounds-checked
/// (same idiom as the serve protocol's cursor).
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cur { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| Error::parse("coordinator message truncated"))?;
        let s = self
            .buf
            .get(self.pos..end)
            .ok_or_else(|| Error::parse("coordinator message truncated"))?;
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        let b = self.take(1)?;
        b.first()
            .copied()
            .ok_or_else(|| Error::parse("coordinator message truncated"))
    }

    fn u32(&mut self) -> Result<u32> {
        let b: [u8; 4] = self
            .take(4)?
            .try_into()
            .map_err(|_| Error::parse("coordinator message truncated"))?;
        Ok(u32::from_le_bytes(b))
    }

    fn u64(&mut self) -> Result<u64> {
        let b: [u8; 8] = self
            .take(8)?
            .try_into()
            .map_err(|_| Error::parse("coordinator message truncated"))?;
        Ok(u64::from_le_bytes(b))
    }

    fn f32(&mut self) -> Result<f32> {
        let b: [u8; 4] = self
            .take(4)?
            .try_into()
            .map_err(|_| Error::parse("coordinator message truncated"))?;
        Ok(f32::from_le_bytes(b))
    }

    fn idxs(&mut self, n: usize) -> Result<Vec<usize>> {
        let bytes = self.take(
            n.checked_mul(4)
                .ok_or_else(|| Error::parse("coordinator count overflow"))?,
        )?;
        let mut out = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            let mut quad = [0u8; 4];
            quad.copy_from_slice(c);
            out.push(u32::from_le_bytes(quad) as usize);
        }
        Ok(out)
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let bytes = self.take(
            n.checked_mul(4)
                .ok_or_else(|| Error::parse("coordinator count overflow"))?,
        )?;
        let mut out = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            let mut quad = [0u8; 4];
            quad.copy_from_slice(c);
            out.push(f32::from_le_bytes(quad));
        }
        Ok(out)
    }

    fn rest(&mut self) -> &'a [u8] {
        let s = self.buf.get(self.pos..).unwrap_or(&[]);
        self.pos = self.buf.len();
        s
    }

    /// Error if undecoded bytes remain — rejects trailing junk.
    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(Error::parse(format!(
                "{} trailing bytes after coordinator message body",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn utf8(bytes: &[u8]) -> Result<String> {
    String::from_utf8(bytes.to_vec())
        .map_err(|_| Error::parse("invalid utf8 in coordinator message"))
}

/// Decode one message payload. Counts are validated against the bytes
/// actually present and trailing junk is rejected, so hostile input
/// degrades to an error — never a panic or an unbounded allocation.
pub fn decode_msg(buf: &[u8]) -> Result<CoordMsg> {
    let mut c = Cur::new(buf);
    let op = c
        .u8()
        .map_err(|_| Error::parse("empty coordinator frame"))?;
    match op {
        OP_HELLO => {
            let worker = c.u32()? as usize;
            c.done()?;
            Ok(CoordMsg::Hello { worker })
        }
        OP_WORK => {
            let item = c.u32()? as usize;
            let frac = c.f32()?;
            let i_len = c.u32()? as usize;
            let j_len = c.u32()? as usize;
            let a_len = c.u32()? as usize;
            if i_len == 0 || j_len == 0 {
                return Err(Error::parse("work item with an empty index batch"));
            }
            let ii = c.idxs(i_len)?;
            let jj = c.idxs(j_len)?;
            let alpha_j = c.f32s(a_len)?;
            c.done()?;
            Ok(CoordMsg::Work(WorkItem {
                item,
                ii,
                jj,
                alpha_j,
                frac,
            }))
        }
        OP_SHARD_UPDATE => {
            let shard = c.u32()? as usize;
            let of = c.u32()? as usize;
            let eta = c.f32()?;
            if of == 0 || shard >= of {
                return Err(Error::parse(format!(
                    "shard update names shard {shard} of {of}"
                )));
            }
            let count = c.u32()? as usize;
            let slots = c.idxs(count)?;
            let grads = c.f32s(count)?;
            c.done()?;
            Ok(CoordMsg::ShardUpdate(ShardUpdate {
                shard,
                of,
                eta,
                slots,
                grads,
            }))
        }
        OP_SHUTDOWN => {
            c.done()?;
            Ok(CoordMsg::Shutdown)
        }
        OP_DELTA => {
            let item = c.u32()? as usize;
            let points = c.u64()?;
            let compute_ns = c.u64()?;
            let loss = c.f32()?;
            let nactive = c.f32()?;
            let j_len = c.u32()? as usize;
            let g_len = c.u32()? as usize;
            let jj = c.idxs(j_len)?;
            let g = c.f32s(g_len)?;
            c.done()?;
            Ok(CoordMsg::Delta(WorkResult {
                item,
                jj,
                g,
                loss,
                nactive,
                points,
                compute_ns,
            }))
        }
        OP_SHARD_DELTA => {
            let shard = c.u32()? as usize;
            let count = c.u32()? as usize;
            let deltas = c.f32s(count)?;
            c.done()?;
            Ok(CoordMsg::ShardDelta(ShardDelta { shard, deltas }))
        }
        OP_WORKER_ERR => {
            let worker = c.u32()? as usize;
            let message = utf8(c.rest())?;
            c.done()?;
            Ok(CoordMsg::WorkerError { worker, message })
        }
        other => Err(Error::parse(format!(
            "unknown coordinator opcode {other}"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: CoordMsg) {
        let bytes = encode_msg(&msg).expect("encode");
        let back = decode_msg(&bytes).expect("decode");
        assert_eq!(back, msg);
    }

    #[test]
    fn every_variant_roundtrips() {
        roundtrip(CoordMsg::Hello { worker: 3 });
        roundtrip(CoordMsg::Work(WorkItem {
            item: 7,
            ii: vec![0, 5, 2],
            jj: vec![4, 1],
            alpha_j: vec![0.5, -1.25, 2.0, 0.0],
            frac: 0.125,
        }));
        roundtrip(CoordMsg::ShardUpdate(ShardUpdate {
            shard: 1,
            of: 4,
            eta: 0.3,
            slots: vec![1, 5, 9],
            grads: vec![0.1, -0.2, 0.3],
        }));
        roundtrip(CoordMsg::Shutdown);
        roundtrip(CoordMsg::Delta(WorkResult {
            item: 2,
            jj: vec![3, 0],
            g: vec![1.5, -0.5, 0.25, 0.75],
            loss: 0.9,
            nactive: 4.0,
            points: 16,
            compute_ns: 123_456,
        }));
        roundtrip(CoordMsg::ShardDelta(ShardDelta {
            shard: 0,
            deltas: vec![0.01, -0.02],
        }));
        roundtrip(CoordMsg::WorkerError {
            worker: 2,
            message: "worker 2 died: step failed: kernel mismatch".into(),
        });
    }

    #[test]
    fn decode_rejects_malformed() {
        // Empty frame, unknown opcode, trailing junk.
        assert!(decode_msg(&[]).is_err());
        assert!(decode_msg(&[99]).is_err());
        assert!(decode_msg(&[OP_SHUTDOWN, 0]).is_err());
        // Truncated work item.
        let mut ok = encode_msg(&CoordMsg::Work(WorkItem {
            item: 0,
            ii: vec![1, 2],
            jj: vec![3],
            alpha_j: vec![0.5],
            frac: 0.5,
        }))
        .unwrap();
        ok.truncate(ok.len() - 2);
        assert!(decode_msg(&ok).is_err());
        // Empty batches are rejected at decode.
        let mut empty = vec![OP_WORK];
        empty.extend_from_slice(&0u32.to_le_bytes());
        empty.extend_from_slice(&0.5f32.to_le_bytes());
        empty.extend_from_slice(&0u32.to_le_bytes());
        empty.extend_from_slice(&0u32.to_le_bytes());
        empty.extend_from_slice(&0u32.to_le_bytes());
        assert!(decode_msg(&empty).is_err());
        // Shard update naming a shard outside its own count.
        let mut bad = vec![OP_SHARD_UPDATE];
        bad.extend_from_slice(&5u32.to_le_bytes());
        bad.extend_from_slice(&2u32.to_le_bytes());
        bad.extend_from_slice(&0.1f32.to_le_bytes());
        bad.extend_from_slice(&0u32.to_le_bytes());
        assert!(decode_msg(&bad).is_err());
        // A count that claims more elements than the frame carries.
        let mut short = vec![OP_SHARD_DELTA];
        short.extend_from_slice(&0u32.to_le_bytes());
        short.extend_from_slice(&1000u32.to_le_bytes());
        short.extend_from_slice(&1.0f32.to_le_bytes());
        assert!(decode_msg(&short).is_err());
        // Invalid utf8 in a worker error.
        let mut junk = vec![OP_WORKER_ERR];
        junk.extend_from_slice(&1u32.to_le_bytes());
        junk.extend_from_slice(&[0xFF, 0xFE]);
        assert!(decode_msg(&junk).is_err());
    }

    #[test]
    fn oversized_counts_error_on_encode() {
        let huge = CoordMsg::Hello {
            worker: u32::MAX as usize + 1,
        };
        assert!(encode_msg(&huge).is_err());
        let mismatched = CoordMsg::ShardUpdate(ShardUpdate {
            shard: 0,
            of: 1,
            eta: 0.1,
            slots: vec![0, 1],
            grads: vec![0.5],
        });
        assert!(encode_msg(&mismatched).is_err());
    }
}
