//! Trained-model representation: shared-ownership expansion storage
//! ([`ExpansionStore`] — dense **or CSR** rows behind an `Arc`), the
//! single-head kernel expansion view ([`KernelModel`], Eq. 1 of the
//! paper), the K-head one-vs-rest model ([`MulticlassModel`]) whose
//! heads share one row block, prediction helpers, support-vector
//! compaction, and self-describing binary save/load formats:
//!
//! * **DSEKLv1** — single head, dense rows;
//! * **DSEKLv2** — K heads, one dense row block;
//! * **DSEKLv3** — 1..K heads over one **CSR** row block, so a model
//!   trained on sparse data serialises in O(nnz) bytes;
//! * **DSEKLmc1** — legacy per-head container; still loads;
//! * **DSEKLrk1** — RKS primal model (random-feature weights).
//!
//! [`load_model`] sniffs the 8-byte magic and dispatches to whichever
//! family the file holds, so callers never need to know the format in
//! advance; the per-family loaders ([`KernelModel::load`],
//! [`MulticlassModel::load`], [`RksModel::load`]) reject files of the
//! wrong family with a precise error naming the format and head count
//! found.
//!
//! Prediction paths serve the store as a [`Rows`] view, so CSR-backed
//! models run the O(nnz) kernels end-to-end — nothing between libsvm
//! input and a saved model ever densifies the expansion rows.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;
use std::sync::Arc;

use crate::data::{CsrBlock, Dataset, MultiDataset, Rows, SparseDataset, SparseMultiDataset};
use crate::kernel::Kernel;
use crate::metrics::error_rate;
use crate::runtime::Backend;
use crate::{Error, Result};

const MAGIC: &[u8; 8] = b"DSEKLv1\0";

/// Shared-ownership expansion-point storage: one immutable row block
/// behind an `Arc` — dense row-major `[n, d]` or an owned CSR block —
/// so any number of model heads (the K one-vs-rest machines, compacted
/// views, coordinator snapshots) can reference the same rows without
/// copying them. Cloning an `ExpansionStore` clones the `Arc`, never
/// the floats. Consumers read the rows through [`ExpansionStore::view`],
/// which keeps every prediction path layout-polymorphic: a CSR-backed
/// store runs the O(nnz) kernel contractions, never a densified copy.
#[derive(Clone, Debug)]
pub enum ExpansionStore {
    /// Dense row-major `[n, d]` rows.
    Dense { rows: Arc<[f32]>, d: usize },
    /// CSR rows (O(nnz) storage — what `--sparse` training produces).
    Csr(Arc<CsrBlock>),
}

impl ExpansionStore {
    /// Take ownership of a row-major dense `[n, d]` block.
    pub fn new(rows: Vec<f32>, d: usize) -> Self {
        if d > 0 {
            assert_eq!(rows.len() % d, 0, "row block not a multiple of d");
        }
        ExpansionStore::Dense {
            rows: rows.into(),
            d,
        }
    }

    /// Take ownership of a CSR row block.
    pub fn from_csr(block: CsrBlock) -> Self {
        ExpansionStore::Csr(Arc::new(block))
    }

    /// Layout-preserving copy of a borrowed [`Rows`] view: dense rows
    /// become a dense store, CSR rows a CSR store. This is the one
    /// place training data is copied into a model — there is no
    /// densification step anywhere.
    pub fn from_rows(rows: Rows) -> Self {
        match rows {
            Rows::Dense { x, d, .. } => ExpansionStore::new(x.to_vec(), d),
            Rows::Csr(c) => ExpansionStore::from_csr(CsrBlock::from_csr(c)),
        }
    }

    /// Number of expansion points.
    pub fn len(&self) -> usize {
        match self {
            ExpansionStore::Dense { rows, d } => {
                if *d == 0 {
                    0
                } else {
                    rows.len() / d
                }
            }
            ExpansionStore::Csr(b) => b.len(),
        }
    }

    /// True when the store holds no rows.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        match self {
            ExpansionStore::Dense { d, .. } => *d,
            ExpansionStore::Csr(b) => b.dim(),
        }
    }

    /// Borrowed [`Rows`] view over the stored rows — what every
    /// prediction path hands the backend.
    pub fn view(&self) -> Rows<'_> {
        match self {
            ExpansionStore::Dense { rows, d } => Rows::dense(rows, self.len(), *d),
            ExpansionStore::Csr(b) => Rows::Csr(b.view()),
        }
    }

    /// True for the dense layout.
    pub fn is_dense(&self) -> bool {
        matches!(self, ExpansionStore::Dense { .. })
    }

    /// The raw dense row block, when dense.
    pub fn dense_rows(&self) -> Option<&[f32]> {
        match self {
            ExpansionStore::Dense { rows, .. } => Some(rows),
            ExpansionStore::Csr(_) => None,
        }
    }

    /// The CSR row block, when CSR.
    pub fn csr_block(&self) -> Option<&CsrBlock> {
        match self {
            ExpansionStore::Csr(b) => Some(b),
            ExpansionStore::Dense { .. } => None,
        }
    }

    /// Whether two stores share the same allocation (not just equal
    /// contents) — the invariant the multi-head formats preserve.
    pub fn shares_rows_with(&self, other: &ExpansionStore) -> bool {
        match (self, other) {
            (ExpansionStore::Dense { rows: a, .. }, ExpansionStore::Dense { rows: b, .. }) => {
                Arc::ptr_eq(a, b)
            }
            (ExpansionStore::Csr(a), ExpansionStore::Csr(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }

    /// Whether two stores hold the same rows in the same layout
    /// (content equality, allocation-independent) — what
    /// [`MulticlassModel::new`] deduplicates on.
    pub fn content_eq(&self, other: &ExpansionStore) -> bool {
        match (self, other) {
            (
                ExpansionStore::Dense { rows: a, d: da },
                ExpansionStore::Dense { rows: b, d: db },
            ) => da == db && a == b,
            (ExpansionStore::Csr(a), ExpansionStore::Csr(b)) => a == b,
            _ => false,
        }
    }

    /// The store restricted to the rows where `keep` is true,
    /// **preserving the layout**: compacting a CSR-backed model yields
    /// a (smaller) CSR-backed model, never a densified one.
    pub fn filter(&self, keep: &[bool]) -> ExpansionStore {
        assert_eq!(keep.len(), self.len(), "keep mask/rows length mismatch");
        match self {
            ExpansionStore::Dense { rows, d } => {
                let mut out = Vec::new();
                for (i, &k) in keep.iter().enumerate() {
                    if k {
                        out.extend_from_slice(&rows[i * d..(i + 1) * d]);
                    }
                }
                ExpansionStore::new(out, *d)
            }
            ExpansionStore::Csr(b) => ExpansionStore::from_csr(b.filter_rows(keep)),
        }
    }
}

/// A kernel expansion `f(x) = sum_j k(x, x_j) alpha_j` (Eq. 1): the
/// output of every kernel solver in this crate. A `KernelModel` is a
/// single-head *view* over an [`ExpansionStore`] — the coefficient
/// vector is owned, the expansion rows are shared.
#[derive(Clone, Debug)]
pub struct KernelModel {
    /// Kernel function the expansion was trained with.
    pub kernel: Kernel,
    /// Shared expansion rows.
    store: ExpansionStore,
    /// Dual coefficients `[n]`.
    pub alpha: Vec<f32>,
}

impl KernelModel {
    /// Build from a dataset's features and a coefficient vector.
    pub fn new(kernel: Kernel, x: Vec<f32>, alpha: Vec<f32>, d: usize) -> Self {
        assert_eq!(x.len(), alpha.len() * d, "x/alpha shape mismatch");
        KernelModel {
            kernel,
            store: ExpansionStore::new(x, d),
            alpha,
        }
    }

    /// Single-head view over an existing (possibly shared) store.
    pub fn from_store(kernel: Kernel, store: ExpansionStore, alpha: Vec<f32>) -> Self {
        assert_eq!(store.len(), alpha.len(), "store/alpha shape mismatch");
        KernelModel {
            kernel,
            store,
            alpha,
        }
    }

    /// The shared expansion storage backing this head.
    pub fn store(&self) -> &ExpansionStore {
        &self.store
    }

    /// Borrowed [`Rows`] view over the expansion points — layout-
    /// polymorphic; what every compute path should use.
    pub fn rows(&self) -> Rows<'_> {
        self.store.view()
    }

    /// Dense expansion points, row-major `[n, d]`; `None` when the
    /// store is CSR-backed. Use [`KernelModel::rows`] on compute paths
    /// — this accessor exists for dense-only tests and serialisation.
    pub fn x(&self) -> Option<&[f32]> {
        self.store.dense_rows()
    }

    /// Feature dimensionality.
    pub fn d(&self) -> usize {
        self.store.dim()
    }

    /// Number of expansion points.
    pub fn len(&self) -> usize {
        self.alpha.len()
    }

    /// True when the expansion is empty.
    pub fn is_empty(&self) -> bool {
        self.alpha.is_empty()
    }

    /// Number of support vectors (|alpha| above `tol`).
    pub fn n_support(&self, tol: f32) -> usize {
        self.alpha.iter().filter(|a| a.abs() > tol).count()
    }

    /// Drop expansion points with |alpha| <= tol — the truncation scheme
    /// the paper's conclusion suggests for fast prediction ("combine
    /// DSEKL with truncation schemes as in [11, 9] after convergence").
    /// The compacted model owns a fresh (smaller) store in the **same
    /// layout**: a CSR-backed model stays CSR-backed.
    pub fn compact(&self, tol: f32) -> KernelModel {
        let keep: Vec<bool> = self.alpha.iter().map(|a| a.abs() > tol).collect();
        let alpha = self
            .alpha
            .iter()
            .zip(&keep)
            .filter_map(|(&a, &k)| k.then_some(a))
            .collect();
        KernelModel::from_store(self.kernel, self.store.filter(&keep), alpha)
    }

    /// Decision scores for arbitrary [`Rows`]: test points and the
    /// expansion are both served as views, so any mix of dense and CSR
    /// layouts runs the backend's layout-polymorphic (O(nnz) on CSR)
    /// kernel path.
    pub fn scores_rows(&self, backend: &mut dyn Backend, xt: Rows) -> Result<Vec<f32>> {
        if xt.dim() != self.d() {
            return Err(Error::invalid(format!(
                "dataset dim {} != model dim {}",
                xt.dim(),
                self.d()
            )));
        }
        let mut f = Vec::new();
        backend.predict(self.kernel, xt, self.rows(), &self.alpha, &mut f)?;
        Ok(f)
    }

    /// Decision scores for a dataset.
    pub fn scores(&self, backend: &mut dyn Backend, ds: &Dataset) -> Result<Vec<f32>> {
        self.scores_rows(backend, Rows::dense(&ds.x, ds.len(), ds.d))
    }

    /// Classification error on a labelled dataset.
    pub fn error(&self, backend: &mut dyn Backend, ds: &Dataset) -> Result<f64> {
        Ok(error_rate(&self.scores(backend, ds)?, &ds.y))
    }

    /// Classification error on arbitrary labelled [`Rows`].
    pub fn error_rows(&self, backend: &mut dyn Backend, xt: Rows, y: &[f32]) -> Result<f64> {
        Ok(error_rate(&self.scores_rows(backend, xt)?, y))
    }

    /// Classification error on a labelled CSR dataset (the test points
    /// stay sparse; only the expansion rows are dense).
    pub fn error_sparse(&self, backend: &mut dyn Backend, ds: &SparseDataset) -> Result<f64> {
        self.error_rows(backend, ds.rows(), &ds.y)
    }

    /// Serialise to a writer (little-endian, self-describing header).
    /// Dense-backed models write DSEKLv1 (byte-identical to earlier
    /// releases); CSR-backed models write single-head DSEKLv3, so the
    /// file size scales with nnz, not `n * d`.
    pub fn save<W: Write>(&self, w: W) -> Result<()> {
        let mut w = BufWriter::new(w);
        match &self.store {
            ExpansionStore::Dense { rows, .. } => {
                w.write_all(MAGIC)?;
                write_kernel(&mut w, self.kernel)?;
                w.write_all(&(self.len() as u64).to_le_bytes())?;
                w.write_all(&(self.d() as u64).to_le_bytes())?;
                write_f32s(&mut w, &self.alpha)?;
                write_f32s(&mut w, rows)?;
                Ok(())
            }
            ExpansionStore::Csr(block) => {
                write_v3(&mut w, self.kernel, &[self.alpha.as_slice()], block)
            }
        }
    }

    /// Deserialise from a reader — DSEKLv1 (dense) or single-head
    /// DSEKLv3 (CSR) files. Files of a recognised but different family
    /// error with a precise message naming the format and the head
    /// count found; [`load_model`] dispatches every family.
    pub fn load<R: Read>(mut r: R) -> Result<KernelModel> {
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        match ModelFormat::sniff(&magic) {
            Some(ModelFormat::V1) => Self::load_v1_body(r),
            Some(ModelFormat::V3) => {
                let (kernel, k, coef, store) = read_v3_body(r)?;
                if k != 1 {
                    return Err(wrong_family(
                        ModelFormat::V3,
                        "a multiclass model",
                        Some(k),
                        "a single-head kernel model",
                    ));
                }
                Ok(KernelModel::from_store(kernel, store, coef))
            }
            Some(f @ (ModelFormat::V2 | ModelFormat::Mc1)) => Err(wrong_family(
                f,
                "a multiclass model",
                peek_head_count(f, &mut r),
                "a single-head kernel model",
            )),
            Some(f @ ModelFormat::Rk1) => Err(wrong_family(
                f,
                "an RKS primal model",
                None,
                "a single-head kernel model",
            )),
            Some(f @ ModelFormat::Hy1) => Err(wrong_family(
                f,
                "a streaming hybrid model",
                None,
                "a single-head kernel model",
            )),
            None => Err(unknown_magic(&magic)),
        }
    }

    /// DSEKLv1 body (after the magic): kernel, alpha, one dense block.
    fn load_v1_body<R: Read>(r: R) -> Result<KernelModel> {
        let mut r = BufReader::new(r);
        let kernel = read_kernel(&mut r)?;
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        let n = u64::from_le_bytes(b8) as usize;
        r.read_exact(&mut b8)?;
        let d = u64::from_le_bytes(b8) as usize;
        if n.checked_mul(d).is_none() || n * d > (1 << 34) {
            return Err(Error::parse("model dimensions implausible"));
        }
        let alpha = read_f32s_counted(&mut r, n)?;
        let x = read_f32s_counted(&mut r, n * d)?;
        Ok(KernelModel::new(kernel, x, alpha, d))
    }

    /// Save to a file path.
    pub fn save_file<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        self.save(std::fs::File::create(path)?)
    }

    /// Load from a file path.
    pub fn load_file<P: AsRef<Path>>(path: P) -> Result<KernelModel> {
        Self::load(std::fs::File::open(path)?)
    }
}

/// Write the kernel wire header (kind + gamma + degree + coef0).
fn write_kernel<W: Write>(w: &mut W, kernel: Kernel) -> Result<()> {
    let (kind, gamma, degree, coef0) = kernel.encode_wire();
    w.write_all(&kind.to_le_bytes())?;
    w.write_all(&gamma.to_le_bytes())?;
    w.write_all(&degree.to_le_bytes())?;
    w.write_all(&coef0.to_le_bytes())?;
    Ok(())
}

/// Read the kernel wire header written by [`write_kernel`].
fn read_kernel<R: Read>(r: &mut R) -> Result<Kernel> {
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let kind = u32::from_le_bytes(b4);
    r.read_exact(&mut b4)?;
    let gamma = f32::from_le_bytes(b4);
    r.read_exact(&mut b4)?;
    let degree = u32::from_le_bytes(b4);
    r.read_exact(&mut b4)?;
    let coef0 = f32::from_le_bytes(b4);
    Kernel::decode_wire(kind, gamma, degree, coef0)
}

fn write_f32s<W: Write>(w: &mut W, vs: &[f32]) -> Result<()> {
    for v in vs {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read exactly `n` little-endian f32s. The buffer grows as bytes
/// actually arrive (capacity is seeded with a small bound, not the
/// header's count), so a crafted header over a tiny file fails with a
/// read error after a few KiB instead of triggering a giant zeroed
/// pre-allocation.
fn read_f32s_counted<R: Read>(r: &mut R, n: usize) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(n.min(1 << 16));
    let mut b4 = [0u8; 4];
    for _ in 0..n {
        r.read_exact(&mut b4)?;
        out.push(f32::from_le_bytes(b4));
    }
    Ok(out)
}

const MC_MAGIC: &[u8; 8] = b"DSEKLmc1";
const V2_MAGIC: &[u8; 8] = b"DSEKLv2\0";
const V3_MAGIC: &[u8; 8] = b"DSEKLv3\0";
const RK_MAGIC: &[u8; 8] = b"DSEKLrk1";
const HY_MAGIC: &[u8; 8] = b"DSEKLhy1";

/// The on-disk model formats this crate reads, sniffed from the 8-byte
/// magic. [`load_model`] dispatches on this; the per-family loaders use
/// it to build precise wrong-family errors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelFormat {
    /// `DSEKLv1` — single head, dense rows.
    V1,
    /// `DSEKLv2` — K heads, one dense row block.
    V2,
    /// `DSEKLv3` — 1..K heads over one CSR row block.
    V3,
    /// `DSEKLmc1` — legacy per-head multiclass container.
    Mc1,
    /// `DSEKLrk1` — RKS primal model (random-feature weights).
    Rk1,
    /// `DSEKLhy1` — streaming hybrid: budgeted kernel head + RKS tail.
    Hy1,
}

impl ModelFormat {
    /// Identify a format from its 8-byte magic.
    pub fn sniff(magic: &[u8; 8]) -> Option<ModelFormat> {
        match magic {
            m if m == MAGIC => Some(ModelFormat::V1),
            m if m == V2_MAGIC => Some(ModelFormat::V2),
            m if m == V3_MAGIC => Some(ModelFormat::V3),
            m if m == MC_MAGIC => Some(ModelFormat::Mc1),
            m if m == RK_MAGIC => Some(ModelFormat::Rk1),
            m if m == HY_MAGIC => Some(ModelFormat::Hy1),
            _ => None,
        }
    }

    /// The magic as printable text (without a trailing NUL).
    pub fn name(&self) -> &'static str {
        match self {
            ModelFormat::V1 => "DSEKLv1",
            ModelFormat::V2 => "DSEKLv2",
            ModelFormat::V3 => "DSEKLv3",
            ModelFormat::Mc1 => "DSEKLmc1",
            ModelFormat::Rk1 => "DSEKLrk1",
            ModelFormat::Hy1 => "DSEKLhy1",
        }
    }
}

/// One precise wrong-family error: which format the file is, what it
/// holds (with the head count when the header yields one), and what the
/// failing reader expected.
fn wrong_family(format: ModelFormat, holds: &str, k: Option<usize>, want: &str) -> Error {
    let k_part = k.map(|k| format!(" (found k={k})")).unwrap_or_default();
    Error::parse(format!(
        "wrong model family: {} file holds {holds}{k_part}, not {want}; \
         Predictor::load_file sniffs the format and loads any family",
        format.name()
    ))
}

/// One precise unknown-magic error site shared by every loader.
fn unknown_magic(magic: &[u8; 8]) -> Error {
    Error::parse(format!(
        "not a DSEKL model file (magic {:?}; known formats: DSEKLv1, \
         DSEKLv2, DSEKLv3, DSEKLmc1, DSEKLrk1, DSEKLhy1)",
        String::from_utf8_lossy(magic)
    ))
}

/// Best-effort head count from a v2/v3/mc1 header, for wrong-family
/// errors only — a truncated header simply drops the count.
fn peek_head_count<R: Read>(format: ModelFormat, r: &mut R) -> Option<usize> {
    if matches!(format, ModelFormat::V2 | ModelFormat::V3) {
        // Skip the 16-byte kernel wire header to reach the head count.
        let mut kern = [0u8; 16];
        r.read_exact(&mut kern).ok()?;
    }
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8).ok()?;
    Some(u64::from_le_bytes(b8) as usize)
}

/// Sanity cap shared by the format readers: no plausible model exceeds
/// 2^34 elements in any one array. This rejects absurd headers up
/// front; allocation safety against *crafted* headers comes from the
/// incremental readers ([`read_f32s_counted`] and friends), whose
/// memory grows with the bytes that actually arrive, never with the
/// header's claimed counts.
const MAX_ELEMS: usize = 1 << 34;

/// DSEKLv3 writer: magic + kernel + `(k, n, d, nnz)` header, the
/// `[k, n]` coefficient matrix, then the CSR arrays (`indptr` as u64,
/// `indices` as u32, `values` as f32). One format serves single-head
/// (`k == 1`, written by [`KernelModel::save`]) and multi-head
/// (`k >= 2`, written by [`MulticlassModel::save`]) CSR-backed models.
fn write_v3<W: Write>(w: &mut W, kernel: Kernel, coef: &[&[f32]], block: &CsrBlock) -> Result<()> {
    w.write_all(V3_MAGIC)?;
    write_kernel(w, kernel)?;
    let n = block.len();
    w.write_all(&(coef.len() as u64).to_le_bytes())?;
    w.write_all(&(n as u64).to_le_bytes())?;
    w.write_all(&(block.dim() as u64).to_le_bytes())?;
    w.write_all(&(block.nnz() as u64).to_le_bytes())?;
    for head in coef {
        debug_assert_eq!(head.len(), n, "coefficient head/row-count mismatch");
        write_f32s(w, head)?;
    }
    for &p in block.indptr() {
        w.write_all(&(p as u64).to_le_bytes())?;
    }
    for &c in block.indices() {
        w.write_all(&c.to_le_bytes())?;
    }
    write_f32s(w, block.values())?;
    Ok(())
}

/// DSEKLv3 body reader (after the magic): returns the kernel, the head
/// count, the `[k, n]` coefficient matrix and the CSR-backed store.
/// Every header field is bounds-checked and the CSR arrays are
/// validated through [`CsrBlock::from_parts`], so corrupt or truncated
/// files error instead of panicking or over-allocating.
fn read_v3_body<R: Read>(r: R) -> Result<(Kernel, usize, Vec<f32>, ExpansionStore)> {
    let mut r = BufReader::new(r);
    let kernel = read_kernel(&mut r)?;
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let k = u64::from_le_bytes(b8) as usize;
    if !(1..=4096).contains(&k) {
        return Err(Error::parse(format!("implausible head count {k}")));
    }
    r.read_exact(&mut b8)?;
    let n = u64::from_le_bytes(b8) as usize;
    r.read_exact(&mut b8)?;
    let d = u64::from_le_bytes(b8) as usize;
    r.read_exact(&mut b8)?;
    let nnz = u64::from_le_bytes(b8) as usize;
    if d == 0 || n > MAX_ELEMS || d > MAX_ELEMS || nnz > MAX_ELEMS {
        return Err(Error::parse("model dimensions implausible"));
    }
    if n.checked_mul(k).is_none() || n * k > MAX_ELEMS {
        return Err(Error::parse("coefficient matrix implausibly large"));
    }
    // nnz can never exceed the dense grid (guard the multiply too: for
    // very wide sparse models n * d may overflow while being perfectly
    // legitimate — that is the point of the format).
    if let Some(grid) = n.checked_mul(d) {
        if nnz > grid {
            return Err(Error::parse("nnz exceeds the row grid"));
        }
    }
    let coef = read_f32s_counted(&mut r, k * n)?;
    // Like read_f32s_counted, the CSR arrays grow with the bytes that
    // actually arrive: a crafted header cannot force an allocation
    // bigger than the file behind it.
    let mut indptr = Vec::with_capacity((n + 1).min(1 << 16));
    for _ in 0..n + 1 {
        r.read_exact(&mut b8)?;
        let v = u64::from_le_bytes(b8);
        if v > nnz as u64 {
            return Err(Error::parse("CSR indptr points past the value buffer"));
        }
        indptr.push(v as usize);
    }
    let mut b4 = [0u8; 4];
    let mut indices = Vec::with_capacity(nnz.min(1 << 16));
    for _ in 0..nnz {
        r.read_exact(&mut b4)?;
        indices.push(u32::from_le_bytes(b4));
    }
    let values = read_f32s_counted(&mut r, nnz)?;
    let block = CsrBlock::from_parts(indptr, indices, values, d)?;
    Ok((kernel, k, coef, ExpansionStore::from_csr(block)))
}

/// A one-vs-rest multiclass model: K binary kernel-expansion heads with
/// argmax decision. Produced by [`crate::solver::ovr::OvrSolver`].
///
/// The K heads are views over **one** [`ExpansionStore`] whenever
/// possible (always, for solver output and v2/v3 files): the expansion
/// rows are stored once, only the K coefficient vectors are per-head.
/// Serialises one row block + `[K, n]` coefficients when the heads
/// share storage and kernel — DSEKLv2 for a dense block, DSEKLv3 for a
/// CSR block — falling back to the legacy per-head DSEKLmc1 container
/// otherwise; all formats load.
#[derive(Clone, Debug)]
pub struct MulticlassModel {
    /// Per-class binary machines; index == class id.
    pub models: Vec<KernelModel>,
}

impl MulticlassModel {
    /// Build from per-class binary models (index == class id). When the
    /// per-class expansions hold identical rows (the one-vs-rest case),
    /// the heads are rebuilt as views over a single shared store.
    pub fn new(models: Vec<KernelModel>) -> Self {
        assert!(models.len() >= 2, "need at least two classes");
        let d = models[0].d();
        assert!(
            models.iter().all(|m| m.d() == d),
            "per-class models disagree on dimensionality"
        );
        let first = &models[0];
        let dedupable = models
            .iter()
            .all(|m| m.kernel == first.kernel && m.store().content_eq(first.store()));
        if dedupable {
            let store = first.store().clone();
            let kernel = first.kernel;
            let models = models
                .into_iter()
                .map(|m| KernelModel::from_store(kernel, store.clone(), m.alpha))
                .collect();
            return MulticlassModel { models };
        }
        MulticlassModel { models }
    }

    /// Build K heads directly over one shared store from a row-major
    /// `[K, n]` coefficient matrix — the solver-facing constructor.
    pub fn from_shared(kernel: Kernel, store: ExpansionStore, coef: Vec<f32>) -> Self {
        let n = store.len();
        assert!(n > 0, "empty expansion store");
        assert_eq!(coef.len() % n, 0, "coef matrix not a multiple of n");
        let k = coef.len() / n;
        assert!(k >= 2, "need at least two classes");
        let models = (0..k)
            .map(|h| {
                KernelModel::from_store(kernel, store.clone(), coef[h * n..(h + 1) * n].to_vec())
            })
            .collect();
        MulticlassModel { models }
    }

    /// Number of classes K.
    pub fn n_classes(&self) -> usize {
        self.models.len()
    }

    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        self.models[0].d()
    }

    /// Whether all heads are views over one shared row block with one
    /// kernel — the invariant that enables the fused predict path and
    /// the DSEKLv2 format.
    pub fn is_shared(&self) -> bool {
        let first = &self.models[0];
        self.models.iter().all(|m| {
            m.kernel == first.kernel
                && m.len() == first.len()
                && m.store().shares_rows_with(first.store())
        })
    }

    /// The `[K, n]` per-head coefficient matrix, row-major.
    pub fn coef_matrix(&self) -> Vec<f32> {
        let mut coef = Vec::with_capacity(self.n_classes() * self.models[0].len());
        for m in &self.models {
            coef.extend_from_slice(&m.alpha);
        }
        coef
    }

    /// Per-class decision scores for arbitrary [`Rows`], row-major
    /// `[n, K]`. Shared-storage models score all K heads in one fused
    /// pass over the kernel rows ([`Backend::predict_multi`]);
    /// heterogeneous models fall back to one predict per head.
    pub fn scores_rows(&self, backend: &mut dyn Backend, xt: Rows) -> Result<Vec<f32>> {
        if xt.dim() != self.dim() {
            return Err(Error::invalid(format!(
                "dataset dim {} != model dim {}",
                xt.dim(),
                self.dim()
            )));
        }
        let n = xt.len();
        let k = self.n_classes();
        if self.is_shared() {
            let head = &self.models[0];
            let coef = self.coef_matrix();
            let mut out = Vec::new();
            backend.predict_multi(head.kernel, xt, head.rows(), &coef, k, &mut out)?;
            return Ok(out);
        }
        let mut out = vec![0.0f32; n * k];
        let mut f = Vec::new();
        for (c, m) in self.models.iter().enumerate() {
            backend.predict(m.kernel, xt, m.rows(), &m.alpha, &mut f)?;
            for (i, &v) in f.iter().enumerate() {
                out[i * k + c] = v;
            }
        }
        Ok(out)
    }

    /// Per-class decision scores for a dense dataset, row-major `[n, K]`.
    pub fn scores(&self, backend: &mut dyn Backend, ds: &MultiDataset) -> Result<Vec<f32>> {
        self.scores_rows(backend, Rows::dense(&ds.x, ds.len(), ds.d))
    }

    /// Argmax class prediction per [`Rows`] example.
    pub fn predict_rows(&self, backend: &mut dyn Backend, xt: Rows) -> Result<Vec<u32>> {
        let k = self.n_classes();
        let scores = self.scores_rows(backend, xt)?;
        Ok(scores
            .chunks(k)
            .map(|row| {
                let mut best = 0usize;
                for (c, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = c;
                    }
                }
                best as u32
            })
            .collect())
    }

    /// Argmax class prediction per example.
    pub fn predict(&self, backend: &mut dyn Backend, ds: &MultiDataset) -> Result<Vec<u32>> {
        self.predict_rows(backend, Rows::dense(&ds.x, ds.len(), ds.d))
    }

    /// Multiclass classification error rate.
    pub fn error(&self, backend: &mut dyn Backend, ds: &MultiDataset) -> Result<f64> {
        if ds.is_empty() {
            return Ok(0.0);
        }
        let pred = self.predict(backend, ds)?;
        let wrong = pred.iter().zip(&ds.y).filter(|(p, y)| p != y).count();
        Ok(wrong as f64 / ds.len() as f64)
    }

    /// Multiclass error rate on a labelled CSR dataset.
    pub fn error_sparse(
        &self,
        backend: &mut dyn Backend,
        ds: &SparseMultiDataset,
    ) -> Result<f64> {
        if ds.is_empty() {
            return Ok(0.0);
        }
        let pred = self.predict_rows(backend, ds.rows())?;
        let wrong = pred.iter().zip(&ds.y).filter(|(p, y)| p != y).count();
        Ok(wrong as f64 / ds.len() as f64)
    }

    /// Serialise. Shared-storage models (the normal case) write one row
    /// block for all K coefficient vectors — DSEKLv2 when the block is
    /// dense, multi-head DSEKLv3 when it is CSR (so a `--sparse`-trained
    /// multiclass model serialises in O(nnz) bytes). Heterogeneous
    /// models fall back to the legacy per-head container
    /// ([`MulticlassModel::save_legacy`]).
    pub fn save<W: Write>(&self, w: W) -> Result<()> {
        if !self.is_shared() {
            return self.save_legacy(w);
        }
        // Buffer the element-wise format writers (one syscall per f32 /
        // index otherwise), matching KernelModel::save.
        let mut w = BufWriter::new(w);
        let head = match self.models.first() {
            Some(h) => h,
            None => return Err(Error::invalid("multiclass model with no heads")),
        };
        if let Some(block) = head.store().csr_block() {
            let coef: Vec<&[f32]> = self.models.iter().map(|m| m.alpha.as_slice()).collect();
            return write_v3(&mut w, head.kernel, &coef, block);
        }
        w.write_all(V2_MAGIC)?;
        write_kernel(&mut w, head.kernel)?;
        w.write_all(&(self.n_classes() as u64).to_le_bytes())?;
        w.write_all(&(head.len() as u64).to_le_bytes())?;
        w.write_all(&(head.d() as u64).to_le_bytes())?;
        for m in &self.models {
            write_f32s(&mut w, &m.alpha)?;
        }
        match head.store().dense_rows() {
            Some(rows) => write_f32s(&mut w, rows)?,
            None => return Err(Error::invalid("shared store is neither dense nor CSR")),
        }
        Ok(())
    }

    /// Serialise in the legacy DSEKLmc1 container: magic + class count +
    /// length-prefixed per-class models (each a full DSEKLv1 blob, rows
    /// duplicated K times). Kept for heterogeneous models and so the
    /// migration path stays testable.
    pub fn save_legacy<W: Write>(&self, mut w: W) -> Result<()> {
        w.write_all(MC_MAGIC)?;
        w.write_all(&(self.models.len() as u64).to_le_bytes())?;
        for m in &self.models {
            let mut buf = Vec::new();
            m.save(&mut buf)?;
            w.write_all(&(buf.len() as u64).to_le_bytes())?;
            w.write_all(&buf)?;
        }
        Ok(())
    }

    /// Deserialise a [`MulticlassModel`] — any multiclass format:
    /// DSEKLv2 (shared dense rows), multi-head DSEKLv3 (shared CSR
    /// rows), or the legacy DSEKLmc1 per-head container. Single-head
    /// and RKS files error with a precise wrong-family message;
    /// [`load_model`] dispatches every family.
    pub fn load<R: Read>(mut r: R) -> Result<MulticlassModel> {
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        match ModelFormat::sniff(&magic) {
            Some(ModelFormat::V2) => Self::load_v2_body(r),
            Some(ModelFormat::V3) => {
                let (kernel, k, coef, store) = read_v3_body(r)?;
                if k < 2 {
                    return Err(wrong_family(
                        ModelFormat::V3,
                        "a single-head kernel model",
                        Some(k),
                        "a multiclass model",
                    ));
                }
                if store.is_empty() {
                    return Err(Error::parse("empty expansion store"));
                }
                Ok(MulticlassModel::from_shared(kernel, store, coef))
            }
            Some(ModelFormat::Mc1) => Self::load_legacy_body(r),
            Some(f @ ModelFormat::V1) => Err(wrong_family(
                f,
                "a single-head kernel model",
                Some(1),
                "a multiclass model",
            )),
            Some(f @ ModelFormat::Rk1) => Err(wrong_family(
                f,
                "an RKS primal model",
                None,
                "a multiclass model",
            )),
            Some(f @ ModelFormat::Hy1) => Err(wrong_family(
                f,
                "a streaming hybrid model",
                None,
                "a multiclass model",
            )),
            None => Err(unknown_magic(&magic)),
        }
    }

    /// DSEKLv2 body (after the magic): one row block, K coefficient
    /// vectors over it.
    fn load_v2_body<R: Read>(r: R) -> Result<MulticlassModel> {
        let mut r = BufReader::new(r);
        let kernel = read_kernel(&mut r)?;
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        let k = u64::from_le_bytes(b8) as usize;
        if !(2..=4096).contains(&k) {
            return Err(Error::parse(format!("implausible class count {k}")));
        }
        r.read_exact(&mut b8)?;
        let n = u64::from_le_bytes(b8) as usize;
        r.read_exact(&mut b8)?;
        let d = u64::from_le_bytes(b8) as usize;
        if n == 0 || d == 0 || n.checked_mul(d).is_none() || n * d > (1 << 34) {
            return Err(Error::parse("model dimensions implausible"));
        }
        // Bound the coefficient matrix too: k and n*d can each look sane
        // while k*n is still a multi-terabyte allocation request.
        if n.checked_mul(k).is_none() || n * k > (1 << 34) {
            return Err(Error::parse("coefficient matrix implausibly large"));
        }
        let coef = read_f32s_counted(&mut r, k * n)?;
        let x = read_f32s_counted(&mut r, n * d)?;
        Ok(MulticlassModel::from_shared(
            kernel,
            ExpansionStore::new(x, d),
            coef,
        ))
    }

    /// Legacy DSEKLmc1 body (after the magic): K length-prefixed
    /// DSEKLv1 models. `MulticlassModel::new` re-deduplicates the rows
    /// into one shared store when the heads agree.
    fn load_legacy_body<R: Read>(mut r: R) -> Result<MulticlassModel> {
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        let k = u64::from_le_bytes(b8) as usize;
        if !(2..=4096).contains(&k) {
            return Err(Error::parse(format!("implausible class count {k}")));
        }
        let mut models: Vec<KernelModel> = Vec::with_capacity(k);
        for _ in 0..k {
            r.read_exact(&mut b8)?;
            let len = u64::from_le_bytes(b8) as usize;
            // Cap each chunk well below anything a real model produces so
            // a crafted header cannot trigger a giant pre-allocation.
            if len > (1 << 30) {
                return Err(Error::parse("model chunk implausibly large"));
            }
            let mut buf = vec![0u8; len];
            r.read_exact(&mut buf)?;
            let m = KernelModel::load(buf.as_slice())?;
            // Validate here with an Err — `new()` asserts, which must
            // never be reachable from untrusted file contents.
            if let Some(first) = models.first() {
                if m.d() != first.d() {
                    return Err(Error::parse(format!(
                        "per-class models disagree on dimensionality ({} vs {})",
                        first.d(),
                        m.d()
                    )));
                }
            }
            models.push(m);
        }
        Ok(MulticlassModel::new(models))
    }

    /// Save to a file path.
    pub fn save_file<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        self.save(std::fs::File::create(path)?)
    }

    /// Load from a file path.
    pub fn load_file<P: AsRef<Path>>(path: P) -> Result<MulticlassModel> {
        Self::load(std::fs::File::open(path)?)
    }
}

/// An RKS (random-kitchen-sinks) linear model in RFF feature space —
/// the explicit-kernel-map baseline of Fig. 2.
#[derive(Clone, Debug)]
pub struct RksModel {
    /// Frequencies `[d, r]`.
    pub w_feat: Vec<f32>,
    /// Phases `[r]`.
    pub b_feat: Vec<f32>,
    /// Primal weights `[r]`.
    pub w: Vec<f32>,
    pub d: usize,
    pub r: usize,
}

impl RksModel {
    /// Decision scores for arbitrary [`Rows`] — dense or CSR; the RFF
    /// feature map is layout-polymorphic like the kernel paths.
    pub fn scores_rows(&self, backend: &mut dyn Backend, xt: Rows) -> Result<Vec<f32>> {
        if xt.dim() != self.d {
            return Err(Error::invalid(format!(
                "dataset dim {} != model dim {}",
                xt.dim(),
                self.d
            )));
        }
        let mut f = Vec::new();
        backend.rks_predict(xt, &self.w_feat, &self.b_feat, &self.w, self.r, &mut f)?;
        Ok(f)
    }

    /// Decision scores for a dataset.
    pub fn scores(&self, backend: &mut dyn Backend, ds: &Dataset) -> Result<Vec<f32>> {
        self.scores_rows(backend, Rows::dense(&ds.x, ds.len(), ds.d))
    }

    /// Classification error on a labelled dataset.
    pub fn error(&self, backend: &mut dyn Backend, ds: &Dataset) -> Result<f64> {
        Ok(error_rate(&self.scores(backend, ds)?, &ds.y))
    }

    /// Serialise as DSEKLrk1: magic + `(d, r)` header + frequencies
    /// `[d, r]` + phases `[r]` + primal weights `[r]`.
    pub fn save<W: Write>(&self, w: W) -> Result<()> {
        let mut w = BufWriter::new(w);
        w.write_all(RK_MAGIC)?;
        w.write_all(&(self.d as u64).to_le_bytes())?;
        w.write_all(&(self.r as u64).to_le_bytes())?;
        write_f32s(&mut w, &self.w_feat)?;
        write_f32s(&mut w, &self.b_feat)?;
        write_f32s(&mut w, &self.w)?;
        Ok(())
    }

    /// DSEKLrk1 body (after the magic).
    fn load_rk1_body<R: Read>(r: R) -> Result<RksModel> {
        let mut r = BufReader::new(r);
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        let d = u64::from_le_bytes(b8) as usize;
        r.read_exact(&mut b8)?;
        let rr = u64::from_le_bytes(b8) as usize;
        if d == 0 || rr == 0 || d.checked_mul(rr).is_none() || d * rr > MAX_ELEMS {
            return Err(Error::parse("model dimensions implausible"));
        }
        let w_feat = read_f32s_counted(&mut r, d * rr)?;
        let b_feat = read_f32s_counted(&mut r, rr)?;
        let w = read_f32s_counted(&mut r, rr)?;
        Ok(RksModel {
            w_feat,
            b_feat,
            w,
            d,
            r: rr,
        })
    }

    /// Deserialise a DSEKLrk1 file. Kernel-expansion files error with a
    /// precise wrong-family message; [`load_model`] dispatches every
    /// family.
    pub fn load<R: Read>(mut r: R) -> Result<RksModel> {
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        match ModelFormat::sniff(&magic) {
            Some(ModelFormat::Rk1) => Self::load_rk1_body(r),
            Some(f @ ModelFormat::V1) => Err(wrong_family(
                f,
                "a single-head kernel model",
                Some(1),
                "an RKS primal model",
            )),
            Some(f @ ModelFormat::V3) => {
                let k = peek_head_count(f, &mut r);
                let holds = if k == Some(1) {
                    "a single-head kernel model"
                } else {
                    "a multiclass model"
                };
                Err(wrong_family(f, holds, k, "an RKS primal model"))
            }
            Some(f @ (ModelFormat::V2 | ModelFormat::Mc1)) => Err(wrong_family(
                f,
                "a multiclass model",
                peek_head_count(f, &mut r),
                "an RKS primal model",
            )),
            Some(f @ ModelFormat::Hy1) => Err(wrong_family(
                f,
                "a streaming hybrid model",
                None,
                "an RKS primal model",
            )),
            None => Err(unknown_magic(&magic)),
        }
    }

    /// Save to a file path.
    pub fn save_file<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        self.save(std::fs::File::create(path)?)
    }

    /// Load from a file path.
    pub fn load_file<P: AsRef<Path>>(path: P) -> Result<RksModel> {
        Self::load(std::fs::File::open(path)?)
    }
}

/// Read one `u64`-length-prefixed sub-blob. The buffer grows as bytes
/// actually arrive (`read_to_end` over a `take`), so a crafted length
/// cannot force an allocation bigger than the file behind it.
fn read_blob_counted<R: Read>(r: &mut R, what: &str) -> Result<Vec<u8>> {
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let len = u64::from_le_bytes(b8);
    if len > (MAX_ELEMS as u64) * 4 {
        return Err(Error::parse(format!("{what} sub-blob implausibly large")));
    }
    let mut buf = Vec::with_capacity((len as usize).min(1 << 16));
    r.by_ref().take(len).read_to_end(&mut buf)?;
    if (buf.len() as u64) < len {
        return Err(Error::parse(format!("{what} sub-blob truncated")));
    }
    Ok(buf)
}

/// The frozen streaming hybrid ([`crate::stream`]): a budgeted
/// empirical-map head plus a primal RKS tail over the same input space,
/// scored as `head + tail` elementwise — Dai et al.'s random-feature
/// backing that keeps accuracy degrading gracefully when the head's
/// budget saturates.
#[derive(Clone, Debug)]
pub struct HybridModel {
    /// The budgeted kernel-expansion head.
    pub head: KernelModel,
    /// The RKS tail (same `d` as the head).
    pub rks: RksModel,
}

impl HybridModel {
    /// Pair a head and tail; they must agree on the input dimension.
    pub fn new(head: KernelModel, rks: RksModel) -> Result<HybridModel> {
        if head.d() != rks.d {
            return Err(Error::invalid(format!(
                "hybrid head dim {} != tail dim {}",
                head.d(),
                rks.d
            )));
        }
        Ok(HybridModel { head, rks })
    }

    /// Input dimensionality.
    pub fn dim(&self) -> usize {
        self.rks.d
    }

    /// Combined decision scores (head + tail) for arbitrary [`Rows`].
    pub fn scores_rows(&self, backend: &mut dyn Backend, xt: Rows) -> Result<Vec<f32>> {
        let mut scores = self.head.scores_rows(backend, xt)?;
        let tail = self.rks.scores_rows(backend, xt)?;
        for (s, t) in scores.iter_mut().zip(&tail) {
            *s += t;
        }
        Ok(scores)
    }

    /// Combined decision scores for a dense dataset.
    pub fn scores(&self, backend: &mut dyn Backend, ds: &Dataset) -> Result<Vec<f32>> {
        self.scores_rows(backend, Rows::dense(&ds.x, ds.len(), ds.d))
    }

    /// Classification error on a labelled dataset.
    pub fn error(&self, backend: &mut dyn Backend, ds: &Dataset) -> Result<f64> {
        Ok(error_rate(&self.scores(backend, ds)?, &ds.y))
    }

    /// Classification error on arbitrary labelled [`Rows`].
    pub fn error_rows(&self, backend: &mut dyn Backend, xt: Rows, y: &[f32]) -> Result<f64> {
        Ok(error_rate(&self.scores_rows(backend, xt)?, y))
    }

    /// Classification error on a labelled CSR dataset.
    pub fn error_sparse(&self, backend: &mut dyn Backend, ds: &SparseDataset) -> Result<f64> {
        self.error_rows(backend, ds.rows(), &ds.y)
    }

    /// Serialise as DSEKLhy1: magic, then head and tail as two
    /// `u64`-length-prefixed sub-blobs, each its family's own canonical
    /// encoding (DSEKLv1/single-head-DSEKLv3 for the head, DSEKLrk1 for
    /// the tail). The loader re-verifies canonicality, so a DSEKLhy1
    /// file admits no second representation — the fuzz suite's
    /// re-encode-identity gate.
    pub fn save<W: Write>(&self, w: W) -> Result<()> {
        let mut w = BufWriter::new(w);
        w.write_all(HY_MAGIC)?;
        let mut blob = Vec::new();
        self.head.save(&mut blob)?;
        w.write_all(&(blob.len() as u64).to_le_bytes())?;
        w.write_all(&blob)?;
        blob.clear();
        self.rks.save(&mut blob)?;
        w.write_all(&(blob.len() as u64).to_le_bytes())?;
        w.write_all(&blob)?;
        Ok(())
    }

    /// DSEKLhy1 body (after the magic): two length-prefixed sub-blobs,
    /// parsed by their family loaders, then checked for canonicality
    /// (sub-blob == its model's re-encoding), dimension agreement and
    /// the absence of trailing bytes — everything a corrupt or crafted
    /// file could smuggle past the per-field checks.
    fn load_hy1_body<R: Read>(mut r: R) -> Result<HybridModel> {
        let head_bytes = read_blob_counted(&mut r, "hybrid head")?;
        let head = KernelModel::load(head_bytes.as_slice())?;
        let tail_bytes = read_blob_counted(&mut r, "hybrid tail")?;
        let rks = RksModel::load(tail_bytes.as_slice())?;
        let mut reenc = Vec::new();
        head.save(&mut reenc)?;
        if reenc != head_bytes {
            return Err(Error::parse("hybrid head sub-blob is not canonical"));
        }
        reenc.clear();
        rks.save(&mut reenc)?;
        if reenc != tail_bytes {
            return Err(Error::parse("hybrid tail sub-blob is not canonical"));
        }
        let mut probe = [0u8; 1];
        match r.read(&mut probe) {
            Ok(0) => {}
            Ok(_) => return Err(Error::parse("trailing bytes after hybrid model")),
            Err(e) => return Err(e.into()),
        }
        HybridModel::new(head, rks)
    }

    /// Deserialise a DSEKLhy1 file. Files of other families error with
    /// a precise wrong-family message; [`load_model`] dispatches every
    /// family.
    pub fn load<R: Read>(mut r: R) -> Result<HybridModel> {
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        match ModelFormat::sniff(&magic) {
            Some(ModelFormat::Hy1) => Self::load_hy1_body(r),
            Some(f @ ModelFormat::V1) => Err(wrong_family(
                f,
                "a single-head kernel model",
                Some(1),
                "a streaming hybrid model",
            )),
            Some(f @ ModelFormat::V3) => {
                let k = peek_head_count(f, &mut r);
                let holds = if k == Some(1) {
                    "a single-head kernel model"
                } else {
                    "a multiclass model"
                };
                Err(wrong_family(f, holds, k, "a streaming hybrid model"))
            }
            Some(f @ (ModelFormat::V2 | ModelFormat::Mc1)) => Err(wrong_family(
                f,
                "a multiclass model",
                peek_head_count(f, &mut r),
                "a streaming hybrid model",
            )),
            Some(f @ ModelFormat::Rk1) => Err(wrong_family(
                f,
                "an RKS primal model",
                None,
                "a streaming hybrid model",
            )),
            None => Err(unknown_magic(&magic)),
        }
    }

    /// Save to a file path.
    pub fn save_file<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        self.save(std::fs::File::create(path)?)
    }

    /// Load from a file path.
    pub fn load_file<P: AsRef<Path>>(path: P) -> Result<HybridModel> {
        Self::load(std::fs::File::open(path)?)
    }
}

/// A loaded model of any family — what [`load_model`] returns after
/// sniffing the 8-byte magic.
#[derive(Clone, Debug)]
pub enum ModelFile {
    /// Single-head kernel expansion (DSEKLv1, single-head DSEKLv3).
    Kernel(KernelModel),
    /// K-head one-vs-rest model (DSEKLv2, multi-head DSEKLv3, DSEKLmc1).
    Multiclass(MulticlassModel),
    /// RKS primal model (DSEKLrk1).
    Rks(RksModel),
    /// Streaming hybrid: budgeted head + RKS tail (DSEKLhy1).
    Hybrid(HybridModel),
}

/// Sniff the magic and load whichever model family the file holds —
/// the one loader that accepts every on-disk format, and the single
/// precise error site for unknown magics and corrupt files.
/// `Predictor::load_file` wraps this with path context; CLI `predict`
/// and `serve` go through it, so no caller ever passes family flags.
pub fn load_model<R: Read>(mut r: R) -> Result<ModelFile> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)
        .map_err(|_| Error::parse("model file shorter than its 8-byte magic"))?;
    match ModelFormat::sniff(&magic) {
        Some(ModelFormat::V1) => Ok(ModelFile::Kernel(KernelModel::load_v1_body(r)?)),
        Some(ModelFormat::V2) => Ok(ModelFile::Multiclass(MulticlassModel::load_v2_body(r)?)),
        Some(ModelFormat::Mc1) => Ok(ModelFile::Multiclass(MulticlassModel::load_legacy_body(r)?)),
        Some(ModelFormat::Rk1) => Ok(ModelFile::Rks(RksModel::load_rk1_body(r)?)),
        Some(ModelFormat::Hy1) => Ok(ModelFile::Hybrid(HybridModel::load_hy1_body(r)?)),
        Some(ModelFormat::V3) => {
            let (kernel, k, coef, store) = read_v3_body(r)?;
            if k == 1 {
                Ok(ModelFile::Kernel(KernelModel::from_store(
                    kernel, store, coef,
                )))
            } else {
                if store.is_empty() {
                    return Err(Error::parse("empty expansion store"));
                }
                Ok(ModelFile::Multiclass(MulticlassModel::from_shared(
                    kernel, store, coef,
                )))
            }
        }
        None => Err(unknown_magic(&magic)),
    }
}

/// [`load_model`] from a file path.
pub fn load_model_file<P: AsRef<Path>>(path: P) -> Result<ModelFile> {
    load_model(std::fs::File::open(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn toy_model() -> KernelModel {
        KernelModel::new(
            Kernel::rbf(0.5),
            vec![0.0, 0.0, 1.0, 1.0, -1.0, -1.0],
            vec![0.5, -0.25, 0.1],
            2,
        )
    }

    #[test]
    fn save_load_roundtrip() {
        let m = toy_model();
        let mut buf = Vec::new();
        m.save(&mut buf).unwrap();
        let m2 = KernelModel::load(buf.as_slice()).unwrap();
        assert_eq!(m.kernel, m2.kernel);
        assert_eq!(m.x(), m2.x());
        assert_eq!(m.alpha, m2.alpha);
        assert_eq!(m.d(), m2.d());
    }

    #[test]
    fn save_load_poly_kernel() {
        let mut m = toy_model();
        m.kernel = Kernel::Poly { gamma: 0.3, degree: 3, coef0: 1.5 };
        let mut buf = Vec::new();
        m.save(&mut buf).unwrap();
        assert_eq!(KernelModel::load(buf.as_slice()).unwrap().kernel, m.kernel);
    }

    #[test]
    fn load_rejects_garbage() {
        assert!(KernelModel::load(&b"not a model"[..]).is_err());
        let mut buf = Vec::new();
        toy_model().save(&mut buf).unwrap();
        buf.truncate(buf.len() - 3);
        assert!(KernelModel::load(buf.as_slice()).is_err());
    }

    #[test]
    fn compact_drops_small_alphas() {
        let m = KernelModel::new(
            Kernel::rbf(1.0),
            vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0],
            vec![0.5, 1e-9, -0.3],
            2,
        );
        assert_eq!(m.n_support(1e-6), 2);
        let c = m.compact(1e-6);
        assert_eq!(c.len(), 2);
        assert_eq!(c.alpha, vec![0.5, -0.3]);
        assert_eq!(c.x().unwrap(), &[0.0, 0.0, 2.0, 2.0]);
    }

    #[test]
    fn compact_preserves_predictions() {
        let m = KernelModel::new(
            Kernel::rbf(1.0),
            vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0],
            vec![0.5, 0.0, -0.3],
            2,
        );
        let mut ds = Dataset::with_dim(2);
        ds.push(&[0.5, 0.5], 1.0);
        ds.push(&[-1.0, 2.0], -1.0);
        let mut be = NativeBackend::new();
        let s1 = m.scores(&mut be, &ds).unwrap();
        let s2 = m.compact(1e-6).scores(&mut be, &ds).unwrap();
        for (a, b) in s1.iter().zip(&s2) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    fn toy_csr_model() -> KernelModel {
        let mut ds = SparseDataset::with_dim(4);
        ds.push(&[0, 2], &[1.0, -2.0], 1.0);
        ds.push(&[], &[], -1.0);
        ds.push(&[1, 3], &[0.5, 3.0], 1.0);
        KernelModel::from_store(
            Kernel::rbf(0.5),
            ExpansionStore::from_rows(ds.rows()),
            vec![0.4, 0.0, -0.7],
        )
    }

    #[test]
    fn csr_store_serves_views_and_roundtrips_v3() {
        let m = toy_csr_model();
        assert!(!m.store().is_dense());
        assert_eq!(m.len(), 3);
        assert_eq!(m.d(), 4);
        let mut buf = Vec::new();
        m.save(&mut buf).unwrap();
        assert_eq!(&buf[..8], b"DSEKLv3\0");
        let m2 = KernelModel::load(buf.as_slice()).unwrap();
        assert!(!m2.store().is_dense(), "v3 load must reconstruct CSR");
        assert_eq!(m.alpha, m2.alpha);
        assert!(m.store().content_eq(m2.store()));
        let mut ds = Dataset::with_dim(4);
        ds.push(&[0.5, 0.0, 1.0, -1.0], 1.0);
        let mut be = NativeBackend::new();
        assert_eq!(
            m.scores(&mut be, &ds).unwrap(),
            m2.scores(&mut be, &ds).unwrap()
        );
    }

    #[test]
    fn compact_preserves_csr_layout() {
        let m = toy_csr_model();
        let c = m.compact(1e-6);
        assert!(!c.store().is_dense(), "compact densified a CSR store");
        assert_eq!(c.alpha, vec![0.4, -0.7]);
        assert_eq!(c.len(), 2);
        // Compacting everything away keeps the (empty) CSR layout and
        // still round-trips through DSEKLv3.
        let empty = m.compact(10.0);
        assert!(empty.is_empty());
        let mut buf = Vec::new();
        empty.save(&mut buf).unwrap();
        let back = KernelModel::load(buf.as_slice()).unwrap();
        assert!(back.is_empty());
        assert_eq!(back.d(), 4);
    }

    #[test]
    fn scores_dimension_check() {
        let m = toy_model();
        let ds = Dataset::with_dim(5);
        let mut be = NativeBackend::new();
        assert!(m.scores(&mut be, &ds).is_err());
    }

    /// Three one-point expansions at distinct centers: argmax picks the
    /// nearest center under the RBF kernel.
    fn toy_multiclass() -> MulticlassModel {
        let centers = [[0.0f32, 0.0], [3.0, 0.0], [0.0, 3.0]];
        let models = centers
            .iter()
            .map(|c| KernelModel::new(Kernel::rbf(1.0), c.to_vec(), vec![1.0], 2))
            .collect();
        MulticlassModel::new(models)
    }

    #[test]
    fn multiclass_argmax_picks_nearest_center() {
        let m = toy_multiclass();
        assert_eq!(m.n_classes(), 3);
        assert_eq!(m.dim(), 2);
        let mut ds = MultiDataset::with_dims(2, 3);
        ds.push(&[0.2, -0.1], 0);
        ds.push(&[2.8, 0.3], 1);
        ds.push(&[-0.2, 3.1], 2);
        let mut be = NativeBackend::new();
        let pred = m.predict(&mut be, &ds).unwrap();
        assert_eq!(pred, vec![0, 1, 2]);
        assert_eq!(m.error(&mut be, &ds).unwrap(), 0.0);
        // Scores matrix is [n, K] row-major with the winning class max.
        let scores = m.scores(&mut be, &ds).unwrap();
        assert_eq!(scores.len(), 9);
        assert!(scores[0] > scores[1] && scores[0] > scores[2]);
    }

    #[test]
    fn multiclass_error_counts_mislabels() {
        let m = toy_multiclass();
        let mut ds = MultiDataset::with_dims(2, 3);
        ds.push(&[0.0, 0.0], 1); // wrong on purpose
        ds.push(&[3.0, 0.0], 1);
        let mut be = NativeBackend::new();
        assert!((m.error(&mut be, &ds).unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn multiclass_save_load_roundtrip() {
        // Distinct rows per head -> the legacy fallback container.
        let m = toy_multiclass();
        assert!(!m.is_shared());
        let mut buf = Vec::new();
        m.save(&mut buf).unwrap();
        assert_eq!(&buf[..8], b"DSEKLmc1");
        let m2 = MulticlassModel::load(buf.as_slice()).unwrap();
        assert_eq!(m2.n_classes(), 3);
        for (a, b) in m.models.iter().zip(&m2.models) {
            assert_eq!(a.kernel, b.kernel);
            assert_eq!(a.x(), b.x());
            assert_eq!(a.alpha, b.alpha);
        }
        // Garbage and truncation are rejected.
        assert!(MulticlassModel::load(&b"DSEKLv1\0junk"[..]).is_err());
        buf.truncate(buf.len() - 2);
        assert!(MulticlassModel::load(buf.as_slice()).is_err());
    }

    #[test]
    fn multiclass_dimension_check() {
        let m = toy_multiclass();
        let ds = MultiDataset::with_dims(5, 3);
        let mut be = NativeBackend::new();
        assert!(m.scores(&mut be, &ds).is_err());
    }

    /// A shared-storage model over random rows: K heads, one row block.
    fn shared_multiclass(k: usize, n: usize, d: usize, seed: u64) -> MulticlassModel {
        use crate::rng::{Pcg64, Rng};
        let mut rng = Pcg64::seed_from(seed);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let coef: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        MulticlassModel::from_shared(Kernel::rbf(0.4), ExpansionStore::new(rows, d), coef)
    }

    #[test]
    fn shared_heads_reference_one_row_block() {
        let m = shared_multiclass(4, 20, 3, 11);
        assert!(m.is_shared());
        let first = m.models[0].store();
        for head in &m.models {
            assert!(head.store().shares_rows_with(first));
        }
        // Cloning the model clones Arcs, not rows.
        let c = m.clone();
        assert!(c.models[0].store().shares_rows_with(first));
        // new() deduplicates equal-but-separate row blocks too.
        let rebuilt = MulticlassModel::new(
            (0..3)
                .map(|h| {
                    KernelModel::new(
                        Kernel::rbf(1.0),
                        vec![0.0, 1.0, 2.0, 3.0],
                        vec![h as f32, -1.0],
                        2,
                    )
                })
                .collect(),
        );
        assert!(rebuilt.is_shared());
        assert!(rebuilt.models[0]
            .store()
            .shares_rows_with(rebuilt.models[2].store()));
    }

    #[test]
    fn v2_save_load_roundtrip_shared() {
        let m = shared_multiclass(5, 17, 4, 12);
        let mut buf = Vec::new();
        m.save(&mut buf).unwrap();
        assert_eq!(&buf[..8], b"DSEKLv2\0");
        let m2 = MulticlassModel::load(buf.as_slice()).unwrap();
        assert_eq!(m2.n_classes(), 5);
        assert!(m2.is_shared(), "v2 load must reconstruct shared storage");
        assert_eq!(m2.models[0].x(), m.models[0].x());
        for (a, b) in m.models.iter().zip(&m2.models) {
            assert_eq!(a.kernel, b.kernel);
            assert_eq!(a.alpha, b.alpha);
        }
    }

    #[test]
    fn legacy_mc1_container_still_loads() {
        // Craft a legacy file (rows duplicated per head) and check it
        // loads AND comes back deduplicated into one shared store.
        let m = shared_multiclass(3, 9, 2, 13);
        let mut legacy = Vec::new();
        m.save_legacy(&mut legacy).unwrap();
        assert_eq!(&legacy[..8], b"DSEKLmc1");
        let m2 = MulticlassModel::load(legacy.as_slice()).unwrap();
        assert_eq!(m2.n_classes(), 3);
        assert!(m2.is_shared(), "legacy load should dedup identical rows");
        for (a, b) in m.models.iter().zip(&m2.models) {
            assert_eq!(a.alpha, b.alpha);
            assert_eq!(a.x(), b.x());
        }
    }

    #[test]
    fn v2_rejects_truncation_and_corrupt_headers() {
        let m = shared_multiclass(3, 8, 2, 14);
        let mut buf = Vec::new();
        m.save(&mut buf).unwrap();
        // Truncation anywhere — inside header, coefs, rows — errors.
        for cut in [4, 12, 30, buf.len() - 5, buf.len() - 1] {
            assert!(
                MulticlassModel::load(&buf[..cut]).is_err(),
                "truncation at {cut} accepted"
            );
        }
        // Corrupt class count (0 heads).
        let mut bad = buf.clone();
        bad[24..32].fill(0);
        assert!(MulticlassModel::load(bad.as_slice()).is_err());
        // Corrupt kernel kind.
        let mut bad = buf.clone();
        bad[8..12].copy_from_slice(&99u32.to_le_bytes());
        assert!(MulticlassModel::load(bad.as_slice()).is_err());
        // Implausible dimensions (d = 0).
        let mut bad = buf.clone();
        bad[40..48].fill(0);
        assert!(MulticlassModel::load(bad.as_slice()).is_err());
        // Coefficient matrix k*n overflowing the sanity cap while k and
        // n*d each look plausible must error, not attempt to allocate.
        let mut bad = buf;
        bad[24..32].copy_from_slice(&4096u64.to_le_bytes()); // k
        bad[32..40].copy_from_slice(&(1u64 << 23).to_le_bytes()); // n
        bad[40..48].copy_from_slice(&1u64.to_le_bytes()); // d
        assert!(MulticlassModel::load(bad.as_slice()).is_err());
    }

    #[test]
    fn v2_file_is_k_times_smaller_than_legacy() {
        // covtype-like shape: K = 7 heads over one expansion block.
        let m = shared_multiclass(7, 200, 10, 15);
        let mut v2 = Vec::new();
        m.save(&mut v2).unwrap();
        let mut legacy = Vec::new();
        m.save_legacy(&mut legacy).unwrap();
        let ratio = legacy.len() as f64 / v2.len() as f64;
        assert!(
            ratio > 5.0,
            "expected ~7x shrink for K=7, got {ratio:.2} ({} vs {} bytes)",
            legacy.len(),
            v2.len()
        );
    }

    #[test]
    fn fused_scores_match_per_head_predict() {
        let m = shared_multiclass(4, 30, 3, 16);
        let mut rng = crate::rng::Pcg64::seed_from(17);
        let mut ds = MultiDataset::with_dims(3, 4);
        for i in 0..25 {
            use crate::rng::Rng;
            let row = [
                rng.normal() as f32,
                rng.normal() as f32,
                rng.normal() as f32,
            ];
            ds.push(&row, (i % 4) as u32);
        }
        let mut be = NativeBackend::new();
        let fused = m.scores(&mut be, &ds).unwrap();
        // Reference: one backend.predict per head, interleaved.
        let k = m.n_classes();
        let mut looped = vec![0.0f32; ds.len() * k];
        let mut f = Vec::new();
        for (c, head) in m.models.iter().enumerate() {
            be.predict(
                head.kernel,
                Rows::dense(&ds.x, ds.len(), ds.d),
                Rows::dense(head.x().unwrap(), head.len(), head.d()),
                &head.alpha,
                &mut f,
            )
            .unwrap();
            for (i, &v) in f.iter().enumerate() {
                looped[i * k + c] = v;
            }
        }
        assert_eq!(fused, looped, "fused predict diverged from looped");
    }

    fn toy_rks() -> RksModel {
        RksModel {
            w_feat: vec![0.1, -0.2, 0.3, 0.4, -0.5, 0.6],
            b_feat: vec![0.7, 1.1, -0.3],
            w: vec![0.5, -0.25, 0.125],
            d: 2,
            r: 3,
        }
    }

    #[test]
    fn rks_save_load_roundtrip() {
        let m = toy_rks();
        let mut buf = Vec::new();
        m.save(&mut buf).unwrap();
        assert_eq!(&buf[..8], b"DSEKLrk1");
        let m2 = RksModel::load(buf.as_slice()).unwrap();
        assert_eq!(m.w_feat, m2.w_feat);
        assert_eq!(m.b_feat, m2.b_feat);
        assert_eq!(m.w, m2.w);
        assert_eq!((m.d, m.r), (m2.d, m2.r));
        let mut ds = Dataset::with_dim(2);
        ds.push(&[0.5, -1.0], 1.0);
        let mut be = NativeBackend::new();
        assert_eq!(
            m.scores(&mut be, &ds).unwrap(),
            m2.scores(&mut be, &ds).unwrap()
        );
        // Truncation errors.
        buf.truncate(buf.len() - 2);
        assert!(RksModel::load(buf.as_slice()).is_err());
    }

    #[test]
    fn wrong_family_errors_name_format_and_head_count() {
        let mut v1 = Vec::new();
        toy_model().save(&mut v1).unwrap();
        let mut v2 = Vec::new();
        shared_multiclass(5, 6, 2, 21).save(&mut v2).unwrap();
        // v1 into the multiclass reader.
        let e = MulticlassModel::load(v1.as_slice()).unwrap_err().to_string();
        assert!(e.contains("wrong model family"), "{e}");
        assert!(e.contains("DSEKLv1") && e.contains("k=1"), "{e}");
        // v2 into the single-head reader reports the real head count.
        let e = KernelModel::load(v2.as_slice()).unwrap_err().to_string();
        assert!(e.contains("DSEKLv2") && e.contains("k=5"), "{e}");
        // rk1 into both kernel readers.
        let mut rk = Vec::new();
        toy_rks().save(&mut rk).unwrap();
        let e = KernelModel::load(rk.as_slice()).unwrap_err().to_string();
        assert!(e.contains("DSEKLrk1") && e.contains("RKS"), "{e}");
        let e = MulticlassModel::load(rk.as_slice()).unwrap_err().to_string();
        assert!(e.contains("DSEKLrk1"), "{e}");
        // kernel files into the RKS reader.
        let e = RksModel::load(v2.as_slice()).unwrap_err().to_string();
        assert!(e.contains("DSEKLv2") && e.contains("k=5"), "{e}");
        // hybrid files into every single-family reader.
        let mut hy = Vec::new();
        toy_hybrid().save(&mut hy).unwrap();
        for e in [
            KernelModel::load(hy.as_slice()).unwrap_err().to_string(),
            MulticlassModel::load(hy.as_slice()).unwrap_err().to_string(),
            RksModel::load(hy.as_slice()).unwrap_err().to_string(),
        ] {
            assert!(e.contains("DSEKLhy1") && e.contains("hybrid"), "{e}");
        }
        // and every other family into the hybrid reader.
        for (buf, tag) in [(&v1, "DSEKLv1"), (&v2, "DSEKLv2"), (&rk, "DSEKLrk1")] {
            let e = HybridModel::load(buf.as_slice()).unwrap_err().to_string();
            assert!(e.contains(tag) && e.contains("hybrid"), "{e}");
        }
    }

    #[test]
    fn load_model_sniffs_every_family() {
        let mut v1 = Vec::new();
        toy_model().save(&mut v1).unwrap();
        assert!(matches!(
            load_model(v1.as_slice()).unwrap(),
            ModelFile::Kernel(_)
        ));
        let mut v3 = Vec::new();
        toy_csr_model().save(&mut v3).unwrap();
        match load_model(v3.as_slice()).unwrap() {
            ModelFile::Kernel(m) => assert!(!m.store().is_dense()),
            other => panic!("v3 k=1 sniffed as {other:?}"),
        }
        let mut v2 = Vec::new();
        shared_multiclass(3, 6, 2, 22).save(&mut v2).unwrap();
        match load_model(v2.as_slice()).unwrap() {
            ModelFile::Multiclass(m) => assert_eq!(m.n_classes(), 3),
            other => panic!("v2 sniffed as {other:?}"),
        }
        let mut mc1 = Vec::new();
        toy_multiclass().save_legacy(&mut mc1).unwrap();
        assert!(matches!(
            load_model(mc1.as_slice()).unwrap(),
            ModelFile::Multiclass(_)
        ));
        let mut rk = Vec::new();
        toy_rks().save(&mut rk).unwrap();
        assert!(matches!(load_model(rk.as_slice()).unwrap(), ModelFile::Rks(_)));
        let mut hy = Vec::new();
        toy_hybrid().save(&mut hy).unwrap();
        assert!(matches!(
            load_model(hy.as_slice()).unwrap(),
            ModelFile::Hybrid(_)
        ));
        // Unknown magic and short files hit the one precise error site.
        let e = load_model(&b"GGUFvXYZrest"[..]).unwrap_err().to_string();
        assert!(e.contains("not a DSEKL model file"), "{e}");
        assert!(load_model(&b"DSE"[..])
            .unwrap_err()
            .to_string()
            .contains("shorter than its 8-byte magic"));
    }

    fn toy_hybrid() -> HybridModel {
        HybridModel::new(toy_model(), toy_rks()).unwrap()
    }

    #[test]
    fn hybrid_save_load_roundtrip_and_scores() {
        let m = toy_hybrid();
        let mut buf = Vec::new();
        m.save(&mut buf).unwrap();
        assert_eq!(&buf[..8], b"DSEKLhy1");
        let m2 = HybridModel::load(buf.as_slice()).unwrap();
        // Bitwise re-encode identity (the fuzz suite's gate).
        let mut buf2 = Vec::new();
        m2.save(&mut buf2).unwrap();
        assert_eq!(buf, buf2);
        let mut ds = Dataset::with_dim(2);
        ds.push(&[0.5, -1.0], 1.0);
        ds.push(&[-0.3, 0.8], -1.0);
        let mut be = NativeBackend::new();
        let s = m.scores(&mut be, &ds).unwrap();
        assert_eq!(s, m2.scores(&mut be, &ds).unwrap());
        // Scores are head + tail elementwise.
        let hs = m.head.scores(&mut be, &ds).unwrap();
        let ts = m.rks.scores(&mut be, &ds).unwrap();
        for ((s, h), t) in s.iter().zip(&hs).zip(&ts) {
            assert!((s - (h + t)).abs() < 1e-6);
        }
    }

    #[test]
    fn hybrid_load_rejects_malformed_containers() {
        let m = toy_hybrid();
        let mut buf = Vec::new();
        m.save(&mut buf).unwrap();
        // Truncation anywhere fails.
        for cut in [9, 20, buf.len() - 1] {
            let mut t = buf.clone();
            t.truncate(cut);
            assert!(HybridModel::load(t.as_slice()).is_err(), "cut={cut}");
        }
        // Trailing bytes are rejected.
        let mut t = buf.clone();
        t.push(0);
        let e = HybridModel::load(t.as_slice()).unwrap_err().to_string();
        assert!(e.contains("trailing"), "{e}");
        // A padded (non-canonical) head sub-blob is rejected even though
        // the inner parse would succeed on a prefix.
        let mut head_blob = Vec::new();
        m.head.save(&mut head_blob).unwrap();
        let mut tail_blob = Vec::new();
        m.rks.save(&mut tail_blob).unwrap();
        let mut padded = Vec::new();
        padded.extend_from_slice(HY_MAGIC);
        padded.extend_from_slice(&((head_blob.len() + 1) as u64).to_le_bytes());
        padded.extend_from_slice(&head_blob);
        padded.push(0);
        padded.extend_from_slice(&(tail_blob.len() as u64).to_le_bytes());
        padded.extend_from_slice(&tail_blob);
        assert!(HybridModel::load(padded.as_slice()).is_err());
        // Mismatched head/tail dimensions are rejected.
        let wide = RksModel {
            w_feat: vec![0.1; 9],
            b_feat: vec![0.2; 3],
            w: vec![0.3; 3],
            d: 3,
            r: 3,
        };
        assert!(HybridModel::new(toy_model(), wide.clone()).is_err());
        let mut wide_blob = Vec::new();
        wide.save(&mut wide_blob).unwrap();
        let mut mismatched = Vec::new();
        mismatched.extend_from_slice(HY_MAGIC);
        mismatched.extend_from_slice(&(head_blob.len() as u64).to_le_bytes());
        mismatched.extend_from_slice(&head_blob);
        mismatched.extend_from_slice(&(wide_blob.len() as u64).to_le_bytes());
        mismatched.extend_from_slice(&wide_blob);
        let e = HybridModel::load(mismatched.as_slice())
            .unwrap_err()
            .to_string();
        assert!(e.contains("dim"), "{e}");
    }
}
