//! Trained-model representation: the dual coefficient vector over its
//! expansion points (Eq. 1 of the paper), prediction helpers, support-
//! vector compaction, and a self-describing binary save/load format.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::data::{Dataset, MultiDataset};
use crate::kernel::Kernel;
use crate::metrics::error_rate;
use crate::runtime::Backend;
use crate::{Error, Result};

const MAGIC: &[u8; 8] = b"DSEKLv1\0";

/// A kernel expansion `f(x) = sum_j k(x, x_j) alpha_j` (Eq. 1): the
/// output of every kernel solver in this crate.
#[derive(Clone, Debug)]
pub struct KernelModel {
    /// Kernel function the expansion was trained with.
    pub kernel: Kernel,
    /// Expansion points, row-major `[n, d]`.
    pub x: Vec<f32>,
    /// Dual coefficients `[n]`.
    pub alpha: Vec<f32>,
    /// Feature dimensionality.
    pub d: usize,
}

impl KernelModel {
    /// Build from a dataset's features and a coefficient vector.
    pub fn new(kernel: Kernel, x: Vec<f32>, alpha: Vec<f32>, d: usize) -> Self {
        assert_eq!(x.len(), alpha.len() * d, "x/alpha shape mismatch");
        KernelModel { kernel, x, alpha, d }
    }

    /// Number of expansion points.
    pub fn len(&self) -> usize {
        self.alpha.len()
    }

    /// True when the expansion is empty.
    pub fn is_empty(&self) -> bool {
        self.alpha.is_empty()
    }

    /// Number of support vectors (|alpha| above `tol`).
    pub fn n_support(&self, tol: f32) -> usize {
        self.alpha.iter().filter(|a| a.abs() > tol).count()
    }

    /// Drop expansion points with |alpha| <= tol — the truncation scheme
    /// the paper's conclusion suggests for fast prediction ("combine
    /// DSEKL with truncation schemes as in [11, 9] after convergence").
    pub fn compact(&self, tol: f32) -> KernelModel {
        let mut x = Vec::new();
        let mut alpha = Vec::new();
        for (jj, &a) in self.alpha.iter().enumerate() {
            if a.abs() > tol {
                x.extend_from_slice(&self.x[jj * self.d..(jj + 1) * self.d]);
                alpha.push(a);
            }
        }
        KernelModel {
            kernel: self.kernel,
            x,
            alpha,
            d: self.d,
        }
    }

    /// Decision scores for a dataset.
    pub fn scores(&self, backend: &mut dyn Backend, ds: &Dataset) -> Result<Vec<f32>> {
        if ds.d != self.d {
            return Err(Error::invalid(format!(
                "dataset dim {} != model dim {}",
                ds.d, self.d
            )));
        }
        let mut f = Vec::new();
        backend.predict(
            self.kernel,
            &ds.x,
            ds.len(),
            &self.x,
            &self.alpha,
            self.len(),
            self.d,
            &mut f,
        )?;
        Ok(f)
    }

    /// Classification error on a labelled dataset.
    pub fn error(&self, backend: &mut dyn Backend, ds: &Dataset) -> Result<f64> {
        Ok(error_rate(&self.scores(backend, ds)?, &ds.y))
    }

    /// Serialise to a writer (little-endian, self-describing header).
    pub fn save<W: Write>(&self, w: W) -> Result<()> {
        let mut w = BufWriter::new(w);
        w.write_all(MAGIC)?;
        let kind: u32 = match self.kernel {
            Kernel::Rbf { .. } => 0,
            Kernel::Linear => 1,
            Kernel::Poly { .. } => 2,
        };
        w.write_all(&kind.to_le_bytes())?;
        let (g, deg, c0) = match self.kernel {
            Kernel::Rbf { gamma } => (gamma, 0u32, 0.0f32),
            Kernel::Linear => (0.0, 0, 0.0),
            Kernel::Poly { gamma, degree, coef0 } => (gamma, degree, coef0),
        };
        w.write_all(&g.to_le_bytes())?;
        w.write_all(&deg.to_le_bytes())?;
        w.write_all(&c0.to_le_bytes())?;
        w.write_all(&(self.len() as u64).to_le_bytes())?;
        w.write_all(&(self.d as u64).to_le_bytes())?;
        for v in &self.alpha {
            w.write_all(&v.to_le_bytes())?;
        }
        for v in &self.x {
            w.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }

    /// Deserialise from a reader.
    pub fn load<R: Read>(r: R) -> Result<KernelModel> {
        let mut r = BufReader::new(r);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::parse("not a DSEKL model file"));
        }
        let mut b4 = [0u8; 4];
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b4)?;
        let kind = u32::from_le_bytes(b4);
        r.read_exact(&mut b4)?;
        let gamma = f32::from_le_bytes(b4);
        r.read_exact(&mut b4)?;
        let degree = u32::from_le_bytes(b4);
        r.read_exact(&mut b4)?;
        let coef0 = f32::from_le_bytes(b4);
        let kernel = match kind {
            0 => Kernel::Rbf { gamma },
            1 => Kernel::Linear,
            2 => Kernel::Poly { gamma, degree, coef0 },
            k => return Err(Error::parse(format!("unknown kernel kind {k}"))),
        };
        r.read_exact(&mut b8)?;
        let n = u64::from_le_bytes(b8) as usize;
        r.read_exact(&mut b8)?;
        let d = u64::from_le_bytes(b8) as usize;
        if n.checked_mul(d).is_none() || n * d > (1 << 34) {
            return Err(Error::parse("model dimensions implausible"));
        }
        let mut alpha = vec![0.0f32; n];
        for v in &mut alpha {
            r.read_exact(&mut b4)?;
            *v = f32::from_le_bytes(b4);
        }
        let mut x = vec![0.0f32; n * d];
        for v in &mut x {
            r.read_exact(&mut b4)?;
            *v = f32::from_le_bytes(b4);
        }
        Ok(KernelModel { kernel, x, alpha, d })
    }

    /// Save to a file path.
    pub fn save_file<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        self.save(std::fs::File::create(path)?)
    }

    /// Load from a file path.
    pub fn load_file<P: AsRef<Path>>(path: P) -> Result<KernelModel> {
        Self::load(std::fs::File::open(path)?)
    }
}

const MC_MAGIC: &[u8; 8] = b"DSEKLmc1";

/// A one-vs-rest multiclass model: K binary kernel expansions, one per
/// class, with argmax decision. Produced by
/// [`crate::solver::ovr::OvrSolver`].
#[derive(Clone, Debug)]
pub struct MulticlassModel {
    /// Per-class binary machines; index == class id.
    pub models: Vec<KernelModel>,
}

impl MulticlassModel {
    /// Build from per-class binary models (index == class id).
    pub fn new(models: Vec<KernelModel>) -> Self {
        assert!(models.len() >= 2, "need at least two classes");
        let d = models[0].d;
        assert!(
            models.iter().all(|m| m.d == d),
            "per-class models disagree on dimensionality"
        );
        MulticlassModel { models }
    }

    /// Number of classes K.
    pub fn n_classes(&self) -> usize {
        self.models.len()
    }

    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        self.models[0].d
    }

    /// Per-class decision scores, row-major `[n, K]`.
    pub fn scores(&self, backend: &mut dyn Backend, ds: &MultiDataset) -> Result<Vec<f32>> {
        if ds.d != self.dim() {
            return Err(Error::invalid(format!(
                "dataset dim {} != model dim {}",
                ds.d,
                self.dim()
            )));
        }
        let n = ds.len();
        let k = self.n_classes();
        let mut out = vec![0.0f32; n * k];
        let mut f = Vec::new();
        for (c, m) in self.models.iter().enumerate() {
            backend.predict(m.kernel, &ds.x, n, &m.x, &m.alpha, m.len(), m.d, &mut f)?;
            for (i, &v) in f.iter().enumerate() {
                out[i * k + c] = v;
            }
        }
        Ok(out)
    }

    /// Argmax class prediction per example.
    pub fn predict(&self, backend: &mut dyn Backend, ds: &MultiDataset) -> Result<Vec<u32>> {
        let k = self.n_classes();
        let scores = self.scores(backend, ds)?;
        Ok(scores
            .chunks(k)
            .map(|row| {
                let mut best = 0usize;
                for (c, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = c;
                    }
                }
                best as u32
            })
            .collect())
    }

    /// Multiclass classification error rate.
    pub fn error(&self, backend: &mut dyn Backend, ds: &MultiDataset) -> Result<f64> {
        if ds.is_empty() {
            return Ok(0.0);
        }
        let pred = self.predict(backend, ds)?;
        let wrong = pred.iter().zip(&ds.y).filter(|(p, y)| p != y).count();
        Ok(wrong as f64 / ds.len() as f64)
    }

    /// Serialise: magic + class count + length-prefixed per-class models
    /// (each in the [`KernelModel`] binary format).
    pub fn save<W: Write>(&self, mut w: W) -> Result<()> {
        w.write_all(MC_MAGIC)?;
        w.write_all(&(self.models.len() as u64).to_le_bytes())?;
        for m in &self.models {
            let mut buf = Vec::new();
            m.save(&mut buf)?;
            w.write_all(&(buf.len() as u64).to_le_bytes())?;
            w.write_all(&buf)?;
        }
        Ok(())
    }

    /// Deserialise a [`MulticlassModel`].
    pub fn load<R: Read>(mut r: R) -> Result<MulticlassModel> {
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MC_MAGIC {
            return Err(Error::parse("not a DSEKL multiclass model file"));
        }
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        let k = u64::from_le_bytes(b8) as usize;
        if !(2..=4096).contains(&k) {
            return Err(Error::parse(format!("implausible class count {k}")));
        }
        let mut models: Vec<KernelModel> = Vec::with_capacity(k);
        for _ in 0..k {
            r.read_exact(&mut b8)?;
            let len = u64::from_le_bytes(b8) as usize;
            // Cap each chunk well below anything a real model produces so
            // a crafted header cannot trigger a giant pre-allocation.
            if len > (1 << 30) {
                return Err(Error::parse("model chunk implausibly large"));
            }
            let mut buf = vec![0u8; len];
            r.read_exact(&mut buf)?;
            let m = KernelModel::load(buf.as_slice())?;
            // Validate here with an Err — `new()` asserts, which must
            // never be reachable from untrusted file contents.
            if let Some(first) = models.first() {
                if m.d != first.d {
                    return Err(Error::parse(format!(
                        "per-class models disagree on dimensionality ({} vs {})",
                        first.d, m.d
                    )));
                }
            }
            models.push(m);
        }
        Ok(MulticlassModel::new(models))
    }

    /// Save to a file path.
    pub fn save_file<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        self.save(std::fs::File::create(path)?)
    }

    /// Load from a file path.
    pub fn load_file<P: AsRef<Path>>(path: P) -> Result<MulticlassModel> {
        Self::load(std::fs::File::open(path)?)
    }
}

/// An RKS (random-kitchen-sinks) linear model in RFF feature space —
/// the explicit-kernel-map baseline of Fig. 2.
#[derive(Clone, Debug)]
pub struct RksModel {
    /// Frequencies `[d, r]`.
    pub w_feat: Vec<f32>,
    /// Phases `[r]`.
    pub b_feat: Vec<f32>,
    /// Primal weights `[r]`.
    pub w: Vec<f32>,
    pub d: usize,
    pub r: usize,
}

impl RksModel {
    /// Decision scores for a dataset.
    pub fn scores(&self, backend: &mut dyn Backend, ds: &Dataset) -> Result<Vec<f32>> {
        if ds.d != self.d {
            return Err(Error::invalid(format!(
                "dataset dim {} != model dim {}",
                ds.d, self.d
            )));
        }
        let mut f = Vec::new();
        backend.rks_predict(
            &ds.x,
            ds.len(),
            &self.w_feat,
            &self.b_feat,
            &self.w,
            self.d,
            self.r,
            &mut f,
        )?;
        Ok(f)
    }

    /// Classification error on a labelled dataset.
    pub fn error(&self, backend: &mut dyn Backend, ds: &Dataset) -> Result<f64> {
        Ok(error_rate(&self.scores(backend, ds)?, &ds.y))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn toy_model() -> KernelModel {
        KernelModel::new(
            Kernel::rbf(0.5),
            vec![0.0, 0.0, 1.0, 1.0, -1.0, -1.0],
            vec![0.5, -0.25, 0.1],
            2,
        )
    }

    #[test]
    fn save_load_roundtrip() {
        let m = toy_model();
        let mut buf = Vec::new();
        m.save(&mut buf).unwrap();
        let m2 = KernelModel::load(buf.as_slice()).unwrap();
        assert_eq!(m.kernel, m2.kernel);
        assert_eq!(m.x, m2.x);
        assert_eq!(m.alpha, m2.alpha);
        assert_eq!(m.d, m2.d);
    }

    #[test]
    fn save_load_poly_kernel() {
        let mut m = toy_model();
        m.kernel = Kernel::Poly { gamma: 0.3, degree: 3, coef0: 1.5 };
        let mut buf = Vec::new();
        m.save(&mut buf).unwrap();
        assert_eq!(KernelModel::load(buf.as_slice()).unwrap().kernel, m.kernel);
    }

    #[test]
    fn load_rejects_garbage() {
        assert!(KernelModel::load(&b"not a model"[..]).is_err());
        let mut buf = Vec::new();
        toy_model().save(&mut buf).unwrap();
        buf.truncate(buf.len() - 3);
        assert!(KernelModel::load(buf.as_slice()).is_err());
    }

    #[test]
    fn compact_drops_small_alphas() {
        let m = KernelModel::new(
            Kernel::rbf(1.0),
            vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0],
            vec![0.5, 1e-9, -0.3],
            2,
        );
        assert_eq!(m.n_support(1e-6), 2);
        let c = m.compact(1e-6);
        assert_eq!(c.len(), 2);
        assert_eq!(c.alpha, vec![0.5, -0.3]);
        assert_eq!(c.x, vec![0.0, 0.0, 2.0, 2.0]);
    }

    #[test]
    fn compact_preserves_predictions() {
        let m = KernelModel::new(
            Kernel::rbf(1.0),
            vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0],
            vec![0.5, 0.0, -0.3],
            2,
        );
        let mut ds = Dataset::with_dim(2);
        ds.push(&[0.5, 0.5], 1.0);
        ds.push(&[-1.0, 2.0], -1.0);
        let mut be = NativeBackend::new();
        let s1 = m.scores(&mut be, &ds).unwrap();
        let s2 = m.compact(1e-6).scores(&mut be, &ds).unwrap();
        for (a, b) in s1.iter().zip(&s2) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn scores_dimension_check() {
        let m = toy_model();
        let ds = Dataset::with_dim(5);
        let mut be = NativeBackend::new();
        assert!(m.scores(&mut be, &ds).is_err());
    }

    /// Three one-point expansions at distinct centers: argmax picks the
    /// nearest center under the RBF kernel.
    fn toy_multiclass() -> MulticlassModel {
        let centers = [[0.0f32, 0.0], [3.0, 0.0], [0.0, 3.0]];
        let models = centers
            .iter()
            .map(|c| KernelModel::new(Kernel::rbf(1.0), c.to_vec(), vec![1.0], 2))
            .collect();
        MulticlassModel::new(models)
    }

    #[test]
    fn multiclass_argmax_picks_nearest_center() {
        let m = toy_multiclass();
        assert_eq!(m.n_classes(), 3);
        assert_eq!(m.dim(), 2);
        let mut ds = MultiDataset::with_dims(2, 3);
        ds.push(&[0.2, -0.1], 0);
        ds.push(&[2.8, 0.3], 1);
        ds.push(&[-0.2, 3.1], 2);
        let mut be = NativeBackend::new();
        let pred = m.predict(&mut be, &ds).unwrap();
        assert_eq!(pred, vec![0, 1, 2]);
        assert_eq!(m.error(&mut be, &ds).unwrap(), 0.0);
        // Scores matrix is [n, K] row-major with the winning class max.
        let scores = m.scores(&mut be, &ds).unwrap();
        assert_eq!(scores.len(), 9);
        assert!(scores[0] > scores[1] && scores[0] > scores[2]);
    }

    #[test]
    fn multiclass_error_counts_mislabels() {
        let m = toy_multiclass();
        let mut ds = MultiDataset::with_dims(2, 3);
        ds.push(&[0.0, 0.0], 1); // wrong on purpose
        ds.push(&[3.0, 0.0], 1);
        let mut be = NativeBackend::new();
        assert!((m.error(&mut be, &ds).unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn multiclass_save_load_roundtrip() {
        let m = toy_multiclass();
        let mut buf = Vec::new();
        m.save(&mut buf).unwrap();
        let m2 = MulticlassModel::load(buf.as_slice()).unwrap();
        assert_eq!(m2.n_classes(), 3);
        for (a, b) in m.models.iter().zip(&m2.models) {
            assert_eq!(a.kernel, b.kernel);
            assert_eq!(a.x, b.x);
            assert_eq!(a.alpha, b.alpha);
        }
        // Garbage and truncation are rejected.
        assert!(MulticlassModel::load(&b"DSEKLv1\0junk"[..]).is_err());
        buf.truncate(buf.len() - 2);
        assert!(MulticlassModel::load(buf.as_slice()).is_err());
    }

    #[test]
    fn multiclass_dimension_check() {
        let m = toy_multiclass();
        let ds = MultiDataset::with_dims(5, 3);
        let mut be = NativeBackend::new();
        assert!(m.scores(&mut be, &ds).is_err());
    }
}
