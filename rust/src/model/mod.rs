//! Trained-model representation: shared-ownership expansion storage
//! ([`ExpansionStore`]), the single-head kernel expansion view
//! ([`KernelModel`], Eq. 1 of the paper), the K-head one-vs-rest model
//! ([`MulticlassModel`]) whose heads share one row block, prediction
//! helpers, support-vector compaction, and self-describing binary
//! save/load formats (DSEKLv1 single-head, DSEKLv2 multi-head with one
//! row block for all K coefficient vectors; legacy DSEKLmc1 files still
//! load).

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;
use std::sync::Arc;

use crate::data::{Dataset, MultiDataset, Rows, SparseDataset, SparseMultiDataset};
use crate::kernel::Kernel;
use crate::metrics::error_rate;
use crate::runtime::Backend;
use crate::{Error, Result};

const MAGIC: &[u8; 8] = b"DSEKLv1\0";

/// Shared-ownership expansion-point storage: one immutable row block
/// `[n, d]` behind an `Arc`, so any number of model heads (the K
/// one-vs-rest machines, compacted views, coordinator snapshots) can
/// reference the same rows without copying them. Cloning an
/// `ExpansionStore` clones the `Arc`, never the floats.
#[derive(Clone, Debug)]
pub struct ExpansionStore {
    rows: Arc<[f32]>,
    d: usize,
}

impl ExpansionStore {
    /// Take ownership of a row-major `[n, d]` block.
    pub fn new(rows: Vec<f32>, d: usize) -> Self {
        if d > 0 {
            assert_eq!(rows.len() % d, 0, "row block not a multiple of d");
        }
        ExpansionStore {
            rows: rows.into(),
            d,
        }
    }

    /// Number of expansion points.
    pub fn len(&self) -> usize {
        if self.d == 0 {
            0
        } else {
            self.rows.len() / self.d
        }
    }

    /// True when the store holds no rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// The raw row block, row-major `[n, d]`.
    pub fn rows(&self) -> &[f32] {
        &self.rows
    }

    /// Whether two stores share the same allocation (not just equal
    /// contents) — the invariant the multi-head formats preserve.
    pub fn shares_rows_with(&self, other: &ExpansionStore) -> bool {
        Arc::ptr_eq(&self.rows, &other.rows)
    }
}

/// A kernel expansion `f(x) = sum_j k(x, x_j) alpha_j` (Eq. 1): the
/// output of every kernel solver in this crate. A `KernelModel` is a
/// single-head *view* over an [`ExpansionStore`] — the coefficient
/// vector is owned, the expansion rows are shared.
#[derive(Clone, Debug)]
pub struct KernelModel {
    /// Kernel function the expansion was trained with.
    pub kernel: Kernel,
    /// Shared expansion rows.
    store: ExpansionStore,
    /// Dual coefficients `[n]`.
    pub alpha: Vec<f32>,
}

impl KernelModel {
    /// Build from a dataset's features and a coefficient vector.
    pub fn new(kernel: Kernel, x: Vec<f32>, alpha: Vec<f32>, d: usize) -> Self {
        assert_eq!(x.len(), alpha.len() * d, "x/alpha shape mismatch");
        KernelModel {
            kernel,
            store: ExpansionStore::new(x, d),
            alpha,
        }
    }

    /// Single-head view over an existing (possibly shared) store.
    pub fn from_store(kernel: Kernel, store: ExpansionStore, alpha: Vec<f32>) -> Self {
        assert_eq!(
            store.rows().len(),
            alpha.len() * store.dim(),
            "store/alpha shape mismatch"
        );
        KernelModel {
            kernel,
            store,
            alpha,
        }
    }

    /// The shared expansion storage backing this head.
    pub fn store(&self) -> &ExpansionStore {
        &self.store
    }

    /// Expansion points, row-major `[n, d]`.
    pub fn x(&self) -> &[f32] {
        self.store.rows()
    }

    /// Feature dimensionality.
    pub fn d(&self) -> usize {
        self.store.dim()
    }

    /// Number of expansion points.
    pub fn len(&self) -> usize {
        self.alpha.len()
    }

    /// True when the expansion is empty.
    pub fn is_empty(&self) -> bool {
        self.alpha.is_empty()
    }

    /// Number of support vectors (|alpha| above `tol`).
    pub fn n_support(&self, tol: f32) -> usize {
        self.alpha.iter().filter(|a| a.abs() > tol).count()
    }

    /// Drop expansion points with |alpha| <= tol — the truncation scheme
    /// the paper's conclusion suggests for fast prediction ("combine
    /// DSEKL with truncation schemes as in [11, 9] after convergence").
    /// The compacted model owns a fresh (smaller) store.
    pub fn compact(&self, tol: f32) -> KernelModel {
        let d = self.d();
        let mut x = Vec::new();
        let mut alpha = Vec::new();
        for (jj, &a) in self.alpha.iter().enumerate() {
            if a.abs() > tol {
                x.extend_from_slice(&self.x()[jj * d..(jj + 1) * d]);
                alpha.push(a);
            }
        }
        KernelModel::new(self.kernel, x, alpha, d)
    }

    /// Decision scores for arbitrary [`Rows`] (dense or CSR test
    /// points against the dense expansion).
    pub fn scores_rows(&self, backend: &mut dyn Backend, xt: Rows) -> Result<Vec<f32>> {
        if xt.dim() != self.d() {
            return Err(Error::invalid(format!(
                "dataset dim {} != model dim {}",
                xt.dim(),
                self.d()
            )));
        }
        let mut f = Vec::new();
        backend.predict(
            self.kernel,
            xt,
            Rows::dense(self.x(), self.len(), self.d()),
            &self.alpha,
            &mut f,
        )?;
        Ok(f)
    }

    /// Decision scores for a dataset.
    pub fn scores(&self, backend: &mut dyn Backend, ds: &Dataset) -> Result<Vec<f32>> {
        self.scores_rows(backend, Rows::dense(&ds.x, ds.len(), ds.d))
    }

    /// Classification error on a labelled dataset.
    pub fn error(&self, backend: &mut dyn Backend, ds: &Dataset) -> Result<f64> {
        Ok(error_rate(&self.scores(backend, ds)?, &ds.y))
    }

    /// Classification error on arbitrary labelled [`Rows`].
    pub fn error_rows(&self, backend: &mut dyn Backend, xt: Rows, y: &[f32]) -> Result<f64> {
        Ok(error_rate(&self.scores_rows(backend, xt)?, y))
    }

    /// Classification error on a labelled CSR dataset (the test points
    /// stay sparse; only the expansion rows are dense).
    pub fn error_sparse(&self, backend: &mut dyn Backend, ds: &SparseDataset) -> Result<f64> {
        self.error_rows(backend, ds.rows(), &ds.y)
    }

    /// Serialise to a writer (little-endian, self-describing header).
    pub fn save<W: Write>(&self, w: W) -> Result<()> {
        let mut w = BufWriter::new(w);
        w.write_all(MAGIC)?;
        write_kernel(&mut w, self.kernel)?;
        w.write_all(&(self.len() as u64).to_le_bytes())?;
        w.write_all(&(self.d() as u64).to_le_bytes())?;
        write_f32s(&mut w, &self.alpha)?;
        write_f32s(&mut w, self.x())?;
        Ok(())
    }

    /// Deserialise from a reader.
    pub fn load<R: Read>(r: R) -> Result<KernelModel> {
        let mut r = BufReader::new(r);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::parse("not a DSEKL model file"));
        }
        let kernel = read_kernel(&mut r)?;
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        let n = u64::from_le_bytes(b8) as usize;
        r.read_exact(&mut b8)?;
        let d = u64::from_le_bytes(b8) as usize;
        if n.checked_mul(d).is_none() || n * d > (1 << 34) {
            return Err(Error::parse("model dimensions implausible"));
        }
        let mut alpha = vec![0.0f32; n];
        read_f32s(&mut r, &mut alpha)?;
        let mut x = vec![0.0f32; n * d];
        read_f32s(&mut r, &mut x)?;
        Ok(KernelModel::new(kernel, x, alpha, d))
    }

    /// Save to a file path.
    pub fn save_file<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        self.save(std::fs::File::create(path)?)
    }

    /// Load from a file path.
    pub fn load_file<P: AsRef<Path>>(path: P) -> Result<KernelModel> {
        Self::load(std::fs::File::open(path)?)
    }
}

/// Write the kernel wire header (kind + gamma + degree + coef0).
fn write_kernel<W: Write>(w: &mut W, kernel: Kernel) -> Result<()> {
    let (kind, gamma, degree, coef0) = kernel.encode_wire();
    w.write_all(&kind.to_le_bytes())?;
    w.write_all(&gamma.to_le_bytes())?;
    w.write_all(&degree.to_le_bytes())?;
    w.write_all(&coef0.to_le_bytes())?;
    Ok(())
}

/// Read the kernel wire header written by [`write_kernel`].
fn read_kernel<R: Read>(r: &mut R) -> Result<Kernel> {
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let kind = u32::from_le_bytes(b4);
    r.read_exact(&mut b4)?;
    let gamma = f32::from_le_bytes(b4);
    r.read_exact(&mut b4)?;
    let degree = u32::from_le_bytes(b4);
    r.read_exact(&mut b4)?;
    let coef0 = f32::from_le_bytes(b4);
    Kernel::decode_wire(kind, gamma, degree, coef0)
}

fn write_f32s<W: Write>(w: &mut W, vs: &[f32]) -> Result<()> {
    for v in vs {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn read_f32s<R: Read>(r: &mut R, out: &mut [f32]) -> Result<()> {
    let mut b4 = [0u8; 4];
    for v in out {
        r.read_exact(&mut b4)?;
        *v = f32::from_le_bytes(b4);
    }
    Ok(())
}

const MC_MAGIC: &[u8; 8] = b"DSEKLmc1";
const V2_MAGIC: &[u8; 8] = b"DSEKLv2\0";

/// A one-vs-rest multiclass model: K binary kernel-expansion heads with
/// argmax decision. Produced by [`crate::solver::ovr::OvrSolver`].
///
/// The K heads are views over **one** [`ExpansionStore`] whenever
/// possible (always, for solver output and DSEKLv2 files): the expansion
/// rows are stored once, only the K coefficient vectors are per-head.
/// Serialises as DSEKLv2 (one row block + `[K, n]` coefficients) when
/// the heads share storage and kernel, falling back to the legacy
/// per-head DSEKLmc1 container otherwise; both formats load.
#[derive(Clone, Debug)]
pub struct MulticlassModel {
    /// Per-class binary machines; index == class id.
    pub models: Vec<KernelModel>,
}

impl MulticlassModel {
    /// Build from per-class binary models (index == class id). When the
    /// per-class expansions hold identical rows (the one-vs-rest case),
    /// the heads are rebuilt as views over a single shared store.
    pub fn new(models: Vec<KernelModel>) -> Self {
        assert!(models.len() >= 2, "need at least two classes");
        let d = models[0].d();
        assert!(
            models.iter().all(|m| m.d() == d),
            "per-class models disagree on dimensionality"
        );
        let first = &models[0];
        let dedupable = models
            .iter()
            .all(|m| m.kernel == first.kernel && m.x() == first.x());
        if dedupable {
            let store = first.store().clone();
            let kernel = first.kernel;
            let models = models
                .into_iter()
                .map(|m| KernelModel::from_store(kernel, store.clone(), m.alpha))
                .collect();
            return MulticlassModel { models };
        }
        MulticlassModel { models }
    }

    /// Build K heads directly over one shared store from a row-major
    /// `[K, n]` coefficient matrix — the solver-facing constructor.
    pub fn from_shared(kernel: Kernel, store: ExpansionStore, coef: Vec<f32>) -> Self {
        let n = store.len();
        assert!(n > 0, "empty expansion store");
        assert_eq!(coef.len() % n, 0, "coef matrix not a multiple of n");
        let k = coef.len() / n;
        assert!(k >= 2, "need at least two classes");
        let models = (0..k)
            .map(|h| {
                KernelModel::from_store(kernel, store.clone(), coef[h * n..(h + 1) * n].to_vec())
            })
            .collect();
        MulticlassModel { models }
    }

    /// Number of classes K.
    pub fn n_classes(&self) -> usize {
        self.models.len()
    }

    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        self.models[0].d()
    }

    /// Whether all heads are views over one shared row block with one
    /// kernel — the invariant that enables the fused predict path and
    /// the DSEKLv2 format.
    pub fn is_shared(&self) -> bool {
        let first = &self.models[0];
        self.models.iter().all(|m| {
            m.kernel == first.kernel
                && m.len() == first.len()
                && m.store().shares_rows_with(first.store())
        })
    }

    /// The `[K, n]` per-head coefficient matrix, row-major.
    pub fn coef_matrix(&self) -> Vec<f32> {
        let mut coef = Vec::with_capacity(self.n_classes() * self.models[0].len());
        for m in &self.models {
            coef.extend_from_slice(&m.alpha);
        }
        coef
    }

    /// Per-class decision scores for arbitrary [`Rows`], row-major
    /// `[n, K]`. Shared-storage models score all K heads in one fused
    /// pass over the kernel rows ([`Backend::predict_multi`]);
    /// heterogeneous models fall back to one predict per head.
    pub fn scores_rows(&self, backend: &mut dyn Backend, xt: Rows) -> Result<Vec<f32>> {
        if xt.dim() != self.dim() {
            return Err(Error::invalid(format!(
                "dataset dim {} != model dim {}",
                xt.dim(),
                self.dim()
            )));
        }
        let n = xt.len();
        let k = self.n_classes();
        if self.is_shared() {
            let head = &self.models[0];
            let coef = self.coef_matrix();
            let mut out = Vec::new();
            backend.predict_multi(
                head.kernel,
                xt,
                Rows::dense(head.x(), head.len(), head.d()),
                &coef,
                k,
                &mut out,
            )?;
            return Ok(out);
        }
        let mut out = vec![0.0f32; n * k];
        let mut f = Vec::new();
        for (c, m) in self.models.iter().enumerate() {
            backend.predict(
                m.kernel,
                xt,
                Rows::dense(m.x(), m.len(), m.d()),
                &m.alpha,
                &mut f,
            )?;
            for (i, &v) in f.iter().enumerate() {
                out[i * k + c] = v;
            }
        }
        Ok(out)
    }

    /// Per-class decision scores for a dense dataset, row-major `[n, K]`.
    pub fn scores(&self, backend: &mut dyn Backend, ds: &MultiDataset) -> Result<Vec<f32>> {
        self.scores_rows(backend, Rows::dense(&ds.x, ds.len(), ds.d))
    }

    /// Argmax class prediction per [`Rows`] example.
    pub fn predict_rows(&self, backend: &mut dyn Backend, xt: Rows) -> Result<Vec<u32>> {
        let k = self.n_classes();
        let scores = self.scores_rows(backend, xt)?;
        Ok(scores
            .chunks(k)
            .map(|row| {
                let mut best = 0usize;
                for (c, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = c;
                    }
                }
                best as u32
            })
            .collect())
    }

    /// Argmax class prediction per example.
    pub fn predict(&self, backend: &mut dyn Backend, ds: &MultiDataset) -> Result<Vec<u32>> {
        self.predict_rows(backend, Rows::dense(&ds.x, ds.len(), ds.d))
    }

    /// Multiclass classification error rate.
    pub fn error(&self, backend: &mut dyn Backend, ds: &MultiDataset) -> Result<f64> {
        if ds.is_empty() {
            return Ok(0.0);
        }
        let pred = self.predict(backend, ds)?;
        let wrong = pred.iter().zip(&ds.y).filter(|(p, y)| p != y).count();
        Ok(wrong as f64 / ds.len() as f64)
    }

    /// Multiclass error rate on a labelled CSR dataset.
    pub fn error_sparse(
        &self,
        backend: &mut dyn Backend,
        ds: &SparseMultiDataset,
    ) -> Result<f64> {
        if ds.is_empty() {
            return Ok(0.0);
        }
        let pred = self.predict_rows(backend, ds.rows())?;
        let wrong = pred.iter().zip(&ds.y).filter(|(p, y)| p != y).count();
        Ok(wrong as f64 / ds.len() as f64)
    }

    /// Serialise. Shared-storage models (the normal case) write the
    /// DSEKLv2 format — magic + kernel + `(K, n, d)` + the `[K, n]`
    /// coefficient matrix + **one** `[n, d]` row block, ~K× smaller than
    /// writing K full expansions. Heterogeneous models fall back to the
    /// legacy per-head container ([`MulticlassModel::save_legacy`]).
    pub fn save<W: Write>(&self, mut w: W) -> Result<()> {
        if !self.is_shared() {
            return self.save_legacy(w);
        }
        let head = &self.models[0];
        w.write_all(V2_MAGIC)?;
        write_kernel(&mut w, head.kernel)?;
        w.write_all(&(self.n_classes() as u64).to_le_bytes())?;
        w.write_all(&(head.len() as u64).to_le_bytes())?;
        w.write_all(&(head.d() as u64).to_le_bytes())?;
        for m in &self.models {
            write_f32s(&mut w, &m.alpha)?;
        }
        write_f32s(&mut w, head.x())?;
        Ok(())
    }

    /// Serialise in the legacy DSEKLmc1 container: magic + class count +
    /// length-prefixed per-class models (each a full DSEKLv1 blob, rows
    /// duplicated K times). Kept for heterogeneous models and so the
    /// migration path stays testable.
    pub fn save_legacy<W: Write>(&self, mut w: W) -> Result<()> {
        w.write_all(MC_MAGIC)?;
        w.write_all(&(self.models.len() as u64).to_le_bytes())?;
        for m in &self.models {
            let mut buf = Vec::new();
            m.save(&mut buf)?;
            w.write_all(&(buf.len() as u64).to_le_bytes())?;
            w.write_all(&buf)?;
        }
        Ok(())
    }

    /// Deserialise a [`MulticlassModel`] — either format: DSEKLv2
    /// (shared rows) or the legacy DSEKLmc1 per-head container.
    pub fn load<R: Read>(mut r: R) -> Result<MulticlassModel> {
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        match &magic {
            m if m == V2_MAGIC => Self::load_v2_body(r),
            m if m == MC_MAGIC => Self::load_legacy_body(r),
            _ => Err(Error::parse("not a DSEKL multiclass model file")),
        }
    }

    /// DSEKLv2 body (after the magic): one row block, K coefficient
    /// vectors over it.
    fn load_v2_body<R: Read>(r: R) -> Result<MulticlassModel> {
        let mut r = BufReader::new(r);
        let kernel = read_kernel(&mut r)?;
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        let k = u64::from_le_bytes(b8) as usize;
        if !(2..=4096).contains(&k) {
            return Err(Error::parse(format!("implausible class count {k}")));
        }
        r.read_exact(&mut b8)?;
        let n = u64::from_le_bytes(b8) as usize;
        r.read_exact(&mut b8)?;
        let d = u64::from_le_bytes(b8) as usize;
        if n == 0 || d == 0 || n.checked_mul(d).is_none() || n * d > (1 << 34) {
            return Err(Error::parse("model dimensions implausible"));
        }
        // Bound the coefficient matrix too: k and n*d can each look sane
        // while k*n is still a multi-terabyte allocation request.
        if n.checked_mul(k).is_none() || n * k > (1 << 34) {
            return Err(Error::parse("coefficient matrix implausibly large"));
        }
        let mut coef = vec![0.0f32; k * n];
        read_f32s(&mut r, &mut coef)?;
        let mut x = vec![0.0f32; n * d];
        read_f32s(&mut r, &mut x)?;
        Ok(MulticlassModel::from_shared(
            kernel,
            ExpansionStore::new(x, d),
            coef,
        ))
    }

    /// Legacy DSEKLmc1 body (after the magic): K length-prefixed
    /// DSEKLv1 models. `MulticlassModel::new` re-deduplicates the rows
    /// into one shared store when the heads agree.
    fn load_legacy_body<R: Read>(mut r: R) -> Result<MulticlassModel> {
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        let k = u64::from_le_bytes(b8) as usize;
        if !(2..=4096).contains(&k) {
            return Err(Error::parse(format!("implausible class count {k}")));
        }
        let mut models: Vec<KernelModel> = Vec::with_capacity(k);
        for _ in 0..k {
            r.read_exact(&mut b8)?;
            let len = u64::from_le_bytes(b8) as usize;
            // Cap each chunk well below anything a real model produces so
            // a crafted header cannot trigger a giant pre-allocation.
            if len > (1 << 30) {
                return Err(Error::parse("model chunk implausibly large"));
            }
            let mut buf = vec![0u8; len];
            r.read_exact(&mut buf)?;
            let m = KernelModel::load(buf.as_slice())?;
            // Validate here with an Err — `new()` asserts, which must
            // never be reachable from untrusted file contents.
            if let Some(first) = models.first() {
                if m.d() != first.d() {
                    return Err(Error::parse(format!(
                        "per-class models disagree on dimensionality ({} vs {})",
                        first.d(),
                        m.d()
                    )));
                }
            }
            models.push(m);
        }
        Ok(MulticlassModel::new(models))
    }

    /// Save to a file path.
    pub fn save_file<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        self.save(std::fs::File::create(path)?)
    }

    /// Load from a file path.
    pub fn load_file<P: AsRef<Path>>(path: P) -> Result<MulticlassModel> {
        Self::load(std::fs::File::open(path)?)
    }
}

/// An RKS (random-kitchen-sinks) linear model in RFF feature space —
/// the explicit-kernel-map baseline of Fig. 2.
#[derive(Clone, Debug)]
pub struct RksModel {
    /// Frequencies `[d, r]`.
    pub w_feat: Vec<f32>,
    /// Phases `[r]`.
    pub b_feat: Vec<f32>,
    /// Primal weights `[r]`.
    pub w: Vec<f32>,
    pub d: usize,
    pub r: usize,
}

impl RksModel {
    /// Decision scores for a dataset.
    pub fn scores(&self, backend: &mut dyn Backend, ds: &Dataset) -> Result<Vec<f32>> {
        if ds.d != self.d {
            return Err(Error::invalid(format!(
                "dataset dim {} != model dim {}",
                ds.d, self.d
            )));
        }
        let mut f = Vec::new();
        backend.rks_predict(
            Rows::dense(&ds.x, ds.len(), ds.d),
            &self.w_feat,
            &self.b_feat,
            &self.w,
            self.r,
            &mut f,
        )?;
        Ok(f)
    }

    /// Classification error on a labelled dataset.
    pub fn error(&self, backend: &mut dyn Backend, ds: &Dataset) -> Result<f64> {
        Ok(error_rate(&self.scores(backend, ds)?, &ds.y))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn toy_model() -> KernelModel {
        KernelModel::new(
            Kernel::rbf(0.5),
            vec![0.0, 0.0, 1.0, 1.0, -1.0, -1.0],
            vec![0.5, -0.25, 0.1],
            2,
        )
    }

    #[test]
    fn save_load_roundtrip() {
        let m = toy_model();
        let mut buf = Vec::new();
        m.save(&mut buf).unwrap();
        let m2 = KernelModel::load(buf.as_slice()).unwrap();
        assert_eq!(m.kernel, m2.kernel);
        assert_eq!(m.x(), m2.x());
        assert_eq!(m.alpha, m2.alpha);
        assert_eq!(m.d(), m2.d());
    }

    #[test]
    fn save_load_poly_kernel() {
        let mut m = toy_model();
        m.kernel = Kernel::Poly { gamma: 0.3, degree: 3, coef0: 1.5 };
        let mut buf = Vec::new();
        m.save(&mut buf).unwrap();
        assert_eq!(KernelModel::load(buf.as_slice()).unwrap().kernel, m.kernel);
    }

    #[test]
    fn load_rejects_garbage() {
        assert!(KernelModel::load(&b"not a model"[..]).is_err());
        let mut buf = Vec::new();
        toy_model().save(&mut buf).unwrap();
        buf.truncate(buf.len() - 3);
        assert!(KernelModel::load(buf.as_slice()).is_err());
    }

    #[test]
    fn compact_drops_small_alphas() {
        let m = KernelModel::new(
            Kernel::rbf(1.0),
            vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0],
            vec![0.5, 1e-9, -0.3],
            2,
        );
        assert_eq!(m.n_support(1e-6), 2);
        let c = m.compact(1e-6);
        assert_eq!(c.len(), 2);
        assert_eq!(c.alpha, vec![0.5, -0.3]);
        assert_eq!(c.x(), &[0.0, 0.0, 2.0, 2.0]);
    }

    #[test]
    fn compact_preserves_predictions() {
        let m = KernelModel::new(
            Kernel::rbf(1.0),
            vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0],
            vec![0.5, 0.0, -0.3],
            2,
        );
        let mut ds = Dataset::with_dim(2);
        ds.push(&[0.5, 0.5], 1.0);
        ds.push(&[-1.0, 2.0], -1.0);
        let mut be = NativeBackend::new();
        let s1 = m.scores(&mut be, &ds).unwrap();
        let s2 = m.compact(1e-6).scores(&mut be, &ds).unwrap();
        for (a, b) in s1.iter().zip(&s2) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn scores_dimension_check() {
        let m = toy_model();
        let ds = Dataset::with_dim(5);
        let mut be = NativeBackend::new();
        assert!(m.scores(&mut be, &ds).is_err());
    }

    /// Three one-point expansions at distinct centers: argmax picks the
    /// nearest center under the RBF kernel.
    fn toy_multiclass() -> MulticlassModel {
        let centers = [[0.0f32, 0.0], [3.0, 0.0], [0.0, 3.0]];
        let models = centers
            .iter()
            .map(|c| KernelModel::new(Kernel::rbf(1.0), c.to_vec(), vec![1.0], 2))
            .collect();
        MulticlassModel::new(models)
    }

    #[test]
    fn multiclass_argmax_picks_nearest_center() {
        let m = toy_multiclass();
        assert_eq!(m.n_classes(), 3);
        assert_eq!(m.dim(), 2);
        let mut ds = MultiDataset::with_dims(2, 3);
        ds.push(&[0.2, -0.1], 0);
        ds.push(&[2.8, 0.3], 1);
        ds.push(&[-0.2, 3.1], 2);
        let mut be = NativeBackend::new();
        let pred = m.predict(&mut be, &ds).unwrap();
        assert_eq!(pred, vec![0, 1, 2]);
        assert_eq!(m.error(&mut be, &ds).unwrap(), 0.0);
        // Scores matrix is [n, K] row-major with the winning class max.
        let scores = m.scores(&mut be, &ds).unwrap();
        assert_eq!(scores.len(), 9);
        assert!(scores[0] > scores[1] && scores[0] > scores[2]);
    }

    #[test]
    fn multiclass_error_counts_mislabels() {
        let m = toy_multiclass();
        let mut ds = MultiDataset::with_dims(2, 3);
        ds.push(&[0.0, 0.0], 1); // wrong on purpose
        ds.push(&[3.0, 0.0], 1);
        let mut be = NativeBackend::new();
        assert!((m.error(&mut be, &ds).unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn multiclass_save_load_roundtrip() {
        // Distinct rows per head -> the legacy fallback container.
        let m = toy_multiclass();
        assert!(!m.is_shared());
        let mut buf = Vec::new();
        m.save(&mut buf).unwrap();
        assert_eq!(&buf[..8], b"DSEKLmc1");
        let m2 = MulticlassModel::load(buf.as_slice()).unwrap();
        assert_eq!(m2.n_classes(), 3);
        for (a, b) in m.models.iter().zip(&m2.models) {
            assert_eq!(a.kernel, b.kernel);
            assert_eq!(a.x(), b.x());
            assert_eq!(a.alpha, b.alpha);
        }
        // Garbage and truncation are rejected.
        assert!(MulticlassModel::load(&b"DSEKLv1\0junk"[..]).is_err());
        buf.truncate(buf.len() - 2);
        assert!(MulticlassModel::load(buf.as_slice()).is_err());
    }

    #[test]
    fn multiclass_dimension_check() {
        let m = toy_multiclass();
        let ds = MultiDataset::with_dims(5, 3);
        let mut be = NativeBackend::new();
        assert!(m.scores(&mut be, &ds).is_err());
    }

    /// A shared-storage model over random rows: K heads, one row block.
    fn shared_multiclass(k: usize, n: usize, d: usize, seed: u64) -> MulticlassModel {
        use crate::rng::{Pcg64, Rng};
        let mut rng = Pcg64::seed_from(seed);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let coef: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        MulticlassModel::from_shared(Kernel::rbf(0.4), ExpansionStore::new(rows, d), coef)
    }

    #[test]
    fn shared_heads_reference_one_row_block() {
        let m = shared_multiclass(4, 20, 3, 11);
        assert!(m.is_shared());
        let first = m.models[0].store();
        for head in &m.models {
            assert!(head.store().shares_rows_with(first));
        }
        // Cloning the model clones Arcs, not rows.
        let c = m.clone();
        assert!(c.models[0].store().shares_rows_with(first));
        // new() deduplicates equal-but-separate row blocks too.
        let rebuilt = MulticlassModel::new(
            (0..3)
                .map(|h| {
                    KernelModel::new(
                        Kernel::rbf(1.0),
                        vec![0.0, 1.0, 2.0, 3.0],
                        vec![h as f32, -1.0],
                        2,
                    )
                })
                .collect(),
        );
        assert!(rebuilt.is_shared());
        assert!(rebuilt.models[0]
            .store()
            .shares_rows_with(rebuilt.models[2].store()));
    }

    #[test]
    fn v2_save_load_roundtrip_shared() {
        let m = shared_multiclass(5, 17, 4, 12);
        let mut buf = Vec::new();
        m.save(&mut buf).unwrap();
        assert_eq!(&buf[..8], b"DSEKLv2\0");
        let m2 = MulticlassModel::load(buf.as_slice()).unwrap();
        assert_eq!(m2.n_classes(), 5);
        assert!(m2.is_shared(), "v2 load must reconstruct shared storage");
        assert_eq!(m2.models[0].x(), m.models[0].x());
        for (a, b) in m.models.iter().zip(&m2.models) {
            assert_eq!(a.kernel, b.kernel);
            assert_eq!(a.alpha, b.alpha);
        }
    }

    #[test]
    fn legacy_mc1_container_still_loads() {
        // Craft a legacy file (rows duplicated per head) and check it
        // loads AND comes back deduplicated into one shared store.
        let m = shared_multiclass(3, 9, 2, 13);
        let mut legacy = Vec::new();
        m.save_legacy(&mut legacy).unwrap();
        assert_eq!(&legacy[..8], b"DSEKLmc1");
        let m2 = MulticlassModel::load(legacy.as_slice()).unwrap();
        assert_eq!(m2.n_classes(), 3);
        assert!(m2.is_shared(), "legacy load should dedup identical rows");
        for (a, b) in m.models.iter().zip(&m2.models) {
            assert_eq!(a.alpha, b.alpha);
            assert_eq!(a.x(), b.x());
        }
    }

    #[test]
    fn v2_rejects_truncation_and_corrupt_headers() {
        let m = shared_multiclass(3, 8, 2, 14);
        let mut buf = Vec::new();
        m.save(&mut buf).unwrap();
        // Truncation anywhere — inside header, coefs, rows — errors.
        for cut in [4, 12, 30, buf.len() - 5, buf.len() - 1] {
            assert!(
                MulticlassModel::load(&buf[..cut]).is_err(),
                "truncation at {cut} accepted"
            );
        }
        // Corrupt class count (0 heads).
        let mut bad = buf.clone();
        bad[24..32].fill(0);
        assert!(MulticlassModel::load(bad.as_slice()).is_err());
        // Corrupt kernel kind.
        let mut bad = buf.clone();
        bad[8..12].copy_from_slice(&99u32.to_le_bytes());
        assert!(MulticlassModel::load(bad.as_slice()).is_err());
        // Implausible dimensions (d = 0).
        let mut bad = buf.clone();
        bad[40..48].fill(0);
        assert!(MulticlassModel::load(bad.as_slice()).is_err());
        // Coefficient matrix k*n overflowing the sanity cap while k and
        // n*d each look plausible must error, not attempt to allocate.
        let mut bad = buf;
        bad[24..32].copy_from_slice(&4096u64.to_le_bytes()); // k
        bad[32..40].copy_from_slice(&(1u64 << 23).to_le_bytes()); // n
        bad[40..48].copy_from_slice(&1u64.to_le_bytes()); // d
        assert!(MulticlassModel::load(bad.as_slice()).is_err());
    }

    #[test]
    fn v2_file_is_k_times_smaller_than_legacy() {
        // covtype-like shape: K = 7 heads over one expansion block.
        let m = shared_multiclass(7, 200, 10, 15);
        let mut v2 = Vec::new();
        m.save(&mut v2).unwrap();
        let mut legacy = Vec::new();
        m.save_legacy(&mut legacy).unwrap();
        let ratio = legacy.len() as f64 / v2.len() as f64;
        assert!(
            ratio > 5.0,
            "expected ~7x shrink for K=7, got {ratio:.2} ({} vs {} bytes)",
            legacy.len(),
            v2.len()
        );
    }

    #[test]
    fn fused_scores_match_per_head_predict() {
        let m = shared_multiclass(4, 30, 3, 16);
        let mut rng = crate::rng::Pcg64::seed_from(17);
        let mut ds = MultiDataset::with_dims(3, 4);
        for i in 0..25 {
            use crate::rng::Rng;
            let row = [
                rng.normal() as f32,
                rng.normal() as f32,
                rng.normal() as f32,
            ];
            ds.push(&row, (i % 4) as u32);
        }
        let mut be = NativeBackend::new();
        let fused = m.scores(&mut be, &ds).unwrap();
        // Reference: one backend.predict per head, interleaved.
        let k = m.n_classes();
        let mut looped = vec![0.0f32; ds.len() * k];
        let mut f = Vec::new();
        for (c, head) in m.models.iter().enumerate() {
            be.predict(
                head.kernel,
                Rows::dense(&ds.x, ds.len(), ds.d),
                Rows::dense(head.x(), head.len(), head.d()),
                &head.alpha,
                &mut f,
            )
            .unwrap();
            for (i, &v) in f.iter().enumerate() {
                looped[i * k + c] = v;
            }
        }
        assert_eq!(fused, looped, "fused predict diverged from looped");
    }
}
