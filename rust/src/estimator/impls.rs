//! [`Estimator`] implementations for every solver: each is a thin shim
//! from the unified [`TrainSet`] onto the solver's existing
//! `train_rows`-style loop, so `fit` is bitwise-equal to the legacy
//! entry point it wraps (`rust/tests/estimator_parity.rs`). Layouts a
//! solver cannot train on are rejected with a structured error; the
//! [`crate::estimator::Fit`] builder routes around those by
//! construction.

use super::{Estimator, FitBackend, Fitted, Predictor, TrainData, TrainSet};
use crate::coordinator::ParallelDsekl;
use crate::data::Rows;
use crate::rng::{Pcg64, Rng};
use crate::solver::batch::BatchSvm;
use crate::solver::dsekl::DseklSolver;
use crate::solver::empfix::EmpFixSolver;
use crate::solver::online::OnlineSolver;
use crate::solver::ovr::OvrSolver;
use crate::solver::rks::RksSolver;
use crate::model::HybridModel;
use crate::solver::TrainStats;
use crate::stream::StreamSolver;
use crate::{Error, Result};

/// Structured rejection for a layout the estimator cannot train on.
fn unsupported(est: &dyn Estimator, data: &TrainData<'_>, expected: &str) -> Error {
    Error::invalid(format!(
        "the {} solver trains on {expected} data, got a {} {} set",
        est.name(),
        data.layout(),
        if data.is_multiclass() {
            "multiclass"
        } else {
            "binary"
        },
    ))
}

/// Binary rows + labels, or the structured rejection.
fn binary<'a>(est: &dyn Estimator, data: &TrainData<'a>) -> Result<(Rows<'a>, &'a [f32])> {
    data.binary_rows()
        .ok_or_else(|| unsupported(est, data, "binary (dense or CSR)"))
}

/// Reject an attached validation set for solvers without val tracking.
fn reject_val(est: &dyn Estimator, data: &TrainSet<'_>) -> Result<()> {
    match data.val() {
        None => Ok(()),
        Some(_) => Err(Error::invalid(format!(
            "the {} solver does not track validation error; drop the \
             validation attachment",
            est.name(),
        ))),
    }
}

/// Aggregate per-head stats into the [`Fitted`] summary: iterations and
/// wall-clock are shared across heads (max), gradient samples add up,
/// and the run converged only if every head froze. Per-head traces stay
/// in `Fitted::per_class`.
fn merge_stats(per_class: &[TrainStats]) -> TrainStats {
    let mut out = TrainStats::new();
    for s in per_class {
        out.iterations = out.iterations.max(s.iterations);
        out.points_processed += s.points_processed;
        out.elapsed_s = out.elapsed_s.max(s.elapsed_s);
    }
    out.converged = !per_class.is_empty() && per_class.iter().all(|s| s.converged);
    out
}

impl Estimator for DseklSolver {
    fn name(&self) -> &'static str {
        "dsekl"
    }

    fn fit(
        &self,
        backend: &mut FitBackend,
        data: TrainSet<'_>,
        rng: &mut Pcg64,
    ) -> Result<Fitted> {
        let (x, y) = binary(self, data.data())?;
        let val = match data.val() {
            None => None,
            Some(v) => Some(binary(self, v)?),
        };
        let r = self.train_rows(backend.leader()?, x, y, val, rng)?;
        Ok(Fitted::new(Predictor::Kernel(r.model), r.stats))
    }
}

impl Estimator for OvrSolver {
    fn name(&self) -> &'static str {
        "ovr"
    }

    fn fit(
        &self,
        backend: &mut FitBackend,
        data: TrainSet<'_>,
        rng: &mut Pcg64,
    ) -> Result<Fitted> {
        let (x, y, k) = data
            .data()
            .multi_rows()
            .ok_or_else(|| unsupported(self, data.data(), "multiclass (dense or CSR)"))?;
        reject_val(self, &data)?;
        let r = self.train_rows(backend.leader()?, x, y, k, rng)?;
        let mut fitted = Fitted::new(Predictor::Multiclass(r.model), merge_stats(&r.per_class));
        fitted.per_class = Some(r.per_class);
        Ok(fitted)
    }
}

impl Estimator for BatchSvm {
    fn name(&self) -> &'static str {
        "batch"
    }

    fn fit(
        &self,
        backend: &mut FitBackend,
        data: TrainSet<'_>,
        _rng: &mut Pcg64,
    ) -> Result<Fitted> {
        let ds = match data.data() {
            TrainData::Dense(r) => r.get(),
            other => return Err(unsupported(self, other, "dense binary")),
        };
        reject_val(self, &data)?;
        // lint:allow(deprecated) reason="sanctioned estimator shim; estimator_parity.rs pins fit() bitwise-equal to this legacy entry"
        let r = self.train(backend.leader()?, ds)?;
        Ok(Fitted::new(Predictor::Kernel(r.model), r.stats))
    }
}

impl Estimator for EmpFixSolver {
    fn name(&self) -> &'static str {
        "empfix"
    }

    fn fit(
        &self,
        backend: &mut FitBackend,
        data: TrainSet<'_>,
        rng: &mut Pcg64,
    ) -> Result<Fitted> {
        let ds = match data.data() {
            TrainData::Dense(r) => r.get(),
            other => return Err(unsupported(self, other, "dense binary")),
        };
        reject_val(self, &data)?;
        // lint:allow(deprecated) reason="sanctioned estimator shim; estimator_parity.rs pins fit() bitwise-equal to this legacy entry"
        let r = self.train(backend.leader()?, ds, rng)?;
        Ok(Fitted::new(Predictor::Kernel(r.model), r.stats))
    }
}

impl Estimator for RksSolver {
    fn name(&self) -> &'static str {
        "rks"
    }

    fn fit(
        &self,
        backend: &mut FitBackend,
        data: TrainSet<'_>,
        rng: &mut Pcg64,
    ) -> Result<Fitted> {
        let ds = match data.data() {
            TrainData::Dense(r) => r.get(),
            other => return Err(unsupported(self, other, "dense binary")),
        };
        reject_val(self, &data)?;
        // lint:allow(deprecated) reason="sanctioned estimator shim; estimator_parity.rs pins fit() bitwise-equal to this legacy entry"
        let r = self.train(backend.leader()?, ds, rng)?;
        Ok(Fitted::new(Predictor::Rks(r.model), r.stats))
    }
}

impl Estimator for OnlineSolver {
    fn name(&self) -> &'static str {
        "online"
    }

    fn fit(
        &self,
        backend: &mut FitBackend,
        data: TrainSet<'_>,
        rng: &mut Pcg64,
    ) -> Result<Fitted> {
        let (x, y) = binary(self, data.data())?;
        reject_val(self, &data)?;
        let r = self.train_rows(backend.leader()?, x, y, rng)?;
        Ok(Fitted::new(Predictor::Kernel(r.model), r.stats))
    }
}

impl Estimator for StreamSolver {
    fn name(&self) -> &'static str {
        "stream"
    }

    /// Prequential pass over the rows in storage order: validation is
    /// rejected (the trace *is* held-out error — every item is scored
    /// before it trains). With a tail the fit freezes as a
    /// [`Predictor::Hybrid`]; budget-only runs freeze the head alone.
    fn fit(
        &self,
        backend: &mut FitBackend,
        data: TrainSet<'_>,
        rng: &mut Pcg64,
    ) -> Result<Fitted> {
        let (x, y) = binary(self, data.data())?;
        reject_val(self, &data)?;
        let r = self.train_rows(backend.leader()?, x, y, rng)?;
        let predictor = match r.tail {
            Some(rks) => Predictor::Hybrid(HybridModel::new(r.head, rks)?),
            None => Predictor::Kernel(r.head),
        };
        Ok(Fitted::new(predictor, r.stats))
    }
}

impl Estimator for ParallelDsekl {
    fn name(&self) -> &'static str {
        "parallel"
    }

    /// All four layouts route to the matching coordinator loop. The
    /// coordinator reseeds internally, so the seed is drawn from `rng`
    /// (one `next_u64`): equal rng states still mean identical runs.
    /// Validation stays what the coordinator supports — a **dense** set
    /// of the matching label family (snapshots predict dense validation
    /// points through the possibly-CSR shared store).
    fn fit(
        &self,
        backend: &mut FitBackend,
        data: TrainSet<'_>,
        rng: &mut Pcg64,
    ) -> Result<Fitted> {
        let seed = rng.next_u64();
        let spec = backend.spec().clone();
        let (predictor, stats, telemetry) = if data.is_multiclass() {
            let val = match data.val() {
                None => None,
                Some(TrainData::Multi(v)) => Some(v.get()),
                Some(other) => {
                    return Err(Error::invalid(format!(
                        "the parallel coordinator tracks multiclass validation \
                         on dense sets only, got a {} {} validation set",
                        other.layout(),
                        if other.is_multiclass() {
                            "multiclass"
                        } else {
                            "binary"
                        },
                    )))
                }
            };
            let res = match data.data() {
                // lint:allow(deprecated) reason="sanctioned estimator shim; estimator_parity.rs pins fit() bitwise-equal to this legacy entry"
                TrainData::Multi(r) => self.train_multi(&spec, &r.arc(), val, seed)?,
                // lint:allow(deprecated) reason="sanctioned estimator shim; estimator_parity.rs pins fit() bitwise-equal to this legacy entry"
                TrainData::SparseMulti(r) => self.train_multi_sparse(&spec, &r.arc(), val, seed)?,
                _ => return Err(Error::invalid("is_multiclass left a binary layout in play")),
            };
            (Predictor::Multiclass(res.model), res.stats, res.telemetry)
        } else {
            let val = match data.val() {
                None => None,
                Some(TrainData::Dense(v)) => Some(v.get()),
                Some(other) => {
                    return Err(Error::invalid(format!(
                        "the parallel coordinator tracks validation on dense \
                         binary sets only, got a {} {} validation set",
                        other.layout(),
                        if other.is_multiclass() {
                            "multiclass"
                        } else {
                            "binary"
                        },
                    )))
                }
            };
            let res = match data.data() {
                // lint:allow(deprecated) reason="sanctioned estimator shim; estimator_parity.rs pins fit() bitwise-equal to this legacy entry"
                TrainData::Dense(r) => self.train(&spec, &r.arc(), val, seed)?,
                // lint:allow(deprecated) reason="sanctioned estimator shim; estimator_parity.rs pins fit() bitwise-equal to this legacy entry"
                TrainData::Sparse(r) => self.train_sparse(&spec, &r.arc(), val, seed)?,
                _ => return Err(Error::invalid("!is_multiclass left a multiclass layout in play")),
            };
            (Predictor::Kernel(res.model), res.stats, res.telemetry)
        };
        let mut fitted = Fitted::new(predictor, stats);
        fitted.telemetry = Some(telemetry);
        Ok(fitted)
    }
}
