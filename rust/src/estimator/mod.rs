//! One estimator API over every solver in the crate.
//!
//! Four PRs of growth left training spread over sixteen `train*` entry
//! points (dense/sparse × binary/multiclass × serial/parallel, times
//! six solvers). This module collapses that matrix behind three ideas:
//!
//! * [`TrainSet`] — one borrowed input over all four data layouts
//!   ([`Dataset`] / [`MultiDataset`] / [`SparseDataset`] /
//!   [`SparseMultiDataset`]), with an optional validation set of the
//!   same family riding along ([`TrainSet::with_val`]).
//! * [`Estimator`] — `fit(backend, data, rng) -> Fitted`, implemented
//!   by every solver (serial DSEKL, the one-vs-rest driver, the
//!   parallel coordinator, the batch/Emp_Fix/RKS baselines and the
//!   streaming solver). A [`Fitted`] carries a unified [`Predictor`]
//!   plus the shared [`TrainStats`] (and, where the solver produces
//!   them, per-class stats and coordinator telemetry).
//! * [`Fit`] — a builder front door
//!   (`Fit::dsekl().gamma(0.5).loss(Loss::Logistic).parallel(4)`) that
//!   owns the serial-vs-parallel and dense-vs-sparse routing **once**;
//!   the CLI, the hyper-parameter search and the experiment drivers all
//!   go through it.
//!
//! Every estimator is a thin shim over the solver's existing
//! `train_rows`-style loop, so `Estimator::fit` is **bitwise equal** to
//! the legacy entry point it wraps — coefficients, traces and iteration
//! counts — for every solver × layout (`rust/tests/estimator_parity.rs`).
//!
//! ```
//! use dsekl::data::synth;
//! use dsekl::estimator::{Fit, FitBackend, TrainSet};
//! use dsekl::rng::Pcg64;
//!
//! let mut rng = Pcg64::seed_from(7);
//! let ds = synth::xor(120, 0.2, &mut rng);
//! let (train, test) = ds.split(0.5, &mut rng);
//! let mut backend = FitBackend::native();
//! let fitted = Fit::dsekl()
//!     .gamma(1.0)
//!     .sizes(16, 16)
//!     .iters(200)
//!     .fit(&mut backend, TrainSet::from(&train), &mut rng)
//!     .expect("training");
//! let err = fitted
//!     .predictor
//!     .error(backend.leader().expect("backend"), &TrainSet::from(&test))
//!     .expect("predict");
//! assert!(err < 0.25);
//! ```

mod builder;
mod impls;

pub use builder::{AnyEstimator, Fit, FitBuilder, SolverKind};

use std::sync::Arc;

use crate::coordinator::ParallelTelemetry;
use crate::data::{Dataset, MultiDataset, Rows, SparseDataset, SparseMultiDataset};
use crate::model::{HybridModel, KernelModel, ModelFile, MulticlassModel, RksModel};
use crate::rng::Pcg64;
use crate::runtime::{Backend, BackendSpec};
use crate::solver::TrainStats;
use crate::{Error, Result};

/// A borrowed-or-shared reference: estimators that run on the calling
/// thread borrow the data, while the parallel coordinator needs an
/// `Arc` to share rows across workers. Callers that already hold an
/// `Arc` hand it in so the coordinator clones the pointer, not the
/// floats; plain borrows are cloned into a fresh `Arc` only if a
/// multi-threaded estimator actually runs.
#[derive(Debug)]
pub enum SharedRef<'a, T> {
    /// Plain borrow (serial estimators never copy it).
    Borrowed(&'a T),
    /// Borrow of an existing `Arc` (the coordinator clones the pointer).
    Shared(&'a Arc<T>),
}

// Manual impls: `#[derive(Clone, Copy)]` would bound `T: Clone`/`T:
// Copy`, but a reference is copyable regardless of `T`.
impl<T> Clone for SharedRef<'_, T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SharedRef<'_, T> {}

impl<'a, T> SharedRef<'a, T> {
    /// The underlying value.
    pub fn get(&self) -> &'a T {
        match *self {
            SharedRef::Borrowed(r) => r,
            SharedRef::Shared(a) => a.as_ref(),
        }
    }
}

impl<T: Clone> SharedRef<'_, T> {
    /// An owning `Arc`: pointer clone when one already exists, data
    /// clone otherwise (the price the legacy CLI paid on every parallel
    /// run; passing `&Arc<T>` into the [`TrainSet`] avoids it).
    pub fn arc(&self) -> Arc<T> {
        match *self {
            SharedRef::Borrowed(r) => Arc::new(r.clone()),
            SharedRef::Shared(a) => Arc::clone(a),
        }
    }
}

/// One of the four data layouts a [`TrainSet`] can carry.
#[derive(Debug, Clone, Copy)]
pub enum TrainData<'a> {
    /// Dense rows, ±1 labels.
    Dense(SharedRef<'a, Dataset>),
    /// CSR rows, ±1 labels.
    Sparse(SharedRef<'a, SparseDataset>),
    /// Dense rows, class ids `0..K`.
    Multi(SharedRef<'a, MultiDataset>),
    /// CSR rows, class ids `0..K`.
    SparseMulti(SharedRef<'a, SparseMultiDataset>),
}

impl<'a> TrainData<'a> {
    /// Number of examples.
    pub fn len(&self) -> usize {
        match self {
            TrainData::Dense(r) => r.get().len(),
            TrainData::Sparse(r) => r.get().len(),
            TrainData::Multi(r) => r.get().len(),
            TrainData::SparseMulti(r) => r.get().len(),
        }
    }

    /// True when there are no examples.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        match self {
            TrainData::Dense(r) => r.get().d,
            TrainData::Sparse(r) => r.get().d,
            TrainData::Multi(r) => r.get().d,
            TrainData::SparseMulti(r) => r.get().d,
        }
    }

    /// CSR layout?
    pub fn is_sparse(&self) -> bool {
        matches!(self, TrainData::Sparse(_) | TrainData::SparseMulti(_))
    }

    /// Class-id labels (vs ±1 binary labels)?
    pub fn is_multiclass(&self) -> bool {
        matches!(self, TrainData::Multi(_) | TrainData::SparseMulti(_))
    }

    /// Declared class count for the multiclass layouts.
    pub fn n_classes(&self) -> Option<usize> {
        match self {
            TrainData::Multi(r) => Some(r.get().n_classes),
            TrainData::SparseMulti(r) => Some(r.get().n_classes),
            _ => None,
        }
    }

    /// Fraction of zero entries (O(nnz) on CSR layouts).
    pub fn sparsity(&self) -> f64 {
        match self {
            TrainData::Dense(r) => r.get().sparsity(),
            TrainData::Sparse(r) => r.get().sparsity(),
            TrainData::Multi(r) => r.get().sparsity(),
            TrainData::SparseMulti(r) => r.get().sparsity(),
        }
    }

    /// Short layout tag for log lines.
    pub fn layout(&self) -> &'static str {
        if self.is_sparse() {
            "csr"
        } else {
            "dense"
        }
    }

    /// Feature rows + ±1 labels when this is a binary layout.
    pub(crate) fn binary_rows(&self) -> Option<(Rows<'a>, &'a [f32])> {
        match self {
            TrainData::Dense(r) => {
                let d = r.get();
                Some((d.rows(), d.y.as_slice()))
            }
            TrainData::Sparse(r) => {
                let d = r.get();
                Some((d.rows(), d.y.as_slice()))
            }
            _ => None,
        }
    }

    /// Feature rows + class ids + K when this is a multiclass layout.
    pub(crate) fn multi_rows(&self) -> Option<(Rows<'a>, &'a [u32], usize)> {
        match self {
            TrainData::Multi(r) => {
                let d = r.get();
                Some((d.rows(), d.y.as_slice(), d.n_classes))
            }
            TrainData::SparseMulti(r) => {
                let d = r.get();
                Some((d.rows(), d.y.as_slice(), d.n_classes))
            }
            _ => None,
        }
    }
}

/// Unified training input: one of the four data layouts, plus an
/// optional validation set of any compatible layout. Built from plain
/// references (`TrainSet::from(&ds)`) or from `&Arc<_>` when the caller
/// already shares the data (`TrainSet::from(&arc)` — the parallel
/// coordinator then clones the pointer instead of the rows).
#[derive(Debug, Clone, Copy)]
pub struct TrainSet<'a> {
    data: TrainData<'a>,
    val: Option<TrainData<'a>>,
}

macro_rules! train_set_from {
    ($ty:ty, $variant:ident) => {
        impl<'a> From<&'a $ty> for TrainSet<'a> {
            fn from(ds: &'a $ty) -> TrainSet<'a> {
                TrainSet {
                    data: TrainData::$variant(SharedRef::Borrowed(ds)),
                    val: None,
                }
            }
        }
        impl<'a> From<&'a Arc<$ty>> for TrainSet<'a> {
            fn from(ds: &'a Arc<$ty>) -> TrainSet<'a> {
                TrainSet {
                    data: TrainData::$variant(SharedRef::Shared(ds)),
                    val: None,
                }
            }
        }
    };
}

train_set_from!(Dataset, Dense);
train_set_from!(SparseDataset, Sparse);
train_set_from!(MultiDataset, Multi);
train_set_from!(SparseMultiDataset, SparseMulti);

impl<'a> TrainSet<'a> {
    /// Attach a validation set (solvers that track validation record
    /// its error in the trace; solvers that cannot reject it).
    pub fn with_val(mut self, val: impl Into<TrainSet<'a>>) -> TrainSet<'a> {
        self.val = Some(val.into().data);
        self
    }

    /// The training data.
    pub fn data(&self) -> &TrainData<'a> {
        &self.data
    }

    /// The attached validation data, if any.
    pub fn val(&self) -> Option<&TrainData<'a>> {
        self.val.as_ref()
    }

    /// Number of training examples.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when there are no training examples.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        self.data.dim()
    }

    /// CSR layout?
    pub fn is_sparse(&self) -> bool {
        self.data.is_sparse()
    }

    /// Class-id labels?
    pub fn is_multiclass(&self) -> bool {
        self.data.is_multiclass()
    }

    /// Declared class count for the multiclass layouts.
    pub fn n_classes(&self) -> Option<usize> {
        self.data.n_classes()
    }

    /// Short layout tag for log lines.
    pub fn layout(&self) -> &'static str {
        self.data.layout()
    }
}

/// The compute substrate of a fit: the [`BackendSpec`] (multi-threaded
/// estimators instantiate one backend per worker from it) plus a
/// lazily created leader backend for the calling thread — what the
/// serial solvers step on, and what prediction helpers reuse after the
/// fit. PJRT compilation caches live per instance, so keeping one
/// `FitBackend` across fit + evaluate avoids recompiling artifacts.
pub struct FitBackend {
    spec: BackendSpec,
    leader: Option<Box<dyn Backend>>,
}

impl std::fmt::Debug for FitBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FitBackend")
            .field("spec", &self.spec)
            .field("leader", &self.leader.as_ref().map(|b| b.name()))
            .finish()
    }
}

impl FitBackend {
    /// Backend from a spec; nothing is instantiated until first use.
    pub fn new(spec: BackendSpec) -> FitBackend {
        FitBackend { spec, leader: None }
    }

    /// The always-available pure-rust backend.
    pub fn native() -> FitBackend {
        FitBackend::new(BackendSpec::Native)
    }

    /// The spec (what the coordinator hands each worker thread).
    pub fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    /// The calling thread's backend instance, created on first use.
    pub fn leader(&mut self) -> Result<&mut dyn Backend> {
        if self.leader.is_none() {
            self.leader = Some(self.spec.instantiate()?);
        }
        match self.leader.as_mut() {
            Some(b) => Ok(b.as_mut()),
            None => Err(Error::invalid("backend failed to instantiate")),
        }
    }
}

/// What a fit produces: a [`Predictor`] plus the crate-wide
/// [`TrainStats`], with solver-specific extras where they exist.
#[derive(Debug)]
pub struct Fitted {
    /// The trained model, unified over the three model families.
    pub predictor: Predictor,
    /// Aggregate statistics (for multi-head runs: iterations/elapsed
    /// are the maximum over heads, points the sum, converged the
    /// conjunction; the per-head traces live in `per_class`).
    pub stats: TrainStats,
    /// Per-class statistics for one-vs-rest runs (index == class id).
    pub per_class: Option<Vec<TrainStats>>,
    /// Coordinator telemetry when the parallel solver ran.
    pub telemetry: Option<ParallelTelemetry>,
}

impl Fitted {
    pub(crate) fn new(predictor: Predictor, stats: TrainStats) -> Fitted {
        Fitted {
            predictor,
            stats,
            per_class: None,
            telemetry: None,
        }
    }
}

/// Unified trained-model handle: a single-head kernel expansion, a
/// K-head argmax model, primal RKS weights, or the streaming hybrid.
#[derive(Debug, Clone)]
pub enum Predictor {
    /// Binary kernel expansion ([`KernelModel`]).
    Kernel(KernelModel),
    /// K one-vs-rest heads over one shared expansion store.
    Multiclass(MulticlassModel),
    /// Random-kitchen-sinks primal weights.
    Rks(RksModel),
    /// Streaming hybrid: budgeted head + RKS tail ([`HybridModel`]).
    Hybrid(HybridModel),
}

impl Predictor {
    /// Misclassification rate on `data` (its validation attachment, if
    /// any, is ignored). Binary predictors take the binary layouts,
    /// the multiclass predictor the multiclass ones; RKS models are
    /// dense-only.
    pub fn error(&self, backend: &mut dyn Backend, data: &TrainSet<'_>) -> Result<f64> {
        match (self, data.data()) {
            (Predictor::Kernel(m), TrainData::Dense(r)) => m.error(backend, r.get()),
            (Predictor::Kernel(m), TrainData::Sparse(r)) => m.error_sparse(backend, r.get()),
            (Predictor::Multiclass(m), TrainData::Multi(r)) => m.error(backend, r.get()),
            (Predictor::Multiclass(m), TrainData::SparseMulti(r)) => {
                m.error_sparse(backend, r.get())
            }
            (Predictor::Rks(m), TrainData::Dense(r)) => m.error(backend, r.get()),
            (Predictor::Hybrid(m), TrainData::Dense(r)) => m.error(backend, r.get()),
            (Predictor::Hybrid(m), TrainData::Sparse(r)) => m.error_sparse(backend, r.get()),
            (p, d) => Err(Error::invalid(format!(
                "predictor/data mismatch: a {} predictor cannot score a {} {} set",
                p.family(),
                d.layout(),
                if d.is_multiclass() {
                    "multiclass"
                } else {
                    "binary"
                },
            ))),
        }
    }

    /// Family tag for error messages and log lines.
    pub fn family(&self) -> &'static str {
        match self {
            Predictor::Kernel(_) => "kernel",
            Predictor::Multiclass(_) => "multiclass",
            Predictor::Rks(_) => "rks",
            Predictor::Hybrid(_) => "hybrid",
        }
    }

    /// Number of classes scored (2 for the binary families).
    pub fn n_classes(&self) -> usize {
        match self {
            Predictor::Multiclass(m) => m.n_classes(),
            _ => 2,
        }
    }

    /// The kernel model, when single-head.
    pub fn as_kernel(&self) -> Option<&KernelModel> {
        match self {
            Predictor::Kernel(m) => Some(m),
            _ => None,
        }
    }

    /// The K-head model, when multiclass.
    pub fn as_multiclass(&self) -> Option<&MulticlassModel> {
        match self {
            Predictor::Multiclass(m) => Some(m),
            _ => None,
        }
    }

    /// The RKS model, when primal.
    pub fn as_rks(&self) -> Option<&RksModel> {
        match self {
            Predictor::Rks(m) => Some(m),
            _ => None,
        }
    }

    /// The hybrid model, when streaming head + tail.
    pub fn as_hybrid(&self) -> Option<&HybridModel> {
        match self {
            Predictor::Hybrid(m) => Some(m),
            _ => None,
        }
    }

    /// Feature dimensionality the predictor scores.
    pub fn dim(&self) -> usize {
        match self {
            Predictor::Kernel(m) => m.d(),
            Predictor::Multiclass(m) => m.dim(),
            Predictor::Rks(m) => m.d,
            Predictor::Hybrid(m) => m.dim(),
        }
    }

    /// Size of the representation: expansion points for the kernel
    /// families, random features for RKS, head expansion points plus
    /// tail features for the hybrid.
    pub fn n_expansion(&self) -> usize {
        match self {
            Predictor::Kernel(m) => m.len(),
            Predictor::Multiclass(m) => m.models.first().map_or(0, KernelModel::len),
            Predictor::Rks(m) => m.r,
            Predictor::Hybrid(m) => m.head.len() + m.rks.r,
        }
    }

    /// Decision scores for arbitrary [`Rows`], row-major `[n, k]` with
    /// the head count `k` returned alongside (1 for the binary
    /// families, K for multiclass — where all heads score in one fused
    /// [`Backend::predict_multi`] pass). This is the serve layer's one
    /// scoring entry point.
    pub fn scores_rows(&self, backend: &mut dyn Backend, xt: Rows) -> Result<(Vec<f32>, usize)> {
        match self {
            Predictor::Kernel(m) => Ok((m.scores_rows(backend, xt)?, 1)),
            Predictor::Multiclass(m) => Ok((m.scores_rows(backend, xt)?, m.n_classes())),
            Predictor::Rks(m) => Ok((m.scores_rows(backend, xt)?, 1)),
            Predictor::Hybrid(m) => Ok((m.scores_rows(backend, xt)?, 1)),
        }
    }

    /// Persist to the self-describing binary formats: DSEKLv1/v2/v3 by
    /// head count and store layout for the kernel families, DSEKLrk1
    /// for RKS primal weights, DSEKLhy1 for the streaming hybrid.
    pub fn save_file<P: AsRef<std::path::Path>>(&self, path: P) -> Result<()> {
        match self {
            Predictor::Kernel(m) => m.save_file(path),
            Predictor::Multiclass(m) => m.save_file(path),
            Predictor::Rks(m) => m.save_file(path),
            Predictor::Hybrid(m) => m.save_file(path),
        }
    }

    /// Load any saved model: sniffs the 8-byte magic and dispatches
    /// v1/v2/mc1/v3/rk1/hy1 to the right family, so callers never pass
    /// family flags. Wrong-family confusion is impossible here by
    /// construction; corrupt or unknown files error through the model
    /// layer's one precise error site ([`crate::model::load_model`]).
    pub fn load<R: std::io::Read>(r: R) -> Result<Predictor> {
        Ok(match crate::model::load_model(r)? {
            ModelFile::Kernel(m) => Predictor::Kernel(m),
            ModelFile::Multiclass(m) => Predictor::Multiclass(m),
            ModelFile::Rks(m) => Predictor::Rks(m),
            ModelFile::Hybrid(m) => Predictor::Hybrid(m),
        })
    }

    /// [`Predictor::load`] from a file path, with the path prefixed to
    /// any open/parse error.
    pub fn load_file<P: AsRef<std::path::Path>>(path: P) -> Result<Predictor> {
        let path = path.as_ref();
        let with_path = |msg: &str| format!("model file '{}': {msg}", path.display());
        let f = std::fs::File::open(path)
            .map_err(|e| Error::invalid(format!("cannot open model file '{}': {e}", path.display())))?;
        Self::load(f).map_err(|e| match e {
            Error::Parse(msg) => Error::Parse(with_path(&msg)),
            Error::Io(io) => Error::Parse(with_path(&format!("truncated or unreadable: {io}"))),
            other => other,
        })
    }
}

/// One trainable algorithm behind one verb. Implementations reject
/// data layouts they cannot train on with a structured error instead
/// of a compile-time split — the [`Fit`] builder routes around that by
/// construction.
pub trait Estimator {
    /// Solver name for log lines and error messages.
    fn name(&self) -> &'static str;

    /// Train on `data` and return the fitted model + statistics.
    ///
    /// `rng` drives all solver randomness; estimators that internally
    /// reseed (the parallel coordinator) draw their seed from it, so
    /// two fits from equal rng states are identical. Serial estimators
    /// consume the stream exactly like the legacy entry point they
    /// wrap (pinned bitwise in `rust/tests/estimator_parity.rs`).
    fn fit(
        &self,
        backend: &mut FitBackend,
        data: TrainSet<'_>,
        rng: &mut Pcg64,
    ) -> Result<Fitted>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn train_set_layout_probes() {
        let mut rng = Pcg64::seed_from(1);
        let dense = synth::xor(20, 0.2, &mut rng);
        let multi = synth::multi_blobs(24, 3, 2, 0.3, &mut rng);
        let sparse = synth::sparse_binary(30, 16, 0.2, &mut rng);

        let t = TrainSet::from(&dense);
        assert_eq!(t.len(), 20);
        assert_eq!(t.dim(), 2);
        assert!(!t.is_sparse() && !t.is_multiclass());
        assert_eq!(t.layout(), "dense");
        assert_eq!(t.n_classes(), None);

        let t = TrainSet::from(&multi);
        assert!(t.is_multiclass());
        assert_eq!(t.n_classes(), Some(3));

        let t = TrainSet::from(&sparse);
        assert!(t.is_sparse());
        assert_eq!(t.layout(), "csr");
    }

    #[test]
    fn shared_ref_arc_reuses_pointer() {
        let mut rng = Pcg64::seed_from(2);
        let arc = Arc::new(synth::xor(10, 0.2, &mut rng));
        let set = TrainSet::from(&arc);
        match set.data() {
            TrainData::Dense(r) => assert!(Arc::ptr_eq(&r.arc(), &arc)),
            _ => panic!("wrong layout"),
        }
    }

    #[test]
    fn with_val_attaches() {
        let mut rng = Pcg64::seed_from(3);
        let train = synth::xor(10, 0.2, &mut rng);
        let val = synth::xor(6, 0.2, &mut rng);
        let set = TrainSet::from(&train).with_val(&val);
        assert_eq!(set.val().map(|v| v.len()), Some(6));
    }

    #[test]
    fn predictor_mismatch_is_structured() {
        let mut rng = Pcg64::seed_from(4);
        let multi = synth::multi_blobs(12, 3, 2, 0.3, &mut rng);
        let m = KernelModel::new(crate::kernel::Kernel::rbf(1.0), vec![0.0, 0.0], vec![0.0], 2);
        let mut be = FitBackend::native();
        let err = Predictor::Kernel(m)
            .error(be.leader().unwrap(), &TrainSet::from(&multi))
            .unwrap_err();
        assert!(err.to_string().contains("predictor/data mismatch"));
    }
}
