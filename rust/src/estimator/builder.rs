//! The [`Fit`] builder: one front door over every solver, owning the
//! serial-vs-parallel and dense-vs-sparse routing (and the structured
//! errors for unsupported combinations) in exactly one place.

use super::{Estimator, FitBackend, Fitted, TrainSet};
use crate::coordinator::{CoordTransport, ParallelDsekl, ParallelOpts};
use crate::kernel::Kernel;
use crate::loss::Loss;
use crate::rng::Pcg64;
use crate::solver::batch::{BatchOpts, BatchSvm};
use crate::solver::dsekl::{DseklOpts, DseklSolver};
use crate::solver::empfix::{EmpFixOpts, EmpFixSolver};
use crate::solver::online::{OnlineOpts, OnlineSolver};
use crate::solver::ovr::{OvrOpts, OvrSolver};
use crate::solver::rks::{RksOpts, RksSolver};
use crate::solver::LrSchedule;
use crate::stream::{StreamOpts, StreamSolver};
use crate::{Error, Result};

/// The solver families a [`FitBuilder`] can route to. `Parallel` is the
/// DSEKL family on the shared-memory coordinator — the same thing as
/// `Dsekl` plus [`FitBuilder::parallel`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverKind {
    /// Serial doubly stochastic EKM (Algorithm 1); routes to the
    /// one-vs-rest driver on multiclass data.
    Dsekl,
    /// The shared-memory coordinator (Algorithm 2), any layout.
    Parallel,
    /// Full-batch kernel SVM baseline (dense binary only).
    Batch,
    /// Fixed-random-subset baseline (dense binary only).
    EmpFix,
    /// Random kitchen sinks baseline (dense binary only).
    Rks,
    /// Streaming DSEKL with a budgeted reservoir (binary, dense or CSR).
    Online,
    /// Drift-aware prequential streaming: budgeted head with magnitude
    /// eviction plus an optional RKS tail (binary, dense or CSR).
    Stream,
}

impl SolverKind {
    /// Every kind, in CLI-listing order.
    pub const ALL: [SolverKind; 7] = [
        SolverKind::Dsekl,
        SolverKind::Parallel,
        SolverKind::Batch,
        SolverKind::EmpFix,
        SolverKind::Rks,
        SolverKind::Online,
        SolverKind::Stream,
    ];

    /// Parse a CLI-style solver name. This is the **one** place the
    /// unknown-solver error is constructed, so every train path (binary
    /// or multiclass, dense or sparse) reports it identically.
    pub fn parse(s: &str) -> Result<SolverKind> {
        match s {
            "dsekl" => Ok(SolverKind::Dsekl),
            "parallel" => Ok(SolverKind::Parallel),
            "batch" => Ok(SolverKind::Batch),
            "empfix" => Ok(SolverKind::EmpFix),
            "rks" => Ok(SolverKind::Rks),
            "online" => Ok(SolverKind::Online),
            "stream" => Ok(SolverKind::Stream),
            other => Err(Error::invalid(format!(
                "unknown solver '{other}' (expected dsekl|parallel|batch|empfix|rks|online|stream)"
            ))),
        }
    }

    /// The CLI-style name.
    pub fn name(&self) -> &'static str {
        match self {
            SolverKind::Dsekl => "dsekl",
            SolverKind::Parallel => "parallel",
            SolverKind::Batch => "batch",
            SolverKind::EmpFix => "empfix",
            SolverKind::Rks => "rks",
            SolverKind::Online => "online",
            SolverKind::Stream => "stream",
        }
    }
}

impl std::fmt::Display for SolverKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Entry points of the builder API: `Fit::dsekl()`, `Fit::batch()`, …
/// each returns a [`FitBuilder`] whose unset knobs fall through to the
/// solver's own `*Opts::default()` values.
pub struct Fit;

impl Fit {
    /// Doubly stochastic EKM learning (serial; chain
    /// [`FitBuilder::parallel`] for the coordinator; multiclass data
    /// routes to the one-vs-rest driver automatically).
    pub fn dsekl() -> FitBuilder {
        FitBuilder::new(SolverKind::Dsekl)
    }

    /// Full-batch kernel SVM baseline.
    pub fn batch() -> FitBuilder {
        FitBuilder::new(SolverKind::Batch)
    }

    /// Fixed-random-subset baseline.
    pub fn empfix() -> FitBuilder {
        FitBuilder::new(SolverKind::EmpFix)
    }

    /// Random kitchen sinks baseline.
    pub fn rks() -> FitBuilder {
        FitBuilder::new(SolverKind::Rks)
    }

    /// Streaming DSEKL over a budgeted reservoir.
    pub fn online() -> FitBuilder {
        FitBuilder::new(SolverKind::Online)
    }

    /// Drift-aware prequential streaming: budgeted head with magnitude
    /// eviction plus an optional RKS tail ([`crate::stream`]).
    pub fn stream() -> FitBuilder {
        FitBuilder::new(SolverKind::Stream)
    }

    /// Builder from a parsed [`SolverKind`] (the CLI path).
    pub fn solver(kind: SolverKind) -> FitBuilder {
        FitBuilder::new(kind)
    }
}

/// Configures one fit. Every knob is optional; unset knobs keep the
/// routed solver's `Default`. Knobs a solver does not use are ignored
/// (e.g. `budget` outside `online`), matching how the CLI has always
/// treated its flags.
#[derive(Debug, Clone)]
pub struct FitBuilder {
    kind: SolverKind,
    workers: Option<usize>,
    gamma: Option<f32>,
    lam: Option<f32>,
    eta0: Option<f32>,
    lr: Option<LrSchedule>,
    i_size: Option<usize>,
    j_size: Option<usize>,
    iters: Option<u64>,
    epochs: Option<u64>,
    tol: Option<f32>,
    eval_every: Option<u64>,
    kernel: Option<Kernel>,
    loss: Option<Loss>,
    round_batches: Option<usize>,
    shards: Option<usize>,
    transport: Option<CoordTransport>,
    subset: Option<usize>,
    features: Option<usize>,
    budget: Option<usize>,
    chunk: Option<usize>,
    evict_every: Option<u64>,
}

impl FitBuilder {
    fn new(kind: SolverKind) -> FitBuilder {
        FitBuilder {
            kind,
            workers: None,
            gamma: None,
            lam: None,
            eta0: None,
            lr: None,
            i_size: None,
            j_size: None,
            iters: None,
            epochs: None,
            tol: None,
            eval_every: None,
            kernel: None,
            loss: None,
            round_batches: None,
            shards: None,
            transport: None,
            subset: None,
            features: None,
            budget: None,
            chunk: None,
            evict_every: None,
        }
    }

    /// RBF width (ignored when [`FitBuilder::kernel`] overrides).
    pub fn gamma(mut self, gamma: f32) -> Self {
        self.gamma = Some(gamma);
        self
    }

    /// L2 regularisation strength.
    pub fn lam(mut self, lam: f32) -> Self {
        self.lam = Some(lam);
        self
    }

    /// Base step size, applied within each solver's own schedule
    /// family: `eta0/t` for the serial SGD solvers, `eta0/sqrt(t)` for
    /// the online solver, and the per-epoch base rate for the
    /// coordinator. [`FitBuilder::lr`] overrides the serial schedule
    /// entirely. The full-batch baseline keeps its own mean-normalised
    /// `InvSqrtT` default and only reads the explicit
    /// [`FitBuilder::lr`] schedule.
    pub fn eta0(mut self, eta0: f32) -> Self {
        self.eta0 = Some(eta0);
        self
    }

    /// Full learning-rate schedule for the serial solvers (takes
    /// precedence over [`FitBuilder::eta0`]; the coordinator's
    /// `eta0/epoch`-with-AdaGrad scheme only reads `eta0`).
    pub fn lr(mut self, lr: LrSchedule) -> Self {
        self.lr = Some(lr);
        self
    }

    /// Gradient sample size |I|.
    pub fn i_size(mut self, i: usize) -> Self {
        self.i_size = Some(i);
        self
    }

    /// Expansion sample size |J|.
    pub fn j_size(mut self, j: usize) -> Self {
        self.j_size = Some(j);
        self
    }

    /// Both sample sizes at once.
    pub fn sizes(self, i: usize, j: usize) -> Self {
        self.i_size(i).j_size(j)
    }

    /// Iteration cap for the serial solvers.
    pub fn iters(mut self, iters: u64) -> Self {
        self.iters = Some(iters);
        self
    }

    /// Epoch cap for the parallel coordinator.
    pub fn epochs(mut self, epochs: u64) -> Self {
        self.epochs = Some(epochs);
        self
    }

    /// Epoch-change convergence tolerance (`0` disables).
    pub fn tol(mut self, tol: f32) -> Self {
        self.tol = Some(tol);
        self
    }

    /// Validation cadence: iterations between trace evaluations for the
    /// serial solvers, rounds for the coordinator (`0` = the solver's
    /// default cadence).
    pub fn eval_every(mut self, every: u64) -> Self {
        self.eval_every = Some(every);
        self
    }

    /// Kernel override (defaults to `RBF(gamma)`).
    pub fn kernel(mut self, kernel: Kernel) -> Self {
        self.kernel = Some(kernel);
        self
    }

    /// Per-example loss (default: the paper's hinge).
    pub fn loss(mut self, loss: Loss) -> Self {
        self.loss = Some(loss);
        self
    }

    /// Run on the shared-memory coordinator with this many workers.
    /// Only the DSEKL family parallelises; other kinds error at fit.
    pub fn parallel(mut self, workers: usize) -> Self {
        self.workers = Some(workers);
        self
    }

    /// Batches per coordinator round (`0` = one per worker; a fixed
    /// positive value makes training bitwise worker-count-independent).
    pub fn round_batches(mut self, g: usize) -> Self {
        self.round_batches = Some(g);
        self
    }

    /// Coefficient shards hosted on the coordinator's workers (`0`,
    /// the default, keeps AdaGrad state on the leader; any `W > 0` is
    /// bitwise-equivalent — only the update *ownership* moves).
    pub fn shards(mut self, w: usize) -> Self {
        self.shards = Some(w);
        self
    }

    /// Leader↔worker transport for the coordinator: in-process channels
    /// (default) or one framed loopback socket per worker.
    pub fn coord_transport(mut self, t: CoordTransport) -> Self {
        self.transport = Some(t);
        self
    }

    /// Emp_Fix subset size (defaults to |J|).
    pub fn subset(mut self, m: usize) -> Self {
        self.subset = Some(m);
        self
    }

    /// RKS random-feature count (defaults to |J|).
    pub fn features(mut self, r: usize) -> Self {
        self.features = Some(r);
        self
    }

    /// Online reservoir budget.
    pub fn budget(mut self, budget: usize) -> Self {
        self.budget = Some(budget);
        self
    }

    /// Online chunk size (stream items per gradient step).
    pub fn chunk(mut self, chunk: usize) -> Self {
        self.chunk = Some(chunk);
        self
    }

    /// Stream eviction cadence in gradient steps (`stream` only): every
    /// `evict_every` steps the head is trimmed back to the budget by
    /// coefficient magnitude.
    pub fn evict_every(mut self, every: u64) -> Self {
        self.evict_every = Some(every);
        self
    }

    /// Effective serial learning-rate schedule, if any knob was set.
    fn serial_lr(&self) -> Option<LrSchedule> {
        self.lr
            .or_else(|| self.eta0.map(|eta0| LrSchedule::InvT { eta0 }))
    }

    fn dsekl_opts(&self) -> DseklOpts {
        let mut o = DseklOpts::default();
        if let Some(v) = self.gamma {
            o.gamma = v;
        }
        if let Some(v) = self.lam {
            o.lam = v;
        }
        if let Some(v) = self.i_size {
            o.i_size = v;
        }
        if let Some(v) = self.j_size {
            o.j_size = v;
        }
        if let Some(v) = self.serial_lr() {
            o.lr = v;
        }
        if let Some(v) = self.iters {
            o.max_iters = v;
        }
        if let Some(v) = self.tol {
            o.tol = v;
        }
        if let Some(v) = self.eval_every {
            o.eval_every = v;
        }
        if let Some(v) = self.kernel {
            o.kernel = Some(v);
        }
        if let Some(v) = self.loss {
            o.loss = v;
        }
        o
    }

    fn parallel_opts(&self) -> ParallelOpts {
        let mut o = ParallelOpts::default();
        if let Some(v) = self.gamma {
            o.gamma = v;
        }
        if let Some(v) = self.lam {
            o.lam = v;
        }
        if let Some(v) = self.i_size {
            o.i_size = v;
        }
        if let Some(v) = self.j_size {
            o.j_size = v;
        }
        if let Some(v) = self.workers {
            o.workers = v;
        }
        if let Some(v) = self.epochs {
            o.max_epochs = v;
        }
        if let Some(v) = self.tol {
            o.tol = v;
        }
        if let Some(v) = self.eta0 {
            o.eta0 = v;
        }
        if let Some(v) = self.eval_every {
            o.eval_every_rounds = v;
        }
        if let Some(v) = self.kernel {
            o.kernel = Some(v);
        }
        if let Some(v) = self.loss {
            o.loss = v;
        }
        if let Some(v) = self.round_batches {
            o.round_batches = v;
        }
        if let Some(v) = self.shards {
            o.shards = v;
        }
        if let Some(v) = self.transport {
            o.transport = v;
        }
        o
    }

    fn batch_opts(&self) -> BatchOpts {
        let mut o = BatchOpts::default();
        if let Some(v) = self.gamma {
            o.gamma = v;
        }
        if let Some(v) = self.lam {
            o.lam = v;
        }
        if let Some(v) = self.lr {
            o.lr = v;
        }
        if let Some(v) = self.iters {
            o.max_iters = v;
        }
        if let Some(v) = self.tol {
            o.tol = v;
        }
        if let Some(v) = self.kernel {
            o.kernel = Some(v);
        }
        if let Some(v) = self.loss {
            o.loss = v;
        }
        o
    }

    fn rks_opts(&self) -> RksOpts {
        let mut o = RksOpts::default();
        if let Some(v) = self.gamma {
            o.gamma = v;
        }
        if let Some(v) = self.lam {
            o.lam = v;
        }
        if let Some(v) = self.features.or(self.j_size) {
            o.n_features = v;
        }
        if let Some(v) = self.i_size {
            o.i_size = v;
        }
        if let Some(v) = self.serial_lr() {
            o.lr = v;
        }
        if let Some(v) = self.iters {
            o.max_iters = v;
        }
        if let Some(v) = self.loss {
            o.loss = v;
        }
        o
    }

    fn online_opts(&self) -> OnlineOpts {
        let mut o = OnlineOpts::default();
        if let Some(v) = self.gamma {
            o.gamma = v;
        }
        if let Some(v) = self.lam {
            o.lam = v;
        }
        if let Some(v) = self.budget {
            o.budget = v;
        }
        if let Some(v) = self.chunk {
            o.chunk = v;
        }
        // eta0 scales the base rate *within* the online solver's own
        // InvSqrtT default family (a budgeted reservoir keeps replacing
        // expansion points, so the 1/t decay the batch solvers use
        // would freeze it — see the OnlineOpts Default rationale); an
        // explicit .lr() still overrides the family outright.
        if let Some(v) = self
            .lr
            .or_else(|| self.eta0.map(|eta0| LrSchedule::InvSqrtT { eta0 }))
        {
            o.lr = v;
        }
        if let Some(v) = self.kernel {
            o.kernel = Some(v);
        }
        if let Some(v) = self.loss {
            o.loss = v;
        }
        o
    }

    fn stream_opts(&self) -> StreamOpts {
        let mut o = StreamOpts::default();
        if let Some(v) = self.gamma {
            o.gamma = v;
        }
        if let Some(v) = self.lam {
            o.lam = v;
        }
        if let Some(v) = self.budget {
            o.budget = v;
        }
        if let Some(v) = self.chunk {
            o.chunk = v;
        }
        if let Some(v) = self.evict_every {
            o.evict_every = v;
        }
        // `.features()` (or its |J| fallback) sizes the RKS tail; an
        // explicit 0 disables it — budget-only streaming.
        if let Some(v) = self.features.or(self.j_size) {
            o.tail_features = v;
        }
        // The streaming hybrid keeps its constant-rate default family
        // under `.eta0()`: a drifting stream never becomes stationary,
        // so a decaying schedule would freeze the model into the past.
        // An explicit `.lr()` still overrides the family outright.
        if let Some(v) = self
            .lr
            .or_else(|| self.eta0.map(|eta0| LrSchedule::Const { eta0 }))
        {
            o.lr = v;
        }
        if let Some(v) = self.kernel {
            o.kernel = Some(v);
        }
        if let Some(v) = self.loss {
            o.loss = v;
        }
        // The trace-cadence knob doubles as the prequential window.
        if let Some(v) = self.eval_every {
            o.trace_window = v as usize;
        }
        o
    }

    /// **The** routing point: resolve this configuration against the
    /// data's layout into a concrete estimator, or a structured error.
    /// Every dispatch rule the CLI used to duplicate lives here once:
    ///
    /// * unknown solver names never reach this far
    ///   ([`SolverKind::parse`] owns that error);
    /// * multiclass data is DSEKL-family only (serial routes to the
    ///   one-vs-rest driver, [`FitBuilder::parallel`] to the fused
    ///   K-head coordinator);
    /// * CSR data is DSEKL-family + online/stream only;
    /// * only the DSEKL family runs on the parallel coordinator.
    pub fn estimator_for(&self, data: &TrainSet<'_>) -> Result<AnyEstimator> {
        let parallel = self.kind == SolverKind::Parallel || self.workers.is_some();
        if parallel && !matches!(self.kind, SolverKind::Dsekl | SolverKind::Parallel) {
            return Err(Error::invalid(format!(
                "only the dsekl family runs on the parallel coordinator; \
                 solver {} is serial-only",
                self.kind,
            )));
        }
        if data.is_multiclass() && !matches!(self.kind, SolverKind::Dsekl | SolverKind::Parallel) {
            return Err(Error::invalid(format!(
                "one-vs-rest multiclass training steps DSEKL machines; \
                 supported solvers are dsekl|parallel, not {}",
                self.kind,
            )));
        }
        if data.is_sparse()
            && matches!(
                self.kind,
                SolverKind::Batch | SolverKind::EmpFix | SolverKind::Rks
            )
        {
            return Err(Error::invalid(format!(
                "sparse (CSR) data supports solvers dsekl|parallel|online|stream, \
                 not {} (densify the data to use the dense-only baselines)",
                self.kind,
            )));
        }
        Ok(if parallel {
            AnyEstimator::Parallel(ParallelDsekl::new(self.parallel_opts()))
        } else {
            match self.kind {
                SolverKind::Dsekl if data.is_multiclass() => {
                    AnyEstimator::Ovr(OvrSolver::new(OvrOpts {
                        inner: self.dsekl_opts(),
                    }))
                }
                SolverKind::Dsekl => AnyEstimator::Dsekl(DseklSolver::new(self.dsekl_opts())),
                SolverKind::Batch => AnyEstimator::Batch(BatchSvm::new(self.batch_opts())),
                SolverKind::EmpFix => AnyEstimator::EmpFix(EmpFixSolver::new(EmpFixOpts {
                    subset_size: self
                        .subset
                        .or(self.j_size)
                        .unwrap_or_else(|| DseklOpts::default().j_size),
                    inner: self.dsekl_opts(),
                })),
                SolverKind::Rks => AnyEstimator::Rks(RksSolver::new(self.rks_opts())),
                SolverKind::Online => AnyEstimator::Online(OnlineSolver::new(self.online_opts())),
                SolverKind::Stream => AnyEstimator::Stream(StreamSolver::new(self.stream_opts())),
                // `parallel` is true for this kind, so the branch above
                // took it; routing here anyway keeps the match total.
                SolverKind::Parallel => AnyEstimator::Parallel(ParallelDsekl::new(self.parallel_opts())),
            }
        })
    }

    /// Route and fit in one call — the single public training path.
    pub fn fit(
        &self,
        backend: &mut FitBackend,
        data: TrainSet<'_>,
        rng: &mut Pcg64,
    ) -> Result<Fitted> {
        self.estimator_for(&data)?.fit(backend, data, rng)
    }
}

/// A routed, concrete estimator (what [`FitBuilder::estimator_for`]
/// produces). Dispatches [`Estimator`] to the wrapped solver.
#[derive(Debug, Clone)]
pub enum AnyEstimator {
    /// Serial DSEKL (Algorithm 1).
    Dsekl(DseklSolver),
    /// One-vs-rest K-head driver.
    Ovr(OvrSolver),
    /// The parallel coordinator (Algorithm 2).
    Parallel(ParallelDsekl),
    /// Full-batch kernel SVM.
    Batch(BatchSvm),
    /// Fixed-subset baseline.
    EmpFix(EmpFixSolver),
    /// Random kitchen sinks.
    Rks(RksSolver),
    /// Streaming reservoir DSEKL.
    Online(OnlineSolver),
    /// Drift-aware prequential streaming (budgeted head + RKS tail).
    Stream(StreamSolver),
}

impl Estimator for AnyEstimator {
    fn name(&self) -> &'static str {
        match self {
            AnyEstimator::Dsekl(e) => e.name(),
            AnyEstimator::Ovr(e) => e.name(),
            AnyEstimator::Parallel(e) => e.name(),
            AnyEstimator::Batch(e) => e.name(),
            AnyEstimator::EmpFix(e) => e.name(),
            AnyEstimator::Rks(e) => e.name(),
            AnyEstimator::Online(e) => e.name(),
            AnyEstimator::Stream(e) => e.name(),
        }
    }

    fn fit(
        &self,
        backend: &mut FitBackend,
        data: TrainSet<'_>,
        rng: &mut Pcg64,
    ) -> Result<Fitted> {
        match self {
            AnyEstimator::Dsekl(e) => e.fit(backend, data, rng),
            AnyEstimator::Ovr(e) => e.fit(backend, data, rng),
            AnyEstimator::Parallel(e) => e.fit(backend, data, rng),
            AnyEstimator::Batch(e) => e.fit(backend, data, rng),
            AnyEstimator::EmpFix(e) => e.fit(backend, data, rng),
            AnyEstimator::Rks(e) => e.fit(backend, data, rng),
            AnyEstimator::Online(e) => e.fit(backend, data, rng),
            AnyEstimator::Stream(e) => e.fit(backend, data, rng),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn parse_round_trips_and_rejects() {
        for kind in SolverKind::ALL {
            assert_eq!(SolverKind::parse(kind.name()).unwrap(), kind);
        }
        let err = SolverKind::parse("magic").unwrap_err().to_string();
        assert!(err.contains("unknown solver 'magic'"), "{err}");
    }

    #[test]
    fn routing_matrix() {
        let mut rng = Pcg64::seed_from(1);
        let dense = synth::xor(16, 0.2, &mut rng);
        let multi = synth::multi_blobs(16, 3, 2, 0.3, &mut rng);
        let sparse = synth::sparse_binary(16, 8, 0.3, &mut rng);
        let smulti = synth::sparse_multiclass(16, 3, 8, 0.3, &mut rng);

        // Serial dsekl: binary -> Dsekl, multiclass -> Ovr.
        assert!(matches!(
            Fit::dsekl().estimator_for(&TrainSet::from(&dense)).unwrap(),
            AnyEstimator::Dsekl(_)
        ));
        assert!(matches!(
            Fit::dsekl().estimator_for(&TrainSet::from(&multi)).unwrap(),
            AnyEstimator::Ovr(_)
        ));
        // Parallel covers all four layouts.
        for set in [
            TrainSet::from(&dense),
            TrainSet::from(&multi),
            TrainSet::from(&sparse),
            TrainSet::from(&smulti),
        ] {
            assert!(matches!(
                Fit::dsekl().parallel(2).estimator_for(&set).unwrap(),
                AnyEstimator::Parallel(_)
            ));
        }
        // Online takes both binary layouts, rejects multiclass.
        assert!(matches!(
            Fit::online().estimator_for(&TrainSet::from(&sparse)).unwrap(),
            AnyEstimator::Online(_)
        ));
        assert!(Fit::online().estimator_for(&TrainSet::from(&multi)).is_err());
        // Stream likewise: both binary layouts, never multiclass or
        // parallel.
        for set in [TrainSet::from(&dense), TrainSet::from(&sparse)] {
            assert!(matches!(
                Fit::stream().estimator_for(&set).unwrap(),
                AnyEstimator::Stream(_)
            ));
        }
        assert!(Fit::stream().estimator_for(&TrainSet::from(&multi)).is_err());
        assert!(Fit::stream()
            .parallel(2)
            .estimator_for(&TrainSet::from(&dense))
            .is_err());
        // Dense-only baselines reject CSR and multiclass, and cannot
        // parallelise.
        for builder in [Fit::batch(), Fit::empfix(), Fit::rks()] {
            assert!(builder.estimator_for(&TrainSet::from(&dense)).is_ok());
            assert!(builder.estimator_for(&TrainSet::from(&sparse)).is_err());
            assert!(builder.estimator_for(&TrainSet::from(&multi)).is_err());
            assert!(builder
                .clone()
                .parallel(2)
                .estimator_for(&TrainSet::from(&dense))
                .is_err());
        }
    }

    #[test]
    fn builder_defaults_fall_through_to_solver_defaults() {
        // An untouched builder must produce exactly the solver's
        // Default options — the knobs are overrides, not re-statements.
        let b = Fit::dsekl();
        let o = b.dsekl_opts();
        let d = DseklOpts::default();
        assert_eq!(o.gamma, d.gamma);
        assert_eq!(o.lam, d.lam);
        assert_eq!(o.lr, d.lr);
        assert_eq!(o.max_iters, d.max_iters);
        let bo = Fit::batch().batch_opts();
        let bd = BatchOpts::default();
        assert_eq!(bo.lr, bd.lr); // batch keeps its InvSqrtT default
        assert_eq!(bo.tol, bd.tol); // ... and its 1e-4 tolerance
        let oo = Fit::online().online_opts();
        assert_eq!(oo.budget, OnlineOpts::default().budget);
        let so = Fit::stream().stream_opts();
        let sd = StreamOpts::default();
        assert_eq!(so.budget, sd.budget);
        assert_eq!(so.evict_every, sd.evict_every);
        assert_eq!(so.tail_features, sd.tail_features);
        assert_eq!(so.lr, sd.lr);
        // Stream knobs reach the options; features(0) disables the tail.
        let so = Fit::stream()
            .budget(32)
            .chunk(4)
            .evict_every(2)
            .features(0)
            .eta0(0.5)
            .stream_opts();
        assert_eq!((so.budget, so.chunk, so.evict_every), (32, 4, 2));
        assert_eq!(so.tail_features, 0);
        assert_eq!(so.lr, LrSchedule::Const { eta0: 0.5 });
    }

    #[test]
    fn shards_and_transport_reach_the_coordinator_opts() {
        let o = Fit::dsekl()
            .parallel(3)
            .shards(4)
            .coord_transport(CoordTransport::Socket)
            .parallel_opts();
        assert_eq!(o.workers, 3);
        assert_eq!(o.shards, 4);
        assert_eq!(o.transport, CoordTransport::Socket);
        // Untouched builders keep the leader-applied channel defaults.
        let d = Fit::dsekl().parallel_opts();
        assert_eq!(d.shards, 0);
        assert_eq!(d.transport, CoordTransport::Channel);
    }

    #[test]
    fn eta0_maps_per_family_and_lr_wins() {
        let b = Fit::dsekl().eta0(0.25);
        assert_eq!(b.dsekl_opts().lr, LrSchedule::InvT { eta0: 0.25 });
        assert_eq!(b.parallel_opts().eta0, 0.25);
        let b = b.lr(LrSchedule::Const { eta0: 0.1 });
        assert_eq!(b.dsekl_opts().lr, LrSchedule::Const { eta0: 0.1 });
        // The coordinator's eta0 knob is not an LrSchedule; .lr() does
        // not clobber it.
        assert_eq!(b.parallel_opts().eta0, 0.25);
        // The online solver keeps its InvSqrtT family under .eta0();
        // only an explicit .lr() changes the schedule shape.
        assert_eq!(
            Fit::online().eta0(0.25).online_opts().lr,
            LrSchedule::InvSqrtT { eta0: 0.25 }
        );
        assert_eq!(
            Fit::online()
                .lr(LrSchedule::Const { eta0: 0.1 })
                .online_opts()
                .lr,
            LrSchedule::Const { eta0: 0.1 }
        );
    }

    #[test]
    fn jsize_feeds_empfix_subset_and_rks_features() {
        // The CLI's "--subset defaults to --jsize" (and features
        // likewise) contract lives in the builder now.
        let mut rng = Pcg64::seed_from(2);
        let ds = synth::xor(8, 0.2, &mut rng);
        let set = TrainSet::from(&ds);
        match Fit::empfix().j_size(17).estimator_for(&set).unwrap() {
            AnyEstimator::EmpFix(e) => assert_eq!(e.opts().subset_size, 17),
            _ => panic!("wrong estimator"),
        }
        match Fit::empfix()
            .j_size(17)
            .subset(5)
            .estimator_for(&set)
            .unwrap()
        {
            AnyEstimator::EmpFix(e) => assert_eq!(e.opts().subset_size, 5),
            _ => panic!("wrong estimator"),
        }
        match Fit::rks().j_size(33).estimator_for(&set).unwrap() {
            AnyEstimator::Rks(e) => assert_eq!(e.opts().n_features, 33),
            _ => panic!("wrong estimator"),
        }
    }
}
