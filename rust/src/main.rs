//! `dsekl` — the L3 coordinator binary.
//!
//! See `dsekl help` (or `cli::commands::USAGE`) for the interface. The
//! heavy lifting lives in the library crate so examples, benches and
//! tests reuse it. Every failure funnels through this one exit site as
//! a formatted `error: …` diagnostic (pinned in `cli_roundtrip.rs`).

#![forbid(unsafe_code)]

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match dsekl::cli::run(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}
