//! Pluggable per-example losses for the doubly stochastic solvers.
//!
//! The paper trains the L2-regularised **hinge** loss (Eq. 3/4), but the
//! doubly-stochastic-gradients line of work (Dai et al. 2014, Lu et al.
//! 2016) runs the same machinery over any loss with a computable
//! (sub)gradient in the function value `f`. Every solver in this crate
//! minimises
//!
//! ```text
//!   E(alpha) = sum_a loss(y_a, f_a) + lam * frac * ||alpha||^2
//! ```
//!
//! where `f_a` is the empirical-kernel-map score (or the RFF-space score
//! for RKS). The only loss-specific quantity the compute kernels need is
//! the **residual** `r = -dloss/df`: the data half of the gradient is the
//! transposed kernel contraction `g_b = -sum_a K[a,b] r_a` regardless of
//! which loss produced `r` (see `kernel::native::dsekl_step`).
//!
//! | Loss | value | residual `-dL/df` | use case |
//! |------|-------|-------------------|----------|
//! | [`Loss::Hinge`] | `max(0, 1 - y f)` | `y` if active else 0 | the paper's SVM |
//! | [`Loss::SquaredHinge`] | `max(0, 1 - y f)^2` | `2 y max(0, 1 - y f)` | smooth SVM (L2-SVM) |
//! | [`Loss::Logistic`] | `ln(1 + exp(-y f))` | `y sigma(-y f)` | probabilistic classification |
//! | [`Loss::Ridge`] | `(f - y)^2 / 2` | `y - f` | kernel ridge / regression |
//!
//! Only the hinge loss has AOT/PJRT artifacts; the PJRT backend rejects
//! the others just like it rejects non-RBF kernels
//! ([`Loss::is_aot_supported`]).

use std::fmt;

/// Per-example loss selector, threaded through `StepInput`/`RksStepInput`
/// and every solver's options (default: the paper's hinge).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Loss {
    /// `max(0, 1 - y f)` — the paper's SVM loss.
    #[default]
    Hinge,
    /// `max(0, 1 - y f)^2` — differentiable hinge (L2-SVM).
    SquaredHinge,
    /// `ln(1 + exp(-y f))` — logistic regression.
    Logistic,
    /// `(f - y)^2 / 2` — squared error on the ±1 targets (kernel ridge).
    Ridge,
}

/// All losses, in a stable order (tests and CLI help iterate this).
pub const ALL_LOSSES: [Loss; 4] = [
    Loss::Hinge,
    Loss::SquaredHinge,
    Loss::Logistic,
    Loss::Ridge,
];

impl Loss {
    /// Loss value and residual `r = -dL/df` at score `f` for label `y`.
    ///
    /// The residual is what the gradient contraction consumes: an example
    /// with `r == 0` contributes nothing to the step (for the hinge
    /// family that is exactly "margin satisfied").
    #[inline]
    pub fn eval(self, y: f32, f: f32) -> (f32, f32) {
        match self {
            Loss::Hinge => {
                let margin = 1.0 - y * f;
                if margin > 0.0 {
                    (margin, y)
                } else {
                    (0.0, 0.0)
                }
            }
            Loss::SquaredHinge => {
                let margin = 1.0 - y * f;
                if margin > 0.0 {
                    (margin * margin, 2.0 * y * margin)
                } else {
                    (0.0, 0.0)
                }
            }
            Loss::Logistic => {
                // Stable in both tails: ln(1 + e^{-z}) with z = y f.
                let z = y * f;
                let value = if z > 0.0 {
                    (-z).exp().ln_1p()
                } else {
                    -z + z.exp().ln_1p()
                };
                // sigma(-z) = 1 / (1 + e^{z}); e^{z} -> inf gives 0, fine.
                let sig = 1.0 / (1.0 + z.exp());
                (value, y * sig)
            }
            Loss::Ridge => {
                let e = f - y;
                (0.5 * e * e, -e)
            }
        }
    }

    /// Loss value only (objective evaluation).
    #[inline]
    pub fn value(self, y: f32, f: f32) -> f32 {
        self.eval(y, f).0
    }

    /// Residual `-dL/df` only (gradient evaluation).
    #[inline]
    pub fn residual(self, y: f32, f: f32) -> f32 {
        self.eval(y, f).1
    }

    /// Whether an AOT/PJRT artifact family exists for this loss. Only the
    /// paper's hinge was lowered; the PJRT backend falls back to a clear
    /// error for the rest (use the native backend).
    pub fn is_aot_supported(self) -> bool {
        matches!(self, Loss::Hinge)
    }

    /// CLI/report name.
    pub fn name(self) -> &'static str {
        match self {
            Loss::Hinge => "hinge",
            Loss::SquaredHinge => "squared-hinge",
            Loss::Logistic => "logistic",
            Loss::Ridge => "ridge",
        }
    }
}

impl fmt::Display for Loss {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Loss {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "hinge" => Ok(Loss::Hinge),
            "squared-hinge" | "squared_hinge" | "l2-svm" => Ok(Loss::SquaredHinge),
            "logistic" | "log" => Ok(Loss::Logistic),
            "ridge" | "squared" | "l2" => Ok(Loss::Ridge),
            other => Err(format!(
                "unknown loss '{other}' (expected hinge|squared-hinge|logistic|ridge)"
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hinge_matches_paper_definition() {
        // Active example: value = margin, residual = y.
        let (v, r) = Loss::Hinge.eval(1.0, 0.25);
        assert!((v - 0.75).abs() < 1e-7);
        assert_eq!(r, 1.0);
        // Satisfied margin: no contribution.
        assert_eq!(Loss::Hinge.eval(-1.0, -2.0), (0.0, 0.0));
        // At f = 0 every example is active with unit loss.
        assert_eq!(Loss::Hinge.eval(-1.0, 0.0), (1.0, -1.0));
    }

    #[test]
    fn squared_hinge_is_squared() {
        let (v, r) = Loss::SquaredHinge.eval(1.0, 0.5);
        assert!((v - 0.25).abs() < 1e-7);
        assert!((r - 1.0).abs() < 1e-7); // 2 * 1 * 0.5
        assert_eq!(Loss::SquaredHinge.eval(1.0, 2.0), (0.0, 0.0));
    }

    #[test]
    fn logistic_symmetry_and_tails() {
        // ln 2 at the decision boundary, residual y/2.
        let (v, r) = Loss::Logistic.eval(1.0, 0.0);
        assert!((v - std::f32::consts::LN_2).abs() < 1e-6);
        assert!((r - 0.5).abs() < 1e-6);
        // Symmetric in y f.
        let a = Loss::Logistic.eval(1.0, 1.3).0;
        let b = Loss::Logistic.eval(-1.0, -1.3).0;
        assert!((a - b).abs() < 1e-6);
        // Deep tails stay finite and sensible.
        let (v_far, r_far) = Loss::Logistic.eval(1.0, 50.0);
        assert!(v_far >= 0.0 && v_far < 1e-6);
        assert!(r_far.abs() < 1e-6);
        let (v_bad, r_bad) = Loss::Logistic.eval(1.0, -50.0);
        assert!((v_bad - 50.0).abs() < 1e-3);
        assert!((r_bad - 1.0).abs() < 1e-6);
    }

    #[test]
    fn ridge_residual_is_linear() {
        let (v, r) = Loss::Ridge.eval(1.0, 0.0);
        assert!((v - 0.5).abs() < 1e-7);
        assert_eq!(r, 1.0);
        let (v2, r2) = Loss::Ridge.eval(-1.0, 1.0);
        assert!((v2 - 2.0).abs() < 1e-7);
        assert_eq!(r2, -2.0);
    }

    #[test]
    fn residuals_are_finite_difference_of_value() {
        // Central finite differences of value() match -residual() away
        // from the hinge kinks, for every loss.
        let eps = 1e-3f64;
        for loss in ALL_LOSSES {
            for &y in &[1.0f32, -1.0] {
                for &f in &[-2.3f32, -0.4, 0.1, 0.7, 1.9] {
                    if matches!(loss, Loss::Hinge | Loss::SquaredHinge)
                        && (1.0 - y * f).abs() < 0.05
                    {
                        continue; // skip the kink neighbourhood
                    }
                    let vp = loss.value(y, f + eps as f32) as f64;
                    let vm = loss.value(y, f - eps as f32) as f64;
                    let fd = (vp - vm) / (2.0 * eps);
                    let r = loss.residual(y, f) as f64;
                    assert!(
                        (fd + r).abs() < 1e-2,
                        "{loss}: y={y} f={f}: fd {fd} vs -r {}",
                        -r
                    );
                }
            }
        }
    }

    #[test]
    fn parse_and_display_roundtrip() {
        for loss in ALL_LOSSES {
            let parsed: Loss = loss.name().parse().unwrap();
            assert_eq!(parsed, loss);
        }
        assert_eq!("squared_hinge".parse::<Loss>().unwrap(), Loss::SquaredHinge);
        assert!("focal".parse::<Loss>().is_err());
    }

    #[test]
    fn aot_support_is_hinge_only() {
        assert!(Loss::Hinge.is_aot_supported());
        assert!(!Loss::SquaredHinge.is_aot_supported());
        assert!(!Loss::Logistic.is_aot_supported());
        assert!(!Loss::Ridge.is_aot_supported());
    }
}
