//! Minimal blocking client for the serve protocol: one request frame
//! out, one response frame back, over any `Read + Write` stream (a
//! `TcpStream`, a child process's stdio pipes, or an in-memory duplex
//! in tests).
//!
//! Error contract: the server's tagged errors surface as distinct
//! messages — `server overloaded:` (shed by backpressure, safe to
//! retry after backoff), `server timed out:` (deadline elapsed),
//! `server shutting down:` (connection is going away), and plain
//! `server error:` for scoring failures. TCP clients built with
//! [`Client::connect_timeout`] additionally bound every socket read
//! and write, so a dead or wedged server surfaces as a timely error
//! instead of a hung client.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use super::protocol::{self, Response};
use crate::data::CsrBlock;
use crate::{Error, Result};

/// A connected serve-protocol client. Requests are strictly
/// sequential (the protocol is one-response-per-request, in order).
pub struct Client<S: Read + Write> {
    stream: S,
}

impl Client<TcpStream> {
    /// Connect over TCP, e.g. `Client::connect("127.0.0.1:7878")`.
    /// No socket deadlines: reads block until the server answers. Use
    /// [`Client::connect_timeout`] when a hung server must not hang
    /// the client too.
    pub fn connect(addr: &str) -> Result<Client<TcpStream>> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::invalid(format!("cannot connect to '{addr}': {e}")))?;
        stream.set_nodelay(true).ok();
        Ok(Client::new(stream))
    }

    /// Connect with socket deadlines: every read and write on the
    /// connection errors after `timeout` instead of blocking forever.
    /// Pair it with the server's `--request-timeout-ms` (plus queue
    /// linger headroom) so the client outlasts a healthy server's
    /// worst case but never a wedged one.
    pub fn connect_timeout(addr: &str, timeout: Duration) -> Result<Client<TcpStream>> {
        let client = Client::connect(addr)?;
        client.stream.set_read_timeout(Some(timeout)).ok();
        client.stream.set_write_timeout(Some(timeout)).ok();
        Ok(client)
    }
}

impl<S: Read + Write> Client<S> {
    /// Wrap an already-connected duplex stream.
    pub fn new(stream: S) -> Client<S> {
        Client { stream }
    }

    /// The underlying stream (e.g. to shut a TCP socket down).
    pub fn stream(&self) -> &S {
        &self.stream
    }

    fn call(&mut self, frame: &[u8]) -> Result<Response> {
        protocol::write_frame(&mut self.stream, frame)?;
        self.stream.flush()?;
        match protocol::read_frame(&mut self.stream)? {
            Some(payload) => protocol::decode_response(&payload),
            None => Err(Error::parse("server closed the connection mid-request")),
        }
    }

    /// Liveness check.
    pub fn ping(&mut self) -> Result<()> {
        match self.call(&protocol::encode_ping())? {
            Response::Pong => Ok(()),
            other => Err(failure("pong", other)),
        }
    }

    /// Score `n` dense rows of dimensionality `d` (row-major, `n * d`
    /// values). Returns row-major `[n, k]` scores plus the head count
    /// `k` (1 for binary-family models, K for multiclass).
    pub fn score_dense(&mut self, x: &[f32], n: usize, d: usize) -> Result<(Vec<f32>, usize)> {
        match self.call(&protocol::encode_score_dense(x, n, d)?)? {
            Response::Scores { k, scores } => Ok((scores, k)),
            other => Err(failure("scores", other)),
        }
    }

    /// Score a CSR block (same `[n, k]` + `k` contract as
    /// [`Client::score_dense`]).
    pub fn score_csr(&mut self, block: &CsrBlock) -> Result<(Vec<f32>, usize)> {
        match self.call(&protocol::encode_score_csr(block)?)? {
            Response::Scores { k, scores } => Ok((scores, k)),
            other => Err(failure("scores", other)),
        }
    }

    /// Hot-reload the served model: `Some(path)` switches files,
    /// `None` re-reads the current one. Returns the server's one-line
    /// reload summary.
    pub fn reload(&mut self, path: Option<&str>) -> Result<String> {
        match self.call(&protocol::encode_reload(path)?)? {
            Response::Text(summary) => Ok(summary),
            other => Err(failure("text", other)),
        }
    }

    /// The server's metrics snapshot as rendered text (one `key value`
    /// line per counter, plus the latency percentile summary).
    pub fn stats(&mut self) -> Result<String> {
        match self.call(&protocol::encode_stats())? {
            Response::Text(text) => Ok(text),
            other => Err(failure("text", other)),
        }
    }
}

/// Turn any non-expected response into an error: server errors keep
/// their kind recognisable in the message prefix (generic /
/// overloaded / timed out / shutting down), successes of the wrong
/// shape are protocol violations.
fn failure(want: &str, got: Response) -> Error {
    match got {
        Response::Error(msg) => Error::invalid(format!("server error: {msg}")),
        Response::Overloaded(msg) => Error::invalid(format!("server overloaded: {msg}")),
        Response::TimedOut(msg) => Error::invalid(format!("server timed out: {msg}")),
        Response::ShuttingDown(msg) => Error::invalid(format!("server shutting down: {msg}")),
        Response::Pong => unexpected(want, "pong"),
        Response::Scores { .. } => unexpected(want, "scores"),
        Response::Text(_) => unexpected(want, "text"),
    }
}

fn unexpected(want: &str, got: &str) -> Error {
    Error::parse(format!(
        "protocol violation: expected a {want} response, got {got}"
    ))
}
