//! Prediction serving: a long-lived model server over the sniffing
//! [`Predictor::load_file`](crate::estimator::Predictor::load_file)
//! front door — the path that looks like "serving predictions to
//! millions of users" which the paper's scaling pitch implies and the
//! roadmap names as the top open item.
//!
//! Four pieces:
//!
//! * [`protocol`] — a length-prefixed binary framing (u32 length +
//!   payload) carrying score / reload / stats / ping requests and
//!   their responses. Message-shaped on purpose: the same front door
//!   can later fan out to sharded workers (Tu et al.'s block-
//!   coordinate setting) without changing clients.
//! * [`server`] — the server itself: connection handlers enqueue
//!   scoring jobs, a dedicated scorer thread **micro-batches**
//!   concurrent requests (drain-with-linger, see
//!   [`ServeOpts::max_wait`]) into one fused
//!   [`predict_multi`](crate::runtime::Backend::predict_multi) call
//!   per compatible group, and **hot reload** atomically swaps the
//!   `Arc`-shared model under readers — in-flight batches finish on
//!   the store they started with, new requests score the new one.
//! * [`metrics`] — p50/p90/p99 request latency, throughput and
//!   batch-size counters, reported over the wire via the stats op.
//! * [`client`] — a minimal blocking client over any `Read + Write`
//!   stream (TCP or a child process's stdio), used by the smoke tests
//!   and available to embedders.
//!
//! The CLI front end is `dsekl serve --model m.dsekl --addr
//! 127.0.0.1:7878` (or `--stdio` for a pipe-driven child process);
//! everything here is plain `std` — no registry dependencies.

pub mod client;
pub mod metrics;
pub mod protocol;
pub mod server;

pub use client::Client;
pub use metrics::{ServeMetrics, ServeSnapshot};
pub use protocol::{Request, Response, ScorePayload};
pub use server::{serve_connection, Server, ServerHandle};

use std::time::Duration;

use crate::runtime::BackendSpec;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Compute backend the scorer thread instantiates.
    pub backend: BackendSpec,
    /// Micro-batch cap: the scorer drains queued requests until their
    /// combined row count reaches this (a single larger request still
    /// goes through whole).
    pub max_batch_rows: usize,
    /// Linger: after picking up the first queued request the scorer
    /// waits up to this long for more requests to coalesce into the
    /// batch. 0 disables batching-by-wait (only already-queued
    /// requests coalesce).
    pub max_wait: Duration,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            backend: BackendSpec::Native,
            max_batch_rows: 256,
            max_wait: Duration::from_millis(1),
        }
    }
}
