//! Prediction serving: a long-lived model server over the sniffing
//! [`Predictor::load_file`](crate::estimator::Predictor::load_file)
//! front door — the path that looks like "serving predictions to
//! millions of users" which the paper's scaling pitch implies and the
//! roadmap names as the top open item.
//!
//! Four pieces:
//!
//! * [`protocol`] — a length-prefixed binary framing (u32 length +
//!   payload) carrying score / reload / stats / ping requests and
//!   their responses. Message-shaped on purpose: the same front door
//!   can later fan out to sharded workers (Tu et al.'s block-
//!   coordinate setting) without changing clients.
//! * [`server`] — the server itself: connection handlers enqueue
//!   scoring jobs onto a **bounded** queue ([`ServeOpts::max_queue_rows`];
//!   past the cap requests are shed immediately with a structured
//!   overloaded response, the serving-side analogue of Dai et al.'s
//!   budget/variance trade-off — bounded memory, graceful degradation),
//!   one or more scorer threads ([`ServeOpts::scorer_threads`], the
//!   serving mirror of block-partitioned training) **micro-batch**
//!   concurrent requests (drain-with-linger, see [`ServeOpts::max_wait`])
//!   into one fused [`predict_multi`](crate::runtime::Backend::predict_multi)
//!   call per compatible group, every reply is bounded by a
//!   **per-request deadline** ([`ServeOpts::request_timeout`] — a dead
//!   scorer or stalled client can never hang a connection thread), and
//!   **hot reload** atomically swaps the `Arc`-shared model under
//!   readers — in-flight batches finish on the store they started
//!   with, new requests score the new one.
//! * [`metrics`] — p50/p90/p99 request latency, throughput and
//!   batch-size counters, reported over the wire via the stats op.
//! * [`client`] — a minimal blocking client over any `Read + Write`
//!   stream (TCP or a child process's stdio), used by the smoke tests
//!   and available to embedders.
//!
//! The CLI front end is `dsekl serve --model m.dsekl --addr
//! 127.0.0.1:7878` (or `--stdio` for a pipe-driven child process);
//! everything here is plain `std` — no registry dependencies.

pub mod client;
pub mod metrics;
pub mod protocol;
pub mod server;

pub use client::Client;
pub use metrics::{ServeMetrics, ServeSnapshot};
pub use protocol::{FrameEvent, Request, Response, ScorePayload};
pub use server::{serve_connection, ScoreError, Server, ServerHandle};

use std::time::Duration;

use crate::runtime::BackendSpec;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Compute backend each scorer thread instantiates.
    pub backend: BackendSpec,
    /// Micro-batch cap: a scorer drains queued requests until their
    /// combined row count reaches this. A single larger request still
    /// goes through whole at the queue, but is scored in row chunks of
    /// at most this size, so scorer memory stays bounded by the cap
    /// regardless of request size.
    pub max_batch_rows: usize,
    /// Linger: after picking up the first queued request a scorer
    /// waits up to this long for more requests to coalesce into the
    /// batch. 0 disables batching-by-wait (only already-queued
    /// requests coalesce).
    pub max_wait: Duration,
    /// Scorer threads draining the shared queue (`--scorer-threads`).
    /// Each owns its own backend; for a fixed model the returned
    /// scores are identical for any thread count (per-row scoring is
    /// independent of batch composition). 0 means "the caller manages
    /// scorers" — [`server::Server::spawn_tcp`] then starts none,
    /// which tests use to simulate a wedged server.
    pub scorer_threads: usize,
    /// Backpressure cap (`--max-queue-rows`): total rows allowed to
    /// wait in the scoring queue. A request that would push past the
    /// cap (or alone exceeds it) is refused immediately with a
    /// structured overloaded response instead of queuing without
    /// bound. 0 disables the cap.
    pub max_queue_rows: usize,
    /// Per-request deadline (`--request-timeout-ms`): how long a
    /// connection thread waits for a scorer's reply before answering
    /// with a structured timeout — a wedged or dead scorer can delay a
    /// client by at most this, never hang it. Also bounds how long a
    /// stalled peer may sit mid-frame before its connection is
    /// dropped.
    pub request_timeout: Duration,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            backend: BackendSpec::Native,
            max_batch_rows: 256,
            max_wait: Duration::from_millis(1),
            scorer_threads: 1,
            max_queue_rows: 4096,
            request_timeout: Duration::from_millis(10_000),
        }
    }
}
