//! Serving counters: request/row/batch totals on atomics, a bounded
//! reservoir of per-request latencies for p50/p90/p99, and a plain-text
//! snapshot served over the wire by the stats op.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::metrics::LatencySummary;

/// Cap on retained latency samples; older samples are overwritten
/// ring-buffer style so a long-lived server reports recent behaviour
/// with bounded memory.
const SAMPLE_CAP: usize = 1 << 16;

#[derive(Debug, Default)]
struct LatencyRing {
    samples: Vec<u64>,
    next: usize,
}

/// Live serving metrics. All counters are atomics (connection handlers
/// and the scorer thread update them concurrently); only the latency
/// reservoir takes a lock, briefly.
#[derive(Debug)]
pub struct ServeMetrics {
    start: Instant,
    score_requests: AtomicU64,
    rows_scored: AtomicU64,
    batches: AtomicU64,
    batched_rows: AtomicU64,
    max_batch_rows: AtomicU64,
    max_batch_requests: AtomicU64,
    reloads: AtomicU64,
    errors: AtomicU64,
    control_requests: AtomicU64,
    latencies: Mutex<LatencyRing>,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics {
            start: Instant::now(),
            score_requests: AtomicU64::new(0),
            rows_scored: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_rows: AtomicU64::new(0),
            max_batch_rows: AtomicU64::new(0),
            max_batch_requests: AtomicU64::new(0),
            reloads: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            control_requests: AtomicU64::new(0),
            latencies: Mutex::new(LatencyRing::default()),
        }
    }
}

impl ServeMetrics {
    /// One successfully answered score request of `rows` rows,
    /// measured from decode to response-ready (queue wait + batching
    /// linger + compute).
    pub fn record_score(&self, rows: usize, latency: Duration) {
        self.score_requests.fetch_add(1, Ordering::Relaxed);
        self.rows_scored.fetch_add(rows as u64, Ordering::Relaxed);
        let us = latency.as_micros().min(u128::from(u64::MAX)) as u64;
        // Poisoned lock: keep serving on the surviving samples rather
        // than propagating a metrics panic into the request path.
        let mut ring = self.latencies.lock().unwrap_or_else(|e| e.into_inner());
        if ring.samples.len() < SAMPLE_CAP {
            ring.samples.push(us);
        } else {
            let i = ring.next;
            if let Some(slot) = ring.samples.get_mut(i) {
                *slot = us;
            }
            ring.next = (i + 1) % SAMPLE_CAP;
        }
    }

    /// One fused scoring pass covering `rows` rows from `requests`
    /// coalesced requests — the counter that verifies micro-batching.
    pub fn record_batch(&self, rows: usize, requests: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_rows.fetch_add(rows as u64, Ordering::Relaxed);
        self.max_batch_rows.fetch_max(rows as u64, Ordering::Relaxed);
        self.max_batch_requests
            .fetch_max(requests as u64, Ordering::Relaxed);
    }

    /// One completed hot reload.
    pub fn record_reload(&self) {
        self.reloads.fetch_add(1, Ordering::Relaxed);
    }

    /// One request answered with an error.
    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// One control-plane request (ping / stats) — kept separate from
    /// the bulk scoring counters.
    pub fn record_control(&self) {
        self.control_requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Consistent-enough point-in-time copy of every counter plus the
    /// latency distribution summary.
    pub fn snapshot(&self) -> ServeSnapshot {
        let mut samples = {
            let ring = self.latencies.lock().unwrap_or_else(|e| e.into_inner());
            ring.samples.clone()
        };
        let uptime = self.start.elapsed();
        let rows = self.rows_scored.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let batched_rows = self.batched_rows.load(Ordering::Relaxed);
        ServeSnapshot {
            uptime_s: uptime.as_secs_f64(),
            score_requests: self.score_requests.load(Ordering::Relaxed),
            rows_scored: rows,
            batches,
            mean_batch_rows: if batches == 0 {
                0.0
            } else {
                batched_rows as f64 / batches as f64
            },
            max_batch_rows: self.max_batch_rows.load(Ordering::Relaxed),
            max_batch_requests: self.max_batch_requests.load(Ordering::Relaxed),
            reloads: self.reloads.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            control_requests: self.control_requests.load(Ordering::Relaxed),
            rows_per_s: crate::metrics::throughput(rows, uptime),
            latency: LatencySummary::from_samples(&mut samples),
        }
    }
}

/// Point-in-time serving metrics, as reported by the stats op.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ServeSnapshot {
    /// Seconds since the metrics (and server) started.
    pub uptime_s: f64,
    /// Score requests answered successfully.
    pub score_requests: u64,
    /// Rows scored across those requests.
    pub rows_scored: u64,
    /// Fused scoring passes run by the scorer thread.
    pub batches: u64,
    /// Mean rows per fused pass (> 1 per request mean means batching
    /// is actually coalescing).
    pub mean_batch_rows: f64,
    /// Largest fused pass, in rows.
    pub max_batch_rows: u64,
    /// Most requests coalesced into one fused pass.
    pub max_batch_requests: u64,
    /// Hot reloads completed.
    pub reloads: u64,
    /// Requests answered with an error.
    pub errors: u64,
    /// Control-plane (ping / stats) requests.
    pub control_requests: u64,
    /// Rows scored per second of uptime.
    pub rows_per_s: f64,
    /// Request latency distribution (p50/p90/p99/max/mean).
    pub latency: LatencySummary,
}

impl ServeSnapshot {
    /// Plain-text table, one `key value` line per counter — what the
    /// stats op returns over the wire.
    pub fn render(&self) -> String {
        format!(
            "uptime_s {:.3}\n\
             score_requests {}\n\
             rows_scored {}\n\
             batches {}\n\
             mean_batch_rows {:.2}\n\
             max_batch_rows {}\n\
             max_batch_requests {}\n\
             reloads {}\n\
             errors {}\n\
             control_requests {}\n\
             rows_per_s {:.1}\n\
             latency {}\n",
            self.uptime_s,
            self.score_requests,
            self.rows_scored,
            self.batches,
            self.mean_batch_rows,
            self.max_batch_rows,
            self.max_batch_requests,
            self.reloads,
            self.errors,
            self.control_requests,
            self.rows_per_s,
            self.latency,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_snapshot() {
        let m = ServeMetrics::default();
        m.record_score(4, Duration::from_micros(100));
        m.record_score(2, Duration::from_micros(300));
        m.record_batch(6, 2);
        m.record_reload();
        m.record_control();
        let s = m.snapshot();
        assert_eq!(s.score_requests, 2);
        assert_eq!(s.rows_scored, 6);
        assert_eq!(s.batches, 1);
        assert_eq!(s.max_batch_rows, 6);
        assert_eq!(s.max_batch_requests, 2);
        assert_eq!(s.reloads, 1);
        assert_eq!(s.errors, 0);
        assert_eq!(s.control_requests, 1);
        assert_eq!(s.latency.count, 2);
        assert_eq!(s.latency.max_us, 300);
        let text = s.render();
        assert!(text.contains("score_requests 2"), "{text}");
        assert!(text.contains("p50="), "{text}");
        assert!(text.contains("p99="), "{text}");
    }

    #[test]
    fn latency_ring_is_bounded() {
        let m = ServeMetrics::default();
        for i in 0..(SAMPLE_CAP + 10) {
            m.record_score(1, Duration::from_micros(i as u64));
        }
        let s = m.snapshot();
        assert_eq!(s.latency.count, SAMPLE_CAP);
        assert_eq!(s.score_requests, (SAMPLE_CAP + 10) as u64);
    }
}
