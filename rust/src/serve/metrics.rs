//! Serving counters: request/row/batch totals on atomics, a bounded
//! reservoir of per-request latencies for p50/p90/p99, a sliding
//! throughput window (so a long-lived server reports *recent* rate,
//! not a lifetime average), and a plain-text snapshot served over the
//! wire by the stats op.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::metrics::LatencySummary;

/// Cap on retained latency samples; older samples are overwritten
/// ring-buffer style so a long-lived server reports recent behaviour
/// with bounded memory.
const SAMPLE_CAP: usize = 1 << 16;

/// Width of the recent-throughput window. `rows_per_s` is the lifetime
/// average (stale after hours of varying load); `recent_rows_per_s`
/// covers at most the last two of these windows.
const RATE_WINDOW: Duration = Duration::from_secs(10);

#[derive(Debug, Default)]
struct LatencyRing {
    samples: Vec<u64>,
    next: usize,
}

#[derive(Debug)]
struct RateWindow {
    start: Instant,
    rows: u64,
    /// Rate of the last *completed* window — reported while the
    /// current window is too young to be meaningful.
    prev_rate: f64,
}

impl Default for RateWindow {
    fn default() -> Self {
        RateWindow {
            start: Instant::now(),
            rows: 0,
            prev_rate: 0.0,
        }
    }
}

/// Live serving metrics. All counters are atomics (connection handlers
/// and the scorer threads update them concurrently); only the latency
/// reservoir and the rate window take a lock, briefly.
#[derive(Debug)]
pub struct ServeMetrics {
    start: Instant,
    score_requests: AtomicU64,
    rows_scored: AtomicU64,
    batches: AtomicU64,
    fused_groups: AtomicU64,
    batched_rows: AtomicU64,
    max_batch_rows: AtomicU64,
    max_batch_requests: AtomicU64,
    reloads: AtomicU64,
    errors: AtomicU64,
    shed: AtomicU64,
    timeouts: AtomicU64,
    control_requests: AtomicU64,
    latencies: Mutex<LatencyRing>,
    rate: Mutex<RateWindow>,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics {
            start: Instant::now(),
            score_requests: AtomicU64::new(0),
            rows_scored: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            fused_groups: AtomicU64::new(0),
            batched_rows: AtomicU64::new(0),
            max_batch_rows: AtomicU64::new(0),
            max_batch_requests: AtomicU64::new(0),
            reloads: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            control_requests: AtomicU64::new(0),
            latencies: Mutex::new(LatencyRing::default()),
            rate: Mutex::new(RateWindow::default()),
        }
    }
}

impl ServeMetrics {
    /// One successfully answered score request of `rows` rows,
    /// measured from decode to response-ready (queue wait + batching
    /// linger + compute).
    pub fn record_score(&self, rows: usize, latency: Duration) {
        self.score_requests.fetch_add(1, Ordering::Relaxed);
        self.rows_scored.fetch_add(rows as u64, Ordering::Relaxed);
        let us = latency.as_micros().min(u128::from(u64::MAX)) as u64;
        // Poisoned lock: keep serving on the surviving samples rather
        // than propagating a metrics panic into the request path.
        let mut ring = self.latencies.lock().unwrap_or_else(|e| e.into_inner());
        if ring.samples.len() < SAMPLE_CAP {
            ring.samples.push(us);
        } else {
            let i = ring.next;
            if let Some(slot) = ring.samples.get_mut(i) {
                *slot = us;
            }
            ring.next = (i + 1) % SAMPLE_CAP;
        }
        drop(ring);
        let mut rate = self.rate.lock().unwrap_or_else(|e| e.into_inner());
        let elapsed = rate.start.elapsed();
        if elapsed >= RATE_WINDOW {
            rate.prev_rate = rate.rows as f64 / elapsed.as_secs_f64();
            rate.rows = 0;
            rate.start = Instant::now();
        }
        rate.rows += rows as u64;
    }

    /// One queue **drain** covering `rows` rows from `requests`
    /// coalesced requests — recorded once per drain, however many
    /// per-layout fused passes it splits into, so `mean_batch_rows`
    /// and `max_batch_requests` describe drains even under
    /// mixed-layout traffic.
    pub fn record_drain(&self, rows: usize, requests: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_rows.fetch_add(rows as u64, Ordering::Relaxed);
        self.max_batch_rows.fetch_max(rows as u64, Ordering::Relaxed);
        self.max_batch_requests
            .fetch_max(requests as u64, Ordering::Relaxed);
    }

    /// One fused scoring pass (per (layout, dim) group within a drain;
    /// `fused_groups >= batches`, with equality under uniform-layout
    /// traffic).
    pub fn record_group(&self) {
        self.fused_groups.fetch_add(1, Ordering::Relaxed);
    }

    /// One completed hot reload.
    pub fn record_reload(&self) {
        self.reloads.fetch_add(1, Ordering::Relaxed);
    }

    /// One request answered with an error.
    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// One request shed by backpressure (queue past `max_queue_rows`
    /// or shutdown drain). Also counts as an error.
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// One request that hit its deadline before a scorer answered.
    /// Also counts as an error.
    pub fn record_timeout(&self) {
        self.timeouts.fetch_add(1, Ordering::Relaxed);
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// One control-plane request (ping / stats) — kept separate from
    /// the bulk scoring counters.
    pub fn record_control(&self) {
        self.control_requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Consistent-enough point-in-time copy of every counter plus the
    /// latency distribution summary.
    pub fn snapshot(&self) -> ServeSnapshot {
        let mut samples = {
            let ring = self.latencies.lock().unwrap_or_else(|e| e.into_inner());
            ring.samples.clone()
        };
        let recent_rows_per_s = {
            let rate = self.rate.lock().unwrap_or_else(|e| e.into_inner());
            let elapsed = rate.start.elapsed();
            // A very young window has too little signal; fall back to
            // the last completed window's rate until ~0.5s has passed.
            if elapsed >= Duration::from_millis(500) {
                rate.rows as f64 / elapsed.as_secs_f64()
            } else {
                rate.prev_rate
            }
        };
        let uptime = self.start.elapsed();
        let rows = self.rows_scored.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let batched_rows = self.batched_rows.load(Ordering::Relaxed);
        ServeSnapshot {
            uptime_s: uptime.as_secs_f64(),
            score_requests: self.score_requests.load(Ordering::Relaxed),
            rows_scored: rows,
            batches,
            fused_groups: self.fused_groups.load(Ordering::Relaxed),
            mean_batch_rows: if batches == 0 {
                0.0
            } else {
                batched_rows as f64 / batches as f64
            },
            max_batch_rows: self.max_batch_rows.load(Ordering::Relaxed),
            max_batch_requests: self.max_batch_requests.load(Ordering::Relaxed),
            reloads: self.reloads.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            timeouts: self.timeouts.load(Ordering::Relaxed),
            control_requests: self.control_requests.load(Ordering::Relaxed),
            rows_per_s: crate::metrics::throughput(rows, uptime),
            recent_rows_per_s,
            latency: LatencySummary::from_samples(&mut samples),
        }
    }
}

/// Point-in-time serving metrics, as reported by the stats op.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ServeSnapshot {
    /// Seconds since the metrics (and server) started.
    pub uptime_s: f64,
    /// Score requests answered successfully.
    pub score_requests: u64,
    /// Rows scored across those requests.
    pub rows_scored: u64,
    /// Queue drains (micro-batches picked up by a scorer thread).
    pub batches: u64,
    /// Fused scoring passes — one per (layout, dim) group per drain,
    /// so `>= batches`, equal under uniform-layout traffic.
    pub fused_groups: u64,
    /// Mean rows per drain (> 1 per request mean means batching is
    /// actually coalescing).
    pub mean_batch_rows: f64,
    /// Largest drain, in rows.
    pub max_batch_rows: u64,
    /// Most requests coalesced into one drain.
    pub max_batch_requests: u64,
    /// Hot reloads completed.
    pub reloads: u64,
    /// Requests answered with an error (sheds and timeouts included).
    pub errors: u64,
    /// Requests shed by backpressure (queue cap or shutdown drain).
    pub shed: u64,
    /// Requests that hit their `--request-timeout-ms` deadline.
    pub timeouts: u64,
    /// Control-plane (ping / stats) requests.
    pub control_requests: u64,
    /// Rows scored per second of uptime (lifetime average).
    pub rows_per_s: f64,
    /// Rows per second over the last ~10s window — what a dashboard
    /// should plot; the lifetime average goes stale on long-lived
    /// servers.
    pub recent_rows_per_s: f64,
    /// Request latency distribution (p50/p90/p99/max/mean).
    pub latency: LatencySummary,
}

impl ServeSnapshot {
    /// Plain-text table, one `key value` line per counter — what the
    /// stats op returns over the wire.
    pub fn render(&self) -> String {
        format!(
            "uptime_s {:.3}\n\
             score_requests {}\n\
             rows_scored {}\n\
             batches {}\n\
             fused_groups {}\n\
             mean_batch_rows {:.2}\n\
             max_batch_rows {}\n\
             max_batch_requests {}\n\
             reloads {}\n\
             errors {}\n\
             shed {}\n\
             timeouts {}\n\
             control_requests {}\n\
             rows_per_s {:.1}\n\
             recent_rows_per_s {:.1}\n\
             latency {}\n",
            self.uptime_s,
            self.score_requests,
            self.rows_scored,
            self.batches,
            self.fused_groups,
            self.mean_batch_rows,
            self.max_batch_rows,
            self.max_batch_requests,
            self.reloads,
            self.errors,
            self.shed,
            self.timeouts,
            self.control_requests,
            self.rows_per_s,
            self.recent_rows_per_s,
            self.latency,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_snapshot() {
        let m = ServeMetrics::default();
        m.record_score(4, Duration::from_micros(100));
        m.record_score(2, Duration::from_micros(300));
        // One drain of 6 rows / 2 requests that split into two fused
        // (layout, dim) groups: batches counts the drain, not the
        // groups.
        m.record_drain(6, 2);
        m.record_group();
        m.record_group();
        m.record_reload();
        m.record_control();
        let s = m.snapshot();
        assert_eq!(s.score_requests, 2);
        assert_eq!(s.rows_scored, 6);
        assert_eq!(s.batches, 1);
        assert_eq!(s.fused_groups, 2);
        assert_eq!(s.mean_batch_rows, 6.0);
        assert_eq!(s.max_batch_rows, 6);
        assert_eq!(s.max_batch_requests, 2);
        assert_eq!(s.reloads, 1);
        assert_eq!(s.errors, 0);
        assert_eq!(s.control_requests, 1);
        assert_eq!(s.latency.count, 2);
        assert_eq!(s.latency.max_us, 300);
        let text = s.render();
        assert!(text.contains("score_requests 2"), "{text}");
        assert!(text.contains("fused_groups 2"), "{text}");
        assert!(text.contains("recent_rows_per_s"), "{text}");
        assert!(text.contains("p50="), "{text}");
        assert!(text.contains("p99="), "{text}");
    }

    #[test]
    fn shed_and_timeout_count_as_errors_too() {
        let m = ServeMetrics::default();
        m.record_shed();
        m.record_shed();
        m.record_timeout();
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.shed, 2);
        assert_eq!(s.timeouts, 1);
        assert_eq!(s.errors, 4, "sheds and timeouts roll up into errors");
        let text = s.render();
        assert!(text.contains("shed 2"), "{text}");
        assert!(text.contains("timeouts 1"), "{text}");
    }

    #[test]
    fn latency_ring_is_bounded() {
        let m = ServeMetrics::default();
        for i in 0..(SAMPLE_CAP + 10) {
            m.record_score(1, Duration::from_micros(i as u64));
        }
        let s = m.snapshot();
        assert_eq!(s.latency.count, SAMPLE_CAP);
        assert_eq!(s.score_requests, (SAMPLE_CAP + 10) as u64);
    }

    #[test]
    fn recent_rate_reports_window_not_lifetime() {
        let m = ServeMetrics::default();
        for _ in 0..50 {
            m.record_score(2, Duration::from_micros(10));
        }
        std::thread::sleep(Duration::from_millis(600));
        let s = m.snapshot();
        // 100 rows over >= 0.6s of window: a finite, positive rate.
        assert!(s.recent_rows_per_s > 0.0, "{s:?}");
        assert!(s.recent_rows_per_s <= s.rows_per_s * 2.0 + 1.0, "{s:?}");
    }
}
