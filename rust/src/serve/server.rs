//! The model server: a **bounded** scoring queue drained by one or
//! more scorer threads that micro-batch concurrent requests into fused
//! predict calls, per-request deadlines so no client ever hangs on a
//! wedged or dead scorer, an `Arc`-swapped model for hot reload, and
//! transports over TCP or stdio. Everything is plain `std` (threads,
//! channels, condvars).
//!
//! Liveness contract, end to end:
//!
//! * [`Server::enqueue`] refuses work past
//!   [`ServeOpts::max_queue_rows`] immediately (structured
//!   [`ScoreError::Overloaded`]) — the queue cannot grow without
//!   bound, latency degrades by shedding, not by queuing.
//! * The request handler waits on the reply channel with
//!   `recv_timeout(request_timeout)` — a scorer that wedges mid-batch
//!   delays a client by at most the deadline, and a scorer that
//!   *died* is reported as exactly that (the reply channel
//!   disconnects), never mislabelled as a shutdown.
//! * Scorer threads register themselves; when the last one exits
//!   outside shutdown, queued jobs are failed immediately with a
//!   scorer-death error and later enqueues are refused up front.
//! * [`Server::shutdown`] sheds queued jobs with a precise
//!   shutting-down error and [`ServerHandle::shutdown`] joins scorer,
//!   accept *and* connection threads — no thread is abandoned.

use std::collections::VecDeque;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::metrics::{ServeMetrics, ServeSnapshot};
use super::protocol::{self, FrameEvent, Request, Response, ScorePayload};
use super::ServeOpts;
use crate::data::{CsrBlock, Rows};
use crate::estimator::Predictor;
use crate::runtime::Backend;
use crate::{Error, Result};

/// How often an idle connection thread wakes from its socket read to
/// check for shutdown. Small enough that [`ServerHandle::shutdown`]
/// joins connection threads promptly; large enough to cost nothing.
const IDLE_TICK: Duration = Duration::from_millis(100);

/// Why a scoring request was refused or abandoned instead of scored —
/// the structured half of the reply channel, mapped 1:1 onto the
/// tagged wire errors so clients can react without parsing text.
#[derive(Debug, Clone)]
pub enum ScoreError {
    /// Backpressure shed: admitting the request would push the queue
    /// past `max_queue_rows` (or the request alone exceeds the cap).
    Overloaded(String),
    /// The per-request deadline elapsed before any scorer replied.
    TimedOut(String),
    /// The server is shutting down; the job was shed unscored.
    ShuttingDown(String),
    /// Scoring ran and failed (dim mismatch, backend error), or the
    /// scorer serving this job died.
    Failed(String),
}

impl ScoreError {
    /// The wire response this error becomes.
    pub fn into_response(self) -> Response {
        match self {
            ScoreError::Overloaded(m) => Response::Overloaded(m),
            ScoreError::TimedOut(m) => Response::TimedOut(m),
            ScoreError::ShuttingDown(m) => Response::ShuttingDown(m),
            ScoreError::Failed(m) => Response::Error(m),
        }
    }

    /// The message, for in-process callers.
    pub fn message(&self) -> &str {
        match self {
            ScoreError::Overloaded(m)
            | ScoreError::TimedOut(m)
            | ScoreError::ShuttingDown(m)
            | ScoreError::Failed(m) => m,
        }
    }
}

/// What the scorer sends back per job: scores + head count, or a
/// structured error (cheap to clone, so group failures fan out).
type ScoreReply = std::result::Result<(Vec<f32>, usize), ScoreError>;

struct Job {
    payload: ScorePayload,
    resp: mpsc::Sender<ScoreReply>,
}

struct Queue {
    jobs: VecDeque<Job>,
    /// Total rows across `jobs` — the backpressure quantity.
    queued_rows: usize,
    shutdown: bool,
    /// Scorer threads ever started / currently alive. `started > 0 &&
    /// alive == 0` outside shutdown means every scorer died: new work
    /// is refused immediately instead of waiting out its deadline.
    scorers_started: usize,
    scorers_alive: usize,
}

impl Queue {
    fn scorers_dead(&self) -> bool {
        self.scorers_started > 0 && self.scorers_alive == 0 && !self.shutdown
    }
}

/// Fail-and-drop every queued job with `err`; resets the row count.
fn shed_jobs(q: &mut Queue, err: &ScoreError) {
    for job in q.jobs.drain(..) {
        let _ = job.resp.send(Err(err.clone()));
    }
    q.queued_rows = 0;
}

struct Shared {
    opts: ServeOpts,
    /// The served model. Readers (`Server::model`) clone the `Arc`;
    /// [`Server::reload`] swaps it under the write lock, so in-flight
    /// batches finish on the store they started with.
    model: RwLock<Arc<Predictor>>,
    /// Where the model came from — what a path-less reload re-reads.
    model_path: Mutex<PathBuf>,
    queue: Mutex<Queue>,
    cv: Condvar,
    metrics: ServeMetrics,
}

/// Lock acquisition that survives poisoning: a scorer- or
/// connection-thread panic must not wedge every other request, so a
/// poisoned lock yields its guard and serving continues on whatever
/// state the panicking thread left behind (all protected state here —
/// queue, model `Arc`, path — stays structurally valid mid-update).
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn read_unpoisoned<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| e.into_inner())
}

fn write_unpoisoned<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|e| e.into_inner())
}

/// Handle on a running (or startable) server. Cheap to clone; all
/// clones share one queue, model and metrics.
#[derive(Clone)]
pub struct Server {
    shared: Arc<Shared>,
}

impl Server {
    /// Load the model through the sniffing
    /// [`Predictor::load_file`] and build an idle server around it
    /// (no threads yet — see [`Server::spawn_scorers`] /
    /// [`Server::spawn_tcp`]).
    pub fn new(model_path: impl Into<PathBuf>, opts: ServeOpts) -> Result<Server> {
        let model_path = model_path.into();
        let model = Arc::new(Predictor::load_file(&model_path)?);
        Ok(Server {
            shared: Arc::new(Shared {
                opts,
                model: RwLock::new(model),
                model_path: Mutex::new(model_path),
                queue: Mutex::new(Queue {
                    jobs: VecDeque::new(),
                    queued_rows: 0,
                    shutdown: false,
                    scorers_started: 0,
                    scorers_alive: 0,
                }),
                cv: Condvar::new(),
                metrics: ServeMetrics::default(),
            }),
        })
    }

    /// The currently served model (an `Arc` clone — stable for the
    /// caller's lifetime even across reloads).
    pub fn model(&self) -> Arc<Predictor> {
        read_unpoisoned(&self.shared.model).clone()
    }

    /// One-line model description for logs and reload summaries.
    pub fn describe_model(&self) -> String {
        let m = self.model();
        format!(
            "family={} d={} n_expansion={} classes={}",
            m.family(),
            m.dim(),
            m.n_expansion(),
            m.n_classes()
        )
    }

    /// Point-in-time metrics.
    pub fn metrics_snapshot(&self) -> ServeSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Hot-reload the model: load the new file completely (any
    /// family, sniffed), then atomically swap it in. In-flight
    /// batches hold their own `Arc` and finish on the old expansion
    /// store; requests enqueued after the swap score the new one. On
    /// error the old model keeps serving.
    pub fn reload(&self, path: Option<&str>) -> Result<String> {
        let new_path = match path {
            Some(p) if !p.is_empty() => PathBuf::from(p),
            _ => lock_unpoisoned(&self.shared.model_path).clone(),
        };
        let model = Arc::new(Predictor::load_file(&new_path)?);
        let summary = format!(
            "reloaded {}: family={} d={} n_expansion={} classes={}",
            new_path.display(),
            model.family(),
            model.dim(),
            model.n_expansion(),
            model.n_classes()
        );
        *write_unpoisoned(&self.shared.model) = model;
        *lock_unpoisoned(&self.shared.model_path) = new_path;
        self.shared.metrics.record_reload();
        Ok(summary)
    }

    /// Queue rows for scoring. `Ok(rx)` delivers the reply once a
    /// scorer's batch containing the job completes; `Err` is an
    /// *immediate* structured refusal — shutdown, every scorer dead,
    /// or backpressure (the queue cap would be exceeded). Refusals
    /// never enqueue, so the queued-row total provably never passes
    /// [`ServeOpts::max_queue_rows`].
    pub fn enqueue(
        &self,
        payload: ScorePayload,
    ) -> std::result::Result<mpsc::Receiver<ScoreReply>, ScoreError> {
        let rows = payload.len();
        let mut q = lock_unpoisoned(&self.shared.queue);
        if q.shutdown {
            return Err(ScoreError::ShuttingDown(
                "server is shutting down — request refused before scoring".into(),
            ));
        }
        if q.scorers_dead() {
            return Err(ScoreError::Failed(
                "every scorer thread has died — the server cannot score; restart it".into(),
            ));
        }
        let cap = self.shared.opts.max_queue_rows;
        if cap > 0 && q.queued_rows + rows > cap {
            return Err(ScoreError::Overloaded(format!(
                "queue full: {} rows queued + {} requested exceeds the {} row cap \
                 (--max-queue-rows) — retry later",
                q.queued_rows, rows, cap
            )));
        }
        let (tx, rx) = mpsc::channel();
        q.jobs.push_back(Job { payload, resp: tx });
        q.queued_rows += rows;
        drop(q);
        self.shared.cv.notify_one();
        Ok(rx)
    }

    /// Stop accepting work: queued jobs are shed with a precise
    /// shutting-down error (not silently scored or dropped), future
    /// enqueues are refused, and every scorer is woken so it exits.
    pub fn shutdown(&self) {
        let mut q = lock_unpoisoned(&self.shared.queue);
        q.shutdown = true;
        shed_jobs(
            &mut q,
            &ScoreError::ShuttingDown(
                "server is shutting down — queued request shed before scoring".into(),
            ),
        );
        drop(q);
        self.shared.cv.notify_all();
    }

    /// True once [`Server::shutdown`] ran.
    pub fn is_shutdown(&self) -> bool {
        lock_unpoisoned(&self.shared.queue).shutdown
    }

    /// Start one scorer thread. It instantiates its own backend from
    /// [`ServeOpts::backend`] (PJRT clients are not `Send`, so the
    /// spec crosses the thread boundary, not the backend), then loops:
    /// drain a micro-batch, score it fused, reply per request. The
    /// thread is registered *before* spawn returns, so scorer-death
    /// detection never races a fresh spawn.
    pub fn spawn_scorer(&self) -> JoinHandle<()> {
        {
            let mut q = lock_unpoisoned(&self.shared.queue);
            q.scorers_started += 1;
            q.scorers_alive += 1;
        }
        let shared = Arc::clone(&self.shared);
        std::thread::spawn(move || {
            // The guard marks this scorer dead on ANY exit — normal
            // return or unwind — and fails queued jobs when the last
            // scorer dies outside shutdown.
            let guard = ScorerGuard { shared };
            scorer_loop(&guard.shared);
        })
    }

    /// Start [`ServeOpts::scorer_threads`] scorer threads (0 starts
    /// none — the caller manages scoring). Scores for a fixed model
    /// are identical for any thread count: each row is scored by one
    /// worker via the same fused kernels, and per-row results are
    /// independent of which worker (and which batch) carried them.
    pub fn spawn_scorers(&self) -> Vec<JoinHandle<()>> {
        (0..self.shared.opts.scorer_threads)
            .map(|_| self.spawn_scorer())
            .collect()
    }

    /// Bind `addr` (e.g. `127.0.0.1:7878`; port 0 picks a free port),
    /// start the scorer and accept threads, and return a handle
    /// carrying the bound address.
    pub fn spawn_tcp(&self, addr: &str) -> Result<ServerHandle> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::invalid(format!("cannot bind '{addr}': {e}")))?;
        let bound = listener.local_addr()?;
        let scorers = self.spawn_scorers();
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept_server = self.clone();
        let accept_conns = Arc::clone(&conns);
        let accept = std::thread::spawn(move || accept_loop(accept_server, listener, accept_conns));
        Ok(ServerHandle {
            server: self.clone(),
            addr: bound,
            scorers,
            accept: Some(accept),
            conns,
        })
    }

    /// Serve one connection over the process's stdin/stdout — the
    /// pipe-driven mode (`dsekl serve --stdio`). The caller should
    /// spawn the scorers first; returns at EOF.
    pub fn serve_stdio(&self) -> Result<()> {
        let stdin = std::io::stdin();
        let stdout = std::io::stdout();
        let mut r = stdin.lock();
        let mut w = stdout.lock();
        serve_connection(self, &mut r, &mut w)
    }
}

/// RAII registration of one scorer thread: decrements the live count
/// on drop (normal exit *or* panic unwind). When the last scorer dies
/// outside shutdown, queued jobs are failed right away — their clients
/// get an accurate "scorer died" error instead of waiting out the
/// deadline against a queue nobody will ever drain.
struct ScorerGuard {
    shared: Arc<Shared>,
}

impl Drop for ScorerGuard {
    fn drop(&mut self) {
        let mut q = lock_unpoisoned(&self.shared.queue);
        q.scorers_alive = q.scorers_alive.saturating_sub(1);
        if q.scorers_dead() {
            shed_jobs(
                &mut q,
                &ScoreError::Failed(
                    "the scorer thread died before scoring this request — restart the server"
                        .into(),
                ),
            );
        }
    }
}

/// A running TCP server: bound address plus the scorer/accept threads
/// and every live connection thread (tracked so shutdown joins them
/// instead of abandoning them).
pub struct ServerHandle {
    server: Server,
    addr: SocketAddr,
    scorers: Vec<JoinHandle<()>>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl ServerHandle {
    /// The bound address (resolves port 0 to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The shared server handle (for reload / metrics from the
    /// hosting process).
    pub fn server(&self) -> &Server {
        &self.server
    }

    /// Run in the foreground: block until the accept loop exits
    /// (effectively until the process is killed) — the CLI's TCP mode.
    pub fn join(mut self) {
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
        self.server.shutdown();
        self.join_workers();
    }

    /// Graceful drain: flag shutdown (shedding queued jobs with a
    /// precise error), wake the accept loop with a dummy connection,
    /// and join the accept, scorer *and* connection threads.
    /// Connection threads notice shutdown within one idle tick
    /// (100 ms) of going quiet, so this returns promptly.
    pub fn shutdown(mut self) {
        self.server.shutdown();
        // The accept loop blocks in accept(); poke it awake.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
        self.join_workers();
    }

    fn join_workers(&mut self) {
        for t in self.scorers.drain(..) {
            let _ = t.join();
        }
        let conns: Vec<JoinHandle<()>> = {
            let mut guard = lock_unpoisoned(&self.conns);
            guard.drain(..).collect()
        };
        for t in conns {
            let _ = t.join();
        }
    }
}

fn accept_loop(server: Server, listener: TcpListener, conns: Arc<Mutex<Vec<JoinHandle<()>>>>) {
    for conn in listener.incoming() {
        if server.is_shutdown() {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        // Socket deadlines: reads wake every IDLE_TICK (to notice
        // shutdown; mid-frame stalls are bounded separately by
        // request_timeout inside read_frame_deadline), and a write to
        // a client that stopped reading fails after request_timeout
        // instead of pinning the thread forever.
        let _ = stream.set_read_timeout(Some(IDLE_TICK));
        let _ = stream.set_write_timeout(Some(server.shared.opts.request_timeout.max(IDLE_TICK)));
        let per_conn = server.clone();
        let handle = std::thread::spawn(move || {
            let reader = match stream.try_clone() {
                Ok(s) => s,
                Err(_) => return,
            };
            let mut r = BufReader::new(reader);
            let mut w = BufWriter::new(stream);
            let _ = serve_connection(&per_conn, &mut r, &mut w);
        });
        lock_unpoisoned(&conns).push(handle);
    }
}

/// Serve one framed request/response stream until the peer closes
/// (clean EOF), shutdown is observed between frames, or a
/// transport/framing error — including a peer stalled mid-frame past
/// the request deadline — ends the connection. Decode errors inside a
/// well-framed message are answered with an error response and the
/// connection stays up.
pub fn serve_connection<R: Read, W: Write>(server: &Server, r: &mut R, w: &mut W) -> Result<()> {
    let stall = server.shared.opts.request_timeout.max(IDLE_TICK);
    loop {
        let payload = match protocol::read_frame_deadline(r, stall)? {
            FrameEvent::Payload(p) => p,
            FrameEvent::Eof => return Ok(()),
            FrameEvent::Idle => {
                if server.is_shutdown() {
                    return Ok(());
                }
                continue;
            }
        };
        let resp = match protocol::decode_request(&payload) {
            Ok(req) => handle_request(server, req),
            Err(e) => {
                server.shared.metrics.record_error();
                Response::Error(e.to_string())
            }
        };
        protocol::write_frame(w, &protocol::encode_response(&resp))?;
        w.flush()?;
    }
}

fn handle_request(server: &Server, req: Request) -> Response {
    let metrics = &server.shared.metrics;
    match req {
        Request::Ping => {
            metrics.record_control();
            Response::Pong
        }
        Request::Stats => {
            metrics.record_control();
            Response::Text(server.metrics_snapshot().render())
        }
        Request::Reload(path) => match server.reload(path.as_deref()) {
            Ok(summary) => Response::Text(summary),
            Err(e) => {
                metrics.record_error();
                Response::Error(e.to_string())
            }
        },
        Request::Score(payload) => {
            let t0 = Instant::now();
            let rows = payload.len();
            let rx = match server.enqueue(payload) {
                Ok(rx) => rx,
                Err(err) => {
                    match &err {
                        ScoreError::Overloaded(_) | ScoreError::ShuttingDown(_) => {
                            metrics.record_shed()
                        }
                        _ => metrics.record_error(),
                    }
                    return err.into_response();
                }
            };
            let deadline = server.shared.opts.request_timeout;
            match rx.recv_timeout(deadline) {
                Ok(Ok((scores, k))) => {
                    metrics.record_score(rows, t0.elapsed());
                    Response::Scores { k, scores }
                }
                Ok(Err(err)) => {
                    match &err {
                        ScoreError::Overloaded(_) | ScoreError::ShuttingDown(_) => {
                            metrics.record_shed()
                        }
                        ScoreError::TimedOut(_) => metrics.record_timeout(),
                        ScoreError::Failed(_) => metrics.record_error(),
                    }
                    err.into_response()
                }
                Err(RecvTimeoutError::Timeout) => {
                    metrics.record_timeout();
                    Response::TimedOut(format!(
                        "no result within the {} ms deadline (--request-timeout-ms) — \
                         the scorer is wedged or the queue is draining too slowly",
                        deadline.as_millis()
                    ))
                }
                // The reply sender was dropped without an answer: the
                // scorer thread died mid-batch. Distinct from shutdown
                // (which sends an explicit shed error before dropping).
                Err(RecvTimeoutError::Disconnected) => {
                    metrics.record_error();
                    Response::Error(
                        "the scorer thread died while this request was in flight — \
                         restart the server"
                            .into(),
                    )
                }
            }
        }
    }
}

fn scorer_loop(shared: &Arc<Shared>) {
    let mut backend: Option<Box<dyn Backend>> = None;
    while let Some(batch) = next_batch(shared) {
        if batch.is_empty() {
            continue;
        }
        if backend.is_none() {
            match shared.opts.backend.instantiate() {
                Ok(b) => backend = Some(b),
                Err(e) => {
                    let err = ScoreError::Failed(e.to_string());
                    for job in batch {
                        let _ = job.resp.send(Err(err.clone()));
                    }
                    continue;
                }
            }
        }
        let model = read_unpoisoned(&shared.model).clone();
        let be = match backend.as_mut() {
            Some(b) => b.as_mut(),
            None => continue,
        };
        score_batch(shared, be, &model, batch);
    }
}

/// Drain the next micro-batch: block for the first job, then linger up
/// to `max_wait` for more, stopping early once `max_batch_rows` is
/// reached. Returns `None` when the server shut down (the shutdown
/// path has already shed whatever was queued, so there is nothing to
/// drain). Safe under any number of concurrent scorer threads: the
/// queue lock serialises draining, each job is popped exactly once.
fn next_batch(shared: &Shared) -> Option<Vec<Job>> {
    let mut q = lock_unpoisoned(&shared.queue);
    loop {
        if q.shutdown {
            // Defensive: shutdown sheds under the same lock, so the
            // queue should already be empty — make it true regardless.
            shed_jobs(
                &mut q,
                &ScoreError::ShuttingDown(
                    "server is shutting down — queued request shed before scoring".into(),
                ),
            );
            return None;
        }
        if !q.jobs.is_empty() {
            break;
        }
        q = shared.cv.wait(q).unwrap_or_else(|e| e.into_inner());
    }
    let cap = shared.opts.max_batch_rows.max(1);
    let deadline = Instant::now() + shared.opts.max_wait;
    let mut batch = Vec::new();
    let mut rows = 0usize;
    loop {
        while let Some(job_rows) = q.jobs.front().map(|j| j.payload.len()) {
            // The first job always goes through whole, even when it is
            // larger than the cap by itself (score_batch then scores
            // it in row chunks of at most the cap).
            if !batch.is_empty() && rows + job_rows > cap {
                break;
            }
            if let Some(job) = q.jobs.pop_front() {
                q.queued_rows = q.queued_rows.saturating_sub(job_rows);
                batch.push(job);
                rows += job_rows;
            }
            if rows >= cap {
                break;
            }
        }
        if rows >= cap || q.shutdown {
            break;
        }
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        let (guard, timeout) = shared
            .cv
            .wait_timeout(q, deadline - now)
            .unwrap_or_else(|e| e.into_inner());
        q = guard;
        if timeout.timed_out() && q.jobs.is_empty() {
            break;
        }
    }
    Some(batch)
}

/// Score one drained batch: record the drain once, group jobs by
/// (layout, dimensionality), run one fused scoring pass per group,
/// split the score matrix back per request. A group that fails (e.g.
/// dims mismatching the model) errors only its own jobs.
fn score_batch(shared: &Shared, backend: &mut dyn Backend, model: &Predictor, batch: Vec<Job>) {
    let total_rows: usize = batch.iter().map(|j| j.payload.len()).sum();
    shared.metrics.record_drain(total_rows, batch.len());
    let mut groups: Vec<((bool, usize), Vec<Job>)> = Vec::new();
    for job in batch {
        let key = (job.payload.is_csr(), job.payload.dim());
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, jobs)) => jobs.push(job),
            None => groups.push((key, vec![job])),
        }
    }
    for (_, jobs) in groups {
        score_group(shared, backend, model, jobs);
    }
}

fn score_group(shared: &Shared, backend: &mut dyn Backend, model: &Predictor, jobs: Vec<Job>) {
    shared.metrics.record_group();
    let result = fused_scores(shared, backend, model, &jobs);
    match result {
        Ok((scores, k)) => {
            let mut offset = 0usize;
            for job in &jobs {
                let n = job.payload.len();
                match scores.get(offset * k..(offset + n) * k) {
                    Some(part) => {
                        let _ = job.resp.send(Ok((part.to_vec(), k)));
                    }
                    None => {
                        let _ = job.resp.send(Err(ScoreError::Failed(
                            "score matrix shorter than the batch".into(),
                        )));
                    }
                }
                offset += n;
            }
        }
        Err(e) => {
            let err = ScoreError::Failed(e.to_string());
            for job in &jobs {
                let _ = job.resp.send(Err(err.clone()));
            }
        }
    }
}

/// One fused scoring pass over every row of `jobs` (all the same
/// layout and dimensionality): single requests score zero-copy,
/// coalesced groups concatenate rows first — one kernel block serves
/// all heads and all requests. A single job larger than
/// `max_batch_rows` (the only way a drain exceeds the cap — see
/// [`next_batch`]) is scored in row chunks of at most the cap, so one
/// huge request cannot blow up scorer memory; chunk boundaries depend
/// only on the cap, never on thread count, keeping scores identical
/// for any `scorer_threads`.
fn fused_scores(
    shared: &Shared,
    backend: &mut dyn Backend,
    model: &Predictor,
    jobs: &[Job],
) -> Result<(Vec<f32>, usize)> {
    let (first, tail) = match jobs.split_first() {
        Some(p) => p,
        None => return Err(Error::invalid("empty scoring group")),
    };
    let cap = shared.opts.max_batch_rows.max(1);
    if tail.is_empty() {
        if first.payload.len() > cap {
            return chunked_scores(backend, model, first.payload.rows(), cap);
        }
        return model.scores_rows(backend, first.payload.rows());
    }
    match &first.payload {
        ScorePayload::Dense { d, .. } => {
            let d = *d;
            let mut n = 0usize;
            let mut x = Vec::new();
            for job in jobs {
                match &job.payload {
                    ScorePayload::Dense { n: jn, x: jx, .. } => {
                        n += jn;
                        x.extend_from_slice(jx);
                    }
                    ScorePayload::Csr(_) => {
                        return Err(Error::invalid("mixed-layout scoring group"))
                    }
                }
            }
            model.scores_rows(backend, Rows::dense(&x, n, d))
        }
        ScorePayload::Csr(first_block) => {
            let d = first_block.dim();
            let mut indptr = vec![0usize];
            let mut indices = Vec::new();
            let mut values = Vec::new();
            for job in jobs {
                match &job.payload {
                    ScorePayload::Csr(b) => {
                        let base = values.len();
                        indptr.extend(b.indptr().iter().skip(1).map(|p| base + p));
                        indices.extend_from_slice(b.indices());
                        values.extend_from_slice(b.values());
                    }
                    ScorePayload::Dense { .. } => {
                        return Err(Error::invalid("mixed-layout scoring group"))
                    }
                }
            }
            let block = CsrBlock::from_parts(indptr, indices, values, d)?;
            model.scores_rows(backend, Rows::Csr(block.view()))
        }
    }
}

/// Score `rows` in chunks of at most `cap` rows and concatenate the
/// `[n, k]` score matrix. Per-row scores are independent of chunking
/// (each row's kernel contraction touches only that row), so the
/// result is bitwise the chunk-free pass with bounded peak memory.
fn chunked_scores(
    backend: &mut dyn Backend,
    model: &Predictor,
    rows: Rows<'_>,
    cap: usize,
) -> Result<(Vec<f32>, usize)> {
    let n = rows.len();
    let mut out: Vec<f32> = Vec::new();
    let mut k_out = 1usize;
    let mut r0 = 0usize;
    while r0 < n {
        let r1 = (r0 + cap).min(n);
        let (scores, k) = model.scores_rows(backend, rows.slice(r0, r1))?;
        k_out = k;
        out.extend_from_slice(&scores);
        r0 = r1;
    }
    Ok((out, k_out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::estimator::{Fit, FitBackend, TrainSet};
    use crate::rng::Pcg64;
    use std::time::Duration;

    fn trained_model_file(dir: &std::path::Path, name: &str) -> (PathBuf, crate::data::Dataset) {
        let mut rng = Pcg64::seed_from(41);
        let ds = synth::xor(120, 0.2, &mut rng);
        let mut backend = FitBackend::native();
        let fitted = Fit::dsekl()
            .gamma(1.0)
            .sizes(16, 16)
            .iters(120)
            .fit(&mut backend, TrainSet::from(&ds), &mut rng)
            .expect("training");
        let path = dir.join(name);
        fitted.predictor.save_file(&path).expect("save");
        (path, ds)
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dsekl-serve-unit-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("tmpdir");
        dir
    }

    fn one_row(ds: &crate::data::Dataset, i: usize) -> ScorePayload {
        ScorePayload::Dense {
            n: 1,
            d: ds.d,
            x: ds.x[i * ds.d..(i + 1) * ds.d].to_vec(),
        }
    }

    #[test]
    fn queued_jobs_coalesce_into_one_fused_batch() {
        let dir = tmpdir("batch");
        let (path, ds) = trained_model_file(&dir, "m.dsekl");
        let opts = ServeOpts {
            max_wait: Duration::from_millis(0),
            ..Default::default()
        };
        let server = Server::new(&path, opts).expect("server");
        // Enqueue 5 requests BEFORE the scorer starts: one drain must
        // coalesce them into a single fused pass.
        let receivers: Vec<_> = (0..5)
            .map(|i| server.enqueue(one_row(&ds, i)).expect("enqueue"))
            .collect();
        let scorer = server.spawn_scorer();
        let mut fused = Vec::new();
        for rx in receivers {
            let (scores, k) = rx.recv().expect("reply").expect("scores");
            assert_eq!(k, 1);
            assert_eq!(scores.len(), 1);
            fused.push(scores[0]);
        }
        let snap = server.metrics_snapshot();
        assert_eq!(snap.batches, 1, "expected one drain, got {snap:?}");
        assert_eq!(snap.fused_groups, 1, "uniform layout: one fused pass");
        assert_eq!(snap.max_batch_requests, 5);
        assert_eq!(snap.max_batch_rows, 5);
        // Fused scores equal the model scored directly.
        let model = server.model();
        let mut be = FitBackend::native();
        let (direct, _) = model
            .scores_rows(
                be.leader().expect("backend"),
                Rows::dense(&ds.x[..5 * ds.d], 5, ds.d),
            )
            .expect("direct scores");
        assert_eq!(fused, direct, "fused batch diverged from direct scoring");
        server.shutdown();
        scorer.join().expect("scorer join");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dim_mismatch_errors_cleanly_and_server_survives() {
        let dir = tmpdir("dims");
        let (path, ds) = trained_model_file(&dir, "m.dsekl");
        let server = Server::new(&path, ServeOpts::default()).expect("server");
        let scorer = server.spawn_scorer();
        let bad = server
            .enqueue(ScorePayload::Dense {
                n: 1,
                d: 7,
                x: vec![0.0; 7],
            })
            .expect("enqueue");
        let err = bad.recv().expect("reply").expect_err("dim mismatch");
        assert!(err.message().contains("dim"), "{err:?}");
        // Good requests still work after the failed group.
        let good = server.enqueue(one_row(&ds, 0)).expect("enqueue");
        assert!(good.recv().expect("reply").is_ok());
        server.shutdown();
        scorer.join().expect("scorer join");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reload_swaps_model_and_keeps_old_arcs_alive() {
        let dir = tmpdir("reload");
        let (path_a, _) = trained_model_file(&dir, "a.dsekl");
        // A second, different model file.
        let mut rng = Pcg64::seed_from(99);
        let ds2 = synth::blobs(80, 3, 4.0, &mut rng);
        let mut backend = FitBackend::native();
        let fitted = Fit::dsekl()
            .gamma(0.5)
            .sizes(8, 8)
            .iters(60)
            .fit(&mut backend, TrainSet::from(&ds2), &mut rng)
            .expect("training");
        let path_b = dir.join("b.dsekl");
        fitted.predictor.save_file(&path_b).expect("save");

        let server = Server::new(&path_a, ServeOpts::default()).expect("server");
        let before = server.model();
        assert_eq!(before.dim(), 2);
        let summary = server
            .reload(Some(path_b.to_str().expect("utf8 path")))
            .expect("reload");
        assert!(summary.contains("family=kernel"), "{summary}");
        assert_eq!(server.model().dim(), 3, "new model not swapped in");
        // The old Arc survives for in-flight use.
        assert_eq!(before.dim(), 2);
        assert_eq!(server.metrics_snapshot().reloads, 1);
        // A failed reload keeps the current model serving.
        assert!(server.reload(Some("/nonexistent/x.dsekl")).is_err());
        assert_eq!(server.model().dim(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn enqueue_past_queue_cap_sheds_immediately_and_cap_is_never_exceeded() {
        let dir = tmpdir("overload");
        let (path, ds) = trained_model_file(&dir, "m.dsekl");
        let opts = ServeOpts {
            max_queue_rows: 4,
            ..Default::default()
        };
        // No scorer: the queue can only drain by shedding, so the cap
        // is exercised deterministically.
        let server = Server::new(&path, opts).expect("server");
        let mut pending = Vec::new();
        for i in 0..4 {
            pending.push(server.enqueue(one_row(&ds, i)).expect("under the cap"));
            let q = lock_unpoisoned(&server.shared.queue);
            assert!(q.queued_rows <= 4, "cap exceeded: {} rows", q.queued_rows);
        }
        // The 5th row is refused immediately — no channel, no waiting.
        let t0 = Instant::now();
        let err = server.enqueue(one_row(&ds, 4)).expect_err("past the cap");
        assert!(t0.elapsed() < Duration::from_millis(100), "shed was not immediate");
        match &err {
            ScoreError::Overloaded(msg) => {
                assert!(msg.contains("max-queue-rows"), "{msg}");
                assert!(msg.contains("4"), "{msg}");
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
        // A single request larger than the whole cap is refused too.
        let big = ScorePayload::Dense {
            n: 8,
            d: ds.d,
            x: ds.x[..8 * ds.d].to_vec(),
        };
        // Drain the queue first so it is the only candidate.
        server.shutdown();
        for rx in pending {
            match rx.recv().expect("shed reply") {
                Err(ScoreError::ShuttingDown(msg)) => {
                    assert!(msg.contains("shutting down"), "{msg}")
                }
                other => panic!("expected ShuttingDown, got {other:?}"),
            }
        }
        match server.enqueue(big).expect_err("after shutdown") {
            ScoreError::ShuttingDown(_) => {}
            other => panic!("expected ShuttingDown, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn oversized_single_request_is_shed_when_it_exceeds_the_cap() {
        let dir = tmpdir("oversize");
        let (path, ds) = trained_model_file(&dir, "m.dsekl");
        let opts = ServeOpts {
            max_queue_rows: 4,
            ..Default::default()
        };
        let server = Server::new(&path, opts).expect("server");
        let big = ScorePayload::Dense {
            n: 8,
            d: ds.d,
            x: ds.x[..8 * ds.d].to_vec(),
        };
        match server.enqueue(big).expect_err("oversized") {
            ScoreError::Overloaded(msg) => assert!(msg.contains("8 requested"), "{msg}"),
            other => panic!("expected Overloaded, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dead_scorer_fails_queued_and_future_jobs_with_an_accurate_error() {
        let dir = tmpdir("deadscorer");
        let (path, ds) = trained_model_file(&dir, "m.dsekl");
        let server = Server::new(&path, ServeOpts::default()).expect("server");
        // Register a scorer the way spawn_scorer does, then kill it
        // with a panic while a job is queued: the drop guard must fail
        // the queued job immediately and accurately.
        {
            let mut q = lock_unpoisoned(&server.shared.queue);
            q.scorers_started += 1;
            q.scorers_alive += 1;
        }
        let rx = server.enqueue(one_row(&ds, 0)).expect("enqueue");
        let shared = Arc::clone(&server.shared);
        let t0 = Instant::now();
        let dying = std::thread::spawn(move || {
            let _guard = ScorerGuard { shared };
            panic!("simulated scorer death");
        });
        assert!(dying.join().is_err(), "the fake scorer must panic");
        // The queued job fails promptly — no deadline wait, no hang —
        // and names the scorer death, not a shutdown.
        match rx.recv().expect("reply channel live") {
            Err(ScoreError::Failed(msg)) => {
                assert!(msg.contains("scorer"), "{msg}");
                assert!(msg.contains("died"), "{msg}");
                assert!(!msg.contains("shutting down"), "mislabelled as shutdown: {msg}");
            }
            other => panic!("expected Failed(scorer died), got {other:?}"),
        }
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "dead-scorer error was not timely"
        );
        // New work is refused up front with the same diagnosis.
        match server.enqueue(one_row(&ds, 1)).expect_err("scorer dead") {
            ScoreError::Failed(msg) => assert!(msg.contains("died"), "{msg}"),
            other => panic!("expected Failed, got {other:?}"),
        }
        // A fresh scorer resurrects the server.
        let scorer = server.spawn_scorer();
        let rx = server.enqueue(one_row(&ds, 2)).expect("alive again");
        assert!(rx.recv().expect("reply").is_ok());
        server.shutdown();
        scorer.join().expect("scorer join");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn oversized_job_is_scored_in_chunks_bitwise_equal_to_direct() {
        let dir = tmpdir("chunks");
        let (path, ds) = trained_model_file(&dir, "m.dsekl");
        let opts = ServeOpts {
            max_batch_rows: 8,
            max_queue_rows: 0, // uncapped queue: the batch cap is under test
            max_wait: Duration::from_millis(0),
            ..Default::default()
        };
        let server = Server::new(&path, opts).expect("server");
        let n = 20;
        let rx = server
            .enqueue(ScorePayload::Dense {
                n,
                d: ds.d,
                x: ds.x[..n * ds.d].to_vec(),
            })
            .expect("enqueue");
        let scorer = server.spawn_scorer();
        let (scores, k) = rx.recv().expect("reply").expect("scores");
        assert_eq!(k, 1);
        assert_eq!(scores.len(), n);
        let model = server.model();
        let mut be = FitBackend::native();
        let (direct, _) = model
            .scores_rows(
                be.leader().expect("backend"),
                Rows::dense(&ds.x[..n * ds.d], n, ds.d),
            )
            .expect("direct");
        assert_eq!(scores, direct, "chunked scoring diverged from direct");
        server.shutdown();
        scorer.join().expect("scorer join");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scores_are_identical_for_any_scorer_thread_count() {
        let dir = tmpdir("nscorers");
        let (path, ds) = trained_model_file(&dir, "m.dsekl");
        let n_requests = 12;
        let mut per_config: Vec<Vec<f32>> = Vec::new();
        for threads in [1usize, 2, 4] {
            let opts = ServeOpts {
                scorer_threads: threads,
                max_wait: Duration::from_millis(0),
                ..Default::default()
            };
            let server = Server::new(&path, opts).expect("server");
            // Enqueue before spawning so multiple workers race to
            // drain a non-empty queue.
            let receivers: Vec<_> = (0..n_requests)
                .map(|i| server.enqueue(one_row(&ds, i)).expect("enqueue"))
                .collect();
            let scorers = server.spawn_scorers();
            assert_eq!(scorers.len(), threads);
            let scores: Vec<f32> = receivers
                .into_iter()
                .map(|rx| {
                    let (s, k) = rx.recv().expect("reply").expect("scores");
                    assert_eq!(k, 1);
                    s[0]
                })
                .collect();
            server.shutdown();
            for t in scorers {
                t.join().expect("scorer join");
            }
            per_config.push(scores);
        }
        assert_eq!(per_config[0], per_config[1], "1 vs 2 scorers diverged");
        assert_eq!(per_config[0], per_config[2], "1 vs 4 scorers diverged");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
