//! The model server: a scoring queue drained by one scorer thread
//! that micro-batches concurrent requests into fused predict calls,
//! an `Arc`-swapped model for hot reload, and transports over TCP or
//! stdio. Everything is plain `std` (threads, channels, condvars).

use std::collections::VecDeque;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::thread::JoinHandle;
use std::time::Instant;

use super::metrics::{ServeMetrics, ServeSnapshot};
use super::protocol::{self, Request, Response, ScorePayload};
use super::ServeOpts;
use crate::data::{CsrBlock, Rows};
use crate::estimator::Predictor;
use crate::runtime::Backend;
use crate::{Error, Result};

/// What the scorer sends back per job: scores + head count, or an
/// error message (a `String`, so group failures fan out cheaply).
type ScoreReply = std::result::Result<(Vec<f32>, usize), String>;

struct Job {
    payload: ScorePayload,
    resp: mpsc::Sender<ScoreReply>,
}

struct Queue {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    opts: ServeOpts,
    /// The served model. Readers (`Server::model`) clone the `Arc`;
    /// [`Server::reload`] swaps it under the write lock, so in-flight
    /// batches finish on the store they started with.
    model: RwLock<Arc<Predictor>>,
    /// Where the model came from — what a path-less reload re-reads.
    model_path: Mutex<PathBuf>,
    queue: Mutex<Queue>,
    cv: Condvar,
    metrics: ServeMetrics,
}

/// Lock acquisition that survives poisoning: a scorer- or
/// connection-thread panic must not wedge every other request, so a
/// poisoned lock yields its guard and serving continues on whatever
/// state the panicking thread left behind (all protected state here —
/// queue, model `Arc`, path — stays structurally valid mid-update).
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn read_unpoisoned<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| e.into_inner())
}

fn write_unpoisoned<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|e| e.into_inner())
}

/// Handle on a running (or startable) server. Cheap to clone; all
/// clones share one queue, model and metrics.
#[derive(Clone)]
pub struct Server {
    shared: Arc<Shared>,
}

impl Server {
    /// Load the model through the sniffing
    /// [`Predictor::load_file`] and build an idle server around it
    /// (no threads yet — see [`Server::spawn_scorer`] /
    /// [`Server::spawn_tcp`]).
    pub fn new(model_path: impl Into<PathBuf>, opts: ServeOpts) -> Result<Server> {
        let model_path = model_path.into();
        let model = Arc::new(Predictor::load_file(&model_path)?);
        Ok(Server {
            shared: Arc::new(Shared {
                opts,
                model: RwLock::new(model),
                model_path: Mutex::new(model_path),
                queue: Mutex::new(Queue {
                    jobs: VecDeque::new(),
                    shutdown: false,
                }),
                cv: Condvar::new(),
                metrics: ServeMetrics::default(),
            }),
        })
    }

    /// The currently served model (an `Arc` clone — stable for the
    /// caller's lifetime even across reloads).
    pub fn model(&self) -> Arc<Predictor> {
        read_unpoisoned(&self.shared.model).clone()
    }

    /// One-line model description for logs and reload summaries.
    pub fn describe_model(&self) -> String {
        let m = self.model();
        format!(
            "family={} d={} n_expansion={} classes={}",
            m.family(),
            m.dim(),
            m.n_expansion(),
            m.n_classes()
        )
    }

    /// Point-in-time metrics.
    pub fn metrics_snapshot(&self) -> ServeSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Hot-reload the model: load the new file completely (any
    /// family, sniffed), then atomically swap it in. In-flight
    /// batches hold their own `Arc` and finish on the old expansion
    /// store; requests enqueued after the swap score the new one. On
    /// error the old model keeps serving.
    pub fn reload(&self, path: Option<&str>) -> Result<String> {
        let new_path = match path {
            Some(p) if !p.is_empty() => PathBuf::from(p),
            _ => lock_unpoisoned(&self.shared.model_path).clone(),
        };
        let model = Arc::new(Predictor::load_file(&new_path)?);
        let summary = format!(
            "reloaded {}: family={} d={} n_expansion={} classes={}",
            new_path.display(),
            model.family(),
            model.dim(),
            model.n_expansion(),
            model.n_classes()
        );
        *write_unpoisoned(&self.shared.model) = model;
        *lock_unpoisoned(&self.shared.model_path) = new_path;
        self.shared.metrics.record_reload();
        Ok(summary)
    }

    /// Queue rows for scoring; the reply arrives on the returned
    /// channel once the scorer's batch containing them completes.
    pub fn enqueue(&self, payload: ScorePayload) -> mpsc::Receiver<ScoreReply> {
        let (tx, rx) = mpsc::channel();
        let mut q = lock_unpoisoned(&self.shared.queue);
        if q.shutdown {
            let _ = tx.send(Err("server is shutting down".into()));
            return rx;
        }
        q.jobs.push_back(Job { payload, resp: tx });
        drop(q);
        self.shared.cv.notify_one();
        rx
    }

    /// Stop accepting work and wake the scorer so it drains the queue
    /// and exits.
    pub fn shutdown(&self) {
        lock_unpoisoned(&self.shared.queue).shutdown = true;
        self.shared.cv.notify_all();
    }

    /// True once [`Server::shutdown`] ran.
    pub fn is_shutdown(&self) -> bool {
        lock_unpoisoned(&self.shared.queue).shutdown
    }

    /// Start the scorer thread. It instantiates its own backend from
    /// [`ServeOpts::backend`] (PJRT clients are not `Send`, so the
    /// spec crosses the thread boundary, not the backend), then loops:
    /// drain a micro-batch, score it fused, reply per request.
    pub fn spawn_scorer(&self) -> JoinHandle<()> {
        let shared = Arc::clone(&self.shared);
        std::thread::spawn(move || scorer_loop(shared))
    }

    /// Bind `addr` (e.g. `127.0.0.1:7878`; port 0 picks a free port),
    /// start the scorer and accept threads, and return a handle
    /// carrying the bound address.
    pub fn spawn_tcp(&self, addr: &str) -> Result<ServerHandle> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::invalid(format!("cannot bind '{addr}': {e}")))?;
        let bound = listener.local_addr()?;
        let scorer = self.spawn_scorer();
        let accept_server = self.clone();
        let accept = std::thread::spawn(move || accept_loop(accept_server, listener));
        Ok(ServerHandle {
            server: self.clone(),
            addr: bound,
            scorer: Some(scorer),
            accept: Some(accept),
        })
    }

    /// Serve one connection over the process's stdin/stdout — the
    /// pipe-driven mode (`dsekl serve --stdio`). The caller should
    /// spawn the scorer first; returns at EOF.
    pub fn serve_stdio(&self) -> Result<()> {
        let stdin = std::io::stdin();
        let stdout = std::io::stdout();
        let mut r = stdin.lock();
        let mut w = stdout.lock();
        serve_connection(self, &mut r, &mut w)
    }
}

/// A running TCP server: bound address plus the scorer/accept threads.
pub struct ServerHandle {
    server: Server,
    addr: SocketAddr,
    scorer: Option<JoinHandle<()>>,
    accept: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound address (resolves port 0 to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The shared server handle (for reload / metrics from the
    /// hosting process).
    pub fn server(&self) -> &Server {
        &self.server
    }

    /// Run in the foreground: block until the accept loop exits
    /// (effectively until the process is killed) — the CLI's TCP mode.
    pub fn join(mut self) {
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
        self.server.shutdown();
        if let Some(t) = self.scorer.take() {
            let _ = t.join();
        }
    }

    /// Flag shutdown, wake the accept loop with a dummy connection,
    /// and join the scorer and accept threads. Connection threads
    /// finish as their clients hang up.
    pub fn shutdown(mut self) {
        self.server.shutdown();
        // The accept loop blocks in accept(); poke it awake.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
        if let Some(t) = self.scorer.take() {
            let _ = t.join();
        }
    }
}

fn accept_loop(server: Server, listener: TcpListener) {
    for conn in listener.incoming() {
        if server.is_shutdown() {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        let per_conn = server.clone();
        std::thread::spawn(move || {
            let reader = match stream.try_clone() {
                Ok(s) => s,
                Err(_) => return,
            };
            let mut r = BufReader::new(reader);
            let mut w = BufWriter::new(stream);
            let _ = serve_connection(&per_conn, &mut r, &mut w);
        });
    }
}

/// Serve one framed request/response stream until the peer closes
/// (clean EOF) or a transport/framing error ends the connection.
/// Decode errors inside a well-framed message are answered with an
/// error response and the connection stays up.
pub fn serve_connection<R: Read, W: Write>(server: &Server, r: &mut R, w: &mut W) -> Result<()> {
    loop {
        let payload = match protocol::read_frame(r)? {
            Some(p) => p,
            None => return Ok(()),
        };
        let resp = match protocol::decode_request(&payload) {
            Ok(req) => handle_request(server, req),
            Err(e) => {
                server.shared.metrics.record_error();
                Response::Error(e.to_string())
            }
        };
        protocol::write_frame(w, &protocol::encode_response(&resp))?;
        w.flush()?;
    }
}

fn handle_request(server: &Server, req: Request) -> Response {
    let metrics = &server.shared.metrics;
    match req {
        Request::Ping => {
            metrics.record_control();
            Response::Pong
        }
        Request::Stats => {
            metrics.record_control();
            Response::Text(server.metrics_snapshot().render())
        }
        Request::Reload(path) => match server.reload(path.as_deref()) {
            Ok(summary) => Response::Text(summary),
            Err(e) => {
                metrics.record_error();
                Response::Error(e.to_string())
            }
        },
        Request::Score(payload) => {
            let t0 = Instant::now();
            let rows = payload.len();
            let rx = server.enqueue(payload);
            match rx.recv() {
                Ok(Ok((scores, k))) => {
                    metrics.record_score(rows, t0.elapsed());
                    Response::Scores { k, scores }
                }
                Ok(Err(msg)) => {
                    metrics.record_error();
                    Response::Error(msg)
                }
                Err(_) => {
                    metrics.record_error();
                    Response::Error("server is shutting down".into())
                }
            }
        }
    }
}

fn scorer_loop(shared: Arc<Shared>) {
    let mut backend: Option<Box<dyn Backend>> = None;
    while let Some(batch) = next_batch(&shared) {
        if batch.is_empty() {
            continue;
        }
        if backend.is_none() {
            match shared.opts.backend.instantiate() {
                Ok(b) => backend = Some(b),
                Err(e) => {
                    let msg = e.to_string();
                    for job in batch {
                        let _ = job.resp.send(Err(msg.clone()));
                    }
                    continue;
                }
            }
        }
        let model = read_unpoisoned(&shared.model).clone();
        let be = match backend.as_mut() {
            Some(b) => b.as_mut(),
            None => continue,
        };
        score_batch(&shared, be, &model, batch);
    }
}

/// Drain the next micro-batch: block for the first job, then linger up
/// to `max_wait` for more, stopping early once `max_batch_rows` is
/// reached. Returns `None` when the server shut down and the queue is
/// empty (in-flight requests drain before exit — reload/shutdown never
/// drops them).
fn next_batch(shared: &Shared) -> Option<Vec<Job>> {
    let mut q = lock_unpoisoned(&shared.queue);
    loop {
        if !q.jobs.is_empty() {
            break;
        }
        if q.shutdown {
            return None;
        }
        q = shared.cv.wait(q).unwrap_or_else(|e| e.into_inner());
    }
    let cap = shared.opts.max_batch_rows.max(1);
    let deadline = Instant::now() + shared.opts.max_wait;
    let mut batch = Vec::new();
    let mut rows = 0usize;
    loop {
        while let Some(job_rows) = q.jobs.front().map(|j| j.payload.len()) {
            // The first job always goes through whole, even when it is
            // larger than the cap by itself.
            if !batch.is_empty() && rows + job_rows > cap {
                break;
            }
            if let Some(job) = q.jobs.pop_front() {
                batch.push(job);
                rows += job_rows;
            }
            if rows >= cap {
                break;
            }
        }
        if rows >= cap || q.shutdown {
            break;
        }
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        let (guard, timeout) = shared
            .cv
            .wait_timeout(q, deadline - now)
            .unwrap_or_else(|e| e.into_inner());
        q = guard;
        if timeout.timed_out() && q.jobs.is_empty() {
            break;
        }
    }
    Some(batch)
}

/// Score one drained batch: group jobs by (layout, dimensionality),
/// run one fused scoring pass per group, split the score matrix back
/// per request. A group that fails (e.g. dims mismatching the model)
/// errors only its own jobs.
fn score_batch(shared: &Shared, backend: &mut dyn Backend, model: &Predictor, batch: Vec<Job>) {
    let mut groups: Vec<((bool, usize), Vec<Job>)> = Vec::new();
    for job in batch {
        let key = (job.payload.is_csr(), job.payload.dim());
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, jobs)) => jobs.push(job),
            None => groups.push((key, vec![job])),
        }
    }
    for (_, jobs) in groups {
        score_group(shared, backend, model, jobs);
    }
}

fn score_group(shared: &Shared, backend: &mut dyn Backend, model: &Predictor, jobs: Vec<Job>) {
    let total_rows: usize = jobs.iter().map(|j| j.payload.len()).sum();
    shared.metrics.record_batch(total_rows, jobs.len());
    let result = fused_scores(backend, model, &jobs);
    match result {
        Ok((scores, k)) => {
            let mut offset = 0usize;
            for job in &jobs {
                let n = job.payload.len();
                match scores.get(offset * k..(offset + n) * k) {
                    Some(part) => {
                        let _ = job.resp.send(Ok((part.to_vec(), k)));
                    }
                    None => {
                        let _ = job
                            .resp
                            .send(Err("score matrix shorter than the batch".into()));
                    }
                }
                offset += n;
            }
        }
        Err(e) => {
            let msg = e.to_string();
            for job in &jobs {
                let _ = job.resp.send(Err(msg.clone()));
            }
        }
    }
}

/// One fused scoring pass over every row of `jobs` (all the same
/// layout and dimensionality): single requests score zero-copy,
/// coalesced groups concatenate rows first — one kernel block serves
/// all heads and all requests.
fn fused_scores(
    backend: &mut dyn Backend,
    model: &Predictor,
    jobs: &[Job],
) -> Result<(Vec<f32>, usize)> {
    let (first, tail) = match jobs.split_first() {
        Some(p) => p,
        None => return Err(Error::invalid("empty scoring group")),
    };
    if tail.is_empty() {
        return model.scores_rows(backend, first.payload.rows());
    }
    match &first.payload {
        ScorePayload::Dense { d, .. } => {
            let d = *d;
            let mut n = 0usize;
            let mut x = Vec::new();
            for job in jobs {
                match &job.payload {
                    ScorePayload::Dense { n: jn, x: jx, .. } => {
                        n += jn;
                        x.extend_from_slice(jx);
                    }
                    ScorePayload::Csr(_) => {
                        return Err(Error::invalid("mixed-layout scoring group"))
                    }
                }
            }
            model.scores_rows(backend, Rows::dense(&x, n, d))
        }
        ScorePayload::Csr(first_block) => {
            let d = first_block.dim();
            let mut indptr = vec![0usize];
            let mut indices = Vec::new();
            let mut values = Vec::new();
            for job in jobs {
                match &job.payload {
                    ScorePayload::Csr(b) => {
                        let base = values.len();
                        indptr.extend(b.indptr().iter().skip(1).map(|p| base + p));
                        indices.extend_from_slice(b.indices());
                        values.extend_from_slice(b.values());
                    }
                    ScorePayload::Dense { .. } => {
                        return Err(Error::invalid("mixed-layout scoring group"))
                    }
                }
            }
            let block = CsrBlock::from_parts(indptr, indices, values, d)?;
            model.scores_rows(backend, Rows::Csr(block.view()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::estimator::{Fit, FitBackend, TrainSet};
    use crate::rng::Pcg64;
    use std::time::Duration;

    fn trained_model_file(dir: &std::path::Path, name: &str) -> (PathBuf, crate::data::Dataset) {
        let mut rng = Pcg64::seed_from(41);
        let ds = synth::xor(120, 0.2, &mut rng);
        let mut backend = FitBackend::native();
        let fitted = Fit::dsekl()
            .gamma(1.0)
            .sizes(16, 16)
            .iters(120)
            .fit(&mut backend, TrainSet::from(&ds), &mut rng)
            .expect("training");
        let path = dir.join(name);
        fitted.predictor.save_file(&path).expect("save");
        (path, ds)
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dsekl-serve-unit-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("tmpdir");
        dir
    }

    #[test]
    fn queued_jobs_coalesce_into_one_fused_batch() {
        let dir = tmpdir("batch");
        let (path, ds) = trained_model_file(&dir, "m.dsekl");
        let opts = ServeOpts {
            max_wait: Duration::from_millis(0),
            ..Default::default()
        };
        let server = Server::new(&path, opts).expect("server");
        // Enqueue 5 requests BEFORE the scorer starts: one drain must
        // coalesce them into a single fused pass.
        let receivers: Vec<_> = (0..5)
            .map(|i| {
                let row = &ds.x[i * ds.d..(i + 1) * ds.d];
                server.enqueue(ScorePayload::Dense {
                    n: 1,
                    d: ds.d,
                    x: row.to_vec(),
                })
            })
            .collect();
        let scorer = server.spawn_scorer();
        let mut fused = Vec::new();
        for rx in receivers {
            let (scores, k) = rx.recv().expect("reply").expect("scores");
            assert_eq!(k, 1);
            assert_eq!(scores.len(), 1);
            fused.push(scores[0]);
        }
        let snap = server.metrics_snapshot();
        assert_eq!(snap.batches, 1, "expected one fused pass, got {snap:?}");
        assert_eq!(snap.max_batch_requests, 5);
        assert_eq!(snap.max_batch_rows, 5);
        // Fused scores equal the model scored directly.
        let model = server.model();
        let mut be = FitBackend::native();
        let (direct, _) = model
            .scores_rows(
                be.leader().expect("backend"),
                Rows::dense(&ds.x[..5 * ds.d], 5, ds.d),
            )
            .expect("direct scores");
        assert_eq!(fused, direct, "fused batch diverged from direct scoring");
        server.shutdown();
        scorer.join().expect("scorer join");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dim_mismatch_errors_cleanly_and_server_survives() {
        let dir = tmpdir("dims");
        let (path, ds) = trained_model_file(&dir, "m.dsekl");
        let server = Server::new(&path, ServeOpts::default()).expect("server");
        let scorer = server.spawn_scorer();
        let bad = server.enqueue(ScorePayload::Dense {
            n: 1,
            d: 7,
            x: vec![0.0; 7],
        });
        let err = bad.recv().expect("reply").expect_err("dim mismatch");
        assert!(err.contains("dim"), "{err}");
        // Good requests still work after the failed group.
        let good = server.enqueue(ScorePayload::Dense {
            n: 1,
            d: ds.d,
            x: ds.x[..ds.d].to_vec(),
        });
        assert!(good.recv().expect("reply").is_ok());
        server.shutdown();
        scorer.join().expect("scorer join");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reload_swaps_model_and_keeps_old_arcs_alive() {
        let dir = tmpdir("reload");
        let (path_a, _) = trained_model_file(&dir, "a.dsekl");
        // A second, different model file.
        let mut rng = Pcg64::seed_from(99);
        let ds2 = synth::blobs(80, 3, 4.0, &mut rng);
        let mut backend = FitBackend::native();
        let fitted = Fit::dsekl()
            .gamma(0.5)
            .sizes(8, 8)
            .iters(60)
            .fit(&mut backend, TrainSet::from(&ds2), &mut rng)
            .expect("training");
        let path_b = dir.join("b.dsekl");
        fitted.predictor.save_file(&path_b).expect("save");

        let server = Server::new(&path_a, ServeOpts::default()).expect("server");
        let before = server.model();
        assert_eq!(before.dim(), 2);
        let summary = server
            .reload(Some(path_b.to_str().expect("utf8 path")))
            .expect("reload");
        assert!(summary.contains("family=kernel"), "{summary}");
        assert_eq!(server.model().dim(), 3, "new model not swapped in");
        // The old Arc survives for in-flight use.
        assert_eq!(before.dim(), 2);
        assert_eq!(server.metrics_snapshot().reloads, 1);
        // A failed reload keeps the current model serving.
        assert!(server.reload(Some("/nonexistent/x.dsekl")).is_err());
        assert_eq!(server.model().dim(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
