//! Wire protocol of the model server: little-endian, length-prefixed
//! frames over any byte stream (TCP or stdio).
//!
//! Framing: each message is a `u32` payload length followed by that
//! many payload bytes; frames above [`MAX_FRAME`] are rejected on both
//! ends. Requests start with a one-byte opcode:
//!
//! | op | request | body |
//! |----|---------|------|
//! | 1  | ping    | — |
//! | 2  | score (dense) | `u32 n, u32 d, f32[n*d]` row-major |
//! | 3  | score (CSR)   | `u32 n, u32 d, u64 nnz, u64 indptr[n+1], u32 indices[nnz], f32 values[nnz]` |
//! | 4  | reload  | `u16 len, utf8 path` (len 0 ⇒ reload the current path) |
//! | 5  | stats   | — |
//!
//! Responses start with a status byte (0 ok, 1 error). Ok responses
//! carry a kind byte: 0 pong, 1 scores (`u32 n, u32 k, f32[n*k]`
//! row-major), 2 text (utf8). Error responses carry a one-byte error
//! code — 0 generic, 1 overloaded (load shed), 2 deadline exceeded,
//! 3 shutting down — followed by the utf8 message, so clients can
//! react to backpressure (retry later, fail over) without parsing
//! message text.
//!
//! Every decoder validates counts against the bytes actually present
//! (and CSR payloads go through [`CsrBlock::from_parts`]), so a
//! malformed or hostile frame errors instead of panicking or
//! over-allocating.

use std::io::{ErrorKind, Read, Write};
use std::time::{Duration, Instant};

use crate::data::{CsrBlock, Rows};
use crate::{Error, Result};

/// Largest accepted frame payload (64 MiB) — bounds per-connection
/// memory no matter what the peer claims.
pub const MAX_FRAME: u32 = 1 << 26;

const OP_PING: u8 = 1;
const OP_SCORE_DENSE: u8 = 2;
const OP_SCORE_CSR: u8 = 3;
const OP_RELOAD: u8 = 4;
const OP_STATS: u8 = 5;

const STATUS_OK: u8 = 0;
const STATUS_ERR: u8 = 1;
const KIND_PONG: u8 = 0;
const KIND_SCORES: u8 = 1;
const KIND_TEXT: u8 = 2;

// Error-response codes: the second byte of a STATUS_ERR payload. A
// tagged code instead of free text so clients distinguish "back off"
// (overloaded) from "give up" (timeout, shutdown) structurally; the
// repo-lint registry rule forces every code into the decode dispatch.
const ERR_GENERIC: u8 = 0;
const ERR_OVERLOADED: u8 = 1;
const ERR_TIMEOUT: u8 = 2;
const ERR_SHUTDOWN: u8 = 3;

/// Rows to score, as decoded off the wire. The CSR variant is a
/// validated [`CsrBlock`], so the scorer serves it straight to the
/// layout-polymorphic (O(nnz)) kernel paths.
#[derive(Debug, Clone)]
pub enum ScorePayload {
    /// Dense row-major `[n, d]` rows.
    Dense {
        n: usize,
        d: usize,
        x: Vec<f32>,
    },
    /// CSR rows.
    Csr(CsrBlock),
}

impl ScorePayload {
    /// Number of rows.
    pub fn len(&self) -> usize {
        match self {
            ScorePayload::Dense { n, .. } => *n,
            ScorePayload::Csr(b) => b.len(),
        }
    }

    /// True when there are no rows (decoders reject this).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        match self {
            ScorePayload::Dense { d, .. } => *d,
            ScorePayload::Csr(b) => b.dim(),
        }
    }

    /// CSR layout?
    pub fn is_csr(&self) -> bool {
        matches!(self, ScorePayload::Csr(_))
    }

    /// Borrowed [`Rows`] view for the backend.
    pub fn rows(&self) -> Rows<'_> {
        match self {
            ScorePayload::Dense { n, d, x } => Rows::dense(x, *n, *d),
            ScorePayload::Csr(b) => Rows::Csr(b.view()),
        }
    }
}

/// A decoded client request.
#[derive(Debug, Clone)]
pub enum Request {
    /// Liveness probe.
    Ping,
    /// Score rows against the served model.
    Score(ScorePayload),
    /// Hot-reload the model (`None` ⇒ re-read the current path).
    Reload(Option<String>),
    /// Fetch the metrics table.
    Stats,
}

/// A decoded server response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Answer to ping.
    Pong,
    /// Decision scores, row-major `[n, k]`.
    Scores {
        /// Heads per row (1 binary, K multiclass).
        k: usize,
        /// The `[n, k]` score matrix.
        scores: Vec<f32>,
    },
    /// Text payload (reload summaries, the stats table).
    Text(String),
    /// The request failed; the message explains why.
    Error(String),
    /// The request was shed without scoring: admitting it would push
    /// the queue past `--max-queue-rows`. Retry later or fail over —
    /// the server is alive, just saturated.
    Overloaded(String),
    /// No result arrived within the per-request deadline
    /// (`--request-timeout-ms`): the scorer is wedged, dead, or the
    /// queue is draining slower than the deadline allows.
    TimedOut(String),
    /// The server is shutting down; queued work was shed unscored.
    ShuttingDown(String),
}

/// Write one length-prefixed frame.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME as usize {
        return Err(Error::invalid(format!(
            "frame of {} bytes exceeds the {} byte cap",
            payload.len(),
            MAX_FRAME
        )));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    Ok(())
}

/// Read one length-prefixed frame. `Ok(None)` on clean EOF at a frame
/// boundary (the peer closed); mid-frame EOF is an error. On a stream
/// with a read timeout set (e.g. a [`Client`](super::Client) socket),
/// a timeout anywhere — idle or mid-frame — is an error: the caller
/// asked for a bounded wait and did not get a frame.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>> {
    match read_frame_deadline(r, Duration::ZERO)? {
        FrameEvent::Payload(p) => Ok(Some(p)),
        FrameEvent::Eof => Ok(None),
        FrameEvent::Idle => Err(Error::parse(
            "read timed out waiting for a response frame",
        )),
    }
}

/// Outcome of one deadline-aware frame read.
#[derive(Debug)]
pub enum FrameEvent {
    /// A complete frame payload.
    Payload(Vec<u8>),
    /// Clean EOF at a frame boundary: the peer closed.
    Eof,
    /// The stream's read timeout elapsed before any byte of a new
    /// frame arrived. Not an error — the peer is idle, not stalled;
    /// the caller decides whether to keep waiting (and can check for
    /// shutdown between ticks).
    Idle,
}

/// Read one frame from a stream that may have a socket read timeout.
///
/// A timeout at a frame boundary (zero bytes read) returns
/// [`FrameEvent::Idle`]; once a frame has started, timeouts are
/// tolerated until `stall` has elapsed since the first byte, after
/// which the peer is declared stalled mid-frame and the read errors —
/// a half-sent frame can therefore pin a connection thread for at most
/// `stall`, never forever. On streams without a read timeout the
/// behaviour is identical to a plain blocking read.
pub fn read_frame_deadline<R: Read>(r: &mut R, stall: Duration) -> Result<FrameEvent> {
    let mut len = [0u8; 4];
    let mut got = 0usize;
    let mut frame_start: Option<Instant> = None;
    while let Some(buf) = len.get_mut(got..).filter(|b| !b.is_empty()) {
        match r.read(buf) {
            Ok(0) => {
                if got == 0 {
                    return Ok(FrameEvent::Eof);
                }
                return Err(Error::parse("connection closed mid-frame"));
            }
            Ok(n) => {
                got += n;
                frame_start.get_or_insert_with(Instant::now);
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) if is_timeout(&e) => {
                if got == 0 {
                    return Ok(FrameEvent::Idle);
                }
                check_stall(frame_start, stall)?;
            }
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_le_bytes(len);
    if len > MAX_FRAME {
        return Err(Error::parse(format!(
            "frame of {len} bytes exceeds the {MAX_FRAME} byte cap"
        )));
    }
    // Incremental read: allocation grows with bytes that actually
    // arrive, mirroring the model-file readers.
    let mut payload = Vec::with_capacity((len as usize).min(1 << 16));
    let mut scratch = [0u8; 8192];
    while payload.len() < len as usize {
        let want = (len as usize - payload.len()).min(scratch.len());
        let buf = scratch
            .get_mut(..want)
            .ok_or_else(|| Error::parse("frame scratch sizing"))?;
        match r.read(buf) {
            Ok(0) => return Err(Error::parse("connection closed mid-frame")),
            Ok(n) => payload.extend_from_slice(buf.get(..n).unwrap_or(&[])),
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) if is_timeout(&e) => check_stall(frame_start, stall)?,
            Err(e) => return Err(e.into()),
        }
    }
    Ok(FrameEvent::Payload(payload))
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

/// Error once `stall` has elapsed since the frame's first byte; `Ok`
/// means "keep reading".
fn check_stall(frame_start: Option<Instant>, stall: Duration) -> Result<()> {
    let elapsed = frame_start.map(|t| t.elapsed()).unwrap_or(stall);
    if elapsed >= stall {
        return Err(Error::parse(format!(
            "peer stalled mid-frame for {:.1}s — dropping the connection",
            elapsed.as_secs_f64()
        )));
    }
    Ok(())
}

/// Byte cursor over a request/response payload; every take is
/// bounds-checked.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cur { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| Error::parse("message truncated"))?;
        let s = self
            .buf
            .get(self.pos..end)
            .ok_or_else(|| Error::parse("message truncated"))?;
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        let b = self.take(1)?;
        b.first()
            .copied()
            .ok_or_else(|| Error::parse("message truncated"))
    }

    fn u16(&mut self) -> Result<u16> {
        let b: [u8; 2] = self
            .take(2)?
            .try_into()
            .map_err(|_| Error::parse("message truncated"))?;
        Ok(u16::from_le_bytes(b))
    }

    fn u32(&mut self) -> Result<u32> {
        let b: [u8; 4] = self
            .take(4)?
            .try_into()
            .map_err(|_| Error::parse("message truncated"))?;
        Ok(u32::from_le_bytes(b))
    }

    fn u64(&mut self) -> Result<u64> {
        let b: [u8; 8] = self
            .take(8)?
            .try_into()
            .map_err(|_| Error::parse("message truncated"))?;
        Ok(u64::from_le_bytes(b))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let bytes = self.take(n.checked_mul(4).ok_or_else(|| Error::parse("count overflow"))?)?;
        let mut out = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            let mut quad = [0u8; 4];
            quad.copy_from_slice(c);
            out.push(f32::from_le_bytes(quad));
        }
        Ok(out)
    }

    fn rest(&mut self) -> &'a [u8] {
        let s = self.buf.get(self.pos..).unwrap_or(&[]);
        self.pos = self.buf.len();
        s
    }

    /// Error if undecoded bytes remain — rejects trailing junk.
    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(Error::parse(format!(
                "{} trailing bytes after message body",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn utf8(bytes: &[u8]) -> Result<String> {
    String::from_utf8(bytes.to_vec()).map_err(|_| Error::parse("invalid utf8 in message"))
}

/// Encode a ping request.
pub fn encode_ping() -> Vec<u8> {
    vec![OP_PING]
}

/// Encode a stats request.
pub fn encode_stats() -> Vec<u8> {
    vec![OP_STATS]
}

/// Encode a reload request (`None` ⇒ reload the current path).
pub fn encode_reload(path: Option<&str>) -> Result<Vec<u8>> {
    let path = path.unwrap_or("");
    if path.len() > usize::from(u16::MAX) {
        return Err(Error::invalid("reload path too long"));
    }
    let mut out = Vec::with_capacity(3 + path.len());
    out.push(OP_RELOAD);
    out.extend_from_slice(&(path.len() as u16).to_le_bytes());
    out.extend_from_slice(path.as_bytes());
    Ok(out)
}

/// Encode a dense scoring request over row-major `[n, d]` rows.
pub fn encode_score_dense(x: &[f32], n: usize, d: usize) -> Result<Vec<u8>> {
    if n == 0 || d == 0 || x.len() != n * d {
        return Err(Error::invalid(format!(
            "dense score payload shape mismatch (n={n}, d={d}, len={})",
            x.len()
        )));
    }
    let mut out = Vec::with_capacity(9 + 4 * x.len());
    out.push(OP_SCORE_DENSE);
    out.extend_from_slice(&(n as u32).to_le_bytes());
    out.extend_from_slice(&(d as u32).to_le_bytes());
    for v in x {
        out.extend_from_slice(&v.to_le_bytes());
    }
    Ok(out)
}

/// Encode a CSR scoring request.
pub fn encode_score_csr(block: &CsrBlock) -> Result<Vec<u8>> {
    let (n, d, nnz) = (block.len(), block.dim(), block.nnz());
    if n == 0 || d == 0 {
        return Err(Error::invalid("CSR score payload must have rows and columns"));
    }
    let mut out = Vec::with_capacity(25 + 8 * (n + 1) + 8 * nnz);
    out.push(OP_SCORE_CSR);
    out.extend_from_slice(&(n as u32).to_le_bytes());
    out.extend_from_slice(&(d as u32).to_le_bytes());
    out.extend_from_slice(&(nnz as u64).to_le_bytes());
    for &p in block.indptr() {
        out.extend_from_slice(&(p as u64).to_le_bytes());
    }
    for &c in block.indices() {
        out.extend_from_slice(&c.to_le_bytes());
    }
    for v in block.values() {
        out.extend_from_slice(&v.to_le_bytes());
    }
    Ok(out)
}

/// Decode a request payload.
pub fn decode_request(buf: &[u8]) -> Result<Request> {
    let mut c = Cur::new(buf);
    let op = c.u8().map_err(|_| Error::parse("empty request frame"))?;
    match op {
        OP_PING => {
            c.done()?;
            Ok(Request::Ping)
        }
        OP_STATS => {
            c.done()?;
            Ok(Request::Stats)
        }
        OP_RELOAD => {
            let len = usize::from(c.u16()?);
            let path = utf8(c.take(len)?)?;
            c.done()?;
            Ok(Request::Reload((!path.is_empty()).then_some(path)))
        }
        OP_SCORE_DENSE => {
            let n = c.u32()? as usize;
            let d = c.u32()? as usize;
            if n == 0 || d == 0 {
                return Err(Error::parse("score request with zero rows or columns"));
            }
            let elems = n
                .checked_mul(d)
                .ok_or_else(|| Error::parse("score request shape overflow"))?;
            let x = c.f32s(elems)?;
            c.done()?;
            Ok(Request::Score(ScorePayload::Dense { n, d, x }))
        }
        OP_SCORE_CSR => {
            let n = c.u32()? as usize;
            let d = c.u32()? as usize;
            let nnz = c.u64()? as usize;
            if n == 0 || d == 0 {
                return Err(Error::parse("score request with zero rows or columns"));
            }
            let mut indptr = Vec::with_capacity((n + 1).min(1 << 16));
            for _ in 0..n + 1 {
                let v = c.u64()? as usize;
                if v > nnz {
                    return Err(Error::parse("CSR indptr points past the value buffer"));
                }
                indptr.push(v);
            }
            let mut indices = Vec::with_capacity(nnz.min(1 << 16));
            for _ in 0..nnz {
                indices.push(c.u32()?);
            }
            let values = c.f32s(nnz)?;
            c.done()?;
            let block = CsrBlock::from_parts(indptr, indices, values, d)?;
            Ok(Request::Score(ScorePayload::Csr(block)))
        }
        other => Err(Error::parse(format!("unknown request opcode {other}"))),
    }
}

/// Encode a response payload.
pub fn encode_response(resp: &Response) -> Vec<u8> {
    match resp {
        Response::Pong => vec![STATUS_OK, KIND_PONG],
        Response::Scores { k, scores } => {
            let k = (*k).max(1);
            let mut out = Vec::with_capacity(10 + 4 * scores.len());
            out.push(STATUS_OK);
            out.push(KIND_SCORES);
            out.extend_from_slice(&((scores.len() / k) as u32).to_le_bytes());
            out.extend_from_slice(&(k as u32).to_le_bytes());
            for v in scores {
                out.extend_from_slice(&v.to_le_bytes());
            }
            out
        }
        Response::Text(text) => {
            let mut out = Vec::with_capacity(2 + text.len());
            out.push(STATUS_OK);
            out.push(KIND_TEXT);
            out.extend_from_slice(text.as_bytes());
            out
        }
        Response::Error(msg) => err_frame(ERR_GENERIC, msg),
        Response::Overloaded(msg) => err_frame(ERR_OVERLOADED, msg),
        Response::TimedOut(msg) => err_frame(ERR_TIMEOUT, msg),
        Response::ShuttingDown(msg) => err_frame(ERR_SHUTDOWN, msg),
    }
}

fn err_frame(code: u8, msg: &str) -> Vec<u8> {
    let mut out = Vec::with_capacity(2 + msg.len());
    out.push(STATUS_ERR);
    out.push(code);
    out.extend_from_slice(msg.as_bytes());
    out
}

/// Decode a response payload.
pub fn decode_response(buf: &[u8]) -> Result<Response> {
    let mut c = Cur::new(buf);
    match c.u8().map_err(|_| Error::parse("empty response frame"))? {
        STATUS_OK => match c.u8()? {
            KIND_PONG => {
                c.done()?;
                Ok(Response::Pong)
            }
            KIND_SCORES => {
                let n = c.u32()? as usize;
                let k = c.u32()? as usize;
                if k == 0 {
                    return Err(Error::parse("score response with zero heads"));
                }
                let elems = n
                    .checked_mul(k)
                    .ok_or_else(|| Error::parse("score response shape overflow"))?;
                let scores = c.f32s(elems)?;
                c.done()?;
                Ok(Response::Scores { k, scores })
            }
            KIND_TEXT => {
                let text = utf8(c.rest())?;
                Ok(Response::Text(text))
            }
            other => Err(Error::parse(format!("unknown response kind {other}"))),
        },
        STATUS_ERR => {
            let code = c
                .u8()
                .map_err(|_| Error::parse("error response missing its code byte"))?;
            let msg = utf8(c.rest())?;
            match code {
                ERR_GENERIC => Ok(Response::Error(msg)),
                ERR_OVERLOADED => Ok(Response::Overloaded(msg)),
                ERR_TIMEOUT => Ok(Response::TimedOut(msg)),
                ERR_SHUTDOWN => Ok(Response::ShuttingDown(msg)),
                other => Err(Error::parse(format!("unknown error code {other}"))),
            }
        }
        other => Err(Error::parse(format!("unknown response status {other}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_and_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = buf.as_slice();
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"");
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF");
        // Mid-frame EOF errors.
        let mut short = &buf[..3];
        assert!(read_frame(&mut short).is_err());
        let mut short = &buf[..7];
        assert!(read_frame(&mut short).is_err());
        // Oversized length header is rejected before allocating.
        let huge = (MAX_FRAME + 1).to_le_bytes();
        assert!(read_frame(&mut &huge[..]).is_err());
    }

    #[test]
    fn request_roundtrips() {
        match decode_request(&encode_ping()).unwrap() {
            Request::Ping => {}
            other => panic!("{other:?}"),
        }
        match decode_request(&encode_stats()).unwrap() {
            Request::Stats => {}
            other => panic!("{other:?}"),
        }
        match decode_request(&encode_reload(Some("m.dsekl")).unwrap()).unwrap() {
            Request::Reload(Some(p)) => assert_eq!(p, "m.dsekl"),
            other => panic!("{other:?}"),
        }
        match decode_request(&encode_reload(None).unwrap()).unwrap() {
            Request::Reload(None) => {}
            other => panic!("{other:?}"),
        }
        let x = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        match decode_request(&encode_score_dense(&x, 2, 3).unwrap()).unwrap() {
            Request::Score(ScorePayload::Dense { n, d, x: got }) => {
                assert_eq!((n, d), (2, 3));
                assert_eq!(got, x);
            }
            other => panic!("{other:?}"),
        }
        let block =
            CsrBlock::from_parts(vec![0, 2, 2, 3], vec![0, 3, 1], vec![1.0, -2.0, 0.5], 4)
                .unwrap();
        match decode_request(&encode_score_csr(&block).unwrap()).unwrap() {
            Request::Score(ScorePayload::Csr(b)) => {
                assert_eq!(b.len(), 3);
                assert_eq!(b.dim(), 4);
                assert_eq!(b.values(), &[1.0, -2.0, 0.5]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn request_decode_rejects_malformed() {
        assert!(decode_request(&[]).is_err());
        assert!(decode_request(&[99]).is_err());
        // Trailing junk after a ping.
        assert!(decode_request(&[OP_PING, 0]).is_err());
        // Zero-row and zero-dim scores.
        assert!(encode_score_dense(&[], 0, 3).is_err());
        let mut bad = encode_score_dense(&[1.0, 2.0], 1, 2).unwrap();
        bad[1..5].fill(0); // n = 0 on the wire
        assert!(decode_request(&bad).is_err());
        // Dense payload shorter than n*d.
        let mut bad = encode_score_dense(&[1.0, 2.0, 3.0, 4.0], 2, 2).unwrap();
        bad.truncate(bad.len() - 4);
        assert!(decode_request(&bad).is_err());
        // CSR indptr pointing past nnz.
        let block = CsrBlock::from_parts(vec![0, 1], vec![2], vec![1.0], 3).unwrap();
        let mut bad = encode_score_csr(&block).unwrap();
        // indptr[1] lives at offset 1 + 4 + 4 + 8 + 8.
        bad[25..33].copy_from_slice(&9u64.to_le_bytes());
        assert!(decode_request(&bad).is_err());
        // CSR column out of range is caught by from_parts.
        let mut bad = encode_score_csr(&block).unwrap();
        let idx_at = 25 + 8; // after both indptr entries
        bad[idx_at..idx_at + 4].copy_from_slice(&7u32.to_le_bytes());
        assert!(decode_request(&bad).is_err());
    }

    #[test]
    fn response_roundtrips() {
        assert_eq!(
            decode_response(&encode_response(&Response::Pong)).unwrap(),
            Response::Pong
        );
        let r = Response::Scores {
            k: 3,
            scores: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        };
        assert_eq!(decode_response(&encode_response(&r)).unwrap(), r);
        let r = Response::Text("uptime_s 1.0\n".into());
        assert_eq!(decode_response(&encode_response(&r)).unwrap(), r);
        let r = Response::Error("dataset dim 3 != model dim 2".into());
        assert_eq!(decode_response(&encode_response(&r)).unwrap(), r);
        assert!(decode_response(&[]).is_err());
        assert!(decode_response(&[7]).is_err());
    }

    #[test]
    fn tagged_error_responses_roundtrip() {
        for r in [
            Response::Error("kernel mismatch".into()),
            Response::Overloaded("queue full: 4096 rows queued".into()),
            Response::TimedOut("no result within 5000 ms".into()),
            Response::ShuttingDown("server is shutting down".into()),
        ] {
            assert_eq!(decode_response(&encode_response(&r)).unwrap(), r);
        }
        // A status-err frame with no code byte is malformed, and an
        // unknown code is rejected rather than collapsed to generic.
        assert!(decode_response(&[STATUS_ERR]).is_err());
        assert!(decode_response(&[STATUS_ERR, 9, b'x']).is_err());
    }

    #[test]
    fn deadline_reader_matches_plain_reader_on_blocking_streams() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"abc").unwrap();
        let mut r = buf.as_slice();
        match read_frame_deadline(&mut r, Duration::from_millis(50)).unwrap() {
            FrameEvent::Payload(p) => assert_eq!(p, b"abc"),
            other => panic!("{other:?}"),
        }
        match read_frame_deadline(&mut r, Duration::from_millis(50)).unwrap() {
            FrameEvent::Eof => {}
            other => panic!("{other:?}"),
        }
        // Mid-frame EOF errors through the deadline reader too.
        let mut short = &buf[..5];
        assert!(read_frame_deadline(&mut short, Duration::from_millis(50)).is_err());
    }
}
