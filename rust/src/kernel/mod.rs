//! Kernel functions and the native (pure-rust) block compute.
//!
//! The paper's experiments all use the RBF kernel; we also ship linear
//! and polynomial kernels as the "versatile off-the-shelf kernel"
//! extension the conclusion motivates. The AOT/PJRT artifacts implement
//! RBF only — [`Kernel::is_aot_supported`] tells the runtime when it must
//! fall back to the native backend.

pub mod native;

use crate::{Error, Result};

/// Kernel function selector.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// `exp(-gamma ||x - z||^2)` — the paper's kernel.
    Rbf { gamma: f32 },
    /// `x . z`
    Linear,
    /// `(gamma x.z + coef0)^degree`
    Poly { gamma: f32, degree: u32, coef0: f32 },
}

impl Kernel {
    /// RBF with the given width.
    pub fn rbf(gamma: f32) -> Self {
        Kernel::Rbf { gamma }
    }

    /// The `gamma` hyper-parameter fed to the AOT artifacts (RBF only).
    pub fn gamma(&self) -> f32 {
        match self {
            Kernel::Rbf { gamma } => *gamma,
            Kernel::Poly { gamma, .. } => *gamma,
            Kernel::Linear => 0.0,
        }
    }

    /// Whether a PJRT artifact exists for this kernel family.
    pub fn is_aot_supported(&self) -> bool {
        matches!(self, Kernel::Rbf { .. })
    }

    /// Encode as the `(kind, gamma, degree, coef0)` wire tuple shared by
    /// every model file format (DSEKLv1 and DSEKLv2 headers). The match
    /// is exhaustive on purpose: adding a kernel without extending the
    /// wire format is a compile error, and [`Kernel::decode_wire`] is
    /// the one place that maps kinds back.
    pub fn encode_wire(&self) -> (u32, f32, u32, f32) {
        match *self {
            Kernel::Rbf { gamma } => (0, gamma, 0, 0.0),
            Kernel::Linear => (1, 0.0, 0, 0.0),
            Kernel::Poly {
                gamma,
                degree,
                coef0,
            } => (2, gamma, degree, coef0),
        }
    }

    /// Decode the wire tuple written by [`Kernel::encode_wire`].
    pub fn decode_wire(kind: u32, gamma: f32, degree: u32, coef0: f32) -> Result<Kernel> {
        match kind {
            0 => Ok(Kernel::Rbf { gamma }),
            1 => Ok(Kernel::Linear),
            2 => Ok(Kernel::Poly {
                gamma,
                degree,
                coef0,
            }),
            k => Err(Error::parse(format!("unknown kernel kind {k}"))),
        }
    }

    /// Evaluate on a single pair (reference path; the block routines in
    /// [`native`] are the hot path).
    pub fn eval(&self, x: &[f32], z: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), z.len());
        match *self {
            Kernel::Rbf { gamma } => {
                let d2: f32 = x
                    .iter()
                    .zip(z)
                    .map(|(a, b)| {
                        let d = a - b;
                        d * d
                    })
                    .sum();
                (-gamma * d2).exp()
            }
            Kernel::Linear => x.iter().zip(z).map(|(a, b)| a * b).sum(),
            Kernel::Poly {
                gamma,
                degree,
                coef0,
            } => {
                let dot: f32 = x.iter().zip(z).map(|(a, b)| a * b).sum();
                (gamma * dot + coef0).powi(degree as i32)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rbf_identity_and_symmetry() {
        let k = Kernel::rbf(0.5);
        let x = [1.0, 2.0, 3.0];
        let z = [0.0, 1.0, -1.0];
        assert!((k.eval(&x, &x) - 1.0).abs() < 1e-7);
        assert!((k.eval(&x, &z) - k.eval(&z, &x)).abs() < 1e-7);
        // d2 = 1 + 1 + 16 = 18 -> exp(-9)
        assert!((k.eval(&x, &z) - (-9.0f32).exp()).abs() < 1e-7);
    }

    #[test]
    fn linear_matches_dot() {
        let k = Kernel::Linear;
        assert_eq!(k.eval(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }

    #[test]
    fn poly_explicit() {
        let k = Kernel::Poly {
            gamma: 1.0,
            degree: 2,
            coef0: 1.0,
        };
        // (1*2 + 1)^2 = 9
        assert_eq!(k.eval(&[1.0, 1.0], &[1.0, 1.0]), 9.0);
    }

    #[test]
    fn wire_roundtrip_every_kernel() {
        // One instance per variant; encode_wire's exhaustive match makes
        // a forgotten variant a compile error, this test makes a broken
        // mapping a runtime failure.
        let all = [
            Kernel::rbf(0.37),
            Kernel::Linear,
            Kernel::Poly {
                gamma: 0.3,
                degree: 4,
                coef0: 1.5,
            },
        ];
        for k in all {
            let (kind, gamma, degree, coef0) = k.encode_wire();
            assert_eq!(Kernel::decode_wire(kind, gamma, degree, coef0).unwrap(), k);
        }
        // Distinct kinds per variant.
        assert_ne!(all[0].encode_wire().0, all[1].encode_wire().0);
        assert_ne!(all[1].encode_wire().0, all[2].encode_wire().0);
        // Unknown kinds are rejected, not misparsed.
        assert!(Kernel::decode_wire(99, 0.0, 0, 0.0).is_err());
    }

    #[test]
    fn aot_support_flags() {
        assert!(Kernel::rbf(1.0).is_aot_supported());
        assert!(!Kernel::Linear.is_aot_supported());
    }
}
