//! Native (pure-rust) implementations of the fixed-shape compute ops —
//! the same math the AOT artifacts implement, kept in bit-for-bit-close
//! agreement with them by the `backend_parity` integration test.
//!
//! Layout mirrors the Pallas kernels: the cross term of the squared
//! distance is a blocked GEMM (`I x D . D x J`), norms are precomputed
//! per row, and the kernel block is contracted against the residual
//! immediately (never stored for the fused step). Blocking constants are
//! tuned for L1/L2 locality on CPU in the §Perf pass.
//!
//! Every contraction also exists in a [`Rows`]-polymorphic `*_rows`
//! variant: dense×dense inputs dispatch to the blocked-GEMM twins above
//! (bitwise identical), while CSR operands take a sparse dot path that
//! touches only stored entries — `O(nnz)` instead of `O(n d)` per row,
//! with RBF norms precomputed from the CSR values. The dense entry
//! points are thin wrappers over the `*_rows` ones, so there is exactly
//! one implementation of each step's arithmetic.

use crate::data::sparse::Rows;
use crate::kernel::Kernel;
use crate::loss::Loss;

/// Strip height: rows of K computed (and immediately contracted) at a
/// time in the fused routines. 32 rows amortise the BT stream across
/// 8 micro-tiles while the strip (32 x 1024 f32 = 128 KiB worst case)
/// still fits L2.
const MR: usize = 32;

/// `out[a, b] = k(xi_a, xj_b)` for dense row-major inputs.
///
/// `xi: [i, d]`, `xj: [j, d]`, `out: [i, j]` (caller-allocated).
pub fn kernel_block(kernel: Kernel, xi: &[f32], xj: &[f32], i: usize, j: usize, d: usize, out: &mut [f32]) {
    assert_eq!(xi.len(), i * d);
    assert_eq!(xj.len(), j * d);
    assert_eq!(out.len(), i * j);
    match kernel {
        Kernel::Rbf { gamma } => rbf_block(xi, xj, i, j, d, gamma, out),
        Kernel::Linear => {
            gemm_nt(xi, xj, i, j, d, out);
        }
        Kernel::Poly {
            gamma,
            degree,
            coef0,
        } => {
            gemm_nt(xi, xj, i, j, d, out);
            for v in out.iter_mut() {
                *v = (gamma * *v + coef0).powi(degree as i32);
            }
        }
    }
}

/// RBF block via `||x||^2 + ||z||^2 - 2 x.z`.
fn rbf_block(xi: &[f32], xj: &[f32], i: usize, j: usize, d: usize, gamma: f32, out: &mut [f32]) {
    gemm_nt(xi, xj, i, j, d, out);
    let ni = row_norms(xi, i, d);
    let nj = row_norms(xj, j, d);
    for a in 0..i {
        let base = a * j;
        let na = ni[a];
        for b in 0..j {
            let d2 = (na + nj[b] - 2.0 * out[base + b]).max(0.0);
            out[base + b] = (-gamma * d2).exp();
        }
    }
}

/// Squared row norms of a `[n, d]` matrix.
pub fn row_norms(x: &[f32], n: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    for a in 0..n {
        let row = &x[a * d..(a + 1) * d];
        out[a] = row.iter().map(|v| v * v).sum();
    }
    out
}

/// Squared row norms of a [`Rows`] block — O(nnz) on CSR input.
pub fn rows_norms(rows: Rows) -> Vec<f32> {
    match rows {
        Rows::Dense { x, n, d } => row_norms(x, n, d),
        Rows::Csr(c) => (0..c.len())
            .map(|i| c.row(i).1.iter().map(|v| v * v).sum())
            .collect(),
    }
}

/// Cross dot-product matrix `out[a, b] = xi_a . xj_b` for any mix of
/// dense and CSR operands. Dense×dense delegates to the blocked GEMM;
/// when a CSR operand is involved, every dot touches only stored
/// entries and accumulates in ascending column order (a scalar dot —
/// the property the sparse parity suite leans on).
fn rows_dots(xi: Rows, xj: Rows, out: &mut [f32]) {
    let (i, j, d) = (xi.len(), xj.len(), xi.dim());
    assert_eq!(xj.dim(), d, "operand dimensionality mismatch");
    assert_eq!(out.len(), i * j);
    match (xi, xj) {
        (Rows::Dense { x: a, .. }, Rows::Dense { x: b, .. }) => gemm_nt(a, b, i, j, d, out),
        (Rows::Csr(a), Rows::Csr(b)) => {
            // Scatter each xi row into a dense scratch once, then stream
            // xj's stored entries against it: O(nnz(xi) + i * nnz(xj))
            // for the block instead of O(i * j * d).
            SPARSE_SCRATCH.with(|s| {
                let mut dense_row = s.borrow_mut();
                if dense_row.len() < d {
                    dense_row.resize(d, 0.0);
                }
                for ar in 0..i {
                    let (cols, vals) = a.row(ar);
                    for (c, v) in cols.iter().zip(vals) {
                        dense_row[*c as usize] = *v;
                    }
                    let orow = &mut out[ar * j..(ar + 1) * j];
                    for (br, ov) in orow.iter_mut().enumerate() {
                        let (bc, bv) = b.row(br);
                        let mut acc = 0.0f32;
                        for (c, v) in bc.iter().zip(bv) {
                            acc += dense_row[*c as usize] * *v;
                        }
                        *ov = acc;
                    }
                    // Restore the all-zeros invariant, touching only the
                    // entries this row set.
                    for c in cols {
                        dense_row[*c as usize] = 0.0;
                    }
                }
            });
        }
        (Rows::Csr(a), Rows::Dense { x: b, .. }) => {
            for ar in 0..i {
                let (cols, vals) = a.row(ar);
                let orow = &mut out[ar * j..(ar + 1) * j];
                for (br, ov) in orow.iter_mut().enumerate() {
                    let brow = &b[br * d..(br + 1) * d];
                    let mut acc = 0.0f32;
                    for (c, v) in cols.iter().zip(vals) {
                        acc += *v * brow[*c as usize];
                    }
                    *ov = acc;
                }
            }
        }
        (Rows::Dense { x: a, .. }, Rows::Csr(b)) => {
            for ar in 0..i {
                let arow = &a[ar * d..(ar + 1) * d];
                let orow = &mut out[ar * j..(ar + 1) * j];
                for (br, ov) in orow.iter_mut().enumerate() {
                    let (cols, vals) = b.row(br);
                    let mut acc = 0.0f32;
                    for (c, v) in cols.iter().zip(vals) {
                        acc += arow[*c as usize] * *v;
                    }
                    *ov = acc;
                }
            }
        }
    }
}

/// `out[a, b] = k(xi_a, xj_b)` for any mix of dense and CSR rows.
/// Dense×dense is exactly [`kernel_block`] (bitwise); sparse operands
/// compute the cross dots over stored entries only and derive the RBF
/// distance from precomputed CSR row norms.
pub fn kernel_block_rows(kernel: Kernel, xi: Rows, xj: Rows, out: &mut [f32]) {
    let (i, j, d) = (xi.len(), xj.len(), xi.dim());
    if let (Some(a), Some(b)) = (xi.as_dense(), xj.as_dense()) {
        kernel_block(kernel, a, b, i, j, d, out);
        return;
    }
    let norms = rbf_norms(kernel, xi, xj);
    sparse_block_with_norms(kernel, xi, xj, norms_ref(&norms), out);
}

/// Row norms of both operands when `kernel` needs them (RBF), computed
/// once so strip-wise callers don't redo the O(nnz(xj)) pass per strip.
fn rbf_norms(kernel: Kernel, xi: Rows, xj: Rows) -> Option<(Vec<f32>, Vec<f32>)> {
    match kernel {
        Kernel::Rbf { .. } => Some((rows_norms(xi), rows_norms(xj))),
        _ => None,
    }
}

/// Borrow an owned norms pair as the slices [`sparse_block_with_norms`]
/// takes.
fn norms_ref(norms: &Option<(Vec<f32>, Vec<f32>)>) -> Option<(&[f32], &[f32])> {
    norms.as_ref().map(|(a, b)| (a.as_slice(), b.as_slice()))
}

/// Sparse-path kernel block with caller-provided row norms (`Some`
/// exactly when `kernel` is RBF; `ni` aligned to `xi`'s rows, `nj` to
/// `xj`'s). The per-entry arithmetic is identical to
/// [`kernel_block_rows`] — norms are per-row sums, so hoisting them out
/// of a strip loop does not change a single bit of the output.
fn sparse_block_with_norms(
    kernel: Kernel,
    xi: Rows,
    xj: Rows,
    norms: Option<(&[f32], &[f32])>,
    out: &mut [f32],
) {
    let (i, j) = (xi.len(), xj.len());
    assert_eq!(out.len(), i * j);
    rows_dots(xi, xj, out);
    match kernel {
        Kernel::Linear => {}
        Kernel::Poly {
            gamma,
            degree,
            coef0,
        } => {
            for v in out.iter_mut() {
                *v = (gamma * *v + coef0).powi(degree as i32);
            }
        }
        Kernel::Rbf { gamma } => {
            let (ni, nj) = norms.expect("RBF kernel needs precomputed row norms");
            assert_eq!(ni.len(), i);
            assert_eq!(nj.len(), j);
            for a in 0..i {
                let base = a * j;
                let na = ni[a];
                for b in 0..j {
                    let d2 = (na + nj[b] - 2.0 * out[base + b]).max(0.0);
                    out[base + b] = (-gamma * d2).exp();
                }
            }
        }
    }
}

/// Transpose a row-major `[n, d]` matrix into `bt` (`[d, n]`,
/// resized as needed).
pub fn transpose(b: &[f32], n: usize, d: usize, bt: &mut Vec<f32>) {
    assert_eq!(b.len(), n * d);
    bt.clear();
    bt.resize(d * n, 0.0);
    // Block the transpose for cache-friendliness on both sides.
    const TB: usize = 32;
    for j0 in (0..n).step_by(TB) {
        let j1 = (j0 + TB).min(n);
        for k0 in (0..d).step_by(TB) {
            let k1 = (k0 + TB).min(d);
            for j in j0..j1 {
                for k in k0..k1 {
                    bt[k * n + j] = b[j * d + k];
                }
            }
        }
    }
}

/// Micro-kernel register tile: 4 C rows x 16 C columns accumulated in
/// registers across the whole k loop (8 ymm accumulators + broadcasts —
/// the classic register-blocked GEMM inner kernel, written so LLVM
/// auto-vectorises it; see EXPERIMENTS.md §Perf for the measured steps).
const MR_GEMM: usize = 4;
const NR_GEMM: usize = 16;

/// `pack`: the BT panel for columns `j0..j0+16`, contiguous `[d][16]`.
#[inline]
fn micro_4x16(a: &[f32], pack: &[f32], i0: usize, j0: usize, n: usize, d: usize, c: &mut [f32]) {
    let mut acc = [[0.0f32; NR_GEMM]; MR_GEMM];
    let a0 = &a[i0 * d..(i0 + 1) * d];
    let a1 = &a[(i0 + 1) * d..(i0 + 2) * d];
    let a2 = &a[(i0 + 2) * d..(i0 + 3) * d];
    let a3 = &a[(i0 + 3) * d..(i0 + 4) * d];
    for k in 0..d {
        let b: &[f32; NR_GEMM] = pack[k * NR_GEMM..(k + 1) * NR_GEMM].try_into().unwrap();
        let av = [a0[k], a1[k], a2[k], a3[k]];
        for r in 0..MR_GEMM {
            let ar = av[r];
            for cc in 0..NR_GEMM {
                acc[r][cc] += ar * b[cc];
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        c[(i0 + r) * n + j0..(i0 + r) * n + j0 + NR_GEMM].copy_from_slice(accr);
    }
}

/// `C = A . B` with B **already transposed** to `[d, n]` (`bt`).
/// Register-blocked 4x16 micro-kernel on the interior, (i, k, j)
/// broadcast-FMA loops on the edges — the §Perf rewrite that took the
/// native GEMM from ~6 to >20 GFLOP/s single-core.
pub fn gemm_nt_bt(a: &[f32], bt: &[f32], m: usize, n: usize, d: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * d);
    debug_assert_eq!(bt.len(), d * n);
    debug_assert_eq!(c.len(), m * n);
    let m_main = (m / MR_GEMM) * MR_GEMM;
    let n_main = (n / NR_GEMM) * NR_GEMM;
    // Panel the rows so the A panel (~IP * d floats) stays L2-resident,
    // and pack each BT column panel contiguously (one strided read per
    // (panel, j0) instead of per micro-tile — at n = 1024 the raw BT
    // walk has a 4 KiB stride that thrashes the TLB).
    const IP: usize = 64;
    PACK_SCRATCH.with(|s| {
        let mut pack = s.borrow_mut();
        pack.resize(d * NR_GEMM, 0.0);
        for ip in (0..m_main).step_by(IP) {
            let ip_end = (ip + IP).min(m_main);
            for j0 in (0..n_main).step_by(NR_GEMM) {
                for k in 0..d {
                    pack[k * NR_GEMM..(k + 1) * NR_GEMM]
                        .copy_from_slice(&bt[k * n + j0..k * n + j0 + NR_GEMM]);
                }
                for i0 in (ip..ip_end).step_by(MR_GEMM) {
                    micro_4x16(a, &pack, i0, j0, n, d, c);
                }
            }
        }
    });
    // Edges: remaining rows (m_main..m, full width) and remaining
    // columns (all rows, n_main..n).
    if n_main < n {
        for i in 0..m_main {
            let arow = &a[i * d..(i + 1) * d];
            let crow = &mut c[i * n + n_main..(i + 1) * n];
            crow.fill(0.0);
            for (k, &aik) in arow.iter().enumerate() {
                let brow = &bt[k * n + n_main..(k + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += aik * bv;
                }
            }
        }
    }
    for i in m_main..m {
        let arow = &a[i * d..(i + 1) * d];
        let crow = &mut c[i * n..(i + 1) * n];
        crow.fill(0.0);
        for (k, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue; // zero-padded feature dims cost nothing
            }
            let brow = &bt[k * n..(k + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
}

thread_local! {
    // Scratch for the implicit transpose in `gemm_nt` — reused across
    // calls so the hot loop stays allocation-free.
    static GEMM_SCRATCH: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
    // Scratch for the packed BT column panel in `gemm_nt_bt`.
    static PACK_SCRATCH: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
    // Dense scatter row for CSR x CSR dots in `rows_dots` — kept
    // all-zeros between calls so the hot loop only touches nnz entries.
    static SPARSE_SCRATCH: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// `C = A . B^T` for row-major `A: [m, d]`, `B: [n, d]`, `C: [m, n]`.
/// Transposes B once (thread-local scratch) and runs [`gemm_nt_bt`].
pub fn gemm_nt(a: &[f32], b: &[f32], m: usize, n: usize, d: usize, c: &mut [f32]) {
    GEMM_SCRATCH.with(|s| {
        let mut bt = s.borrow_mut();
        transpose(b, n, d, &mut bt);
        gemm_nt_bt(a, &bt, m, n, d, c);
    });
}

/// `f_a = sum_b k(xi_a, xj_b) alpha_b mj_b` — empirical kernel map
/// scores, fused (K tiles contracted immediately, never materialised
/// beyond one `MR x J` strip).
pub fn emp_scores(
    kernel: Kernel,
    xi: &[f32],
    xj: &[f32],
    alpha: &[f32],
    mj: &[f32],
    i: usize,
    j: usize,
    d: usize,
    f: &mut [f32],
) {
    assert_eq!(alpha.len(), j);
    assert_eq!(mj.len(), j);
    assert_eq!(f.len(), i);
    // Masked coefficients once, outside the loop.
    let aw: Vec<f32> = alpha.iter().zip(mj).map(|(a, m)| a * m).collect();
    match kernel {
        Kernel::Rbf { gamma } => {
            let ni = row_norms(xi, i, d);
            let nj = row_norms(xj, j, d);
            // Transpose the expansion block once; each MR-row strip of
            // K is then a vector-friendly gemm_nt_bt and is contracted
            // against alpha while still cache-hot (never materialising
            // the full I x J block — the CPU twin of the Pallas fusion).
            let mut xjt = Vec::new();
            transpose(xj, j, d, &mut xjt);
            let mut strip = vec![0.0f32; MR.min(i.max(1)) * j];
            for i0 in (0..i).step_by(MR) {
                let i1 = (i0 + MR).min(i);
                let rows = i1 - i0;
                gemm_nt_bt(&xi[i0 * d..i1 * d], &xjt, rows, j, d, &mut strip[..rows * j]);
                for r in 0..rows {
                    let na = ni[i0 + r];
                    let mut acc = 0.0f32;
                    let srow = &strip[r * j..(r + 1) * j];
                    for b in 0..j {
                        let d2 = (na + nj[b] - 2.0 * srow[b]).max(0.0);
                        acc += (-gamma * d2).exp() * aw[b];
                    }
                    f[i0 + r] = acc;
                }
            }
        }
        _ => {
            // Generic path for linear/poly: row-at-a-time.
            for a in 0..i {
                let xa = &xi[a * d..(a + 1) * d];
                let mut acc = 0.0f32;
                for b in 0..j {
                    if aw[b] != 0.0 {
                        acc += kernel.eval(xa, &xj[b * d..(b + 1) * d]) * aw[b];
                    }
                }
                f[a] = acc;
            }
        }
    }
}

/// [`Rows`]-polymorphic empirical-kernel-map scores. Dense×dense is
/// exactly [`emp_scores`]; with CSR operands the kernel block is built
/// strip-wise through [`kernel_block_rows`] (MR rows at a time, never
/// materialising `i x j`) and contracted while cache-hot.
pub fn emp_scores_rows(
    kernel: Kernel,
    xi: Rows,
    xj: Rows,
    alpha: &[f32],
    mj: &[f32],
    f: &mut [f32],
) {
    let (i, j, d) = (xi.len(), xj.len(), xi.dim());
    if let (Some(a), Some(b)) = (xi.as_dense(), xj.as_dense()) {
        emp_scores(kernel, a, b, alpha, mj, i, j, d, f);
        return;
    }
    assert_eq!(alpha.len(), j);
    assert_eq!(mj.len(), j);
    assert_eq!(f.len(), i);
    let aw: Vec<f32> = alpha.iter().zip(mj).map(|(a, m)| a * m).collect();
    // Norms once, outside the strip loop (the dense twin hoists them
    // the same way); per-entry values are unchanged.
    let norms = rbf_norms(kernel, xi, xj);
    let mut strip = vec![0.0f32; MR.min(i.max(1)) * j];
    for i0 in (0..i).step_by(MR) {
        let i1 = (i0 + MR).min(i);
        let rows = i1 - i0;
        let strip_norms = norms
            .as_ref()
            .map(|(ni, nj)| (&ni[i0..i1], nj.as_slice()));
        sparse_block_with_norms(
            kernel,
            xi.slice(i0, i1),
            xj,
            strip_norms,
            &mut strip[..rows * j],
        );
        for r in 0..rows {
            let srow = &strip[r * j..(r + 1) * j];
            let mut acc = 0.0f32;
            for b in 0..j {
                acc += srow[b] * aw[b];
            }
            f[i0 + r] = acc;
        }
    }
}

/// `g_b = sum_a k(xi_a, xj_b) r_a` — the transposed contraction of the
/// gradient step (fused, strip-wise over J).
pub fn grad_contract(
    kernel: Kernel,
    xj: &[f32],
    xi: &[f32],
    r: &[f32],
    j: usize,
    i: usize,
    d: usize,
    g: &mut [f32],
) {
    assert_eq!(r.len(), i);
    assert_eq!(g.len(), j);
    match kernel {
        Kernel::Rbf { gamma } => {
            let ni = row_norms(xi, i, d);
            let nj = row_norms(xj, j, d);
            let mut xit = Vec::new();
            transpose(xi, i, d, &mut xit);
            let mut strip = vec![0.0f32; MR.min(j.max(1)) * i];
            for j0 in (0..j).step_by(MR) {
                let j1 = (j0 + MR).min(j);
                let rows = j1 - j0;
                gemm_nt_bt(&xj[j0 * d..j1 * d], &xit, rows, i, d, &mut strip[..rows * i]);
                for rj in 0..rows {
                    let nb = nj[j0 + rj];
                    let mut acc = 0.0f32;
                    let srow = &strip[rj * i..(rj + 1) * i];
                    for a in 0..i {
                        if r[a] != 0.0 {
                            let d2 = (nb + ni[a] - 2.0 * srow[a]).max(0.0);
                            acc += (-gamma * d2).exp() * r[a];
                        }
                    }
                    g[j0 + rj] = acc;
                }
            }
        }
        _ => {
            for b in 0..j {
                let xb = &xj[b * d..(b + 1) * d];
                let mut acc = 0.0f32;
                for a in 0..i {
                    if r[a] != 0.0 {
                        acc += kernel.eval(&xi[a * d..(a + 1) * d], xb) * r[a];
                    }
                }
                g[b] = acc;
            }
        }
    }
}

/// [`Rows`]-polymorphic transposed gradient contraction. Dense×dense is
/// exactly [`grad_contract`]; CSR operands take the strip-wise sparse
/// block path with the same zero-residual skip.
pub fn grad_contract_rows(kernel: Kernel, xj: Rows, xi: Rows, r: &[f32], g: &mut [f32]) {
    let (j, i) = (xj.len(), xi.len());
    if let (Some(b), Some(a)) = (xj.as_dense(), xi.as_dense()) {
        grad_contract(kernel, b, a, r, j, i, xi.dim(), g);
        return;
    }
    assert_eq!(r.len(), i);
    assert_eq!(g.len(), j);
    // Norms once, outside the strip loop (roles swapped: strips run
    // over xj's rows here).
    let norms = rbf_norms(kernel, xj, xi);
    let mut strip = vec![0.0f32; MR.min(j.max(1)) * i];
    for j0 in (0..j).step_by(MR) {
        let j1 = (j0 + MR).min(j);
        let rows = j1 - j0;
        let strip_norms = norms
            .as_ref()
            .map(|(nj, ni)| (&nj[j0..j1], ni.as_slice()));
        sparse_block_with_norms(
            kernel,
            xj.slice(j0, j1),
            xi,
            strip_norms,
            &mut strip[..rows * i],
        );
        for rj in 0..rows {
            let srow = &strip[rj * i..(rj + 1) * i];
            let mut acc = 0.0f32;
            for a in 0..i {
                if r[a] != 0.0 {
                    acc += srow[a] * r[a];
                }
            }
            g[j0 + rj] = acc;
        }
    }
}

/// Outputs of one DSEKL step (mirrors the AOT artifact's output tuple).
#[derive(Clone, Debug, Default)]
pub struct StepOut {
    /// Masked loss sum over the I sample (per the step's [`Loss`]).
    pub loss: f32,
    /// Number of examples with a nonzero residual in the I sample — for
    /// the hinge family this is the count of margin violations.
    pub nactive: f32,
}

/// One doubly-stochastic gradient step — native twin of
/// `model.dsekl_step` (see python/compile/model.py for the math), with a
/// pluggable per-example [`Loss`]: the loss only enters through the
/// residual `r_a = -dL/df_a`, the rest of the step (score contraction,
/// transposed gradient contraction, L2 term) is loss-independent.
///
/// Writes the gradient w.r.t. `alpha[J]` into `g` and returns the
/// loss/active-count diagnostics. `scratch` holds the `f`/`r` buffers so
/// the hot loop never allocates.
#[allow(clippy::too_many_arguments)]
pub fn dsekl_step(
    kernel: Kernel,
    loss: Loss,
    xi: &[f32],
    yi: &[f32],
    mi: &[f32],
    xj: &[f32],
    alpha: &[f32],
    mj: &[f32],
    lam: f32,
    frac: f32,
    i: usize,
    j: usize,
    d: usize,
    g: &mut [f32],
    scratch: &mut StepScratch,
) -> StepOut {
    dsekl_step_rows(
        kernel,
        loss,
        Rows::dense(xi, i, d),
        yi,
        mi,
        Rows::dense(xj, j, d),
        alpha,
        mj,
        lam,
        frac,
        g,
        scratch,
    )
}

/// [`Rows`]-polymorphic DSEKL step: the one implementation of the step
/// arithmetic. The score and gradient contractions dispatch per-layout
/// ([`emp_scores_rows`] / [`grad_contract_rows`]); the residual loop and
/// the regulariser term are layout-independent, so dense inputs are
/// bitwise [`dsekl_step`] and CSR inputs differ from the dense result
/// only by the contraction's accumulation order.
#[allow(clippy::too_many_arguments)]
pub fn dsekl_step_rows(
    kernel: Kernel,
    loss: Loss,
    xi: Rows,
    yi: &[f32],
    mi: &[f32],
    xj: Rows,
    alpha: &[f32],
    mj: &[f32],
    lam: f32,
    frac: f32,
    g: &mut [f32],
    scratch: &mut StepScratch,
) -> StepOut {
    let (i, j) = (xi.len(), xj.len());
    assert_eq!(xi.dim(), xj.dim(), "xi/xj dimensionality mismatch");
    scratch.f.resize(i, 0.0);
    scratch.r.resize(i, 0.0);
    emp_scores_rows(kernel, xi, xj, alpha, mj, &mut scratch.f);
    let mut loss_sum = 0.0f32;
    let mut nactive = 0.0f32;
    for a in 0..i {
        if mi[a] > 0.0 {
            let (v, r) = loss.eval(yi[a], scratch.f[a]);
            scratch.r[a] = r;
            loss_sum += v;
            if r != 0.0 {
                nactive += 1.0;
            }
        } else {
            scratch.r[a] = 0.0;
        }
    }
    grad_contract_rows(kernel, xj, xi, &scratch.r, g);
    for b in 0..j {
        g[b] = (2.0 * lam * frac * alpha[b] - g[b]) * mj[b];
    }
    StepOut {
        loss: loss_sum,
        nactive,
    }
}

/// Reusable buffers for [`dsekl_step`].
#[derive(Default, Debug)]
pub struct StepScratch {
    f: Vec<f32>,
    r: Vec<f32>,
}

/// Reusable buffers for [`dsekl_step_multi`]: the shared `[i, j]` kernel
/// block plus per-head residual/coefficient scratch.
#[derive(Default, Debug)]
pub struct MultiStepScratch {
    block: Vec<f32>,
    r: Vec<f32>,
    aw: Vec<f32>,
}

/// Fused K-head doubly-stochastic gradient step: the `|I| x |J|` kernel
/// block is computed **once** and contracted against `heads` independent
/// coefficient/label heads — the one-vs-rest structure where every class
/// machine draws the identical I/J schedule, so the block is identical
/// across classes and only `(y, alpha)` differ.
///
/// Per-head arithmetic mirrors [`dsekl_step`] operation-for-operation
/// (same accumulation orders, same zero-residual and masked-coefficient
/// skips), so a fused step is **bitwise equal** to `heads` independent
/// single-head steps; `heads == 1` is bitwise equal to [`dsekl_step`].
///
/// Shapes: `yi: [heads, i]`, `alpha: [heads, j]`, `g: [heads, j]`;
/// `mi`/`mj` masks are shared across heads (the padding pattern of a
/// batch does not depend on the class). Returns one [`StepOut`] per head.
#[allow(clippy::too_many_arguments)]
pub fn dsekl_step_multi(
    kernel: Kernel,
    loss: Loss,
    xi: &[f32],
    yi: &[f32],
    mi: &[f32],
    xj: &[f32],
    alpha: &[f32],
    mj: &[f32],
    lam: f32,
    frac: f32,
    heads: usize,
    i: usize,
    j: usize,
    d: usize,
    g: &mut [f32],
    scratch: &mut MultiStepScratch,
) -> Vec<StepOut> {
    dsekl_step_multi_rows(
        kernel,
        loss,
        Rows::dense(xi, i, d),
        yi,
        mi,
        Rows::dense(xj, j, d),
        alpha,
        mj,
        lam,
        frac,
        heads,
        g,
        scratch,
    )
}

/// [`Rows`]-polymorphic fused K-head step: one kernel block (dense GEMM
/// or sparse dots, per [`kernel_block_rows`]), `heads` contractions.
/// Dense inputs are bitwise [`dsekl_step_multi`]'s historical output;
/// CSR inputs are bitwise equal to `heads` independent
/// [`dsekl_step_rows`] calls (the sparse per-entry block values and the
/// per-head accumulation orders are identical).
#[allow(clippy::too_many_arguments)]
pub fn dsekl_step_multi_rows(
    kernel: Kernel,
    loss: Loss,
    xi: Rows,
    yi: &[f32],
    mi: &[f32],
    xj: Rows,
    alpha: &[f32],
    mj: &[f32],
    lam: f32,
    frac: f32,
    heads: usize,
    g: &mut [f32],
    scratch: &mut MultiStepScratch,
) -> Vec<StepOut> {
    let (i, j) = (xi.len(), xj.len());
    assert_eq!(xi.dim(), xj.dim(), "xi/xj dimensionality mismatch");
    assert_eq!(yi.len(), heads * i);
    assert_eq!(alpha.len(), heads * j);
    assert_eq!(g.len(), heads * j);
    scratch.block.resize(i * j, 0.0);
    kernel_block_rows(kernel, xi, xj, &mut scratch.block);
    // Mirror whichever single-head score path these inputs would take,
    // so fused == looped at the bit level: the dense generic (non-RBF)
    // branch skips masked-out coefficients, the dense RBF branch and the
    // sparse strip path never skip.
    let skip_zero_coef =
        !matches!(kernel, Kernel::Rbf { .. }) && xi.is_dense() && xj.is_dense();
    let mut outs = Vec::with_capacity(heads);
    scratch.r.resize(i, 0.0);
    for h in 0..heads {
        let ah = &alpha[h * j..(h + 1) * j];
        let yh = &yi[h * i..(h + 1) * i];
        let gh = &mut g[h * j..(h + 1) * j];
        scratch.aw.clear();
        scratch.aw.extend(ah.iter().zip(mj).map(|(a, m)| a * m));
        let mut loss_sum = 0.0f32;
        let mut nactive = 0.0f32;
        for a in 0..i {
            let brow = &scratch.block[a * j..(a + 1) * j];
            let mut f = 0.0f32;
            if skip_zero_coef {
                for b in 0..j {
                    if scratch.aw[b] != 0.0 {
                        f += brow[b] * scratch.aw[b];
                    }
                }
            } else {
                for b in 0..j {
                    f += brow[b] * scratch.aw[b];
                }
            }
            if mi[a] > 0.0 {
                let (v, r) = loss.eval(yh[a], f);
                scratch.r[a] = r;
                loss_sum += v;
                if r != 0.0 {
                    nactive += 1.0;
                }
            } else {
                scratch.r[a] = 0.0;
            }
        }
        // Transposed contraction, row-wise over the shared block: each
        // g[b] accumulates over ascending `a` exactly like grad_contract.
        gh.fill(0.0);
        for a in 0..i {
            let ra = scratch.r[a];
            if ra != 0.0 {
                let brow = &scratch.block[a * j..(a + 1) * j];
                for b in 0..j {
                    gh[b] += brow[b] * ra;
                }
            }
        }
        for b in 0..j {
            gh[b] = (2.0 * lam * frac * ah[b] - gh[b]) * mj[b];
        }
        outs.push(StepOut {
            loss: loss_sum,
            nactive,
        });
    }
    outs
}

/// Fused K-head empirical-kernel-map scores: `f[a, h] = sum_b k(xt_a,
/// xj_b) coef[h, b] mj_b` with the kernel row computed **once** per test
/// point and contracted against all heads — one pass over the expansion
/// for a whole `[t, heads]` score matrix. Bitwise equal to running
/// [`emp_scores`] once per head.
#[allow(clippy::too_many_arguments)]
pub fn predict_multi(
    kernel: Kernel,
    xt: &[f32],
    xj: &[f32],
    coef: &[f32],
    mj: &[f32],
    heads: usize,
    t: usize,
    j: usize,
    d: usize,
    f: &mut [f32],
) {
    assert_eq!(coef.len(), heads * j);
    assert_eq!(mj.len(), j);
    assert_eq!(f.len(), t * heads);
    // Masked per-head coefficients once, mirroring emp_scores.
    let mut aw = Vec::with_capacity(heads * j);
    for h in 0..heads {
        aw.extend(coef[h * j..(h + 1) * j].iter().zip(mj).map(|(a, m)| a * m));
    }
    match kernel {
        Kernel::Rbf { gamma } => {
            let ni = row_norms(xt, t, d);
            let nj = row_norms(xj, j, d);
            let mut xjt = Vec::new();
            transpose(xj, j, d, &mut xjt);
            let mut strip = vec![0.0f32; MR.min(t.max(1)) * j];
            for i0 in (0..t).step_by(MR) {
                let i1 = (i0 + MR).min(t);
                let rows = i1 - i0;
                gemm_nt_bt(&xt[i0 * d..i1 * d], &xjt, rows, j, d, &mut strip[..rows * j]);
                for r in 0..rows {
                    let na = ni[i0 + r];
                    let srow = &mut strip[r * j..(r + 1) * j];
                    // Exponentiate the row in place, then reuse it for
                    // every head while still cache-hot.
                    for b in 0..j {
                        let d2 = (na + nj[b] - 2.0 * srow[b]).max(0.0);
                        srow[b] = (-gamma * d2).exp();
                    }
                    for h in 0..heads {
                        let awh = &aw[h * j..(h + 1) * j];
                        let mut acc = 0.0f32;
                        for b in 0..j {
                            acc += srow[b] * awh[b];
                        }
                        f[(i0 + r) * heads + h] = acc;
                    }
                }
            }
        }
        _ => {
            let mut kv = vec![0.0f32; j];
            for a in 0..t {
                let xa = &xt[a * d..(a + 1) * d];
                for (b, v) in kv.iter_mut().enumerate() {
                    *v = kernel.eval(xa, &xj[b * d..(b + 1) * d]);
                }
                for h in 0..heads {
                    let awh = &aw[h * j..(h + 1) * j];
                    let mut acc = 0.0f32;
                    for b in 0..j {
                        if awh[b] != 0.0 {
                            acc += kv[b] * awh[b];
                        }
                    }
                    f[a * heads + h] = acc;
                }
            }
        }
    }
}

/// [`Rows`]-polymorphic fused K-head scores. Dense×dense is exactly
/// [`predict_multi`]; with CSR operands the kernel rows are built in MR
/// strips through [`kernel_block_rows`] and contracted against every
/// head while cache-hot — the same strip pattern as
/// [`emp_scores_rows`], so fused CSR scores are bitwise equal to one
/// [`emp_scores_rows`] call per head.
#[allow(clippy::too_many_arguments)]
pub fn predict_multi_rows(
    kernel: Kernel,
    xt: Rows,
    xj: Rows,
    coef: &[f32],
    mj: &[f32],
    heads: usize,
    f: &mut [f32],
) {
    let (t, j, d) = (xt.len(), xj.len(), xt.dim());
    if let (Some(a), Some(b)) = (xt.as_dense(), xj.as_dense()) {
        predict_multi(kernel, a, b, coef, mj, heads, t, j, d, f);
        return;
    }
    assert_eq!(coef.len(), heads * j);
    assert_eq!(mj.len(), j);
    assert_eq!(f.len(), t * heads);
    let mut aw = Vec::with_capacity(heads * j);
    for h in 0..heads {
        aw.extend(coef[h * j..(h + 1) * j].iter().zip(mj).map(|(a, m)| a * m));
    }
    // Norms once, outside the strip loop, like emp_scores_rows.
    let norms = rbf_norms(kernel, xt, xj);
    let mut strip = vec![0.0f32; MR.min(t.max(1)) * j];
    for i0 in (0..t).step_by(MR) {
        let i1 = (i0 + MR).min(t);
        let rows = i1 - i0;
        let strip_norms = norms
            .as_ref()
            .map(|(ni, nj)| (&ni[i0..i1], nj.as_slice()));
        sparse_block_with_norms(
            kernel,
            xt.slice(i0, i1),
            xj,
            strip_norms,
            &mut strip[..rows * j],
        );
        for r in 0..rows {
            let srow = &strip[r * j..(r + 1) * j];
            for h in 0..heads {
                let awh = &aw[h * j..(h + 1) * j];
                let mut acc = 0.0f32;
                for b in 0..j {
                    acc += srow[b] * awh[b];
                }
                f[(i0 + r) * heads + h] = acc;
            }
        }
    }
}

/// Random Fourier features `phi = sqrt(2/R) cos(x W + b)` —
/// native twin of `kernels.rff_features`.
pub fn rff_features(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    n: usize,
    d: usize,
    r: usize,
    phi: &mut [f32],
) {
    assert_eq!(x.len(), n * d);
    assert_eq!(w.len(), d * r);
    assert_eq!(b.len(), r);
    assert_eq!(phi.len(), n * r);
    let scale = (2.0f32 / r as f32).sqrt();
    // x [n,d] . w [d,r]: w is already in the [d, n'] layout gemm_nt_bt
    // wants, so no transpose is needed at all.
    gemm_nt_bt(x, w, n, r, d, phi);
    for a in 0..n {
        let row = &mut phi[a * r..(a + 1) * r];
        for (v, bb) in row.iter_mut().zip(b) {
            *v = scale * (*v + bb).cos();
        }
    }
}

/// [`Rows`]-polymorphic random Fourier features. Dense input is exactly
/// [`rff_features`]; CSR rows accumulate `x W` over stored entries only
/// (`O(nnz * r)` instead of `O(n d r)`).
pub fn rff_features_rows(x: Rows, w: &[f32], b: &[f32], r: usize, phi: &mut [f32]) {
    let (n, d) = (x.len(), x.dim());
    assert_eq!(w.len(), d * r);
    assert_eq!(b.len(), r);
    assert_eq!(phi.len(), n * r);
    let c = match x {
        Rows::Dense { x: xd, .. } => {
            rff_features(xd, w, b, n, d, r, phi);
            return;
        }
        Rows::Csr(c) => c,
    };
    let scale = (2.0f32 / r as f32).sqrt();
    for a in 0..n {
        let prow = &mut phi[a * r..(a + 1) * r];
        prow.fill(0.0);
        let (cols, vals) = c.row(a);
        for (col, v) in cols.iter().zip(vals) {
            let wrow = &w[*col as usize * r..(*col as usize + 1) * r];
            for (p, wv) in prow.iter_mut().zip(wrow) {
                *p += *v * wv;
            }
        }
        for (p, bb) in prow.iter_mut().zip(b) {
            *p = scale * (*p + bb).cos();
        }
    }
}

/// One RKS linear-model SGD step — native twin of `model.rks_step`, with
/// the same pluggable [`Loss`] as [`dsekl_step`] (the hinge instance is
/// the paper's linear SVM in RFF space).
#[allow(clippy::too_many_arguments)]
pub fn rks_step(
    loss: Loss,
    xi: &[f32],
    yi: &[f32],
    mi: &[f32],
    w_feat: &[f32],
    b_feat: &[f32],
    w: &[f32],
    lam: f32,
    frac: f32,
    i: usize,
    d: usize,
    r: usize,
    g: &mut [f32],
) -> StepOut {
    rks_step_rows(
        loss,
        Rows::dense(xi, i, d),
        yi,
        mi,
        w_feat,
        b_feat,
        w,
        lam,
        frac,
        r,
        g,
    )
}

/// [`Rows`]-polymorphic RKS step: dense input is bitwise [`rks_step`];
/// CSR input builds the RFF features from stored entries only.
#[allow(clippy::too_many_arguments)]
pub fn rks_step_rows(
    loss: Loss,
    xi: Rows,
    yi: &[f32],
    mi: &[f32],
    w_feat: &[f32],
    b_feat: &[f32],
    w: &[f32],
    lam: f32,
    frac: f32,
    r: usize,
    g: &mut [f32],
) -> StepOut {
    let i = xi.len();
    let mut phi = vec![0.0f32; i * r];
    rff_features_rows(xi, w_feat, b_feat, r, &mut phi);
    let mut loss_sum = 0.0f32;
    let mut nactive = 0.0f32;
    g.iter_mut()
        .zip(w)
        .for_each(|(gv, &wv)| *gv = 2.0 * lam * frac * wv);
    for a in 0..i {
        if mi[a] <= 0.0 {
            continue;
        }
        let prow = &phi[a * r..(a + 1) * r];
        let f: f32 = prow.iter().zip(w).map(|(p, wv)| p * wv).sum();
        let (v, res) = loss.eval(yi[a], f);
        loss_sum += v;
        if res != 0.0 {
            nactive += 1.0;
            for (gv, p) in g.iter_mut().zip(prow) {
                *gv -= res * p;
            }
        }
    }
    StepOut {
        loss: loss_sum,
        nactive,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    fn randv(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// Naive O(i*j*d) oracle.
    fn naive_block(k: Kernel, xi: &[f32], xj: &[f32], i: usize, j: usize, d: usize) -> Vec<f32> {
        let mut out = vec![0.0; i * j];
        for a in 0..i {
            for b in 0..j {
                out[a * j + b] = k.eval(&xi[a * d..(a + 1) * d], &xj[b * d..(b + 1) * d]);
            }
        }
        out
    }

    #[test]
    fn gemm_matches_naive() {
        let mut rng = Pcg64::seed_from(1);
        for &(m, n, d) in &[(1usize, 1usize, 1usize), (3, 5, 7), (17, 33, 9), (64, 64, 54), (100, 30, 2)] {
            let a = randv(&mut rng, m * d);
            let b = randv(&mut rng, n * d);
            let mut c = vec![0.0; m * n];
            gemm_nt(&a, &b, m, n, d, &mut c);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = (0..d).map(|k| a[i * d + k] * b[j * d + k]).sum();
                    assert!(
                        (c[i * n + j] - want).abs() < 1e-4 * (1.0 + want.abs()),
                        "({m},{n},{d}) at ({i},{j}): {} vs {want}",
                        c[i * n + j]
                    );
                }
            }
        }
    }

    #[test]
    fn kernel_block_matches_naive_all_kernels() {
        let mut rng = Pcg64::seed_from(2);
        let (i, j, d) = (23, 17, 6);
        let xi = randv(&mut rng, i * d);
        let xj = randv(&mut rng, j * d);
        for k in [
            Kernel::rbf(0.5),
            Kernel::Linear,
            Kernel::Poly {
                gamma: 0.3,
                degree: 3,
                coef0: 1.0,
            },
        ] {
            let mut out = vec![0.0; i * j];
            kernel_block(k, &xi, &xj, i, j, d, &mut out);
            let want = naive_block(k, &xi, &xj, i, j, d);
            for (a, b) in out.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b} ({k:?})");
            }
        }
    }

    #[test]
    fn emp_scores_matches_naive() {
        let mut rng = Pcg64::seed_from(3);
        let (i, j, d) = (41, 29, 5);
        let xi = randv(&mut rng, i * d);
        let xj = randv(&mut rng, j * d);
        let alpha = randv(&mut rng, j);
        let mut mj = vec![1.0f32; j];
        mj[3] = 0.0;
        mj[7] = 0.0;
        let k = Kernel::rbf(0.7);
        let kb = naive_block(k, &xi, &xj, i, j, d);
        let mut f = vec![0.0; i];
        emp_scores(k, &xi, &xj, &alpha, &mj, i, j, d, &mut f);
        for a in 0..i {
            let want: f32 = (0..j).map(|b| kb[a * j + b] * alpha[b] * mj[b]).sum();
            assert!((f[a] - want).abs() < 1e-4 * (1.0 + want.abs()));
        }
    }

    #[test]
    fn grad_contract_matches_naive() {
        let mut rng = Pcg64::seed_from(4);
        let (i, j, d) = (31, 19, 4);
        let xi = randv(&mut rng, i * d);
        let xj = randv(&mut rng, j * d);
        let r = randv(&mut rng, i);
        let k = Kernel::rbf(0.9);
        let kb = naive_block(k, &xi, &xj, i, j, d);
        let mut g = vec![0.0; j];
        grad_contract(k, &xj, &xi, &r, j, i, d, &mut g);
        for b in 0..j {
            let want: f32 = (0..i).map(|a| kb[a * j + b] * r[a]).sum();
            assert!((g[b] - want).abs() < 1e-4 * (1.0 + want.abs()));
        }
    }

    #[test]
    fn step_descends_objective() {
        // E(alpha - eta g) < E(alpha) on the same batch, full masks.
        let mut rng = Pcg64::seed_from(5);
        let (i, j, d) = (64, 32, 3);
        let xi = randv(&mut rng, i * d);
        let yi: Vec<f32> = (0..i).map(|_| rng.sign()).collect();
        let mi = vec![1.0f32; i];
        let xj = xi[..j * d].to_vec();
        let alpha = randv(&mut rng, j).iter().map(|v| v * 0.1).collect::<Vec<_>>();
        let mj = vec![1.0f32; j];
        let k = Kernel::rbf(0.5);
        let lam = 1e-3;
        let energy = |a: &[f32]| -> f32 {
            let mut f = vec![0.0; i];
            emp_scores(k, &xi, &xj, a, &mj, i, j, d, &mut f);
            let hinge: f32 = (0..i).map(|t| (1.0 - yi[t] * f[t]).max(0.0)).sum();
            hinge + lam * a.iter().map(|v| v * v).sum::<f32>()
        };
        let mut g = vec![0.0; j];
        let mut scratch = StepScratch::default();
        dsekl_step(
            k,
            Loss::Hinge,
            &xi,
            &yi,
            &mi,
            &xj,
            &alpha,
            &mj,
            lam,
            1.0,
            i,
            j,
            d,
            &mut g,
            &mut scratch,
        );
        let stepped: Vec<f32> = alpha.iter().zip(&g).map(|(a, gv)| a - 1e-3 * gv).collect();
        assert!(energy(&stepped) < energy(&alpha));
    }

    #[test]
    fn step_descends_objective_every_loss() {
        // One small step reduces E(alpha) = sum loss + lam |alpha|^2 on
        // the same batch, for all four losses.
        let mut rng = Pcg64::seed_from(15);
        let (i, j, d) = (48, 24, 3);
        let xi = randv(&mut rng, i * d);
        let yi: Vec<f32> = (0..i).map(|_| rng.sign()).collect();
        let mi = vec![1.0f32; i];
        let xj = xi[..j * d].to_vec();
        let alpha: Vec<f32> = randv(&mut rng, j).iter().map(|v| v * 0.05).collect();
        let mj = vec![1.0f32; j];
        let k = Kernel::rbf(0.5);
        let lam = 1e-3;
        for loss in crate::loss::ALL_LOSSES {
            let energy = |a: &[f32]| -> f64 {
                let mut f = vec![0.0; i];
                emp_scores(k, &xi, &xj, a, &mj, i, j, d, &mut f);
                let data: f64 = (0..i).map(|t| loss.value(yi[t], f[t]) as f64).sum();
                data + lam as f64 * a.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>()
            };
            let mut g = vec![0.0; j];
            let mut s = StepScratch::default();
            dsekl_step(
                k,
                loss,
                &xi,
                &yi,
                &mi,
                &xj,
                &alpha,
                &mj,
                lam as f32,
                1.0,
                i,
                j,
                d,
                &mut g,
                &mut s,
            );
            let stepped: Vec<f32> = alpha.iter().zip(&g).map(|(a, gv)| a - 1e-3 * gv).collect();
            assert!(
                energy(&stepped) < energy(&alpha),
                "{loss}: step did not descend"
            );
        }
    }

    #[test]
    fn step_zero_alpha_all_active() {
        let mut rng = Pcg64::seed_from(6);
        let (i, j, d) = (16, 8, 2);
        let xi = randv(&mut rng, i * d);
        let yi: Vec<f32> = (0..i).map(|_| rng.sign()).collect();
        let mi = vec![1.0f32; i];
        let xj = randv(&mut rng, j * d);
        let alpha = vec![0.0f32; j];
        let mj = vec![1.0f32; j];
        let mut g = vec![0.0; j];
        let mut s = StepScratch::default();
        let out = dsekl_step(
            Kernel::rbf(1.0),
            Loss::Hinge,
            &xi,
            &yi,
            &mi,
            &xj,
            &alpha,
            &mj,
            1e-3,
            0.5,
            i,
            j,
            d,
            &mut g,
            &mut s,
        );
        assert_eq!(out.nactive, i as f32);
        assert!((out.loss - i as f32).abs() < 1e-5);
    }

    #[test]
    fn step_masked_rows_noop() {
        let mut rng = Pcg64::seed_from(7);
        let (i, j, d) = (20, 12, 3);
        let xi = randv(&mut rng, i * d);
        let yi: Vec<f32> = (0..i).map(|_| rng.sign()).collect();
        let xj = randv(&mut rng, j * d);
        let alpha = randv(&mut rng, j);
        let mj = vec![1.0f32; j];
        let k = Kernel::rbf(0.5);
        let mut s = StepScratch::default();

        // Full batch of 20 with last 4 masked out...
        let mut mi = vec![1.0f32; i];
        mi[16..].fill(0.0);
        let mut g1 = vec![0.0; j];
        let o1 = dsekl_step(
            k,
            Loss::Hinge,
            &xi,
            &yi,
            &mi,
            &xj,
            &alpha,
            &mj,
            1e-3,
            0.5,
            i,
            j,
            d,
            &mut g1,
            &mut s,
        );
        // ...equals the unpadded batch of 16.
        let mut g2 = vec![0.0; j];
        let o2 = dsekl_step(
            k,
            Loss::Hinge,
            &xi[..16 * d],
            &yi[..16],
            &vec![1.0; 16],
            &xj,
            &alpha,
            &mj,
            1e-3,
            0.5,
            16,
            j,
            d,
            &mut g2,
            &mut s,
        );
        for (a, b) in g1.iter().zip(&g2) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        assert_eq!(o1.nactive, o2.nactive);
        assert!((o1.loss - o2.loss).abs() < 1e-4);
    }

    /// Random CSR rows at the given density, plus their dense copy.
    fn rand_sparse(
        rng: &mut Pcg64,
        n: usize,
        d: usize,
        density: f64,
    ) -> (crate::data::SparseDataset, Vec<f32>) {
        let mut ds = crate::data::SparseDataset::with_dim(d);
        for _ in 0..n {
            let mut cols = Vec::new();
            let mut vals = Vec::new();
            for c in 0..d {
                if rng.range_f64(0.0, 1.0) < density {
                    cols.push(c as u32);
                    vals.push(rng.normal() as f32);
                }
            }
            ds.push(&cols, &vals, 1.0);
        }
        let x = ds.densify_x();
        (ds, x)
    }

    #[test]
    fn rows_dots_and_norms_match_dense() {
        let mut rng = Pcg64::seed_from(31);
        let (i, j, d) = (13, 9, 24);
        let (si, xi) = rand_sparse(&mut rng, i, d, 0.3);
        let (sj, xj) = rand_sparse(&mut rng, j, d, 0.3);
        let mut sparse = vec![0.0f32; i * j];
        rows_dots(si.rows(), sj.rows(), &mut sparse);
        for a in 0..i {
            for b in 0..j {
                let want: f32 = (0..d).map(|k| xi[a * d + k] * xj[b * d + k]).sum();
                let got = sparse[a * j + b];
                assert!((got - want).abs() < 1e-5 * (1.0 + want.abs()), "{got} vs {want}");
            }
        }
        // Mixed layouts agree with the all-sparse result.
        let mut mixed = vec![0.0f32; i * j];
        rows_dots(si.rows(), Rows::dense(&xj, j, d), &mut mixed);
        for (a, b) in sparse.iter().zip(&mixed) {
            assert!((a - b).abs() < 1e-6);
        }
        rows_dots(Rows::dense(&xi, i, d), sj.rows(), &mut mixed);
        for (a, b) in sparse.iter().zip(&mixed) {
            assert!((a - b).abs() < 1e-6);
        }
        let nd = row_norms(&xi, i, d);
        let ns = rows_norms(si.rows());
        for (a, b) in nd.iter().zip(&ns) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn kernel_block_rows_matches_dense_all_kernels() {
        let mut rng = Pcg64::seed_from(32);
        let (i, j, d) = (17, 11, 20);
        let (si, xi) = rand_sparse(&mut rng, i, d, 0.25);
        let (sj, xj) = rand_sparse(&mut rng, j, d, 0.25);
        for k in [
            Kernel::rbf(0.4),
            Kernel::Linear,
            Kernel::Poly {
                gamma: 0.3,
                degree: 2,
                coef0: 1.0,
            },
        ] {
            let mut dense = vec![0.0f32; i * j];
            kernel_block(k, &xi, &xj, i, j, d, &mut dense);
            let mut sparse = vec![0.0f32; i * j];
            kernel_block_rows(k, si.rows(), sj.rows(), &mut sparse);
            for (a, b) in sparse.iter().zip(&dense) {
                assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{k:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn sparse_step_matches_dense_step() {
        let mut rng = Pcg64::seed_from(33);
        let (i, j, d) = (24, 16, 30);
        let (si, xi) = rand_sparse(&mut rng, i, d, 0.2);
        let (sj, xj) = rand_sparse(&mut rng, j, d, 0.2);
        let yi: Vec<f32> = (0..i).map(|_| rng.sign()).collect();
        let mi = vec![1.0f32; i];
        let mj = vec![1.0f32; j];
        let alpha: Vec<f32> = randv(&mut rng, j).iter().map(|v| v * 0.05).collect();
        let k = Kernel::rbf(0.3);
        let mut gd = vec![0.0f32; j];
        let mut gs = vec![0.0f32; j];
        let mut s1 = StepScratch::default();
        let mut s2 = StepScratch::default();
        let od = dsekl_step(
            k, Loss::Hinge, &xi, &yi, &mi, &xj, &alpha, &mj, 1e-3, 0.5, i, j, d, &mut gd, &mut s1,
        );
        let os = dsekl_step_rows(
            k,
            Loss::Hinge,
            si.rows(),
            &yi,
            &mi,
            sj.rows(),
            &alpha,
            &mj,
            1e-3,
            0.5,
            &mut gs,
            &mut s2,
        );
        for (a, b) in gs.iter().zip(&gd) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
        assert_eq!(os.nactive, od.nactive);
        assert!((os.loss - od.loss).abs() < 1e-3 * (1.0 + od.loss.abs()));
    }

    #[test]
    fn sparse_rff_matches_dense() {
        let mut rng = Pcg64::seed_from(34);
        let (n, d, r) = (9, 12, 8);
        let (sn, x) = rand_sparse(&mut rng, n, d, 0.3);
        let w = randv(&mut rng, d * r);
        let b: Vec<f32> = (0..r).map(|_| rng.range_f64(0.0, 6.28) as f32).collect();
        let mut pd = vec![0.0f32; n * r];
        rff_features(&x, &w, &b, n, d, r, &mut pd);
        let mut ps = vec![0.0f32; n * r];
        rff_features_rows(sn.rows(), &w, &b, r, &mut ps);
        for (a, b) in ps.iter().zip(&pd) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn rff_matches_definition() {
        let mut rng = Pcg64::seed_from(8);
        let (n, d, r) = (13, 5, 17);
        let x = randv(&mut rng, n * d);
        let w = randv(&mut rng, d * r);
        let b: Vec<f32> = (0..r).map(|_| rng.range_f64(0.0, 6.28) as f32).collect();
        let mut phi = vec![0.0; n * r];
        rff_features(&x, &w, &b, n, d, r, &mut phi);
        let scale = (2.0f32 / r as f32).sqrt();
        for a in 0..n {
            for c in 0..r {
                let proj: f32 = (0..d).map(|k| x[a * d + k] * w[k * r + c]).sum();
                let want = scale * (proj + b[c]).cos();
                assert!((phi[a * r + c] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn rks_step_gradient_check() {
        // Finite-difference check of the RKS objective gradient at a
        // point with all margins strictly active (smooth region).
        let mut rng = Pcg64::seed_from(9);
        let (i, d, r) = (24, 4, 8);
        let xi = randv(&mut rng, i * d);
        let yi: Vec<f32> = (0..i).map(|_| rng.sign()).collect();
        let mi = vec![1.0f32; i];
        let w_feat = randv(&mut rng, d * r);
        let b_feat: Vec<f32> = (0..r).map(|_| rng.range_f64(0.0, 6.28) as f32).collect();
        let w = vec![0.0f32; r]; // all margins active at w = 0
        let lam = 1e-2;
        let obj = |wv: &[f32]| -> f64 {
            let mut phi = vec![0.0; i * r];
            rff_features(&xi, &w_feat, &b_feat, i, d, r, &mut phi);
            let mut e = lam as f64 * wv.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>();
            for a in 0..i {
                let f: f32 = phi[a * r..(a + 1) * r].iter().zip(wv).map(|(p, v)| p * v).sum();
                e += ((1.0 - yi[a] * f) as f64).max(0.0);
            }
            e
        };
        let mut g = vec![0.0; r];
        rks_step(
            Loss::Hinge,
            &xi,
            &yi,
            &mi,
            &w_feat,
            &b_feat,
            &w,
            lam,
            1.0,
            i,
            d,
            r,
            &mut g,
        );
        let eps = 1e-3;
        for c in 0..r {
            let mut wp = w.clone();
            wp[c] += eps;
            let mut wm = w.clone();
            wm[c] -= eps;
            let fd = (obj(&wp) - obj(&wm)) / (2.0 * eps as f64);
            assert!(
                (fd - g[c] as f64).abs() < 1e-2 * (1.0 + fd.abs()),
                "coord {c}: fd {fd} vs g {}",
                g[c]
            );
        }
    }
}
