//! Hyper-parameter optimisation: exhaustive grid search with k-fold
//! cross-validation, matching the paper's §4 protocol ("two-fold
//! cross-validation and exhaustive grid search for all models;
//! logarithmic grid from 1e-6 to 1e6").

use crate::data::Dataset;
use crate::estimator::{Estimator, FitBackend, TrainSet};
use crate::rng::{Pcg64, Rng};
use crate::solver::dsekl::{DseklOpts, DseklSolver};
use crate::solver::LrSchedule;
use crate::{Error, Result};

/// Logarithmic grid `10^lo ..= 10^hi` (inclusive, integer exponents).
pub fn log_grid(lo: i32, hi: i32) -> Vec<f32> {
    (lo..=hi).map(|e| 10f32.powi(e)).collect()
}

/// k-fold index split: returns `k` (train, val) index pairs.
pub fn kfold<R: Rng>(n: usize, k: usize, rng: &mut R) -> Vec<(Vec<usize>, Vec<usize>)> {
    assert!(k >= 2 && k <= n, "kfold needs 2 <= k <= n");
    let mut idx: Vec<usize> = (0..n).collect();
    for i in (1..n).rev() {
        let j = rng.below(i + 1);
        idx.swap(i, j);
    }
    let mut folds = Vec::with_capacity(k);
    for f in 0..k {
        let lo = f * n / k;
        let hi = (f + 1) * n / k;
        let val: Vec<usize> = idx[lo..hi].to_vec();
        let train: Vec<usize> = idx[..lo].iter().chain(&idx[hi..]).copied().collect();
        folds.push((train, val));
    }
    folds
}

/// A candidate hyper-parameter point for the DSEKL solver.
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    pub gamma: f32,
    pub lam: f32,
    pub eta0: f32,
}

/// Grid definition. Defaults mirror the paper's ranges but trimmed to
/// the decades that matter after standardisation (the full 1e-6..1e6
/// sweep is available via [`GridSpec::paper_full`]).
#[derive(Debug, Clone)]
pub struct GridSpec {
    pub gammas: Vec<f32>,
    pub lams: Vec<f32>,
    pub eta0s: Vec<f32>,
}

impl Default for GridSpec {
    fn default() -> Self {
        GridSpec {
            gammas: log_grid(-3, 1),
            lams: log_grid(-6, -1),
            eta0s: vec![0.1, 1.0, 10.0],
        }
    }
}

impl GridSpec {
    /// The paper's full logarithmic ranges (1e-6..1e6 for gamma/lambda,
    /// 1e-4..1e4 for the step size). 13*13*9 = 1521 candidates — use on
    /// small sets only.
    pub fn paper_full() -> Self {
        GridSpec {
            gammas: log_grid(-6, 6),
            lams: log_grid(-6, 6),
            eta0s: log_grid(-4, 4),
        }
    }

    /// Materialise the cartesian product.
    pub fn candidates(&self) -> Vec<Candidate> {
        let mut out = Vec::new();
        for &gamma in &self.gammas {
            for &lam in &self.lams {
                for &eta0 in &self.eta0s {
                    out.push(Candidate { gamma, lam, eta0 });
                }
            }
        }
        out
    }
}

/// Result of a grid search.
#[derive(Debug, Clone)]
pub struct GridResult {
    pub best: Candidate,
    pub best_cv_error: f64,
    /// (candidate, mean CV error) for every grid point, in search order.
    pub all: Vec<(Candidate, f64)>,
}

/// Exhaustive grid search with k-fold CV for the DSEKL solver. `base`
/// supplies the non-searched options (batch sizes, iteration budget).
/// Candidates train through the unified [`Estimator`] layer, so the
/// search exercises the same path as every other training surface.
pub fn grid_search_dsekl(
    backend: &mut FitBackend,
    data: &Dataset,
    base: &DseklOpts,
    spec: &GridSpec,
    folds: usize,
    seed: u64,
) -> Result<GridResult> {
    let n = data.len();
    if n < folds || folds < 2 {
        return Err(Error::invalid(format!(
            "need >= {folds} examples for {folds}-fold CV, have {n}"
        )));
    }
    let mut rng = Pcg64::seed_from(seed);
    let fold_idx = kfold(n, folds, &mut rng);
    let mut all = Vec::new();
    let mut best: Option<(Candidate, f64)> = None;
    for cand in spec.candidates() {
        let mut errs = Vec::with_capacity(folds);
        for (train_i, val_i) in &fold_idx {
            let train = data.subset(train_i);
            let val = data.subset(val_i);
            let opts = DseklOpts {
                gamma: cand.gamma,
                lam: cand.lam,
                lr: LrSchedule::InvT { eta0: cand.eta0 },
                ..base.clone()
            };
            let mut fold_rng = rng.split(0xC0FFEE);
            let fitted =
                DseklSolver::new(opts).fit(backend, TrainSet::from(&train), &mut fold_rng)?;
            let val_set = TrainSet::from(&val);
            errs.push(fitted.predictor.error(backend.leader()?, &val_set)?);
        }
        let mean = errs.iter().sum::<f64>() / errs.len() as f64;
        if best.as_ref().map(|(_, e)| mean < *e).unwrap_or(true) {
            best = Some((cand.clone(), mean));
        }
        all.push((cand, mean));
    }
    let (best, best_cv_error) = best.expect("non-empty grid");
    Ok(GridResult {
        best,
        best_cv_error,
        all,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn log_grid_values() {
        assert_eq!(log_grid(-2, 1), vec![0.01, 0.1, 1.0, 10.0]);
    }

    #[test]
    fn kfold_partitions() {
        let mut rng = Pcg64::seed_from(1);
        let folds = kfold(10, 2, &mut rng);
        assert_eq!(folds.len(), 2);
        for (tr, va) in &folds {
            assert_eq!(tr.len() + va.len(), 10);
            let mut all: Vec<usize> = tr.iter().chain(va.iter()).copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..10).collect::<Vec<_>>());
        }
        // The two validation folds partition the data.
        let mut v: Vec<usize> = folds[0].1.iter().chain(&folds[1].1).copied().collect();
        v.sort_unstable();
        assert_eq!(v, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn kfold_uneven_sizes() {
        let mut rng = Pcg64::seed_from(2);
        let folds = kfold(11, 3, &mut rng);
        let total: usize = folds.iter().map(|(_, v)| v.len()).sum();
        assert_eq!(total, 11);
    }

    #[test]
    fn candidates_cartesian() {
        let spec = GridSpec {
            gammas: vec![0.1, 1.0],
            lams: vec![1e-3],
            eta0s: vec![1.0, 2.0, 3.0],
        };
        assert_eq!(spec.candidates().len(), 6);
    }

    #[test]
    fn grid_search_picks_sane_gamma_on_xor() {
        // On XOR with std 0.2, gamma must be O(1): gamma = 1e-3 makes all
        // kernel values ~1 (underfit). The search should not pick the
        // degenerate end of the grid.
        let mut rng = Pcg64::seed_from(3);
        let ds = synth::xor(80, 0.2, &mut rng);
        let mut be = FitBackend::native();
        let base = DseklOpts {
            i_size: 20,
            j_size: 20,
            max_iters: 120,
            ..Default::default()
        };
        let spec = GridSpec {
            gammas: vec![1e-3, 1.0],
            lams: vec![1e-4],
            eta0s: vec![1.0],
        };
        let res = grid_search_dsekl(&mut be, &ds, &base, &spec, 2, 42).unwrap();
        assert_eq!(res.all.len(), 2);
        assert_eq!(res.best.gamma, 1.0);
        assert!(res.best_cv_error < 0.2);
    }

    #[test]
    fn grid_search_input_validation() {
        let ds = synth::xor(3, 0.2, &mut Pcg64::seed_from(1));
        let mut be = FitBackend::native();
        let base = DseklOpts::default();
        assert!(grid_search_dsekl(&mut be, &ds, &base, &GridSpec::default(), 5, 1).is_err());
    }
}
