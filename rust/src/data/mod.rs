//! Data substrate: the in-memory dataset representation, libsvm-format
//! loading, feature scaling, and train/test splitting.
//!
//! The paper evaluates on libsvm binary-classification sets and UCI
//! covertype; the offline environment has no network, so
//! [`synth`] provides generators matched to each set's size,
//! dimensionality, sparsity and class geometry (DESIGN.md §4,
//! "Substitutions").

pub mod libsvm;
pub mod sparse;
pub mod synth;

pub use sparse::{
    CsrBatch, CsrBlock, CsrRows, GatherBatch, Rows, SparseDataset, SparseMultiDataset,
};

use crate::rng::{Rng, sample_without_replacement};

/// Dense row-major binary-classification dataset.
///
/// Labels are `{-1.0, +1.0}` f32, matching the SVM formulation (Eq. 3/4
/// of the paper). Dense storage is deliberate: the PJRT artifacts and the
/// native compute backend both consume dense `[rows, d]` tiles, and even
/// "sparse" sets in the paper's table (mushrooms, madelon) are small
/// enough that density costs nothing at these scales.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Row-major features, `len == n * d`.
    pub x: Vec<f32>,
    /// Labels in {-1, +1}, `len == n`.
    pub y: Vec<f32>,
    /// Number of feature dimensions.
    pub d: usize,
}

impl Dataset {
    /// Empty dataset with fixed dimensionality.
    pub fn with_dim(d: usize) -> Self {
        Dataset {
            x: Vec::new(),
            y: Vec::new(),
            d,
        }
    }

    /// Number of examples.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    /// True if the dataset holds no examples.
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Feature row `i`.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Borrowed [`Rows`] view over all feature rows — the dense half of
    /// the gather abstraction the unified solver loops train through.
    pub fn rows(&self) -> Rows<'_> {
        Rows::dense(&self.x, self.len(), self.d)
    }

    /// Append one example.
    pub fn push(&mut self, row: &[f32], label: f32) {
        assert_eq!(row.len(), self.d, "row dimensionality mismatch");
        assert!(label == 1.0 || label == -1.0, "label must be ±1");
        self.x.extend_from_slice(row);
        self.y.push(label);
    }

    /// Gather the rows at `idx` into a dense `[idx.len(), d]` buffer,
    /// writing into `out` (resized as needed). The hot-path version used
    /// by the solvers to build PJRT/native input tiles without
    /// reallocating each step.
    pub fn gather_into(&self, idx: &[usize], out: &mut Vec<f32>) {
        out.clear();
        out.reserve(idx.len() * self.d);
        for &i in idx {
            out.extend_from_slice(self.row(i));
        }
    }

    /// Gather labels at `idx` into `out`.
    pub fn gather_labels_into(&self, idx: &[usize], out: &mut Vec<f32>) {
        out.clear();
        out.extend(idx.iter().map(|&i| self.y[i]));
    }

    /// Subset by indices (allocating convenience wrapper).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut x = Vec::with_capacity(idx.len() * self.d);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        Dataset { x, y, d: self.d }
    }

    /// Random split into `(train, test)` with `frac` of rows in train.
    pub fn split<R: Rng>(&self, frac: f64, rng: &mut R) -> (Dataset, Dataset) {
        let n = self.len();
        let n_train = ((n as f64) * frac).round() as usize;
        let train_idx = sample_without_replacement(rng, n, n_train);
        let mut in_train = vec![false; n];
        for &i in &train_idx {
            in_train[i] = true;
        }
        let test_idx: Vec<usize> = (0..n).filter(|&i| !in_train[i]).collect();
        (self.subset(&train_idx), self.subset(&test_idx))
    }

    /// Draw `min(k, n)` rows uniformly without replacement (the paper's
    /// "we sampled min(1000, N_dataset) data points").
    pub fn sample<R: Rng>(&self, k: usize, rng: &mut R) -> Dataset {
        let k = k.min(self.len());
        let idx = sample_without_replacement(rng, self.len(), k);
        self.subset(&idx)
    }

    /// Fraction of positive labels.
    pub fn positive_rate(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        self.y.iter().filter(|&&v| v > 0.0).count() as f64 / self.len() as f64
    }

    /// Fraction of exactly-zero feature entries (sparsity diagnostic).
    pub fn sparsity(&self) -> f64 {
        if self.x.is_empty() {
            return 0.0;
        }
        self.x.iter().filter(|&&v| v == 0.0).count() as f64 / self.x.len() as f64
    }
}

/// Dense row-major **multiclass** dataset: the K-class generalisation of
/// [`Dataset`] behind the one-vs-rest driver
/// ([`crate::solver::ovr::OvrSolver`]).
///
/// Labels are class ids `0..n_classes`. Binary training machinery never
/// sees this type — [`MultiDataset::binary_view`] materialises the
/// ±1-labelled view for one class, which is exactly how the paper's
/// flagship covtype set (natively 7-class) was binarised to "class 2 vs
/// rest".
#[derive(Clone, Debug)]
pub struct MultiDataset {
    /// Row-major features, `len == n * d`.
    pub x: Vec<f32>,
    /// Class ids in `0..n_classes`, `len == n`.
    pub y: Vec<u32>,
    /// Number of feature dimensions.
    pub d: usize,
    /// Number of classes K.
    pub n_classes: usize,
}

impl MultiDataset {
    /// Empty dataset with fixed dimensionality and class count.
    pub fn with_dims(d: usize, n_classes: usize) -> Self {
        MultiDataset {
            x: Vec::new(),
            y: Vec::new(),
            d,
            n_classes,
        }
    }

    /// Number of examples.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    /// True if the dataset holds no examples.
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Feature row `i`.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Borrowed [`Rows`] view over all feature rows.
    pub fn rows(&self) -> Rows<'_> {
        Rows::dense(&self.x, self.len(), self.d)
    }

    /// Append one example.
    pub fn push(&mut self, row: &[f32], class: u32) {
        assert_eq!(row.len(), self.d, "row dimensionality mismatch");
        assert!(
            (class as usize) < self.n_classes,
            "class {class} out of range (K = {})",
            self.n_classes
        );
        self.x.extend_from_slice(row);
        self.y.push(class);
    }

    /// One-vs-rest binary view: `class` maps to +1, everything else to
    /// -1. This **copies the full feature matrix** — it exists for
    /// tests, experiments and external consumers that need an owned
    /// [`Dataset`]. Training paths must not call it per class: the OvR
    /// driver and the multiclass coordinator use the label views below
    /// ([`MultiDataset::class_labels`],
    /// [`MultiDataset::gather_class_labels_into`]) over the shared rows,
    /// so memory stays O(N) instead of O(K·N·d).
    pub fn binary_view(&self, class: u32) -> Dataset {
        Dataset {
            x: self.x.clone(),
            y: self.class_labels(class),
            d: self.d,
        }
    }

    /// The ±1 one-vs-rest label vector for `class` — a label view over
    /// the shared feature rows (no feature copy).
    pub fn class_labels(&self, class: u32) -> Vec<f32> {
        self.y
            .iter()
            .map(|&c| if c == class { 1.0 } else { -1.0 })
            .collect()
    }

    /// Gather the ±1 one-vs-rest labels of `class` at `idx` into `out`
    /// (cleared and refilled) — the hot-path twin of
    /// [`Dataset::gather_labels_into`] for K-head training: one call per
    /// head per step, features gathered once for all heads.
    pub fn gather_class_labels_into(&self, class: u32, idx: &[usize], out: &mut Vec<f32>) {
        out.clear();
        out.extend(
            idx.iter()
                .map(|&i| if self.y[i] == class { 1.0 } else { -1.0 }),
        );
    }

    /// Gather the rows at `idx` into a dense `[idx.len(), d]` buffer,
    /// writing into `out` (resized as needed) — shared across all K
    /// heads of a fused step.
    pub fn gather_into(&self, idx: &[usize], out: &mut Vec<f32>) {
        out.clear();
        out.reserve(idx.len() * self.d);
        for &i in idx {
            out.extend_from_slice(self.row(i));
        }
    }

    /// Subset by indices.
    pub fn subset(&self, idx: &[usize]) -> MultiDataset {
        let mut out = MultiDataset::with_dims(self.d, self.n_classes);
        for &i in idx {
            out.x.extend_from_slice(self.row(i));
            out.y.push(self.y[i]);
        }
        out
    }

    /// Random split into `(train, test)` with `frac` of rows in train.
    pub fn split<R: Rng>(&self, frac: f64, rng: &mut R) -> (MultiDataset, MultiDataset) {
        let n = self.len();
        let n_train = ((n as f64) * frac).round() as usize;
        let train_idx = sample_without_replacement(rng, n, n_train);
        let mut in_train = vec![false; n];
        for &i in &train_idx {
            in_train[i] = true;
        }
        let test_idx: Vec<usize> = (0..n).filter(|&i| !in_train[i]).collect();
        (self.subset(&train_idx), self.subset(&test_idx))
    }

    /// Examples per class.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_classes];
        for &c in &self.y {
            counts[c as usize] += 1;
        }
        counts
    }

    /// Fraction of exactly-zero feature entries (sparsity diagnostic;
    /// the CSR twin computes the same value in O(nnz)).
    pub fn sparsity(&self) -> f64 {
        if self.x.is_empty() {
            return 0.0;
        }
        self.x.iter().filter(|&&v| v == 0.0).count() as f64 / self.x.len() as f64
    }
}

/// Per-feature standardisation parameters (fit on train, apply to test —
/// never the other way round).
#[derive(Clone, Debug)]
pub struct Scaler {
    mean: Vec<f32>,
    inv_std: Vec<f32>,
}

impl Scaler {
    /// Fit mean/std per column of a flat row-major `[n, d]` buffer.
    pub fn fit_rows(x: &[f32], n: usize, d: usize) -> Scaler {
        assert_eq!(x.len(), n * d);
        let denom = n.max(1);
        let mut mean = vec![0.0f64; d];
        for i in 0..n {
            for (j, &v) in x[i * d..(i + 1) * d].iter().enumerate() {
                mean[j] += v as f64;
            }
        }
        for m in &mut mean {
            *m /= denom as f64;
        }
        let mut var = vec![0.0f64; d];
        for i in 0..n {
            for (j, &v) in x[i * d..(i + 1) * d].iter().enumerate() {
                let dlt = v as f64 - mean[j];
                var[j] += dlt * dlt;
            }
        }
        let inv_std = var
            .iter()
            .map(|&v| {
                let s = (v / denom as f64).sqrt();
                if s > 1e-12 {
                    (1.0 / s) as f32
                } else {
                    0.0 // constant feature -> zero out
                }
            })
            .collect();
        Scaler {
            mean: mean.into_iter().map(|m| m as f32).collect(),
            inv_std,
        }
    }

    /// Fit mean/std per feature column.
    pub fn fit(ds: &Dataset) -> Scaler {
        Self::fit_rows(&ds.x, ds.len(), ds.d)
    }

    /// Fit on a multiclass dataset's features.
    pub fn fit_multi(ds: &MultiDataset) -> Scaler {
        Self::fit_rows(&ds.x, ds.len(), ds.d)
    }

    /// Fit per-column mean/std over CSR rows in O(nnz): implicit zeros
    /// enter the moments through the `n` denominator, so the statistics
    /// match a dense fit of the densified data (up to accumulation
    /// order).
    fn fit_csr(rows: sparse::CsrRows) -> Scaler {
        let (n, d) = (rows.len(), rows.dim());
        let denom = n.max(1) as f64;
        let mut s1 = vec![0.0f64; d];
        let mut s2 = vec![0.0f64; d];
        for i in 0..n {
            let (cols, vals) = rows.row(i);
            for (c, &v) in cols.iter().zip(vals) {
                s1[*c as usize] += v as f64;
                s2[*c as usize] += (v as f64) * (v as f64);
            }
        }
        let mean: Vec<f64> = s1.iter().map(|s| s / denom).collect();
        let inv_std = mean
            .iter()
            .zip(&s2)
            .map(|(&m, &sq)| {
                let var = (sq / denom - m * m).max(0.0);
                let s = var.sqrt();
                if s > 1e-12 {
                    (1.0 / s) as f32
                } else {
                    0.0 // constant feature -> zero out
                }
            })
            .collect();
        Scaler {
            mean: mean.into_iter().map(|m| m as f32).collect(),
            inv_std,
        }
    }

    /// Fit on a sparse dataset's columns (O(nnz), never densifies).
    pub fn fit_sparse(ds: &SparseDataset) -> Scaler {
        Self::fit_csr(ds.csr())
    }

    /// Fit on a sparse multiclass dataset's columns.
    pub fn fit_sparse_multi(ds: &SparseMultiDataset) -> Scaler {
        Self::fit_csr(ds.csr())
    }

    /// Standardise a flat row-major `[n, d]` buffer in place.
    pub fn transform_rows(&self, x: &mut [f32]) {
        let d = self.mean.len();
        if d == 0 {
            return; // feature-less dataset: nothing to scale
        }
        assert_eq!(x.len() % d, 0);
        for row in x.chunks_mut(d) {
            for (j, v) in row.iter_mut().enumerate() {
                *v = (*v - self.mean[j]) * self.inv_std[j];
            }
        }
    }

    /// Standardise a dataset in place.
    pub fn transform(&self, ds: &mut Dataset) {
        assert_eq!(ds.d, self.mean.len());
        self.transform_rows(&mut ds.x);
    }

    /// Standardise a multiclass dataset in place.
    pub fn transform_multi(&self, ds: &mut MultiDataset) {
        assert_eq!(ds.d, self.mean.len());
        self.transform_rows(&mut ds.x);
    }

    /// **Center-free** scaling of a flat dense buffer: divide by the
    /// per-column std but do *not* subtract the mean. This is the dense
    /// twin of [`Scaler::transform_sparse`] — centering a CSR matrix
    /// would turn every implicit zero into `-mean/std` and densify it,
    /// so the sparse path scales variance only and this method lets
    /// dense runs reproduce that transform exactly (parity tests, and
    /// mixed sparse-train/dense-eval pipelines).
    pub fn transform_rows_scale_only(&self, x: &mut [f32]) {
        let d = self.mean.len();
        if d == 0 {
            return;
        }
        assert_eq!(x.len() % d, 0);
        for row in x.chunks_mut(d) {
            for (v, &s) in row.iter_mut().zip(&self.inv_std) {
                *v *= s;
            }
        }
    }

    /// Center-free variance scaling of a CSR dataset in place: stored
    /// values are divided by the column std, implicit zeros stay
    /// implicit (the matrix keeps its sparsity pattern). See
    /// [`Scaler::transform_rows_scale_only`] for the dense equivalent.
    pub fn transform_sparse(&self, ds: &mut SparseDataset) {
        assert_eq!(ds.d, self.mean.len());
        ds.scale_columns(&self.inv_std);
    }

    /// Center-free variance scaling of a sparse multiclass dataset.
    pub fn transform_sparse_multi(&self, ds: &mut SparseMultiDataset) {
        assert_eq!(ds.d, self.mean.len());
        ds.scale_columns(&self.inv_std);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn toy() -> Dataset {
        let mut ds = Dataset::with_dim(2);
        for i in 0..10 {
            let v = i as f32;
            ds.push(&[v, -v], if i % 2 == 0 { 1.0 } else { -1.0 });
        }
        ds
    }

    #[test]
    fn push_and_row() {
        let ds = toy();
        assert_eq!(ds.len(), 10);
        assert_eq!(ds.row(3), &[3.0, -3.0]);
        assert_eq!(ds.y[3], -1.0);
    }

    #[test]
    fn gather_matches_subset() {
        let ds = toy();
        let idx = [7usize, 0, 3];
        let sub = ds.subset(&idx);
        let mut buf = Vec::new();
        ds.gather_into(&idx, &mut buf);
        assert_eq!(buf, sub.x);
        let mut lab = Vec::new();
        ds.gather_labels_into(&idx, &mut lab);
        assert_eq!(lab, sub.y);
    }

    #[test]
    fn split_partitions() {
        let ds = toy();
        let mut rng = Pcg64::seed_from(1);
        let (tr, te) = ds.split(0.5, &mut rng);
        assert_eq!(tr.len() + te.len(), ds.len());
        assert_eq!(tr.len(), 5);
        // Each original row appears exactly once across the split: check
        // via the (unique) first feature values.
        let mut firsts: Vec<f32> = tr
            .x
            .chunks(2)
            .chain(te.x.chunks(2))
            .map(|r| r[0])
            .collect();
        firsts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(firsts, (0..10).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn sample_caps_at_len() {
        let ds = toy();
        let mut rng = Pcg64::seed_from(2);
        assert_eq!(ds.sample(1000, &mut rng).len(), 10);
        assert_eq!(ds.sample(4, &mut rng).len(), 4);
    }

    #[test]
    fn scaler_standardises() {
        let mut ds = Dataset::with_dim(2);
        let mut rng = Pcg64::seed_from(3);
        for _ in 0..500 {
            ds.push(
                &[rng.normal_ms(5.0, 2.0) as f32, rng.normal_ms(-1.0, 0.5) as f32],
                rng.sign(),
            );
        }
        let scaler = Scaler::fit(&ds);
        scaler.transform(&mut ds);
        for j in 0..2 {
            let col: Vec<f64> = (0..ds.len()).map(|i| ds.row(i)[j] as f64).collect();
            let (m, s) = crate::util::mean_std(&col);
            assert!(m.abs() < 1e-4, "col {j} mean {m}");
            assert!((s - 1.0).abs() < 1e-3, "col {j} std {s}");
        }
    }

    #[test]
    fn scaler_zeroes_constant_features() {
        let mut ds = Dataset::with_dim(2);
        for i in 0..10 {
            ds.push(&[3.0, i as f32], 1.0);
        }
        let scaler = Scaler::fit(&ds);
        scaler.transform(&mut ds);
        assert!((0..10).all(|i| ds.row(i)[0] == 0.0));
    }

    #[test]
    fn stats() {
        let ds = toy();
        assert!((ds.positive_rate() - 0.5).abs() < 1e-9);
        // row 0 is [0, 0] -> 2 zeros of 20 entries
        assert!((ds.sparsity() - 0.1).abs() < 1e-9);
    }

    fn toy_multi() -> MultiDataset {
        let mut ds = MultiDataset::with_dims(2, 3);
        for i in 0..9 {
            let v = i as f32;
            ds.push(&[v, -v], (i % 3) as u32);
        }
        ds
    }

    #[test]
    fn multi_push_counts_and_rows() {
        let ds = toy_multi();
        assert_eq!(ds.len(), 9);
        assert_eq!(ds.row(4), &[4.0, -4.0]);
        assert_eq!(ds.y[4], 1);
        assert_eq!(ds.class_counts(), vec![3, 3, 3]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn multi_push_rejects_bad_class() {
        let mut ds = MultiDataset::with_dims(2, 3);
        ds.push(&[0.0, 0.0], 3);
    }

    #[test]
    fn binary_view_is_one_vs_rest() {
        let ds = toy_multi();
        let b = ds.binary_view(1);
        assert_eq!(b.len(), 9);
        assert_eq!(b.d, 2);
        assert_eq!(b.x, ds.x);
        for (i, &y) in b.y.iter().enumerate() {
            assert_eq!(y, if ds.y[i] == 1 { 1.0 } else { -1.0 });
        }
        assert!((b.positive_rate() - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn class_label_views_match_binary_view() {
        let ds = toy_multi();
        for class in 0..3u32 {
            let view = ds.binary_view(class);
            assert_eq!(ds.class_labels(class), view.y);
            // Gathered labels match the owned view at arbitrary indices.
            let idx = [8usize, 1, 4, 4, 0];
            let mut got = Vec::new();
            ds.gather_class_labels_into(class, &idx, &mut got);
            let want: Vec<f32> = idx.iter().map(|&i| view.y[i]).collect();
            assert_eq!(got, want);
        }
        // Feature gathering is shared across heads: same rows as Dataset.
        let idx = [2usize, 7];
        let mut rows = Vec::new();
        ds.gather_into(&idx, &mut rows);
        assert_eq!(rows, ds.binary_view(0).subset(&idx).x);
    }

    #[test]
    fn multi_split_partitions_and_keeps_classes() {
        let ds = toy_multi();
        let mut rng = Pcg64::seed_from(8);
        let (tr, te) = ds.split(2.0 / 3.0, &mut rng);
        assert_eq!(tr.len() + te.len(), 9);
        assert_eq!(tr.n_classes, 3);
        let total: usize = tr
            .class_counts()
            .iter()
            .zip(te.class_counts())
            .map(|(a, b)| a + b)
            .sum();
        assert_eq!(total, 9);
    }

    #[test]
    fn sparse_scaler_matches_dense_scale_only() {
        // fit_sparse statistics agree with the dense fit of the
        // densified copy, and transform_sparse == the center-free dense
        // transform — so sparse and dense runs see the same features.
        let mut rng = Pcg64::seed_from(17);
        let mut ds = SparseDataset::with_dim(6);
        for _ in 0..300 {
            let mut cols = Vec::new();
            let mut vals = Vec::new();
            for c in 0..6u32 {
                if rng.below(3) == 0 {
                    cols.push(c);
                    vals.push(rng.normal_ms(2.0, 3.0) as f32);
                }
            }
            ds.push(&cols, &vals, rng.sign());
        }
        let mut dense = ds.to_dense();
        let s_sparse = Scaler::fit_sparse(&ds);
        let s_dense = Scaler::fit(&dense);
        for (a, b) in s_sparse.inv_std.iter().zip(&s_dense.inv_std) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
        for (a, b) in s_sparse.mean.iter().zip(&s_dense.mean) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
        s_sparse.transform_sparse(&mut ds);
        s_sparse.transform_rows_scale_only(&mut dense.x);
        for (a, b) in ds.densify_x().iter().zip(&dense.x) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        // Sparsity pattern untouched by the center-free transform.
        assert_eq!(ds.sparsity(), dense.sparsity());
    }

    #[test]
    fn sparse_multi_scaler_matches_binary_view() {
        let mut rng = Pcg64::seed_from(19);
        let mut ds = SparseMultiDataset::with_dims(4, 3);
        for _ in 0..200 {
            let mut cols = Vec::new();
            let mut vals = Vec::new();
            for c in 0..4u32 {
                if rng.below(2) == 0 {
                    cols.push(c);
                    vals.push(rng.normal() as f32);
                }
            }
            ds.push(&cols, &vals, rng.below(3) as u32);
        }
        let s_multi = Scaler::fit_sparse_multi(&ds);
        let s_bin = Scaler::fit_sparse(&ds.binary_view(0));
        assert_eq!(s_multi.inv_std, s_bin.inv_std);
        let mut scaled = ds.clone();
        s_multi.transform_sparse_multi(&mut scaled);
        let mut bv = ds.binary_view(1);
        s_bin.transform_sparse(&mut bv);
        assert_eq!(scaled.densify_x(), bv.densify_x());
    }

    #[test]
    fn scaler_multi_matches_binary() {
        let mut rng = Pcg64::seed_from(9);
        let mut multi = MultiDataset::with_dims(3, 2);
        for _ in 0..200 {
            let row = [
                rng.normal_ms(2.0, 3.0) as f32,
                rng.normal_ms(-1.0, 0.5) as f32,
                rng.normal_ms(0.0, 1.0) as f32,
            ];
            multi.push(&row, rng.below(2) as u32);
        }
        let mut binary = multi.binary_view(0);
        let s_multi = Scaler::fit_multi(&multi);
        let s_bin = Scaler::fit(&binary);
        let mut multi2 = multi.clone();
        s_multi.transform_multi(&mut multi2);
        s_bin.transform(&mut binary);
        for (a, b) in multi2.x.iter().zip(&binary.x) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
