//! Data substrate: the in-memory dataset representation, libsvm-format
//! loading, feature scaling, and train/test splitting.
//!
//! The paper evaluates on libsvm binary-classification sets and UCI
//! covertype; the offline environment has no network, so
//! [`synth`] provides generators matched to each set's size,
//! dimensionality, sparsity and class geometry (DESIGN.md §4,
//! "Substitutions").

pub mod libsvm;
pub mod synth;

use crate::rng::{Rng, sample_without_replacement};

/// Dense row-major binary-classification dataset.
///
/// Labels are `{-1.0, +1.0}` f32, matching the SVM formulation (Eq. 3/4
/// of the paper). Dense storage is deliberate: the PJRT artifacts and the
/// native compute backend both consume dense `[rows, d]` tiles, and even
/// "sparse" sets in the paper's table (mushrooms, madelon) are small
/// enough that density costs nothing at these scales.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Row-major features, `len == n * d`.
    pub x: Vec<f32>,
    /// Labels in {-1, +1}, `len == n`.
    pub y: Vec<f32>,
    /// Number of feature dimensions.
    pub d: usize,
}

impl Dataset {
    /// Empty dataset with fixed dimensionality.
    pub fn with_dim(d: usize) -> Self {
        Dataset {
            x: Vec::new(),
            y: Vec::new(),
            d,
        }
    }

    /// Number of examples.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    /// True if the dataset holds no examples.
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Feature row `i`.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Append one example.
    pub fn push(&mut self, row: &[f32], label: f32) {
        assert_eq!(row.len(), self.d, "row dimensionality mismatch");
        assert!(label == 1.0 || label == -1.0, "label must be ±1");
        self.x.extend_from_slice(row);
        self.y.push(label);
    }

    /// Gather the rows at `idx` into a dense `[idx.len(), d]` buffer,
    /// writing into `out` (resized as needed). The hot-path version used
    /// by the solvers to build PJRT/native input tiles without
    /// reallocating each step.
    pub fn gather_into(&self, idx: &[usize], out: &mut Vec<f32>) {
        out.clear();
        out.reserve(idx.len() * self.d);
        for &i in idx {
            out.extend_from_slice(self.row(i));
        }
    }

    /// Gather labels at `idx` into `out`.
    pub fn gather_labels_into(&self, idx: &[usize], out: &mut Vec<f32>) {
        out.clear();
        out.extend(idx.iter().map(|&i| self.y[i]));
    }

    /// Subset by indices (allocating convenience wrapper).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut x = Vec::with_capacity(idx.len() * self.d);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        Dataset { x, y, d: self.d }
    }

    /// Random split into `(train, test)` with `frac` of rows in train.
    pub fn split<R: Rng>(&self, frac: f64, rng: &mut R) -> (Dataset, Dataset) {
        let n = self.len();
        let n_train = ((n as f64) * frac).round() as usize;
        let train_idx = sample_without_replacement(rng, n, n_train);
        let mut in_train = vec![false; n];
        for &i in &train_idx {
            in_train[i] = true;
        }
        let test_idx: Vec<usize> = (0..n).filter(|&i| !in_train[i]).collect();
        (self.subset(&train_idx), self.subset(&test_idx))
    }

    /// Draw `min(k, n)` rows uniformly without replacement (the paper's
    /// "we sampled min(1000, N_dataset) data points").
    pub fn sample<R: Rng>(&self, k: usize, rng: &mut R) -> Dataset {
        let k = k.min(self.len());
        let idx = sample_without_replacement(rng, self.len(), k);
        self.subset(&idx)
    }

    /// Fraction of positive labels.
    pub fn positive_rate(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        self.y.iter().filter(|&&v| v > 0.0).count() as f64 / self.len() as f64
    }

    /// Fraction of exactly-zero feature entries (sparsity diagnostic).
    pub fn sparsity(&self) -> f64 {
        if self.x.is_empty() {
            return 0.0;
        }
        self.x.iter().filter(|&&v| v == 0.0).count() as f64 / self.x.len() as f64
    }
}

/// Per-feature standardisation parameters (fit on train, apply to test —
/// never the other way round).
#[derive(Clone, Debug)]
pub struct Scaler {
    mean: Vec<f32>,
    inv_std: Vec<f32>,
}

impl Scaler {
    /// Fit mean/std per feature column.
    pub fn fit(ds: &Dataset) -> Scaler {
        let (n, d) = (ds.len().max(1), ds.d);
        let mut mean = vec![0.0f64; d];
        for i in 0..ds.len() {
            for (j, &v) in ds.row(i).iter().enumerate() {
                mean[j] += v as f64;
            }
        }
        for m in &mut mean {
            *m /= n as f64;
        }
        let mut var = vec![0.0f64; d];
        for i in 0..ds.len() {
            for (j, &v) in ds.row(i).iter().enumerate() {
                let dlt = v as f64 - mean[j];
                var[j] += dlt * dlt;
            }
        }
        let inv_std = var
            .iter()
            .map(|&v| {
                let s = (v / n as f64).sqrt();
                if s > 1e-12 {
                    (1.0 / s) as f32
                } else {
                    0.0 // constant feature -> zero out
                }
            })
            .collect();
        Scaler {
            mean: mean.into_iter().map(|m| m as f32).collect(),
            inv_std,
        }
    }

    /// Standardise a dataset in place.
    pub fn transform(&self, ds: &mut Dataset) {
        assert_eq!(ds.d, self.mean.len());
        for i in 0..ds.len() {
            let row = &mut ds.x[i * ds.d..(i + 1) * ds.d];
            for (j, v) in row.iter_mut().enumerate() {
                *v = (*v - self.mean[j]) * self.inv_std[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn toy() -> Dataset {
        let mut ds = Dataset::with_dim(2);
        for i in 0..10 {
            let v = i as f32;
            ds.push(&[v, -v], if i % 2 == 0 { 1.0 } else { -1.0 });
        }
        ds
    }

    #[test]
    fn push_and_row() {
        let ds = toy();
        assert_eq!(ds.len(), 10);
        assert_eq!(ds.row(3), &[3.0, -3.0]);
        assert_eq!(ds.y[3], -1.0);
    }

    #[test]
    fn gather_matches_subset() {
        let ds = toy();
        let idx = [7usize, 0, 3];
        let sub = ds.subset(&idx);
        let mut buf = Vec::new();
        ds.gather_into(&idx, &mut buf);
        assert_eq!(buf, sub.x);
        let mut lab = Vec::new();
        ds.gather_labels_into(&idx, &mut lab);
        assert_eq!(lab, sub.y);
    }

    #[test]
    fn split_partitions() {
        let ds = toy();
        let mut rng = Pcg64::seed_from(1);
        let (tr, te) = ds.split(0.5, &mut rng);
        assert_eq!(tr.len() + te.len(), ds.len());
        assert_eq!(tr.len(), 5);
        // Each original row appears exactly once across the split: check
        // via the (unique) first feature values.
        let mut firsts: Vec<f32> = tr
            .x
            .chunks(2)
            .chain(te.x.chunks(2))
            .map(|r| r[0])
            .collect();
        firsts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(firsts, (0..10).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn sample_caps_at_len() {
        let ds = toy();
        let mut rng = Pcg64::seed_from(2);
        assert_eq!(ds.sample(1000, &mut rng).len(), 10);
        assert_eq!(ds.sample(4, &mut rng).len(), 4);
    }

    #[test]
    fn scaler_standardises() {
        let mut ds = Dataset::with_dim(2);
        let mut rng = Pcg64::seed_from(3);
        for _ in 0..500 {
            ds.push(
                &[rng.normal_ms(5.0, 2.0) as f32, rng.normal_ms(-1.0, 0.5) as f32],
                rng.sign(),
            );
        }
        let scaler = Scaler::fit(&ds);
        scaler.transform(&mut ds);
        for j in 0..2 {
            let col: Vec<f64> = (0..ds.len()).map(|i| ds.row(i)[j] as f64).collect();
            let (m, s) = crate::util::mean_std(&col);
            assert!(m.abs() < 1e-4, "col {j} mean {m}");
            assert!((s - 1.0).abs() < 1e-3, "col {j} std {s}");
        }
    }

    #[test]
    fn scaler_zeroes_constant_features() {
        let mut ds = Dataset::with_dim(2);
        for i in 0..10 {
            ds.push(&[3.0, i as f32], 1.0);
        }
        let scaler = Scaler::fit(&ds);
        scaler.transform(&mut ds);
        assert!((0..10).all(|i| ds.row(i)[0] == 0.0));
    }

    #[test]
    fn stats() {
        let ds = toy();
        assert!((ds.positive_rate() - 0.5).abs() < 1e-9);
        // row 0 is [0, 0] -> 2 zeros of 20 entries
        assert!((ds.sparsity() - 0.1).abs() < 1e-9);
    }
}
