//! Sparse (CSR) data substrate and the [`Rows`] abstraction that lets
//! the whole compute stack accept dense or sparse feature rows through
//! one type.
//!
//! The paper's large-scale regime is dominated by sparse libsvm sets
//! (rcv1, news20, url — the workloads studied in Tu et al., *Block
//! Coordinate Descent*, and Dai et al., *Doubly Stochastic Gradients*)
//! where >90% of entries are zero: storing them dense either does not
//! fit in memory or wastes almost all of the `|I| x |J|` kernel-block
//! FLOPs multiplying zeros. [`SparseDataset`] /
//! [`SparseMultiDataset`] store rows in CSR (`indptr`/`indices`/
//! `values`) with the same gather/subset/split/sample surface as the
//! dense [`Dataset`] / [`MultiDataset`], and [`Rows`] is the borrowed
//! view both layouts lower to on the way into a
//! [`crate::runtime::Backend`].
//!
//! The **gather abstraction** the solvers train through lives here too:
//! [`Rows::gather_into`] pulls sampled rows into a reusable
//! [`GatherBatch`] in the layout of the source, so one doubly
//! stochastic loop serves dense and CSR data with identical code (and
//! identical floating-point inputs — schedule parity by construction).
//! [`CsrBlock`] is the owned CSR row block a sparse-trained
//! `model::ExpansionStore` keeps its expansion points in.

use super::{Dataset, MultiDataset};
use crate::rng::{sample_without_replacement, Rng};
use crate::{Error, Result};

/// Borrowed CSR view over `n` rows of dimensionality `d`.
///
/// `indptr` is an `n + 1` window of offsets into `indices`/`values`
/// (absolute offsets, so slicing a row range only re-windows `indptr`).
/// Column indices are strictly ascending within each row.
#[derive(Clone, Copy, Debug)]
pub struct CsrRows<'a> {
    indptr: &'a [usize],
    indices: &'a [u32],
    values: &'a [f32],
    d: usize,
}

impl<'a> CsrRows<'a> {
    /// View over raw CSR parts. Offsets must be non-decreasing and in
    /// bounds; column indices must be `< d`.
    pub fn new(indptr: &'a [usize], indices: &'a [u32], values: &'a [f32], d: usize) -> Self {
        assert!(!indptr.is_empty(), "indptr needs at least one offset");
        assert_eq!(indices.len(), values.len(), "indices/values length mismatch");
        assert!(
            *indptr.last().unwrap() <= indices.len(),
            "indptr points past the value buffer"
        );
        debug_assert!(indptr.windows(2).all(|w| w[0] <= w[1]));
        CsrRows {
            indptr,
            indices,
            values,
            d,
        }
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        self.indptr.len() - 1
    }

    /// True when the view holds no rows.
    pub fn is_empty(&self) -> bool {
        self.indptr.len() <= 1
    }

    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Stored entries in the viewed rows.
    pub fn nnz(&self) -> usize {
        self.indptr[self.len()] - self.indptr[0]
    }

    /// Row `i` as `(column indices, values)`.
    pub fn row(&self, i: usize) -> (&'a [u32], &'a [f32]) {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[s..e], &self.values[s..e])
    }

    /// Sub-view over rows `r0..r1` (no copying — `indptr` re-windowed).
    pub fn slice(&self, r0: usize, r1: usize) -> CsrRows<'a> {
        CsrRows {
            indptr: &self.indptr[r0..=r1],
            indices: self.indices,
            values: self.values,
            d: self.d,
        }
    }
}

/// A borrowed block of feature rows in either layout — the one type the
/// [`crate::runtime::Backend`] surface and the step inputs accept, so
/// every solver threads dense and CSR batches through identical code.
#[derive(Clone, Copy, Debug)]
pub enum Rows<'a> {
    /// Row-major dense `[n, d]`.
    Dense { x: &'a [f32], n: usize, d: usize },
    /// CSR rows.
    Csr(CsrRows<'a>),
}

impl<'a> Rows<'a> {
    /// Dense view over a row-major `[n, d]` buffer.
    pub fn dense(x: &'a [f32], n: usize, d: usize) -> Rows<'a> {
        assert_eq!(x.len(), n * d, "dense rows shape mismatch");
        Rows::Dense { x, n, d }
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        match self {
            Rows::Dense { n, .. } => *n,
            Rows::Csr(c) => c.len(),
        }
    }

    /// True when the view holds no rows.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        match self {
            Rows::Dense { d, .. } => *d,
            Rows::Csr(c) => c.dim(),
        }
    }

    /// True for the dense layout.
    pub fn is_dense(&self) -> bool {
        matches!(self, Rows::Dense { .. })
    }

    /// The flat dense buffer, when dense.
    pub fn as_dense(&self) -> Option<&'a [f32]> {
        match *self {
            Rows::Dense { x, .. } => Some(x),
            Rows::Csr(_) => None,
        }
    }

    /// Sub-view over rows `r0..r1` (no copying in either layout).
    pub fn slice(&self, r0: usize, r1: usize) -> Rows<'a> {
        match *self {
            Rows::Dense { x, d, .. } => Rows::Dense {
                x: &x[r0 * d..r1 * d],
                n: r1 - r0,
                d,
            },
            Rows::Csr(c) => Rows::Csr(c.slice(r0, r1)),
        }
    }

    /// Materialise into a dense row-major `[n, d]` buffer (cleared and
    /// refilled) — the boundary densification the PJRT backend uses:
    /// its AOT artifacts only take dense tiles, so gathered CSR batches
    /// are densified right before padding (documented in
    /// `runtime/pjrt.rs`).
    pub fn to_dense_into(&self, out: &mut Vec<f32>) {
        let (n, d) = (self.len(), self.dim());
        out.clear();
        match *self {
            Rows::Dense { x, .. } => out.extend_from_slice(x),
            Rows::Csr(c) => {
                out.resize(n * d, 0.0);
                for i in 0..n {
                    let (cols, vals) = c.row(i);
                    let row = &mut out[i * d..(i + 1) * d];
                    for (col, v) in cols.iter().zip(vals) {
                        row[*col as usize] = *v;
                    }
                }
            }
        }
    }

    /// Gather the rows at `idx` into a reusable [`GatherBatch`], in the
    /// layout of the source view: dense rows gather into a flat dense
    /// buffer, CSR rows into a CSR batch. This is the batch-side half of
    /// the gather abstraction — a solver loop written against
    /// `Rows::gather_into` + [`GatherBatch::view`] serves both layouts
    /// with identical code (and identical floating-point inputs).
    pub fn gather_into(&self, idx: &[usize], out: &mut GatherBatch) {
        match *self {
            Rows::Dense { x, d, .. } => {
                if !matches!(out, GatherBatch::Dense { .. }) {
                    *out = GatherBatch::default();
                }
                if let GatherBatch::Dense { buf, n, d: bd } = out {
                    buf.clear();
                    buf.reserve(idx.len() * d);
                    for &i in idx {
                        buf.extend_from_slice(&x[i * d..(i + 1) * d]);
                    }
                    *n = idx.len();
                    *bd = d;
                }
            }
            Rows::Csr(c) => {
                if !matches!(out, GatherBatch::Csr(_)) {
                    *out = GatherBatch::Csr(CsrBatch::default());
                }
                if let GatherBatch::Csr(batch) = out {
                    gather_csr_rows(c, idx, batch);
                }
            }
        }
    }
}

/// Gather CSR rows at `idx` into a reusable [`CsrBatch`] — the shared
/// implementation behind [`Rows::gather_into`] and the datasets'
/// `gather_into` methods.
fn gather_csr_rows(rows: CsrRows, idx: &[usize], out: &mut CsrBatch) {
    out.reset(rows.dim());
    for &i in idx {
        let (cols, vals) = rows.row(i);
        out.indices.extend_from_slice(cols);
        out.values.extend_from_slice(vals);
        out.indptr.push(out.indices.len());
    }
}

/// Owned, reusable gather buffer in either layout — what
/// [`Rows::gather_into`] fills. The variant follows the layout of the
/// source rows and is stable across iterations of a training loop, so
/// the buffers are reused and the hot path stays allocation-free after
/// warmup.
#[derive(Debug, Clone)]
pub enum GatherBatch {
    /// Dense row-major `[n, d]` batch.
    Dense { buf: Vec<f32>, n: usize, d: usize },
    /// CSR batch.
    Csr(CsrBatch),
}

impl Default for GatherBatch {
    fn default() -> Self {
        GatherBatch::Dense {
            buf: Vec::new(),
            n: 0,
            d: 0,
        }
    }
}

impl GatherBatch {
    /// Borrowed [`Rows`] view of the gathered rows.
    pub fn view(&self) -> Rows<'_> {
        match self {
            GatherBatch::Dense { buf, n, d } => Rows::dense(buf, *n, *d),
            GatherBatch::Csr(b) => b.view(),
        }
    }
}

/// Owned CSR row block: the storage twin of the borrowed [`CsrRows`]
/// view. This is what a CSR-backed `model::ExpansionStore` holds, so a
/// model trained on sparse data keeps its expansion rows in O(nnz)
/// memory end-to-end (training, serving, and the DSEKLv3 file format).
#[derive(Clone, Debug, PartialEq)]
pub struct CsrBlock {
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
    d: usize,
}

impl CsrBlock {
    /// Build from raw CSR parts, validating every invariant with an
    /// `Err` (never a panic) — this is the constructor model-file
    /// loaders hand untrusted bytes to.
    pub fn from_parts(
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
        d: usize,
    ) -> Result<CsrBlock> {
        if indptr.first() != Some(&0) {
            return Err(Error::parse("CSR indptr must start at 0"));
        }
        if indices.len() != values.len() {
            return Err(Error::parse("CSR indices/values length mismatch"));
        }
        if *indptr.last().unwrap() != indices.len() {
            return Err(Error::parse("CSR indptr does not cover the value buffer"));
        }
        if indptr.windows(2).any(|w| w[0] > w[1]) {
            return Err(Error::parse("CSR indptr must be non-decreasing"));
        }
        for r in 0..indptr.len() - 1 {
            let mut prev: Option<u32> = None;
            for &c in &indices[indptr[r]..indptr[r + 1]] {
                if (c as usize) >= d {
                    return Err(Error::parse(format!(
                        "CSR column {c} out of range (d = {d})"
                    )));
                }
                if prev.is_some_and(|p| c <= p) {
                    return Err(Error::parse("CSR columns must be strictly ascending"));
                }
                prev = Some(c);
            }
        }
        Ok(CsrBlock {
            indptr,
            indices,
            values,
            d,
        })
    }

    /// Owned copy of a borrowed CSR view (`indptr` rebased to 0).
    pub fn from_csr(rows: CsrRows) -> CsrBlock {
        let mut block = CsrBlock {
            indptr: Vec::with_capacity(rows.len() + 1),
            indices: Vec::with_capacity(rows.nnz()),
            values: Vec::with_capacity(rows.nnz()),
            d: rows.dim(),
        };
        block.indptr.push(0);
        for i in 0..rows.len() {
            let (cols, vals) = rows.row(i);
            block.indices.extend_from_slice(cols);
            block.values.extend_from_slice(vals);
            block.indptr.push(block.indices.len());
        }
        block
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        self.indptr.len() - 1
    }

    /// True when the block holds no rows.
    pub fn is_empty(&self) -> bool {
        self.indptr.len() <= 1
    }

    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Stored entries.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Borrowed view over the rows.
    pub fn view(&self) -> CsrRows<'_> {
        CsrRows::new(&self.indptr, &self.indices, &self.values, self.d)
    }

    /// Row offsets (`len + 1` entries, starting at 0).
    pub fn indptr(&self) -> &[usize] {
        &self.indptr
    }

    /// Column indices, strictly ascending within each row.
    pub fn indices(&self) -> &[u32] {
        &self.indices
    }

    /// Stored values.
    pub fn values(&self) -> &[f32] {
        &self.values
    }

    /// The block restricted to the rows where `keep` is true — support-
    /// vector compaction for CSR-backed stores.
    pub fn filter_rows(&self, keep: &[bool]) -> CsrBlock {
        assert_eq!(keep.len(), self.len(), "keep mask/rows length mismatch");
        let mut out = CsrBlock {
            indptr: vec![0],
            indices: Vec::new(),
            values: Vec::new(),
            d: self.d,
        };
        for (i, &k) in keep.iter().enumerate() {
            if k {
                let (cols, vals) = self.view().row(i);
                out.indices.extend_from_slice(cols);
                out.values.extend_from_slice(vals);
                out.indptr.push(out.indices.len());
            }
        }
        out
    }
}

/// Owned, reusable CSR gather buffer: the sparse twin of the dense
/// `Vec<f32>` the solvers pass to `Dataset::gather_into`, so the hot
/// loop stays allocation-free after warmup.
#[derive(Debug, Clone)]
pub struct CsrBatch {
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
    d: usize,
}

impl Default for CsrBatch {
    fn default() -> Self {
        CsrBatch {
            indptr: vec![0],
            indices: Vec::new(),
            values: Vec::new(),
            d: 0,
        }
    }
}

impl CsrBatch {
    /// Borrowed view of the gathered rows.
    pub fn view(&self) -> Rows<'_> {
        Rows::Csr(CsrRows::new(&self.indptr, &self.indices, &self.values, self.d))
    }

    /// Reset to `0` rows of dimensionality `d`.
    fn reset(&mut self, d: usize) {
        self.indptr.clear();
        self.indptr.push(0);
        self.indices.clear();
        self.values.clear();
        self.d = d;
    }
}

/// Validate and append one CSR row to `(indptr, indices, values)`.
fn push_csr_row(
    indptr: &mut Vec<usize>,
    indices: &mut Vec<u32>,
    values: &mut Vec<f32>,
    d: usize,
    cols: &[u32],
    vals: &[f32],
) {
    assert_eq!(cols.len(), vals.len(), "cols/vals length mismatch");
    let mut prev: Option<u32> = None;
    for &c in cols {
        assert!((c as usize) < d, "column {c} out of range (d = {d})");
        assert!(
            prev.is_none_or(|p| c > p),
            "columns must be strictly ascending"
        );
        prev = Some(c);
    }
    indices.extend_from_slice(cols);
    values.extend_from_slice(vals);
    indptr.push(indices.len());
}

/// CSR binary-classification dataset: the sparse twin of [`Dataset`],
/// with labels in `{-1, +1}` and the same gather/subset/split/sample
/// surface. Feature rows lower to [`Rows::Csr`] views; nothing is ever
/// densified on the training path.
#[derive(Clone, Debug)]
pub struct SparseDataset {
    /// Row offsets, `len == n + 1`.
    indptr: Vec<usize>,
    /// Column indices, strictly ascending within each row.
    indices: Vec<u32>,
    /// Stored values (explicit zeros are kept).
    values: Vec<f32>,
    /// Labels in {-1, +1}, `len == n`.
    pub y: Vec<f32>,
    /// Number of feature dimensions.
    pub d: usize,
}

impl SparseDataset {
    /// Empty dataset with fixed dimensionality.
    pub fn with_dim(d: usize) -> Self {
        SparseDataset {
            indptr: vec![0],
            indices: Vec::new(),
            values: Vec::new(),
            y: Vec::new(),
            d,
        }
    }

    /// Number of examples.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    /// True if the dataset holds no examples.
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Stored entries.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Append one example given its `(ascending column, value)` pairs.
    pub fn push(&mut self, cols: &[u32], vals: &[f32], label: f32) {
        assert!(label == 1.0 || label == -1.0, "label must be ±1");
        push_csr_row(
            &mut self.indptr,
            &mut self.indices,
            &mut self.values,
            self.d,
            cols,
            vals,
        );
        self.y.push(label);
    }

    /// Row `i` as `(column indices, values)`.
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        self.csr().row(i)
    }

    /// CSR view over all rows.
    pub fn csr(&self) -> CsrRows<'_> {
        CsrRows::new(&self.indptr, &self.indices, &self.values, self.d)
    }

    /// [`Rows`] view over all rows (what prediction paths consume).
    pub fn rows(&self) -> Rows<'_> {
        Rows::Csr(self.csr())
    }

    /// Gather the rows at `idx` into a reusable CSR batch — the sparse
    /// twin of [`Dataset::gather_into`].
    pub fn gather_into(&self, idx: &[usize], out: &mut CsrBatch) {
        gather_csr_rows(self.csr(), idx, out);
    }

    /// Gather labels at `idx` into `out`.
    pub fn gather_labels_into(&self, idx: &[usize], out: &mut Vec<f32>) {
        out.clear();
        out.extend(idx.iter().map(|&i| self.y[i]));
    }

    /// Subset by indices (allocating convenience wrapper).
    pub fn subset(&self, idx: &[usize]) -> SparseDataset {
        let mut out = SparseDataset::with_dim(self.d);
        for &i in idx {
            let (cols, vals) = self.row(i);
            out.push(cols, vals, self.y[i]);
        }
        out
    }

    /// Random split into `(train, test)` with `frac` of rows in train.
    pub fn split<R: Rng>(&self, frac: f64, rng: &mut R) -> (SparseDataset, SparseDataset) {
        let n = self.len();
        let n_train = ((n as f64) * frac).round() as usize;
        let train_idx = sample_without_replacement(rng, n, n_train);
        let mut in_train = vec![false; n];
        for &i in &train_idx {
            in_train[i] = true;
        }
        let test_idx: Vec<usize> = (0..n).filter(|&i| !in_train[i]).collect();
        (self.subset(&train_idx), self.subset(&test_idx))
    }

    /// Draw `min(k, n)` rows uniformly without replacement.
    pub fn sample<R: Rng>(&self, k: usize, rng: &mut R) -> SparseDataset {
        let k = k.min(self.len());
        let idx = sample_without_replacement(rng, self.len(), k);
        self.subset(&idx)
    }

    /// Fraction of positive labels.
    pub fn positive_rate(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        self.y.iter().filter(|&&v| v > 0.0).count() as f64 / self.len() as f64
    }

    /// Fraction of exactly-zero feature entries, computed in O(nnz)
    /// from the CSR arrays (implicit zeros plus any explicitly stored
    /// `0.0` values) — same definition as [`Dataset::sparsity`], never
    /// materialising the `n * d` grid.
    pub fn sparsity(&self) -> f64 {
        let total = self.len() * self.d;
        if total == 0 {
            return 0.0;
        }
        let stored_nonzero = self.values.iter().filter(|&&v| v != 0.0).count();
        (total - stored_nonzero) as f64 / total as f64
    }

    /// Multiply every stored value by `scale[column]` (zeros stay
    /// implicit — the transform CSR-safe scalers use).
    pub fn scale_columns(&mut self, scale: &[f32]) {
        assert_eq!(scale.len(), self.d, "scale/d mismatch");
        for (c, v) in self.indices.iter().zip(self.values.iter_mut()) {
            *v *= scale[*c as usize];
        }
    }

    /// Densify the feature rows into a row-major `[n, d]` buffer.
    pub fn densify_x(&self) -> Vec<f32> {
        let mut x = Vec::new();
        self.rows().to_dense_into(&mut x);
        x
    }

    /// Densify into an owned [`Dataset`] (tests / model construction).
    pub fn to_dense(&self) -> Dataset {
        Dataset {
            x: self.densify_x(),
            y: self.y.clone(),
            d: self.d,
        }
    }

    /// CSR copy of a dense dataset (zeros dropped).
    pub fn from_dense(ds: &Dataset) -> SparseDataset {
        let mut out = SparseDataset::with_dim(ds.d);
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        for i in 0..ds.len() {
            cols.clear();
            vals.clear();
            for (c, &v) in ds.row(i).iter().enumerate() {
                if v != 0.0 {
                    cols.push(c as u32);
                    vals.push(v);
                }
            }
            out.push(&cols, &vals, ds.y[i]);
        }
        out
    }
}

/// CSR **multiclass** dataset: the sparse twin of [`MultiDataset`] with
/// class-id labels `0..n_classes` and per-class ±1 label views over the
/// shared rows (the K-head training surface).
#[derive(Clone, Debug)]
pub struct SparseMultiDataset {
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
    /// Class ids in `0..n_classes`, `len == n`.
    pub y: Vec<u32>,
    /// Number of feature dimensions.
    pub d: usize,
    /// Number of classes K.
    pub n_classes: usize,
}

impl SparseMultiDataset {
    /// Empty dataset with fixed dimensionality and class count.
    pub fn with_dims(d: usize, n_classes: usize) -> Self {
        SparseMultiDataset {
            indptr: vec![0],
            indices: Vec::new(),
            values: Vec::new(),
            y: Vec::new(),
            d,
            n_classes,
        }
    }

    /// Number of examples.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    /// True if the dataset holds no examples.
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Stored entries.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Append one example.
    pub fn push(&mut self, cols: &[u32], vals: &[f32], class: u32) {
        assert!(
            (class as usize) < self.n_classes,
            "class {class} out of range (K = {})",
            self.n_classes
        );
        push_csr_row(
            &mut self.indptr,
            &mut self.indices,
            &mut self.values,
            self.d,
            cols,
            vals,
        );
        self.y.push(class);
    }

    /// Row `i` as `(column indices, values)`.
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        self.csr().row(i)
    }

    /// CSR view over all rows.
    pub fn csr(&self) -> CsrRows<'_> {
        CsrRows::new(&self.indptr, &self.indices, &self.values, self.d)
    }

    /// [`Rows`] view over all rows.
    pub fn rows(&self) -> Rows<'_> {
        Rows::Csr(self.csr())
    }

    /// Gather the rows at `idx` into a reusable CSR batch, shared by
    /// all K heads of a fused step.
    pub fn gather_into(&self, idx: &[usize], out: &mut CsrBatch) {
        gather_csr_rows(self.csr(), idx, out);
    }

    /// The ±1 one-vs-rest label vector for `class` over the shared rows.
    pub fn class_labels(&self, class: u32) -> Vec<f32> {
        self.y
            .iter()
            .map(|&c| if c == class { 1.0 } else { -1.0 })
            .collect()
    }

    /// Gather the ±1 one-vs-rest labels of `class` at `idx` into `out`.
    pub fn gather_class_labels_into(&self, class: u32, idx: &[usize], out: &mut Vec<f32>) {
        out.clear();
        out.extend(
            idx.iter()
                .map(|&i| if self.y[i] == class { 1.0 } else { -1.0 }),
        );
    }

    /// One-vs-rest binary view (copies the CSR arrays; training paths
    /// use the label views above instead).
    pub fn binary_view(&self, class: u32) -> SparseDataset {
        SparseDataset {
            indptr: self.indptr.clone(),
            indices: self.indices.clone(),
            values: self.values.clone(),
            y: self.class_labels(class),
            d: self.d,
        }
    }

    /// Subset by indices.
    pub fn subset(&self, idx: &[usize]) -> SparseMultiDataset {
        let mut out = SparseMultiDataset::with_dims(self.d, self.n_classes);
        for &i in idx {
            let (cols, vals) = self.row(i);
            out.push(cols, vals, self.y[i]);
        }
        out
    }

    /// Random split into `(train, test)` with `frac` of rows in train.
    pub fn split<R: Rng>(&self, frac: f64, rng: &mut R) -> (SparseMultiDataset, SparseMultiDataset) {
        let n = self.len();
        let n_train = ((n as f64) * frac).round() as usize;
        let train_idx = sample_without_replacement(rng, n, n_train);
        let mut in_train = vec![false; n];
        for &i in &train_idx {
            in_train[i] = true;
        }
        let test_idx: Vec<usize> = (0..n).filter(|&i| !in_train[i]).collect();
        (self.subset(&train_idx), self.subset(&test_idx))
    }

    /// Examples per class.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_classes];
        for &c in &self.y {
            counts[c as usize] += 1;
        }
        counts
    }

    /// Fraction of exactly-zero feature entries, O(nnz) from CSR.
    pub fn sparsity(&self) -> f64 {
        let total = self.len() * self.d;
        if total == 0 {
            return 0.0;
        }
        let stored_nonzero = self.values.iter().filter(|&&v| v != 0.0).count();
        (total - stored_nonzero) as f64 / total as f64
    }

    /// Multiply every stored value by `scale[column]`.
    pub fn scale_columns(&mut self, scale: &[f32]) {
        assert_eq!(scale.len(), self.d, "scale/d mismatch");
        for (c, v) in self.indices.iter().zip(self.values.iter_mut()) {
            *v *= scale[*c as usize];
        }
    }

    /// Densify the feature rows into a row-major `[n, d]` buffer.
    pub fn densify_x(&self) -> Vec<f32> {
        let mut x = Vec::new();
        self.rows().to_dense_into(&mut x);
        x
    }

    /// Densify into an owned [`MultiDataset`].
    pub fn to_dense(&self) -> MultiDataset {
        MultiDataset {
            x: self.densify_x(),
            y: self.y.clone(),
            d: self.d,
            n_classes: self.n_classes,
        }
    }

    /// CSR copy of a dense multiclass dataset (zeros dropped).
    pub fn from_dense(ds: &MultiDataset) -> SparseMultiDataset {
        let mut out = SparseMultiDataset::with_dims(ds.d, ds.n_classes);
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        for i in 0..ds.len() {
            cols.clear();
            vals.clear();
            for (c, &v) in ds.row(i).iter().enumerate() {
                if v != 0.0 {
                    cols.push(c as u32);
                    vals.push(v);
                }
            }
            out.push(&cols, &vals, ds.y[i]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn toy() -> SparseDataset {
        let mut ds = SparseDataset::with_dim(5);
        ds.push(&[0, 3], &[1.0, 2.0], 1.0);
        ds.push(&[], &[], -1.0);
        ds.push(&[1, 2, 4], &[-0.5, 0.25, 3.0], 1.0);
        ds.push(&[4], &[7.0], -1.0);
        ds
    }

    #[test]
    fn push_row_and_views() {
        let ds = toy();
        assert_eq!(ds.len(), 4);
        assert_eq!(ds.nnz(), 6);
        let (c, v) = ds.row(2);
        assert_eq!(c, &[1, 2, 4]);
        assert_eq!(v, &[-0.5, 0.25, 3.0]);
        assert_eq!(ds.rows().len(), 4);
        assert_eq!(ds.rows().dim(), 5);
        assert!(!ds.rows().is_dense());
    }

    #[test]
    #[should_panic(expected = "strictly ascending")]
    fn push_rejects_unsorted() {
        let mut ds = SparseDataset::with_dim(5);
        ds.push(&[3, 1], &[1.0, 1.0], 1.0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn push_rejects_out_of_range_column() {
        let mut ds = SparseDataset::with_dim(3);
        ds.push(&[3], &[1.0], 1.0);
    }

    #[test]
    fn densify_matches_manual() {
        let ds = toy();
        let dense = ds.to_dense();
        assert_eq!(dense.row(0), &[1.0, 0.0, 0.0, 2.0, 0.0]);
        assert_eq!(dense.row(1), &[0.0; 5]);
        assert_eq!(dense.row(2), &[0.0, -0.5, 0.25, 0.0, 3.0]);
        assert_eq!(dense.row(3), &[0.0, 0.0, 0.0, 0.0, 7.0]);
        assert_eq!(dense.y, ds.y);
        // from_dense round-trips back to the same CSR content.
        let back = SparseDataset::from_dense(&dense);
        assert_eq!(back.indptr, ds.indptr);
        assert_eq!(back.indices, ds.indices);
        assert_eq!(back.values, ds.values);
    }

    #[test]
    fn gather_matches_subset_and_dense_gather() {
        let ds = toy();
        let idx = [3usize, 0, 2, 0];
        let mut batch = CsrBatch::default();
        ds.gather_into(&idx, &mut batch);
        assert_eq!(batch.view().len(), 4);
        let sub = ds.subset(&idx);
        let mut got = Vec::new();
        batch.view().to_dense_into(&mut got);
        let mut want = Vec::new();
        ds.to_dense().gather_into(&idx, &mut want);
        assert_eq!(got, want);
        assert_eq!(sub.densify_x(), want);
        let mut lab = Vec::new();
        ds.gather_labels_into(&idx, &mut lab);
        assert_eq!(lab, vec![-1.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn slice_views_rows() {
        let ds = toy();
        let rows = ds.rows();
        let s = rows.slice(1, 3);
        assert_eq!(s.len(), 2);
        let mut got = Vec::new();
        s.to_dense_into(&mut got);
        let dense = ds.densify_x();
        assert_eq!(got, dense[5..15].to_vec());
        // Dense slicing agrees.
        let dr = Rows::dense(&dense, 4, 5);
        let mut got2 = Vec::new();
        dr.slice(1, 3).to_dense_into(&mut got2);
        assert_eq!(got, got2);
    }

    #[test]
    fn split_partitions_sparse() {
        let ds = toy();
        let mut rng = Pcg64::seed_from(3);
        let (tr, te) = ds.split(0.5, &mut rng);
        assert_eq!(tr.len() + te.len(), 4);
        assert_eq!(tr.len(), 2);
        assert_eq!(tr.d, 5);
        // Same split as the densified copy under the same seed.
        let mut rng2 = Pcg64::seed_from(3);
        let (dtr, dte) = ds.to_dense().split(0.5, &mut rng2);
        assert_eq!(tr.densify_x(), dtr.x);
        assert_eq!(te.densify_x(), dte.x);
    }

    #[test]
    fn sparsity_matches_dense_in_o_nnz() {
        let ds = toy();
        // 6 stored entries, all nonzero, over 20 cells -> 0.7 zero.
        assert!((ds.sparsity() - 0.7).abs() < 1e-12);
        assert_eq!(ds.sparsity(), ds.to_dense().sparsity());
        // Explicitly stored zeros count as zeros, like the dense scan.
        let mut with_zero = SparseDataset::with_dim(2);
        with_zero.push(&[0, 1], &[0.0, 1.0], 1.0);
        assert_eq!(with_zero.sparsity(), with_zero.to_dense().sparsity());
        assert!((with_zero.sparsity() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn stats_match_dense() {
        let ds = toy();
        assert_eq!(ds.positive_rate(), ds.to_dense().positive_rate());
        let mut rng = Pcg64::seed_from(9);
        assert_eq!(ds.sample(100, &mut rng).len(), 4);
    }

    fn toy_multi() -> SparseMultiDataset {
        let mut ds = SparseMultiDataset::with_dims(4, 3);
        ds.push(&[0], &[1.0], 0);
        ds.push(&[1, 3], &[2.0, -1.0], 1);
        ds.push(&[2], &[0.5], 2);
        ds.push(&[0, 2], &[3.0, 4.0], 1);
        ds
    }

    #[test]
    fn multi_surface_matches_dense_twin() {
        let ds = toy_multi();
        let dense = ds.to_dense();
        assert_eq!(ds.class_counts(), dense.class_counts());
        for class in 0..3u32 {
            assert_eq!(ds.class_labels(class), dense.class_labels(class));
            let idx = [3usize, 1, 0];
            let (mut a, mut b) = (Vec::new(), Vec::new());
            ds.gather_class_labels_into(class, &idx, &mut a);
            dense.gather_class_labels_into(class, &idx, &mut b);
            assert_eq!(a, b);
        }
        let bv = ds.binary_view(1);
        assert_eq!(bv.y, dense.binary_view(1).y);
        assert_eq!(bv.densify_x(), dense.x);
        assert_eq!(
            SparseMultiDataset::from_dense(&dense).densify_x(),
            dense.x
        );
        assert_eq!(ds.sparsity(), dense.sparsity());
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn multi_push_rejects_bad_class() {
        let mut ds = SparseMultiDataset::with_dims(2, 2);
        ds.push(&[0], &[1.0], 2);
    }

    #[test]
    fn scale_columns_scales_stored_values() {
        let mut ds = toy();
        ds.scale_columns(&[2.0, 1.0, 1.0, 0.5, 1.0]);
        let dense = ds.to_dense();
        assert_eq!(dense.row(0), &[2.0, 0.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn rows_gather_into_matches_dataset_gathers_both_layouts() {
        let ds = toy();
        let dense = ds.to_dense();
        let idx = [3usize, 0, 2, 0];
        // CSR source -> CSR batch, identical to SparseDataset::gather_into.
        let mut batch = GatherBatch::default();
        ds.rows().gather_into(&idx, &mut batch);
        assert!(!batch.view().is_dense());
        let mut want_csr = CsrBatch::default();
        ds.gather_into(&idx, &mut want_csr);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        batch.view().to_dense_into(&mut a);
        want_csr.view().to_dense_into(&mut b);
        assert_eq!(a, b);
        // Dense source -> dense batch, identical to Dataset::gather_into
        // (the buffers the unified solver loop feeds the backend are
        // bitwise the ones the old per-layout loops built).
        let dr = Rows::dense(&dense.x, dense.len(), dense.d);
        dr.gather_into(&idx, &mut batch);
        assert!(batch.view().is_dense());
        let mut want_dense = Vec::new();
        dense.gather_into(&idx, &mut want_dense);
        assert_eq!(batch.view().as_dense().unwrap(), &want_dense[..]);
        // The batch variant follows the source on re-gather (layout flip
        // is supported, even though loops never need it).
        ds.rows().gather_into(&idx, &mut batch);
        assert!(!batch.view().is_dense());
    }

    #[test]
    fn csr_block_copies_filters_and_validates() {
        let ds = toy();
        let block = CsrBlock::from_csr(ds.csr());
        assert_eq!(block.len(), 4);
        assert_eq!(block.dim(), 5);
        assert_eq!(block.nnz(), 6);
        let mut got = Vec::new();
        Rows::Csr(block.view()).to_dense_into(&mut got);
        assert_eq!(got, ds.densify_x());
        // A block copied from a mid-buffer slice is rebased to 0.
        let tail = CsrBlock::from_csr(ds.csr().slice(2, 4));
        assert_eq!(tail.indptr()[0], 0);
        assert_eq!(tail.len(), 2);
        let mut t = Vec::new();
        Rows::Csr(tail.view()).to_dense_into(&mut t);
        assert_eq!(t, ds.densify_x()[10..].to_vec());
        // Row filtering keeps exactly the marked rows.
        let kept = block.filter_rows(&[true, false, false, true]);
        assert_eq!(kept.len(), 2);
        let mut k = Vec::new();
        Rows::Csr(kept.view()).to_dense_into(&mut k);
        let full = ds.densify_x();
        assert_eq!(&k[..5], &full[..5]);
        assert_eq!(&k[5..], &full[15..]);
        // from_parts round-trips valid parts and rejects broken ones.
        let ok = CsrBlock::from_parts(
            block.indptr().to_vec(),
            block.indices().to_vec(),
            block.values().to_vec(),
            5,
        )
        .unwrap();
        assert_eq!(ok, block);
        assert!(CsrBlock::from_parts(vec![], vec![], vec![], 5).is_err());
        assert!(CsrBlock::from_parts(vec![1, 2], vec![0, 1], vec![1.0, 1.0], 5).is_err());
        assert!(CsrBlock::from_parts(vec![0, 2], vec![0], vec![1.0], 5).is_err());
        assert!(CsrBlock::from_parts(vec![0, 2, 1], vec![0, 1], vec![1.0, 1.0], 5).is_err());
        assert!(CsrBlock::from_parts(vec![0, 1], vec![7], vec![1.0], 5).is_err());
        assert!(CsrBlock::from_parts(vec![0, 2], vec![3, 1], vec![1.0, 1.0], 5).is_err());
        assert!(CsrBlock::from_parts(vec![0, 2], vec![1, 1], vec![1.0, 1.0], 5).is_err());
    }
}
