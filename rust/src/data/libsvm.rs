//! libsvm/svmlight format reader and writer.
//!
//! The paper's real-world sets come from the libsvm repository in this
//! format: one example per line, `label idx:val idx:val ...` with 1-based
//! ascending indices and implicit zeros. We support reading into a dense
//! [`Dataset`] (dimensionality inferred or given), comment lines (`#`),
//! and label conventions `{-1,1}`, `{0,1}` and `{1,2}` (covertype
//! binarised 2-vs-rest, as the paper uses).

use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

use super::Dataset;
use crate::{Error, Result};

/// How to map raw labels onto {-1, +1}.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum LabelMap {
    /// Accept -1/+1; 0 maps to -1 (libsvm binary convention).
    #[default]
    Standard,
    /// `positive_class` vs rest (e.g. covertype class 2 vs rest).
    OneVsRest(i32),
}

impl LabelMap {
    fn map(&self, raw: f64) -> f32 {
        match self {
            LabelMap::Standard => {
                if raw > 0.0 {
                    1.0
                } else {
                    -1.0
                }
            }
            LabelMap::OneVsRest(pos) => {
                if (raw - *pos as f64).abs() < 0.5 {
                    1.0
                } else {
                    -1.0
                }
            }
        }
    }
}

/// Parse a libsvm-format stream. `dim` forces the dimensionality (entries
/// beyond it error out); `None` infers it from the max index seen.
pub fn read<R: Read>(reader: R, dim: Option<usize>, labels: LabelMap) -> Result<Dataset> {
    let mut rows: Vec<(f32, Vec<(usize, f32)>)> = Vec::new();
    let mut max_idx = 0usize;
    for (lineno, line) in BufReader::new(reader).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label_tok = parts
            .next()
            .ok_or_else(|| Error::parse(format!("line {}: empty", lineno + 1)))?;
        let raw: f64 = label_tok.parse().map_err(|e| {
            Error::parse(format!("line {}: bad label '{label_tok}': {e}", lineno + 1))
        })?;
        let mut feats = Vec::new();
        let mut prev_idx = 0usize;
        for tok in parts {
            if tok.starts_with('#') {
                break; // trailing comment
            }
            let (idx_s, val_s) = tok.split_once(':').ok_or_else(|| {
                Error::parse(format!("line {}: bad pair '{tok}'", lineno + 1))
            })?;
            let idx: usize = idx_s.parse().map_err(|e| {
                Error::parse(format!("line {}: bad index '{idx_s}': {e}", lineno + 1))
            })?;
            if idx == 0 {
                return Err(Error::parse(format!(
                    "line {}: libsvm indices are 1-based",
                    lineno + 1
                )));
            }
            if idx <= prev_idx {
                return Err(Error::parse(format!(
                    "line {}: indices must be strictly ascending",
                    lineno + 1
                )));
            }
            prev_idx = idx;
            let val: f32 = val_s.parse().map_err(|e| {
                Error::parse(format!("line {}: bad value '{val_s}': {e}", lineno + 1))
            })?;
            feats.push((idx - 1, val));
            max_idx = max_idx.max(idx);
        }
        rows.push((labels.map(raw), feats));
    }
    let d = match dim {
        Some(d) => {
            if max_idx > d {
                return Err(Error::parse(format!(
                    "feature index {max_idx} exceeds declared dim {d}"
                )));
            }
            d
        }
        None => max_idx,
    };
    let mut ds = Dataset::with_dim(d);
    let mut dense = vec![0.0f32; d];
    for (label, feats) in rows {
        dense.fill(0.0);
        for (idx, val) in feats {
            dense[idx] = val;
        }
        ds.push(&dense, label);
    }
    Ok(ds)
}

/// Read a libsvm file from disk.
pub fn read_file<P: AsRef<Path>>(path: P, dim: Option<usize>, labels: LabelMap) -> Result<Dataset> {
    read(std::fs::File::open(path)?, dim, labels)
}

/// Write a dataset in libsvm format (zeros skipped).
pub fn write<W: Write>(ds: &Dataset, mut w: W) -> Result<()> {
    for i in 0..ds.len() {
        let label = if ds.y[i] > 0.0 { "+1" } else { "-1" };
        write!(w, "{label}")?;
        for (j, &v) in ds.row(i).iter().enumerate() {
            if v != 0.0 {
                write!(w, " {}:{}", j + 1, v)?;
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic() {
        let text = "+1 1:0.5 3:1.5\n-1 2:2.0\n";
        let ds = read(text.as_bytes(), None, LabelMap::Standard).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.d, 3);
        assert_eq!(ds.row(0), &[0.5, 0.0, 1.5]);
        assert_eq!(ds.row(1), &[0.0, 2.0, 0.0]);
        assert_eq!(ds.y, vec![1.0, -1.0]);
    }

    #[test]
    fn zero_one_labels() {
        let text = "1 1:1\n0 1:2\n";
        let ds = read(text.as_bytes(), None, LabelMap::Standard).unwrap();
        assert_eq!(ds.y, vec![1.0, -1.0]);
    }

    #[test]
    fn one_vs_rest_labels() {
        let text = "1 1:1\n2 1:2\n7 1:3\n";
        let ds = read(text.as_bytes(), None, LabelMap::OneVsRest(2)).unwrap();
        assert_eq!(ds.y, vec![-1.0, 1.0, -1.0]);
    }

    #[test]
    fn skips_comments_and_blanks() {
        let text = "# header\n\n+1 1:1.0 # trailing\n";
        let ds = read(text.as_bytes(), None, LabelMap::Standard).unwrap();
        assert_eq!(ds.len(), 1);
        assert_eq!(ds.row(0), &[1.0]);
    }

    #[test]
    fn forced_dim() {
        let text = "+1 2:1.0\n";
        let ds = read(text.as_bytes(), Some(5), LabelMap::Standard).unwrap();
        assert_eq!(ds.d, 5);
        assert_eq!(ds.row(0), &[0.0, 1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(read("x 1:1\n".as_bytes(), None, LabelMap::Standard).is_err());
        assert!(read("+1 0:1\n".as_bytes(), None, LabelMap::Standard).is_err());
        assert!(read("+1 2:1 1:1\n".as_bytes(), None, LabelMap::Standard).is_err());
        assert!(read("+1 1:x\n".as_bytes(), None, LabelMap::Standard).is_err());
        assert!(read("+1 9:1\n".as_bytes(), Some(3), LabelMap::Standard).is_err());
    }

    #[test]
    fn roundtrip() {
        let text = "+1 1:0.5 3:1.5\n-1 2:2\n";
        let ds = read(text.as_bytes(), None, LabelMap::Standard).unwrap();
        let mut buf = Vec::new();
        write(&ds, &mut buf).unwrap();
        let ds2 = read(buf.as_slice(), Some(3), LabelMap::Standard).unwrap();
        assert_eq!(ds.x, ds2.x);
        assert_eq!(ds.y, ds2.y);
    }
}
