//! libsvm/svmlight format reader and writer.
//!
//! The paper's real-world sets come from the libsvm repository in this
//! format: one example per line, `label idx:val idx:val ...` with 1-based
//! ascending indices and implicit zeros. We support reading into a dense
//! [`Dataset`] (dimensionality inferred or given), comment lines (`#`),
//! label conventions `{-1,1}`, `{0,1}` and `{1,2}` (covertype binarised
//! 2-vs-rest, as the paper uses), **multiclass** targets into a
//! [`MultiDataset`] (covertype's native 7 classes), and the 0-based
//! index convention some exporters emit ([`IndexBase::Zero`]).

use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

use super::{Dataset, MultiDataset};
use crate::{Error, Result};

/// How to map raw labels onto {-1, +1}.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum LabelMap {
    /// Accept -1/+1; 0 maps to -1 (libsvm binary convention).
    #[default]
    Standard,
    /// `positive_class` vs rest (e.g. covertype class 2 vs rest).
    OneVsRest(i32),
}

impl LabelMap {
    fn map(&self, raw: f64) -> f32 {
        match self {
            LabelMap::Standard => {
                if raw > 0.0 {
                    1.0
                } else {
                    -1.0
                }
            }
            LabelMap::OneVsRest(pos) => {
                if (raw - *pos as f64).abs() < 0.5 {
                    1.0
                } else {
                    -1.0
                }
            }
        }
    }
}

/// Feature index convention of the input stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum IndexBase {
    /// Standard libsvm: 1-based strictly ascending; index 0 is an error.
    #[default]
    One,
    /// 0-based strictly ascending, as some exporters write.
    Zero,
}

/// One parsed line: raw label + sparse (0-based index, value) pairs.
type SparseRow = (f64, Vec<(usize, f32)>);

/// Parse the sparse rows of a libsvm stream. Returns the rows plus the
/// inferred dimensionality (max feature index seen, in 0-based terms,
/// plus one).
fn parse_rows<R: Read>(reader: R, base: IndexBase) -> Result<(Vec<SparseRow>, usize)> {
    let mut rows: Vec<SparseRow> = Vec::new();
    let mut d_seen = 0usize;
    for (lineno, line) in BufReader::new(reader).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label_tok = parts
            .next()
            .ok_or_else(|| Error::parse(format!("line {}: empty", lineno + 1)))?;
        let raw: f64 = label_tok.parse().map_err(|e| {
            Error::parse(format!("line {}: bad label '{label_tok}': {e}", lineno + 1))
        })?;
        let mut feats = Vec::new();
        let mut prev: Option<usize> = None;
        for tok in parts {
            if tok.starts_with('#') {
                break; // trailing comment
            }
            let (idx_s, val_s) = tok.split_once(':').ok_or_else(|| {
                Error::parse(format!("line {}: bad pair '{tok}'", lineno + 1))
            })?;
            let idx: usize = idx_s.parse().map_err(|e| {
                Error::parse(format!("line {}: bad index '{idx_s}': {e}", lineno + 1))
            })?;
            let idx0 = match base {
                IndexBase::One => {
                    if idx == 0 {
                        return Err(Error::parse(format!(
                            "line {}: libsvm indices are 1-based (use IndexBase::Zero \
                             for 0-based files)",
                            lineno + 1
                        )));
                    }
                    idx - 1
                }
                IndexBase::Zero => idx,
            };
            if prev.is_some_and(|p| idx0 <= p) {
                return Err(Error::parse(format!(
                    "line {}: indices must be strictly ascending",
                    lineno + 1
                )));
            }
            prev = Some(idx0);
            let val: f32 = val_s.parse().map_err(|e| {
                Error::parse(format!("line {}: bad value '{val_s}': {e}", lineno + 1))
            })?;
            feats.push((idx0, val));
            d_seen = d_seen.max(idx0 + 1);
        }
        rows.push((raw, feats));
    }
    Ok((rows, d_seen))
}

/// Resolve the dense dimensionality: forced (`Some`) or inferred.
fn resolve_dim(dim: Option<usize>, d_seen: usize) -> Result<usize> {
    match dim {
        Some(d) => {
            if d_seen > d {
                Err(Error::parse(format!(
                    "feature index {d_seen} exceeds declared dim {d}"
                )))
            } else {
                Ok(d)
            }
        }
        None => Ok(d_seen),
    }
}

/// Parse a libsvm-format stream with an explicit index convention.
pub fn read_with_base<R: Read>(
    reader: R,
    dim: Option<usize>,
    labels: LabelMap,
    base: IndexBase,
) -> Result<Dataset> {
    let (rows, d_seen) = parse_rows(reader, base)?;
    let d = resolve_dim(dim, d_seen)?;
    let mut ds = Dataset::with_dim(d);
    let mut dense = vec![0.0f32; d];
    for (raw, feats) in rows {
        dense.fill(0.0);
        for (idx, val) in feats {
            dense[idx] = val;
        }
        ds.push(&dense, labels.map(raw));
    }
    Ok(ds)
}

/// Parse a libsvm-format stream (standard 1-based indices). `dim` forces
/// the dimensionality (entries beyond it error out); `None` infers it
/// from the max index seen.
pub fn read<R: Read>(reader: R, dim: Option<usize>, labels: LabelMap) -> Result<Dataset> {
    read_with_base(reader, dim, labels, IndexBase::One)
}

/// Read a libsvm file from disk.
pub fn read_file<P: AsRef<Path>>(path: P, dim: Option<usize>, labels: LabelMap) -> Result<Dataset> {
    read(std::fs::File::open(path)?, dim, labels)
}

/// Parse a libsvm stream with **multiclass** integer targets (e.g. the
/// native 7-class covertype file). Distinct labels are sorted ascending
/// and mapped to class ids `0..K`; non-integral labels are rejected.
///
/// The label → class-id mapping is derived from *this* stream's label
/// set. Models trained on the resulting class ids are only comparable
/// to datasets parsed from files with the **same** label set — a test
/// file missing one of the training labels would shift every id. When
/// evaluating a saved model on a second file, ensure both files carry
/// identical label sets (true for standard libsvm train/test pairs).
pub fn read_multiclass_with_base<R: Read>(
    reader: R,
    dim: Option<usize>,
    base: IndexBase,
) -> Result<MultiDataset> {
    let (rows, d_seen) = parse_rows(reader, base)?;
    let d = resolve_dim(dim, d_seen)?;
    let mut classes: Vec<i64> = Vec::new();
    for (raw, _) in &rows {
        if raw.fract().abs() > 1e-9 {
            return Err(Error::parse(format!(
                "multiclass label {raw} is not an integer"
            )));
        }
        let c = *raw as i64;
        if let Err(pos) = classes.binary_search(&c) {
            classes.insert(pos, c);
        }
    }
    let n_classes = classes.len().max(1);
    let mut ds = MultiDataset::with_dims(d, n_classes);
    let mut dense = vec![0.0f32; d];
    for (raw, feats) in rows {
        dense.fill(0.0);
        for (idx, val) in feats {
            dense[idx] = val;
        }
        let class = classes
            .binary_search(&(raw as i64))
            .expect("label registered above") as u32;
        ds.push(&dense, class);
    }
    Ok(ds)
}

/// Multiclass read with standard 1-based indices.
pub fn read_multiclass<R: Read>(reader: R, dim: Option<usize>) -> Result<MultiDataset> {
    read_multiclass_with_base(reader, dim, IndexBase::One)
}

/// Read a multiclass libsvm file from disk.
pub fn read_multiclass_file<P: AsRef<Path>>(
    path: P,
    dim: Option<usize>,
) -> Result<MultiDataset> {
    read_multiclass(std::fs::File::open(path)?, dim)
}

/// Write a dataset in libsvm format (zeros skipped).
pub fn write<W: Write>(ds: &Dataset, mut w: W) -> Result<()> {
    for i in 0..ds.len() {
        let label = if ds.y[i] > 0.0 { "+1" } else { "-1" };
        write!(w, "{label}")?;
        for (j, &v) in ds.row(i).iter().enumerate() {
            if v != 0.0 {
                write!(w, " {}:{}", j + 1, v)?;
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Write a multiclass dataset in libsvm format (class ids as labels,
/// zeros skipped).
pub fn write_multiclass<W: Write>(ds: &MultiDataset, mut w: W) -> Result<()> {
    for i in 0..ds.len() {
        write!(w, "{}", ds.y[i])?;
        for (j, &v) in ds.row(i).iter().enumerate() {
            if v != 0.0 {
                write!(w, " {}:{}", j + 1, v)?;
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic() {
        let text = "+1 1:0.5 3:1.5\n-1 2:2.0\n";
        let ds = read(text.as_bytes(), None, LabelMap::Standard).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.d, 3);
        assert_eq!(ds.row(0), &[0.5, 0.0, 1.5]);
        assert_eq!(ds.row(1), &[0.0, 2.0, 0.0]);
        assert_eq!(ds.y, vec![1.0, -1.0]);
    }

    #[test]
    fn zero_one_labels() {
        let text = "1 1:1\n0 1:2\n";
        let ds = read(text.as_bytes(), None, LabelMap::Standard).unwrap();
        assert_eq!(ds.y, vec![1.0, -1.0]);
    }

    #[test]
    fn one_vs_rest_labels() {
        let text = "1 1:1\n2 1:2\n7 1:3\n";
        let ds = read(text.as_bytes(), None, LabelMap::OneVsRest(2)).unwrap();
        assert_eq!(ds.y, vec![-1.0, 1.0, -1.0]);
    }

    #[test]
    fn skips_comments_and_blanks() {
        let text = "# header\n\n+1 1:1.0 # trailing\n";
        let ds = read(text.as_bytes(), None, LabelMap::Standard).unwrap();
        assert_eq!(ds.len(), 1);
        assert_eq!(ds.row(0), &[1.0]);
    }

    #[test]
    fn forced_dim() {
        let text = "+1 2:1.0\n";
        let ds = read(text.as_bytes(), Some(5), LabelMap::Standard).unwrap();
        assert_eq!(ds.d, 5);
        assert_eq!(ds.row(0), &[0.0, 1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(read("x 1:1\n".as_bytes(), None, LabelMap::Standard).is_err());
        assert!(read("+1 0:1\n".as_bytes(), None, LabelMap::Standard).is_err());
        assert!(read("+1 2:1 1:1\n".as_bytes(), None, LabelMap::Standard).is_err());
        assert!(read("+1 1:x\n".as_bytes(), None, LabelMap::Standard).is_err());
        assert!(read("+1 9:1\n".as_bytes(), Some(3), LabelMap::Standard).is_err());
    }

    #[test]
    fn malformed_pairs_and_indices() {
        // Missing colon, empty value, duplicate index, junk index.
        assert!(read("+1 1\n".as_bytes(), None, LabelMap::Standard).is_err());
        assert!(read("+1 1:\n".as_bytes(), None, LabelMap::Standard).is_err());
        assert!(read("+1 1:1 1:2\n".as_bytes(), None, LabelMap::Standard).is_err());
        assert!(read("+1 -3:1\n".as_bytes(), None, LabelMap::Standard).is_err());
        // Bad lines report their 1-based line number.
        let err = read("+1 1:1\n+1 0:9\n".as_bytes(), None, LabelMap::Standard)
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn zero_based_index_convention() {
        let text = "+1 0:0.5 2:1.5\n-1 1:2.0\n";
        // Rejected under the default 1-based convention...
        assert!(read(text.as_bytes(), None, LabelMap::Standard).is_err());
        // ...accepted with IndexBase::Zero, same dense layout as the
        // equivalent 1-based file.
        let ds = read_with_base(text.as_bytes(), None, LabelMap::Standard, IndexBase::Zero)
            .unwrap();
        assert_eq!(ds.d, 3);
        assert_eq!(ds.row(0), &[0.5, 0.0, 1.5]);
        assert_eq!(ds.row(1), &[0.0, 2.0, 0.0]);
        // Ascending check still applies in 0-based mode.
        assert!(read_with_base(
            "+1 1:1 0:1\n".as_bytes(),
            None,
            LabelMap::Standard,
            IndexBase::Zero
        )
        .is_err());
    }

    #[test]
    fn roundtrip() {
        let text = "+1 1:0.5 3:1.5\n-1 2:2\n";
        let ds = read(text.as_bytes(), None, LabelMap::Standard).unwrap();
        let mut buf = Vec::new();
        write(&ds, &mut buf).unwrap();
        let ds2 = read(buf.as_slice(), Some(3), LabelMap::Standard).unwrap();
        assert_eq!(ds.x, ds2.x);
        assert_eq!(ds.y, ds2.y);
    }

    #[test]
    fn multiclass_labels_sorted_and_mapped() {
        // Covtype-style 1..7 labels, out of order in the file.
        let text = "3 1:1\n1 1:2\n7 1:3\n3 1:4\n";
        let ds = read_multiclass(text.as_bytes(), None).unwrap();
        assert_eq!(ds.n_classes, 3); // distinct labels {1, 3, 7}
        assert_eq!(ds.y, vec![1, 0, 2, 1]); // sorted ascending -> ids
        assert_eq!(ds.len(), 4);
        assert_eq!(ds.class_counts(), vec![1, 2, 1]);
    }

    #[test]
    fn multiclass_rejects_fractional_labels() {
        assert!(read_multiclass("1.5 1:1\n".as_bytes(), None).is_err());
    }

    #[test]
    fn multiclass_roundtrip() {
        let mut src = MultiDataset::with_dims(3, 4);
        src.push(&[1.0, 0.0, 2.0], 0);
        src.push(&[0.0, 3.0, 0.0], 2);
        src.push(&[1.0, 1.0, 1.0], 3);
        let mut buf = Vec::new();
        write_multiclass(&src, &mut buf).unwrap();
        let ds = read_multiclass(buf.as_slice(), Some(3)).unwrap();
        assert_eq!(ds.x, src.x);
        // Class ids are re-derived from the sorted distinct labels
        // {0, 2, 3} -> {0, 1, 2}.
        assert_eq!(ds.y, vec![0, 1, 2]);
        assert_eq!(ds.n_classes, 3);
    }

    #[test]
    fn multiclass_respects_forced_dim_and_comments() {
        let text = "# covtype slice\n2 2:1.0\n5 1:0.5 # tail\n";
        let ds = read_multiclass(text.as_bytes(), Some(4)).unwrap();
        assert_eq!(ds.d, 4);
        assert_eq!(ds.row(0), &[0.0, 1.0, 0.0, 0.0]);
        assert_eq!(ds.y, vec![0, 1]);
        assert!(read_multiclass("2 9:1\n".as_bytes(), Some(3)).is_err());
    }
}
